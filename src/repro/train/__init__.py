from .state import (TrainState, protected_leaves, protected_structs,
                    replace_protected)
from .train_loop import make_train_step, make_redundancy_step, Trainer
