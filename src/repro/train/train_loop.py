"""Train-step factory + host Trainer, both driven by a ProtectedStore.

The redundancy lifecycle (dirty marking vs sync diff per leaf group,
Algorithm-1 scheduling, scrub double-check, straggler back-off, preemption
flush) lives behind :class:`repro.core.ProtectedStore`; this module only
wires the model/optimizer step into it.  The legacy
``Trainer(engine=..., mode=...)`` signature still works for one release via
the deprecation shim.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Mapping, Optional

import jax
import jax.numpy as jnp

from repro.core.engine import RedundancyEngine
from repro.core.store import ProtectedStore, as_store
from repro.optim.adamw import AdamW
from .state import TrainState, protected_leaves, replace_protected


def make_train_step(model, opt: AdamW,
                    store: Optional[Any] = None,
                    mode: Optional[str] = None,
                    accum_steps: int = 1) -> Callable:
    """accum_steps > 1 microbatches the global batch (gradient accumulation):
    activation memory scales down by the accumulation factor; gradients
    accumulate in fp32 across microbatches inside one jitted step.

    ``store`` is a ProtectedStore (or, deprecated, a RedundancyEngine paired
    with ``mode``)."""
    store = as_store(store, mode, caller="make_train_step")

    def grads_of(params, batch):
        if accum_steps == 1:
            return jax.value_and_grad(model.loss, has_aux=True)(params, batch)

        mb = jax.tree.map(
            lambda x: x.reshape(accum_steps, x.shape[0] // accum_steps,
                                *x.shape[1:]), batch)

        def mb_step(carry, microbatch):
            gacc, loss_acc, aux_acc = carry
            (loss, aux), g = jax.value_and_grad(
                model.loss, has_aux=True)(params, microbatch)
            gacc = jax.tree.map(lambda a, b: a + b.astype(jnp.float32), gacc, g)
            aux_acc = {
                "ce": aux_acc["ce"] + aux["ce"],
                "aux_loss": aux_acc["aux_loss"] + aux["aux_loss"],
                "expert_counts": aux_acc["expert_counts"] + aux["expert_counts"],
                "logits_mean": aux_acc["logits_mean"] + aux["logits_mean"],
            }
            return (gacc, loss_acc + loss, aux_acc), None

        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        aux0 = {"ce": jnp.float32(0), "aux_loss": jnp.float32(0),
                "expert_counts": jnp.zeros(
                    (model.cfg.n_groups, model.cfg.group_size,
                     max(model.cfg.n_experts, 1)), jnp.int32),
                "logits_mean": jnp.float32(0)}
        (gacc, loss_sum, aux_sum), _ = jax.lax.scan(
            mb_step, (g0, jnp.float32(0), aux0), mb,
            unroll=True if model.cfg.unroll_layers else 1)
        n = float(accum_steps)
        grads = jax.tree.map(lambda g: g / n, gacc)
        aux = {k: (v / n if k != "expert_counts" else v) for k, v in aux_sum.items()}
        return (loss_sum / n, aux), grads

    def train_step(state: TrainState, batch):
        (loss, aux), grads = grads_of(state.params, batch)
        if getattr(model.cfg, "opt_grad_barrier", False):
            # Keep the data-parallel gradient reduction on a bf16 wire: the
            # barrier stops XLA hoisting AdamW's f32 converts above the
            # all-reduce/reduce-scatter (§Perf).
            grads = jax.lax.optimization_barrier(grads)
        sparse_events = model.dirty_events_train(batch, aux)
        row_masks = {k: v for k, v in sparse_events.items()
                     if not isinstance(v, str)}
        new_params, new_opt, gnorm = opt.update(
            grads, state.opt, state.params, row_masks)
        red = state.red
        if store is not None and store.protects:
            old = new = None
            if store.has_sync:
                old = protected_leaves(state.params, state.opt)
                new = protected_leaves(new_params, new_opt)
            red = store.on_write(red, events=store.expand_events(sparse_events),
                                 old=old, new=new)
        metrics = {"loss": loss, "ce": aux["ce"], "grad_norm": gnorm,
                   "aux_loss": aux["aux_loss"]}
        return TrainState(new_params, new_opt, red, state.step + 1), metrics

    return train_step


def make_redundancy_step(store) -> Callable:
    """Algorithm 1 over the protected state (the paper's background thread).

    ``store`` may be a ProtectedStore or a bare RedundancyEngine — both
    expose a traceable ``redundancy_step(leaves, red)``."""
    def redundancy_step(state: TrainState) -> TrainState:
        leaves = protected_leaves(state.params, state.opt)
        red = store.redundancy_step(leaves, state.red)
        return dataclasses.replace(state, red=red)
    return redundancy_step


@dataclasses.dataclass
class Trainer:
    """Host-side loop around ``store.tick``: periodic redundancy, scrubbing
    with double-check, preemption flush, straggler back-off with recovery —
    all owned by the ProtectedStore."""
    model: Any
    opt: AdamW
    store: Optional[ProtectedStore] = None
    engine: Optional[RedundancyEngine] = None      # deprecated: use store=
    mode: Optional[str] = None                     # deprecated: use store=
    period_steps: int = 8
    # None defers to the store's per-leaf policy; 0 disables scrubbing.
    scrub_period_steps: Optional[int] = None
    donate: bool = True

    def __post_init__(self):
        if self.store is None and self.engine is not None:
            self.store = as_store(self.engine, self.mode or "vilamb",
                                  period_steps=self.period_steps,
                                  scrub_period_steps=self.scrub_period_steps or 0,
                                  caller="Trainer")
        if self.store is not None and not self.store.protects:
            self.store = None
        donate = (0,) if self.donate else ()
        self.train_step = jax.jit(
            make_train_step(self.model, self.opt, self.store),
            donate_argnums=donate)
        self.redundancy_step = (
            jax.jit(make_redundancy_step(self.store), donate_argnums=donate)
            if self.store is not None else None)
        self.scrub_fn = ((lambda state: self.store.scrub(
            protected_leaves(state.params, state.opt), state.red))
            if self.store is not None else None)
        self.step_times: list = []

    @property
    def corruption_alarms(self) -> int:
        return self.store.corruption_alarms if self.store is not None else 0

    def init_state(self, key) -> TrainState:
        params = self.model.init(key)
        opt_state = self.opt.init(params)
        red = {}
        if self.store is not None:
            red = self.store.init(protected_leaves(params, opt_state))
        return TrainState.create(params, opt_state, red)

    def scrub_check(self, state: TrainState) -> int:
        """Scrub with the paper's double-check (delegated to the store)."""
        if self.store is None:
            return 0
        return self.store.scrub_check(
            protected_leaves(state.params, state.opt), state.red)

    def flush(self, state: TrainState) -> TrainState:
        """Battery/preemption flush: force Algorithm 1 now (paper §3.3).

        Resolves any in-flight overlapped update first, so the result is
        bitwise-identical to the blocking path."""
        if self.store is None:
            return state
        red = self.store.flush(
            protected_leaves(state.params, state.opt), state.red,
            step=int(state.step))
        return dataclasses.replace(state, red=red)

    def settle(self, state: TrainState) -> TrainState:
        """Adopt in-flight overlapped redundancy results (no new pass).

        Call before handing ``state.red`` to code outside the store's
        lifecycle (custom verification, external persistence).  ``flush``
        and ``scrub_check`` settle on their own."""
        if self.store is None:
            return state
        red = self.store.settle(
            state.red, protected_leaves(state.params, state.opt))
        return dataclasses.replace(state, red=red)

    def run(self, state: TrainState, data, steps: int,
            log_every: int = 10, on_step=None) -> TrainState:
        scrub_period = self.scrub_period_steps
        for i in range(steps):
            t0 = time.perf_counter()
            batch = data.get(int(state.step))
            state, metrics = self.train_step(state, batch)
            jax.block_until_ready(metrics["loss"])
            dt = time.perf_counter() - t0
            self.step_times.append(dt)
            if self.store is not None:
                st = state
                red, report = self.store.tick(
                    lambda: protected_leaves(st.params, st.opt), st.red,
                    int(st.step), step_time=dt, scrub_period=scrub_period)
                state = dataclasses.replace(state, red=red)
                if report.repaired:
                    # The scrub patroller repaired or rebuilt leaves this
                    # tick; fold them back so training continues on the
                    # corrected state.
                    lv = protected_leaves(state.params, state.opt)
                    lv.update(report.repaired)
                    state = replace_protected(state, lv)
            if on_step is not None:
                on_step(state, metrics)
        return state
