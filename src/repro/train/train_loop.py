"""Train-step factories with the three redundancy modes + the host Trainer.

Modes (paper Table 1):
  none   — No-Redundancy baseline.
  sync   — Pangolin analogue: diff-based checksum+parity inside the step.
  vilamb — dirty marking inside the step; Algorithm 1 runs every K steps as
           a separate jitted ``redundancy_step`` (async dispatch lets it
           pipeline behind subsequent train steps on a real TPU).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Mapping, Optional

import jax
import jax.numpy as jnp

from repro.common import flatten_dict
from repro.core import policy
from repro.core.engine import ALL, RedundancyEngine
from repro.optim.adamw import AdamW
from .state import TrainState, protected_leaves


def expand_events(engine: RedundancyEngine, sparse_events: Mapping[str, Any]):
    """Suffix events -> full engine-leaf events; everything else ALL-dirty."""
    events: Dict[str, Any] = {}
    for name in engine.metas:
        root, _, suffix = name.partition("/")
        ev = sparse_events.get(suffix)
        events[name] = ev if ev is not None else ALL
    return events


def make_train_step(model, opt: AdamW, engine: Optional[RedundancyEngine],
                    mode: str = "none", accum_steps: int = 1) -> Callable:
    """accum_steps > 1 microbatches the global batch (gradient accumulation):
    activation memory scales down by the accumulation factor; gradients
    accumulate in fp32 across microbatches inside one jitted step."""
    assert mode in ("none", "sync", "vilamb")

    def grads_of(params, batch):
        if accum_steps == 1:
            return jax.value_and_grad(model.loss, has_aux=True)(params, batch)

        mb = jax.tree.map(
            lambda x: x.reshape(accum_steps, x.shape[0] // accum_steps,
                                *x.shape[1:]), batch)

        def mb_step(carry, microbatch):
            gacc, loss_acc, aux_acc = carry
            (loss, aux), g = jax.value_and_grad(
                model.loss, has_aux=True)(params, microbatch)
            gacc = jax.tree.map(lambda a, b: a + b.astype(jnp.float32), gacc, g)
            aux_acc = {
                "ce": aux_acc["ce"] + aux["ce"],
                "aux_loss": aux_acc["aux_loss"] + aux["aux_loss"],
                "expert_counts": aux_acc["expert_counts"] + aux["expert_counts"],
                "logits_mean": aux_acc["logits_mean"] + aux["logits_mean"],
            }
            return (gacc, loss_acc + loss, aux_acc), None

        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        aux0 = {"ce": jnp.float32(0), "aux_loss": jnp.float32(0),
                "expert_counts": jnp.zeros(
                    (model.cfg.n_groups, model.cfg.group_size,
                     max(model.cfg.n_experts, 1)), jnp.int32),
                "logits_mean": jnp.float32(0)}
        (gacc, loss_sum, aux_sum), _ = jax.lax.scan(
            mb_step, (g0, jnp.float32(0), aux0), mb,
            unroll=True if model.cfg.unroll_layers else 1)
        n = float(accum_steps)
        grads = jax.tree.map(lambda g: g / n, gacc)
        aux = {k: (v / n if k != "expert_counts" else v) for k, v in aux_sum.items()}
        return (loss_sum / n, aux), grads

    def train_step(state: TrainState, batch):
        (loss, aux), grads = grads_of(state.params, batch)
        if getattr(model.cfg, "opt_grad_barrier", False):
            # Keep the data-parallel gradient reduction on a bf16 wire: the
            # barrier stops XLA hoisting AdamW's f32 converts above the
            # all-reduce/reduce-scatter (§Perf).
            grads = jax.lax.optimization_barrier(grads)
        sparse_events = model.dirty_events_train(batch, aux)
        row_masks = {k: v for k, v in sparse_events.items()
                     if not isinstance(v, str)}
        new_params, new_opt, gnorm = opt.update(
            grads, state.opt, state.params, row_masks)
        red = state.red
        if engine is not None and mode == "sync":
            old = protected_leaves(state.params, state.opt)
            new = protected_leaves(new_params, new_opt)
            red = engine.sync_update(old, new, red)
        elif engine is not None and mode == "vilamb":
            red = engine.mark_dirty(red, expand_events(engine, sparse_events))
        metrics = {"loss": loss, "ce": aux["ce"], "grad_norm": gnorm,
                   "aux_loss": aux["aux_loss"]}
        return TrainState(new_params, new_opt, red, state.step + 1), metrics

    return train_step


def make_redundancy_step(engine: RedundancyEngine) -> Callable:
    """Algorithm 1 over the protected state (the paper's background thread)."""
    def redundancy_step(state: TrainState) -> TrainState:
        leaves = protected_leaves(state.params, state.opt)
        red = engine.redundancy_step(leaves, state.red)
        return dataclasses.replace(state, red=red)
    return redundancy_step


def make_scrub(engine: RedundancyEngine) -> Callable:
    def scrub(state: TrainState):
        leaves = protected_leaves(state.params, state.opt)
        return engine.scrub(leaves, state.red)
    return scrub


@dataclasses.dataclass
class Trainer:
    """Host-side loop: periodic redundancy, scrubbing w/ double-check,
    preemption flush, straggler watchdog."""
    model: Any
    opt: AdamW
    engine: Optional[RedundancyEngine] = None
    mode: str = "none"
    period_steps: int = 8
    scrub_period_steps: int = 0
    donate: bool = True

    def __post_init__(self):
        donate = (0,) if self.donate else ()
        self.train_step = jax.jit(
            make_train_step(self.model, self.opt, self.engine, self.mode),
            donate_argnums=donate)
        self.redundancy_step = (
            jax.jit(make_redundancy_step(self.engine), donate_argnums=donate)
            if self.engine is not None else None)
        self.scrub_fn = (jax.jit(make_scrub(self.engine))
                         if self.engine is not None else None)
        self.step_times: list = []
        self.corruption_alarms: int = 0

    def init_state(self, key) -> TrainState:
        params = self.model.init(key)
        opt_state = self.opt.init(params)
        red = {}
        if self.engine is not None:
            red = self.engine.init(protected_leaves(params, opt_state))
        return TrainState.create(params, opt_state, red)

    def scrub_check(self, state: TrainState) -> int:
        """Scrub with the paper's double-check: on mismatch, re-verify after
        quiescing in-flight work (block_until_ready) before raising."""
        mm = self.scrub_fn(state)
        total = int(sum(int(v.sum()) for v in jax.tree.leaves(mm)))
        if total:
            jax.block_until_ready(state.params)
            mm2 = self.scrub_fn(state)           # second check (paper §3.4)
            total = int(sum(int(v.sum()) for v in jax.tree.leaves(mm2)))
            if total:
                self.corruption_alarms += 1
        return total

    def flush(self, state: TrainState) -> TrainState:
        """Battery/preemption flush: force Algorithm 1 now (paper §3.3)."""
        if self.redundancy_step is None:
            return state
        return self.redundancy_step(state)

    def run(self, state: TrainState, data, steps: int,
            log_every: int = 10, on_step=None) -> TrainState:
        for i in range(steps):
            t0 = time.perf_counter()
            batch = data.get(int(state.step))
            state, metrics = self.train_step(state, batch)
            if (self.mode == "vilamb" and self.redundancy_step is not None
                    and policy.should_update(int(state.step), self.period_steps)):
                state = self.redundancy_step(state)
            if (self.scrub_fn is not None and self.scrub_period_steps
                    and policy.should_scrub(int(state.step), self.scrub_period_steps)):
                self.scrub_check(state)
            jax.block_until_ready(metrics["loss"])
            dt = time.perf_counter() - t0
            self.step_times.append(dt)
            # Straggler watchdog: under sustained slowdown, defer redundancy
            # (stretch the period) rather than stall the step (paper's knob).
            if len(self.step_times) > 20:
                med = sorted(self.step_times[-20:])[10]
                if dt > 3 * med and self.period_steps:
                    self.period_steps = min(self.period_steps * 2, 4096)
            if on_step is not None:
                on_step(state, metrics)
        return state
