"""Training state + the protected-leaf view the redundancy engine covers."""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.common import flatten_dict


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TrainState:
    params: Any
    opt: Any
    red: Any          # RedundancyState (flat path -> LeafRedundancy), may be {}
    step: jax.Array

    @staticmethod
    def create(params, opt_state, red=None):
        return TrainState(params=params, opt=opt_state, red=red or {},
                          step=jnp.zeros((), jnp.int32))


def protected_leaves(params, opt_state) -> Dict[str, jax.Array]:
    """The long-lived HBM state Vilamb covers: params + Adam moments.

    (The scalar step/count are excluded — they are checkpoint metadata.)
    """
    out = {}
    for k, v in flatten_dict(params).items():
        out[f"params/{k}"] = v
    for k, v in flatten_dict(opt_state["m"]).items():
        out[f"m/{k}"] = v
    for k, v in flatten_dict(opt_state["v"]).items():
        out[f"v/{k}"] = v
    return out


def protected_structs(params, opt_state) -> Dict[str, jax.ShapeDtypeStruct]:
    return {
        k: jax.ShapeDtypeStruct(v.shape, v.dtype)
        for k, v in protected_leaves(params, opt_state).items()
    }


def replace_protected(state: TrainState, leaves: Dict[str, Any]) -> TrainState:
    """Inverse of :func:`protected_leaves`: fold repaired/restored flat
    leaves back into a TrainState (params + Adam moments; count untouched).

    Updates leaves on the existing trees (preserving empty subtrees that
    flattening drops, e.g. non-learnable norms) rather than rebuilding.
    """
    import dataclasses

    def update(tree: Any, prefix: str) -> Any:
        if isinstance(tree, dict):
            return {k: update(v, f"{prefix}{k}/") for k, v in tree.items()}
        return leaves.get(prefix[:-1], tree)

    opt = dict(state.opt)
    opt["m"] = update(state.opt["m"], "m/")
    opt["v"] = update(state.opt["v"], "v/")
    return dataclasses.replace(
        state, params=update(state.params, "params/"), opt=opt)
