"""Redundancy state containers (pytrees)."""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp

from . import bits
from .blocks import BlockMeta


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class LeafRedundancy:
    """Per-leaf system-redundancy state (all shard-local geometry).

    checksums : uint32[n_blocks]      per-block fmix32 XOR-fold (paper: CRC32C)
    parity    : uint32[n_stripes, L]  stripe XOR parity (paper: parity pages)
    dirty     : uint32[n_words]       packed dirty bitvector (paper: PTE bits)
    shadow    : uint32[n_words]       persistent shadow copy (paper §3.2)
    meta_ck   : uint32[]              checksum-of-checksums (Alg. 1 line 22)
    """
    checksums: jax.Array
    parity: jax.Array
    dirty: jax.Array
    shadow: jax.Array
    meta_ck: jax.Array


def empty_leaf_red(meta: BlockMeta) -> LeafRedundancy:
    return LeafRedundancy(
        checksums=jnp.zeros((meta.n_blocks,), jnp.uint32),
        parity=jnp.zeros((meta.n_stripes, meta.lanes_per_block), jnp.uint32),
        dirty=jnp.zeros((meta.n_dirty_words,), jnp.uint32),
        shadow=jnp.zeros((meta.n_dirty_words,), jnp.uint32),
        meta_ck=jnp.zeros((), jnp.uint32),
    )


def leaf_red_struct(meta: BlockMeta) -> LeafRedundancy:
    """ShapeDtypeStruct skeleton (for dry-run lowering)."""
    return LeafRedundancy(
        checksums=jax.ShapeDtypeStruct((meta.n_blocks,), jnp.uint32),
        parity=jax.ShapeDtypeStruct((meta.n_stripes, meta.lanes_per_block), jnp.uint32),
        dirty=jax.ShapeDtypeStruct((meta.n_dirty_words,), jnp.uint32),
        shadow=jax.ShapeDtypeStruct((meta.n_dirty_words,), jnp.uint32),
        meta_ck=jax.ShapeDtypeStruct((), jnp.uint32),
    )


RedundancyState = Dict[str, LeafRedundancy]
