"""Packed dirty-bitvector primitives (paper §3.2).

The paper repurposes page-table dirty bits and manipulates them as packed
bitvectors fetched/cleared in batches. On TPU there is no MMU in the HBM
path, so the *writer* (the jitted step) produces dirty masks directly; this
module provides the packed uint32 bitvector representation and the
snapshot/clear operations of Algorithm 1.

All functions are jit-safe and shape-static.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

WORD_BITS = 32


def n_words(n_bits: int) -> int:
    """Number of uint32 words needed to hold ``n_bits`` bits."""
    return max(1, (n_bits + WORD_BITS - 1) // WORD_BITS)


def zeros(n_bits: int) -> jax.Array:
    return jnp.zeros((n_words(n_bits),), dtype=jnp.uint32)


def ones(n_bits: int) -> jax.Array:
    """All-valid-bits-set vector (padding bits remain zero)."""
    return pack_mask(jnp.ones((n_bits,), dtype=bool))


def pack_mask(mask: jax.Array) -> jax.Array:
    """Pack a bool[n_bits] mask into uint32[n_words] (little-endian bits)."""
    n_bits = mask.shape[0]
    nw = n_words(n_bits)
    pad = nw * WORD_BITS - n_bits
    m = jnp.pad(mask.astype(jnp.uint32), (0, pad)).reshape(nw, WORD_BITS)
    weights = (jnp.uint32(1) << jnp.arange(WORD_BITS, dtype=jnp.uint32))
    return jnp.sum(m * weights[None, :], axis=1, dtype=jnp.uint32)


def unpack(words: jax.Array, n_bits: int) -> jax.Array:
    """Unpack uint32[n_words] into bool[n_bits]."""
    shifts = jnp.arange(WORD_BITS, dtype=jnp.uint32)
    bits = (words[:, None] >> shifts[None, :]) & jnp.uint32(1)
    return bits.reshape(-1)[:n_bits].astype(bool)


def unpack_rows(words: jax.Array, rows: int, n_bits: int) -> jax.Array:
    """Unpack ``rows`` concatenated bitvectors into bool[rows, n_bits].

    Sharded redundancy state concatenates one ``n_words(n_bits)`` bitvector
    per shard along dim 0; each shard's padding bits sit mid-array, so a
    flat :func:`unpack` of the concatenation would misalign every shard
    after the first.  This unpacks per row (= per shard).
    """
    w = words.reshape(rows, -1)
    shifts = jnp.arange(WORD_BITS, dtype=jnp.uint32)
    bits = (w[:, :, None] >> shifts[None, None, :]) & jnp.uint32(1)
    return bits.reshape(rows, -1)[:, :n_bits].astype(bool)


def mark(words: jax.Array, mask: jax.Array) -> jax.Array:
    """OR a bool[n_bits] dirty mask into the packed bitvector."""
    return jnp.bitwise_or(words, pack_mask(mask))


def mark_ids(words: jax.Array, n_bits: int, ids: jax.Array) -> jax.Array:
    """OR bits for (possibly duplicated) block ids. ids < 0 are ignored.

    Goes through a bool mask so duplicate ids are idempotent (scatter-set).
    """
    valid = ids >= 0
    safe = jnp.where(valid, ids, n_bits)  # out-of-bounds sentinel, dropped below
    mask = jnp.zeros((n_bits,), bool).at[safe].set(True, mode="drop")
    return mark(words, mask)


def test_bit(words: jax.Array, idx) -> jax.Array:
    """Return bool for a single bit index (jit-safe, idx may be traced)."""
    w = words[idx // WORD_BITS]
    return ((w >> jnp.uint32(idx % WORD_BITS)) & jnp.uint32(1)).astype(bool)


def popcount(words: jax.Array) -> jax.Array:
    """Total number of set bits."""
    return jnp.sum(jax.lax.population_count(words), dtype=jnp.int32)


def any_set(words: jax.Array) -> jax.Array:
    return jnp.any(words != 0)
