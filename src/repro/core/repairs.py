"""Shared stripe-repair planning + execution.

Promoted from the restore-time path (``repro.ckpt.failure``): both
checkpoint verification and the live scrub patroller (:mod:`repro.scrub`)
face the same question — given a set of detected-corrupt blocks, which are
parity-repairable and which stripes must be declared lost?  The planning
(group by parity stripe, refuse multi-corrupt groups) and the execution
(``engine.recover_block`` per single-corrupt stripe) live here so the two
callers cannot drift on the recoverability rule, and both surface the same
structured :class:`UnrecoverableBlock` records instead of bare counts.

All block/stripe ids are **global** (``shard * n_blocks + local``), the
same space scrub masks and ``recover_block`` use.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Iterable, List, Mapping, Tuple

import numpy as np

from .blocks import global_stripe_id

# Why a stripe (or block) was refused repair:
#   multi_corrupt      >= 2 detected-corrupt blocks share the parity group;
#                      XOR parity is single-failure-correcting, and
#                      "repairing" one member from such a stripe would
#                      fabricate plausible garbage while reporting success.
#   vulnerable_stripe  another member is dirty/shadow-set, so the stored
#                      parity is stale there (paper §3.3).
#   shard_loss         lost with its shard and not reconstructable from
#                      cross-shard parity (row stale at loss time and never
#                      rewritten by the foreground afterwards).
#   read_timeout       a degraded read (``ProtectedStore.read_verified``)
#                      exhausted its retry/backoff budget without any
#                      recovery path (stripe parity, rebuild image)
#                      producing verified data for the block.
UNRECOVERABLE_REASONS = ("multi_corrupt", "vulnerable_stripe", "shard_loss",
                         "read_timeout")


class UnrecoverableReadError(RuntimeError):
    """A degraded read could not produce verified data for one or more
    requested blocks.  Carries the structured :class:`UnrecoverableBlock`
    records — the typed, honest alternative to returning stale bytes."""

    def __init__(self, leaf: str, records):
        self.leaf = leaf
        self.records = tuple(records)
        blocks = sorted(b for r in self.records for b in r.blocks)
        super().__init__(
            f"{leaf}: degraded read failed for global blocks {blocks} "
            f"({', '.join(sorted({r.reason for r in self.records}))})")


@dataclasses.dataclass(frozen=True)
class UnrecoverableBlock:
    """Structured loss report: which blocks of which stripe, and why.

    ``stripe`` is the global stripe id (``-1`` when the loss is not
    stripe-shaped, e.g. a shard-loss remainder); ``blocks`` lists every
    global block id given up on.
    """
    leaf: str
    stripe: int
    blocks: Tuple[int, ...]
    reason: str

    def __post_init__(self):
        assert self.reason in UNRECOVERABLE_REASONS, self.reason


def plan_stripe_repairs(
    metas, mismatches: Mapping[str, object]
) -> Tuple[List[Tuple[str, int]], List[UnrecoverableBlock]]:
    """Group detected-corrupt blocks by parity stripe.

    ``mismatches`` maps leaf name -> bool mask over global block space (any
    array-like, as produced by ``scrub``) or an iterable of global block
    ids.  Returns ``(singles, unrecoverable)``: the repair candidates (at
    most one per stripe, as ``(leaf, global_block)`` pairs) and the stripes
    refused because XOR parity cannot correct them.
    """
    singles: List[Tuple[str, int]] = []
    unrec: List[UnrecoverableBlock] = []
    for name, mask in sorted(mismatches.items()):
        arr = np.asarray(mask)
        if arr.dtype == np.bool_:
            ids: Iterable[int] = np.flatnonzero(arr)
        else:
            ids = arr.astype(np.int64).ravel()
        meta = metas[name]
        by_stripe = collections.defaultdict(list)
        for b in ids:
            # Global stripe id: parity groups never span shards.
            by_stripe[global_stripe_id(meta, int(b))].append(int(b))
        for stripe, blks in sorted(by_stripe.items()):
            if len(blks) > 1:
                unrec.append(UnrecoverableBlock(
                    name, int(stripe), tuple(blks), "multi_corrupt"))
            else:
                singles.append((name, blks[0]))
    return singles, unrec


def repair_blocks(
    engine, leaves, red, singles: Iterable[Tuple[str, int]]
) -> Tuple[dict, List[Tuple[str, int]], List[Tuple[str, int]]]:
    """Parity-rebuild each planned single-corrupt block.

    ``engine`` is anything exposing ``recover_block`` and ``metas`` — a
    RedundancyEngine or a ProtectedStore (which routes each leaf to its
    owning group).  Returns ``(leaves, fixed, vulnerable)``: the (new dict,
    inputs never mutated) leaf map with repairs applied, the repaired
    ``(leaf, block)`` pairs, and the pairs refused because their stripe was
    vulnerable (stale parity) at repair time — those may become repairable
    after the next redundancy update settles, so callers retry or escalate.
    """
    leaves = dict(leaves)
    fixed: List[Tuple[str, int]] = []
    vulnerable: List[Tuple[str, int]] = []
    for name, b in singles:
        repaired, ok = engine.recover_block(leaves[name], red[name], name, b)
        if bool(ok):
            leaves[name] = repaired
            fixed.append((name, int(b)))
        else:
            vulnerable.append((name, int(b)))
    return leaves, fixed, vulnerable


def vulnerable_unrecoverable(metas, pairs: Iterable[Tuple[str, int]]
                             ) -> List[UnrecoverableBlock]:
    """Wrap refused ``(leaf, block)`` pairs as structured loss records."""
    return [UnrecoverableBlock(n, global_stripe_id(metas[n], b), (int(b),),
                               "vulnerable_stripe")
            for n, b in pairs]
