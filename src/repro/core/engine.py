"""RedundancyEngine — the paper's contribution as a composable JAX module.

Modes (Table 1 of the paper):
  * ``none``   — No-Redundancy baseline.
  * ``sync``   — Pangolin-analogue: checksum+parity updated inside the step,
                 incrementally from the old/new value diff.
  * ``vilamb`` — the paper: dirty bits accumulate during steps; a periodic
                 ``redundancy_step`` (Algorithm 1) amortizes the update.

The engine is machine-local by construction (paper §3.3): when given a mesh
and per-leaf PartitionSpecs, every redundancy computation runs under
``shard_map`` on shard-local blocks with **zero collectives**; checksum,
parity, bitvector, and meta-checksum arrays are sharded alongside their
leaf.  That includes the ∝-dirty work-queue variant (each shard owns a
fixed-capacity queue sized from its local stripe count) and the overlap
form, whose per-shard fit flags are AND-folded on the host after the fetch
— never on device (see ``redundancy_step_async``).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Mapping, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from . import bits, blocks, checksum, parity, workqueue
from .blocks import BlockMeta, DEFAULT_LANES_PER_BLOCK, DEFAULT_STRIPE_DATA_BLOCKS
from .state import LeafRedundancy, RedundancyState, empty_leaf_red, leaf_red_struct

from repro.common.compat import shard_map

# Dirty-event sentinel: "every block of this leaf was (potentially) written".
ALL = "__all__"
DirtyEvent = Union[str, jax.Array]  # ALL or bool row-mask over leading axis


@dataclasses.dataclass(frozen=True)
class RedundancyConfig:
    mode: str = "vilamb"                 # none | sync | vilamb
    period_steps: int = 8                # paper's update period T (in steps)
    scrub_period_steps: int = 64
    lanes_per_block: int = DEFAULT_LANES_PER_BLOCK
    stripe_data_blocks: int = DEFAULT_STRIPE_DATA_BLOCKS
    use_kernels: bool = False            # Pallas path (interpret on CPU)
    kernel_interpret: bool = True        # no real TPU in this container
    # XLA work-queue compaction: per-leaf queue capacity as a fraction of the
    # leaf's stripe count (<= 0 disables; see core/workqueue.py).  Overflow
    # (checked host-side via queue_fits) falls back to the full masked
    # recompute, so semantics never change.
    work_queue_frac: float = workqueue.DEFAULT_QUEUE_FRAC

    def __post_init__(self):
        assert self.mode in ("none", "sync", "vilamb"), self.mode


def _local_shape(shape, spec: Optional[P], mesh: Optional[Mesh]):
    """Per-shard local shape of a leaf under ``spec`` on ``mesh``.

    Raises (AssertionError on an undivisible dim, KeyError on an unknown
    mesh axis) rather than guessing — :func:`repro.remesh.validate_remesh`
    relies on that to vet a target geometry *before* queueing a migration.
    """
    if mesh is None or spec is None:
        return tuple(shape)
    out = []
    for i, dim in enumerate(shape):
        ax = spec[i] if i < len(spec) else None
        if ax is None:
            out.append(dim)
            continue
        axes = ax if isinstance(ax, tuple) else (ax,)
        k = int(np.prod([mesh.shape[a] for a in axes]))
        assert dim % k == 0, f"dim {dim} not divisible by mesh axes {axes} ({k})"
        out.append(dim // k)
    return tuple(out)


def _leaf_axes(spec: Optional[P]) -> Tuple[str, ...]:
    """All mesh axes a leaf is sharded over (flattened, order of appearance)."""
    if spec is None:
        return ()
    out = []
    for ax in spec:
        if ax is None:
            continue
        for a in (ax if isinstance(ax, tuple) else (ax,)):
            out.append(a)
    return tuple(out)


class RedundancyEngine:
    """Builds jitted redundancy ops for a named dict of state leaves."""

    def __init__(
        self,
        leaf_structs: Mapping[str, Any],
        config: RedundancyConfig = RedundancyConfig(),
        mesh: Optional[Mesh] = None,
        specs: Optional[Mapping[str, P]] = None,
    ):
        self.config = config
        self.mesh = mesh
        self.specs = dict(specs or {})
        self.metas: Dict[str, BlockMeta] = {}
        # Global leaf shapes (as handed in); metas below are shard-local.
        self.global_leaf_structs = {
            name: jax.ShapeDtypeStruct(tuple(leaf.shape), leaf.dtype)
            for name, leaf in leaf_structs.items()}
        for name, leaf in leaf_structs.items():
            lshape = _local_shape(leaf.shape, self.specs.get(name), mesh)
            self.metas[name] = blocks.make_meta(
                jax.ShapeDtypeStruct(lshape, leaf.dtype),
                lanes_per_block=config.lanes_per_block,
                stripe_data_blocks=config.stripe_data_blocks,
            )
        self._kernel_ops = None
        if config.use_kernels:
            from repro.kernels.redundancy import ops as kops
            self._kernel_ops = kops
        # Static per-leaf work-queue capacities (0 = plain full recompute).
        self._queue_caps = {
            name: 0 if config.use_kernels else workqueue.queue_capacity(
                meta.n_stripes, config.work_queue_frac)
            for name, meta in self.metas.items()
        }
        self._queue_fits_jit = None

    # ------------------------------------------------------------------ utils
    def shard_factor(self, name: str) -> int:
        """Number of shards a leaf's redundancy arrays concatenate (1 = local)."""
        if self.mesh is None:
            return 1
        return int(np.prod([self.mesh.shape[a] for a in _leaf_axes(self.specs.get(name))]) or 1)

    def _mck_out(self, x: jax.Array) -> jax.Array:
        """Normalize a meta-checksum for storage: scalar machine-local,
        ``(1,)`` per shard under a mesh (global ``(k,)``, one honest
        checksum-of-checksums per shard — a replicated scalar would need a
        collective to agree)."""
        return x.reshape((1,)) if self.mesh is not None else x

    def red_spec(self, name: str) -> LeafRedundancy:
        """PartitionSpecs for a leaf's redundancy arrays (dim0-sharded).

        ``meta_ck`` is sharded like the checksums it covers: one scalar per
        shard (global shape ``(shard_factor,)``) so each shard verifies its
        own checksum page without collectives.
        """
        axes = _leaf_axes(self.specs.get(name))
        s = P(axes if axes else None)
        return LeafRedundancy(checksums=s, parity=s, dirty=s, shadow=s, meta_ck=s)

    def red_structs(self, global_: bool = True) -> RedundancyState:
        """ShapeDtypeStructs of the redundancy state (global shapes)."""
        out = {}
        for name, meta in self.metas.items():
            st = leaf_red_struct(meta)
            if global_:
                k = self.shard_factor(name)
                st = LeafRedundancy(
                    checksums=jax.ShapeDtypeStruct((meta.n_blocks * k,), jnp.uint32),
                    parity=jax.ShapeDtypeStruct(
                        (meta.n_stripes * k, meta.lanes_per_block), jnp.uint32),
                    dirty=jax.ShapeDtypeStruct((meta.n_dirty_words * k,), jnp.uint32),
                    shadow=jax.ShapeDtypeStruct((meta.n_dirty_words * k,), jnp.uint32),
                    meta_ck=jax.ShapeDtypeStruct(
                        (k,) if self.mesh is not None else (), jnp.uint32),
                )
            out[name] = st
        return out

    def red_shardings(self) -> Dict[str, LeafRedundancy]:
        assert self.mesh is not None
        return {
            name: jax.tree.map(lambda s: NamedSharding(self.mesh, s),
                               self.red_spec(name),
                               is_leaf=lambda x: isinstance(x, P))
            for name in self.metas
        }

    def _wrap(self, fn: Callable, leaf_in_specs, red_in: bool, extra_specs=()):
        """shard_map a per-shard-local function when a mesh is present."""
        if self.mesh is None:
            return fn
        in_specs = list(leaf_in_specs)
        if red_in:
            in_specs.append({n: self.red_spec(n) for n in self.metas})
        in_specs.extend(extra_specs)
        out_specs = {n: self.red_spec(n) for n in self.metas}
        return shard_map(
            fn, mesh=self.mesh, in_specs=tuple(in_specs),
            out_specs=out_specs, check_vma=False,
        )

    def _leaf_specs_dict(self) -> Dict[str, P]:
        return {n: self.specs.get(n, P()) for n in self.metas}

    # ------------------------------------------------------------- primitives
    def queue_capacity(self, name: str) -> int:
        """Static work-queue capacity (stripes) for a leaf; 0 = no queue."""
        return self._queue_caps[name]

    @property
    def has_queue(self) -> bool:
        """Whether the queued Algorithm-1 variant exists for this engine.

        Mesh or machine-local alike: under a mesh every shard runs its own
        fixed-capacity queue (capacity from the *local* stripe count) inside
        ``shard_map``, and the fit predicate is evaluated per shard.
        """
        return any(self._queue_caps.values())

    def queue_fits(self, red: RedundancyState) -> bool:
        """Host-side overflow check: do all live dirty stripes fit the queues?

        One tiny jitted popcount pass over the bitvectors (O(n_blocks) bits,
        no data read) and a single bool transfer — the cost that buys
        dispatching the ∝-dirty queued program instead of the full one.
        Under a mesh the per-shard dirty-stripe counts are each checked
        against the shard-local capacity (the queues are per shard); this
        exact check is the blocking path's — the overlap pipeline computes
        the same predicate inside the dispatched program instead.
        """
        if not self.has_queue:
            return False
        if self._queue_fits_jit is None:
            def fits(red_l):
                oks = []
                for name, meta in self.metas.items():
                    cap = self._queue_caps[name]
                    if not cap:
                        continue
                    r = red_l[name]
                    k = self.shard_factor(name)
                    bd = bits.unpack_rows(jnp.bitwise_or(r.dirty, r.shadow),
                                          k, meta.n_blocks)
                    oks.append(jnp.all(jax.vmap(
                        lambda m: workqueue.stripe_fits(
                            self._stripe_dirty(meta, m), cap))(bd)))
                return jnp.all(jnp.stack(oks))
            self._queue_fits_jit = jax.jit(fits)
        return bool(self._queue_fits_jit(red))

    def _update_leaf(self, name: str, meta: BlockMeta, lanes,
                     old: LeafRedundancy, bdirty, sdirty, queued: bool):
        """Masked checksum+parity+meta refresh (Alg. 1 lines 7-22).

        Three interchangeable bitwise-identical realizations: the Pallas
        fused kernel, the XLA work-queue compaction (cost ∝ dirty stripes;
        caller guarantees the fit), or the full-region masked recompute.
        """
        if self._kernel_ops is not None:
            cks, par = self._kernel_ops.fused_update(
                lanes, old.checksums, old.parity, bdirty, sdirty,
                meta.stripe_data_blocks, interpret=self.config.kernel_interpret)
            return cks, par, checksum.meta_checksum(cks)
        cap = self._queue_caps[name]
        if queued and cap:
            ids, _, _ = workqueue.compact_stripe_ids(sdirty, cap)
            return workqueue.queued_update(
                lanes, old.checksums, old.parity, old.meta_ck, bdirty, ids,
                meta.stripe_data_blocks)
        return workqueue.full_update(
            lanes, old.checksums, old.parity, bdirty, sdirty,
            meta.stripe_data_blocks)

    def _stripe_dirty(self, meta: BlockMeta, bdirty):
        return blocks.stripe_dirty_mask(meta, bdirty)

    # -------------------------------------------------------------- init
    def init(self, leaves: Mapping[str, jax.Array]) -> RedundancyState:
        """Full redundancy computation (file-creation time in the paper)."""
        def local(ls):
            out = {}
            for name, meta in self.metas.items():
                lanes = blocks.to_lanes(ls[name], meta)
                cks = checksum.block_checksums(lanes)
                par = parity.stripe_parity(lanes, meta.stripe_data_blocks)
                out[name] = LeafRedundancy(
                    checksums=cks, parity=par,
                    dirty=jnp.zeros((meta.n_dirty_words,), jnp.uint32),
                    shadow=jnp.zeros((meta.n_dirty_words,), jnp.uint32),
                    meta_ck=self._mck_out(checksum.meta_checksum(cks)),
                )
            return out
        fn = self._wrap(local, [self._leaf_specs_dict()], red_in=False)
        return jax.jit(fn)(dict(leaves))

    # -------------------------------------------------------------- marking
    def mark_dirty(
        self, red: RedundancyState, events: Mapping[str, DirtyEvent]
    ) -> RedundancyState:
        """OR dirty events into the bitvectors (run inside the train step).

        Events are domain-space: ``ALL`` for dense leaves, or a bool row-mask
        over the leaf's leading axis (embedding rows / experts / KV pages) —
        converted to shard-local block masks under shard_map.
        """
        events = dict(events)

        def local(red_l, evs):
            out = dict(red_l)
            for name, ev in evs.items():
                meta = self.metas[name]
                r = red_l[name]
                if isinstance(ev, str) and ev == ALL:
                    mask = jnp.ones((meta.n_blocks,), bool)
                elif (ev.ndim == 1 and len(meta.shape) >= 1
                      and ev.shape[0] == meta.shape[0]
                      and meta.n_blocks == meta.shape[0]):
                    # Fast path: rows map 1:1 to blocks (4 KiB-page heaps,
                    # KV pages) — the event mask IS the block mask.
                    mask = ev
                else:
                    # Direct row-mask -> block-mask reduction: no full-event
                    # nonzero sort, cost tracks the event shape.
                    mask = blocks.row_mask_block_mask(meta, ev, row_dims=ev.ndim)
                out[name] = dataclasses.replace(r, dirty=bits.mark(r.dirty, mask))
            return out

        if self.mesh is None:
            return local(red, events)
        ev_specs = {}
        for name, ev in events.items():
            if isinstance(ev, str):
                ev_specs[name] = None
            else:
                spec = self.specs.get(name, P())
                lead = [spec[i] if i < len(spec) else None for i in range(ev.ndim)]
                ev_specs[name] = P(*lead)
        # split static ALL markers from array events for shard_map
        arr_events = {n: e for n, e in events.items() if not isinstance(e, str)}
        all_names = [n for n, e in events.items() if isinstance(e, str)]

        def local2(red_l, arr_evs):
            evs = dict(arr_evs)
            for n in all_names:
                evs[n] = ALL
            return local(red_l, evs)

        fn = shard_map(
            local2, mesh=self.mesh,
            in_specs=({n: self.red_spec(n) for n in self.metas},
                      {n: ev_specs[n] for n in arr_events}),
            out_specs={n: self.red_spec(n) for n in self.metas},
            check_vma=False,
        )
        return fn(red, arr_events)

    # -------------------------------------------------- Algorithm 1 (vilamb)
    def _alg1_parts(self, ls, red_l, queued: bool, want_fits: bool):
        """Shared Algorithm-1 body (traceable): per-leaf masked update.

        Lines 2-4: snapshot ``dirty | shadow`` (include leftover shadow
        from a crash); lines 7-18 + 22: masked checksum + parity recompute
        with the meta-checksum refreshed incrementally on the work-queue
        path.  Returns ``({name: (cks, par, meta_ck, snapshot)}, fits)``
        — the blocking and overlap entry points differ only in how they
        fold these into dirty/shadow outputs.  ``fits`` (the device-side
        queue-fit predicate over every queued leaf) is only evaluated when
        requested.
        """
        parts: Dict[str, Tuple] = {}
        fits = []
        for name, meta in self.metas.items():
            r = red_l[name]
            snapshot = jnp.bitwise_or(r.dirty, r.shadow)
            bdirty = bits.unpack(snapshot, meta.n_blocks)
            sdirty = self._stripe_dirty(meta, bdirty)
            cap = self._queue_caps[name]
            if want_fits and cap:
                fits.append(workqueue.stripe_fits(sdirty, cap))
            lanes = blocks.to_lanes(ls[name], meta)
            cks, par, meta_ck = self._update_leaf(
                name, meta, lanes, r, bdirty, sdirty, queued)
            parts[name] = (cks, par, meta_ck, snapshot)
        fits_all = jnp.all(jnp.stack(fits)) if fits else jnp.asarray(True)
        return parts, fits_all

    def _alg1(self, leaves, red: RedundancyState, queued: bool
              ) -> RedundancyState:
        def local(ls, red_l):
            parts, _ = self._alg1_parts(ls, red_l, queued, want_fits=False)
            out = {}
            for name, (cks, par, meta_ck, snapshot) in parts.items():
                # Lines 19-20: in the paper a fence orders "redundancy
                # written" before "shadow cleared". Inside one jitted step
                # the returned state is atomic; crash-atomicity across steps
                # is provided by the checkpoint layer persisting (data, cks,
                # par, shadow) together. Clearing shadow (line 6 cleared
                # dirty) is therefore safe.
                out[name] = LeafRedundancy(
                    checksums=cks, parity=par,
                    dirty=jnp.zeros_like(snapshot),
                    shadow=jnp.zeros_like(snapshot),
                    meta_ck=self._mck_out(meta_ck),
                )
            return out

        fn = self._wrap(local, [self._leaf_specs_dict()], red_in=True)
        return fn(dict(leaves), red)

    def redundancy_step(
        self, leaves: Mapping[str, jax.Array], red: RedundancyState
    ) -> RedundancyState:
        """One invocation of the paper's background update thread.

        Per leaf: snapshot dirty→shadow, clear dirty, recompute checksums of
        dirty blocks and parity of stripes containing a dirty block, clear
        shadow, refresh the meta-checksum. Fences become data dependencies.
        This is the reference full-region path — safe at any dirty fraction.
        """
        return self._alg1(leaves, red, queued=False)

    def redundancy_step_queued(
        self, leaves: Mapping[str, jax.Array], red: RedundancyState
    ) -> RedundancyState:
        """Work-queue Algorithm 1: cost ∝ dirty stripes, not region size.

        Bitwise-identical to :meth:`redundancy_step` **iff** every leaf's
        dirty-stripe count fits its queue capacity — check
        :meth:`queue_fits` (host-side) before dispatching, as
        ``ProtectedStore.tick`` does.  A truncated queue would silently
        leave stripes stale, so never call this unguarded.
        """
        return self._alg1(leaves, red, queued=True)

    flush = redundancy_step  # battery/preemption flush = forced update pass

    # ------------------------------------------- Algorithm 1, overlap form
    def redundancy_step_async(
        self, leaves: Mapping[str, jax.Array], red: RedundancyState,
        queued: bool = False,
    ) -> Tuple[RedundancyState, jax.Array]:
        """Algorithm 1 restructured for sync-free overlapped dispatch.

        Same snapshot-merge and per-leaf math as :meth:`redundancy_step` /
        :meth:`redundancy_step_queued` — one donated in-place program — but
        returning ``(red_out, fits)`` so no host check guards adoption:

        * ``fits`` is the device-computed queue-fit predicate
          (``queue_fits`` without the host round trip); the dispatcher
          fetches it via a non-blocking async copy and uses it one tick
          ahead as the speculation signal for the *next* queued-vs-full
          choice, and retrospectively as the overflow flag for *this* one.
        * The returned state is valid **unconditionally**.  Under
          ``queued=True`` the scattered checksums/parity are correct fresh
          values for every stripe that made the queue; ``red_out.shadow``
          is ``where(overflowed, snapshot, 0)``, so on overflow everything
          the truncated queue may have missed stays conservatively marked
          (epoch A survives in shadow) until the dispatcher runs the
          full-recompute fallback.  ``red_out.dirty`` is the fresh epoch-B
          bitmap the foreground's next ``on_write`` marks into.
          :meth:`redundancy_step_queued`'s "never unguarded" contract is
          thus discharged on device.

        Under a mesh the whole body runs per shard inside ``shard_map``
        (zero collectives): each shard compacts its own queue, and ``fits``
        is the **per-shard** flag array (global shape ``(n_devices,)``,
        sharded over every mesh axis).  The overflow select is per shard
        too — only the shards whose local queue overflowed keep their
        snapshot marked.  Dispatchers never fold the flags on device: the
        store stacks them into its batched fits vector and AND-folds the
        fetched row on the host at resolution
        (``repro.core.workqueue.fold_fits_host``), so this program — and
        the batched multi-group program wrapping it — stays
        collective-free.
        """
        def local(ls, red_l):
            parts, fits_all = self._alg1_parts(ls, red_l, queued,
                                               want_fits=True)
            overflowed = (jnp.logical_not(fits_all) if queued
                          else jnp.asarray(False))
            out: RedundancyState = {}
            for name, (cks, par, meta_ck, snapshot) in parts.items():
                out[name] = LeafRedundancy(
                    checksums=cks, parity=par,
                    dirty=jnp.zeros_like(snapshot),
                    shadow=jnp.where(overflowed, snapshot,
                                     jnp.zeros_like(snapshot)),
                    meta_ck=self._mck_out(meta_ck),
                )
            if self.mesh is not None:
                fits_all = fits_all.reshape((1,))
            return out, fits_all

        if self.mesh is None:
            return local(dict(leaves), red)
        axes = tuple(self.mesh.axis_names)
        fn = shard_map(
            local, mesh=self.mesh,
            in_specs=(self._leaf_specs_dict(),
                      {n: self.red_spec(n) for n in self.metas}),
            out_specs=({n: self.red_spec(n) for n in self.metas}, P(axes)),
            check_vma=False,
        )
        return fn(dict(leaves), red)

    # ----------------------------------------------------- sync (Pangolin)
    def sync_update(
        self,
        old_leaves: Mapping[str, jax.Array],
        new_leaves: Mapping[str, jax.Array],
        red: RedundancyState,
    ) -> RedundancyState:
        """Pangolin-analogue inline update from the old/new diff.

        Valid only when redundancy was up-to-date before the step (sync-mode
        invariant). Reads 2x the changed data, nothing else — the paper's
        micro-buffer diff advantage (§4.2).
        """
        def local(ols, nls, red_l):
            out = {}
            for name, meta in self.metas.items():
                r = red_l[name]
                o = blocks.to_lanes(ols[name], meta)
                n = blocks.to_lanes(nls[name], meta)
                cks = r.checksums ^ checksum.checksum_diff(o, n)
                par = r.parity ^ parity.parity_diff(o, n, meta.stripe_data_blocks)
                out[name] = LeafRedundancy(
                    checksums=cks, parity=par, dirty=r.dirty, shadow=r.shadow,
                    meta_ck=self._mck_out(checksum.meta_checksum(cks)),
                )
            return out

        fn = self._wrap(
            local, [self._leaf_specs_dict(), self._leaf_specs_dict()], red_in=True)
        return fn(dict(old_leaves), dict(new_leaves), red)

    def sync_update_rows(
        self,
        name: str,
        r: LeafRedundancy,
        rows: jax.Array,
        old_rows: jax.Array,
        new_rows: jax.Array,
    ) -> LeafRedundancy:
        """Sparse Pangolin update when rows map 1:1 to blocks.

        The 4 KiB-page-heap fast path (benchmarks, KV pages with
        row-per-block geometry): cost is O(touched rows), not O(leaf).
        ``rows`` must be unique; rows sharing a stripe XOR-accumulate their
        parity deltas through one segment-XOR scatter (not last-write-wins),
        and the meta-checksum is updated incrementally from the touched rows.
        """
        meta = self.metas[name]
        assert self.mesh is None, "row fast path is host/local only"
        assert len(meta.shape) >= 1 and meta.n_blocks == meta.shape[0], (
            f"{name}: rows do not map 1:1 to blocks")
        S = meta.stripe_data_blocks
        old_lanes = jax.lax.bitcast_convert_type(old_rows, jnp.uint32)
        new_lanes = jax.lax.bitcast_convert_type(new_rows, jnp.uint32)
        old_lanes = old_lanes.reshape(old_lanes.shape[0], -1)
        new_lanes = new_lanes.reshape(new_lanes.shape[0], -1)
        bids = rows.astype(jnp.uint32)
        lids = jnp.arange(old_lanes.shape[1], dtype=jnp.uint32)[None, :]
        salt = checksum.lane_salt(bids[:, None], lids)
        dck = jax.lax.reduce(
            checksum.fmix32(old_lanes ^ salt) ^ checksum.fmix32(new_lanes ^ salt),
            jnp.uint32(0), jax.lax.bitwise_xor, (1,))
        old_cks = r.checksums[rows]
        new_cks = old_cks ^ dck
        cks = r.checksums.at[rows].set(new_cks)
        par = parity.scatter_xor_stripes(
            r.parity, (rows // S).astype(jnp.int32), old_lanes ^ new_lanes)
        meta_ck = r.meta_ck ^ checksum.meta_checksum_delta(old_cks, new_cks, rows)
        return dataclasses.replace(
            r, checksums=cks, parity=par, meta_ck=meta_ck)

    # ------------------------------------------------------------- scrubbing
    def scrub(
        self, leaves: Mapping[str, jax.Array], red: RedundancyState
    ) -> Dict[str, jax.Array]:
        """Verification pass over clean blocks (paper §3.4).

        Returns per-leaf bool[n_blocks] mismatch masks. The double-check
        protocol (re-verify cleanliness after a mismatch) is enforced here by
        evaluating cleanliness and checksums on the same immutable snapshot —
        the host-level loop re-runs scrub after quiescing if any mismatch
        fires, mirroring the paper's second check.
        """
        def local(ls, red_l):
            out = {}
            for name, meta in self.metas.items():
                r = red_l[name]
                clean = ~bits.unpack(jnp.bitwise_or(r.dirty, r.shadow), meta.n_blocks)
                lanes = blocks.to_lanes(ls[name], meta)
                fresh = checksum.block_checksums(lanes)
                out[name] = clean & (fresh != r.checksums)
            return out

        if self.mesh is None:
            return local(dict(leaves), red)
        out_specs = {
            n: P(_leaf_axes(self.specs.get(n)) or None) for n in self.metas
        }
        fn = shard_map(
            local, mesh=self.mesh,
            in_specs=(self._leaf_specs_dict(), {n: self.red_spec(n) for n in self.metas}),
            out_specs=out_specs, check_vma=False,
        )
        return fn(dict(leaves), red)

    def verify_window_fn(self, name: str, window: int,
                         want_slab: bool = False) -> Callable:
        """Bounded patrol probe over one leaf (the scrub patroller's core).

        Returns an **unjitted** callable ``fn(leaf, r, start)`` — callers
        own jit + caching (``start`` is traced, so one compile per
        ``(leaf, window, want_slab)`` serves every cursor position).  Per
        shard it checksums the ``window`` local blocks at ``[start,
        start + window)`` and compares against the stored per-block
        checksums, exactly like :meth:`scrub` but over a bounded slab — the
        per-tick byte budget is ``window * meta.bytes_per_block`` per
        shard.  Outputs (global shapes, dim0 = shard):

        * ``mism``  bool ``(k, window)`` — clean-and-mismatching (corrupt),
        * ``clean`` bool ``(k, window)`` — outside the vulnerability window
          and inside the block range (checksum comparison meaningful),
        * ``slab``  uint32 ``(k, window, lanes_per_block)`` (only when
          ``want_slab``) — the raw lanes read anyway, exported so the
          caller can fold cross-shard parity from the same pass.

        Window positions past ``n_blocks`` are clamped and reported
        not-clean.  Under a mesh the body runs per shard inside
        ``shard_map`` with **zero collectives** (the PR 5 program rule);
        machine-local it is the plain function with ``k == 1``.
        """
        meta = self.metas[name]
        spec = self.specs.get(name, P())

        def local(leaf, r, start):
            lanes = blocks.to_lanes(leaf, meta)
            ids = jnp.arange(window, dtype=jnp.int32) + start
            valid = ids < meta.n_blocks
            safe = jnp.clip(ids, 0, meta.n_blocks - 1)
            slab = lanes[safe]
            # Position-salted: block_offset makes the windowed checksums
            # comparable to the stored full-leaf ones at the same ids.
            fresh = checksum.block_checksums(slab, block_offset=start)
            live = bits.unpack(jnp.bitwise_or(r.dirty, r.shadow),
                               meta.n_blocks)
            clean = valid & ~live[safe]
            mism = clean & (fresh != r.checksums[safe])
            out = (mism.reshape(1, window), clean.reshape(1, window))
            if want_slab:
                out += (slab.reshape(1, window, meta.lanes_per_block),)
            return out

        if self.mesh is None:
            return local
        axes = _leaf_axes(spec)
        s2 = P(axes) if axes else P(None)
        out_specs = (s2, s2) + ((s2,) if want_slab else ())
        return shard_map(
            local, mesh=self.mesh,
            in_specs=(spec, self.red_spec(name), P()),
            out_specs=out_specs, check_vma=False,
        )

    def live_words_fn(self, name: str) -> Callable:
        """``fn(r) -> dirty | shadow`` for one leaf — the patroller's
        per-tick write sample (global packed words, ``(k * n_dirty_words,)``
        under a mesh).  Unjitted; a tiny elementwise OR, collective-free
        by construction."""
        def fn(r):
            return jnp.bitwise_or(r.dirty, r.shadow)
        return fn

    def shard_lanes_fn(self, name: str) -> Callable:
        """``fn(leaf) -> uint32 (k, n_blocks, lanes_per_block)`` — every
        shard's block-lane view stacked along a fresh leading axis.

        The cross-shard parity primitive: XOR-folding the result over dim0
        (in a separate tiny cross-shard host program)
        yields one parity row per *local* block covering the same-indexed
        block of every shard.  Per shard the body is a pure reshape —
        collective-free; machine-local it returns ``(1, nb, L)``.
        """
        meta = self.metas[name]
        spec = self.specs.get(name, P())

        def local(leaf):
            lanes = blocks.to_lanes(leaf, meta)
            return lanes.reshape(1, meta.n_blocks, meta.lanes_per_block)

        if self.mesh is None:
            return local
        axes = _leaf_axes(spec)
        return shard_map(
            local, mesh=self.mesh, in_specs=(spec,),
            out_specs=P(axes) if axes else P(None), check_vma=False,
        )

    def verify_meta(self, red: RedundancyState) -> Dict[str, jax.Array]:
        """Check the checksum-of-checksums (detects corrupted checksum pages).

        Under a mesh each shard verifies its own checksum page against its
        own ``meta_ck`` entry inside ``shard_map``; the per-leaf result is
        the AND over shards (a cold-path fold over ``shard_factor`` bools).
        """
        if self.mesh is None:
            return {
                name: checksum.meta_checksum(r.checksums) == r.meta_ck
                for name, r in red.items()
            }

        def local(red_l):
            return {
                name: (checksum.meta_checksum(r.checksums)
                       == r.meta_ck.reshape(())).reshape((1,))
                for name, r in red_l.items()
            }

        out_specs = {
            n: P(_leaf_axes(self.specs.get(n)) or None) for n in self.metas
        }
        fn = shard_map(
            local, mesh=self.mesh,
            in_specs=({n: self.red_spec(n) for n in self.metas},),
            out_specs=out_specs, check_vma=False,
        )
        per_shard = fn({n: red[n] for n in self.metas})
        return {name: jnp.all(v) for name, v in per_shard.items()}

    # -------------------------------------------------------------- recovery
    def recover_block(
        self, leaf: jax.Array, r: LeafRedundancy, name: str, block_id
    ) -> Tuple[jax.Array, jax.Array]:
        """Reconstruct one corrupted block from its stripe.

        Returns (repaired_leaf, ok). ``ok`` is False when the stripe is
        vulnerable (any *other* member dirty/shadow-set) — the paper's §3.3
        recoverability rule. The paper left recovery unimplemented; we do not.

        ``block_id`` is in global block space; under a mesh it addresses
        shard ``block_id // meta.n_blocks``, whose local lane view is
        sliced out for the rebuild (dim0 sharding, see
        :func:`repro.core.blocks.shard_slice`).
        """
        meta = self.metas[name]
        k = self.shard_factor(name)
        par_row = r.parity[blocks.global_stripe_id(meta, block_id)]
        shard, block_id = divmod(int(block_id), meta.n_blocks)
        sub, put = blocks.shard_slice(leaf, meta, k, shard)
        nw = meta.n_dirty_words
        live = jnp.bitwise_or(r.dirty, r.shadow)[shard * nw:(shard + 1) * nw]
        sid = block_id // meta.stripe_data_blocks
        member_ids = sid * meta.stripe_data_blocks + jnp.arange(meta.stripe_data_blocks)
        in_range = member_ids < meta.n_blocks
        dmask = bits.unpack(live, meta.n_blocks)
        member_dirty = jnp.where(
            in_range, dmask[jnp.clip(member_ids, 0, meta.n_blocks - 1)], False)
        others_clean = jnp.all(~member_dirty | (member_ids == block_id))
        lanes = blocks.to_lanes(sub, meta)
        rebuilt = parity.reconstruct_block(
            lanes, par_row, meta.stripe_data_blocks, block_id, sid)
        new_lanes = lanes.at[block_id].set(
            jnp.where(others_clean, rebuilt, lanes[block_id]))
        return put(blocks.from_lanes(new_lanes, meta)), others_clean

    # ------------------------------------------------------------ accounting
    def vulnerable_masks(self, red: RedundancyState) -> Dict[str, jax.Array]:
        """Per-leaf bool[n_blocks] of blocks inside the vulnerability window.

        ``dirty | shadow`` unpacked — the exact block set whose redundancy
        is stale (paper §3.3): corruptions landing here are the knob-bounded
        accepted loss; everything outside must be scrub-detectable.  The
        counts in :meth:`dirty_stats` are reductions of these masks.  Under
        a mesh the mask is in global block space (per-shard bitvectors
        unpacked shard by shard, shard ``s`` local block ``b`` at index
        ``s * n_blocks + b`` — the same layout scrub masks use).
        """
        out: Dict[str, jax.Array] = {}
        for name, meta in self.metas.items():
            r = red[name]
            out[name] = bits.unpack_rows(
                jnp.bitwise_or(r.dirty, r.shadow),
                self.shard_factor(name), meta.n_blocks).reshape(-1)
        return out

    def dirty_stats(self, red: RedundancyState) -> Dict[str, Dict[str, jax.Array]]:
        """Dirty/vulnerable-stripe counts (feeds §4.7 battery + §4.8 MTTDL).

        Totals are global (local geometry x shard count) so flush sizing and
        MTTDL see the whole region under a mesh.
        """
        out = {}
        for name, meta in self.metas.items():
            r = red[name]
            k = self.shard_factor(name)
            live = jnp.bitwise_or(r.dirty, r.shadow)
            bdirty = bits.unpack_rows(live, k, meta.n_blocks)
            sdirty = jax.vmap(lambda m: self._stripe_dirty(meta, m))(bdirty)
            out[name] = {
                "dirty_blocks": jnp.sum(bdirty, dtype=jnp.int32),
                "vulnerable_stripes": jnp.sum(sdirty, dtype=jnp.int32),
                "total_blocks": meta.n_blocks * k,
                "total_stripes": meta.n_stripes * k,
            }
        return out
