"""Block views over state leaves (paper's "pages", §3.1).

A leaf array of any shape/dtype is reinterpreted as a 2-D uint32 lane view
``(n_blocks, lanes_per_block)`` — the unit over which checksums are computed
and parity stripes are formed. 4 KB NVM pages become ``lanes_per_block``
uint32 words (default 16384 lanes = 64 KiB), sized so one block is a clean
multiple of the TPU (8, 128) vreg tile and fits VMEM comfortably.

Bitcasting is layout-only; XLA fuses it into the consuming reduction.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import bits

DEFAULT_LANES_PER_BLOCK = 16384  # 64 KiB blocks, = 128 * (8,128) vregs
DEFAULT_STRIPE_DATA_BLOCKS = 4   # paper: 4 data pages + 1 parity page


def _elems_per_word(dtype) -> int:
    isz = jnp.dtype(dtype).itemsize
    if isz > 4:
        raise ValueError(f"dtypes wider than 4 bytes unsupported: {dtype}")
    if 4 % isz:
        raise ValueError(f"itemsize must divide 4: {dtype}")
    return 4 // isz


@dataclasses.dataclass(frozen=True)
class BlockMeta:
    """Static geometry of a leaf's block view (local to a shard)."""
    shape: Tuple[int, ...]
    dtype: str
    lanes_per_block: int
    stripe_data_blocks: int

    @property
    def n_elems(self) -> int:
        return int(np.prod(self.shape)) if self.shape else 1

    @property
    def elems_per_word(self) -> int:
        return _elems_per_word(self.dtype)

    @property
    def n_lanes(self) -> int:
        """Total uint32 lanes (before block padding)."""
        return -(-self.n_elems // self.elems_per_word)

    @property
    def n_blocks(self) -> int:
        return max(1, -(-self.n_lanes // self.lanes_per_block))

    @property
    def n_stripes(self) -> int:
        return -(-self.n_blocks // self.stripe_data_blocks)

    @property
    def n_dirty_words(self) -> int:
        return bits.n_words(self.n_blocks)

    @property
    def padded_lanes(self) -> int:
        return self.n_blocks * self.lanes_per_block

    @property
    def padded_blocks(self) -> int:
        return self.n_stripes * self.stripe_data_blocks

    @property
    def bytes_per_block(self) -> int:
        return self.lanes_per_block * 4

    @property
    def data_bytes(self) -> int:
        return self.n_elems * jnp.dtype(self.dtype).itemsize


def make_meta(
    leaf: jax.ShapeDtypeStruct | jax.Array,
    lanes_per_block: int = DEFAULT_LANES_PER_BLOCK,
    stripe_data_blocks: int = DEFAULT_STRIPE_DATA_BLOCKS,
) -> BlockMeta:
    n_lanes = -(-int(np.prod(leaf.shape) or 1) // _elems_per_word(leaf.dtype))
    # Small leaves get a single (possibly shorter) block, padded to a multiple
    # of 128 lanes so kernels keep (8,128)-aligned tiles.
    lpb = min(lanes_per_block, max(128, -(-n_lanes // 128) * 128))
    return BlockMeta(
        shape=tuple(leaf.shape),
        dtype=str(jnp.dtype(leaf.dtype).name),
        lanes_per_block=lpb,
        stripe_data_blocks=stripe_data_blocks,
    )


def to_lanes(x: jax.Array, meta: BlockMeta) -> jax.Array:
    """Bitcast + pad a leaf into its (n_blocks, lanes_per_block) uint32 view."""
    epw = meta.elems_per_word
    flat = x.reshape(-1)
    pad_elems = meta.n_lanes * epw - flat.shape[0]
    if pad_elems:
        flat = jnp.pad(flat, (0, pad_elems))
    if epw == 1:
        lanes = jax.lax.bitcast_convert_type(flat, jnp.uint32)
    else:
        lanes = jax.lax.bitcast_convert_type(flat.reshape(-1, epw), jnp.uint32)
    lane_pad = meta.padded_lanes - lanes.shape[0]
    if lane_pad:
        lanes = jnp.pad(lanes, (0, lane_pad))
    return lanes.reshape(meta.n_blocks, meta.lanes_per_block)


def from_lanes(lanes: jax.Array, meta: BlockMeta) -> jax.Array:
    """Inverse of :func:`to_lanes` (used by parity reconstruction)."""
    epw = meta.elems_per_word
    flat = lanes.reshape(-1)[: meta.n_lanes]
    dt = jnp.dtype(meta.dtype)
    if epw == 1:
        out = jax.lax.bitcast_convert_type(flat, dt)
    else:
        out = jax.lax.bitcast_convert_type(flat, dt).reshape(-1)
    return out[: meta.n_elems].reshape(meta.shape)


def stripe_dirty_mask(meta: BlockMeta, block_dirty: jax.Array) -> jax.Array:
    """bool[n_stripes] of stripes containing at least one dirty block.

    The block->stripe reduction of Algorithm 1 (a stripe's parity is stale
    iff any member block is dirty); shared by the update programs, the
    fit check, and the accounting paths.
    """
    padded = jnp.pad(block_dirty, (0, meta.padded_blocks - meta.n_blocks))
    return jnp.any(padded.reshape(meta.n_stripes, meta.stripe_data_blocks),
                   axis=1)


def shard_slice(leaf: jax.Array, meta: BlockMeta, shards: int, shard: int):
    """View one shard's rows of a dim0-sharded global leaf.

    Sharded redundancy state is addressed in *global block space*: shard
    ``s``'s local block ``b`` is global block ``s * meta.n_blocks + b``
    (``meta`` is the shard-local geometry).  Host-side surgery on that
    space — fault injection, parity reconstruction — needs the shard's
    local lane view back.  Supported for leading-axis sharding only (the
    repo's redundancy layout); other specs raise.

    Returns ``(sub_leaf, put)`` where ``put(new_sub)`` writes the modified
    shard back into a new global leaf.
    """
    if shards == 1:
        return leaf, (lambda new: new)
    rows = meta.shape[0]
    if (leaf.shape[0] != rows * shards
            or tuple(leaf.shape[1:]) != tuple(meta.shape[1:])):
        raise ValueError(
            f"global-block addressing needs dim0-only sharding: global "
            f"{tuple(leaf.shape)} vs local {tuple(meta.shape)} x {shards}")
    lo = shard * rows
    sub = leaf[lo:lo + rows]

    def put(new):
        return leaf.at[lo:lo + rows].set(new)

    return sub, put


def global_stripe_id(meta: BlockMeta, block: int) -> int:
    """Global stripe id of a global block id (shard-local geometry ``meta``).

    Parity groups never span shards, so shard ``s`` owns stripes
    ``[s * n_stripes, (s+1) * n_stripes)`` — the one formula repair
    grouping, parity-fault placement, and clean-stripe planning must
    share (global block space as in :func:`shard_slice`).
    """
    s, b = divmod(int(block), meta.n_blocks)
    return s * meta.n_stripes + b // meta.stripe_data_blocks


def block_of_index(meta: BlockMeta, flat_elem_index) -> jax.Array:
    """Block id containing a flat element index (for sparse dirty marking)."""
    lane = flat_elem_index // meta.elems_per_word
    return lane // meta.lanes_per_block


def blocks_of_rows(meta: BlockMeta, row_ids: jax.Array) -> jax.Array:
    """Block-id ranges covered by whole leading-axis rows (embedding rows,
    MoE expert slabs, KV pages). Returns the block id of each row's first
    element; callers should also mark the block of the row's last element
    when rows straddle blocks (see :func:`row_block_mask`)."""
    if not meta.shape:
        return jnp.zeros_like(row_ids)
    row_elems = int(np.prod(meta.shape[1:])) if len(meta.shape) > 1 else 1
    first = row_ids * row_elems
    return block_of_index(meta, first)


def _row_geometry(meta: BlockMeta, row_dims: int):
    """(row_lanes, blocks_per_row) for rows over the first ``row_dims`` axes."""
    row_elems = int(np.prod(meta.shape[row_dims:])) if len(meta.shape) > row_dims else 1
    row_lanes = -(-row_elems // meta.elems_per_word) if meta.elems_per_word else row_elems
    blocks_per_row = max(1, -(-row_elems // (meta.lanes_per_block * meta.elems_per_word)) + 1)
    return row_lanes, blocks_per_row


def row_block_mask(meta: BlockMeta, row_ids: jax.Array, row_dims: int = 1) -> jax.Array:
    """bool[n_blocks] mask of all blocks touched by the given rows.

    Rows index the leaf's first ``row_dims`` axes flattened (ids < 0
    ignored); handles rows straddling multiple blocks. This is the
    domain-space -> block-space translation of the paper's dirty bits.
    """
    if not meta.shape:
        return jnp.ones((meta.n_blocks,), bool)
    row_lanes, blocks_per_row = _row_geometry(meta, row_dims)
    valid = row_ids >= 0
    safe_rows = jnp.where(valid, row_ids, 0)
    first_lane = safe_rows.astype(jnp.int64 if meta.n_lanes > 2**31 else jnp.int32) * row_lanes
    first_block = first_lane // meta.lanes_per_block
    offs = jnp.arange(blocks_per_row)
    ids = first_block[:, None] + offs[None, :]
    last_lane = first_lane + row_lanes - 1
    last_block = last_lane // meta.lanes_per_block
    in_range = ids <= last_block[:, None]
    ids = jnp.where(in_range & valid[:, None], ids, meta.n_blocks)
    mask = jnp.zeros((meta.n_blocks,), bool).at[ids.reshape(-1)].set(True, mode="drop")
    return mask


def row_mask_block_mask(meta: BlockMeta, row_mask: jax.Array,
                        row_dims: int = 1) -> jax.Array:
    """bool[n_blocks] of blocks touched by set rows of a bool row mask.

    Same semantics as ``row_block_mask(meta, nonzero(row_mask))`` but with
    no ``nonzero`` materialization: when rows pack evenly into blocks the
    translation is a plain reshape-any reduction; otherwise it is a masked
    scatter-OR over the row range — cost tracks the event shape, never the
    leaf size.
    """
    if not meta.shape:
        return jnp.full((meta.n_blocks,), jnp.any(row_mask))
    row_mask = row_mask.reshape(-1)
    nb, L = meta.n_blocks, meta.lanes_per_block
    row_lanes, blocks_per_row = _row_geometry(meta, row_dims)
    R = row_mask.shape[0]
    if row_lanes <= L and L % row_lanes == 0:
        # Rows never straddle a block boundary: block b = row // rows_per_block.
        rpb = L // row_lanes
        pad = -R % rpb
        per_block = jnp.pad(row_mask, (0, pad)).reshape(-1, rpb).any(axis=1)
        if per_block.shape[0] >= nb:
            return per_block[:nb]
        return jnp.pad(per_block, (0, nb - per_block.shape[0]))
    idt = jnp.int64 if meta.n_lanes > 2**31 else jnp.int32
    first_lane = jnp.arange(R, dtype=idt) * row_lanes
    first_block = first_lane // L
    last_block = (first_lane + row_lanes - 1) // L
    offs = jnp.arange(blocks_per_row, dtype=idt)
    ids = first_block[:, None] + offs[None, :]
    live = (ids <= last_block[:, None]) & row_mask[:, None]
    ids = jnp.where(live, ids, nb)
    return jnp.zeros((nb,), bool).at[ids.reshape(-1)].set(True, mode="drop")
