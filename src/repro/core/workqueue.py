"""Work-queue compaction for Algorithm 1 in pure XLA.

The Pallas kernel (kernels/redundancy) realizes the paper's "work ∝ dirty
pages" claim with a scalar-prefetch work queue; this module is the same
idea expressed in plain jnp so *every* backend — including the default
CPU/XLA reference path — pays for dirty stripes, not region size:

1. **Compact** dirty-stripe ids into a fixed-capacity queue (static shape
   ``K``, padded with the out-of-range sentinel ``n_stripes``).
2. **Gather** only those stripes into a ``(K, P, L)`` slab — one fused read
   feeds both checksum and parity, like the kernel.  XLA fuses the leaf
   bitcast into the gather, so clean stripes are never even read.
3. **Compute** per-member checksums (true block-id salts) and the stripe
   XOR parity on the slab in one pass.
4. **Scatter** results back under the dirty masks; sentinel rows drop.
5. The meta-checksum is updated *incrementally* from the changed checksum
   deltas (XOR algebra makes this bitwise-exact) instead of rehashing every
   checksum.

**Overflow is a host-side dispatch decision, not a device branch.**  A
``lax.cond``/``fori_loop`` realization was measured first and rejected:
XLA materializes every conditional operand (the whole lane view, parity,
checksums), which costs more than the full recompute it was meant to skip.
Instead :func:`queued_update` assumes the caller has already checked
``dirty-stripe count <= capacity`` (see ``RedundancyEngine.queue_fits``);
the store's tick — a host loop by construction — dispatches either the
queued or the full jitted program.  Both produce bitwise-identical results
on their shared domain, so the fallback never changes semantics.

Everything here is shard-oblivious on purpose: under a mesh the engine
calls these helpers *inside* ``shard_map``, so each shard compacts its own
queue over its local stripes (capacity derived from the local stripe
count) and :func:`stripe_fits` becomes the shard-local flag the overlap
pipeline AND-folds across shards — on the host, after the batched fetch
(:func:`fold_fits_host`; see ``engine.redundancy_step_async``).
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import checksum

DEFAULT_QUEUE_FRAC = 0.125   # queue capacity as a fraction of n_stripes
MIN_QUEUE_STRIPES = 4


def queue_capacity(n_stripes: int, frac: float,
                   min_stripes: int = MIN_QUEUE_STRIPES) -> int:
    """Static per-leaf queue capacity; 0 disables compaction.

    Compaction only pays when the queue is a strict subset of the stripes:
    a capacity >= n_stripes would gather everything and is reported as 0
    (callers then use the plain full-recompute path).
    """
    if frac <= 0.0 or n_stripes <= 1:
        return 0
    cap = max(min_stripes, math.ceil(n_stripes * frac))
    if cap >= n_stripes:
        return 0
    return cap


def compact_stripe_ids(
    stripe_dirty: jax.Array, size: int, *, pad_repeat_last: bool = False
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Compact a bool[n_stripes] mask into int32 ids of static length ``size``.

    Returns ``(ids, count, overflow)``.  Padding entries are the sentinel
    ``n_stripes`` (scatters with ``mode="drop"`` discard them) unless
    ``pad_repeat_last`` — the Pallas-kernel convention, where repeating the
    last live id lets Mosaic elide the DMA for trailing grid steps.
    ``overflow`` is True when the mask holds more than ``size`` set bits
    (``ids`` is then truncated and callers must fall back).
    """
    ns = stripe_dirty.shape[0]
    fill = 0 if pad_repeat_last else ns
    ids = jnp.nonzero(stripe_dirty, size=size, fill_value=fill)[0].astype(jnp.int32)
    count = jnp.sum(stripe_dirty, dtype=jnp.int32)
    if pad_repeat_last:
        last = ids[jnp.maximum(jnp.minimum(count, size) - 1, 0)]
        ids = jnp.where(jnp.arange(size) < count, ids, last)
    return ids, count, count > size


def stripe_dirty_count(stripe_dirty: jax.Array) -> jax.Array:
    """Number of dirty stripes (int32 scalar)."""
    return jnp.sum(stripe_dirty, dtype=jnp.int32)


def stripe_fits(stripe_dirty: jax.Array, capacity: int) -> jax.Array:
    """Device-side fit check: do the dirty stripes fit a ``capacity`` queue?

    Bool scalar, traceable.  This is the same predicate
    ``RedundancyEngine.queue_fits`` evaluates host-side; the overlap
    pipeline computes it *inside* the dispatched Algorithm-1 program and
    fetches it one tick ahead via a non-blocking async copy, so a due tick
    never pays a device->host round trip (see ``redundancy_step_async``).
    """
    return stripe_dirty_count(stripe_dirty) <= capacity


def fold_fits_host(fits_row) -> bool:
    """Host-side AND-fold of one group's fetched fit signal.

    ``fits_row`` is either the machine-local scalar or the per-shard flag
    row out of the store's batched ``(n_groups, n_devices)`` fits vector.
    The fold happens here, on already-fetched host memory — never as a
    device program: a cross-shard AND would be the one collective in an
    otherwise collective-free redundancy pipeline, and a dedicated fold
    launch per group was exactly the per-tick dispatch overhead the
    batched path removes.
    """
    return bool(np.asarray(fits_row).all())


def queued_update(
    lanes: jax.Array,
    old_cks: jax.Array,
    old_par: jax.Array,
    old_meta: jax.Array,
    bdirty: jax.Array,
    ids: jax.Array,
    stripe_width: int,
):
    """Gather→compute→scatter one compacted work queue (Alg. 1 lines 7-22).

    ``ids`` comes from :func:`compact_stripe_ids` (sentinel padding).
    Caller contract: every dirty stripe id is present in ``ids`` — i.e. the
    dirty-stripe count fit the queue capacity.  Under that contract the
    result is bitwise-identical to :func:`full_update` (given ``old_meta``
    is the true meta-checksum of ``old_cks``, the engine invariant); with a
    truncated queue it would silently leave stripes stale, so dispatchers
    must check ``queue_fits`` first.
    """
    nb, L = lanes.shape
    ns = old_par.shape[0]
    P = stripe_width
    valid_q = ids < ns                                        # live queue rows
    safe_sid = jnp.minimum(ids, ns - 1)
    block_ids = safe_sid[:, None] * P + jnp.arange(P, dtype=jnp.int32)[None, :]
    in_leaf = block_ids < nb                                  # last partial stripe
    safe_bid = jnp.minimum(block_ids, nb - 1)
    # One fused read: the (K, P, L) slab feeds parity AND member checksums.
    slab = jnp.where(in_leaf[:, :, None], lanes[safe_bid], jnp.uint32(0))
    par_rows = jax.lax.reduce(slab, jnp.uint32(0), jax.lax.bitwise_xor, (1,))
    bids = block_ids.astype(jnp.uint32)[:, :, None]
    lids = jnp.arange(L, dtype=jnp.uint32)[None, None, :]
    h = checksum.fmix32(slab ^ checksum.lane_salt(bids, lids))
    cks_rows = jax.lax.reduce(h, jnp.uint32(0), jax.lax.bitwise_xor, (2,))
    # Scatter back under the masks; sentinel / clean / padded rows drop.
    upd = valid_q[:, None] & in_leaf & bdirty[safe_bid]
    tgt_b = jnp.where(upd, block_ids, nb).reshape(-1)
    cks = old_cks.at[tgt_b].set(cks_rows.reshape(-1), mode="drop")
    tgt_s = jnp.where(valid_q, ids, ns)
    par = old_par.at[tgt_s].set(par_rows, mode="drop")
    # Incremental meta-checksum from the changed deltas only.
    old_vals = jnp.where(upd, old_cks[safe_bid], jnp.uint32(0))
    new_vals = jnp.where(upd, cks_rows, old_vals)            # no-op rows cancel
    meta = old_meta ^ checksum.meta_checksum_delta(
        old_vals.reshape(-1), new_vals.reshape(-1),
        jnp.where(upd, block_ids, 0).reshape(-1))
    return cks, par, meta


def full_update(lanes, old_cks, old_par, bdirty, sdirty, stripe_width):
    """Reference full-region masked recompute (the pre-queue semantics)."""
    from . import parity  # local import: parity has no dep on this module
    cks = jnp.where(bdirty, checksum.block_checksums(lanes), old_cks)
    par = parity.stripe_parity_masked(lanes, old_par, sdirty, stripe_width)
    return cks, par, checksum.meta_checksum(cks)
