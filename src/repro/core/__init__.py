"""Vilamb core: asynchronous system-redundancy for accelerator state.

Public API: :class:`ProtectedStore` + :class:`RedundancyPolicy` own the full
lifecycle (attach / on_write / tick / flush).  :class:`RedundancyEngine` is
the per-group compilation target underneath.
"""
from .blocks import BlockMeta, make_meta, to_lanes, from_lanes
from .checksum import (block_checksums, checksum_diff, fmix32, meta_checksum,
                       meta_checksum_delta)
from .engine import ALL, RedundancyConfig, RedundancyEngine
from .parity import (parity_diff, reconstruct_block, scatter_xor_stripes,
                     stripe_parity, stripe_parity_masked)
from .repairs import (UNRECOVERABLE_REASONS, UnrecoverableBlock,
                      UnrecoverableReadError, plan_stripe_repairs,
                      repair_blocks)
from .state import LeafRedundancy, RedundancyState, empty_leaf_red
from .store import (LeafPolicy, ProtectedStore, RedundancyPolicy,
                    StragglerGovernor, TickReport)
from .workqueue import (compact_stripe_ids, full_update, queue_capacity,
                        queued_update)

__all__ = [
    "ALL", "BlockMeta", "LeafPolicy", "LeafRedundancy", "ProtectedStore",
    "RedundancyConfig", "RedundancyEngine", "RedundancyPolicy",
    "RedundancyState", "StragglerGovernor", "TickReport",
    "UNRECOVERABLE_REASONS", "UnrecoverableBlock", "UnrecoverableReadError",
    "block_checksums",
    "checksum_diff", "compact_stripe_ids", "empty_leaf_red", "fmix32",
    "from_lanes", "full_update", "make_meta", "meta_checksum",
    "meta_checksum_delta", "parity_diff", "plan_stripe_repairs",
    "queue_capacity", "queued_update", "reconstruct_block", "repair_blocks",
    "scatter_xor_stripes", "stripe_parity", "stripe_parity_masked",
    "to_lanes",
]
