"""Vilamb core: asynchronous system-redundancy for accelerator state."""
from .blocks import BlockMeta, make_meta, to_lanes, from_lanes
from .checksum import block_checksums, checksum_diff, fmix32, meta_checksum
from .engine import ALL, RedundancyConfig, RedundancyEngine
from .parity import parity_diff, reconstruct_block, stripe_parity, stripe_parity_masked
from .state import LeafRedundancy, RedundancyState, empty_leaf_red

__all__ = [
    "ALL", "BlockMeta", "LeafRedundancy", "RedundancyConfig", "RedundancyEngine",
    "RedundancyState", "block_checksums", "checksum_diff", "empty_leaf_red",
    "fmix32", "from_lanes", "make_meta", "meta_checksum", "parity_diff",
    "reconstruct_block", "stripe_parity", "stripe_parity_masked", "to_lanes",
]
