"""Reliability model — paper §4.8, plus the measured-detection extension.

Closed form (the paper's):

MTTDL_NoRed  = MTTF_page / P                (P = total pages/blocks)
MTTDL_Vilamb = MTTF_page / (V * N)          (V = vulnerable stripes,
                                             N = blocks per stripe)
uplift       = P / (V * N)

V is measured empirically from dirty traces of real workloads (the engine's
``dirty_stats``), exactly as the paper does.

Measured form (:func:`mttdl_measured`): the closed form treats detection as
instantaneous — a corruption in a *clean* stripe is assumed repaired the
moment it lands.  In reality it sits latent until the next scheduled scrub
flags it; during that latency a **second** fault in the same stripe defeats
the single-failure XOR parity.  The fault-injection oracle
(``repro.faults.oracle``) measures that latency against real scrub
schedules, and the measured MTTDL combines both loss modes:

    rate_window = V * N / MTTF_block          (fault lands inside the window)
    rate_double = S * (N / MTTF_block)^2 * L  (second fault within latency L,
                                               S = total stripes)
    MTTDL_measured = 1 / (rate_window + rate_double)

With L -> 0 this reduces exactly to the paper's closed form.
"""
from __future__ import annotations

from typing import Dict, Iterable, Mapping, Optional, Sequence


def mttdl_no_red(mttf_block: float, total_blocks: int) -> float:
    return mttf_block / max(total_blocks, 1)


def mttdl_vilamb(mttf_block: float, vulnerable_stripes: float, stripe_blocks: int) -> float:
    denom = max(vulnerable_stripes * stripe_blocks, 1e-12)
    return mttf_block / denom


def mttdl_uplift(total_blocks: int, vulnerable_stripes: float, stripe_blocks: int) -> float:
    """P / (V*N); infinite (capped) when no stripe is ever vulnerable."""
    denom = vulnerable_stripes * stripe_blocks
    if denom <= 0:
        return float("inf")
    return total_blocks / denom


def aggregate_uplift(stats: Mapping[str, Mapping[str, float]], stripe_blocks: int) -> float:
    """Uplift across all leaves of a state dict (time-averaged V per leaf)."""
    total = sum(int(s["total_blocks"]) for s in stats.values())
    vuln = sum(float(s["vulnerable_stripes"]) for s in stats.values())
    return mttdl_uplift(total, vuln, stripe_blocks)


def mttdl_measured(mttf_block: float, vulnerable_stripes: float,
                   stripe_blocks: int, total_stripes: int,
                   detect_latency_seconds: float) -> float:
    """MTTDL from *measured* quantities (module docstring for the model).

    ``vulnerable_stripes`` is the time-averaged V from a dirty trace;
    ``detect_latency_seconds`` the measured mean scrub detection latency
    (0 reduces to :func:`mttdl_vilamb` exactly, up to the closed form's
    1e-12 clamp).
    """
    lam = 1.0 / float(mttf_block)
    rate_window = float(vulnerable_stripes) * stripe_blocks * lam
    rate_double = (total_stripes * (stripe_blocks * lam) ** 2
                   * max(float(detect_latency_seconds), 0.0))
    denom = rate_window + rate_double
    if denom <= 0:
        return float("inf")
    return 1.0 / denom


def mttdl_measured_live(mttf_block: float, vulnerable_stripes: float,
                        stripe_blocks: int, total_stripes: int,
                        assumed_latency_seconds: float,
                        measured: Optional[Mapping[str, float]] = None
                        ) -> float:
    """:func:`mttdl_measured` with the latency substituted from a live
    measurement when one exists.

    ``measured`` is a :func:`detection_latency_stats` dict (e.g. the scrub
    patroller's ``latency_stats()``); when it records at least one
    detection (``n > 0``) its mean latency replaces
    ``assumed_latency_seconds`` (the scheduled-scrub fallback).  This is
    how the patroller's measured detection latency feeds the reliability
    model: same closed form, tighter L.
    """
    lat = float(assumed_latency_seconds)
    if measured and int(measured.get("n", 0)) > 0:
        lat = float(measured["mean_s"])
    return mttdl_measured(mttf_block, vulnerable_stripes, stripe_blocks,
                          total_stripes, lat)


def detection_latency_stats(latency_steps: Sequence[float],
                            step_seconds: float = 1.0) -> Dict[str, float]:
    """Summarize measured scrub detection latencies (steps -> seconds).

    Returns mean/max/n in seconds given the measured per-step wall time;
    empty input yields zeros (no detectable injections ran).
    """
    xs = [float(x) for x in latency_steps if x is not None]
    if not xs:
        return {"n": 0, "mean_s": 0.0, "max_s": 0.0}
    return {
        "n": len(xs),
        "mean_s": sum(xs) / len(xs) * step_seconds,
        "max_s": max(xs) * step_seconds,
    }


def average_stats(trace: Iterable[Mapping[str, Mapping[str, float]]]) -> Dict[str, Dict[str, float]]:
    """Average vulnerable-stripe counts over a trace of dirty_stats snapshots."""
    acc: Dict[str, Dict[str, float]] = {}
    n = 0
    for snap in trace:
        n += 1
        for name, s in snap.items():
            a = acc.setdefault(name, {"vulnerable_stripes": 0.0,
                                      "dirty_blocks": 0.0,
                                      "total_blocks": int(s["total_blocks"]),
                                      "total_stripes": int(s["total_stripes"])})
            a["vulnerable_stripes"] += float(s["vulnerable_stripes"])
            a["dirty_blocks"] += float(s["dirty_blocks"])
    for a in acc.values():
        a["vulnerable_stripes"] /= max(n, 1)
        a["dirty_blocks"] /= max(n, 1)
    return acc
