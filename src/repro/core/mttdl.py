"""Reliability model — paper §4.8.

MTTDL_NoRed  = MTTF_page / P                (P = total pages/blocks)
MTTDL_Vilamb = MTTF_page / (V * N)          (V = vulnerable stripes,
                                             N = blocks per stripe)
uplift       = P / (V * N)

V is measured empirically from dirty traces of real workloads (the engine's
``dirty_stats``), exactly as the paper does.
"""
from __future__ import annotations

from typing import Dict, Iterable, Mapping


def mttdl_no_red(mttf_block: float, total_blocks: int) -> float:
    return mttf_block / max(total_blocks, 1)


def mttdl_vilamb(mttf_block: float, vulnerable_stripes: float, stripe_blocks: int) -> float:
    denom = max(vulnerable_stripes * stripe_blocks, 1e-12)
    return mttf_block / denom


def mttdl_uplift(total_blocks: int, vulnerable_stripes: float, stripe_blocks: int) -> float:
    """P / (V*N); infinite (capped) when no stripe is ever vulnerable."""
    denom = vulnerable_stripes * stripe_blocks
    if denom <= 0:
        return float("inf")
    return total_blocks / denom


def aggregate_uplift(stats: Mapping[str, Mapping[str, float]], stripe_blocks: int) -> float:
    """Uplift across all leaves of a state dict (time-averaged V per leaf)."""
    total = sum(int(s["total_blocks"]) for s in stats.values())
    vuln = sum(float(s["vulnerable_stripes"]) for s in stats.values())
    return mttdl_uplift(total, vuln, stripe_blocks)


def average_stats(trace: Iterable[Mapping[str, Mapping[str, float]]]) -> Dict[str, Dict[str, float]]:
    """Average vulnerable-stripe counts over a trace of dirty_stats snapshots."""
    acc: Dict[str, Dict[str, float]] = {}
    n = 0
    for snap in trace:
        n += 1
        for name, s in snap.items():
            a = acc.setdefault(name, {"vulnerable_stripes": 0.0,
                                      "dirty_blocks": 0.0,
                                      "total_blocks": int(s["total_blocks"]),
                                      "total_stripes": int(s["total_stripes"])})
            a["vulnerable_stripes"] += float(s["vulnerable_stripes"])
            a["dirty_blocks"] += float(s["dirty_blocks"])
    for a in acc.values():
        a["vulnerable_stripes"] /= max(n, 1)
        a["dirty_blocks"] /= max(n, 1)
    return acc
