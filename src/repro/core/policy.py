"""Update scheduling + flush budget — paper §3.3 battery / §4.7 cost model.

On TPU fleets the "battery" is the preemption grace window: when a SIGTERM
arrives, the launcher must finish pending redundancy updates (flush) and
checkpoint within the grace budget. This module sizes that flush from dirty
state and prices the paper's battery equivalents for comparison.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Mapping

# Paper §4.7 constants.
ULTRACAP_DOLLARS_PER_KJ = 2.85
LIION_DOLLARS_PER_KJ = 0.02
SERVER_WATTS = 500.0

# TPU v5e target hardware (per chip).
HBM_BYTES_PER_SEC = 819e9
PEAK_BF16_FLOPS = 197e12


@dataclasses.dataclass(frozen=True)
class FlushEstimate:
    dirty_bytes: int          # data read to recompute checksums
    stripe_bytes: int         # stripe reads for parity
    write_bytes: int          # checksum + parity writes
    seconds: float            # at HBM bandwidth (redundancy is memory-bound)
    energy_kj: float
    ultracap_dollars: float
    liion_dollars: float


def should_update(step: int, period_steps: int) -> bool:
    return period_steps > 0 and step % period_steps == 0 and step > 0


def should_scrub(step: int, scrub_period_steps: int) -> bool:
    return scrub_period_steps > 0 and step % scrub_period_steps == 0 and step > 0


def estimate_flush(
    dirty_stats: Mapping[str, Mapping[str, int]],
    bytes_per_block: Mapping[str, int],
    stripe_blocks: int,
) -> FlushEstimate:
    """Size the preemption flush from live dirty state.

    Checksum pass reads every dirty block once; parity pass reads every
    vulnerable stripe once (fused kernel reads each stripe exactly once and
    produces both — see kernels/redundancy). Memory-bound ⇒ seconds =
    bytes / HBM bandwidth.
    """
    dirty_b = 0
    stripe_b = 0
    write_b = 0
    for name, s in dirty_stats.items():
        bpb = bytes_per_block[name]
        dirty_b += int(s["dirty_blocks"]) * bpb
        stripe_b += int(s["vulnerable_stripes"]) * stripe_blocks * bpb
        write_b += int(s["vulnerable_stripes"]) * bpb + int(s["dirty_blocks"]) * 4
    # Fused single pass: stripe read covers the dirty-block read.
    read_b = max(dirty_b, stripe_b)
    seconds = (read_b + write_b) / HBM_BYTES_PER_SEC
    energy_kj = seconds * SERVER_WATTS / 1e3
    return FlushEstimate(
        dirty_bytes=dirty_b, stripe_bytes=stripe_b, write_bytes=write_b,
        seconds=seconds, energy_kj=energy_kj,
        ultracap_dollars=energy_kj * ULTRACAP_DOLLARS_PER_KJ,
        liion_dollars=energy_kj * LIION_DOLLARS_PER_KJ,
    )
