"""Per-block checksums — the TPU adaptation of the paper's CRC-32C.

``crc32q`` is a serial bit-level x86 instruction; CRC's linear-feedback
structure does not vectorize on the TPU VPU. We use a position-salted
Murmur3-finalizer XOR-fold instead:

    cksum(block b) = XOR_i fmix32(w_i XOR salt(b, i))
    salt(b, i)     = (b * GOLDEN) XOR (i * SALT2)

Properties (documented in DESIGN.md §2.1):
  * embarrassingly parallel + XOR-reassociable → maps onto 8x128 VPU lanes;
  * any single-lane change flips the checksum w.p. 1 - 2^-32;
  * position salting defeats lane-swap / block-swap aliasing (the paper's
    misdirected-write bugs);
  * incrementally updatable from a value diff — the same property Pangolin
    exploits in CRC for its micro-buffer diff updates:
        cksum' = cksum ^ fmix32(old^salt) ^ fmix32(new^salt).

All arithmetic is uint32 with wrap-around (XLA semantics).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

GOLDEN = np.uint32(0x9E3779B9)
SALT2 = np.uint32(0x85EBCA77)
C1 = np.uint32(0x85EBCA6B)
C2 = np.uint32(0xC2B2AE35)


def fmix32(x: jax.Array) -> jax.Array:
    """Murmur3 32-bit finalizer (avalanche mix)."""
    x = x ^ (x >> 16)
    x = x * C1
    x = x ^ (x >> 13)
    x = x * C2
    x = x ^ (x >> 16)
    return x


def lane_salt(block_ids: jax.Array, lane_ids: jax.Array) -> jax.Array:
    """salt(b, i); broadcasts (B,1)x(1,L) -> (B,L). uint32 wrap is fine."""
    b = block_ids.astype(jnp.uint32) * GOLDEN
    l = lane_ids.astype(jnp.uint32) * SALT2
    return b ^ l


def block_checksums(lanes: jax.Array, block_offset=0) -> jax.Array:
    """Checksum each row of a (n_blocks, lanes) uint32 view.

    ``block_offset`` shifts the block-id salt (used by sharded callers so
    every local block keeps a distinct salt within the shard).
    """
    nb, L = lanes.shape
    bids = jnp.arange(nb, dtype=jnp.uint32)[:, None] + jnp.uint32(block_offset)
    lids = jnp.arange(L, dtype=jnp.uint32)[None, :]
    h = fmix32(lanes ^ lane_salt(bids, lids))
    return jax.lax.reduce(h, jnp.uint32(0), jax.lax.bitwise_xor, (1,))


def checksum_diff(
    old_lanes: jax.Array, new_lanes: jax.Array, block_offset=0
) -> jax.Array:
    """Per-block incremental checksum delta: cksum' = cksum ^ delta.

    This is the Pangolin-mode (sync, diff-based) update path.
    """
    nb, L = old_lanes.shape
    bids = jnp.arange(nb, dtype=jnp.uint32)[:, None] + jnp.uint32(block_offset)
    lids = jnp.arange(L, dtype=jnp.uint32)[None, :]
    salt = lane_salt(bids, lids)
    h = fmix32(old_lanes ^ salt) ^ fmix32(new_lanes ^ salt)
    return jax.lax.reduce(h, jnp.uint32(0), jax.lax.bitwise_xor, (1,))


def meta_checksum(checksums: jax.Array) -> jax.Array:
    """Checksum-of-checksums (paper Algorithm 1, line 22)."""
    flat = checksums.reshape(-1)
    ids = jnp.arange(flat.shape[0], dtype=jnp.uint32)
    h = fmix32(flat ^ (ids * GOLDEN))
    return jax.lax.reduce(h, jnp.uint32(0), jax.lax.bitwise_xor, (0,))


def meta_checksum_delta(
    old_vals: jax.Array, new_vals: jax.Array, block_ids: jax.Array
) -> jax.Array:
    """XOR-delta of :func:`meta_checksum` from changed entries only.

    ``meta' = meta ^ meta_checksum_delta(old, new, ids)`` is bitwise equal to
    rehashing every checksum, by XOR cancellation.  Entries with
    ``old == new`` contribute zero, so callers may pad with no-op rows
    (each block id must appear at most once with ``old != new``).
    """
    salt = block_ids.astype(jnp.uint32) * GOLDEN
    h = fmix32(old_vals ^ salt) ^ fmix32(new_vals ^ salt)
    return jax.lax.reduce(h.reshape(-1), jnp.uint32(0), jax.lax.bitwise_xor, (0,))
