"""ProtectedStore — the library facade that owns the redundancy lifecycle.

The paper presents Vilamb as a *user-space library* with one tunable knob
between performance and redundancy freshness. This module is that library
surface: callers hand over any pytree of protected state and interact with
exactly three calls —

  * ``store.attach(pytree, specs=...)``   declare what is protected and how
  * ``store.on_write(red, events=...)``   inside the (jitted) mutation step
  * ``store.tick(leaves, red, step)``     once per host step; schedules
    Algorithm-1 updates, scrubbing with the paper's double-check, straggler
    back-off, and freshness deadlines internally

plus ``flush`` for the preemption/battery path.  Policies are declarative
and **per leaf group** (Tvarak's heterogeneous-region argument): params may
run ``sync`` (Pangolin-analogue inline diff) while optimizer moments and KV
pages run ``vilamb`` with different periods.  Each distinct resolved policy
compiles down to one :class:`~repro.core.engine.RedundancyEngine`.
"""
from __future__ import annotations

import collections
import dataclasses
import fnmatch
import queue
import statistics
import threading
import time
import warnings
from typing import Any, Callable, Dict, List, Mapping, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.common import flatten_dict

from . import bits
from . import policy as policy_mod
from . import workqueue
from .blocks import (DEFAULT_LANES_PER_BLOCK, DEFAULT_STRIPE_DATA_BLOCKS,
                     BlockMeta, make_meta)
from .engine import ALL, RedundancyConfig, RedundancyEngine, _local_shape
from .state import LeafRedundancy, RedundancyState, leaf_red_struct

MODES = ("none", "sync", "vilamb")


def _async_tick_default() -> bool:
    """Default for ``RedundancyPolicy.async_tick``: the overlap pipeline,
    unless ``REPRO_ASYNC_TICK=0`` — the CI lever that re-runs the suite on
    the blocking tick (scripts/ci.sh) without touching call sites that
    pass the knob explicitly."""
    import os
    return os.environ.get("REPRO_ASYNC_TICK", "1").lower() not in (
        "0", "false", "no")


# --------------------------------------------------------------------- policy
@dataclasses.dataclass(frozen=True)
class LeafPolicy:
    """Redundancy policy for one leaf group.

    ``max_vulnerable_steps`` / ``max_vulnerable_seconds`` make the paper's
    tunable knob explicit: an upper bound on how long blocks may stay
    vulnerable (dirty, redundancy stale) before an update is forced — even
    when the straggler governor has stretched the period, and regardless of
    where the step counter sits in the modulo schedule.  0 disables.
    """
    mode: str = "vilamb"                 # none | sync | vilamb
    period_steps: int = 8                # Algorithm-1 period T (vilamb)
    scrub_period_steps: int = 0          # 0 = no scheduled scrubbing
    max_vulnerable_steps: int = 0        # freshness deadline, in steps
    max_vulnerable_seconds: float = 0.0  # freshness deadline, wall clock
    # Work-queue capacity knob (fraction of each leaf's stripes); None
    # inherits the store-wide RedundancyPolicy.work_queue_frac, <= 0
    # disables compaction for this group.
    work_queue_frac: Optional[float] = None

    def __post_init__(self):
        if self.mode not in MODES:
            raise ValueError(
                f"unknown redundancy mode {self.mode!r} (want one of {MODES})")


@dataclasses.dataclass(frozen=True)
class RedundancyPolicy:
    """Declarative store-wide policy: per-leaf rules + shared geometry.

    ``rules`` are ``(fnmatch_pattern, LeafPolicy)`` pairs, first match wins;
    unmatched leaves get ``default``.  Leaves resolving to an equal
    LeafPolicy form one group backed by one RedundancyEngine.
    """
    default: LeafPolicy = LeafPolicy()
    rules: Tuple[Tuple[str, LeafPolicy], ...] = ()
    # Shared block geometry / kernel selection (RedundancyConfig fields).
    lanes_per_block: int = DEFAULT_LANES_PER_BLOCK
    stripe_data_blocks: int = DEFAULT_STRIPE_DATA_BLOCKS
    use_kernels: bool = False
    kernel_interpret: bool = True
    # Default XLA work-queue capacity (fraction of a leaf's stripe count);
    # per-group override via LeafPolicy.work_queue_frac.
    work_queue_frac: float = workqueue.DEFAULT_QUEUE_FRAC
    # Straggler governor: stretch periods under sustained slowdown, shrink
    # back once step times renormalize (the seed's watchdog never recovered).
    straggler_factor: float = 3.0
    straggler_window: int = 20
    straggler_recovery_steps: int = 10
    period_cap: int = 4096
    # Overlap pipeline (docs/perf.md): a due tick costs the foreground one
    # dispatch, never a device->host round trip.  ``async_tick=False`` or
    # ``pipeline_depth=0`` reverts to the blocking tick (exact host-side
    # queue_fits dispatch); depth counts in-flight updates per group — 1 is
    # the implemented maximum, deeper requests coalesce.  Mesh-sharded
    # groups overlap too: the per-shard fit flags come back inside the
    # batched update program's stacked fits vector and are AND-folded on
    # the host at resolution.  Defaults to the env lever
    # ``REPRO_ASYNC_TICK`` (scripts/ci.sh runs the suite both ways).
    async_tick: bool = dataclasses.field(default_factory=_async_tick_default)
    pipeline_depth: int = 1
    # Off-thread tick resolver (docs/api.md): with the overlap pipeline
    # on, the device->host fit fetch + AND-fold for each batched
    # Algorithm-1 dispatch runs on a dedicated daemon thread; the
    # foreground tick swaps epochs, dispatches the one batched program
    # (asynchronously — jax never blocks on execution there), and adopts
    # results the resolver has already folded to plain host bools.
    # settle/flush and the deadline/scrub/governor forced-resolve paths
    # join (wait for the resolver, which implies the fit signal landed).
    # ``flush`` and a remesh adoption shut the thread down cleanly; it is
    # re-created lazily on the next overlapped dispatch.  False resolves
    # inline on the tick thread via the non-blocking fetch started at
    # dispatch time (the PR3..PR8 behavior) — bitwise-identical either
    # way.
    dispatcher_thread: bool = True
    # AOT-compile every Algorithm-1 variant a group can dispatch at attach
    # time, so the first overlapped dispatch never hides a compile stall.
    precompile: bool = True
    # Scrub patroller + online shard rebuild (repro.scrub; docs/api.md).
    # ``patrol_bytes_per_tick`` > 0 enables a continuous low-priority
    # verify cursor over block space: each probe checksums at most that
    # many bytes *per shard* per tick (the per-device stall bound — shards
    # scan in parallel).  Detected corruption is repaired from parity at a
    # paced ``patrol_repair_per_tick`` blocks per tick.  A wholesale-corrupt
    # shard (>= ``shard_loss_threshold`` of a probe window's clean blocks
    # mismatching, at least ``shard_loss_min_blocks`` of them) triggers an
    # online rebuild from cross-shard parity, paced by
    # ``rebuild_bytes_per_tick`` (0 = 4x the patrol budget).  Priority:
    # foreground writes > due redundancy ticks > rebuild > patrol — with a
    # starvation floor: after ``patrol_max_starved_ticks`` consecutive
    # probe-less ticks (every tick busy) one probe dispatches anyway, so
    # wall-to-wall update traffic cannot silently stall detection forever
    # (0 disables the floor; ``TickReport.patrol_starved_ticks`` shows the
    # current streak).
    patrol_bytes_per_tick: int = 0
    patrol_repair_per_tick: int = 1
    patrol_max_starved_ticks: int = 32
    rebuild_bytes_per_tick: int = 0
    shard_loss_threshold: float = 0.5
    shard_loss_min_blocks: int = 4
    # Elastic remesh (repro.remesh; docs/api.md): ``store.remesh(new_mesh)``
    # re-stripes every protected leaf onto a grown/shrunk mesh over bounded
    # per-tick migration windows of ``remesh_bytes_per_tick`` bytes per leaf
    # (0 = 4x the patrol budget; if that is also 0 the whole leaf migrates
    # in one window).  Priority: foreground > due ticks > rebuild > remesh
    # > patrol.
    remesh_bytes_per_tick: int = 0
    # Degraded reads (``store.read_verified``): bounded retry/backoff when a
    # block cannot be immediately verified or reconstructed — a transiently
    # vulnerable stripe may settle within the retry budget.  The backoff is
    # exponential (base * 2**attempt) with a hard per-delay cap, a seeded
    # jitter fraction that only ever *shrinks* delays, and a cumulative
    # total budget — repro.health.backoff.backoff_schedule, the same
    # schedule the health governor's dispatch-retry rung uses.
    read_retry_attempts: int = 3
    read_retry_backoff_s: float = 0.0
    read_retry_backoff_cap_s: float = 0.0    # 0 = uncapped
    read_retry_total_s: float = 0.0          # 0 = unbudgeted
    read_retry_jitter_frac: float = 0.0
    # Freshness-SLO health governor (repro.health; docs/api.md): a
    # HealthPolicy (or True for defaults) arms per-group breakers
    # (HEALTHY -> DEGRADED -> CRITICAL, hysteresis on recovery) and the
    # escalation ladder — wedged-dispatch retry, margin-forced blocking
    # resolve, on_write backpressure, temporary sync escalation — that
    # *enforces* max_vulnerable_steps/_seconds instead of best-effort.
    # None (default) keeps the governor off: zero tick overhead.
    health: Optional[Any] = None

    def leaf_policy(self, name: str) -> LeafPolicy:
        for pattern, lp in self.rules:
            if fnmatch.fnmatchcase(name, pattern):
                return lp
        return self.default

    @classmethod
    def single(cls, mode: str, period_steps: int = 8,
               scrub_period_steps: int = 0, max_vulnerable_steps: int = 0,
               max_vulnerable_seconds: float = 0.0, **kw) -> "RedundancyPolicy":
        """The old global ``RedundancyConfig.mode`` as a one-group policy."""
        return cls(default=LeafPolicy(
            mode=mode, period_steps=period_steps,
            scrub_period_steps=scrub_period_steps,
            max_vulnerable_steps=max_vulnerable_steps,
            max_vulnerable_seconds=max_vulnerable_seconds), **kw)

    @classmethod
    def from_spec(cls, spec: str, default_mode: str = "vilamb",
                  period_steps: int = 8, scrub_period_steps: int = 0,
                  max_vulnerable_steps: int = 0, **kw) -> "RedundancyPolicy":
        """Parse ``"params/*=sync,m/*=vilamb:16,v/*=none"`` into rules.

        Each clause is ``pattern=mode[:period]``; omitted periods inherit
        ``period_steps``.  An empty spec yields a single-mode policy.
        """
        rules: List[Tuple[str, LeafPolicy]] = []
        for clause in filter(None, (c.strip() for c in spec.split(","))):
            pattern, _, rhs = clause.partition("=")
            if not rhs:
                raise ValueError(f"bad policy clause {clause!r} "
                                 "(want pattern=mode[:period])")
            mode, _, per = rhs.partition(":")
            rules.append((pattern.strip(), LeafPolicy(
                mode=mode.strip(), period_steps=int(per) if per else period_steps,
                scrub_period_steps=scrub_period_steps,
                max_vulnerable_steps=max_vulnerable_steps)))
        return cls(default=LeafPolicy(
            mode=default_mode, period_steps=period_steps,
            scrub_period_steps=scrub_period_steps,
            max_vulnerable_steps=max_vulnerable_steps), rules=tuple(rules), **kw)


# ------------------------------------------------------------------- governor
class StragglerGovernor:
    """Period back-off with recovery.

    Under sustained slowdown (a step > ``factor`` x the rolling median) the
    update period is stretched (doubled, capped) so redundancy never stalls
    the critical path.  After ``recovery_steps`` consecutive normal steps
    the stretch is halved back toward the configured period — the seed's
    watchdog doubled forever.
    """

    def __init__(self, factor: float = 3.0, window: int = 20,
                 recovery_steps: int = 10, max_scale: int = 512):
        self.factor = factor
        self.recovery_steps = recovery_steps
        self.max_scale = max_scale
        self.times: collections.deque = collections.deque(maxlen=window)
        self.scale = 1
        self._calm = 0

    def observe(self, dt: float) -> int:
        """Record one step time; returns the current period multiplier."""
        self.times.append(dt)
        if len(self.times) < self.times.maxlen:
            return self.scale
        med = statistics.median(self.times)
        if dt > self.factor * med:
            self.scale = min(self.scale * 2, self.max_scale)
            self._calm = 0
        elif self.scale > 1:
            self._calm += 1
            if self._calm >= self.recovery_steps:
                self.scale = max(1, self.scale // 2)
                self._calm = 0
        return self.scale


# ----------------------------------------------------------------------- tick
@dataclasses.dataclass
class TickReport:
    """What one ``tick`` did (host-side observability)."""
    step: int
    updated: Tuple[str, ...] = ()          # group labels that ran Algorithm 1
    deadline_fired: Tuple[str, ...] = ()   # subset forced by freshness deadline
    scrubbed: Tuple[str, ...] = ()
    mismatches: int = 0
    alarms: int = 0
    # Overlap pipeline observability: due ticks folded into a still-in-flight
    # update, and groups whose speculative queued dispatch overflowed (the
    # full-recompute fallback ran on resolution).
    coalesced: Tuple[str, ...] = ()
    overflowed: Tuple[str, ...] = ()
    # Scrub patroller / rebuild (repro.scrub).  ``repaired`` maps leaf name
    # -> replacement leaf array the caller MUST adopt (parity rebuilds and
    # shard-rebuild writes happen functionally; the store cannot mutate the
    # caller's arrays).  ``unrecoverable`` carries structured
    # repro.core.repairs.UnrecoverableBlock records; ``rebuild`` is the
    # active repro.scrub.RebuildStatus (None = no rebuild running).
    patrolled: Tuple[str, ...] = ()
    patrol_mismatches: int = 0
    # Consecutive ticks the patrol has gone without dispatching a probe
    # (busy foreground); resets on dispatch, forced past
    # ``RedundancyPolicy.patrol_max_starved_ticks``.
    patrol_starved_ticks: int = 0
    repaired: Dict[str, Any] = dataclasses.field(default_factory=dict)
    unrecoverable: Tuple[Any, ...] = ()
    rebuild: Optional[Any] = None
    # Active elastic-remesh migration (repro.remesh.RemeshStatus; None = no
    # remesh running).  On the adoption tick this is the final status with
    # ``done=True`` and the returned red is already the new geometry.
    remesh: Optional[Any] = None
    # Health governor observability (repro.health.HealthReport; None when
    # the governor is disabled): per-group breaker states, escalation-
    # ladder actions, vulnerability ages, and freshness violations.
    health: Optional[Any] = None


def _ready(x) -> bool:
    """Non-blocking readiness probe for a dispatched jax array."""
    try:
        return bool(x.is_ready())
    except AttributeError:      # non-jax stand-ins (tests) are always ready
        return True


def _fits_host(x) -> bool:
    """Host fold of a fetched fit signal: scalar (machine-local) or
    per-shard flag array alike."""
    return workqueue.fold_fits_host(x)


@dataclasses.dataclass
class _Pending:
    """One in-flight overlapped Algorithm-1 update (per group).

    ``red`` holds the program's output arrays (futures until the device
    finishes); ``fits`` is the batch's stacked device-computed queue-fit
    vector (row ``fits_index`` belongs to this group; per-shard columns
    under a mesh), with a host copy already in flight
    (``copy_to_host_async`` — or, when the backend lacks it, pre-fetched
    into ``fits_host`` at dispatch time so resolution never pays a
    synchronous device round trip).  Resolution adopts the outputs into
    the live view, feeds the fit row forward as the next speculation
    signal and, for a queued dispatch that overflowed, triggers the
    full-recompute fallback.

    With the off-thread dispatcher, ``launched`` is the batch's shared
    event, set once the resolver thread has fetched + folded the batch's
    fit signal into ``fits_host`` — ``None`` means the dispatch ran in
    inline mode (no thread; the fold happens lazily at resolution).  A
    resolver failure lands in ``error`` and re-raises at resolution.
    """
    red: Optional[Dict[str, Any]]
    fits: Any
    queued: bool
    step: int
    coalesced: int = 0
    launched: Optional[threading.Event] = None
    fits_index: int = 0
    fits_host: Optional[bool] = None
    error: Optional[BaseException] = None
    # Health-governor bookkeeping: wall-clock dispatch timestamp (wedged-
    # dispatch detection) and the group's freshness clocks as they stood
    # *before* this dispatch — abandoning a wedged update rolls back to
    # these, so the deadline keeps counting from the oldest unprotected
    # write.
    dispatched_at: float = dataclasses.field(default_factory=time.monotonic)
    prev_step: int = 0
    prev_time: float = 0.0


def _launched(p: "_Pending") -> bool:
    """Has the pending's resolver job finished (fit signal folded on the
    host)?  ``launched`` is None in inline mode — always done, the fold
    happens lazily at resolution instead."""
    ev = p.launched
    return ev is None or ev.is_set()


def _pending_ready(p: "_Pending") -> bool:
    """Non-blocking: resolver done AND the fit signal is resolvable
    without a device sync.  (The governor's wedged-dispatch rung probes
    this: a fetch stuck behind a wedged device counts as wedged too.)

    Thread mode never probes the device array: the resolver event being
    set means ``fits_host``/``error`` are already published, and the
    array's ``is_ready`` notification can go missing outright when a
    blocking transfer runs concurrently on another thread (observed on
    the CPU backend) — gating on it would stall resolution behind a
    phantom in-flight signal.  ``_ready`` still runs over the published
    value so the crash machine's forced-in-flight override keeps
    simulating a wedge."""
    ev = p.launched
    if ev is not None:
        return ev.is_set() and _ready(p.fits_host)
    return _ready(p.fits)


def _fits_host_pending(p: "_Pending") -> bool:
    """Host fold of a pending's fit row out of the batch's stacked fits
    vector: ``fits_host`` if the dispatch-time fallback fetch ran, else a
    host memory read of row ``fits_index`` (per-shard columns AND-fold on
    the host — no device program, no collective)."""
    if p.fits_host is not None:
        return bool(p.fits_host)
    arr = np.asarray(p.fits)
    return workqueue.fold_fits_host(arr[p.fits_index] if arr.ndim else arr)


class _Dispatcher:
    """Dedicated resolver thread for overlapped Algorithm-1 dispatches.

    A plain FIFO worker: jobs (device->host fit fetch + fold closures
    over already-dispatched batches) run in submission order, so
    per-batch resolution order is preserved and the foreground tick
    never blocks on device execution or a host round trip.  ``stop``
    drains the queue (sentinel goes in behind any queued jobs) and
    joins — a clean shutdown can never drop a fetch.
    """

    def __init__(self):
        self._q: "queue.SimpleQueue" = queue.SimpleQueue()
        self.thread = threading.Thread(
            target=self._run, name="repro-dispatch", daemon=True)
        self.thread.start()

    def _run(self) -> None:
        while True:
            job = self._q.get()
            if job is None:
                return
            job()

    def submit(self, job: Callable[[], None]) -> None:
        self._q.put(job)

    def stop(self) -> None:
        if self.thread.is_alive():
            self._q.put(None)
            self.thread.join()


@dataclasses.dataclass
class _Group:
    label: str
    policy: LeafPolicy
    names: Tuple[str, ...]
    engine: Optional[RedundancyEngine]     # None for mode == "none"
    last_update_step: int = 0
    last_update_time: float = dataclasses.field(default_factory=time.monotonic)
    # Overlap-pipeline state: at most one in-flight update, plus the
    # speculation signal (did the last consumed snapshot fit the queues?).
    # Pessimistic start: the full program is always correct, and the first
    # due tick after attach often carries a large dirty set; the first
    # resolved fit signal (or a flush's exact check) flips it.
    pending: Optional[_Pending] = None
    predicted_fits: bool = False


# ---------------------------------------------------------------------- store
class ProtectedStore:
    """Pytree-native facade owning the full redundancy lifecycle.

    One store wraps one protected state pytree (train params+opt, serve KV
    caches, a raw heap) and hides mode branches, scheduling, double-check
    scrubbing, straggler back-off, and flush behind three calls.
    """

    def __init__(self, policy: Optional[RedundancyPolicy] = None,
                 mesh: Any = None):
        self.policy = policy or RedundancyPolicy()
        self.mesh = mesh
        self.groups: Dict[str, _Group] = {}
        self.corruption_alarms = 0
        self._none_metas: Dict[str, BlockMeta] = {}
        self._governor = StragglerGovernor(
            factor=self.policy.straggler_factor,
            window=self.policy.straggler_window,
            recovery_steps=self.policy.straggler_recovery_steps)
        self._jit_update: Dict[Tuple[Any, Any], Any] = {}
        self._jit_scrub: Dict[str, Any] = {}
        self._jit_misc: Dict[Tuple[Any, str], Any] = {}
        # Off-thread dispatcher (RedundancyPolicy.dispatcher_thread):
        # created lazily at the first overlapped dispatch, shut down by
        # flush and at a remesh handover.
        self._dispatcher: Optional[_Dispatcher] = None
        # Scrub patroller (repro.scrub) — built by attach() when the policy
        # enables it (patrol_bytes_per_tick > 0) and a vilamb group exists.
        self.patroller: Optional[Any] = None
        # Freshness-SLO health governor (repro.health) — built by attach()
        # when policy.health is set; None = off, zero tick overhead.
        self._health: Optional[Any] = None
        # Elastic remesh (repro.remesh): a queued geometry-change request,
        # the active migrator, and the mesh-geometry epoch counter (bumped
        # at every remesh adoption; cross-shard parity images carry the
        # epoch they were folded under).
        self._remesh_request: Optional[Tuple[Any, Dict[str, Any]]] = None
        self._remesh: Optional[Any] = None
        self.geometry_version = 0
        # Leaves pasted/moved by a settle/flush-time background drain
        # (satellite of the rebuild lifecycle): callers adopt via
        # ``take_repaired``.
        self._drained: Dict[str, Any] = {}
        # Lifecycle phase hooks (repro.faults): host-level observation
        # points for crash-consistency replay.  Empty list = zero overhead
        # on every hot path (a single truthiness check).
        self._phase_hooks: List[Callable[[str, Dict[str, Any]], None]] = []

    # -------------------------------------------------------------- phase hooks
    def add_phase_hook(self, fn: Callable[[str, Dict[str, Any]], None]) -> None:
        """Register ``fn(phase, info)`` to fire at lifecycle phases.

        Phases (see ``repro.faults.crashpoints.CRASH_PHASES``): ``on_write``,
        ``dispatcher_enqueue`` (the tick is about to dispatch the batched
        multi-group program and hand its fit fetch to the resolver
        thread), ``dispatch`` (per-group, right after the overlapped
        batch was dispatched and the epoch-swapped live view adopted),
        ``coalesce`` (due tick folded into the in-flight update),
        ``dispatcher_join`` (about to block on the resolver thread's
        fetched fit signal), ``adopt`` / ``adopt_forced`` (lazy vs
        deadline/scrub-forced resolution), ``blocking_update``, ``scrub``,
        ``tick``, ``flush``, ``settle``.  ``info['red']`` is the
        live redundancy view at that instant — the state a crash would
        persist.  Hooks are host-level: they never fire while tracing, so
        an ``on_write`` embedded in a jitted step is silently skipped.
        Exceptions raised by a hook propagate (the crash machine's process-
        death emulation relies on this).
        """
        self._phase_hooks.append(fn)

    def remove_phase_hook(self, fn) -> None:
        self._phase_hooks.remove(fn)

    def _phase(self, name: str, **info) -> None:
        if not self._phase_hooks:
            return
        red = info.get("red")
        if red is not None:
            for leaf in jax.tree_util.tree_leaves(red):
                if isinstance(leaf, jax.core.Tracer):
                    return                  # under trace: host hooks skip
        for fn in list(self._phase_hooks):
            fn(name, info)

    # ------------------------------------------------------------ construction
    def attach(self, tree: Any, specs: Optional[Mapping[str, Any]] = None
               ) -> "ProtectedStore":
        """Declare the protected pytree (arrays or ShapeDtypeStructs).

        Nested dicts are flattened to ``a/b/c`` paths — the namespace the
        policy rules match against.  ``specs`` optionally maps those paths
        to PartitionSpecs for sharded (machine-local) redundancy.  Returns
        ``self`` for chaining: ``red = store.attach(state).init(state)``.
        """
        flat = flatten_dict(tree)
        structs = {k: jax.ShapeDtypeStruct(v.shape, v.dtype)
                   for k, v in flat.items()}
        specs = dict(specs or {})
        # Remembered for elastic remesh: re-striping onto a new mesh reuses
        # the declared global structs and PartitionSpecs.
        self._structs = structs
        self._specs = dict(specs)
        by_policy: Dict[LeafPolicy, List[str]] = {}
        for name in structs:
            by_policy.setdefault(self.policy.leaf_policy(name), []).append(name)
        self.groups = {}
        self._none_metas = {}
        for i, (lp, names) in enumerate(by_policy.items()):
            label = f"g{i}:{lp.mode}"
            engine = None
            if lp.mode == "none":
                for n in names:
                    lshape = _local_shape(structs[n].shape, specs.get(n), self.mesh)
                    self._none_metas[n] = make_meta(
                        jax.ShapeDtypeStruct(lshape, structs[n].dtype),
                        lanes_per_block=self.policy.lanes_per_block,
                        stripe_data_blocks=self.policy.stripe_data_blocks)
            else:
                cfg = RedundancyConfig(
                    mode=lp.mode, period_steps=lp.period_steps,
                    scrub_period_steps=lp.scrub_period_steps,
                    lanes_per_block=self.policy.lanes_per_block,
                    stripe_data_blocks=self.policy.stripe_data_blocks,
                    use_kernels=self.policy.use_kernels,
                    kernel_interpret=self.policy.kernel_interpret,
                    work_queue_frac=(
                        lp.work_queue_frac if lp.work_queue_frac is not None
                        else self.policy.work_queue_frac))
                engine = RedundancyEngine(
                    {n: structs[n] for n in names}, cfg, mesh=self.mesh,
                    specs={n: specs[n] for n in names if n in specs})
            self.groups[label] = _Group(label, lp, tuple(names), engine)
        self._jit_update = {}
        self._jit_scrub = {}
        self._jit_misc = {}
        self._stop_dispatcher()
        if self.policy.precompile:
            self.warmup()
        self.patroller = None
        if self.policy.patrol_bytes_per_tick > 0 and any(
                g.policy.mode == "vilamb" for g in self._protected()):
            # Runtime import: repro.scrub builds on repro.core submodules.
            from repro.scrub import ScrubPatroller
            self.patroller = ScrubPatroller(self)
        self._health = None
        if self.policy.health:
            # Runtime import: repro.health builds on repro.core submodules.
            from repro.health import HealthGovernor, HealthPolicy
            hp = self.policy.health
            self._health = HealthGovernor(
                self, hp if isinstance(hp, HealthPolicy) else None)
        return self

    @classmethod
    def from_engine(cls, engine: RedundancyEngine, mode: str = "vilamb",
                    period_steps: Optional[int] = None,
                    scrub_period_steps: int = 0) -> "ProtectedStore":
        """Wrap a pre-built single-mode engine (deprecation-shim path).

        The engine keeps its geometry (lanes/stripes/kernels); the store adds
        the lifecycle around it.
        """
        cfg = engine.config
        pol = RedundancyPolicy.single(
            mode, period_steps=period_steps if period_steps is not None
            else cfg.period_steps,
            scrub_period_steps=scrub_period_steps,
            lanes_per_block=cfg.lanes_per_block,
            stripe_data_blocks=cfg.stripe_data_blocks,
            use_kernels=cfg.use_kernels, kernel_interpret=cfg.kernel_interpret,
            work_queue_frac=cfg.work_queue_frac)
        store = cls(pol, mesh=engine.mesh)
        if mode == "none":
            store.groups = {}
            store._none_metas = dict(engine.metas)
        else:
            store.groups = {"g0:" + mode: _Group(
                "g0:" + mode, pol.default, tuple(engine.metas), engine)}
        return store

    # ---------------------------------------------------------------- structure
    @property
    def metas(self) -> Dict[str, BlockMeta]:
        out = dict(self._none_metas)
        for g in self.groups.values():
            if g.engine is not None:
                out.update(g.engine.metas)
        return out

    @property
    def protected_metas(self) -> Dict[str, BlockMeta]:
        """Metas of leaves that actually carry redundancy arrays."""
        out: Dict[str, BlockMeta] = {}
        for g in self.groups.values():
            if g.engine is not None:
                out.update(g.engine.metas)
        return out

    def leaf_policy(self, name: str) -> LeafPolicy:
        for g in self.groups.values():
            if name in g.names:
                return g.policy
        raise KeyError(name)

    def engine_for(self, name: str) -> Optional[RedundancyEngine]:
        for g in self.groups.values():
            if name in g.names:
                return g.engine
        return None

    def shard_factor(self, name: str) -> int:
        """Shards a leaf's redundancy arrays concatenate (1 = machine-local).

        Global block space for sharded leaves: shard ``s``'s local block
        ``b`` is global block ``s * meta.n_blocks + b`` — the indexing
        scrub masks, ``vulnerable_masks``, fault injection, and
        ``recover_block`` share.
        """
        eng = self.engine_for(name)
        return 1 if eng is None else eng.shard_factor(name)

    def _protected(self) -> List[_Group]:
        return [g for g in self.groups.values() if g.engine is not None]

    @property
    def has_sync(self) -> bool:
        return any(g.policy.mode == "sync" for g in self._protected())

    @property
    def has_periodic(self) -> bool:
        return any(g.policy.mode == "vilamb" for g in self._protected())

    @property
    def protects(self) -> bool:
        return bool(self._protected())

    def red_structs(self, global_: bool = True) -> RedundancyState:
        out: RedundancyState = {}
        for g in self._protected():
            out.update(g.engine.red_structs(global_))
        return out

    def red_shardings(self) -> Dict[str, Any]:
        out: Dict[str, Any] = {}
        for g in self._protected():
            out.update(g.engine.red_shardings())
        return out

    def expand_events(self, sparse_events: Mapping[str, Any]) -> Dict[str, Any]:
        """Suffix-keyed sparse events -> full-path events, defaulting ALL.

        ``{"moe/wi": mask}`` fans out to every protected leaf whose path
        suffix (after the first ``/``) matches; unmatched leaves are marked
        fully dirty — the conservative choice for dense updates.
        """
        events: Dict[str, Any] = {}
        for g in self._protected():
            for name in g.names:
                _, _, suffix = name.partition("/")
                ev = sparse_events.get(suffix)
                events[name] = ev if ev is not None else ALL
        return events

    # ----------------------------------------------------------------- lifecycle
    def init(self, tree: Any) -> RedundancyState:
        """Full redundancy computation (paper: file-creation time)."""
        leaves = flatten_dict(tree)
        red: RedundancyState = {}
        for g in self._protected():
            red.update(g.engine.init({n: leaves[n] for n in g.names}))
        return red

    def on_write(self, red: RedundancyState,
                 events: Optional[Mapping[str, Any]] = None,
                 old: Optional[Mapping[str, jax.Array]] = None,
                 new: Optional[Mapping[str, jax.Array]] = None,
                 row_diffs: Optional[Mapping[str, Tuple]] = None
                 ) -> RedundancyState:
        """Record writes; traceable — call inside the jitted mutation step.

        Per leaf group: ``vilamb`` ORs ``events`` (dirty marks) into the
        bitvectors; ``sync`` applies the Pangolin inline diff from
        ``old``/``new`` (or the sparse ``row_diffs`` fast path
        ``{name: (rows, old_rows, new_rows)}`` when rows map 1:1 to blocks);
        ``none`` passes through.  Leaves absent from ``events`` are left
        unmarked — use :meth:`expand_events` for dense default-ALL marking.
        """
        if self._health is not None:
            # Rung-3 admission control: while some breaker is CRITICAL the
            # governor throttles (spin) or rejects (BackpressureError)
            # foreground writes so the device can drain.  No-op under a jax
            # trace and while every breaker is below CRITICAL.
            self._health.admit(red)
        events = dict(events or {})
        row_diffs = dict(row_diffs or {})
        out = dict(red)
        for g in self._protected():
            red_sub = {n: out[n] for n in g.names}
            if g.policy.mode == "vilamb":
                evs = {n: events[n] for n in g.names if n in events}
                if evs:
                    out.update(g.engine.mark_dirty(red_sub, evs))
            elif g.policy.mode == "sync":
                if all(n in row_diffs for n in g.names):
                    for n in g.names:
                        rows, o, v = row_diffs[n]
                        out[n] = g.engine.sync_update_rows(n, out[n], rows, o, v)
                elif old is not None and new is not None:
                    out.update(g.engine.sync_update(
                        {n: old[n] for n in g.names},
                        {n: new[n] for n in g.names}, red_sub))
                else:
                    raise ValueError(
                        f"sync leaves {g.names} need old=/new= (or row_diffs=) "
                        "in on_write")
        if self._phase_hooks:
            self._phase("on_write", red=dict(out))
        return out

    # --------------------------------------------------- dispatch machinery
    def _async_group(self, g: _Group) -> bool:
        """Does this group take the overlap-pipelined tick path?

        Mesh-sharded groups qualify too: their per-shard fit flags ride
        the batched program's stacked fits vector, whose host copy starts
        at launch time — the AND-fold over shards is a host memory read
        at resolution, exactly like the machine-local scalar.
        """
        return (g.engine is not None and g.policy.mode == "vilamb"
                and self.policy.async_tick and self.policy.pipeline_depth > 0)

    def _build_update(self, label: str, variant: str):
        """Un-lowered jitted Algorithm-1 program for one group.

        Variants: ``full`` / ``queued`` — the blocking programs (input red
        donated in place; used by ``flush`` and the blocking tick);
        ``async_full`` / ``async_queued`` — the overlap programs
        ``(leaves, red) -> (red, fits)``.  The overlap programs donate
        **nothing**: on this backend a donated dispatch blocks the host
        until its donated inputs are defined, so in-place updates would
        re-serialize the very pipeline the overlap exists to free.  The
        old epoch's arrays instead stay alive as the double buffer (the
        foreground keeps dispatching against them) and the program's
        outputs are adopted at resolution.
        """
        eng = self.groups[label].engine
        if variant == "full":
            return jax.jit(eng.redundancy_step, donate_argnums=(1,))
        if variant == "queued":
            return jax.jit(eng.redundancy_step_queued, donate_argnums=(1,))
        assert variant in ("async_full", "async_queued"), variant
        q = variant == "async_queued"
        return jax.jit(
            lambda lv, rd, e=eng: e.redundancy_step_async(lv, rd, queued=q))

    def _update_fn(self, label: str, variant: str):
        key = (label, variant)
        fn = self._jit_update.get(key)
        if fn is None:
            fn = self._jit_update[key] = self._build_update(label, variant)
        return fn

    def _build_update_many(self, labels: Tuple[str, ...],
                           variants: Tuple[str, ...]):
        """One jitted program running every due group's overlap Algorithm-1
        pass and stacking the fit signals into a single vector.

        This is the tentpole of the sharded-overlap fix: a due tick used to
        launch one update program *plus* one AND-fold program per group —
        each launch serializing a full per-device dispatch on the host.
        Batched, the tick costs one launch total, and the fits come back as
        one stacked ``(n_groups,)`` vector (``(n_groups, n_devices)`` under
        a mesh — pinned to per-device columns so the program still lowers
        collective-free; the AND-fold over shards happens on the host at
        resolution, where the row is already fetched memory).
        """
        engines = [self.groups[l].engine for l in labels]
        qs = [v == "async_queued" for v in variants]
        mesh = engines[0].mesh

        def many(subs, reds):
            outs, fits = [], []
            for eng, q, sub, rd in zip(engines, qs, subs, reds):
                o, f = eng.redundancy_step_async(sub, rd, queued=q)
                outs.append(o)
                fits.append(f)
            stacked = jnp.stack(fits)
            if mesh is not None:
                from jax.sharding import NamedSharding, PartitionSpec as P
                # Per-shard flag columns stay device-local: each device
                # holds its own column of every group's row — stacking is
                # a local concat, never a collective.
                stacked = jax.lax.with_sharding_constraint(
                    stacked,
                    NamedSharding(mesh, P(None, tuple(mesh.axis_names))))
            return tuple(outs), stacked

        return jax.jit(many)

    def _update_many_fn(self, labels: Tuple[str, ...],
                        variants: Tuple[str, ...]):
        key = (tuple(labels), tuple(variants))
        fn = self._jit_update.get(key)
        if fn is None:
            fn = self._jit_update[key] = self._build_update_many(
                key[0], key[1])
        return fn

    def warmup(self) -> "ProtectedStore":
        """AOT-compile every Algorithm-1 variant each group can dispatch.

        Runs at ``attach`` time (``RedundancyPolicy.precompile``) so the
        first due tick never hides a compile stall: both the queued and the
        full program are ready before the first overlapped dispatch.  This
        was the `fig1_insert` threads8 collapse — warmup traffic fit the
        work queue, steady state overflowed, and the full variant's ~200 ms
        compile landed inside the measured loop.

        Mesh-sharded groups are warmed too, lowered against the group's
        declared shardings (leaves per their PartitionSpecs, redundancy per
        ``red_shardings``); callers of a precompiled mesh store must hand
        ``tick``/``flush`` arrays sharded that way — pass
        ``precompile=False`` to keep fully flexible jit dispatch instead.
        Returns ``self`` for chaining.
        """
        from jax.sharding import NamedSharding, PartitionSpec as P

        for g in self._protected():
            if g.policy.mode != "vilamb":
                continue
            eng = g.engine
            if eng.mesh is None:
                leaf_structs = {
                    n: jax.ShapeDtypeStruct(eng.metas[n].shape,
                                            jnp.dtype(eng.metas[n].dtype))
                    for n in g.names}
                red_structs = {n: leaf_red_struct(eng.metas[n])
                               for n in g.names}
            else:
                leaf_structs = {
                    n: jax.ShapeDtypeStruct(
                        s.shape, s.dtype,
                        sharding=NamedSharding(eng.mesh,
                                               eng.specs.get(n, P())))
                    for n, s in eng.global_leaf_structs.items()}
                red_structs = jax.tree.map(
                    lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype,
                                                       sharding=sh),
                    eng.red_structs(global_=True), eng.red_shardings(),
                    is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
            # Async groups also warm the blocking pair: flush (the
            # latency-critical preemption path) still dispatches it.
            variants = (("async_full", "async_queued", "full", "queued")
                        if self._async_group(g) else ("full", "queued"))
            for variant in variants:
                if "queued" in variant and not eng.has_queue:
                    continue
                key = (g.label, variant)
                if key in self._jit_update:
                    continue
                self._jit_update[key] = self._build_update(
                    g.label, variant).lower(leaf_structs, red_structs).compile()
            if self._async_group(g):
                # The tick launches through the batched multi-group program
                # (a singleton batch when one group is due); AOT-lower both
                # speculative variants of it too, so the first overlapped
                # dispatch never hides a compile stall on the dispatcher
                # thread.
                for variant in ("async_full", "async_queued"):
                    if "queued" in variant and not eng.has_queue:
                        continue
                    mkey = ((g.label,), (variant,))
                    if mkey in self._jit_update:
                        continue
                    self._jit_update[mkey] = self._build_update_many(
                        mkey[0], mkey[1]).lower(
                        (leaf_structs,), (red_structs,)).compile()
                # Warm the epoch-swap helper too (it compiles on first use
                # otherwise — a ~50 ms stall inside the first overlapped
                # dispatch).  A real call on the tiny bitvectors both
                # compiles it and keeps the fast C++ dispatch path.
                if eng.mesh is None:
                    words = {n: bits.zeros(eng.metas[n].n_blocks)
                             for n in g.names}
                else:
                    shardings = eng.red_shardings()
                    words = {
                        n: jax.device_put(
                            jnp.zeros((eng.metas[n].n_dirty_words
                                       * eng.shard_factor(n),), jnp.uint32),
                            shardings[n].dirty)
                        for n in g.names}
                jax.block_until_ready(self._swap_fn(g.label)(words, words))
        return self

    def _dispatch_blocking(self, g: _Group, sub, red_sub):
        """Blocking dispatch (flush / legacy ``async_tick=False`` tick):
        queued program when the live dirty stripes fit the work queues — an
        exact, host-side ``queue_fits`` round trip (per-shard counts under
        a mesh) — full recompute otherwise; bitwise-identical either way.
        The exact fit answer doubles as a free speculation seed for later
        overlapped dispatches."""
        queued = g.engine.has_queue and g.engine.queue_fits(red_sub)
        g.predicted_fits = queued or not g.engine.has_queue
        return self._update_fn(g.label, "queued" if queued else "full")(
            sub, red_sub)

    def _swap_fn(self, label: str):
        """One-dispatch epoch swap for the live view: per leaf, the epoch-A
        snapshot (``dirty | shadow``, becomes the live ``shadow``) and a
        fresh zero epoch-B bitmap (becomes the live ``dirty``).

        Not donated: its inputs are usually still being produced by the
        step just dispatched, and a donated dispatch would block on them.

        Under a mesh the outputs are pinned to the bitvectors' shardings:
        the fresh epoch-B zeros are a constant, so GSPMD would otherwise
        freely re-shard them (replicated) and the precompiled update
        program would reject the mismatched live view.
        """
        key = (label, "swap")
        fn = self._jit_misc.get(key)
        if fn is None:
            g = self.groups[label]
            names = g.names

            def swap(dirty, shadow):
                snaps = {n: jnp.bitwise_or(dirty[n], shadow[n]) for n in names}
                fresh = {n: jnp.zeros_like(dirty[n]) for n in names}
                return snaps, fresh

            kw = {}
            if g.engine is not None and g.engine.mesh is not None:
                sh = {n: g.engine.red_shardings()[n].dirty for n in names}
                kw["out_shardings"] = (sh, sh)
            fn = self._jit_misc[key] = jax.jit(swap, **kw)
        return fn

    def _swap_many_fn(self, labels: Tuple[str, ...]):
        """Epoch swap for a whole dispatch batch in one program.

        A singleton batch delegates to the per-group :meth:`_swap_fn` (so
        its warmed ``(label, "swap")`` cache entry keeps serving the
        common case); a multi-group batch compiles one fused program —
        returns a tuple over groups of ``(snaps, fresh)``.
        """
        if len(labels) == 1:
            base = self._swap_fn(labels[0])
            return lambda dirties, shadows: (base(dirties[0], shadows[0]),)
        key = (tuple(labels), "swap_many")
        fn = self._jit_misc.get(key)
        if fn is None:
            groups = [self.groups[l] for l in labels]

            def swap_many(dirties, shadows):
                return tuple(
                    ({n: jnp.bitwise_or(d[n], s[n]) for n in g.names},
                     {n: jnp.zeros_like(d[n]) for n in g.names})
                    for g, d, s in zip(groups, dirties, shadows))

            kw = {}
            if groups[0].engine is not None and groups[0].engine.mesh is not None:
                shs = tuple(
                    ({n: g.engine.red_shardings()[n].dirty for n in g.names},
                     {n: g.engine.red_shardings()[n].dirty for n in g.names})
                    for g in groups)
                kw["out_shardings"] = shs
            fn = self._jit_misc[key] = jax.jit(swap_many, **kw)
        return fn

    def _submit(self, job: Callable[[], None]) -> None:
        """Run ``job`` on the dispatcher thread (lazily created), or inline
        when ``RedundancyPolicy.dispatcher_thread`` is off."""
        if not self.policy.dispatcher_thread:
            job()
            return
        d = self._dispatcher
        if d is None or not d.thread.is_alive():
            d = self._dispatcher = _Dispatcher()
        d.submit(job)

    def _stop_dispatcher(self) -> None:
        """Drain + join the dispatcher thread (flush / remesh handover).
        Queued fetches complete first, so no pending is ever dropped."""
        d, self._dispatcher = self._dispatcher, None
        if d is not None:
            d.stop()

    def sync_inflight(self) -> "ProtectedStore":
        """Wait until every pending's resolver job has run and its fit
        signal is device-complete (test/replay determinism hook — the
        crash machine and the sharded drivers use it to force 'adopt,
        never coalesce' schedules independent of machine load)."""
        for g in self._protected():
            p = g.pending
            if p is None:
                continue
            if p.launched is not None:
                p.launched.wait()
            if p.error is None and p.fits is not None:
                jax.block_until_ready(p.fits)
        return self

    def _dispatch_async_many(self,
                             items: List[Tuple[_Group, bool, int, float]],
                             get_leaves, out: Dict[str, Any], step: int
                             ) -> Dict[str, LeafRedundancy]:
        """Overlapped batched dispatch with an off-thread resolver.

        Every due group's speculative queued-or-full program runs as ONE
        jitted multi-group launch with a single stacked fits vector —
        collapsing the per-group dispatch overhead (the dominant
        per-due-tick host cost on a sharded store) into one program
        launch.  The device->host fit fetch + AND-fold then runs on the
        dispatcher thread, so the tick never touches the device again
        for this batch.  Nothing is donated and nothing waits on
        execution: the returned **live view**
        carries the old epoch's checksums/parity (kept alive as the double
        buffer), a fresh zero epoch-B dirty bitmap for the foreground's
        next ``on_write``, and ``shadow`` = snapshot A — so scrub,
        recovery, accounting, and a crash-persisted checkpoint all keep
        treating the in-flight blocks as vulnerable until resolution
        adopts the result.  The host copy of the fits vector is owned by
        the resolver job (inline mode: ``copy_to_host_async`` at dispatch
        time, with an eager fallback fetch when the backend lacks it), so
        ``_resolve`` never pays a synchronous device round trip.
        """
        labels = tuple(g.label for g, *_ in items)
        variants = tuple("async_queued" if q else "async_full"
                         for _, q, *_ in items)
        lv = get_leaves()
        subs = tuple({n: lv[n] for n in g.names} for g, *_ in items)
        red_subs = tuple({n: out[n] for n in g.names} for g, *_ in items)
        swaps = self._swap_many_fn(labels)(
            tuple({n: rs[n].dirty for n in rs} for rs in red_subs),
            tuple({n: rs[n].shadow for n in rs} for rs in red_subs))
        # The batched program is dispatched HERE, on the tick thread: jax's
        # dispatch is asynchronous (nothing below blocks on execution), and
        # dispatching before returning is what makes a caller's later
        # donation of the captured leaf/red buffers safe — the runtime
        # already holds usage references.  Handing the *dispatch* to the
        # thread was measured and rejected: a donating caller (train step,
        # decode step) deletes the captured buffers before the thread gets
        # to shard them.
        outs, fits = self._update_many_fn(labels, variants)(subs, red_subs)
        ev = threading.Event() if self.policy.dispatcher_thread else None
        pendings = []
        for i, (g, queued, prev_step, prev_time) in enumerate(items):
            # prev_* carry the freshness clocks as they stood when the
            # tick collected this group — before the tick bumped them:
            # the governor's wedged-dispatch abandon rolls back to these.
            # dispatched_at stamps the handoff — a fetch stuck behind a
            # wedged device counts as wedged from the moment the
            # foreground handed it off.
            p = _Pending(red=outs[i], fits=fits, queued=queued, step=step,
                         launched=ev, fits_index=i,
                         prev_step=prev_step, prev_time=prev_time)
            g.pending = p
            pendings.append(p)

        if ev is not None:
            # Off-thread resolver: the dedicated thread rides out device
            # execution (np.asarray blocks *it*, not the tick) and
            # publishes the folded per-group fit bits; ``_resolve`` then
            # only reads plain Python bools.
            def resolve_job(fits=fits, pendings=pendings, ev=ev):
                try:
                    host = np.asarray(fits)
                    for i, p in enumerate(pendings):
                        p.fits_host = workqueue.fold_fits_host(
                            host[i] if host.ndim else host)
                except BaseException as e:   # surfaces at resolution
                    for p in pendings:
                        p.error = e
                finally:
                    ev.set()

            self._submit(resolve_job)
        elif hasattr(fits, "copy_to_host_async"):
            # Inline mode (PR3..PR8 behavior): start the non-blocking
            # device->host copy now; resolution folds the landed row.
            fits.copy_to_host_async()
        else:
            # Backend without a non-blocking device->host copy: fetch
            # HERE, at dispatch time — the resolve-side read must stay a
            # host memory read, never a synchronous round trip.
            host = np.asarray(fits)
            for i, p in enumerate(pendings):
                p.fits_host = workqueue.fold_fits_host(
                    host[i] if host.ndim else host)
        view: Dict[str, LeafRedundancy] = {}
        for (g, *_), (snaps, fresh), rs in zip(items, swaps, red_subs):
            view.update({n: dataclasses.replace(
                            rs[n], dirty=fresh[n], shadow=snaps[n])
                         for n in g.names})
        return view

    def _resolve(self, g: _Group, red_sub, *, wait: bool):
        """Adopt an in-flight update into the live view, if resolvable.

        Returns ``(red_sub', overflowed, deferred)``; ``(None, False, 0)``
        when the update is still in flight (resolver thread still waiting
        on the device, or the device still computing) and ``wait`` is
        False.  Reading the fit row here is a host memory read, not a
        device sync: the resolver thread folded the batch's stacked fits
        vector to plain bools (inline mode: the non-blocking host copy
        started at dispatch time), one tick (or more) ago — ``wait``
        blocks (joins the resolver, which implies the signal landed) only
        when a deadline, scrub, or the governor forces settled state.  A
        dispatch or fetch that threw re-raises here.
        Adoption takes the program's checksums/parity/meta plus its
        ``shadow = overflowed ? snapshot : 0`` select — so a mispredicted
        queued dispatch (``overflowed``) keeps epoch A conservatively
        marked with no host-side merge; the caller then runs the
        full-recompute fallback.  The live dirty bitmap (epoch B, with
        every mark since dispatch) is carried over from the caller.
        ``deferred`` counts due ticks coalesced while the update was
        outstanding.
        """
        p = g.pending
        if p is None:
            return red_sub, False, 0
        if not wait and not _pending_ready(p):
            return None, False, 0
        if p.launched is not None:
            p.launched.wait()            # join: no-op unless wait forced it
        if p.error is not None:
            g.pending = None
            raise p.error
        fits = _fits_host_pending(p)
        g.predicted_fits = fits
        out = {n: dataclasses.replace(p.red[n], dirty=red_sub[n].dirty)
               for n in g.names}
        g.pending = None
        return out, (p.queued and not fits), p.coalesced

    def _drain_background(self, leaves: Dict[str, Any], out: Dict[str, Any],
                          step: Optional[int] = None) -> Dict[str, Any]:
        """Run any active shard rebuild (then remesh migration) to
        completion, synchronously — settle/flush call this before adopting
        so a checkpoint taken mid-rebuild/mid-remesh never persists a
        half-pasted shard or a half-migrated geometry.

        Mutates ``out`` (dirty marks; wholesale red swap on a remesh
        adoption) and returns the possibly-replaced leaves.  Pasted/moved
        leaves are also stashed for :meth:`take_repaired` — the caller of
        settle/flush must adopt them (the store cannot mutate caller
        arrays)."""
        # ``step`` stays Optional all the way down: "caller did not supply
        # a step" is a distinct state from "step 0" (right after attach),
        # and the crash-phase hooks fill in the machine's true current
        # step only when the kwarg is absent — coercing None to 0 here
        # used to stamp rebuild/remesh phases and reports with a bogus
        # step 0.
        step_i = 0 if step is None else int(step)
        pat = self.patroller
        if pat is not None and pat.rebuild is not None:
            rep = TickReport(step=step_i)
            while pat.rebuild is not None:
                pat.rebuild.step_once(leaves, out, rep, step)
                if pat.rebuild.status.done:
                    recs = pat.rebuild.unrecoverable()
                    pat.unrecoverable.extend(recs)
                    pat.rebuild = None
            leaves.update(rep.repaired)
            self._drained.update(rep.repaired)
        if self._remesh is not None:
            rep = TickReport(step=step_i)
            while self._remesh is not None:
                self._remesh_step(leaves, out, rep, step)
            leaves.update(rep.repaired)
            self._drained.update(rep.repaired)
        return leaves

    def take_repaired(self) -> Dict[str, Any]:
        """Leaves replaced by a settle/flush-time background drain (rebuild
        paste windows, remesh migration) since the last call.  Callers that
        settle/flush mid-rebuild/mid-remesh MUST adopt these — the drained
        paste went into these arrays, not the caller's."""
        out, self._drained = self._drained, {}
        return out

    def settle(self, red: RedundancyState,
               leaves: Optional[Mapping[str, jax.Array]] = None,
               step: Optional[int] = None) -> RedundancyState:
        """Adopt every in-flight async update into ``red`` (blocking).

        No new periodic pass is scheduled (that is ``flush``).  With
        ``leaves`` provided, any active shard rebuild / remesh migration is
        drained first (outstanding paste windows complete — a checkpoint
        taken now never sees a half-pasted shard; adopt the drained leaves
        via :meth:`take_repaired`), and a mispredicted speculative queued
        update is repaired immediately with the full-recompute fallback;
        without them, its blocks simply stay marked (shadow) for the next
        pass — conservative either way.  Ticks coalesced behind the
        in-flight update fold into the next due tick.  Pass ``step`` when
        known (it may legitimately be 0): background drain windows stamp
        their reports/phases with it — ``None`` means "unknown", never
        step 0.  Joins the dispatcher for every pending (launch, then fit
        signal) — the ``dispatcher_join`` crash phase fires per joined
        group.
        """
        out = dict(red)
        if leaves is not None:
            leaves = self._drain_background(dict(leaves), out, step=step)
        for g in self._protected():
            if g.pending is None:
                continue
            if self._phase_hooks:
                info = {} if step is None else {"step": int(step)}
                self._phase("dispatcher_join", red=dict(out), group=g.label,
                            **info)
            red_sub, overflowed, _ = self._resolve(
                g, {n: out[n] for n in g.names}, wait=True)
            out.update(red_sub)
            if overflowed and leaves is not None:
                # Full-recompute repair through the *non-donating* overlap
                # program: settle also backs read-only paths (scrub), whose
                # callers keep using their own red — the donating blocking
                # program would invalidate it.  Bitwise-identical to the
                # blocking full program (queued=False never overflows, so
                # its dirty/shadow outputs are zeros too).
                repaired, fits = self._update_fn(g.label, "async_full")(
                    {n: leaves[n] for n in g.names},
                    {n: out[n] for n in g.names})
                g.predicted_fits = _fits_host(fits)
                out.update(repaired)
        if self._phase_hooks:
            self._phase("settle", red=dict(out))
        return out

    def _scrub_fn(self, label: str):
        fn = self._jit_scrub.get(label)
        if fn is None:
            fn = jax.jit(self.groups[label].engine.scrub)
            self._jit_scrub[label] = fn
        return fn

    def tick(self, leaves, red: RedundancyState,
             step: int, *, step_time: Optional[float] = None,
             scrub_period: Optional[int] = None
             ) -> Tuple[RedundancyState, TickReport]:
        """One host-step heartbeat: schedule Algorithm 1 + scrubbing.

        Owns the whole schedule the call sites used to hand-roll: the
        ``step % T`` update cadence per vilamb group (stretched by the
        straggler governor, bounded by the freshness deadline), and
        scrubbing with the paper's double-check (re-verify on an immutable
        snapshot after quiescing before raising an alarm).  ``step_time``
        feeds the governor.  ``scrub_period`` overrides every group's
        scrub cadence (legacy ``scrub_every`` knob).

        ``leaves`` may be the flat leaf mapping or a zero-arg callable
        returning it — the callable form skips building the mapping on the
        (majority of) steps where nothing is due.

        On the default overlap-pipelined path (``RedundancyPolicy
        .async_tick``) a due tick costs the foreground only a dispatch —
        never a device->host round trip: the update program is launched
        speculatively (queued vs full chosen by the previous tick's
        device-computed fit signal, fetched via a non-blocking copy), and
        dirty epochs are double-buffered — the returned state carries the
        previous epoch's checksums/parity with a fresh dirty bitmap and
        the consumed snapshot held in ``shadow``, so the foreground's next
        step depends only on already-defined arrays and never waits on the
        in-flight update.  Results are adopted lazily on a later tick (or
        eagerly on ``flush``/``scrub``/``settle``); a mispredicted queued
        dispatch keeps its snapshot marked (the program's shadow select)
        and the full-recompute fallback runs at resolution
        (``report.overflowed``).  At most one update per group is in
        flight; due ticks arriving meanwhile coalesce
        (``report.coalesced``).

        Note: callers must always adopt the returned state — it is the
        only live lineage (the blocking path donates the Algorithm-1
        input; the overlapped path tracks the epoch buffers through it).
        """
        step = int(step)
        if step_time is not None:
            self._governor.observe(step_time)
        report = TickReport(step=step)
        out = dict(red)
        updated, deadline, scrubbed, coalesced, overflowed = [], [], [], [], []
        # Batched dispatch: the group loop only *decides* (resolve/coalesce/
        # bookkeeping); every group due for an overlapped dispatch lands in
        # to_dispatch and launches as ONE multi-group program after the
        # loop.  Scrubs run last (scrub_groups) so they see the
        # post-dispatch live view exactly as the per-group loop did.
        to_dispatch: List[Tuple[_Group, bool, int, float]] = []
        scrub_groups: List[_Group] = []
        # One clock read and one leaf materialization serve the whole tick.
        now = time.monotonic()
        materialized: Optional[Mapping[str, jax.Array]] = (
            None if callable(leaves) else leaves)

        def get_leaves():
            nonlocal materialized
            if materialized is None:
                materialized = leaves()
            return materialized

        def sub_of(g):
            lv = get_leaves()
            return {n: lv[n] for n in g.names}

        hg = self._health
        if hg is not None:
            hg.begin_tick(step, now)
        # During an active remesh migration the foreground group loop is
        # skipped wholesale: the OLD red stays frozen (authoritative for a
        # crash) while writes keep marking it via on_write, and the
        # migrator recomputes redundancy from current data window by
        # window — a due tick dispatched against the old geometry would
        # race the migration for no benefit.
        for g in (() if self._remesh is not None else self._protected()):
            lp = g.policy
            if step < g.last_update_step:
                # The step counter restarted (new serve wave / fresh run on a
                # long-lived store): rebase so deadlines keep their meaning.
                g.last_update_step = 0
            sp = scrub_period if scrub_period is not None else lp.scrub_period_steps
            scrub_due = bool(sp and policy_mod.should_scrub(step, sp))
            if lp.mode == "vilamb":
                margin = sync_esc = retry = False
                if hg is not None:
                    # Escalation-ladder rung 1: a wedged in-flight update is
                    # abandoned (freshness clocks roll back to pre-dispatch)
                    # and re-dispatched below after a bounded backoff.  The
                    # retry flag forces the dispatch this tick: ``due`` is
                    # step-aligned, so waiting for the next period boundary
                    # would let the breaker cool down between retries.
                    retry = hg.check_pending(g)
                    sync_esc = hg.is_sync_escalated(g.label)
                    margin = hg.within_margin(g, step, now)
                eff = min(lp.period_steps * self._governor.scale,
                          self.policy.period_cap)
                due = policy_mod.should_update(step, eff)
                overdue = (
                    (lp.max_vulnerable_steps > 0
                     and step - g.last_update_step >= lp.max_vulnerable_steps)
                    or (lp.max_vulnerable_seconds > 0
                        and now - g.last_update_time >= lp.max_vulnerable_seconds))
                if self._async_group(g) and not sync_esc:
                    # Overlap pipeline: resolve lazily (blocking only when a
                    # deadline or a scrub forces settled state), then keep the
                    # pipeline primed with at most one in-flight update.
                    had_pending = g.pending is not None
                    # Rung 2: within the governor's deadline margin the tick
                    # stops speculating — resolve blocking and re-dispatch,
                    # meeting the deadline early instead of missing it.
                    forced = overdue or scrub_due or margin
                    if had_pending and forced and self._phase_hooks:
                        # The crash point right before the tick joins the
                        # dispatcher (launch, then fit signal).
                        self._phase("dispatcher_join", red=dict(out),
                                    group=g.label, step=step)
                    res, ovf, deferred = self._resolve(
                        g, {n: out[n] for n in g.names}, wait=forced)
                    if res is None:
                        # Still in flight: fold this due tick into it.  The
                        # deadline clock keeps running, so a wedged device
                        # eventually forces a blocking resolve via overdue.
                        if due:
                            g.pending.coalesced += 1
                            coalesced.append(g.label)
                            updated.append(g.label)
                            if self._phase_hooks:
                                self._phase("coalesce", red=dict(out),
                                            group=g.label, step=step)
                    else:
                        out.update(res)
                        if had_pending and self._phase_hooks:
                            self._phase(
                                "adopt_forced" if forced
                                else "adopt", red=dict(out), group=g.label,
                                step=step, overflowed=ovf)
                        if (had_pending and margin
                                and not (overdue or scrub_due)
                                and hg is not None):
                            hg.note_forced_resolve(g.label, step)
                        if ovf:
                            # Speculation missed: the queued program could not
                            # cover the snapshot (its blocks stayed marked via
                            # the shadow select).  Run the always-correct full
                            # program now.
                            overflowed.append(g.label)
                        if ovf or due or overdue or deferred or margin or retry:
                            # Snapshot the freshness clocks *before* the
                            # bump below: the governor's wedged-dispatch
                            # abandon rolls back to these, and the batched
                            # dispatch only runs after this loop.
                            to_dispatch.append(
                                (g, bool(not ovf and g.engine.has_queue
                                         and g.predicted_fits),
                                 g.last_update_step, g.last_update_time))
                            g.last_update_step = step
                            g.last_update_time = now
                            if due or overdue or margin:
                                updated.append(g.label)
                            if overdue and not due:
                                deadline.append(g.label)
                elif sync_esc or due or overdue or margin:
                    if g.pending is not None:
                        # Rung 4 engaged with an update still in flight
                        # (e.g. escalation via a reported violation): adopt
                        # it first — a stale pending resolved *after* the
                        # blocking pass would clobber newer checksums.
                        if self._phase_hooks:
                            self._phase("dispatcher_join", red=dict(out),
                                        group=g.label, step=step)
                        red_sub, _, _ = self._resolve(
                            g, {n: out[n] for n in g.names}, wait=True)
                        out.update(red_sub)
                    out.update(self._dispatch_blocking(
                        g, sub_of(g), {n: out[n] for n in g.names}))
                    g.last_update_step = step
                    g.last_update_time = now
                    updated.append(g.label)
                    if self._phase_hooks:
                        self._phase("blocking_update", red=dict(out),
                                    group=g.label, step=step)
                    if overdue and not due:
                        deadline.append(g.label)
            if scrub_due:
                scrub_groups.append(g)
        if to_dispatch:
            # The tentpole: every due group launches in ONE batched
            # multi-group program with one stacked fits vector, its fit
            # fetch handed to the resolver thread — the foreground's cost
            # is the epoch swap plus one asynchronous dispatch.
            if self._phase_hooks:
                self._phase("dispatcher_enqueue", red=dict(out), step=step,
                            groups=tuple(g.label for g, *_ in to_dispatch))
            out.update(self._dispatch_async_many(
                to_dispatch, get_leaves, out, step))
            if self._phase_hooks:
                for g, *_ in to_dispatch:
                    self._phase("dispatch", red=dict(out), group=g.label,
                                step=step, queued=g.pending.queued)
        for g in scrub_groups:
            mm, alarms = self._scrub_group(g, sub_of(g), out)
            scrubbed.append(g.label)
            report.mismatches += mm
            report.alarms += alarms
            if self._phase_hooks:
                self._phase("scrub", red=dict(out), group=g.label,
                            step=step, mismatches=mm)
        report.updated = tuple(updated)
        report.deadline_fired = tuple(deadline)
        report.scrubbed = tuple(scrubbed)
        report.coalesced = tuple(coalesced)
        report.overflowed = tuple(overflowed)
        # Elastic remesh slots between rebuild and patrol in the priority
        # ladder: a queued request starts only once no rebuild is active or
        # pending (loss recovery first), and while a migration runs the
        # patroller is skipped entirely (its parity geometry is tied to the
        # old mesh; a fresh patroller is built at adoption).
        ran_remesh = False
        if (self._remesh is None and self._remesh_request is not None
                and (self.patroller is None
                     or (self.patroller.rebuild is None
                         and not self.patroller._pending_loss))):
            self._remesh_start(get_leaves(), out, step, report)
        if self._remesh is not None:
            lv = dict(get_leaves())
            lv.update(report.repaired)      # moved leaves, if started now
            self._remesh_step(lv, out, report, step)
            ran_remesh = True
        if hg is not None and ran_remesh:
            # The group loop was suspended this tick (old-geometry red is
            # authoritative until adoption) — the one window the ladder
            # above cannot cover.  When a group's freshness margin expired
            # mid-migration, drain the remaining windows synchronously
            # (remesh_drain, rung 2: the SLO beats the bounded per-tick
            # window), then run blocking updates post-adoption.  With
            # remesh_drain=False the migration keeps its bound and end_tick
            # reports the violation instead — never silent either way.
            forced = hg.remesh_overdue(step, now)
            if forced and self._remesh is not None and hg.hp.remesh_drain:
                lv = dict(get_leaves())
                lv.update(report.repaired)
                while self._remesh is not None:
                    self._remesh_step(lv, out, report, step)
            if forced and self._remesh is None:
                lv = dict(get_leaves())
                lv.update(report.repaired)   # moved leaves (new geometry)
                extra = []
                for g in self._protected():
                    if g.policy.mode != "vilamb" or g.label not in forced:
                        continue
                    out.update(self._dispatch_blocking(
                        g, {n: lv[n] for n in g.names},
                        {n: out[n] for n in g.names}))
                    g.last_update_step = step
                    g.last_update_time = now
                    extra.append(g.label)
                    hg.note_remesh_drain(g.label, step)
                report.updated = report.updated + tuple(extra)
                report.deadline_fired = report.deadline_fired + tuple(extra)
                updated.extend(extra)
        if self.patroller is not None and not ran_remesh:
            # Low-priority background duty, after every foreground decision:
            # the patroller sees the post-dispatch live view (in-flight
            # blocks are shadow-marked, so probes conservatively skip them)
            # and only dispatches a probe on quiet ticks (no update
            # dispatched) — rebuild, being loss recovery, runs every tick
            # within its byte budget.  It may repair/rebuild leaves
            # (report.repaired — callers adopt) and mark rebuilt blocks
            # dirty in ``out``.
            # A queued (not yet started) remesh also counts as busy: the
            # ladder puts remesh above patrol, so probes defer while a
            # geometry change is waiting on an active rebuild to finish.
            self.patroller.on_tick(
                get_leaves, out, step, report,
                busy=bool(updated) or self._remesh_request is not None)
        if hg is not None:
            # Age audit + breaker transitions; attaches report.health and
            # raises FreshnessViolationError only when the ladder is
            # exhausted and a deadline is still blown (violation_mode).
            hg.end_tick(report, step, now)
        if self._phase_hooks:
            self._phase("tick", red=dict(out), step=step, report=report)
        return out, report

    def flush(self, leaves: Mapping[str, jax.Array], red: RedundancyState,
              step: Optional[int] = None) -> RedundancyState:
        """Battery/preemption flush: force Algorithm 1 on every vilamb group
        now (paper §3.3).  Sync groups are up-to-date by construction.
        Any active shard rebuild / remesh migration is drained first
        (outstanding paste windows complete before anything is adopted —
        adopt the pasted leaves via :meth:`take_repaired`), then any
        in-flight async update is resolved, so the result is
        bitwise-identical to the blocking path's flush.  Pass ``step`` when
        known so the steps-based freshness deadline does not fire a
        spurious pass right after the flush."""
        out = dict(red)
        leaves = self._drain_background(dict(leaves), out, step=step)
        now = time.monotonic()
        for g in self._protected():
            if g.policy.mode == "vilamb":
                if g.pending is not None:
                    # Eager resolution; an overflowed speculative dispatch
                    # left its blocks marked (shadow), so the forced pass
                    # below covers them.
                    if self._phase_hooks:
                        info = {} if step is None else {"step": int(step)}
                        self._phase("dispatcher_join", red=dict(out),
                                    group=g.label, **info)
                    red_sub, _, _ = self._resolve(
                        g, {n: out[n] for n in g.names}, wait=True)
                    out.update(red_sub)
                out.update(self._dispatch_blocking(
                    g, {n: leaves[n] for n in g.names},
                    {n: out[n] for n in g.names}))
                g.last_update_time = now
                if step is not None:
                    g.last_update_step = int(step)
        # Quiescent point: every pending is resolved, so the dispatcher
        # thread has nothing left to do — shut it down cleanly (the
        # battery/preemption flush is exactly where a lingering thread
        # would outlive the process's useful life).  It is re-created
        # lazily on the next overlapped dispatch.
        self._stop_dispatcher()
        if self._phase_hooks:
            self._phase("flush", red=dict(out),
                        **({} if step is None else {"step": int(step)}))
        return out

    # --------------------------------------------------------- elastic remesh
    def remesh(self, new_mesh: Any,
               specs: Optional[Mapping[str, Any]] = None) -> None:
        """Queue an elastic geometry change: grow/shrink the device mesh by
        incrementally re-striping every protected leaf (repro.remesh).

        No stop-the-world re-attach: the migration runs over bounded
        per-tick windows (``RedundancyPolicy.remesh_bytes_per_tick``)
        starting on the next ``tick`` once no shard rebuild is active or
        pending, surfacing a ``RemeshStatus`` through ``TickReport.remesh``
        with a pinned tick bound of ``ceil(n_blocks / window)`` per leaf.
        ``specs`` optionally overrides per-leaf PartitionSpecs for the new
        mesh (default: the specs declared at ``attach`` — valid whenever
        the new mesh keeps the same axis names).

        Raises :class:`repro.remesh.RemeshInProgressError` when a remesh is
        already queued or running, and
        :class:`repro.remesh.RemeshGeometryError` when a leaf cannot be
        evenly re-striped onto the new mesh (dim not divisible by the new
        shard factor) or a group mode does not support migration.
        """
        from repro.remesh import RemeshInProgressError, validate_remesh
        if self._remesh is not None or self._remesh_request is not None:
            raise RemeshInProgressError(
                "a remesh is already queued or in progress")
        new_specs = dict(self._specs) if hasattr(self, "_specs") else {}
        new_specs.update(specs or {})
        validate_remesh(self, new_mesh, new_specs)
        self._remesh_request = (new_mesh, new_specs)

    @property
    def remeshing(self) -> bool:
        """True while a remesh is queued or actively migrating."""
        return self._remesh is not None or self._remesh_request is not None

    def _remesh_start(self, leaves: Mapping[str, jax.Array], out, step: int,
                      report) -> None:
        """Begin the queued migration: settle in-flight overlapped updates
        against the OLD geometry (their outputs are old-sharded), then
        build the migrator — one ``device_put`` of every leaf onto the new
        mesh (value-identical; surfaced via ``report.repaired`` so the
        caller adopts the moved arrays) plus zero-initialised new-geometry
        redundancy the per-tick windows fill in."""
        from repro.remesh import RemeshMigrator
        new_mesh, new_specs = self._remesh_request
        self._remesh_request = None
        for g in self._protected():
            if g.pending is None:
                continue
            if self._phase_hooks:
                self._phase("dispatcher_join", red=dict(out), group=g.label,
                            step=step)
            red_sub, ovf, _ = self._resolve(
                g, {n: out[n] for n in g.names}, wait=True)
            out.update(red_sub)
            if ovf:
                repaired, fits = self._update_fn(g.label, "async_full")(
                    {n: leaves[n] for n in g.names},
                    {n: out[n] for n in g.names})
                g.predicted_fits = _fits_host(fits)
                out.update(repaired)
        # The migration swaps engines and jit caches at adoption; the old
        # geometry's dispatcher (and any compiled programs its queued jobs
        # closed over) must not leak across the handover.
        self._stop_dispatcher()
        self._remesh = RemeshMigrator(self, new_mesh, new_specs,
                                      leaves, out, step)
        report.repaired.update(self._remesh.moved)
        report.remesh = self._remesh.status

    def _remesh_step(self, leaves, out, report, step: Optional[int]) -> None:
        """One bounded migration window; adopts the new geometry (red swap,
        group/engine swap, fresh patroller, ``geometry_version`` bump) on
        the tick the last window completes."""
        m = self._remesh
        m.step_once(leaves, out, report, step)
        if m.status.done:
            m.adopt(out, report)
            self._remesh = None

    def redundancy_step(self, leaves: Mapping[str, jax.Array],
                        red: RedundancyState) -> RedundancyState:
        """Traceable flush (no jit caching/donation) — embed in outer jits.

        Bypasses the overlap pipeline: do not interleave with ``tick`` while
        an async update is in flight (``settle`` first) — the later adoption
        would roll checksums back over this pass's unmarked blocks.
        """
        out = dict(red)
        for g in self._protected():
            if g.policy.mode == "vilamb":
                out.update(g.engine.redundancy_step(
                    {n: leaves[n] for n in g.names},
                    {n: out[n] for n in g.names}))
        return out

    # ------------------------------------------------------- verify + recover
    def _scrub_group(self, g: _Group, sub, red) -> Tuple[int, int]:
        """Scrub one group given its leaf sub-dict (double-check protocol)."""
        fn = self._scrub_fn(g.label)
        red_sub = {n: red[n] for n in g.names}
        mm = fn(sub, red_sub)
        total = int(sum(int(v.sum()) for v in jax.tree.leaves(mm)))
        alarms = 0
        if total:
            # Double-check (paper §3.4): quiesce in-flight work, re-verify on
            # an immutable snapshot before raising the alarm.
            jax.block_until_ready(sub)
            mm = fn(sub, red_sub)
            total = int(sum(int(v.sum()) for v in jax.tree.leaves(mm)))
            if total:
                alarms = 1
                self.corruption_alarms += 1
        return total, alarms

    def scrub(self, leaves: Mapping[str, jax.Array], red: RedundancyState
              ) -> Dict[str, jax.Array]:
        """Per-leaf mismatch masks over clean blocks (no double-check).

        In-flight async updates are settled first (including the full
        fallback on a queued misprediction) so the masks match what the
        blocking path would report.  The caller's ``red`` is left as-is —
        it stays a conservative view (in-flight blocks marked) until the
        next tick/flush adopts results.
        """
        red = self.settle(red, leaves)
        out: Dict[str, jax.Array] = {}
        for g in self._protected():
            out.update(self._scrub_fn(g.label)(
                {n: leaves[n] for n in g.names},
                {n: red[n] for n in g.names}))
        return out

    def scrub_check(self, leaves: Mapping[str, jax.Array],
                    red: RedundancyState) -> int:
        """Scrub all protected groups with the double-check protocol.

        Settles in-flight async updates first — calling this mid-flight
        yields the same mismatch count as the blocking path.
        """
        red = self.settle(red, leaves)
        total = 0
        for g in self._protected():
            mm, _ = self._scrub_group(g, {n: leaves[n] for n in g.names}, red)
            total += mm
        return total

    def verify_meta(self, red: RedundancyState) -> Dict[str, jax.Array]:
        out: Dict[str, jax.Array] = {}
        for g in self._protected():
            out.update(g.engine.verify_meta({n: red[n] for n in g.names}))
        return out

    def recover_block(self, leaf: jax.Array, r: Any, name: str, block_id):
        engine = self.engine_for(name)
        if engine is None:
            raise KeyError(f"{name} is not parity-protected")
        return engine.recover_block(leaf, r, name, block_id)

    def read_verified(self, leaves: Mapping[str, jax.Array],
                      red: RedundancyState, name: str,
                      block_ids: Sequence[int]) -> Dict[str, Any]:
        """Degraded-mode verified read: per requested **global** block,
        return data that is provably current — never stale or in-flight
        garbage — even while a shard is lost or a remesh is migrating.

        Per block, in order: (1) a block inside the vulnerability window
        (``dirty | shadow``) returns the current data — writes land in the
        data array before redundancy, so the array itself is the newest
        truth (unless the block's write was in flight when its shard died,
        a named pre-loss casualty); (2) a clean block whose checksum
        verifies returns the current data; (3) a mismatching block is
        reconstructed — from the active rebuild's cross-shard-parity image
        when its shard is the lost one, else from its XOR stripe via
        ``recover_block`` — and the reconstruction is admitted only if it
        verifies against the stored checksum.  Unverifiable blocks retry
        with backoff (``read_retry_attempts`` / ``read_retry_backoff_s`` —
        a transiently vulnerable stripe may settle); when the budget is
        exhausted a typed :class:`repro.core.UnrecoverableReadError` is
        raised carrying ``UnrecoverableBlock`` records (reason
        ``read_timeout``).

        Returns ``{global_block_id: uint32 lane row (lanes_per_block,)}``.
        A host-side cold path (one blocking fetch per attempt): correctness
        over throughput, by design.
        """
        from . import blocks as blocks_mod
        from . import checksum as checksum_mod
        from .repairs import UnrecoverableBlock, UnrecoverableReadError
        from repro.faults.inject import bits_to_mask

        eng = self.engine_for(name)
        if eng is None:
            raise KeyError(f"{name} is not parity-protected")
        meta = self.metas[name]
        k = self.shard_factor(name)
        rows_local = (eng.global_leaf_structs[name].shape[0] // k
                      if eng.mesh is not None else meta.shape[0])
        want = [int(b) for b in block_ids]
        for b in want:
            if not 0 <= b < k * meta.n_blocks:
                raise IndexError(f"{name}: global block {b} out of range "
                                 f"(0..{k * meta.n_blocks - 1})")
        attempts = max(1, int(self.policy.read_retry_attempts))
        # Exponential, capped, jittered, budget-bounded retry delays — the
        # same schedule the health governor's dispatch-retry rung uses
        # (base 0 = the backwards-compatible no-sleep default).
        from repro.health.backoff import backoff_schedule
        delays = backoff_schedule(
            attempts - 1, float(self.policy.read_retry_backoff_s),
            cap=float(self.policy.read_retry_backoff_cap_s),
            total=float(self.policy.read_retry_total_s),
            jitter_frac=float(self.policy.read_retry_jitter_frac))
        results: Dict[int, np.ndarray] = {}

        def shard_lanes(arr: np.ndarray, s: int) -> np.ndarray:
            sub = arr[s * rows_local:(s + 1) * rows_local] if k > 1 else arr
            return np.asarray(blocks_mod.to_lanes(jnp.asarray(sub), meta))

        def ck_of(lane_row: np.ndarray, lb: int) -> int:
            return int(np.asarray(checksum_mod.block_checksums(
                jnp.asarray(lane_row[None, :]), block_offset=lb))[0])

        for attempt in range(attempts):
            pending = [b for b in want if b not in results]
            if not pending:
                break
            if attempt and delays[attempt - 1] > 0:
                time.sleep(delays[attempt - 1])
            arr = np.asarray(leaves[name])
            r = red[name]
            live = bits_to_mask(
                np.asarray(r.dirty) | np.asarray(r.shadow), meta.n_blocks,
                shards=k).reshape(k, meta.n_blocks)
            cks = np.asarray(r.checksums).reshape(k, meta.n_blocks)
            reb = self.patroller.rebuild if self.patroller else None
            if reb is not None and reb.name != name:
                reb = None
            lanes_cache: Dict[int, np.ndarray] = {}
            for b in pending:
                s, lb = divmod(b, meta.n_blocks)
                on_lost = reb is not None and reb.shard == s
                if s not in lanes_cache:
                    lanes_cache[s] = shard_lanes(arr, s)
                row = lanes_cache[s][lb]
                if live[s, lb]:
                    # In the vulnerability window: the data array holds the
                    # newest write — UNLESS that write was in flight when
                    # the shard died (pre-loss mark): its data died with
                    # the shard and the live bytes are scribble.
                    if not (on_lost and bool(reb.preloss[lb])):
                        results[b] = row.copy()
                    continue
                if ck_of(row, lb) == int(cks[s, lb]):
                    results[b] = row.copy()
                    continue
                # Mismatch: reconstruct, admit only verified bytes.
                if (on_lost and bool(reb.eligible[lb])
                        and not bool(reb.written[lb])):
                    cand = np.asarray(reb.recon)[lb]
                    if ck_of(cand, lb) == int(cks[s, lb]):
                        results[b] = cand.copy()
                        continue
                leaf2, ok = eng.recover_block(leaves[name], r, name, b)
                if bool(ok):
                    cand = shard_lanes(np.asarray(leaf2), s)[lb]
                    if ck_of(cand, lb) == int(cks[s, lb]):
                        results[b] = cand.copy()
        missing = [b for b in want if b not in results]
        if missing:
            recs = tuple(UnrecoverableBlock(
                name, blocks_mod.global_stripe_id(meta, b), (b,),
                "read_timeout") for b in missing)
            raise UnrecoverableReadError(name, recs)
        return {b: results[b] for b in want}

    def repair(self, leaves: Mapping[str, jax.Array], red: RedundancyState,
               mismatches: Mapping[str, jax.Array],
               details: Optional[List[Any]] = None) -> Tuple[Dict, int, int]:
        """Parity-rebuild every detected-corrupt block; see failure module.

        ``details`` (optional list) collects structured
        :class:`repro.core.repairs.UnrecoverableBlock` records for every
        refused stripe."""
        from repro.ckpt.failure import repair_corruption
        return repair_corruption(self, leaves, red, mismatches,
                                 details=details)

    def declare_shard_lost(self, name: str, shard: int,
                           red: Optional[RedundancyState] = None) -> None:
        """Tell the patroller a shard of ``name`` is lost (operator signal).

        The patroller normally detects wholesale shard corruption from its
        own probes (``shard_loss_threshold``); this is the explicit path
        for known losses (a device dropped out).  Requires the patroller
        (``RedundancyPolicy.patrol_bytes_per_tick > 0``); the rebuild
        starts on the next ``tick``.  Pass the current ``red`` state when
        available: its ``dirty | shadow`` marks snapshot which blocks had
        writes in flight at declaration (data died with the shard — they
        report as unrecoverable), so that foreground writes landing
        *after* the declaration still classify as fresh.
        """
        if self.patroller is None:
            raise RuntimeError(
                "declare_shard_lost needs the scrub patroller "
                "(set RedundancyPolicy.patrol_bytes_per_tick > 0)")
        if self.remeshing:
            # The patroller (and its cross-shard parity) is rebuilt fresh
            # at remesh adoption — a loss queued now would silently vanish
            # with the old patroller.  Fail loudly instead.
            raise RuntimeError(
                f"{name}: cannot declare a shard lost while a remesh is "
                "queued or migrating; re-declare after TickReport.remesh "
                "reports done")
        self.patroller.declare_shard_lost(name, shard, red)

    def inject(self, leaves: Mapping[str, jax.Array], red: RedundancyState,
               spec) -> Tuple[Dict[str, jax.Array], RedundancyState]:
        """Apply one ``repro.faults.FaultSpec`` functionally (test/CI hook).

        The store is the façade for fault injection too: corruptions are
        placed in block-lane space against this store's exact geometry —
        global block space under a mesh (the owning shard's slice is
        corrupted) — never via test-local array surgery.  Returns new
        ``(leaves, red)``; inputs are untouched.
        """
        from repro.faults.inject import apply_fault
        return apply_fault(self.metas, leaves, red, spec,
                           factors={n: self.shard_factor(n)
                                    for n in self.metas})

    def vulnerable_masks(self, red: RedundancyState) -> Dict[str, jax.Array]:
        """Per-leaf bool[n_blocks] masks of the instantaneous vulnerability
        window (``dirty | shadow``) — the exact set the §5 oracle audits.
        Deliberately *not* settled, like :meth:`dirty_stats`: blocks
        consumed by an in-flight overlapped update stay marked until
        adoption."""
        out: Dict[str, jax.Array] = {}
        for g in self._protected():
            out.update(g.engine.vulnerable_masks(
                {n: red[n] for n in g.names}))
        return out

    # ------------------------------------------------------------- accounting
    def dirty_stats(self, red: RedundancyState) -> Dict[str, Dict[str, Any]]:
        """Per-leaf dirty/vulnerable counts.  Deliberately *not* settled:
        blocks consumed by an in-flight overlapped update stay counted
        (via the live view's shadow) until resolution — the conservative
        answer for flush sizing and MTTDL accounting."""
        out: Dict[str, Dict[str, Any]] = {}
        for g in self._protected():
            out.update(g.engine.dirty_stats({n: red[n] for n in g.names}))
        return out

    def estimate_flush(self, red: RedundancyState) -> "policy_mod.FlushEstimate":
        """Size the preemption flush (battery analogue, paper §4.7)."""
        stats = jax.tree.map(int, self.dirty_stats(red))
        metas = self.metas
        return policy_mod.estimate_flush(
            stats, {n: metas[n].bytes_per_block for n in stats},
            self.policy.stripe_data_blocks)


def as_store(obj: Any, mode: Optional[str] = None,
             period_steps: Optional[int] = None, scrub_period_steps: int = 0,
             caller: str = "caller") -> Optional[ProtectedStore]:
    """Coerce legacy ``(engine, mode)`` arguments into a ProtectedStore.

    The one-release deprecation shim behind ``Trainer(engine=..., mode=...)``
    and friends.  ``None`` (or mode "none" with no engine) maps to no store.
    """
    if obj is None or isinstance(obj, ProtectedStore):
        return obj
    if isinstance(obj, RedundancyEngine):
        warnings.warn(
            f"passing engine=/mode= to {caller} is deprecated; build a "
            "repro.core.ProtectedStore with a RedundancyPolicy instead",
            DeprecationWarning, stacklevel=3)
        return ProtectedStore.from_engine(
            obj, mode or "vilamb", period_steps=period_steps,
            scrub_period_steps=scrub_period_steps)
    raise TypeError(f"expected ProtectedStore/RedundancyEngine/None, got {obj!r}")
