"""Cross-block XOR parity stripes (paper's cross-page parity).

Stripes are ``P`` consecutive data blocks plus one parity block (paper
default: 4+1, statically assigned at init). The paper computes parity with
AVX over 256-byte words; here it is an XOR reduction over uint32 lanes on
the VPU. Parity lives in a separate array (``uint32[n_stripes, lanes]``),
stored apart from the data like the paper's parity pages.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def _striped(lanes: jax.Array, stripe_width: int) -> jax.Array:
    """(n_blocks, L) -> (n_stripes, P, L), zero-padding trailing blocks."""
    nb, L = lanes.shape
    ns = -(-nb // stripe_width)
    pad = ns * stripe_width - nb
    if pad:
        lanes = jnp.pad(lanes, ((0, pad), (0, 0)))
    return lanes.reshape(ns, stripe_width, L)


def stripe_parity(lanes: jax.Array, stripe_width: int) -> jax.Array:
    """XOR parity for every stripe: uint32[n_stripes, L]."""
    s = _striped(lanes, stripe_width)
    return jax.lax.reduce(s, jnp.uint32(0), jax.lax.bitwise_xor, (1,))


def stripe_parity_masked(
    lanes: jax.Array,
    old_parity: jax.Array,
    stripe_dirty: jax.Array,
    stripe_width: int,
) -> jax.Array:
    """Recompute parity only for dirty stripes; clean stripes keep old parity.

    This is the reference (pure-jnp) semantics; the work-queue versions that
    skip the data *read* for clean stripes too live in core/workqueue.py
    (XLA gather) and kernels/redundancy (Pallas scalar prefetch).
    """
    fresh = stripe_parity(lanes, stripe_width)
    return jnp.where(stripe_dirty[:, None], fresh, old_parity)


def parity_diff(old_lanes: jax.Array, new_lanes: jax.Array, stripe_width: int) -> jax.Array:
    """Pangolin-mode incremental parity delta: parity' = parity ^ delta.

    XOR of old and new bits, folded per stripe — reads only the changed
    blocks, not the rest of the stripe (the paper's diff advantage, §4.2).
    """
    delta = old_lanes ^ new_lanes
    return stripe_parity(delta, stripe_width)


def scatter_xor_stripes(
    parity: jax.Array, stripe_ids: jax.Array, deltas: jax.Array
) -> jax.Array:
    """``parity[s] ^= XOR of deltas with stripe_ids == s`` in one scatter.

    Replaces the slot-partitioned loop of ``stripe_width`` scatters: rows are
    sorted by stripe id, a segmented XOR scan folds same-stripe deltas, and
    one unique-id scatter lands the per-segment totals.  Out-of-range ids
    (``>= n_stripes``) are dropped — use them as padding sentinels.
    """
    ns = parity.shape[0]
    n = stripe_ids.shape[0]
    if n == 0:
        return parity
    order = jnp.argsort(stripe_ids)
    sid = stripe_ids[order]
    d = deltas[order]

    def seg_xor(a, b):
        sa, va = a
        sb, vb = b
        return sb, vb ^ jnp.where((sa == sb)[:, None], va, jnp.uint32(0))

    _, folded = jax.lax.associative_scan(seg_xor, (sid, d))
    is_last = jnp.concatenate(
        [sid[1:] != sid[:-1], jnp.ones((1,), bool)]) if n > 1 else jnp.ones((1,), bool)
    tgt = jnp.where(is_last & (sid < ns), sid, ns)
    cur = parity.at[tgt].get(mode="fill", fill_value=0)
    return parity.at[tgt].set(cur ^ folded, mode="drop")


def reconstruct_block(
    lanes: jax.Array, parity_row: jax.Array, stripe_width: int, block_id, stripe_id
) -> jax.Array:
    """Rebuild one block from its stripe: XOR of parity and the other members.

    Caller must ensure every *other* member is clean and parity is current
    (the paper's vulnerable-stripe rule, §3.3).
    """
    nb, L = lanes.shape
    start = stripe_id * stripe_width
    member_ids = start + jnp.arange(stripe_width)
    # Out-of-range members (last partial stripe) contribute zeros.
    members = jnp.where(
        (member_ids < nb)[:, None],
        lanes[jnp.clip(member_ids, 0, nb - 1)],
        jnp.uint32(0),
    )
    keep = (member_ids != block_id)[:, None]
    acc = jax.lax.reduce(
        jnp.where(keep, members, jnp.uint32(0)),
        jnp.uint32(0), jax.lax.bitwise_xor, (0,),
    )
    return acc ^ parity_row
