"""Per-leaf PartitionSpec rules for params and decode caches.

The model stack is scan-stacked (leading dim = layers-per-slot), so specs
never shard dim 0.  Rules are basename-driven and divisibility-guarded:
an axis is only assigned where it divides the dim, otherwise it is dropped
and the fallback is logged — the dry-run surfaces every replication
fallback instead of failing to compile.

Conventions (match the constrain/shard_map hints inside the model code):
  * TP (``model`` axis): attention heads, FFN hidden, MoE experts,
    mamba d_inner, vocab (embed table rows — see ``Model._embed``).
  * FSDP (``data`` or ``("pod","data")``): the remaining large matrix dim
    (ZeRO-style parameter sharding; gradients reduce-scatter onto it).
  * Batch (``pod``+``data``): the batch dim of KV/recurrent caches.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import jax
from jax.sharding import PartitionSpec as P

from repro.models.parallel import ParallelCtx


def _size(ctx: ParallelCtx, axis) -> int:
    return ctx.axis_size(axis)


def _fits(ctx: ParallelCtx, dim: int, axis) -> bool:
    return axis is not None and dim % max(_size(ctx, axis), 1) == 0


def _guard(ctx: ParallelCtx, name: str, shape, spec: List, log: List[str]):
    """Drop any axis that does not divide its dim; log the fallback."""
    out = []
    for i, ax in enumerate(spec):
        if ax is None or _fits(ctx, shape[i], ax):
            out.append(ax)
        else:
            log.append(f"replicated dim {i} of {name} {tuple(shape)}: "
                       f"{ax} does not divide {shape[i]}")
            out.append(None)
    return P(*out)


def param_specs(flat: Dict[str, jax.ShapeDtypeStruct], ctx: ParallelCtx
                ) -> Tuple[Dict[str, P], List[str]]:
    """PartitionSpecs for a flat (path -> struct) param dict."""
    log: List[str] = []
    if ctx.mesh is None:
        return {k: P() for k in flat}, log
    tp, fs = ctx.tp_axis, ctx.fsdp_axis
    specs: Dict[str, P] = {}
    for name, v in flat.items():
        base = name.rsplit("/", 1)[-1]
        parent = name.rsplit("/", 2)[-2] if name.count("/") else ""
        nd = len(v.shape)
        spec: List = [None] * nd
        if base == "embed":
            # vocab over TP, d_model over FSDP (matches Model._embed's
            # shard_map table_spec).
            spec = [tp, fs]
        elif base == "head":
            spec = [fs, tp]
        elif parent == "moe":
            if base == "router":
                pass                                   # replicated (moe_apply)
            else:                                      # (L, E, D|F, F|D)
                spec = [None, tp, fs, None][:nd]
        elif parent == "attn":
            if base == "wo":                           # (L, H, hd, D)
                spec = [None, tp, None, fs][:nd]
            else:                                      # wq/wk/wv (L, D, H, hd)
                spec = [None, fs, tp, None][:nd]
        elif parent == "ffn":
            if base == "wo":                           # (L, F, D)
                spec = [None, tp, fs][:nd]
            else:                                      # wi/wg (L, D, F)
                spec = [None, fs, tp][:nd]
        elif parent == "mamba":
            if base == "in_proj":                      # (L, D, 2*di)
                spec = [None, fs, tp][:nd]
            elif base in ("out_proj", "x_proj"):       # (L, di, ...)
                spec = [None, tp, None][:nd]
            elif base == "A_log":                      # (L, di, d_state)
                spec = [None, tp, None][:nd]
            elif nd == 2:                              # D/conv_b/dt_bias (L, di)
                spec = [None, tp]
            elif nd == 3:                              # conv_w/dt_proj (L, k, di)
                spec = [None, None, tp]
        elif base == "scale" or nd <= 1:
            pass                                       # norms/bias: replicate
        elif nd >= 2:
            # Unknown matrix: FSDP its largest non-leading dim if it fits.
            big = max(range(1, nd), key=lambda i: v.shape[i])
            spec[big] = fs
        specs[name] = _guard(ctx, name, v.shape, spec, log)
    return specs, log


def cache_specs(cfg, flat: Dict[str, jax.ShapeDtypeStruct], ctx: ParallelCtx,
                batch: int) -> Tuple[Dict[str, P], List[str]]:
    """PartitionSpecs for flat decode caches (KV pages, recurrent state).

    KV: (L, S, B, H, hd) — batch over the data axes, heads over TP.
    Mamba: conv (L, B, k, di), h (L, B, di, d_state) — batch + d_inner.
    Anything unrecognized shards its batch-sized dim only.
    """
    log: List[str] = []
    if ctx.mesh is None:
        return {k: P() for k in flat}, log
    tp = ctx.tp_axis
    dp: Optional[Tuple[str, ...]] = ctx.dp_axes or None
    specs: Dict[str, P] = {}
    for name, v in flat.items():
        base = name.rsplit("/", 1)[-1]
        nd = len(v.shape)
        spec: List = [None] * nd
        if base in ("k", "v") and nd == 5:             # (L, S, B, H, hd)
            spec = [None, None, dp, tp, None]
        elif base == "conv" and nd == 4:               # (L, B, k, di)
            spec = [None, dp, None, tp]
        elif base == "h" and nd == 4:                  # (L, B, di, d_state)
            spec = [None, dp, tp, None]
        else:
            for i, d in enumerate(v.shape):
                if d == batch:
                    spec[i] = dp
                    break
        specs[name] = _guard(ctx, name, v.shape, spec, log)
    return specs, log
