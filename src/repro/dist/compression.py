"""Gradient compression: blockwise int8 quantization with error feedback.

Cross-pod gradient reduction over DCN is bandwidth-bound; 8-bit blockwise
quantization cuts the wire bytes 4x vs fp32 (2x vs bf16).  Error feedback
carries the per-step quantization residual into the next step so no
gradient mass is lost over time (the EF-SGD contract the tests pin down:
``sum_t sent_t + err_T == sum_t grad_t``).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

BLOCK = 256  # elements per scale block (one f32 scale per 256 int8 payloads)


def _quantize(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Flat fp array (multiple of BLOCK) -> (int8[n], f32 scales[n/BLOCK]).

    Symmetric round-to-nearest; scale = max|x| / 127 per block, so the
    absolute error is bounded by scale/2 elementwise.
    """
    xb = x.reshape(-1, BLOCK).astype(jnp.float32)
    s = jnp.max(jnp.abs(xb), axis=1) / 127.0
    q = jnp.where(s[:, None] > 0, jnp.round(xb / jnp.where(
        s[:, None] > 0, s[:, None], 1.0)), 0.0)
    return q.astype(jnp.int8).reshape(-1), s


def _dequantize(q: jax.Array, s: jax.Array) -> jax.Array:
    return (q.reshape(-1, BLOCK).astype(jnp.float32) * s[:, None]).reshape(-1)


def ef_compress(x: jax.Array, err: jax.Array
                ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One error-feedback step: quantize (x + err), return the residual.

    Returns ``(q, scales, new_err)``; the receiver reconstructs with
    :func:`_dequantize` and the sender carries ``new_err`` into the next
    call.
    """
    flat = x + err
    q, s = _quantize(flat)
    new_err = flat - _dequantize(q, s)
    return q, s, new_err
