"""Distribution rules: per-leaf sharding specs + gradient compression."""
from .compression import BLOCK, ef_compress
from .sharding import cache_specs, param_specs

__all__ = ["BLOCK", "cache_specs", "ef_compress", "param_specs"]
