from .pipeline import SyntheticPipeline, batch_structs
