"""Deterministic synthetic data pipeline (shard-aware, restart-reproducible).

Batches are pure functions of (seed, step), so a restarted job resumes the
exact stream from its checkpointed step — a fault-tolerance requirement at
fleet scale. Token streams are zipf-skewed so embedding-row dirty tracking
sees a realistic hot/cold key distribution (the paper's YCSB analogue).

When a mesh is provided, each process materializes only its addressable
shards via ``jax.make_array_from_callback``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig, ShapeConfig


def _zipf_tokens(rng: np.random.Generator, shape, vocab: int, a: float = 1.3):
    """Zipf-skewed token ids in [0, vocab)."""
    z = rng.zipf(a, size=shape).astype(np.int64)
    return ((z - 1) % vocab).astype(np.int32)


def batch_structs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStructs of one training batch (used by the dry-run)."""
    B, S = shape.global_batch, shape.seq_len
    out: Dict[str, jax.ShapeDtypeStruct] = {}
    S_txt = S
    if cfg.frontend == "vision":
        S_txt = S - cfg.frontend_len
        out["frontend"] = jax.ShapeDtypeStruct((B, cfg.frontend_len, cfg.d_model), jnp.bfloat16)
    if cfg.enc_dec:
        S_txt = S // 2
        out["enc_input"] = jax.ShapeDtypeStruct((B, S - S_txt, cfg.d_model), jnp.bfloat16)
    out["tokens"] = jax.ShapeDtypeStruct((B, S_txt), jnp.int32)
    out["labels"] = jax.ShapeDtypeStruct((B, S_txt), jnp.int32)
    return out


@dataclasses.dataclass
class SyntheticPipeline:
    cfg: ModelConfig
    shape: ShapeConfig
    seed: int = 0
    mesh: Optional[Mesh] = None
    zipf_a: float = 1.3

    def _numpy_batch(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed, step))
        B, S = self.shape.global_batch, self.shape.seq_len
        cfg = self.cfg
        out: Dict[str, np.ndarray] = {}
        S_txt = S
        if cfg.frontend == "vision":
            S_txt = S - cfg.frontend_len
            out["frontend"] = rng.standard_normal(
                (B, cfg.frontend_len, cfg.d_model)).astype(np.float32)
        if cfg.enc_dec:
            S_txt = S // 2
            out["enc_input"] = rng.standard_normal(
                (B, S - S_txt, cfg.d_model)).astype(np.float32)
        stream = _zipf_tokens(rng, (B, S_txt + 1), cfg.vocab_size, self.zipf_a)
        out["tokens"] = stream[:, :-1]
        out["labels"] = stream[:, 1:].copy()
        return out

    def batch_spec(self) -> Dict[str, P]:
        dp = tuple(a for a in ("pod", "data") if self.mesh and a in self.mesh.axis_names)
        spec = P(dp or None)
        return {k: spec for k in batch_structs(self.cfg, self.shape)}

    def get(self, step: int) -> Dict[str, jax.Array]:
        np_batch = self._numpy_batch(step)
        if self.mesh is None:
            return {k: jnp.asarray(v) for k, v in np_batch.items()}
        specs = self.batch_spec()
        out = {}
        for k, v in np_batch.items():
            sh = NamedSharding(self.mesh, specs[k])
            out[k] = jax.make_array_from_callback(
                v.shape, sh, lambda idx, v=v: v[idx])
        return out
