"""Jitted wrapper for the checksum kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..common import xor_reduce
from .checksum import checksum_partials
from . import ref


@functools.partial(jax.jit, static_argnames=("block_offset", "use_pallas", "interpret"))
def block_checksums(
    lanes2d: jax.Array,
    block_offset: int = 0,
    use_pallas: bool = True,
    interpret: bool = True,
) -> jax.Array:
    """uint32[n_blocks] checksums of a (n_blocks, L) uint32 lane view."""
    if not use_pallas:
        return ref.block_checksums(lanes2d, block_offset)
    partials = checksum_partials(
        lanes2d, block_offset=block_offset, interpret=interpret)
    # Fold the 128 lane partials, salting by position to match the oracle:
    # oracle = XOR_i fmix(w_i ^ salt_i); partial[c] already holds the XOR of
    # mixed lanes congruent to c mod 128, so a plain XOR-fold suffices.
    return xor_reduce(partials, (1,))
