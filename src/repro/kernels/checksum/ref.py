"""Pure-jnp oracle for the block-checksum kernel (same math as core)."""
from repro.core.checksum import block_checksums  # noqa: F401  (the oracle)
