"""Pallas TPU kernel: per-block position-salted fmix32 XOR-fold checksum.

The paper uses ``crc32q`` per 4 KB page; the TPU adaptation hashes uint32
lanes on the VPU (DESIGN.md §2.1). Grid = (n_blocks, lane_tiles); each step
loads a (1, TILE) VMEM slab, mixes, and XOR-accumulates 128-lane partials
into the output vreg row; ops.py folds the 128 partials.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..common import GOLDEN, LANES, SALT2, fmix32, lane_index_2d, lane_tile, xor_reduce


def _kernel(x_ref, out_ref, *, tile: int, block_offset: int):
    b = pl.program_id(0)
    j = pl.program_id(1)
    x = x_ref[0, :].reshape(tile // LANES, LANES)
    lanes = lane_index_2d(tile, 0) + jnp.uint32(j * tile)
    bid = jnp.uint32(b) + jnp.uint32(block_offset)
    salt = (bid * GOLDEN) ^ (lanes * SALT2)
    h = fmix32(x ^ salt)
    partial = xor_reduce(h, (0,))[None, :]  # (1, 128)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = partial

    @pl.when(j != 0)
    def _acc():
        out_ref[...] ^= partial


def checksum_partials(
    lanes2d: jax.Array,
    *,
    block_offset: int = 0,
    max_tile: int = 4096,
    interpret: bool = False,
) -> jax.Array:
    """uint32[n_blocks, 128] partial checksums (XOR-fold outside)."""
    nb, L = lanes2d.shape
    tile = lane_tile(L, max_tile)
    grid = (nb, L // tile)
    return pl.pallas_call(
        functools.partial(_kernel, tile=tile, block_offset=block_offset),
        grid=grid,
        in_specs=[pl.BlockSpec((1, tile), lambda b, j: (b, j))],
        out_specs=pl.BlockSpec((1, LANES), lambda b, j: (b, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, LANES), jnp.uint32),
        interpret=interpret,
    )(lanes2d)
