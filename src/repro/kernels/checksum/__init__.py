from .ops import block_checksums
