"""Shared kernel helpers: uint32 mixing on (8,128)-tiled vregs."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

GOLDEN = np.uint32(0x9E3779B9)
SALT2 = np.uint32(0x85EBCA77)
C1 = np.uint32(0x85EBCA6B)
C2 = np.uint32(0xC2B2AE35)

SUBLANES = 8
LANES = 128


def fmix32(x):
    x = x ^ (x >> 16)
    x = x * C1
    x = x ^ (x >> 13)
    x = x * C2
    x = x ^ (x >> 16)
    return x


def xor_reduce(x, axes):
    return jax.lax.reduce(x, jnp.uint32(0), jax.lax.bitwise_xor, axes)


def lane_tile(n_lanes: int, max_tile: int = 4096) -> int:
    """Largest multiple-of-128 tile dividing n_lanes, capped at max_tile."""
    assert n_lanes % LANES == 0, n_lanes
    if n_lanes <= max_tile:
        return n_lanes
    t = max_tile
    while t >= LANES:
        if n_lanes % t == 0:
            return t
        t -= LANES
    return LANES


def lane_index_2d(tile_lanes: int, lane_offset):
    """uint32 lane indices for a (tile_lanes//128, 128) vreg view.

    TPU requires >=2-D iota; build global lane ids from two broadcasted iotas.
    """
    rows = tile_lanes // LANES
    r = jax.lax.broadcasted_iota(jnp.uint32, (rows, LANES), 0)
    c = jax.lax.broadcasted_iota(jnp.uint32, (rows, LANES), 1)
    return r * jnp.uint32(LANES) + c + jnp.uint32(lane_offset)
