"""Jitted wrapper for the parity kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import ref
from .parity import stripe_parity_striped


def _striped(lanes: jax.Array, stripe_width: int) -> jax.Array:
    nb, L = lanes.shape
    ns = -(-nb // stripe_width)
    pad = ns * stripe_width - nb
    if pad:
        lanes = jnp.pad(lanes, ((0, pad), (0, 0)))
    return lanes.reshape(ns, stripe_width, L)


@functools.partial(jax.jit, static_argnames=("stripe_width", "use_pallas", "interpret"))
def stripe_parity(
    lanes2d: jax.Array,
    stripe_width: int = 4,
    use_pallas: bool = True,
    interpret: bool = True,
) -> jax.Array:
    """uint32[n_stripes, L] XOR parity of a (n_blocks, L) lane view."""
    if not use_pallas:
        return ref.stripe_parity(lanes2d, stripe_width)
    return stripe_parity_striped(_striped(lanes2d, stripe_width), interpret=interpret)
