"""Pure-jnp oracle for the stripe-parity kernel."""
from repro.core.parity import stripe_parity, stripe_parity_masked  # noqa: F401
