"""Pallas TPU kernel: stripe XOR parity (paper's cross-page parity).

AVX 256-byte-word XOR in the paper becomes a uint32 XOR reduction over the
stripe axis on the VPU. Grid = (n_stripes, lane_tiles); each step loads a
(1, P, TILE) slab — the P stripe members' matching lane range — and writes
their XOR.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..common import lane_tile, xor_reduce


def _kernel(x_ref, out_ref):
    out_ref[...] = xor_reduce(x_ref[...], (1,))


def stripe_parity_striped(
    striped: jax.Array, *, max_tile: int = 4096, interpret: bool = False
) -> jax.Array:
    """Parity of a pre-striped uint32[n_stripes, P, L] view -> [n_stripes, L]."""
    ns, P, L = striped.shape
    tile = lane_tile(L, max_tile)
    return pl.pallas_call(
        _kernel,
        grid=(ns, L // tile),
        in_specs=[pl.BlockSpec((1, P, tile), lambda s, j: (s, 0, j))],
        out_specs=pl.BlockSpec((1, tile), lambda s, j: (s, j)),
        out_shape=jax.ShapeDtypeStruct((ns, L), jnp.uint32),
        interpret=interpret,
    )(striped)
