from .ops import stripe_parity
