"""Jitted wrapper: dirty-mask → work queue → fused kernel → merged state."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.workqueue import compact_stripe_ids

from ..common import xor_reduce
from . import ref
from .redundancy import fused_update_striped


def _striped(lanes: jax.Array, stripe_width: int) -> jax.Array:
    nb, L = lanes.shape
    ns = -(-nb // stripe_width)
    pad = ns * stripe_width - nb
    if pad:
        lanes = jnp.pad(lanes, ((0, pad), (0, 0)))
    return lanes.reshape(ns, stripe_width, L)


@functools.partial(
    jax.jit, static_argnames=("stripe_width", "use_pallas", "interpret"))
def fused_update(
    lanes2d: jax.Array,
    old_checksums: jax.Array,
    old_parity: jax.Array,
    block_dirty: jax.Array,
    stripe_dirty: jax.Array,
    stripe_width: int = 4,
    use_pallas: bool = True,
    interpret: bool = True,
):
    """Masked checksum+parity refresh. Semantics == ref.fused_update."""
    if not use_pallas:
        return ref.fused_update(
            lanes2d, old_checksums, old_parity, block_dirty, stripe_dirty,
            stripe_width)
    nb, L = lanes2d.shape
    striped = _striped(lanes2d, stripe_width)
    ns = striped.shape[0]
    # Compact dirty stripe ids into the work queue (shared helper with the
    # XLA path); pad by repeating the last live id so trailing grid steps
    # re-address the same block (DMA elided).
    ids, count, _ = compact_stripe_ids(stripe_dirty, ns, pad_repeat_last=True)
    par_raw, cks_part = fused_update_striped(
        striped, ids, count[None], interpret=interpret)
    cks_new = xor_reduce(cks_part, (2,)).reshape(ns * stripe_width)[:nb]
    cks = jnp.where(block_dirty, cks_new, old_checksums)
    par = jnp.where(stripe_dirty[:, None], par_raw, old_parity)
    return cks, par
