from .ops import fused_update
