"""Pure-jnp oracle for the fused masked checksum+parity update."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import checksum, parity


def fused_update(
    lanes2d: jax.Array,
    old_checksums: jax.Array,
    old_parity: jax.Array,
    block_dirty: jax.Array,
    stripe_dirty: jax.Array,
    stripe_width: int,
):
    """Reference semantics of Algorithm 1 lines 7-18 over a lane view.

    * checksums recomputed for dirty blocks only (clean blocks keep stored
      values so scrubbing can still catch their corruption);
    * parity recomputed for stripes containing any dirty block.
    """
    cks = jnp.where(block_dirty, checksum.block_checksums(lanes2d), old_checksums)
    par = parity.stripe_parity_masked(lanes2d, old_parity, stripe_dirty, stripe_width)
    return cks, par
