"""Pallas TPU kernel: fused, work-queue-driven checksum+parity update.

This is the Vilamb hot loop (Algorithm 1 lines 7-18) as a single data pass,
plus two TPU-native improvements over the paper's software loop:

1. **Fusion** — the paper's thread reads each dirty page once for its
   checksum and then re-reads the stripe for parity. Here one (1, P, TILE)
   VMEM slab per grid step yields both the parity XOR *and* all P member
   checksum partials: each dirty stripe is read exactly once (halves the
   memory term; see EXPERIMENTS.md §Perf).

2. **Work queue via scalar prefetch** — dirty-stripe ids are compacted into
   an SMEM-prefetched index vector that drives the BlockSpec ``index_map``.
   Grid steps beyond ``count`` re-address the last dirty stripe; Mosaic
   skips the DMA when the block index is unchanged and ``pl.when`` skips the
   compute, so the cost scales with the number of *dirty* stripes, not the
   total — the kernel-level realization of the paper's "work ∝ dirty pages"
   claim.

Clean stripes are never addressed, so their output rows are untouched
garbage; ops.py merges with the old arrays under the dirty masks.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..common import GOLDEN, LANES, SALT2, fmix32, lane_tile, xor_reduce


def _kernel(wids_ref, count_ref, x_ref, par_ref, cks_ref, *, tile: int, stripe_width: int):
    i = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(i < count_ref[0])
    def _():
        sid = wids_ref[i]
        x = x_ref[0]  # (P, tile) uint32
        par = xor_reduce(x, (0,))[None, :]  # (1, tile)

        rows = tile // LANES
        xv = x.reshape(stripe_width, rows, LANES)
        r = jax.lax.broadcasted_iota(jnp.uint32, (stripe_width, rows, LANES), 1)
        c = jax.lax.broadcasted_iota(jnp.uint32, (stripe_width, rows, LANES), 2)
        p = jax.lax.broadcasted_iota(jnp.uint32, (stripe_width, rows, LANES), 0)
        lanes = r * jnp.uint32(LANES) + c + jnp.uint32(j * tile)
        bids = jnp.uint32(sid) * jnp.uint32(stripe_width) + p
        salt = (bids * GOLDEN) ^ (lanes * SALT2)
        h = fmix32(xv ^ salt)
        partial = xor_reduce(h, (1,))[None, :, :]  # (1, P, 128)

        @pl.when(j == 0)
        def _init():
            par_ref[...] = par
            cks_ref[...] = partial

        @pl.when(j != 0)
        def _acc():
            par_ref[...] ^= par
            cks_ref[...] ^= partial


def fused_update_striped(
    striped: jax.Array,
    work_ids: jax.Array,
    count: jax.Array,
    *,
    max_tile: int = 4096,
    interpret: bool = False,
):
    """Run the work-queue kernel over a (n_stripes, P, L) view.

    Returns (parity_raw [ns, L], cks_partials_raw [ns, P, 128]); rows not in
    the work queue contain stale/garbage values — callers must merge.
    """
    ns, P, L = striped.shape
    tile = lane_tile(L, max_tile)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(ns, L // tile),
        in_specs=[
            pl.BlockSpec((1, P, tile), lambda i, j, wids, cnt: (wids[i], 0, j)),
        ],
        out_specs=[
            pl.BlockSpec((1, tile), lambda i, j, wids, cnt: (wids[i], j)),
            pl.BlockSpec((1, P, LANES), lambda i, j, wids, cnt: (wids[i], 0, 0)),
        ],
    )
    return pl.pallas_call(
        functools.partial(_kernel, tile=tile, stripe_width=P),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((ns, L), jnp.uint32),
            jax.ShapeDtypeStruct((ns, P, LANES), jnp.uint32),
        ],
        interpret=interpret,
    )(work_ids, count, striped)
