"""Jitted wrapper: (B,S,H,hd) GQA-expanded attention via the flash kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import ref
from .flash_attn import flash_attention_bh


@functools.partial(jax.jit, static_argnames=("causal", "use_pallas", "interpret",
                                             "block_q", "block_k"))
def flash_attention(
    q: jax.Array, k: jax.Array, v: jax.Array,
    causal: bool = True, use_pallas: bool = True, interpret: bool = True,
    block_q: int = 256, block_k: int = 256,
) -> jax.Array:
    """q,k,v: (B, S, H, hd) with KV already expanded to H heads."""
    B, Sq, H, hd = q.shape
    Sk = k.shape[1]

    def bh(x):
        return x.transpose(0, 2, 1, 3).reshape(B * H, x.shape[1], hd)

    qb, kb, vb = bh(q), bh(k), bh(v)
    pad = (-hd) % 128
    if pad:
        qb = jnp.pad(qb, ((0, 0), (0, 0), (0, pad)))
        kb = jnp.pad(kb, ((0, 0), (0, 0), (0, pad)))
        vb = jnp.pad(vb, ((0, 0), (0, 0), (0, pad)))
    if not use_pallas:
        out = ref.attention(qb, kb, vb, causal=causal,
                            scale=1.0 / (hd ** 0.5))
    else:
        out = flash_attention_bh(qb, kb, vb, causal=causal,
                                 scale=1.0 / (hd ** 0.5),
                                 block_q=block_q, block_k=block_k,
                                 interpret=interpret)
    out = out[..., :hd]
    return out.reshape(B, H, Sq, hd).transpose(0, 2, 1, 3)
