"""Pure-jnp oracle: exact softmax attention."""
from __future__ import annotations

import jax.numpy as jnp


def attention(q, k, v, causal: bool = True, scale: float | None = None):
    """q,k,v: (BH, S, hd) -> (BH, S, hd)."""
    BH, Sq, hd = q.shape
    Sk = k.shape[1]
    scale = scale if scale is not None else 1.0 / (hd ** 0.5)
    s = jnp.einsum("bqd,bkd->bqk", q, k).astype(jnp.float32) * scale
    if causal:
        mask = jnp.arange(Sq)[:, None] >= jnp.arange(Sk)[None, :]
        s = jnp.where(mask[None], s, -1e30)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    return jnp.einsum("bqk,bkd->bqd", p.astype(v.dtype), v).astype(q.dtype)
