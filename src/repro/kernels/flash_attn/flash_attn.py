"""Pallas TPU flash attention (forward): online-softmax tiling in VMEM.

The §Roofline prefill cells are memory-bound on score-tensor traffic — the
pure-JAX tiled attention materializes (B,H,Sq,Sk) partials in HBM; this
kernel keeps the (block_q, block_k) score tile and the running (m, l, acc)
accumulators in VMEM, so per-chip attention HBM traffic drops from
O(S^2·H/tp) to O(S·hd) reads of q/k/v — the standard TPU adaptation
(HBM→VMEM hierarchy + MXU-aligned 128-multiple tiles) of the GPU flash
algorithm. Forward-only: serving prefill is inference; training keeps the
jnp path (fully differentiable) until a bwd kernel lands.

Grid: (B*H, q_blocks, kv_blocks); the kv dim iterates innermost
(sequentially on TPU) so scratch accumulators carry across kv steps.
Causal skip: fully-masked (q, kv) tiles are predicated off with pl.when —
the trailing-tile DMAs are elided by Mosaic's revisit rule.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
LANES = 128


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
            *, scale: float, causal: bool, block_q: int, block_k: int,
            n_kv: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    def _compute():
        q = q_ref[0].astype(jnp.float32)          # (bq, hd)
        k = k_ref[0].astype(jnp.float32)          # (bk, hd)
        v = v_ref[0]                               # (bk, hd)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # (bq, bk)
        if causal:
            rows = jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) + qi * block_q
            cols = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1) + ki * block_k
            s = jnp.where(rows >= cols, s, NEG_INF)
        m_prev = m_scr[:, :1]                      # (bq, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                     # (bq, bk)
        corr = jnp.exp(m_prev - m_new)             # (bq, 1)
        l_new = l_scr[:, :1] * corr + jnp.sum(p, axis=1, keepdims=True)
        acc = acc_scr[...] * corr + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)
        l_scr[...] = jnp.broadcast_to(l_new, l_scr.shape)
        acc_scr[...] = acc

    if causal:
        # tile needed iff k_start <= q_end (fully-masked tiles predicated off)
        pl.when(ki * block_k <= qi * block_q + block_q - 1)(_compute)
    else:
        _compute()

    @pl.when(ki == n_kv - 1)
    def _finish():
        l = jnp.maximum(l_scr[:, :1], 1e-30)
        o_ref[0] = (acc_scr[...] / l).astype(o_ref.dtype)


def flash_attention_bh(
    q: jax.Array, k: jax.Array, v: jax.Array,
    *, causal: bool = True, scale: float | None = None,
    block_q: int = 256, block_k: int = 256, interpret: bool = False,
) -> jax.Array:
    """q,k,v: (BH, S, hd) with S % block == 0, hd % 128 == 0 (pad in ops)."""
    BH, Sq, hd = q.shape
    Sk = k.shape[1]
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    assert Sq % block_q == 0 and Sk % block_k == 0
    n_kv = Sk // block_k
    scale = scale if scale is not None else 1.0 / (hd ** 0.5)
    grid = (BH, Sq // block_q, n_kv)
    return pl.pallas_call(
        functools.partial(_kernel, scale=scale, causal=causal,
                          block_q=block_q, block_k=block_k, n_kv=n_kv),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, block_k, hd), lambda bh, qi, ki: (bh, ki, 0)),
            pl.BlockSpec((1, block_k, hd), lambda bh, qi, ki: (bh, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, LANES), jnp.float32),   # running max
            pltpu.VMEM((block_q, LANES), jnp.float32),   # running sum
            pltpu.VMEM((block_q, hd), jnp.float32),      # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
