"""Deterministic fault injection & crash-consistency verification.

The paper's core claim (§5) is not raw speed but that *delayed* redundancy
still bounds data loss: scrub + cross-page parity detect and repair
firmware-induced corruptions, and the tunable knob bounds the vulnerability
window.  This package makes that claim executable:

* :mod:`repro.faults.inject` — a seeded injector that corrupts data pages,
  checksums, parity, and meta-checksums at chosen stripes/leaves (bit
  flips, torn multi-stripe writes, stale-redundancy emulation) as a
  first-class operation on a :class:`repro.core.ProtectedStore`.
* :mod:`repro.faults.crashpoints` — a crash-point state machine that
  enumerates interleavings of the pipelined tick (speculative dispatch,
  mid-flight, lazy adoption, forced resolve, flush, scrub, process death),
  snapshots the persisted view at each phase, and replays recovery via
  ``CheckpointManager.restore_verified``.
* :mod:`repro.faults.oracle` — computes the exact vulnerability window per
  run (from the dirty/shadow epoch state and the freshness deadline) and
  asserts scrub detects 100% of injected corruptions outside it with zero
  false positives, feeding measured detection latencies into
  :mod:`repro.core.mttdl`.

* :mod:`repro.faults.chaos` — the chaos-soak battery: a seeded
  :class:`ChaosSchedule` composing bitflips, a crash point, straggler
  storms, wholesale shard loss, and a mid-rebuild remesh under live
  traffic, with an invariant checker (no stale ``read_verified`` bytes,
  no silent freshness violations, bitwise post-storm recovery).

``python -m repro.faults --smoke`` runs the CI battery (crash sweep +
oracle over several seeds); ``python -m repro.faults --chaos --smoke``
runs the chaos soak; see ``docs/testing.md``.
"""
from .inject import (FAULT_KINDS, FaultInjector, FaultSpec, apply_fault)
from .crashpoints import (CRASH_PHASES, CrashOutcome, CrashPlan,
                          CrashPointMachine)
from .oracle import (DetectionRecord, OracleReport, VulnerabilityWindow,
                     check_detection, vulnerability_window)
from .chaos import (ChaosResult, ChaosSchedule, StormPhase, run_chaos_soak)

__all__ = [
    "FAULT_KINDS", "FaultInjector", "FaultSpec", "apply_fault",
    "CRASH_PHASES", "CrashOutcome", "CrashPlan", "CrashPointMachine",
    "DetectionRecord", "OracleReport", "VulnerabilityWindow",
    "check_detection", "vulnerability_window",
    "ChaosResult", "ChaosSchedule", "StormPhase", "run_chaos_soak",
]
