"""Crash-point state machine over the pipelined redundancy lifecycle.

PR3 made the tick a pipeline: a due tick *speculatively dispatches* an
overlapped Algorithm-1 update, later ticks *lazily adopt* its results (or
*coalesce* into it while in flight), and deadlines/scrubs *force a
blocking resolve*.  Each of those phases is a distinct interleaving a
crash can land in — and the paper's shadow protocol claims every one of
them is safe: the persisted ``(data, checksums, parity, dirty, shadow)``
tuple is always either fully covered or conservatively marked.

This module proves it by construction:

1. :class:`ProtectedStore` exposes host-level **phase hooks**
   (``add_phase_hook``) that fire at every lifecycle phase with the live
   redundancy view at that instant.
2. :class:`CrashPointMachine` drives a deterministic scripted workload,
   enumerates every fired ``(phase, occurrence)`` pair, and replays the
   run crashing at each one: the live view at the phase is persisted via
   :class:`repro.ckpt.CheckpointManager` (the NVM-survives-the-crash
   analogue — in-flight device work is dropped, exactly like process
   death), a **fresh** store restores it through ``restore_verified``,
   and the outcome is classified.
3. Outcomes are binary and checkable: ``recovered_bitwise`` (data
   identical, scrub clean, forward progress resumes) or
   ``lost_within_window`` (every diverging block provably inside the
   vulnerability window at crash time — the paper's accepted loss mode).
   Anything else fails the machine.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Any, Callable, Dict, List, Mapping, Optional, Sequence, Set, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import blocks as B
from repro.ckpt.checkpoint import CheckpointManager

from .inject import FaultSpec, apply_fault
from .oracle import vulnerability_window

# Phases the store instruments (docs/testing.md maps them to paper §5 /
# PR3 pipeline stages).  "adopt" = lazy adoption on a later tick;
# "adopt_forced" = deadline- or scrub-forced blocking resolve;
# "coalesce" = a due tick folded into the still-in-flight update
# (mid-flight); "dispatcher_enqueue" = the batched multi-group launch is
# about to be handed to the dispatcher thread (pre-epoch-swap live view);
# "dispatch" = per due group, right after the overlapped launch was
# enqueued (post-swap live view); "dispatcher_join" = a settle/flush/
# deadline/remesh path is about to block on the dispatcher (launch, then
# fit signal); "rebuild_paste" = one shard-rebuild paste window landed
# (PR6); "remesh_migrate" = one remesh migration window re-striped (PR7)
# — the live red at both is the *old-geometry* authoritative copy, so a
# crash there restarts on the pre-remesh mesh.
CRASH_PHASES = ("init", "on_write", "dispatcher_enqueue", "dispatch",
                "coalesce", "dispatcher_join", "adopt", "adopt_forced",
                "blocking_update", "scrub", "tick", "flush",
                "settle", "rebuild_paste", "remesh_migrate")


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class StoreState:
    """Minimal persisted pytree for a raw ProtectedStore run: the protected
    leaves plus their redundancy state — what NVM holds at a crash."""
    leaves: Dict[str, jax.Array]
    red: Any
    step: jax.Array


@dataclasses.dataclass(frozen=True)
class CrashPlan:
    """Crash at the ``occurrence``-th firing of ``phase`` (0-based)."""
    phase: str
    occurrence: int = 0


@dataclasses.dataclass
class CrashOutcome:
    plan: CrashPlan
    step: int                               # workload step at the crash
    classification: str                     # recovered_bitwise | lost_within_window | rejected | FAILED
    diverged: Dict[str, Set[int]]           # restored-vs-pristine block diffs
    window: Dict[str, Set[int]]             # vulnerable blocks at crash time
    scrub_after_flush: int = -1             # mismatches after restart+flush

    @property
    def ok(self) -> bool:
        return self.classification in ("recovered_bitwise",
                                       "lost_within_window")


class _CrashNow(Exception):
    """Raised from a phase hook to emulate process death at that phase."""

    def __init__(self, phase: str, red_live, leaves, step: int):
        super().__init__(phase)
        self.phase = phase
        self.red_live = red_live
        self.leaves = leaves
        self.step = step


def default_mutate(rng: np.random.Generator, step: int,
                   leaves: Mapping[str, jax.Array]
                   ) -> Tuple[Dict[str, jax.Array], Dict[str, jax.Array]]:
    """Deterministic scripted writes: touch 1-4 random leading-axis rows of
    every leaf, returning (new_leaves, row-mask events)."""
    out = dict(leaves)
    events: Dict[str, jax.Array] = {}
    for name in sorted(leaves):
        v = leaves[name]
        n = v.shape[0]
        rows = rng.choice(n, size=int(rng.integers(1, min(4, n) + 1)),
                          replace=False)
        idx = jnp.asarray(np.sort(rows))
        out[name] = v.at[idx].add(jnp.asarray(0.25 * step, v.dtype))
        events[name] = jnp.zeros((n,), bool).at[idx].set(True)
    return out, events


class CrashPointMachine:
    """Enumerate-and-replay crash consistency over a scripted store run.

    ``make_store`` builds a fresh, identically-configured ProtectedStore
    (one per replay — a crash kills the process, state machines included);
    ``make_leaves`` the initial protected pytree.  The workload is
    ``steps`` iterations of ``mutate`` (seeded rng -> identical writes
    every replay) + ``on_write`` + ``tick``; ``scrub_every`` forwards to
    the tick, and steps listed in ``hold_inflight_steps`` pretend the
    in-flight update is not ready yet (deterministically exercising the
    coalesce/mid-flight interleavings on a fast device).

    ``actions`` maps workload step -> ``fn(store, leaves, red)`` fired
    after that step's writes but before its tick — the deterministic way
    to script background-work triggers (``declare_shard_lost``,
    ``remesh``) into the replayed run.  An action may return nothing, or
    ``(leaves, red)`` to substitute state (e.g. after injecting a fault).
    Leaves repaired/moved by background work (rebuild pastes, remesh
    migration) are adopted into the driven pytree after every tick, so
    replays observe exactly what a real serving loop would.
    """

    def __init__(self, make_store: Callable[[], Any],
                 make_leaves: Callable[[], Dict[str, jax.Array]],
                 ckpt_dir, *, seed: int = 0, steps: int = 8,
                 scrub_every: int = 0,
                 hold_inflight_steps: Sequence[int] = (),
                 mutate: Callable = default_mutate,
                 flush_at_end: bool = True,
                 actions: Optional[Mapping[int, Callable]] = None):
        self.make_store = make_store
        self.make_leaves = make_leaves
        self.ckpt_dir = str(ckpt_dir)
        self.seed = int(seed)
        self.steps = int(steps)
        self.scrub_every = int(scrub_every)
        self.hold_inflight_steps = set(int(s) for s in hold_inflight_steps)
        self.mutate = mutate
        self.flush_at_end = flush_at_end
        self.actions = {int(k): v for k, v in (actions or {}).items()}
        self._probe_store = None

    def _probe(self):
        if self._probe_store is None:
            self._probe_store = self.make_store()
        return self._probe_store

    # ------------------------------------------------------------- driving
    @contextlib.contextmanager
    def _held_readiness(self, active: bool):
        """Force the non-blocking readiness probe to report 'in flight'."""
        import repro.core.store as store_mod
        if not active:
            yield
            return
        orig = store_mod._ready
        store_mod._ready = lambda x: False
        try:
            yield
        finally:
            store_mod._ready = orig

    def _drive(self, on_phase: Optional[Callable[[str, dict], None]] = None):
        """One full scripted run; returns (store, leaves, red, fired).

        ``on_phase(phase, info)`` may raise :class:`_CrashNow`; ``fired``
        is the ordered list of every phase firing with its occurrence
        index (the machine's transition log).
        """
        store = self.make_store()
        leaves = self.make_leaves()
        rng = np.random.default_rng(self.seed)
        fired: List[Tuple[str, int]] = []
        counts: Dict[str, int] = {}
        cur = {"leaves": leaves, "step": 0}

        def hook(phase: str, info: dict):
            occ = counts.get(phase, 0)
            counts[phase] = occ + 1
            fired.append((phase, occ))
            if on_phase is not None:
                info = dict(info)
                info.setdefault("step", cur["step"])
                info["occurrence"] = occ
                info["leaves"] = cur["leaves"]
                on_phase(phase, info)

        store.add_phase_hook(hook)
        red = store.init(leaves)
        hook("init", {"red": red})
        try:
            for step in range(1, self.steps + 1):
                cur["step"] = step
                leaves, events = self.mutate(rng, step, leaves)
                cur["leaves"] = leaves
                red = store.on_write(red, events=events)
                act = self.actions.get(step)
                if act is not None:
                    res = act(store, leaves, red)
                    if res is not None:
                        leaves, red = dict(res[0]), dict(res[1])
                        cur["leaves"] = leaves
                held = step in self.hold_inflight_steps
                if not held:
                    # Determinism: a non-held tick must always see the
                    # in-flight update as ready, regardless of machine
                    # load — otherwise the adopt-vs-coalesce branch (and
                    # with it the enumerated crash-point list) would
                    # depend on dispatcher-thread and async-copy timing.
                    if hasattr(store, "sync_inflight"):
                        store.sync_inflight()
                with self._held_readiness(held):
                    red, rep = store.tick(
                        leaves, red, step,
                        scrub_period=self.scrub_every or None)
                if rep.repaired:
                    # Rebuild pastes / remesh moves: the serving loop
                    # adopts these, so the crash machine must too.
                    leaves = dict(leaves)
                    leaves.update(rep.repaired)
                    cur["leaves"] = leaves
            if self.flush_at_end:
                red = store.flush(leaves, red, step=self.steps)
                drained = getattr(store, "take_repaired", lambda: {})()
                if drained:
                    leaves = dict(leaves)
                    leaves.update(drained)
                    cur["leaves"] = leaves
        finally:
            store.remove_phase_hook(hook)
        return store, leaves, red, fired

    def enumerate_phases(self) -> List[Tuple[str, int]]:
        """Dry run: every (phase, occurrence) a crash could land in."""
        _, _, _, fired = self._drive()
        return fired

    # ------------------------------------------------------------ crashing
    def run_crash(self, plan: CrashPlan,
                  faults: Sequence[FaultSpec] = ()) -> CrashOutcome:
        """Replay the workload, die at ``plan``, restart, classify.

        ``faults`` are applied to the *persisted* state between death and
        restart — corruption landing while the process is down.
        """

        def on_phase(phase: str, info: dict):
            if phase == plan.phase and info["occurrence"] == plan.occurrence:
                raise _CrashNow(phase, info.get("red"), info["leaves"],
                                int(info["step"]))

        try:
            self._drive(on_phase)
        except _CrashNow as crash:
            return self._restart(plan, crash, faults)
        raise ValueError(
            f"plan {plan} never fired; enumerate_phases() lists valid "
            "crash points for this workload")

    def _restart(self, plan: CrashPlan, crash: _CrashNow,
                 faults: Sequence[FaultSpec]) -> CrashOutcome:
        """Persist the crash-time view, corrupt it, restore, classify."""
        pristine = {k: np.asarray(jax.device_get(v))
                    for k, v in crash.leaves.items()}
        leaves, red = dict(crash.leaves), dict(crash.red_live)
        # The window is judged at the instant of death — exactly the
        # dirty|shadow set the persisted bitmaps encode.  The probe store
        # is only consulted for static geometry (metas), so one instance
        # serves every replay.
        probe_store = self._probe()
        window = vulnerability_window(probe_store, red)
        factors = {n: probe_store.shard_factor(n) for n in probe_store.metas}
        for spec in faults:
            leaves, red = apply_fault(probe_store.metas, leaves, red, spec,
                                      factors=factors)
        state = StoreState(leaves=dict(leaves), red=red,
                           step=jnp.asarray(crash.step, jnp.int32))
        # One directory per replay: the manager's keep-last-k GC must never
        # collect a checkpoint another replay of this sweep just wrote.
        mgr = CheckpointManager(
            f"{self.ckpt_dir}/crash_{plan.phase}_{plan.occurrence}")
        mgr.save(crash.step, state, blocking=True)
        # ----- restart: fresh process, fresh store, verified restore -----
        store2 = self.make_store()
        struct = jax.eval_shape(lambda: state)
        restored = mgr.restore_verified(
            struct, store2,
            leaves_of=lambda st: st.leaves,
            replace_leaves=lambda st, lv: dataclasses.replace(
                st, leaves=dict(lv)),
            step=crash.step)
        win_sets = {n: set(np.flatnonzero(m).tolist())
                    for n, m in window.blocks.items() if m.any()}
        if restored is None:
            return CrashOutcome(plan=plan, step=crash.step,
                                classification="rejected", diverged={},
                                window=win_sets)
        diverged = self._block_diff(probe_store, restored.leaves, pristine)
        in_window = all(
            window.contains(name, b)
            for name, blks in diverged.items() for b in blks)
        # Forward progress: the restarted store must be able to bring the
        # restored state back to full coverage and a clean scrub.
        red2 = store2.flush(restored.leaves, restored.red,
                            step=int(restored.step))
        scrub_after = store2.scrub_check(restored.leaves, red2)
        if not diverged:
            cls = "recovered_bitwise"
        elif in_window:
            cls = "lost_within_window"
        else:
            cls = "FAILED"
        if scrub_after != 0:
            cls = "FAILED"
        return CrashOutcome(plan=plan, step=crash.step, classification=cls,
                            diverged=diverged, window=win_sets,
                            scrub_after_flush=int(scrub_after))

    @staticmethod
    def _block_diff(store, got: Mapping[str, jax.Array],
                    want: Mapping[str, np.ndarray]) -> Dict[str, Set[int]]:
        """Blocks whose restored bits differ from the pristine crash view.

        Global block ids: sharded leaves are diffed shard by shard through
        each shard's local lane view (the metas are shard-local geometry).
        """
        out: Dict[str, Set[int]] = {}
        factor = getattr(store, "shard_factor", lambda n: 1)
        for name, meta in store.protected_metas.items():
            k = int(factor(name))
            ga, gb = jnp.asarray(got[name]), jnp.asarray(want[name])
            bad_all: Set[int] = set()
            for s in range(k):
                a = np.asarray(jax.device_get(
                    B.to_lanes(B.shard_slice(ga, meta, k, s)[0], meta)))
                b = np.asarray(jax.device_get(
                    B.to_lanes(B.shard_slice(gb, meta, k, s)[0], meta)))
                bad = np.flatnonzero((a != b).any(axis=1)) + s * meta.n_blocks
                bad_all.update(int(x) for x in bad)
            if bad_all:
                out[name] = bad_all
        return out

    # -------------------------------------------------------------- sweeps
    def sweep(self, faults_for: Optional[Callable[[CrashPlan], Sequence[FaultSpec]]] = None,
              require_phases: Sequence[str] = (),
              only_phases: Sequence[str] = ()) -> List[CrashOutcome]:
        """Crash at every enumerated phase occurrence; every outcome must be
        recoverable or provably lost within the window.

        ``require_phases`` asserts the workload actually exercised the
        named phases (e.g. the PR3 pipeline set) before sweeping —
        otherwise a too-tame workload would vacuously pass.

        ``only_phases`` restricts the replayed crashes to the named
        phases (still enumerated from the full run).  Use it for remesh
        workloads: a crash *after* adoption persists new-geometry state
        that a fresh old-mesh store cannot restore, so those sweeps crash
        only inside the migration (``remesh_migrate``), where the
        old-geometry redundancy is still authoritative.
        """
        fired = self.enumerate_phases()
        have = {p for p, _ in fired}
        missing = set(require_phases) - have
        if missing:
            raise AssertionError(
                f"workload never reached phases {sorted(missing)}; "
                f"fired={sorted(have)}")
        keep = set(only_phases)
        outcomes = []
        for phase, occ in fired:
            if keep and phase not in keep:
                continue
            plan = CrashPlan(phase, occ)
            faults = tuple(faults_for(plan)) if faults_for else ()
            outcomes.append(self.run_crash(plan, faults))
        return outcomes
