"""Chaos-soak battery: every fault mode at once, under live traffic.

The fault machinery so far proves each hazard in isolation — bitflips
(oracle + patroller), process death (crash points), wholesale shard loss
(online rebuild), geometry changes (remesh battery).  Production fails
them *together*.  This module composes them into one seeded, deterministic
soak: a :class:`ChaosSchedule` of storm phases runs against a live
write/tick workload while an invariant checker audits every tick:

(a) **no stale bytes** — periodic ``read_verified`` spot-checks against a
    host-side ground-truth mirror either return the mirror's exact bytes
    or raise a typed ``UnrecoverableReadError``; a silent mismatch fails
    the run,
(b) **no silent deadline violations** — whenever a group's vulnerability
    age exceeds ``max_vulnerable_steps`` the tick's ``report.health``
    must carry a matching violation or escalation action (the governor's
    never-silent contract); an excursion nothing reported fails the run,
(c) **bitwise recovery** — after the last storm the store settles,
    flushes, scrubs clean, and every leaf equals the mirror bit for bit.

Measured patrol detection latencies feed
:func:`repro.core.mttdl.mttdl_measured_live` — the soak's empirical
reliability number — and the post-storm breaker recovery time is
reported as ``recovery_ticks`` (guarded by ``benchmarks/health_bench``).

Ground truth: writes are row ``set``s with seeded values, mirrored into a
host numpy array — bitwise-identical arithmetic on both sides, so the
final comparison is exact equality, not tolerance.

The full schedule (bitflips + crash + straggler storm + shard loss +
mid-rebuild remesh) needs a mesh and runs in the 8-device subprocess leg
(``python -m repro.faults --chaos``); :func:`run_chaos_soak` also runs
machine-local with the mesh-dependent phases (``shard_loss``,
``remesh``) omitted — the in-process test-suite configuration.
"""
from __future__ import annotations

import dataclasses
import tempfile
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (ProtectedStore, RedundancyPolicy,
                        UnrecoverableReadError, blocks as blocks_mod, mttdl)
from repro.health import HealthPolicy

from .inject import FaultInjector, FaultSpec

# Nominal per-block MTTF for the soak's MTTDL projection (same figure the
# mttdl benchmark uses for its scheduled-vs-patrol comparison).
MTTF_BLOCK_S = 1e9


@dataclasses.dataclass(frozen=True)
class StormPhase:
    """One schedule entry.  Kinds:

    ``traffic``    — ``steps`` plain write+tick steps
    ``bitflips``   — inject ``n`` clean-block bitflips, then quiet ticks
                     until the patroller repairs them all
    ``straggler``  — ``steps`` write+tick steps reporting ``step_time``
                     seconds each (stretches the straggler governor)
    ``crash``      — persist live (leaves, red) via CheckpointManager,
                     build a fresh store/governor, ``restore_verified``
    ``quiesce``    — flush, then quiet ticks until cross-shard parity
                     covers the leaf (pre-loss coverage wait)
    ``shard_loss`` — wipe shard ``n`` wholesale + declare it lost, then
                     ``steps`` live-traffic ticks (rebuild runs under
                     traffic; needs a mesh)
    ``remesh``     — queue ``store.remesh`` onto the grow mesh (mid-storm:
                     issued while the rebuild is still pasting), then
                     ``steps``+ ticks until rebuild and migration adopt
    ``drain``      — stop the traffic, tick until every breaker is
                     HEALTHY again (measures ``recovery_ticks``)
    """
    kind: str
    steps: int = 0
    n: int = 0
    step_time: float = 0.0


class ChaosSchedule:
    """A seeded sequence of storm phases (see :class:`StormPhase`)."""

    def __init__(self, phases: Sequence[StormPhase], seed: int = 0):
        self.phases = tuple(phases)
        self.seed = int(seed)

    @classmethod
    def default(cls, seed: int = 0, *, sharded: bool = True,
                smoke: bool = True) -> "ChaosSchedule":
        t = 4 if smoke else 12
        phases = [
            StormPhase("traffic", steps=2 * t),
            StormPhase("bitflips", n=2 if smoke else 4),
            StormPhase("traffic", steps=t),
            StormPhase("straggler", steps=2 * t, step_time=1.0),
            StormPhase("crash"),
            StormPhase("traffic", steps=t),
        ]
        if sharded:
            phases += [
                StormPhase("quiesce"),
                StormPhase("shard_loss", steps=2, n=2),
                StormPhase("remesh", steps=6 * t, step_time=0.5),
            ]
        phases += [StormPhase("traffic", steps=t), StormPhase("drain")]
        return cls(phases, seed)


@dataclasses.dataclass
class ChaosResult:
    seed: int
    steps: int = 0
    ticks: int = 0
    phases_run: Tuple[str, ...] = ()
    # Invariant (b): excursions past the deadline with NO matching
    # violation/action on report.health.  Must be zero, always.
    silent_violations: int = 0
    violations_reported: int = 0
    ladder_actions: int = 0
    backpressure_events: int = 0
    # Invariant (a): read_verified spot-checks.
    reads_checked: int = 0
    reads_typed_errors: int = 0
    reads_stale: int = 0
    # Storm bookkeeping.
    bitflips_injected: int = 0
    bitflips_repaired: int = 0
    crash_restores: int = 0
    # Named losses: blocks the rebuild reported structurally
    # unrecoverable (e.g. a survivor write staled the cross-shard parity
    # row before the rebuild froze the survivors' XOR).  The runner plays
    # the app and restores them from its mirror — loss is acceptable only
    # when *named*; the final bitwise check stays strict.
    named_lost_blocks: int = 0
    named_lost_rows_restored: int = 0
    rebuild_done: bool = True      # vacuously true when phase not scheduled
    remesh_done: bool = True
    deadline_fired: int = 0
    # Invariant (c): post-storm state.
    final_clean: bool = False
    final_bitwise: bool = False
    recovery_ticks: int = 0
    # Reliability projection from measured patrol detection latencies.
    detect_latency_stats: Dict[str, float] = dataclasses.field(
        default_factory=dict)
    mttdl_live_s: float = 0.0
    failures: Tuple[str, ...] = ()

    def ok(self) -> bool:
        return (not self.failures and self.silent_violations == 0
                and self.reads_stale == 0 and self.final_clean
                and self.final_bitwise and self.rebuild_done
                and self.remesh_done)

    def summary(self) -> str:
        return (f"seed={self.seed} ticks={self.ticks} "
                f"phases={len(self.phases_run)} "
                f"silent={self.silent_violations} "
                f"violations={self.violations_reported} "
                f"actions={self.ladder_actions} "
                f"reads={self.reads_checked}"
                f"(typed={self.reads_typed_errors} stale={self.reads_stale}) "
                f"deadline_fired={self.deadline_fired} "
                f"named_lost={self.named_lost_blocks} "
                f"recovery_ticks={self.recovery_ticks} "
                f"clean={self.final_clean} bitwise={self.final_bitwise} "
                f"mttdl={self.mttdl_live_s:.3g}s "
                f"{'OK' if self.ok() else 'FAIL: ' + '; '.join(self.failures)}")


class _ChaosRunner:
    """One soak run: store + mirror + invariant checker."""

    N_ROWS, N_COLS = 64, 2048

    def __init__(self, schedule: ChaosSchedule, *, sharded: bool,
                 verbose=None):
        self.schedule = schedule
        self.sharded = sharded
        self.rng = np.random.default_rng(schedule.seed)
        self.log = verbose or (lambda *_: None)
        self.result = ChaosResult(seed=schedule.seed)
        self.step = 0
        self.lost_shard: Optional[int] = None
        self.rebuild_done_seen = False
        self.detect_latencies: List[float] = []
        if sharded:
            from jax.sharding import PartitionSpec as P
            from repro.launch.mesh import make_mesh
            self.mesh = make_mesh((1, 2, 2), ("pod", "data", "model"))
            self.grow_mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
            self.specs = {"w": P(("pod", "data", "model"), None)}
        else:
            self.mesh = self.grow_mesh = None
            self.specs = {}
        self.store = self._make_store(self.mesh)
        self.leaves = self._make_leaves(self.mesh)
        self.mirror = np.array(jax.device_get(self.leaves["w"]))
        self.red = self.store.init(self.leaves)
        self.injector = FaultInjector(self.store, seed=schedule.seed)

    # ------------------------------------------------------------ plumbing

    def _make_leaves(self, mesh) -> Dict[str, jax.Array]:
        w = jax.random.normal(jax.random.PRNGKey(self.schedule.seed),
                              (self.N_ROWS, self.N_COLS), jnp.float32)
        if mesh is not None:
            from jax.sharding import NamedSharding
            w = jax.device_put(w, NamedSharding(mesh, self.specs["w"]))
        return {"w": w}

    def _make_store(self, mesh) -> ProtectedStore:
        # precompile=False: crash replays restore unsharded host arrays and
        # the remesh adoption re-lowers against the new mesh anyway.
        pol = RedundancyPolicy.single(
            "vilamb", period_steps=2, max_vulnerable_steps=6,
            lanes_per_block=128, work_queue_frac=0.5, async_tick=True,
            patrol_bytes_per_tick=32 * 128 * 4, precompile=False,
            straggler_window=4, straggler_recovery_steps=2,
            health=HealthPolicy(dispatch_timeout_s=5.0,
                                deadline_margin_steps=1,
                                backpressure="spin",
                                backpressure_spin_s=0.0,
                                recovery_ticks=2,
                                violation_mode="report"))
        store = ProtectedStore(pol, mesh=mesh)
        if mesh is not None:
            return store.attach(self._make_leaves(mesh), specs=self.specs)
        return store.attach(self._make_leaves(None))

    def _harvest_latencies(self) -> None:
        pat = self.store.patroller
        if pat is not None and pat.latencies:
            self.detect_latencies.extend(pat.latencies)
            pat.latencies.clear()

    # ----------------------------------------------------------- invariants

    def _check_tick(self, rep) -> None:
        r = self.result
        r.deadline_fired += len(rep.deadline_fired)
        if rep.health is not None:
            r.violations_reported += len(rep.health.violations)
            r.ladder_actions += len(rep.health.actions)
            r.backpressure_events += rep.health.backpressure_events
        for g in self.store._protected():
            lp = g.policy
            if lp.mode != "vilamb" or lp.max_vulnerable_steps <= 0:
                continue
            age = self.step - g.last_update_step
            if age <= lp.max_vulnerable_steps:
                continue
            h = rep.health
            visible = h is not None and (
                any(v.group == g.label for v in h.violations)
                or any(a.group == g.label for a in h.actions))
            if not visible:
                r.silent_violations += 1
                self.log(f"  SILENT deadline excursion: {g.label} age {age} "
                         f"> {lp.max_vulnerable_steps} at step {self.step}")

    def _spot_read(self, n_blocks: int = 2) -> None:
        r = self.result
        meta = self.store.protected_metas["w"]
        k = self.store.shard_factor("w")
        total = k * meta.n_blocks
        blocks = sorted(self.rng.choice(
            total, size=min(n_blocks, total), replace=False).tolist())
        try:
            got = self.store.read_verified(self.leaves, self.red, "w", blocks)
        except UnrecoverableReadError:
            # Typed is the contract: degraded, but never stale-silent.
            r.reads_checked += len(blocks)
            r.reads_typed_errors += len(blocks)
            return
        rows_local = self.N_ROWS // k
        for b in blocks:
            s, lb = divmod(b, meta.n_blocks)
            sub = self.mirror[s * rows_local:(s + 1) * rows_local] \
                if k > 1 else self.mirror
            want = np.asarray(blocks_mod.to_lanes(jnp.asarray(sub), meta))[lb]
            r.reads_checked += 1
            if not np.array_equal(np.asarray(got[b]), want):
                r.reads_stale += 1
                self.log(f"  STALE read_verified bytes: block {b} at step "
                         f"{self.step}")

    # ------------------------------------------------------------- workload

    def _tick(self, *, step_time: float = 0.0, write: bool = True) -> Any:
        if write:
            rows = np.sort(self.rng.choice(self.N_ROWS, size=3,
                                           replace=False))
            vals = self.rng.standard_normal(
                (len(rows), self.N_COLS)).astype(np.float32)
            idx = jnp.asarray(rows)
            self.leaves = dict(
                self.leaves, w=self.leaves["w"].at[idx].set(jnp.asarray(vals)))
            self.mirror[rows] = vals
            ev = jnp.zeros((self.N_ROWS,), bool).at[idx].set(True)
            self.red = self.store.on_write(self.red, events={"w": ev})
            self.result.steps += 1
        self.step += 1
        # Always feed the straggler governor: calm ticks report a small
        # baseline so a storm's inflated step_time registers as > factor x
        # the rolling median (an all-storm window would look "normal").
        self.red, rep = self.store.tick(
            self.leaves, self.red, self.step,
            step_time=step_time if step_time > 0 else 0.01, scrub_period=0)
        if rep.repaired:
            self.leaves = dict(self.leaves, **rep.repaired)
        if rep.rebuild is not None and rep.rebuild.done:
            self.rebuild_done_seen = True
        if rep.unrecoverable:
            self._restore_named_losses(rep.unrecoverable)
        self.result.ticks += 1
        self._check_tick(rep)
        if self.result.ticks % 5 == 0:
            self._spot_read()
        return rep

    def _restore_named_losses(self, recs) -> None:
        """App-level restore of structurally reported losses.

        A rebuild can *name* blocks it cannot reconstruct (stale
        cross-shard parity row: a survivor write between the xpar fold
        and the rebuild's survivor-XOR freeze makes the XOR garbage).
        That is the contract — loss is acceptable only when reported.
        The runner answers like an application with a backup: rewrite
        the affected rows from the mirror as ordinary foreground
        writes, so redundancy re-converges through the normal dirty
        path and the final bitwise check stays strict."""
        meta = self.store.protected_metas["w"]
        k = self.store.shard_factor("w")
        rows_local = self.N_ROWS // k
        blocks_per_row = meta.n_blocks // rows_local
        rows = set()
        n_blocks = 0
        for rec in recs:
            if rec.leaf != "w":
                continue
            for gb in rec.blocks:
                s, lb = divmod(int(gb), meta.n_blocks)
                rows.add(s * rows_local + lb // blocks_per_row)
                n_blocks += 1
        if not rows:
            return
        r = np.asarray(sorted(rows))
        idx = jnp.asarray(r)
        self.leaves = dict(
            self.leaves,
            w=self.leaves["w"].at[idx].set(jnp.asarray(self.mirror[r])))
        ev = jnp.zeros((self.N_ROWS,), bool).at[idx].set(True)
        self.red = self.store.on_write(self.red, events={"w": ev})
        self.result.named_lost_blocks += n_blocks
        self.result.named_lost_rows_restored += len(r)
        self.log(f"  named loss: {n_blocks} blocks -> restored rows "
                 f"{r.tolist()} from the mirror at step {self.step}")

    # --------------------------------------------------------------- phases

    def _phase_bitflips(self, ph: StormPhase) -> None:
        r = self.result
        specs = self.injector.plan_clean_blocks(
            self.red, n=ph.n, kinds=("data_bitflip",))
        if not specs:
            r.failures += ("bitflips: no clean blocks to corrupt",)
            return
        pat = self.store.patroller
        for spec in specs:
            self.leaves, self.red = self.injector.inject_many(
                self.leaves, self.red, [spec])
            pat.expect_injection("w", spec.block, self.step)
        r.bitflips_injected += len(specs)
        before = len(pat.latencies)
        # Quiet ticks: the patroller only probes idle ticks, and repairs
        # must not race fresh writes into the corrupted rows (a write
        # into a latently-corrupt block would launder the corruption into
        # recomputed checksums — the one sequence redundancy cannot catch).
        for _ in range(96):
            self._tick(write=False)
            if len(pat.latencies) - before >= len(specs):
                break
        repaired = len(pat.latencies) - before
        r.bitflips_repaired += repaired
        if repaired < len(specs):
            r.failures += (f"bitflips: {len(specs) - repaired} of "
                           f"{len(specs)} never repaired",)
        self._harvest_latencies()

    def _phase_crash(self, ph: StormPhase) -> None:
        from repro.ckpt.checkpoint import CheckpointManager
        from .crashpoints import StoreState
        self._harvest_latencies()
        # In-flight work dies with the process: persist the live view as-is
        # (pendings dropped — their blocks are shadow-marked, so the
        # restore treats them as vulnerable), restore into a FRESH store.
        state = StoreState(leaves=dict(self.leaves), red=dict(self.red),
                           step=jnp.asarray(self.step, jnp.int32))
        with tempfile.TemporaryDirectory() as tmp:
            mgr = CheckpointManager(tmp)
            mgr.save(self.step, state, blocking=True)
            self.store = self._make_store(self.mesh)
            self.injector = FaultInjector(self.store,
                                          seed=self.schedule.seed + 1)
            struct = jax.eval_shape(lambda: state)
            restored = mgr.restore_verified(
                struct, self.store,
                leaves_of=lambda st: st.leaves,
                replace_leaves=lambda st, lv: dataclasses.replace(
                    st, leaves=dict(lv)),
                step=self.step)
        if restored is None:
            self.result.failures += ("crash: restore_verified failed",)
            return
        leaves = dict(restored.leaves)
        if self.mesh is not None:
            from jax.sharding import NamedSharding
            leaves = {n: jax.device_put(
                v, NamedSharding(self.mesh, self.specs[n]))
                for n, v in leaves.items()}
        self.leaves, self.red = leaves, dict(restored.red)
        self.result.crash_restores += 1
        # The restore scrub-repairs any latent out-of-window corruption;
        # in-window blocks keep their (newest, mirror-equal) data.
        if not np.array_equal(np.asarray(jax.device_get(self.leaves["w"])),
                              self.mirror):
            self.result.failures += ("crash: restored leaves != mirror",)

    def _phase_quiesce(self, ph: StormPhase) -> None:
        self.red = self.store.flush(self.leaves, self.red, self.step)
        pat = self.store.patroller
        for _ in range(96):
            self._tick(write=False)
            xp = pat.xpar.get("w") if pat is not None else None
            if xp is not None and bool(xp.xvalid.all()):
                return
        if self.sharded:
            self.result.failures += ("quiesce: xpar never covered the leaf",)

    def _phase_shard_loss(self, ph: StormPhase) -> None:
        lost = ph.n
        self.leaves, self.red = self.store.inject(
            self.leaves, self.red,
            FaultSpec(kind="shard_loss", leaf="w", block=lost))
        self.store.declare_shard_lost("w", lost, self.red)
        self.lost_shard = lost
        for _ in range(max(1, ph.steps)):
            self._tick()

    def _phase_remesh(self, ph: StormPhase) -> None:
        r = self.result
        # Mid-storm: the rebuild from the shard loss is still pasting; the
        # remesh queues behind it in the priority ladder and starts only
        # once the loss is recovered.
        self.store.remesh(self.grow_mesh)
        # The rebuild may already have finished during the shard-loss
        # phase's own live ticks — _tick tracks completion globally.
        rebuild_done = self.lost_shard is None or self.rebuild_done_seen
        remesh_done = False
        for i in range(max(ph.steps, 8) + 192):
            # Straggler storm overlapping the migration for the first
            # half of the nominal phase length.
            st = ph.step_time if i < max(ph.steps, 8) // 2 else 0.0
            rep = self._tick(step_time=st)
            if self.rebuild_done_seen:
                rebuild_done = True
            if rep.remesh is not None and rep.remesh.done:
                remesh_done = True
                break
        r.rebuild_done = r.rebuild_done and rebuild_done
        r.remesh_done = r.remesh_done and remesh_done
        if not rebuild_done:
            r.failures += ("shard rebuild never completed",)
        if not remesh_done:
            r.failures += ("remesh migration never adopted",)
        self.lost_shard = None
        self._harvest_latencies()

    def _phase_drain(self, ph: StormPhase) -> None:
        hg = self.store._health
        ticks = 0
        for _ in range(256):
            rep = self._tick(write=False)
            if hg is None or rep.health is None:
                break
            if rep.health.worst == "healthy":
                break
            ticks += 1
        else:
            self.result.failures += ("drain: breakers never recovered",)
        self.result.recovery_ticks = ticks

    # ------------------------------------------------------------------ run

    def run(self) -> ChaosResult:
        r = self.result
        dispatch = {
            "traffic": lambda ph: [self._tick(step_time=ph.step_time or 0.0)
                                   for _ in range(ph.steps)],
            "straggler": lambda ph: [self._tick(step_time=ph.step_time)
                                     for _ in range(ph.steps)],
            "bitflips": self._phase_bitflips,
            "crash": self._phase_crash,
            "quiesce": self._phase_quiesce,
            "shard_loss": self._phase_shard_loss,
            "remesh": self._phase_remesh,
            "drain": self._phase_drain,
        }
        for ph in self.schedule.phases:
            if not self.sharded and ph.kind in ("quiesce", "shard_loss",
                                                "remesh"):
                continue
            self.log(f"  chaos phase {ph.kind} (step {self.step})")
            dispatch[ph.kind](ph)
            r.phases_run += (ph.kind,)
            if r.failures:
                break
        # Invariant (c): settle, flush, scrub clean, bitwise vs mirror.
        self.red = self.store.settle(self.red, self.leaves)
        self.leaves = dict(self.leaves, **self.store.take_repaired())
        self.red = self.store.flush(self.leaves, self.red, self.step)
        self.leaves = dict(self.leaves, **self.store.take_repaired())
        r.final_clean = int(self.store.scrub_check(self.leaves,
                                                   self.red)) == 0
        r.final_bitwise = np.array_equal(
            np.asarray(jax.device_get(self.leaves["w"])), self.mirror)
        self._harvest_latencies()
        stats = mttdl.detection_latency_stats(self.detect_latencies,
                                              step_seconds=1.0)
        r.detect_latency_stats = stats
        meta = self.store.protected_metas["w"]
        r.mttdl_live_s = mttdl.mttdl_measured_live(
            MTTF_BLOCK_S, 0.0, self.store.policy.stripe_data_blocks + 1,
            meta.n_stripes, assumed_latency_seconds=stats["mean_s"],
            measured=stats)
        return r


def run_chaos_soak(seed: int = 0, *, sharded: bool = False,
                   smoke: bool = True,
                   schedule: Optional[ChaosSchedule] = None,
                   verbose=None) -> ChaosResult:
    """Run one seeded chaos soak; see the module docstring for invariants.

    ``sharded=True`` requires a multi-device jax runtime (the ``--chaos``
    CLI leg spawns one with 8 forced host devices); machine-local runs
    skip the mesh-dependent storm phases.
    """
    sched = schedule or ChaosSchedule.default(seed, sharded=sharded,
                                              smoke=smoke)
    return _ChaosRunner(sched, sharded=sharded, verbose=verbose).run()
