"""Seeded, deterministic corruption injector for ProtectedStore state.

Every fault the paper's §5 analysis worries about is expressible as a
:class:`FaultSpec` applied *functionally* to ``(leaves, red)`` — no
test-local array surgery.  The injector never mutates dirty bitmaps as a
side effect (except where the fault *is* a lost dirty bit), so the
vulnerability-window oracle can classify each fault exactly.

Kinds
-----
``data_bitflip``       flip one bit of one uint32 lane of a data block —
                       the paper's firmware scribble / media SDC.
``checksum_bitflip``   corrupt a stored per-block checksum (detected by the
                       meta-checksum, Alg. 1 line 22).
``parity_bitflip``     corrupt a stored parity lane (silent until a repair
                       needs that stripe; surfaced by repair verification).
``meta_bitflip``       corrupt the checksum-of-checksums scalar.
``torn_write``         a multi-block write that only partially landed and
                       whose dirty marks were lost (crash between the data
                       store and the mark): blocks get fresh random bits,
                       the bitmaps stay clean — scrub must catch all of it.
``stale_redundancy``   firmware lost a dirty bit: the block's data changed
                       but dirty|shadow say it did not — redundancy is
                       silently stale, indistinguishable from corruption.
``mesh_shrink``        a departing shard (``block`` = shard index) leaves the
                       cluster dirty: its data lanes, its slice of the
                       global checksum array, and its meta checksum are all
                       XOR-scribbled — the worst case a shrink-side remesh
                       must re-stripe through.
``mesh_grow``          a joining shard arrives with data intact but zeroed
                       redundancy (checksums slice + meta checksum) — the
                       fresh-capacity case a grow-side remesh covers via
                       full recomputation of the new geometry.

All randomness flows from the single ``numpy`` generator seeded at
construction; an injector with the same seed over the same store geometry
produces the same fault sequence bit for bit.

Mesh-sharded stores are addressed through **global block geometry**:
``FaultSpec.block`` (and every id in ``blocks``) indexes
``shard * meta.n_blocks + local_block`` over the shard-local metas — the
same space scrub masks, ``vulnerable_masks``, and ``recover_block`` use.
``apply_fault`` resolves the owning shard and performs the lane surgery on
that shard's slice of the (dim0-sharded) global arrays, so a fault planned
on shard 3 corrupts shard 3's bits and must be detected by shard 3's local
scrub — never by a neighbour's.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import blocks as B
from repro.core.state import LeafRedundancy

FAULT_KINDS = ("data_bitflip", "checksum_bitflip", "parity_bitflip",
               "meta_bitflip", "torn_write", "stale_redundancy",
               "shard_loss", "mesh_shrink", "mesh_grow")

# Adversarial uint32 payloads: float32 NaN/Inf bit patterns and sentinel-ish
# values.  Injection draws from these (as well as uniform bits) so detection
# never depends on "corrupt values look random".
SPECIAL_LANES = np.array([
    0x7FC00000,  # float32 quiet NaN
    0x7F800000,  # +Inf
    0xFF800000,  # -Inf
    0x7F800001,  # signalling NaN
    0x00000000,  # zeros (absorbing for XOR mistakes)
    0xFFFFFFFF,  # all ones
], dtype=np.uint32)


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One concrete, replayable fault.

    ``block``/``lane``/``bit`` address the corruption site in block-lane
    space (see :mod:`repro.core.blocks`); ``blocks`` lists every block a
    ``torn_write``/``stale_redundancy`` fault touches.  ``payload`` carries
    the uint32 value XORed/stored at the site, so a spec fully determines
    the corrupted state.
    """
    kind: str
    leaf: str
    block: int = -1
    lane: int = 0
    bit: int = 0
    blocks: Tuple[int, ...] = ()
    payload: int = 0

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r} "
                             f"(want one of {FAULT_KINDS})")

    @property
    def touched_blocks(self) -> Tuple[int, ...]:
        """Every data block whose *content vs redundancy* this fault skews.

        Checksum/parity/meta faults corrupt redundancy, not data; they
        report the block (or stripe members) whose protection they weaken.
        """
        if self.blocks:
            return self.blocks
        if self.block >= 0:
            return (self.block,)
        return ()


def apply_fault(metas, leaves: Mapping[str, jax.Array],
                red: Mapping[str, LeafRedundancy], spec: FaultSpec,
                factors: Optional[Mapping[str, int]] = None
                ) -> Tuple[Dict[str, jax.Array], Dict[str, LeafRedundancy]]:
    """Apply one fault functionally; returns new ``(leaves, red)``.

    ``metas`` maps leaf name -> :class:`repro.core.blocks.BlockMeta` (use
    ``store.metas``).  ``factors`` maps leaf name -> shard count for
    mesh-sharded leaves (``store.shard_factor``; absent/1 = machine-local):
    block ids are then interpreted in global block space and the surgery
    lands on the owning shard's slice.  Inputs are never mutated.
    """
    leaves = dict(leaves)
    red = dict(red)
    meta = metas[spec.leaf]
    k = int((factors or {}).get(spec.leaf, 1))

    def owner(block):
        """(shard, local_block) for a global id — loud on a bad factor."""
        s, b = divmod(int(block), meta.n_blocks)
        if not 0 <= s < k:
            raise ValueError(
                f"{spec.leaf}: global block {block} addresses shard {s} but "
                f"the leaf has {k} shard(s) — pass factors= "
                "(store.shard_factor) when injecting into a sharded store")
        return s, b

    def shard_lanes(block):
        """(shard, local_block, lanes_of_shard, put_back) for a global id."""
        s, b = owner(block)
        sub, put = B.shard_slice(leaves[spec.leaf], meta, k, s)
        return s, b, B.to_lanes(sub, meta), (
            lambda lanes: put(B.from_lanes(lanes, meta)))

    if spec.kind == "data_bitflip":
        _, b, lanes, put = shard_lanes(spec.block)
        word = jnp.uint32(spec.payload) if spec.payload else (
            jnp.uint32(1) << jnp.uint32(spec.bit))
        lanes = lanes.at[b, spec.lane].set(lanes[b, spec.lane] ^ word)
        leaves[spec.leaf] = put(lanes)
    elif spec.kind == "checksum_bitflip":
        # Global checksums concatenate shard-locally, so the global block
        # id indexes the global array directly (owner() validates it).
        owner(spec.block)
        r = red[spec.leaf]
        red[spec.leaf] = dataclasses.replace(
            r, checksums=r.checksums.at[spec.block].set(
                r.checksums[spec.block] ^ jnp.uint32(spec.payload or (1 << spec.bit))))
    elif spec.kind == "parity_bitflip":
        owner(spec.block)
        r = red[spec.leaf]
        sid = B.global_stripe_id(meta, spec.block)
        red[spec.leaf] = dataclasses.replace(
            r, parity=r.parity.at[sid, spec.lane].set(
                r.parity[sid, spec.lane] ^ jnp.uint32(spec.payload or (1 << spec.bit))))
    elif spec.kind == "meta_bitflip":
        r = red[spec.leaf]
        word = jnp.uint32(spec.payload or (1 << spec.bit))
        if r.meta_ck.ndim:        # sharded: one meta checksum per shard
            s = owner(spec.block)[0] if spec.block >= 0 else 0
            mck = r.meta_ck.at[s].set(r.meta_ck[s] ^ word)
        else:
            mck = r.meta_ck ^ word
        red[spec.leaf] = dataclasses.replace(r, meta_ck=mck)
    elif spec.kind == "shard_loss":
        # Wholesale shard corruption: every lane of one shard's slice is
        # XOR-scribbled (``spec.block`` = shard index), redundancy left
        # untouched — the failure domain the online rebuild
        # (repro.scrub) recovers from via cross-shard parity.
        s = int(spec.block)
        if not 0 <= s < k:
            raise ValueError(
                f"{spec.leaf}: shard_loss addresses shard {s} but the leaf "
                f"has {k} shard(s)")
        sub, put = B.shard_slice(leaves[spec.leaf], meta, k, s)
        lanes = B.to_lanes(sub, meta)
        lanes = lanes ^ jnp.uint32(spec.payload or 0xA5A5A5A5)
        leaves[spec.leaf] = put(B.from_lanes(lanes, meta))
    elif spec.kind in ("mesh_shrink", "mesh_grow"):
        # Remesh failure domains (``spec.block`` = shard index).
        #   mesh_shrink: a departing shard's whole slice — data lanes AND
        #     its redundancy (checksums rows + meta checksum) — is
        #     XOR-scribbled; the shrink must re-stripe without it.
        #   mesh_grow: a joining shard has valid data but *zeroed*
        #     redundancy; the grow-side migration recomputes it wholesale.
        s = int(spec.block)
        if not 0 <= s < k:
            raise ValueError(
                f"{spec.leaf}: {spec.kind} addresses shard {s} but the leaf "
                f"has {k} shard(s)")
        r = red[spec.leaf]
        lo, hi = s * meta.n_blocks, (s + 1) * meta.n_blocks
        word = jnp.uint32(spec.payload or 0xA5A5A5A5)
        if spec.kind == "mesh_shrink":
            sub, put = B.shard_slice(leaves[spec.leaf], meta, k, s)
            lanes = B.to_lanes(sub, meta) ^ word
            leaves[spec.leaf] = put(B.from_lanes(lanes, meta))
            cks = r.checksums.at[lo:hi].set(r.checksums[lo:hi] ^ word)
            mval = (r.meta_ck[s] if r.meta_ck.ndim else r.meta_ck) ^ word
        else:           # mesh_grow: redundancy-less arrival, data intact
            cks = r.checksums.at[lo:hi].set(jnp.uint32(0))
            mval = jnp.uint32(0)
        if r.meta_ck.ndim:
            mck = r.meta_ck.at[s].set(mval)
        else:
            mck = mval
        red[spec.leaf] = dataclasses.replace(r, checksums=cks, meta_ck=mck)
    elif spec.kind in ("torn_write", "stale_redundancy"):
        # Data changes land, the dirty marks do not: red is left untouched.
        seed = np.uint32(spec.payload or 0xD15EA5E)
        for gb in spec.touched_blocks:
            _, b, lanes, put = shard_lanes(gb)
            # Deterministic per-block garbage mixing special payloads — a
            # torn write is *partial*, so only a prefix of lanes flips.
            n = max(1, meta.lanes_per_block // 4)
            rng = np.random.default_rng(int(seed) + int(gb))
            vals = rng.integers(0, 2**32, size=n, dtype=np.uint32)
            kk = rng.integers(0, n + 1)
            vals[:kk] = SPECIAL_LANES[rng.integers(0, len(SPECIAL_LANES), size=kk)]
            lanes = lanes.at[b, :n].set(lanes[b, :n] ^ jnp.asarray(vals))
            leaves[spec.leaf] = put(lanes)
    else:  # pragma: no cover — guarded by FaultSpec.__post_init__
        raise AssertionError(spec.kind)
    return leaves, red


class FaultInjector:
    """Plans and applies deterministic fault sequences over a store.

    One generator (``numpy`` PCG64, seeded once) drives every placement
    decision; :meth:`plan` with the same seed and store geometry returns
    the same specs.  Every applied fault is recorded in :attr:`log` so the
    oracle can audit the run afterwards.
    """

    def __init__(self, store, seed: int = 0):
        self.store = store
        self.seed = int(seed)
        self.rng = np.random.default_rng(self.seed)
        self.log: List[FaultSpec] = []

    # ------------------------------------------------------------- planning
    def _leaf_names(self) -> List[str]:
        return sorted(self.store.protected_metas)

    def _factor(self, name: str) -> int:
        fn = getattr(self.store, "shard_factor", None)
        return int(fn(name)) if fn is not None else 1

    def _factors(self) -> Dict[str, int]:
        return {n: self._factor(n) for n in self.store.protected_metas}

    def plan(self, n: int, kinds: Sequence[str] = ("data_bitflip",),
             leaf: Optional[str] = None) -> List[FaultSpec]:
        """Draw ``n`` fault specs over the protected geometry.

        Placement is uniform over blocks/lanes/bits of the chosen leaf (or
        all protected leaves); ``torn_write`` draws 2-4 consecutive blocks
        spanning at least one stripe boundary when the leaf allows it.
        Sharded leaves are addressed in global block space: placement is
        uniform over every shard's blocks, and a torn run never crosses a
        shard boundary (shards are separate failure domains).
        """
        metas = self.store.protected_metas
        names = [leaf] if leaf is not None else self._leaf_names()
        out: List[FaultSpec] = []
        for _ in range(n):
            kind = str(self.rng.choice(list(kinds)))
            name = str(names[self.rng.integers(0, len(names))])
            meta = metas[name]
            k = self._factor(name)
            b = int(self.rng.integers(0, meta.n_blocks * k))
            lane = int(self.rng.integers(0, meta.lanes_per_block))
            bit = int(self.rng.integers(0, 32))
            payload = 0
            if self.rng.random() < 0.5:
                payload = int(SPECIAL_LANES[self.rng.integers(0, len(SPECIAL_LANES))])
            blocks: Tuple[int, ...] = ()
            if kind == "torn_write":
                width = int(self.rng.integers(2, 5))
                sw = meta.stripe_data_blocks
                base = (b // meta.n_blocks) * meta.n_blocks  # owning shard
                if meta.n_blocks > sw:
                    # Straddle a stripe boundary: pick a random non-zero
                    # stripe start B and begin the run 1..width-1 blocks
                    # before it, so the torn run always spans >= 2 stripes
                    # (shard-local ids, offset into the shard's block range).
                    bnd = sw * int(self.rng.integers(
                        1, (meta.n_blocks - 1) // sw + 1))
                    start = max(0, bnd - int(self.rng.integers(1, width)))
                else:   # single-stripe leaf: boundary impossible
                    start = int(self.rng.integers(
                        0, max(1, meta.n_blocks - width + 1)))
                blocks = tuple(
                    base + lb
                    for lb in range(start, min(start + width, meta.n_blocks)))
            elif kind == "stale_redundancy":
                blocks = (b,)
            out.append(FaultSpec(kind=kind, leaf=name, block=b, lane=lane,
                                 bit=bit, blocks=blocks, payload=payload))
        return out

    def plan_clean_blocks(self, red, n: int, kinds=("data_bitflip",),
                          ) -> List[FaultSpec]:
        """Like :meth:`plan` but place only on blocks *outside* the current
        vulnerability window (clean per ``dirty | shadow``) — at most one
        fault per stripe, so every planned fault is detectable AND
        parity-repairable by construction.  Returns possibly fewer than
        ``n`` specs when not enough clean stripes exist."""
        metas = self.store.protected_metas
        out: List[FaultSpec] = []
        used_stripes = set()
        window = {}
        for name, r in red.items():
            if name in metas:
                live = np.asarray(jax.device_get(
                    jnp.bitwise_or(r.dirty, r.shadow)))
                window[name] = bits_to_mask(live, metas[name].n_blocks,
                                            shards=self._factor(name))
        candidates = []
        for name, mask in window.items():
            clean = np.flatnonzero(~mask)
            for b in clean:
                candidates.append((name, int(b)))
        candidates = [candidates[i]
                      for i in self.rng.permutation(len(candidates))]
        for name, b in candidates:
            if len(out) >= n:
                break
            meta = metas[name]
            sid = (name, B.global_stripe_id(meta, b))
            if sid in used_stripes:
                continue
            used_stripes.add(sid)
            kind = str(self.rng.choice(list(kinds)))
            out.append(FaultSpec(
                kind=kind, leaf=name, block=b,
                lane=int(self.rng.integers(0, metas[name].lanes_per_block)),
                bit=int(self.rng.integers(0, 32)),
                blocks=(b,) if kind == "stale_redundancy" else ()))
        return out

    # ------------------------------------------------------------ injection
    def inject(self, leaves, red, spec: FaultSpec):
        """Apply one spec (records it in :attr:`log`)."""
        self.log.append(spec)
        return apply_fault(self.store.metas, leaves, red, spec,
                           factors=self._factors())

    def inject_many(self, leaves, red, specs: Sequence[FaultSpec]):
        for spec in specs:
            leaves, red = self.inject(leaves, red, spec)
        return leaves, red


def bits_to_mask(words: np.ndarray, n_bits: int, shards: int = 1) -> np.ndarray:
    """Host-side unpack of a packed uint32 bitvector (numpy mirror of
    :func:`repro.core.bits.unpack`).

    ``shards > 1``: ``words`` concatenates one bitvector per shard (each
    padded to whole uint32 words); the result is the global block-space
    mask of length ``shards * n_bits``.
    """
    shifts = np.arange(32, dtype=np.uint32)
    w = words.reshape(shards, -1)
    m = ((w[:, :, None] >> shifts[None, None, :]) & 1).astype(bool)
    return m.reshape(shards, -1)[:, :n_bits].reshape(-1)
