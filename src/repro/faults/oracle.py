"""Vulnerability-window oracle (paper §5 made executable).

The paper's delayed-coverage guarantee is conditional: a corruption is
*detectable* (and single-block corruptions *repairable*) iff it lands in a
block whose redundancy is fresh — i.e. outside the **vulnerability
window**.  The window at any instant is exactly the set of blocks marked in
``dirty | shadow``: epoch B marks (writes since the last consumed
snapshot), plus the epoch-A snapshot a still-in-flight overlapped update is
covering (``ProtectedStore`` keeps it in ``shadow`` until adoption).  The
freshness knob (``max_vulnerable_steps`` / ``_seconds``) bounds how long
any block may stay in that set.

This module computes the window from live state and audits a run:

* every injected corruption **outside** the window must be detected by
  scrub (100% detection), and
* scrub must report **nothing else** (zero false positives), and
* every *missed* corruption must lie **inside** the window (provably lost
  within the knob's bound — the paper's accepted loss mode).

Detection latencies measured against scheduled scrubs feed
:func:`repro.core.mttdl.mttdl_measured` so MTTDL is empirically grounded,
not closed-form-only.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Mapping, Optional, Sequence, Set, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .inject import FaultSpec, bits_to_mask

# Fault kinds that skew data vs redundancy of specific blocks — the kinds
# a *scrub* is responsible for catching.  Redundancy-side faults
# (checksum/parity/meta bitflips) are audited by verify_meta / repair
# verification instead.
DATA_FAULT_KINDS = ("data_bitflip", "torn_write", "stale_redundancy")


@dataclasses.dataclass
class VulnerabilityWindow:
    """Per-leaf block masks of the instantaneous vulnerability window."""
    blocks: Dict[str, np.ndarray]          # bool[n_blocks], True = vulnerable
    stripes: Dict[str, np.ndarray]         # bool[n_stripes]

    def contains(self, leaf: str, block: int) -> bool:
        return bool(self.blocks[leaf][block])

    def n_vulnerable_stripes(self) -> int:
        return int(sum(int(m.sum()) for m in self.stripes.values()))


def vulnerability_window(store, red) -> VulnerabilityWindow:
    """The exact current window from the epoch double-buffer state.

    ``dirty | shadow`` per protected leaf, unpacked host-side; the stripe
    view uses the same block->stripe reduction as Algorithm 1.  Sharded
    leaves unpack shard by shard into global block/stripe space (shard
    ``s``'s local block ``b`` at ``s * n_blocks + b`` — the injector's and
    scrub's addressing).
    """
    blocks: Dict[str, np.ndarray] = {}
    stripes: Dict[str, np.ndarray] = {}
    metas = store.protected_metas
    factor = getattr(store, "shard_factor", lambda n: 1)
    for name, meta in metas.items():
        r = red[name]
        k = int(factor(name))
        live = np.asarray(jax.device_get(jnp.bitwise_or(r.dirty, r.shadow)))
        bmask = bits_to_mask(live, meta.n_blocks, shards=k)
        blocks[name] = bmask
        padded = np.zeros((k, meta.padded_blocks), bool)
        padded[:, :meta.n_blocks] = bmask.reshape(k, meta.n_blocks)
        stripes[name] = padded.reshape(
            k * meta.n_stripes, meta.stripe_data_blocks).any(axis=1)
    return VulnerabilityWindow(blocks=blocks, stripes=stripes)


@dataclasses.dataclass
class OracleReport:
    """Audit result of one scrub against a set of injected faults."""
    detected: Dict[str, Set[int]]          # leaf -> blocks scrub flagged
    expected: Dict[str, Set[int]]          # injected data-faults outside window
    in_window: Dict[str, Set[int]]         # injected data-faults inside window
    false_positives: Dict[str, Set[int]]   # flagged but never injected
    missed: Dict[str, Set[int]]            # outside window but not flagged

    @property
    def ok(self) -> bool:
        return not any(self.false_positives.values()) and not any(
            self.missed.values())

    def summary(self) -> str:
        n = lambda d: sum(len(v) for v in d.values())
        return (f"detected={n(self.detected)} expected={n(self.expected)} "
                f"in_window={n(self.in_window)} "
                f"false_pos={n(self.false_positives)} missed={n(self.missed)}")


def _injected_blocks(specs: Sequence[FaultSpec]) -> Dict[str, Set[int]]:
    out: Dict[str, Set[int]] = {}
    for s in specs:
        if s.kind in DATA_FAULT_KINDS:
            out.setdefault(s.leaf, set()).update(s.touched_blocks)
    return out


def check_detection(store, leaves, red, specs: Sequence[FaultSpec],
                    window: Optional[VulnerabilityWindow] = None
                    ) -> OracleReport:
    """Scrub and audit: 100% detection outside the window, zero false
    positives, misses only inside the window.

    ``window`` defaults to the window at call time — pass the window
    snapshotted *at injection time* when the run kept mutating state
    between injection and scrub (blocks may have left the window since,
    which only makes detection easier, never harder).
    """
    if window is None:
        window = vulnerability_window(store, red)
    mm = store.scrub(leaves, red)
    detected = {name: set(np.flatnonzero(np.asarray(mask)).tolist())
                for name, mask in mm.items()}
    injected = _injected_blocks(specs)
    expected: Dict[str, Set[int]] = {}
    in_window: Dict[str, Set[int]] = {}
    for name, blks in injected.items():
        for b in blks:
            if window.contains(name, b):
                in_window.setdefault(name, set()).add(b)
            else:
                expected.setdefault(name, set()).add(b)
    false_positives = {
        name: blks - injected.get(name, set())
        for name, blks in detected.items() if blks - injected.get(name, set())}
    missed = {
        name: blks - detected.get(name, set())
        for name, blks in expected.items() if blks - detected.get(name, set())}
    return OracleReport(detected=detected, expected=expected,
                        in_window=in_window, false_positives=false_positives,
                        missed=missed)


# ------------------------------------------------------- detection latency
@dataclasses.dataclass
class DetectionRecord:
    """One injected corruption's life cycle against scheduled scrubs."""
    spec: FaultSpec
    injected_step: int
    detected_step: Optional[int] = None    # None = never detected (in window)
    in_window_at_injection: bool = False

    @property
    def latency_steps(self) -> Optional[int]:
        if self.detected_step is None:
            return None
        return self.detected_step - self.injected_step


def measure_detection_latency(store, drive,
                              inject_at: Mapping[int, Sequence[FaultSpec]],
                              steps: int, scrub_period: int
                              ) -> List[DetectionRecord]:
    """Drive a workload, injecting per ``inject_at[step]`` and recording the
    first scheduled scrub that flags each corrupted block.

    ``drive(step, leaves, red) -> (leaves, red)`` applies the workload's
    own write+tick for one step (scrubbing handled here so latencies are
    attributed exactly).  Returns one record per injected spec.
    """
    records: List[DetectionRecord] = []
    live: Dict[Tuple[str, int], DetectionRecord] = {}
    leaves, red = drive(0, None, None)       # step 0 = init convention
    for step in range(1, steps + 1):
        leaves, red = drive(step, leaves, red)
        for spec in inject_at.get(step, ()):
            window = vulnerability_window(store, red)
            leaves, red = store.inject(leaves, red, spec)
            rec = DetectionRecord(
                spec=spec, injected_step=step,
                in_window_at_injection=any(
                    window.contains(spec.leaf, b)
                    for b in spec.touched_blocks))
            records.append(rec)
            for b in spec.touched_blocks:
                live.setdefault((spec.leaf, b), rec)
        if scrub_period and step % scrub_period == 0:
            mm = store.scrub(leaves, red)
            for name, mask in mm.items():
                for b in np.flatnonzero(np.asarray(mask)).tolist():
                    rec = live.pop((name, int(b)), None)
                    if rec is not None and rec.detected_step is None:
                        rec.detected_step = step
    return records
