"""CI fault-injection battery:  ``python -m repro.faults [--smoke]``.

Five passes, each seeded and fully deterministic:

1. **Crash sweep** — enumerate every lifecycle phase the pipelined tick
   fires (speculative dispatch, coalesce/mid-flight, lazy adoption,
   forced resolve, blocking update, scrub, flush, …) and crash+restart at
   each one; every outcome must be bitwise-recoverable.
2. **Crash + corruption** — at a mid-flight crash point, corrupt one
   block outside the vulnerability window (must be parity-repaired on
   restore) and one inside it (loss must be provably within the window).
3. **Oracle** — scrub over injected single-stripe corruptions must detect
   100% outside the window with zero false positives, across >= 3 seeds.
4. **Patroller** — a bitflip injected into a settled store must be found
   by the background scrub patroller (repro.scrub, no scheduled scrub)
   within one sweep of quiet ticks, parity-repaired bitwise, and leave a
   clean store.
5. **Sharded** — the same oracle + a crash-point subset on a 2x2x2
   mesh-sharded store (8 forced host devices, spawned as a subprocess so
   ``XLA_FLAGS`` lands before the jax import): faults placed through
   global block geometry on non-zero shards must be detected by the
   owning shard's scrub, and mid-pipeline crashes must recover bitwise —
   plus a wholesale shard-loss case whose online rebuild from cross-shard
   parity must restore the lost shard bitwise while the store keeps
   ticking.

Exit status 1 on any violation, so ``scripts/ci.sh`` fails the build.
"""
from __future__ import annotations

import argparse
import os
import subprocess
import sys
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ProtectedStore, RedundancyPolicy

from .crashpoints import CrashPlan, CrashPointMachine
from .inject import FaultInjector, FaultSpec
from .oracle import check_detection, vulnerability_window

# The pipeline phases a sweep must prove crash-safe (acceptance
# criterion: speculative dispatch, mid-flight, lazy adoption, forced
# resolve — plus the classic flush/scrub/write points).  PR10 adds the
# off-thread dispatcher edges: the batch enqueue before the launch thread
# runs, and the join barrier right before a forced resolve.
REQUIRED_PHASES = ("dispatch", "coalesce", "adopt", "adopt_forced",
                   "dispatcher_enqueue", "dispatcher_join",
                   "on_write", "tick", "flush")


def _make_leaves():
    return {
        "w": jax.random.normal(jax.random.PRNGKey(0), (24, 200), jnp.float32),
        "e": jax.random.normal(jax.random.PRNGKey(1), (16, 64), jnp.bfloat16),
    }


def _make_store():
    # period 2 + a deadline of 3 + a scrub at step 5 exercises speculative
    # dispatch (step 2), coalescing while held in flight (step 4),
    # deadline+scrub-forced resolve (step 5) and lazy adoption (step 6).
    pol = RedundancyPolicy.single(
        "vilamb", period_steps=2, max_vulnerable_steps=3,
        lanes_per_block=128, work_queue_frac=0.5, async_tick=True,
        precompile=False)
    return ProtectedStore(pol).attach(_make_leaves())


def crash_sweep(seed: int, steps: int, tmp: str) -> int:
    machine = CrashPointMachine(
        _make_store, _make_leaves, tmp, seed=seed, steps=steps,
        scrub_every=5, hold_inflight_steps=(3, 4))
    outcomes = machine.sweep(require_phases=REQUIRED_PHASES)
    bad = [o for o in outcomes if not o.ok]
    byc = {}
    for o in outcomes:
        byc[o.classification] = byc.get(o.classification, 0) + 1
    print(f"  crash sweep seed={seed}: {len(outcomes)} crash points, "
          f"outcomes={byc}")
    for o in bad:
        print(f"    FAIL {o.plan.phase}#{o.plan.occurrence} step={o.step}: "
              f"{o.classification} diverged={o.diverged} "
              f"scrub_after={o.scrub_after_flush}")
    return len(bad)


def crash_with_corruption(seed: int, steps: int, tmp: str) -> int:
    """Corrupt the persisted state at a mid-flight crash: outside-window
    blocks must repair, inside-window blocks must be provably in-window."""
    machine = CrashPointMachine(
        _make_store, _make_leaves, f"{tmp}/fx", seed=seed, steps=steps,
        scrub_every=0, hold_inflight_steps=(3, 4))
    fired = machine.enumerate_phases()
    plans = [CrashPlan(p, o) for p, o in fired if p == "dispatch"]
    if not plans:
        print("  crash+corruption: no dispatch phase fired (workload bug)")
        return 1
    plan = plans[-1]
    probe = machine.run_crash(plan)            # learn the window at the crash
    fails = 0
    meta = machine._probe().protected_metas["w"]
    window_w = probe.window.get("w", set())
    clean = [b for b in range(meta.n_blocks)
             if b not in window_w
             and not any((b // meta.stripe_data_blocks)
                         == (v // meta.stripe_data_blocks)
                         for v in window_w)]
    if clean:
        out = machine.run_crash(plan, faults=(
            FaultSpec(kind="data_bitflip", leaf="w", block=clean[0],
                      lane=3, bit=7),))
        ok = out.classification == "recovered_bitwise"
        print(f"  crash+corruption outside window @{plan.phase}: "
              f"{out.classification} {'OK' if ok else 'FAIL'}")
        fails += 0 if ok else 1
    if window_w:
        b = sorted(window_w)[0]
        out = machine.run_crash(plan, faults=(
            FaultSpec(kind="data_bitflip", leaf="w", block=b, lane=3,
                      bit=7),))
        ok = out.ok
        print(f"  crash+corruption inside window @{plan.phase}: "
              f"{out.classification} {'OK' if ok else 'FAIL'}")
        fails += 0 if ok else 1
    return fails


def oracle_pass(seed: int, steps: int) -> int:
    store = _make_store()
    leaves = _make_leaves()
    inj = FaultInjector(store, seed=seed)
    rng = np.random.default_rng(seed)
    red = store.init(leaves)
    for step in range(1, steps + 1):
        rows = rng.choice(24, size=int(rng.integers(1, 4)), replace=False)
        idx = jnp.asarray(np.sort(rows))
        leaves = dict(leaves, w=leaves["w"].at[idx].add(0.5))
        ev = jnp.zeros((24,), bool).at[idx].set(True)
        red = store.on_write(red, events={"w": ev})
        red, _ = store.tick(leaves, red, step)
    # single-stripe corruptions outside the live window: all must detect
    specs = inj.plan_clean_blocks(red, n=5, kinds=("data_bitflip",
                                                   "stale_redundancy"))
    window = vulnerability_window(store, red)
    leaves2, red2 = inj.inject_many(leaves, red, specs)
    report = check_detection(store, leaves2, red2, specs, window=window)
    ok = report.ok and sum(len(v) for v in report.expected.values()) == len(
        {(s.leaf, b) for s in specs for b in s.touched_blocks})
    print(f"  oracle seed={seed}: {report.summary()} "
          f"{'OK' if ok else 'FAIL'}")
    return 0 if ok else 1


def patrol_pass(seed: int, steps: int) -> int:
    """Patroller detection leg: an injected bitflip on a settled store must
    be found by the background patrol (no scheduled scrub) within one
    sweep-ish of quiet ticks, repaired bitwise, and leave the store clean."""
    pol = RedundancyPolicy.single(
        "vilamb", period_steps=2, lanes_per_block=128, async_tick=True,
        patrol_bytes_per_tick=8 * 128 * 4, precompile=False)
    leaves = _make_leaves()
    store = ProtectedStore(pol).attach(leaves)
    rng = np.random.default_rng(seed)
    red = store.init(leaves)
    for step in range(1, steps + 1):
        rows = rng.choice(24, size=int(rng.integers(1, 4)), replace=False)
        idx = jnp.asarray(np.sort(rows))
        leaves = dict(leaves, w=leaves["w"].at[idx].add(0.5))
        ev = jnp.zeros((24,), bool).at[idx].set(True)
        red = store.on_write(red, events={"w": ev})
        red, _ = store.tick(leaves, red, step)
    red = store.flush(leaves, red, steps + 1)      # settle: V -> 0
    expected = {n: np.array(np.asarray(v)) for n, v in leaves.items()}
    blk = 5 + seed
    leaves, red = store.inject(leaves, red, FaultSpec(
        kind="data_bitflip", leaf="w", block=blk, lane=3, bit=7))
    step = steps + 2
    store.patroller.expect_injection("w", blk, step)
    # Latency bound: round-robin over both leaves, probe processed one
    # tick after dispatch -> ~2 ticks per window, plus repair pacing.
    # Probes only dispatch on quiet ticks and a probe result may take an
    # extra tick to land, so the exact latency jitters with dispatch/
    # resolver timing — budget two full sweeps plus slack, not one.
    nb = sum(store.protected_metas[n].n_blocks for n in ("w", "e"))
    budget = 4 * (nb // 8 + 2) + 16
    detected = repaired = False
    for _ in range(budget):
        red, rep = store.tick(leaves, red, step, scrub_period=0)
        step += 1
        if rep.repaired:
            leaves = dict(leaves, **rep.repaired)
            repaired = True
        if store.patroller.latencies:
            detected = True
        if detected and repaired:
            break
    clean = store.scrub_check(leaves, red) == 0
    bitwise = all(np.array_equal(np.asarray(leaves[n]).view(np.uint8),
                                 expected[n].view(np.uint8))
                  for n in expected)
    pat = store.patroller
    lat = pat.latency_stats(step_seconds=1.0)
    ok = detected and repaired and clean and bitwise
    diag = ("" if ok else
            f" [budget={budget} starved={pat.starved_ticks} "
            f"sweeps={dict(pat.sweeps)} scanned={pat.blocks_scanned} "
            f"probe_out={pat._probe is not None}]")
    print(f"  patrol seed={seed}: detected={detected} (latency "
          f"{lat['mean_s']:.0f} ticks) repaired={repaired} clean={clean} "
          f"bitwise={bitwise} {'OK' if ok else 'FAIL'}{diag}")
    return 0 if ok else 1


def sharded_child(seed: int, steps: int) -> int:
    """Runs inside the 8-device subprocess: sharded oracle + crash subset."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch.mesh import make_mesh

    mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
    specs = {"w": P(("pod", "data", "model"), None)}

    def make_leaves():
        w = jax.random.normal(jax.random.PRNGKey(0), (64, 2048), jnp.float32)
        return {"w": jax.device_put(w, NamedSharding(mesh, specs["w"]))}

    def make_store():
        # precompile=False: crash replays restore *unsharded* host arrays,
        # which the sharding-pinned AOT executables would reject.
        pol = RedundancyPolicy.single(
            "vilamb", period_steps=2, max_vulnerable_steps=3,
            lanes_per_block=128, work_queue_frac=0.5, async_tick=True,
            precompile=False)
        return ProtectedStore(pol, mesh=mesh).attach(make_leaves(),
                                                     specs=specs)

    fails = 0
    # -- oracle over global block geometry (multiple shards must be hit) --
    store = make_store()
    leaves = make_leaves()
    inj = FaultInjector(store, seed=seed)
    rng = np.random.default_rng(seed)
    red = store.init(leaves)
    for step in range(1, steps + 1):
        rows = rng.choice(64, size=int(rng.integers(1, 4)), replace=False)
        idx = jnp.asarray(np.sort(rows))
        leaves = dict(leaves, w=leaves["w"].at[idx].add(0.5))
        ev = jnp.zeros((64,), bool).at[idx].set(True)
        red = store.on_write(red, events={"w": ev})
        red, _ = store.tick(leaves, red, step)
    spec_list = inj.plan_clean_blocks(red, n=6, kinds=("data_bitflip",
                                                      "stale_redundancy"))
    nb = store.protected_metas["w"].n_blocks
    shards_hit = {s.block // nb for s in spec_list}
    window = vulnerability_window(store, red)
    leaves2, red2 = inj.inject_many(leaves, red, spec_list)
    report = check_detection(store, leaves2, red2, spec_list, window=window)
    ok = report.ok and len(shards_hit) > 1
    print(f"  sharded oracle seed={seed}: {report.summary()} "
          f"shards_hit={sorted(shards_hit)} {'OK' if ok else 'FAIL'}")
    fails += 0 if ok else 1
    # -- crash-point subset on the sharded overlap pipeline --
    with tempfile.TemporaryDirectory() as tmp:
        machine = CrashPointMachine(
            make_store, make_leaves, tmp, seed=seed, steps=steps,
            scrub_every=5, hold_inflight_steps=(3, 4))
        fired = machine.enumerate_phases()
        plans = []
        for ph in ("dispatch", "coalesce", "adopt", "adopt_forced",
                   "dispatcher_enqueue", "dispatcher_join", "flush"):
            occ = [o for p, o in fired if p == ph]
            if occ:
                plans.append(CrashPlan(ph, occ[-1]))
        for plan in plans:
            out = machine.run_crash(plan)
            print(f"  sharded crash @{plan.phase}#{plan.occurrence}: "
                  f"{out.classification} {'OK' if out.ok else 'FAIL'}")
            fails += 0 if out.ok else 1
    # -- wholesale shard loss: online rebuild from cross-shard parity --
    fails += sharded_rebuild_case(seed, steps, mesh, specs)
    return fails


def sharded_rebuild_case(seed, steps, mesh, specs) -> int:
    """One shard wiped wholesale must rebuild bitwise from the patroller's
    cross-shard parity while the store keeps ticking (no restore)."""
    from jax.sharding import NamedSharding

    pol = RedundancyPolicy.single(
        "vilamb", period_steps=2, lanes_per_block=128, async_tick=True,
        patrol_bytes_per_tick=32 * 128 * 4, precompile=False)
    w = jax.random.normal(jax.random.PRNGKey(seed), (64, 2048), jnp.float32)
    leaves = {"w": jax.device_put(w, NamedSharding(mesh, specs["w"]))}
    store = ProtectedStore(pol, mesh=mesh).attach(leaves,
                                                  specs={"w": specs["w"]})
    red = store.init(leaves)
    rng = np.random.default_rng(seed)
    step = 0
    for _ in range(3):
        rows = rng.choice(64, size=4, replace=False)
        idx = jnp.asarray(np.sort(rows))
        leaves = dict(leaves, w=leaves["w"].at[idx].add(0.5))
        ev = jnp.zeros((64,), bool).at[idx].set(True)
        red = store.on_write(red, events={"w": ev})
        red, _ = store.tick(leaves, red, step)
        step += 1
    red = store.flush(leaves, red, step)
    pat = store.patroller
    for _ in range(48):          # quiet sweeps until xpar covers the leaf
        red, _ = store.tick(leaves, red, step, scrub_period=0)
        step += 1
        xp = pat.xpar.get("w")
        # Probes racing the warm writes fail adoption (their slabs saw
        # live rows), so sweep counts under-promise: wait for coverage.
        if xp is not None and bool(xp.xvalid.all()):
            break
    else:
        print(f"  sharded shard-loss rebuild seed={seed}: xpar never "
              "covered the leaf FAIL")
        return 1
    expected = np.array(np.asarray(leaves["w"]))
    lost = 3
    leaves, red = store.inject(leaves, red, FaultSpec(
        kind="shard_loss", leaf="w", block=lost))
    store.declare_shard_lost("w", lost, red)
    status = None
    for _ in range(32):
        red, rep = store.tick(leaves, red, step, scrub_period=0)
        step += 1
        if rep.repaired:
            leaves = dict(leaves, **rep.repaired)
        if rep.rebuild is not None and rep.rebuild.done:
            status = rep.rebuild
            break
    red = store.flush(leaves, red, step)
    clean = store.scrub_check(leaves, red) == 0
    bitwise = np.array_equal(np.asarray(leaves["w"]), expected)
    ok = (status is not None and status.lost == 0 and clean and bitwise)
    print(f"  sharded shard-loss rebuild seed={seed}: "
          f"status={status} clean={clean} bitwise={bitwise} "
          f"{'OK' if ok else 'FAIL'}")
    return 0 if ok else 1


def sharded_pass(seed: int, steps: int) -> int:
    """Spawn the sharded battery under 8 forced host devices.

    ``XLA_FLAGS`` must be set before jax is imported, so this re-execs the
    module rather than re-configuring the already-initialized backend.
    """
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8")
    try:
        r = subprocess.run(
            [sys.executable, "-m", "repro.faults", "--sharded-child",
             "--seeds", str(seed), "--steps", str(steps)],
            env=env, capture_output=True, text=True, timeout=1800)
    except Exception as e:   # timeout/OSError: count it, keep the summary
        print(f"  sharded battery subprocess FAILED ({e!r})")
        return 1
    sys.stdout.write(r.stdout)
    if r.returncode != 0:
        sys.stdout.write(r.stderr[-4000:])
        print(f"  sharded battery subprocess FAILED (exit {r.returncode})")
        return 1
    return 0


def chaos_child(seed: int, smoke: bool) -> int:
    """Runs inside the 8-device subprocess: the full multi-storm soak
    (bitflips + straggler storm + crash + shard loss + mid-rebuild remesh
    under live traffic; see repro.faults.chaos)."""
    from .chaos import run_chaos_soak
    r = run_chaos_soak(seed, sharded=True, smoke=smoke, verbose=print)
    print(f"  chaos soak: {r.summary()}")
    return 0 if r.ok() else 1


def chaos_pass(seed: int, smoke: bool) -> int:
    """Spawn the chaos soak under 8 forced host devices (the shard-loss
    and remesh storm phases need a mesh; XLA_FLAGS must predate the jax
    import, so this re-execs the module)."""
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8")
    cmd = [sys.executable, "-m", "repro.faults", "--chaos-child",
           "--seeds", str(seed)]
    if smoke:
        cmd.append("--smoke")
    try:
        r = subprocess.run(cmd, env=env, capture_output=True, text=True,
                           timeout=1800)
    except Exception as e:
        print(f"  chaos soak subprocess FAILED ({e!r})")
        return 1
    sys.stdout.write(r.stdout)
    if r.returncode != 0:
        sys.stdout.write(r.stderr[-4000:])
        print(f"  chaos soak subprocess FAILED (exit {r.returncode})")
        return 1
    return 0


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--smoke", action="store_true",
                   help="CI budget: 1 crash-sweep seed, 3 oracle seeds")
    p.add_argument("--seeds", type=int, default=3)
    p.add_argument("--steps", type=int, default=6)
    p.add_argument("--no-sharded", action="store_true",
                   help="skip the multi-device (subprocess) battery")
    p.add_argument("--chaos", action="store_true",
                   help="run ONLY the chaos soak (seeded multi-storm run "
                        "under live traffic, 8 host devices)")
    p.add_argument("--sharded-child", action="store_true",
                   help=argparse.SUPPRESS)   # internal: runs in-process
    p.add_argument("--chaos-child", action="store_true",
                   help=argparse.SUPPRESS)   # internal: runs in-process
    args = p.parse_args(argv)

    if args.sharded_child:
        return sharded_child(args.seeds, args.steps)
    if args.chaos_child:
        return chaos_child(args.seeds, args.smoke)
    if args.chaos:
        t0 = time.time()
        print("== chaos soak (multi-storm, live traffic, 8 host devices) ==")
        fails = chaos_pass(args.seeds if args.seeds != 3 else 0, args.smoke)
        dt = time.time() - t0
        print(f"== chaos soak {'OK' if not fails else 'FAILED'} "
              f"in {dt:.1f}s ==")
        return 1 if fails else 0

    t0 = time.time()
    fails = 0
    sweep_seeds = 1 if args.smoke else args.seeds
    with tempfile.TemporaryDirectory() as tmp:
        print("== crash-point sweep ==")
        for seed in range(sweep_seeds):
            fails += crash_sweep(seed, args.steps, f"{tmp}/s{seed}")
        print("== crash + corruption ==")
        fails += crash_with_corruption(0, args.steps, tmp)
    print("== vulnerability-window oracle ==")
    for seed in range(max(args.seeds, 3)):
        fails += oracle_pass(seed, args.steps)
    print("== scrub patroller detection ==")
    for seed in range(1 if args.smoke else max(args.seeds, 2)):
        fails += patrol_pass(seed, args.steps)
    if not args.no_sharded:
        print("== sharded battery (2x2x2 mesh, 8 host devices) ==")
        fails += sharded_pass(0, args.steps)
    dt = time.time() - t0
    print(f"== fault battery {'OK' if not fails else f'FAILED ({fails})'} "
          f"in {dt:.1f}s ==")
    return 1 if fails else 0


if __name__ == "__main__":
    sys.exit(main())
