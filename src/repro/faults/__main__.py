"""CI fault-injection battery:  ``python -m repro.faults [--smoke]``.

Three passes, each seeded and fully deterministic:

1. **Crash sweep** — enumerate every lifecycle phase the pipelined tick
   fires (speculative dispatch, coalesce/mid-flight, lazy adoption,
   forced resolve, blocking update, scrub, flush, …) and crash+restart at
   each one; every outcome must be bitwise-recoverable.
2. **Crash + corruption** — at a mid-flight crash point, corrupt one
   block outside the vulnerability window (must be parity-repaired on
   restore) and one inside it (loss must be provably within the window).
3. **Oracle** — scrub over injected single-stripe corruptions must detect
   100% outside the window with zero false positives, across >= 3 seeds.

Exit status 1 on any violation, so ``scripts/ci.sh`` fails the build.
"""
from __future__ import annotations

import argparse
import sys
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ProtectedStore, RedundancyPolicy

from .crashpoints import CrashPlan, CrashPointMachine
from .inject import FaultInjector, FaultSpec
from .oracle import check_detection, vulnerability_window

# The PR3 pipeline phases a sweep must prove crash-safe (acceptance
# criterion: speculative dispatch, mid-flight, lazy adoption, forced
# resolve — plus the classic flush/scrub/write points).
REQUIRED_PHASES = ("dispatch", "coalesce", "adopt", "adopt_forced",
                   "on_write", "tick", "flush")


def _make_leaves():
    return {
        "w": jax.random.normal(jax.random.PRNGKey(0), (24, 200), jnp.float32),
        "e": jax.random.normal(jax.random.PRNGKey(1), (16, 64), jnp.bfloat16),
    }


def _make_store():
    # period 2 + a deadline of 3 + a scrub at step 5 exercises speculative
    # dispatch (step 2), coalescing while held in flight (step 4),
    # deadline+scrub-forced resolve (step 5) and lazy adoption (step 6).
    pol = RedundancyPolicy.single(
        "vilamb", period_steps=2, max_vulnerable_steps=3,
        lanes_per_block=128, work_queue_frac=0.5, async_tick=True,
        precompile=False)
    return ProtectedStore(pol).attach(_make_leaves())


def crash_sweep(seed: int, steps: int, tmp: str) -> int:
    machine = CrashPointMachine(
        _make_store, _make_leaves, tmp, seed=seed, steps=steps,
        scrub_every=5, hold_inflight_steps=(3, 4))
    outcomes = machine.sweep(require_phases=REQUIRED_PHASES)
    bad = [o for o in outcomes if not o.ok]
    byc = {}
    for o in outcomes:
        byc[o.classification] = byc.get(o.classification, 0) + 1
    print(f"  crash sweep seed={seed}: {len(outcomes)} crash points, "
          f"outcomes={byc}")
    for o in bad:
        print(f"    FAIL {o.plan.phase}#{o.plan.occurrence} step={o.step}: "
              f"{o.classification} diverged={o.diverged} "
              f"scrub_after={o.scrub_after_flush}")
    return len(bad)


def crash_with_corruption(seed: int, steps: int, tmp: str) -> int:
    """Corrupt the persisted state at a mid-flight crash: outside-window
    blocks must repair, inside-window blocks must be provably in-window."""
    machine = CrashPointMachine(
        _make_store, _make_leaves, f"{tmp}/fx", seed=seed, steps=steps,
        scrub_every=0, hold_inflight_steps=(3, 4))
    fired = machine.enumerate_phases()
    plans = [CrashPlan(p, o) for p, o in fired if p == "dispatch"]
    if not plans:
        print("  crash+corruption: no dispatch phase fired (workload bug)")
        return 1
    plan = plans[-1]
    probe = machine.run_crash(plan)            # learn the window at the crash
    fails = 0
    meta = machine._probe().protected_metas["w"]
    window_w = probe.window.get("w", set())
    clean = [b for b in range(meta.n_blocks)
             if b not in window_w
             and not any((b // meta.stripe_data_blocks)
                         == (v // meta.stripe_data_blocks)
                         for v in window_w)]
    if clean:
        out = machine.run_crash(plan, faults=(
            FaultSpec(kind="data_bitflip", leaf="w", block=clean[0],
                      lane=3, bit=7),))
        ok = out.classification == "recovered_bitwise"
        print(f"  crash+corruption outside window @{plan.phase}: "
              f"{out.classification} {'OK' if ok else 'FAIL'}")
        fails += 0 if ok else 1
    if window_w:
        b = sorted(window_w)[0]
        out = machine.run_crash(plan, faults=(
            FaultSpec(kind="data_bitflip", leaf="w", block=b, lane=3,
                      bit=7),))
        ok = out.ok
        print(f"  crash+corruption inside window @{plan.phase}: "
              f"{out.classification} {'OK' if ok else 'FAIL'}")
        fails += 0 if ok else 1
    return fails


def oracle_pass(seed: int, steps: int) -> int:
    store = _make_store()
    leaves = _make_leaves()
    inj = FaultInjector(store, seed=seed)
    rng = np.random.default_rng(seed)
    red = store.init(leaves)
    for step in range(1, steps + 1):
        rows = rng.choice(24, size=int(rng.integers(1, 4)), replace=False)
        idx = jnp.asarray(np.sort(rows))
        leaves = dict(leaves, w=leaves["w"].at[idx].add(0.5))
        ev = jnp.zeros((24,), bool).at[idx].set(True)
        red = store.on_write(red, events={"w": ev})
        red, _ = store.tick(leaves, red, step)
    # single-stripe corruptions outside the live window: all must detect
    specs = inj.plan_clean_blocks(red, n=5, kinds=("data_bitflip",
                                                   "stale_redundancy"))
    window = vulnerability_window(store, red)
    leaves2, red2 = inj.inject_many(leaves, red, specs)
    report = check_detection(store, leaves2, red2, specs, window=window)
    ok = report.ok and sum(len(v) for v in report.expected.values()) == len(
        {(s.leaf, b) for s in specs for b in s.touched_blocks})
    print(f"  oracle seed={seed}: {report.summary()} "
          f"{'OK' if ok else 'FAIL'}")
    return 0 if ok else 1


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--smoke", action="store_true",
                   help="CI budget: 1 crash-sweep seed, 3 oracle seeds")
    p.add_argument("--seeds", type=int, default=3)
    p.add_argument("--steps", type=int, default=6)
    args = p.parse_args(argv)

    t0 = time.time()
    fails = 0
    sweep_seeds = 1 if args.smoke else args.seeds
    with tempfile.TemporaryDirectory() as tmp:
        print("== crash-point sweep ==")
        for seed in range(sweep_seeds):
            fails += crash_sweep(seed, args.steps, f"{tmp}/s{seed}")
        print("== crash + corruption ==")
        fails += crash_with_corruption(0, args.steps, tmp)
    print("== vulnerability-window oracle ==")
    for seed in range(max(args.seeds, 3)):
        fails += oracle_pass(seed, args.steps)
    dt = time.time() - t0
    print(f"== fault battery {'OK' if not fails else f'FAILED ({fails})'} "
          f"in {dt:.1f}s ==")
    return 1 if fails else 0


if __name__ == "__main__":
    sys.exit(main())
