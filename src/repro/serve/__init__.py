from .serve_loop import Server, make_decode_step, make_prefill
