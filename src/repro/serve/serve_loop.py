"""Serving loop: batched prefill + decode with Vilamb-protected KV caches.

In serving, params are immutable (redundancy computed once at load); the
*KV cache* is the hot, sparsely-written state — each decode step dirties one
page per layer, the closest analogue of the paper's cache-line writes to DAX
pages. Recurrent-state caches (mamba/xlstm) rewrite wholesale and are marked
ALL-dirty.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.common import flatten_dict, unflatten_dict
from repro.core import policy
from repro.core.engine import ALL, RedundancyEngine


def make_prefill(model, max_len: int) -> Callable:
    def prefill(params, batch):
        return model.prefill(params, batch, max_len)
    return prefill


def make_decode_step(model, engine: Optional[RedundancyEngine] = None,
                     mode: str = "none") -> Callable:
    """decode_step(params, caches, red, token, pos) -> (logits, caches, red, next)."""

    def decode_step(params, caches, red, token, pos):
        logits, new_caches, next_token, _ = model.decode_step(params, caches, token, pos)
        if engine is not None:
            events = model.dirty_events_decode(new_caches, pos)
            if mode == "vilamb":
                red = engine.mark_dirty(red, events)
            elif mode == "sync":
                old = flatten_dict(caches)
                new = flatten_dict(new_caches)
                red = engine.sync_update(old, new, red)
        return logits, new_caches, red, next_token

    return decode_step


@dataclasses.dataclass
class Server:
    model: Any
    engine: Optional[RedundancyEngine] = None
    mode: str = "none"
    period_steps: int = 64
    max_len: int = 2048

    def __post_init__(self):
        self.prefill = jax.jit(make_prefill(self.model, self.max_len))
        self.decode = jax.jit(
            make_decode_step(self.model, self.engine, self.mode),
            donate_argnums=(1, 2))
        if self.engine is not None:
            self._red_step = jax.jit(
                lambda caches, red: self.engine.redundancy_step(flatten_dict(caches), red),
                donate_argnums=(1,))
            self._scrub = jax.jit(
                lambda caches, red: self.engine.scrub(flatten_dict(caches), red))

    def init_redundancy(self, caches):
        if self.engine is None:
            return {}
        return self.engine.init(flatten_dict(caches))

    def generate(self, params, batch, n_tokens: int,
                 scrub_every: int = 0) -> Tuple[jax.Array, Dict[str, Any]]:
        """Prefill then decode n_tokens greedily; returns (tokens, stats)."""
        logits, caches, pos = self.prefill(params, batch)
        red = self.init_redundancy(caches)
        token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        out = [token]
        mismatches = 0
        for t in range(n_tokens - 1):
            logits, caches, red, token = self.decode(params, caches, red, token, pos + t)
            out.append(token)
            if (self.engine is not None and self.mode == "vilamb"
                    and policy.should_update(t + 1, self.period_steps)):
                red = self._red_step(caches, red)
            if self.engine is not None and scrub_every and (t + 1) % scrub_every == 0:
                mm = self._scrub(caches, red)
                mismatches += int(sum(int(v.sum()) for v in jax.tree.leaves(mm)))
        return jnp.stack(out, axis=1), {"mismatches": mismatches, "red": red,
                                        "caches": caches, "pos": pos + n_tokens - 1}
