"""Serving loop: batched prefill + decode with Vilamb-protected KV caches.

In serving, params are immutable (redundancy computed once at load); the
*KV cache* is the hot, sparsely-written state — each decode step dirties one
page per layer, the closest analogue of the paper's cache-line writes to DAX
pages.  Recurrent-state caches (mamba/xlstm) rewrite wholesale and are
marked ALL-dirty.

The redundancy lifecycle is owned by a :class:`repro.core.ProtectedStore`:
``decode_step`` records writes via ``store.on_write`` and the generate loop
heartbeats ``store.tick`` — the same scheduling code the Trainer uses, so
serve and train can no longer drift on step semantics.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.common import flatten_dict, unflatten_dict
from repro.core.engine import RedundancyEngine
from repro.core.store import ProtectedStore, as_store


def make_prefill(model, max_len: int) -> Callable:
    def prefill(params, batch):
        return model.prefill(params, batch, max_len)
    return prefill


def make_decode_step(model, store: Optional[Any] = None,
                     mode: Optional[str] = None) -> Callable:
    """decode_step(params, caches, red, token, pos) -> (logits, caches, red, next).

    ``store`` is a ProtectedStore (or, deprecated, a RedundancyEngine with
    ``mode``)."""
    store = as_store(store, mode, caller="make_decode_step")

    def decode_step(params, caches, red, token, pos):
        logits, new_caches, next_token, _ = model.decode_step(params, caches, token, pos)
        if store is not None and store.protects:
            old = new = None
            if store.has_sync:
                old = flatten_dict(caches)
                new = flatten_dict(new_caches)
            red = store.on_write(red, events=model.dirty_events_decode(new_caches, pos),
                                 old=old, new=new)
        return logits, new_caches, red, next_token

    return decode_step


@dataclasses.dataclass
class Server:
    model: Any
    store: Optional[ProtectedStore] = None
    engine: Optional[RedundancyEngine] = None      # deprecated: use store=
    mode: Optional[str] = None                     # deprecated: use store=
    period_steps: int = 64
    max_len: int = 2048

    def __post_init__(self):
        if self.store is None and self.engine is not None:
            self.store = as_store(self.engine, self.mode or "vilamb",
                                  period_steps=self.period_steps,
                                  caller="Server")
        if self.store is not None and not self.store.protects:
            self.store = None
        self.prefill = jax.jit(make_prefill(self.model, self.max_len))
        self.decode = jax.jit(
            make_decode_step(self.model, self.store),
            donate_argnums=(1, 2))

    def init_redundancy(self, caches):
        if self.store is None:
            return {}
        return self.store.init(flatten_dict(caches))

    def read_verified(self, caches, red, name: str, blocks):
        """Degraded-mode read of cache blocks (flat-key ``name``).

        Delegates to :meth:`ProtectedStore.read_verified`: returns verified
        lane data per global block — reconstructing from parity or the
        active shard rebuild instead of serving stale or in-flight bytes —
        or raises :class:`repro.core.UnrecoverableReadError`."""
        if self.store is None:
            raise ValueError("Server has no ProtectedStore; "
                             "read_verified needs protected caches")
        return self.store.read_verified(flatten_dict(caches), red, name, blocks)

    def generate(self, params, batch, n_tokens: int,
                 scrub_every: Optional[int] = None
                 ) -> Tuple[jax.Array, Dict[str, Any]]:
        """Prefill then decode n_tokens greedily; returns (tokens, stats).

        The store's tick owns update + scrub cadence; ``scrub_every``
        overrides the policy scrub period for this call (legacy knob):
        ``None`` defers to the policy, ``0`` disables scrubbing.  Decode
        intervals feed the straggler governor, so a stalling host stretches
        the redundancy period here exactly as in training."""
        logits, caches, pos = self.prefill(params, batch)
        red = self.init_redundancy(caches)
        token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        out = [token]
        mismatches = 0
        remesh_status = None
        health_status = None
        health_actions = 0
        last = time.perf_counter()
        for t in range(n_tokens - 1):
            logits, caches, red, token = self.decode(params, caches, red, token, pos + t)
            out.append(token)
            if self.store is not None:
                c = caches
                red, report = self.store.tick(
                    lambda: flatten_dict(c), red, t + 1,
                    step_time=time.perf_counter() - last,
                    scrub_period=scrub_every)
                mismatches += report.mismatches
                if report.remesh is not None:
                    remesh_status = report.remesh
                if report.health is not None:
                    # Health-governor surface: the last tick's breaker
                    # states plus a cumulative escalation-action count for
                    # the whole generate call (SLO dashboards watch these).
                    health_status = report.health
                    health_actions += len(report.health.actions)
                if report.repaired:
                    # The scrub patroller repaired or rebuilt cache leaves
                    # (or a remesh migrated them onto the new mesh); decode
                    # must continue on the corrected/moved pages.
                    flat = flatten_dict(caches)
                    flat.update(report.repaired)
                    caches = unflatten_dict(flat)
                last = time.perf_counter()
        if self.store is not None:
            # Adopt any update still in flight from the overlap pipeline so
            # the returned redundancy state is settled for the caller.  The
            # settle also drains active rebuild/remesh windows; adopt any
            # leaves they repaired or moved.  The last decode tick ran at
            # step n_tokens - 1, so stamp the drain there (a stepless
            # settle would leave background status clocks ambiguous).
            red = self.store.settle(red, flatten_dict(caches),
                                    step=n_tokens - 1)
            moved = self.store.take_repaired()
            if moved:
                flat = flatten_dict(caches)
                flat.update(moved)
                caches = unflatten_dict(flat)
        return jnp.stack(out, axis=1), {"mismatches": mismatches, "red": red,
                                        "caches": caches, "pos": pos + n_tokens - 1,
                                        "remesh": remesh_status,
                                        "health": health_status,
                                        "health_actions": health_actions}
