from .adamw import AdamW
from .schedule import warmup_cosine
