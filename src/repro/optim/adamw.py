"""AdamW with global-norm clipping and row-sparse (lazy) updates.

Row-sparse semantics: leaves named in ``row_masks`` (embedding tables, MoE
expert slabs) only update rows the step actually touched — untouched rows
keep params/moments unchanged (lazy-Adam variant, standard for large
embedding tables). This is what makes Vilamb's dirty tracking *real* for
sparse substrates: an untouched expert slab is bit-identical across steps,
so its blocks stay clean (paper §3.2).

Moment dtype is configurable; the 400B-class archs use bf16 moments to fit
the v5e HBM budget (DESIGN.md §6).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Mapping, Optional

import jax
import jax.numpy as jnp

from repro.common import flatten_dict, unflatten_dict


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: Callable[[jax.Array], jax.Array]
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    moment_dtype: str = "float32"

    def init(self, params) -> Dict[str, Any]:
        zeros = lambda p: jnp.zeros(p.shape, jnp.dtype(self.moment_dtype))
        return {"m": jax.tree.map(zeros, params),
                "v": jax.tree.map(zeros, params),
                "count": jnp.zeros((), jnp.int32)}

    def update(
        self,
        grads,
        opt_state,
        params,
        row_masks: Optional[Mapping[str, jax.Array]] = None,
    ):
        """Returns (new_params, new_opt_state, grad_norm).

        Structure-preserving (empty subtrees survive — non-parametric norms
        have {} param dicts).
        """
        row_masks = dict(row_masks or {})
        count = opt_state["count"] + 1
        lr = self.lr(count)

        sq = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                 for g in jax.tree.leaves(grads))
        gnorm = jnp.sqrt(sq)
        scale = jnp.minimum(1.0, self.clip_norm / jnp.maximum(gnorm, 1e-12))
        bc1 = 1 - self.b1 ** count.astype(jnp.float32)
        bc2 = 1 - self.b2 ** count.astype(jnp.float32)

        def path_str(kp):
            parts = []
            for k in kp:
                parts.append(str(getattr(k, "key", getattr(k, "name", getattr(k, "idx", k)))))
            return "/".join(parts)

        def upd(kp, p, g, m0_, v0_):
            g = g.astype(jnp.float32) * scale
            m0 = m0_.astype(jnp.float32)
            v0 = v0_.astype(jnp.float32)
            m1 = self.b1 * m0 + (1 - self.b1) * g
            v1 = self.b2 * v0 + (1 - self.b2) * jnp.square(g)
            step_ = (m1 / bc1) / (jnp.sqrt(v1 / bc2) + self.eps)
            decay = self.weight_decay if p.ndim >= 2 else 0.0
            p1 = p.astype(jnp.float32) - lr * (step_ + decay * p.astype(jnp.float32))
            mask = row_masks.get(path_str(kp))
            if mask is not None:  # lazy rows: untouched rows bit-identical
                mb = mask.reshape(mask.shape + (1,) * (p.ndim - mask.ndim))
                p1 = jnp.where(mb, p1, p.astype(jnp.float32))
                m1 = jnp.where(mb, m1, m0)
                v1 = jnp.where(mb, v1, v0)
            return (p1.astype(p.dtype),
                    m1.astype(jnp.dtype(self.moment_dtype)),
                    v1.astype(jnp.dtype(self.moment_dtype)))

        triples = jax.tree_util.tree_map_with_path(
            upd, params, grads, opt_state["m"], opt_state["v"])
        is_triple = lambda x: isinstance(x, tuple) and len(x) == 3
        pick = lambda i: jax.tree.map(lambda t: t[i], triples, is_leaf=is_triple)
        return (
            pick(0),
            {"m": pick(1), "v": pick(2), "count": count},
            gnorm,
        )
