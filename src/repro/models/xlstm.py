"""xLSTM blocks: mLSTM (matrix memory, chunkwise-parallel) and sLSTM.

Faithful to arXiv:2405.04517's structure (mLSTM:sLSTM at 7:1, matrix memory
C = sum_t decay * k_t v_t^T read by queries, per-head scalar gates) with one
documented numerics simplification: input gates use sigmoid rather than exp,
bounding every decay/gate term in (0,1) so the chunkwise-parallel form needs
no running max stabilizer (DESIGN.md §6). Training uses chunkwise
parallelism (intra-chunk quadratic + inter-chunk recurrent state), decode is
the O(1) recurrent step — the pair is validated against each other in tests.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from .layers import dense_init
from .parallel import ParallelCtx, NO_PARALLEL


def mlstm_init(key, cfg, dtype=jnp.float32):
    d, H = cfg.d_model, cfg.n_heads
    ks = jax.random.split(key, 7)
    return {
        "wq": dense_init(ks[0], (d, d), in_axis=0, dtype=dtype),
        "wk": dense_init(ks[1], (d, d), in_axis=0, dtype=dtype),
        "wv": dense_init(ks[2], (d, d), in_axis=0, dtype=dtype),
        "wi": dense_init(ks[3], (d, H), in_axis=0, dtype=jnp.float32),
        "wf": dense_init(ks[4], (d, H), in_axis=0, dtype=jnp.float32),
        "f_bias": jnp.full((H,), 3.0),  # open forget gates at init
        "wo": dense_init(ks[5], (d, d), in_axis=0, dtype=dtype),
        "wout": dense_init(ks[6], (d, d), in_axis=0, dtype=dtype),
    }


slstm_init = mlstm_init  # same parameter family (scalar-memory variant)


def _heads(x, H):
    B, S, d = x.shape
    return x.reshape(B, S, H, d // H)


def mlstm_apply(params, x, cfg, ctx: ParallelCtx = NO_PARALLEL, chunk: int = 256):
    """Chunkwise-parallel mLSTM. x: (B,S,d) -> (B,S,d)."""
    B, S, d = x.shape
    H = cfg.n_heads
    hd = d // H
    dt_ = x.dtype
    q = _heads(x @ params["wq"].astype(dt_), H)
    k = _heads(x @ params["wk"].astype(dt_), H) / jnp.sqrt(float(hd)).astype(dt_)
    v = _heads(x @ params["wv"].astype(dt_), H)
    i = jax.nn.sigmoid((x @ params["wi"].astype(dt_)).astype(jnp.float32))   # (B,S,H)
    f = jax.nn.sigmoid((x @ params["wf"].astype(dt_)).astype(jnp.float32)
                       + params["f_bias"][None, None])
    o = jax.nn.sigmoid((x @ params["wo"].astype(dt_)).astype(jnp.float32))

    chunk = min(chunk, S)
    assert S % chunk == 0
    n_chunks = S // chunk

    def reshape_c(t):  # (B,S,...) -> (n_chunks, B, chunk, ...)
        return t.reshape(B, n_chunks, chunk, *t.shape[2:]).transpose(1, 0, 2, *range(3, t.ndim + 1))

    qs, ks_, vs = reshape_c(q), reshape_c(k), reshape_c(v)
    is_, fs, os_ = reshape_c(i), reshape_c(f), reshape_c(o)

    def step(carry, inp):
        C0, n0 = carry                                 # (B,H,hd,hd), (B,H,hd)
        qc, kc, vc, ic, fc, oc = inp
        qf = qc.astype(jnp.float32)
        kf = kc.astype(jnp.float32)
        vf = vc.astype(jnp.float32)
        logf = jnp.log(fc + 1e-12)                     # (B,C,H) in (-inf, 0)
        b = jnp.cumsum(logf, axis=1)                   # cumulative decay
        # inter-chunk: read decayed carried state
        decay_q = jnp.exp(b)                           # (B,C,H)
        h_inter = jnp.einsum("bchd,bhde->bche", qf * decay_q[..., None], C0)
        n_inter = jnp.einsum("bchd,bhd->bch", qf * decay_q[..., None], n0)
        # intra-chunk: masked quadratic with relative decay
        rel = b[:, :, None] - b[:, None, :]            # (B,Cq,Ck,H) log decay
        gate = jnp.exp(rel) * ic[:, None]              # * input gate at source
        causal = (jnp.arange(chunk)[:, None] >= jnp.arange(chunk)[None, :])
        gate = jnp.where(causal[None, :, :, None], gate, 0.0)
        scores = jnp.einsum("bchd,bkhd->bckh", qf, kf) * gate
        h_intra = jnp.einsum("bckh,bkhd->bchd", scores, vf)
        n_intra = jnp.sum(scores, axis=2)          # q_t . n_t (intra part)
        # normalizer (xLSTM: max(|n q|, 1))
        h = h_inter + h_intra
        n = jnp.abs(n_inter + n_intra)
        h = h / jnp.maximum(n, 1.0)[..., None]
        h = h.reshape(*h.shape[:2], -1) * oc      # (B,C,d) * per-channel o-gate
        # state update: C1 = exp(b_T) C0 + sum_s exp(b_T - b_s) i_s k_s v_s^T
        wdecay = jnp.exp(b[:, -1:, :] - b) * ic        # (B,C,H)
        C1 = (jnp.exp(b[:, -1])[..., None, None] * C0
              + jnp.einsum("bchd,bche->bhde", kf * wdecay[..., None], vf))
        n1 = (jnp.exp(b[:, -1])[..., None] * n0
              + jnp.sum(kf * wdecay[..., None], axis=1))
        return (C1, n1), h.astype(dt_)

    C0 = jnp.zeros((B, H, hd, hd), jnp.float32)
    n0 = jnp.zeros((B, H, hd), jnp.float32)
    step_fn = jax.checkpoint(step) if cfg.remat != "none" else step
    (CT, nT), hs = jax.lax.scan(step_fn, (C0, n0), (qs, ks_, vs, is_, fs, os_))
    h = hs.transpose(1, 0, 2, 3).reshape(B, S, d)
    return h @ params["wout"].astype(dt_), {"C": CT, "n": nT}


def mlstm_init_cache(cfg, batch: int):
    H, hd = cfg.n_heads, cfg.d_model // cfg.n_heads
    return {"C": jnp.zeros((batch, H, hd, hd), jnp.float32),
            "n": jnp.zeros((batch, H, hd), jnp.float32)}


def mlstm_decode_step(params, x, cfg, cache):
    """O(1) recurrent step. x: (B,1,d)."""
    B, _, d = x.shape
    H = cfg.n_heads
    hd = d // H
    dt_ = x.dtype
    xt = x[:, 0]
    q = (xt @ params["wq"].astype(dt_)).reshape(B, H, hd).astype(jnp.float32)
    k = (xt @ params["wk"].astype(dt_)).reshape(B, H, hd).astype(jnp.float32) / jnp.sqrt(float(hd))
    v = (xt @ params["wv"].astype(dt_)).reshape(B, H, hd).astype(jnp.float32)
    i = jax.nn.sigmoid((xt @ params["wi"].astype(dt_)).astype(jnp.float32))
    f = jax.nn.sigmoid((xt @ params["wf"].astype(dt_)).astype(jnp.float32)
                       + params["f_bias"][None])
    o = jax.nn.sigmoid((xt @ params["wo"].astype(dt_)).astype(jnp.float32))
    C = f[..., None, None] * cache["C"] + i[..., None, None] * k[..., :, None] * v[..., None, :]
    n = f[..., None] * cache["n"] + i[..., None] * k
    h = jnp.einsum("bhd,bhde->bhe", q, C)
    nq = jnp.abs(jnp.einsum("bhd,bhd->bh", q, n))
    h = h / jnp.maximum(nq, 1.0)[..., None]
    h = h.reshape(B, d) * o                       # per-channel o-gate
    out = (h.astype(dt_) @ params["wout"].astype(dt_))[:, None]
    return out, {"C": C, "n": n}


# --------------------------------------------------------------------- sLSTM
def slstm_apply(params, x, cfg, ctx: ParallelCtx = NO_PARALLEL, chunk: int = 256):
    """Scalar-memory sLSTM: strictly sequential scan (chunked for remat)."""
    B, S, d = x.shape
    H = cfg.n_heads
    dt_ = x.dtype
    z = jnp.tanh((x @ params["wq"].astype(dt_)).astype(jnp.float32))  # cell input
    i = jax.nn.sigmoid((x @ params["wi"].astype(dt_)).astype(jnp.float32))
    f = jax.nn.sigmoid((x @ params["wf"].astype(dt_)).astype(jnp.float32)
                       + params["f_bias"][None, None])
    o = jax.nn.sigmoid((x @ params["wo"].astype(dt_)).astype(jnp.float32))
    hd = d // H
    zh = z.reshape(B, S, H, hd)

    def cell(carry, inp):
        c0, n0 = carry                                  # (B,H,hd), (B,H)
        zt, it, ft = inp
        c1 = ft[..., None] * c0 + it[..., None] * zt
        n1 = ft * n0 + it
        return (c1, n1), (c1, n1)

    chunk = min(chunk, S)
    n_chunks = S // chunk

    def chunk_step(carry, inp):
        zc, ic, fc = inp                                # (B,chunk,...)
        (c1, n1), (cs, ns) = jax.lax.scan(
            cell, carry,
            (zc.transpose(1, 0, 2, 3), ic.transpose(1, 0, 2), fc.transpose(1, 0, 2)))
        return (c1, n1), (cs, ns)

    def reshape_c(t):
        return t.reshape(B, n_chunks, chunk, *t.shape[2:]).transpose(1, 0, 2, *range(3, t.ndim + 1))

    c0 = jnp.zeros((B, H, hd), jnp.float32)
    n0 = jnp.full((B, H), 1e-6, jnp.float32)
    step_fn = jax.checkpoint(chunk_step) if cfg.remat != "none" else chunk_step
    (cT, nT), (cs, ns) = jax.lax.scan(
        step_fn, (c0, n0),
        (reshape_c(zh), reshape_c(i.reshape(B, S, H)), reshape_c(f.reshape(B, S, H))))
    # cs: (n_chunks, chunk, B, H, hd) -> (B, S, H, hd)
    cs = cs.reshape(n_chunks * chunk, B, H, hd).transpose(1, 0, 2, 3)
    ns = ns.reshape(n_chunks * chunk, B, H).transpose(1, 0, 2)
    h = cs / jnp.maximum(jnp.abs(ns), 1.0)[..., None]
    h = h.reshape(B, S, d) * o
    return (h.astype(dt_)) @ params["wout"].astype(dt_), {"c": cT, "n": nT}


def slstm_init_cache(cfg, batch: int):
    H, hd = cfg.n_heads, cfg.d_model // cfg.n_heads
    return {"c": jnp.zeros((batch, H, hd), jnp.float32),
            "n": jnp.full((batch, H), 1e-6, jnp.float32)}


def slstm_decode_step(params, x, cfg, cache):
    B, _, d = x.shape
    H = cfg.n_heads
    hd = d // H
    dt_ = x.dtype
    xt = x[:, 0]
    z = jnp.tanh((xt @ params["wq"].astype(dt_)).astype(jnp.float32)).reshape(B, H, hd)
    i = jax.nn.sigmoid((xt @ params["wi"].astype(dt_)).astype(jnp.float32))
    f = jax.nn.sigmoid((xt @ params["wf"].astype(dt_)).astype(jnp.float32)
                       + params["f_bias"][None])
    o = jax.nn.sigmoid((xt @ params["wo"].astype(dt_)).astype(jnp.float32))
    c = f[..., None] * cache["c"] + i[..., None] * z
    n = f * cache["n"] + i
    h = c / jnp.maximum(jnp.abs(n), 1.0)[..., None]
    h = (h.reshape(B, d) * o).astype(dt_)
    out = (h @ params["wout"].astype(dt_))[:, None]
    return out, {"c": c, "n": n}
