"""Mamba (selective SSM) block for the jamba hybrid architecture.

Training/prefill uses a two-level scan: an outer ``lax.scan`` over sequence
chunks carrying the (B, d_inner, d_state) recurrent state, with a
within-chunk associative scan. The (B, chunk, d_inner, d_state) discretized
tensors are materialized only per chunk (rematerialized in the backward
pass), and d_inner is sharding-constrained onto the TP axis, keeping the
working set bounded — a pure-JAX stand-in for the fused Mamba kernel (the
paper under reproduction contributes no SSM kernel; see DESIGN.md §3).

Decode is the O(1) recurrent step over carried (ssm_state, conv_state).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .layers import dense_init
from .parallel import ParallelCtx, NO_PARALLEL
from jax.sharding import PartitionSpec as P


def mamba_init(key, cfg, dtype=jnp.float32):
    d, di, ds, dtr, dc = cfg.d_model, cfg.d_inner, cfg.d_state, cfg.dt_rank, cfg.d_conv
    ks = jax.random.split(key, 6)
    A = jnp.tile(jnp.arange(1, ds + 1, dtype=jnp.float32)[None, :], (di, 1))
    return {
        "in_proj": dense_init(ks[0], (d, 2 * di), in_axis=0, dtype=dtype),
        "conv_w": dense_init(ks[1], (dc, di), in_axis=0, dtype=dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": dense_init(ks[2], (di, dtr + 2 * ds), in_axis=0, dtype=dtype),
        "dt_proj": dense_init(ks[3], (dtr, di), in_axis=0, dtype=dtype),
        "dt_bias": jnp.log(jnp.expm1(jnp.full((di,), 0.01))).astype(jnp.float32),
        "A_log": jnp.log(A),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": dense_init(ks[4], (di, d), in_axis=0, dtype=dtype),
    }


def _causal_conv_chunk(x, conv_state, w, b):
    """Depthwise causal conv over a chunk. x: (B,C,di); conv_state: (B,dc-1,di)."""
    dc = w.shape[0]
    full = jnp.concatenate([conv_state, x], axis=1)            # (B, C+dc-1, di)
    out = sum(full[:, j:j + x.shape[1]] * w[j][None, None, :] for j in range(dc))
    new_state = full[:, -(dc - 1):] if dc > 1 else conv_state
    return out + b[None, None, :], new_state


def _ssm_chunk(xc, dt, Bc, Cc, A, D, h0):
    """Selective scan within one chunk via associative scan.

    xc,dt:(B,C,di)  Bc,Cc:(B,C,ds)  A:(di,ds)  h0:(B,di,ds)
    """
    Ab = jnp.exp(dt[..., None] * A[None, None])                 # (B,C,di,ds)
    Bx = (dt * xc)[..., None] * Bc[:, :, None, :]               # (B,C,di,ds)

    def combine(a, b):
        a_a, b_a = a
        a_b, b_b = b
        return a_a * a_b, b_a * a_b + b_b

    cumA, h_local = jax.lax.associative_scan(combine, (Ab, Bx), axis=1)
    h = h_local + cumA * h0[:, None]                            # (B,C,di,ds)
    y = jnp.einsum("bcds,bcs->bcd", h, Cc) + D[None, None] * xc
    return y, h[:, -1]


def mamba_apply(
    params, x, cfg, ctx: ParallelCtx = NO_PARALLEL, chunk: int = 128
) -> jax.Array:
    """Full-sequence mamba mixer. x: (B,S,d) -> (B,S,d)."""
    B, S, d = x.shape
    di, ds, dtr = cfg.d_inner, cfg.d_state, cfg.dt_rank
    dt_ = x.dtype
    xz = x @ params["in_proj"].astype(dt_)
    xz = ctx.constrain(xz, ctx.batch_spec, None, ctx.tp_axis)
    xs, z = jnp.split(xz, 2, axis=-1)

    chunk = min(chunk, S)
    assert S % chunk == 0
    n_chunks = S // chunk
    xs = xs.reshape(B, n_chunks, chunk, di).transpose(1, 0, 2, 3)
    zs = z.reshape(B, n_chunks, chunk, di).transpose(1, 0, 2, 3)

    A = -jnp.exp(params["A_log"])

    def step(carry, inp):
        h0, conv_state = carry
        xc, zc = inp
        xc, conv_state = _causal_conv_chunk(
            xc, conv_state, params["conv_w"].astype(dt_), params["conv_b"].astype(dt_))
        xc = jax.nn.silu(xc)
        proj = xc @ params["x_proj"].astype(dt_)               # (B,C,dtr+2ds)
        dt_r, Bc, Cc = jnp.split(proj, [dtr, dtr + ds], axis=-1)
        dt = jax.nn.softplus(
            (dt_r @ params["dt_proj"].astype(dt_)).astype(jnp.float32)
            + params["dt_bias"][None, None])
        y, h1 = _ssm_chunk(
            xc.astype(jnp.float32), dt, Bc.astype(jnp.float32),
            Cc.astype(jnp.float32), A, params["D"], h0)
        y = (y.astype(dt_) * jax.nn.silu(zc))
        return (h1, conv_state), y

    h0 = jnp.zeros((B, di, ds), jnp.float32)
    conv0 = jnp.zeros((B, cfg.d_conv - 1, di), dt_)
    step_fn = jax.checkpoint(step) if cfg.remat != "none" else step
    (hT, convT), ys = jax.lax.scan(step_fn, (h0, conv0), (xs, zs))
    y = ys.transpose(1, 0, 2, 3).reshape(B, S, di)
    y = ctx.constrain(y, ctx.batch_spec, None, ctx.tp_axis)
    return y @ params["out_proj"].astype(dt_), {"h": hT, "conv": convT}


def mamba_init_cache(cfg, batch: int, dtype=jnp.bfloat16):
    return {
        "h": jnp.zeros((batch, cfg.d_inner, cfg.d_state), jnp.float32),
        "conv": jnp.zeros((batch, cfg.d_conv - 1, cfg.d_inner), dtype),
    }


def mamba_decode_step(params, x, cfg, cache):
    """One-token recurrent step. x: (B,1,d)."""
    B = x.shape[0]
    di, ds, dtr = cfg.d_inner, cfg.d_state, cfg.dt_rank
    dt_ = x.dtype
    xz = x[:, 0] @ params["in_proj"].astype(dt_)               # (B, 2di)
    xs, z = jnp.split(xz, 2, axis=-1)
    full = jnp.concatenate([cache["conv"], xs[:, None]], axis=1)  # (B,dc,di)
    conv_new = full[:, 1:]
    w = params["conv_w"].astype(dt_)
    xc = jnp.sum(full * w[None], axis=1) + params["conv_b"].astype(dt_)
    xc = jax.nn.silu(xc)
    proj = xc @ params["x_proj"].astype(dt_)
    dt_r, Bc, Cc = jnp.split(proj, [dtr, dtr + ds], axis=-1)
    dt = jax.nn.softplus(
        (dt_r @ params["dt_proj"].astype(dt_)).astype(jnp.float32)
        + params["dt_bias"][None])
    A = -jnp.exp(params["A_log"])
    Ab = jnp.exp(dt[..., None] * A[None])                      # (B,di,ds)
    Bx = (dt * xc.astype(jnp.float32))[..., None] * Bc.astype(jnp.float32)[:, None, :]
    h = Ab * cache["h"] + Bx
    y = jnp.einsum("bds,bs->bd", h, Cc.astype(jnp.float32)) + params["D"][None] * xc.astype(jnp.float32)
    y = y.astype(dt_) * jax.nn.silu(z)
    out = (y @ params["out_proj"].astype(dt_))[:, None]
    return out, {"h": h, "conv": conv_new}
