"""GQA attention: statically-tiled causal (flash-semantics) + decode paths.

Long sequences use a trace-time tiled schedule: python loops over (q, kv)
tiles skip fully-masked tiles *at trace time*, so the compiled HLO contains
only the lower-triangle work (~half the FLOPs of a masked dense attention)
and never materializes the full S x S score matrix.

KV caches use a sequence-major layout ``(S_max, B, KV, hd)`` so that
(a) decode writes are a single leading-axis dynamic_update_slice, and
(b) Vilamb page-level dirty tracking maps pages to leading-axis rows
    (`core.blocks.row_block_mask`), exactly like the paper's page table.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .layers import apply_rope, dense_init
from .parallel import NO_PARALLEL, ParallelCtx

NEG_INF = -1e30


def _head_axis(ctx: ParallelCtx, n: int):
    return ctx.tp_axis if ctx.divides(n, ctx.tp_axis) else None


@jax.custom_vjp
def grad_cast(x):
    """Identity whose COTANGENT is cast back to the primal dtype.

    Attention keeps f32 score/normalizer accumulators (intentional); without
    a boundary the f32-ness propagates through dq/dk/dv into the projection
    transposes, turning every (B,S,d) gradient tensor and weight-grad
    all-reduce fp32 (2x wire bytes + 2x backward buffers). §Perf knob
    ``bf16_grad_boundaries``.
    """
    return x


def _gc_fwd(x):
    return x, jnp.zeros((0,), x.dtype)  # dtype token (residuals must be JAX types)


def _gc_bwd(token, g):
    return (g.astype(token.dtype),)


grad_cast.defvjp(_gc_fwd, _gc_bwd)


def attn_init(key, cfg, dtype=jnp.float32):
    d, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "wq": dense_init(k1, (d, H, hd), in_axis=0, dtype=dtype),
        "wk": dense_init(k2, (d, KV, hd), in_axis=0, dtype=dtype),
        "wv": dense_init(k3, (d, KV, hd), in_axis=0, dtype=dtype),
        "wo": dense_init(k4, (H, hd, d), in_axis=0, scale=1.0, dtype=dtype),
    }


def _qkv(params, x, cfg, positions, rope: bool = True):
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
    if rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def expand_kv(k, n_heads: int):
    """Broadcast GQA KV heads up to n_heads.

    Keeps every attention einsum on the H-sharded layout: reshaping a
    TP-sharded H dim into (KV, G) is inexpressible for GSPMD when
    KV < |model| and silently replicates q and the S^2 score tensors
    (tens of GB at jamba scale). Expanding the (small, replicated) k/v to H
    is a local slice per shard instead.
    """
    B, S, KV, hd = k.shape
    G = n_heads // KV
    return jnp.broadcast_to(k[:, :, :, None, :], (B, S, KV, G, hd)).reshape(
        B, S, n_heads, hd)


def _tile_attn(q, k, v, scale, mask=None):
    """One (q-tile, kv-tile) partial: returns (acc, lse-style m, l).

    q: (B,Sq,H,hd)  k,v: (B,Sk,H,hd) (KV already expanded to H).
    """
    s = jnp.einsum("bqhd,bshd->bhqs", q, k).astype(jnp.float32) * scale
    if mask is not None:
        s = jnp.where(mask, s, NEG_INF)
    m = jnp.max(s, axis=-1)                       # (B,H,Sq)
    p = jnp.exp(s - m[..., None])
    l = jnp.sum(p, axis=-1)
    acc = jnp.einsum("bhqs,bshd->bhqd", p.astype(v.dtype), v).astype(jnp.float32)
    return acc, m, l


def _merge(acc1, m1, l1, acc2, m2, l2):
    m = jnp.maximum(m1, m2)
    a1 = jnp.exp(m1 - m)
    a2 = jnp.exp(m2 - m)
    return (acc1 * a1[..., None] + acc2 * a2[..., None], m, l1 * a1 + l2 * a2)


def pick_tile(B: int, H: int, S: int, shards: int = 1,
              budget_bytes: int = 256 * 2**20) -> int:
    """Largest q/kv tile whose fp32 score block fits the per-chip budget."""
    for t in (4096, 2048, 1024, 512):
        if S % t == 0 and B * H * t * t * 4 // max(shards, 1) <= budget_bytes:
            return t
    return 512 if S % 512 == 0 else S


def causal_attention(
    params, x, cfg, positions=None, rope: bool = True, tile: int = 0,
    shards: int = 1, ctx: ParallelCtx = NO_PARALLEL,
) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    """Causal GQA over (B,S,d). Returns (out, (k, v)) for cache prefill."""
    B, S, d = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    G = H // KV
    if not tile:
        tile = pick_tile(B, H, S, shards)
    if positions is None:
        positions = jnp.arange(S)[None, :]
    q, k, v = _qkv(params, x, cfg, positions, rope)
    if getattr(cfg, "bf16_grad_boundaries", False):
        q, k, v = grad_cast(q), grad_cast(k), grad_cast(v)
    ha = _head_axis(ctx, H)
    if getattr(cfg, "attn_kv_gather_first", False):
        # §Perf: gather the RAW (kv-head) k/v over the SP-sharded seq dim
        # first — KV/H-fold fewer bytes than gathering the expanded tensors —
        # then expansion to H heads is a local slice under the head-sharded
        # constraint.
        k = ctx.constrain(k, ctx.batch_spec, None, None, None)
        v = ctx.constrain(v, ctx.batch_spec, None, None, None)
    ke = expand_kv(k, H)
    ve = expand_kv(v, H)
    # Pin the expanded-KV layout onto the TP axis: without the constraint
    # GSPMD resolves the broadcast-reshape as "replicated" and materializes
    # full-size q/k/v and S^2 score tensors per chip.
    q = ctx.constrain(q, ctx.batch_spec, None, ha, None)
    ke = ctx.constrain(ke, ctx.batch_spec, None, ha, None)
    ve = ctx.constrain(ve, ctx.batch_spec, None, ha, None)
    scale = 1.0 / math.sqrt(hd)

    if getattr(cfg, "use_flash_kernel", False):
        # Pallas flash kernel (forward-only): prefill/serving path. Keeps the
        # score tile in VMEM — the fix for the memory-bound prefill cells
        # (§Roofline). Training keeps the differentiable jnp path.
        from repro.kernels.flash_attn.ops import flash_attention
        out = flash_attention(q, ke, ve, causal=True,
                              interpret=jax.default_backend() == "cpu")
        return jnp.einsum("bshk,hkd->bsd", out.astype(x.dtype), params["wo"]), (k, v)

    if S <= tile:
        mask = (jnp.arange(S)[:, None] >= jnp.arange(S)[None, :])[None, None]
        acc, m, l = _tile_attn(q, ke, ve, scale, mask)
        out = acc / jnp.maximum(l[..., None], 1e-30)
    else:
        assert S % tile == 0
        nt = S // tile
        outs = []
        for i in range(nt):                      # static schedule
            qi = q[:, i * tile:(i + 1) * tile]
            acc = m = l = None
            for j in range(i + 1):               # lower triangle only
                kj = ke[:, j * tile:(j + 1) * tile]
                vj = ve[:, j * tile:(j + 1) * tile]
                mask = None
                if j == i:                        # diagonal tile: causal mask
                    mask = (jnp.arange(tile)[:, None] >= jnp.arange(tile)[None, :])[None, None]
                a2, m2, l2 = _tile_attn(qi, kj, vj, scale, mask)
                if acc is None:
                    acc, m, l = a2, m2, l2
                else:
                    acc, m, l = _merge(acc, m, l, a2, m2, l2)
            outs.append(acc / jnp.maximum(l[..., None], 1e-30))
        out = jnp.concatenate(outs, axis=2)      # (B,H,S,hd)

    out = out.transpose(0, 2, 1, 3).astype(x.dtype)  # (B,S,H,hd)
    return jnp.einsum("bshk,hkd->bsd", out, params["wo"]), (k, v)


def full_attention(params, x, cfg, kv_x=None, rope: bool = False,
                   ctx: ParallelCtx = NO_PARALLEL):
    """Non-causal (encoder / cross) attention. kv_x defaults to x."""
    B, S, d = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    src = x if kv_x is None else kv_x
    pos_q = jnp.arange(S)[None, :]
    pos_k = jnp.arange(src.shape[1])[None, :]
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k = jnp.einsum("bsd,dhk->bshk", src, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", src, params["wv"])
    if rope:
        q = apply_rope(q, pos_q, cfg.rope_theta)
        k = apply_rope(k, pos_k, cfg.rope_theta)
    ha = _head_axis(ctx, H)
    q = ctx.constrain(q, ctx.batch_spec, None, ha, None)
    ke = ctx.constrain(expand_kv(k, H), ctx.batch_spec, None, ha, None)
    ve = ctx.constrain(expand_kv(v, H), ctx.batch_spec, None, ha, None)
    acc, m, l = _tile_attn(q, ke, ve, 1.0 / math.sqrt(hd))
    out = (acc / jnp.maximum(l[..., None], 1e-30))
    out = out.transpose(0, 2, 1, 3).astype(x.dtype)  # (B,S,H,hd)
    return jnp.einsum("bshk,hkd->bsd", out, params["wo"]), (k, v)


# ------------------------------------------------------------------ decode
def decode_attention(
    params, x, cfg, k_cache, v_cache, pos, rope: bool = True, cross: bool = False
):
    """One-token decode. x: (B,1,d); caches: (S_max, B, KV, hd) seq-major.

    Returns (out, new_k_cache, new_v_cache). For cross attention the caches
    are the precomputed encoder memory and are not updated.
    """
    B = x.shape[0]
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    G = H // KV
    S_max = k_cache.shape[0]
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    if rope:
        q = apply_rope(q, jnp.full((B, 1), pos), cfg.rope_theta)
    qg = q.reshape(B, KV, G, hd)

    if not cross:
        k_new = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
        v_new = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
        if rope:
            k_new = apply_rope(k_new, jnp.full((B, 1), pos), cfg.rope_theta)
        k_cache = jax.lax.dynamic_update_slice_in_dim(
            k_cache, k_new.astype(k_cache.dtype).transpose(1, 0, 2, 3), pos, axis=0)
        v_cache = jax.lax.dynamic_update_slice_in_dim(
            v_cache, v_new.astype(v_cache.dtype).transpose(1, 0, 2, 3), pos, axis=0)
        valid = jnp.arange(S_max) <= pos
    else:
        valid = jnp.arange(S_max) < S_max  # full encoder memory

    s = jnp.einsum("bkgd,sbkd->bkgs", qg, k_cache).astype(jnp.float32)
    s = s / math.sqrt(hd)
    s = jnp.where(valid[None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,sbkd->bkgd", p.astype(v_cache.dtype), v_cache)
    out = out.reshape(B, 1, H, hd).astype(x.dtype)
    return jnp.einsum("bshk,hkd->bsd", out, params["wo"]), k_cache, v_cache
