"""Shared layer primitives: norms, RoPE, dense FFNs, initializers."""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


def dense_init(key, shape, in_axis: int = -2, scale: float = 1.0, dtype=jnp.float32):
    """Truncated-normal fan-in init."""
    fan_in = shape[in_axis] if len(shape) > 1 else shape[0]
    std = scale / math.sqrt(fan_in)
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * std).astype(dtype)


def embed_init(key, shape, dtype=jnp.float32):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


# ----------------------------------------------------------------- norms
# Reductions run in fp32 (stability); the (B,S,d)-sized products stay in the
# input dtype. A full-fp32 norm keeps fp32 activation/cotangent copies of the
# entire residual stream alive through the backward pass (gigabytes/layer at
# jamba scale).
def rmsnorm(x, scale, eps: float = 1e-6):
    # f32 accumulation WITHOUT materializing a converted copy of x: the
    # einsum accumulates bf16 inputs into an f32 (B,S) result directly.
    sq = jnp.einsum("...d,...d->...", x, x, preferred_element_type=jnp.float32)
    var = (sq / x.shape[-1])[..., None]
    inv = jax.lax.rsqrt(var + eps).astype(x.dtype)
    y = x * inv
    if scale is not None:
        y = y * (1.0 + scale).astype(x.dtype)
    return y


# Custom-VJP norms (§Perf): autodiff of the f32-accumulating variance einsum
# emits an fp32 (B,S,d) cotangent contribution that promotes the entire
# residual-stream gradient chain to fp32 (doubling every backward activation
# buffer and TP/DP collective). The hand-written backward keeps all
# (B,S,d)-sized tensors in the input dtype; only (B,S)-sized reductions are
# fp32. Enabled per-arch via ModelConfig.norm_vjp="custom".
import functools as _ft


def _f32_dot_last(a, b):
    return jnp.einsum("...d,...d->...", a, b, preferred_element_type=jnp.float32)


@_ft.partial(jax.custom_vjp, nondiff_argnums=(2,))
def rmsnorm_cv(x, scale, eps: float = 1e-6):
    return rmsnorm(x, scale, eps)


def _rms_fwd(x, scale, eps):
    var = (_f32_dot_last(x, x) / x.shape[-1])[..., None]
    inv = jax.lax.rsqrt(var + eps)                       # f32 (B,S,1)
    y = x * inv.astype(x.dtype)
    if scale is not None:
        y = y * (1.0 + scale).astype(x.dtype)
    return y, (x, scale, inv)


def _rms_bwd(eps, res, g):
    x, scale, inv = res
    d = x.shape[-1]
    s = (1.0 + scale).astype(g.dtype) if scale is not None else None
    gs = g * s if s is not None else g                    # bf16 (B,S,d)
    xhat = x * inv.astype(x.dtype)                        # bf16
    t = (_f32_dot_last(gs, x) / d)[..., None]             # f32 (B,S,1)
    dx = gs * inv.astype(g.dtype) - x * (t * inv ** 3).astype(g.dtype)
    dscale = None
    if scale is not None:
        dims = tuple(range(g.ndim - 1))
        dscale = jnp.sum((g * xhat).astype(jnp.float32), axis=dims).astype(scale.dtype)
    return dx, dscale


rmsnorm_cv.defvjp(_rms_fwd, _rms_bwd)


@_ft.partial(jax.custom_vjp, nondiff_argnums=(3,))
def layernorm_cv(x, scale, bias, eps: float = 1e-5):
    return layernorm(x, scale, bias, eps)


def _ln_fwd(x, scale, bias, eps):
    xf32_mean = (jnp.einsum("...d->...", x, preferred_element_type=jnp.float32)
                 / x.shape[-1])[..., None]
    var = (_f32_dot_last(x, x) / x.shape[-1])[..., None] - jnp.square(xf32_mean)
    inv = jax.lax.rsqrt(jnp.maximum(var, 0.0) + eps)      # f32 (B,S,1)
    xhat = (x - xf32_mean.astype(x.dtype)) * inv.astype(x.dtype)
    y = xhat
    if scale is not None:
        y = y * scale.astype(x.dtype) + bias.astype(x.dtype)
    return y, (xhat, scale, inv)


def _ln_bwd(eps, res, g):
    xhat, scale, inv = res
    d = xhat.shape[-1]
    gs = g * scale.astype(g.dtype) if scale is not None else g
    m1 = (jnp.einsum("...d->...", gs, preferred_element_type=jnp.float32) / d)[..., None]
    m2 = (_f32_dot_last(gs, xhat) / d)[..., None]
    dx = inv.astype(g.dtype) * (gs - m1.astype(g.dtype)
                                - xhat * m2.astype(g.dtype))
    dscale = dbias = None
    if scale is not None:
        dims = tuple(range(g.ndim - 1))
        dscale = jnp.sum((g * xhat).astype(jnp.float32), axis=dims).astype(scale.dtype)
        dbias = jnp.sum(g.astype(jnp.float32), axis=dims).astype(scale.dtype)
    return dx, dscale, dbias


layernorm_cv.defvjp(_ln_fwd, _ln_bwd)


def layernorm(x, scale, bias, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True) - jnp.square(mu)
    inv = jax.lax.rsqrt(jnp.maximum(var, 0.0) + eps)
    y = (x - mu.astype(x.dtype)) * inv.astype(x.dtype)
    if scale is not None:
        y = y * scale.astype(x.dtype) + bias.astype(x.dtype)
    return y


def nonparam_ln(x, eps: float = 1e-5):
    """OLMo's non-parametric LayerNorm (no scale/bias)."""
    return layernorm(x, None, None, eps)


def make_norm(cfg):
    kind = cfg.norm
    custom = getattr(cfg, "norm_vjp", "autodiff") == "custom"

    def init(key, d):
        if kind == "nonparam_ln":
            return {}
        if kind == "layernorm":
            return {"scale": jnp.ones((d,), jnp.float32),
                    "bias": jnp.zeros((d,), jnp.float32)}
        return {"scale": jnp.zeros((d,), jnp.float32)}  # rms, (1+scale) form

    def apply(params, x):
        if kind == "nonparam_ln":
            return layernorm_cv(x, None, None) if custom else nonparam_ln(x)
        if kind == "layernorm":
            if custom:
                return layernorm_cv(x, params["scale"], params["bias"])
            return layernorm(x, params["scale"], params["bias"])
        if custom:
            return rmsnorm_cv(x, params["scale"])
        return rmsnorm(x, params["scale"])

    return init, apply


# ----------------------------------------------------------------- RoPE
def rope_freqs(hd: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, hd) or (..., H, hd) with positions broadcastable.

    Angles/cos/sin are f32 (small, (S, hd/2)); the rotation itself runs in
    the input dtype — converting q/k to f32 here puts an f32 copy of every
    attention input on the sequence-parallel all-gather path (2x wire bytes;
    EXPERIMENTS.md §Perf A3).
    """
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(ang)[..., None, :].astype(x.dtype)
    sin = jnp.sin(ang)[..., None, :].astype(x.dtype)
    x1, x2 = jnp.split(x, 2, axis=-1)
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1, y2], axis=-1)


# ----------------------------------------------------------------- FFN
def ffn_init(key, cfg, d_ff: Optional[int] = None, dtype=jnp.float32):
    d = cfg.d_model
    ff = d_ff or cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    if cfg.activation == "swiglu":
        return {"wi": dense_init(k1, (d, ff), dtype=dtype),
                "wg": dense_init(k2, (d, ff), dtype=dtype),
                "wo": dense_init(k3, (ff, d), dtype=dtype)}
    return {"wi": dense_init(k1, (d, ff), dtype=dtype),
            "wo": dense_init(k3, (ff, d), dtype=dtype)}


def ffn_apply(params, x, cfg):
    if cfg.activation == "swiglu":
        h = jax.nn.silu(x @ params["wg"]) * (x @ params["wi"])
    elif cfg.activation == "squared_relu":   # nemotron-4
        h = jnp.square(jax.nn.relu(x @ params["wi"]))
    else:  # gelu
        h = jax.nn.gelu(x @ params["wi"])
    return h @ params["wo"]
