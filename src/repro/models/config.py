"""Model configuration covering all assigned architecture families."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

VOCAB_PAD_MULTIPLE = 2048  # pad vocab so 16-way TP divides it cleanly


def pad_vocab(v: int, mult: int = VOCAB_PAD_MULTIPLE) -> int:
    return -(-v // mult) * mult


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | hybrid | ssm | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int

    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0           # expert hidden width (defaults to d_ff)
    moe_every: int = 1          # MoE FFN every k-th layer (jamba: 2)
    dense_residual: bool = False  # arctic: dense FFN in parallel with MoE
    capacity_factor: float = 1.25

    # --- hybrid (jamba): one attention layer per `attn_every` layers ---
    attn_every: int = 0

    # --- SSM ---
    ssm_kind: str = ""          # mamba | xlstm
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2             # mamba d_inner = expand * d_model
    slstm_every: int = 0        # xlstm: one sLSTM per k layers (7:1 ratio -> 8)

    # --- norm / activation / positions ---
    norm: str = "rmsnorm"       # rmsnorm | layernorm | nonparam_ln
    activation: str = "swiglu"  # swiglu | squared_relu | gelu
    rope_theta: float = 10000.0

    # --- structure ---
    enc_dec: bool = False       # seamless: n_layers encoder + n_layers decoder
    frontend: str = ""          # "" | vision | audio  (stubbed per assignment)
    frontend_len: int = 256     # patches/frames supplied by input_specs
    tie_embeddings: bool = False
    head_dim: int = 0           # 0 -> d_model // n_heads

    # --- numerics ---
    param_dtype: str = "bfloat16"
    moment_dtype: str = "float32"   # bf16 for the 400B+ archs (HBM budget)
    remat: str = "full"             # none | full
    # Unroll the layer scan at lowering time. Used by the dry-run: XLA cost
    # analysis counts while-loop bodies once, so scanned stacks under-report
    # FLOPs/bytes/collectives by ~n_groups; unrolling makes the compiled
    # artifact's cost analysis exact (inner SSM chunk scans remain, <6% of
    # FLOPs — see EXPERIMENTS.md §Dry-run notes).
    unroll_layers: bool = False
    # Perf knobs (hillclimbed in EXPERIMENTS.md §Perf).
    seq_parallel: bool = True   # Megatron-SP activation sharding over TP axis
    attn_tile: int = 0          # 0 = auto (pick_tile budget)
    norm_vjp: str = "autodiff"  # "custom" = hand-written bf16-cotangent VJP
    # Default ON after §Perf A5: gathering the raw (kv-head) k/v over the
    # SP seq dim before head expansion cut the collective term 22% and the
    # memory term 26% with no downside. (The §Roofline baseline table was
    # measured with the knob off; see §Perf for both.)
    attn_kv_gather_first: bool = True
    bf16_grad_boundaries: bool = False  # cast attention cotangents to bf16
    opt_grad_barrier: bool = False      # stop f32 converts hoisting past grad AR
    use_flash_kernel: bool = False      # Pallas flash attn (fwd-only; serving)

    # serving
    kv_page_tokens: int = 512   # dirty-tracking page granularity (tokens)

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        return pad_vocab(self.vocab_size)

    @property
    def d_inner(self) -> int:   # mamba inner width
        return self.expand * self.d_model

    @property
    def dt_rank(self) -> int:
        return max(1, self.d_model // 16)

    @property
    def expert_d_ff(self) -> int:
        return self.moe_d_ff or self.d_ff

    def layer_kind(self, i: int) -> str:
        """Mixer kind of layer i: attn | mamba | mlstm | slstm."""
        if self.ssm_kind == "xlstm":
            return "slstm" if (self.slstm_every and i % self.slstm_every == self.slstm_every - 1) else "mlstm"
        if self.attn_every:  # hybrid: 1 attention per attn_every layers
            return "attn" if i % self.attn_every == self.attn_every // 2 else "mamba"
        return "attn"

    def ffn_kind(self, i: int) -> str:
        """FFN kind of layer i: dense | moe | none (xlstm has no FFN)."""
        if self.ssm_kind == "xlstm":
            return "none"
        if self.n_experts and i % self.moe_every == self.moe_every - 1:
            return "moe"
        return "dense"

    @property
    def group_size(self) -> int:
        """Layers per scan group (pattern period)."""
        if self.ssm_kind == "xlstm":
            return self.slstm_every or 1
        p = 1
        if self.attn_every:
            p = self.attn_every
        if self.n_experts and self.moe_every > 1:
            import math
            p = p * self.moe_every // math.gcd(p, self.moe_every)
        return p

    @property
    def n_groups(self) -> int:
        assert self.n_layers % self.group_size == 0, (self.n_layers, self.group_size)
        return self.n_layers // self.group_size

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for the long_500k shape (SSM/hybrid)."""
        return self.ssm_kind != "" or self.attn_every > 0

    def param_count(self) -> int:
        """Analytic parameter count (for 6ND roofline + sanity checks)."""
        d, hd = self.d_model, self.hd
        total = 0
        emb = self.vocab_size * d
        total += emb if self.tie_embeddings else 2 * emb
        for i in range(self.n_layers):
            k = self.layer_kind(i)
            if k == "attn":
                total += d * self.n_heads * hd * 2          # q, o
                total += d * self.n_kv_heads * hd * 2       # k, v
            elif k == "mamba":
                di, ds, dtr = self.d_inner, self.d_state, self.dt_rank
                total += d * 2 * di + di * self.d_conv + di
                total += di * (dtr + 2 * ds) + dtr * di + di
                total += di * ds + di + di * d
            elif k in ("mlstm", "slstm"):
                total += 4 * d * d + 2 * d * self.n_heads + 2 * d
            f = self.ffn_kind(i)
            n_mats = 3 if self.activation == "swiglu" else 2
            if f == "dense":
                total += n_mats * d * self.d_ff
            elif f == "moe":
                total += self.n_experts * n_mats * d * self.expert_d_ff
                total += d * self.n_experts  # router
                if self.dense_residual:
                    total += n_mats * d * self.d_ff
            total += 2 * d if self.norm != "nonparam_ln" else 0
        if self.enc_dec:  # encoder stack + cross attention in decoder
            for i in range(self.n_layers):
                total += d * self.n_heads * hd * 2 + d * self.n_kv_heads * hd * 2
                n_mats = 3 if self.activation == "swiglu" else 2
                total += n_mats * d * self.d_ff
                total += d * self.n_heads * hd * 2 + d * self.n_kv_heads * hd * 2  # cross
        return total

    def active_param_count(self) -> int:
        """Active params per token (MoE top-k instead of all experts)."""
        if not self.n_experts:
            return self.param_count()
        d = self.d_model
        n_mats = 3 if self.activation == "swiglu" else 2
        per_expert = n_mats * d * self.expert_d_ff
        n_moe_layers = sum(1 for i in range(self.n_layers) if self.ffn_kind(i) == "moe")
        return (self.param_count()
                - n_moe_layers * self.n_experts * per_expert
                + n_moe_layers * self.top_k * per_expert)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""
    name: str                   # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str                   # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}
