"""Top-level Model: init / loss / prefill / decode, plus Vilamb dirty events.

``build_model(cfg, ctx)`` returns a Model whose pure functions are ready for
jit/pjit. The model also reports *dirty events* — which embedding rows, MoE
expert slabs, and KV pages a step touched — feeding the redundancy engine's
bitvectors (paper §3.2's dirty bits, generated at the writer; DESIGN.md §2.1).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import embed_init, make_norm
from .parallel import ParallelCtx, NO_PARALLEL
from . import transformer as tfm
from . import mamba as mamba_mod
from . import xlstm as xlstm_mod


import functools as _functools


@_functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def cross_entropy(logits, labels, vocab_size: int):
    """Masked CE. labels < 0 are ignored. logits: (B,S,Vpad) any float dtype.

    Two memory-critical choices (DESIGN.md §7):
      * the label score uses a one-hot einsum, NOT take_along_axis — a gather
        along a TP-sharded vocab dim makes GSPMD replicate the full fp32
        logits per chip (tens of GB);
      * a custom VJP emits the (B,S,V)-sized cotangent in the *logits dtype*
        (bf16), not fp32 — softmax-minus-onehot is exactly representable to
        bf16 rounding and halves the largest backward buffer, and keeps the
        LM-head weight-gradient matmul in bf16.
    """
    return _ce_fwd(logits, labels, vocab_size)[0]


def _ce_parts(logits, labels, vocab_size):
    lf = logits.astype(jnp.float32)
    vpad = lf.shape[-1]
    if vpad > vocab_size:  # mask padded vocab tail
        lf = jnp.where(jnp.arange(vpad) < vocab_size, lf, -1e30)
    m = jax.lax.stop_gradient(jnp.max(lf, axis=-1, keepdims=True))
    shifted = lf - m
    lse = jnp.log(jnp.sum(jnp.exp(shifted), axis=-1))
    onehot = jax.nn.one_hot(jnp.maximum(labels, 0), vpad, dtype=jnp.bfloat16)
    ll = jnp.einsum("bsv,bsv->bs", shifted, onehot,
                    preferred_element_type=jnp.float32)
    nll = lse - ll
    mask = (labels >= 0).astype(jnp.float32)
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.sum(nll * mask) / denom, (shifted, lse, mask, denom)


def _ce_fwd(logits, labels, vocab_size):
    loss, (shifted, lse, mask, denom) = _ce_parts(logits, labels, vocab_size)
    return loss, (logits, labels, lse, mask, denom)


def _ce_bwd(vocab_size, res, g):
    logits, labels, lse, mask, denom = res
    lf = logits.astype(jnp.float32)
    vpad = lf.shape[-1]
    if vpad > vocab_size:
        lf = jnp.where(jnp.arange(vpad) < vocab_size, lf, -1e30)
    m = jnp.max(lf, axis=-1, keepdims=True)
    p = jnp.exp(lf - m) / jnp.exp(lse[..., None])  # softmax from saved lse
    onehot = jax.nn.one_hot(jnp.maximum(labels, 0), vpad, dtype=jnp.float32)
    scale = (g * mask / denom)[..., None]
    dlogits = ((p - onehot) * scale).astype(logits.dtype)
    return (dlogits, None)


cross_entropy.defvjp(_ce_fwd, _ce_bwd)


@dataclasses.dataclass
class Model:
    cfg: ModelConfig
    ctx: ParallelCtx = NO_PARALLEL

    # ------------------------------------------------------------------ init
    def init(self, key) -> Dict[str, Any]:
        cfg = self.cfg
        dtype = jnp.dtype(cfg.param_dtype)
        ks = jax.random.split(key, 6)
        norm_init, _ = make_norm(cfg)
        params: Dict[str, Any] = {
            "embed": embed_init(ks[0], (cfg.padded_vocab, cfg.d_model), dtype),
            "final_norm": norm_init(ks[1], cfg.d_model),
            "stack": tfm.stack_init(ks[2], cfg, cfg.n_groups, dtype, cross=cfg.enc_dec),
        }
        if not cfg.tie_embeddings:
            params["head"] = embed_init(ks[3], (cfg.d_model, cfg.padded_vocab), dtype)
        if cfg.enc_dec:
            enc_cfg = dataclasses.replace(
                cfg, attn_every=0, ssm_kind="", n_experts=0, slstm_every=0)
            params["enc_stack"] = tfm.stack_init(
                ks[4], enc_cfg, enc_cfg.n_layers, dtype, cross=False)
            params["enc_final_norm"] = norm_init(ks[5], cfg.d_model)
        return params

    # ---------------------------------------------------------------- embed
    def _embed(self, params, tokens):
        """Vocab-sharded embedding lookup.

        A plain gather on a TP-sharded table makes GSPMD replicate the whole
        table per chip; instead each TP rank does a masked local lookup of
        its vocab shard and the shards combine with a psum (exact: one-hot).
        The FSDP-sharded feature dim is all-gathered per lookup (the same
        per-layer gather FSDP does for every weight).
        """
        cfg, ctx = self.cfg, self.ctx
        dtype = jnp.dtype(cfg.param_dtype)
        table = params["embed"]
        tp = ctx.tp_axis
        if (ctx.mesh is None or tp is None
                or cfg.padded_vocab % ctx.axis_size(tp)):
            return jnp.take(table, tokens, axis=0).astype(dtype)
        from .parallel import shard_map
        from jax.sharding import PartitionSpec as P
        import numpy as _np
        fsdp = ctx.fsdp_axis if ctx.divides(cfg.d_model, ctx.fsdp_axis) else None
        dp = ctx.batch_spec
        if dp is not None:
            k = int(_np.prod([ctx.axis_size(a) for a in ctx.dp_axes]))
            if tokens.shape[0] % max(k, 1):
                dp = None
        table_spec = P(tp, fsdp)

        def body(tbl, tok):
            if fsdp is not None:
                tbl = jax.lax.all_gather(tbl, fsdp, axis=1, tiled=True)
            vm = tbl.shape[0]
            off = jax.lax.axis_index(tp) * vm
            ids = tok - off
            ok = (ids >= 0) & (ids < vm)
            out = tbl[jnp.clip(ids, 0, vm - 1)] * ok[..., None].astype(tbl.dtype)
            return jax.lax.psum(out, tp)

        fn = shard_map(body, mesh=ctx.mesh,
                       in_specs=(table_spec, P(dp, None)),
                       out_specs=P(dp, None, None), check_vma=False)
        return fn(table, tokens).astype(dtype)

    def _logits(self, params, x):
        if self.cfg.tie_embeddings:
            return jnp.einsum("bsd,vd->bsv", x, params["embed"])
        return jnp.einsum("bsd,dv->bsv", x, params["head"])

    def _encode(self, params, enc_input):
        enc_cfg = dataclasses.replace(
            self.cfg, attn_every=0, ssm_kind="", n_experts=0, slstm_every=0)
        _, norm = make_norm(enc_cfg)
        x, _, _ = tfm.stack_apply_full(
            params["enc_stack"], enc_input.astype(jnp.dtype(self.cfg.param_dtype)),
            enc_cfg, self.ctx, causal=False)
        return norm(params["enc_final_norm"], x)

    # ----------------------------------------------------------------- loss
    def loss(self, params, batch) -> Tuple[jax.Array, Dict[str, Any]]:
        cfg, ctx = self.cfg, self.ctx
        _, norm = make_norm(cfg)
        memory = None
        if cfg.enc_dec:
            memory = self._encode(params, batch["enc_input"])
        x = self._embed(params, batch["tokens"])
        if cfg.frontend == "vision":
            fe = batch["frontend"].astype(x.dtype)
            x = jnp.concatenate([fe, x], axis=1)
        x = ctx.constrain(x, ctx.batch_spec, None, None)
        x, _, (counts, aux_loss) = tfm.stack_apply_full(
            params["stack"], x, cfg, ctx, memory=memory, causal=True)
        x = norm(params["final_norm"], x)
        if cfg.frontend == "vision":
            x = x[:, batch["frontend"].shape[1]:]
        logits = self._logits(params, x)
        logits = ctx.constrain(logits, ctx.batch_spec, None, ctx.tp_axis)
        ce = cross_entropy(logits, batch["labels"], cfg.vocab_size)
        loss = ce + 0.01 * aux_loss
        return loss, {"ce": ce, "aux_loss": aux_loss, "expert_counts": counts,
                      "logits_mean": jnp.mean(jnp.abs(logits).astype(jnp.float32))}

    # ---------------------------------------------------------------- caches
    def init_caches(self, batch: int, max_len: int, enc_len: int = 0):
        cfg = self.cfg
        dtype = jnp.dtype(cfg.param_dtype)
        kinds = tfm.slot_kinds(cfg)
        G = cfg.n_groups
        caches = {}
        for s, (mixer, _) in enumerate(kinds):
            if mixer == "attn":
                shape = (G, max_len, batch, cfg.n_kv_heads, cfg.hd)
                c = {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}
            elif mixer == "mamba":
                c = {"h": jnp.zeros((G, batch, cfg.d_inner, cfg.d_state), jnp.float32),
                     "conv": jnp.zeros((G, batch, cfg.d_conv - 1, cfg.d_inner), dtype)}
            elif mixer == "mlstm":
                hd = cfg.d_model // cfg.n_heads
                c = {"C": jnp.zeros((G, batch, cfg.n_heads, hd, hd), jnp.float32),
                     "n": jnp.zeros((G, batch, cfg.n_heads, hd), jnp.float32)}
            else:  # slstm
                hd = cfg.d_model // cfg.n_heads
                c = {"c": jnp.zeros((G, batch, cfg.n_heads, hd), jnp.float32),
                     "n": jnp.full((G, batch, cfg.n_heads), 1e-6, jnp.float32)}
            if cfg.enc_dec:
                eshape = (G, enc_len, batch, cfg.n_kv_heads, cfg.hd)
                c = dict(c, ck=jnp.zeros(eshape, dtype), cv=jnp.zeros(eshape, dtype))
            caches[f"slot_{s}"] = c
        return caches

    # --------------------------------------------------------------- prefill
    def prefill(self, params, batch, max_len: int):
        """Full forward filling caches; returns (last_logits, caches, pos)."""
        cfg, ctx = self.cfg, self.ctx
        _, norm = make_norm(cfg)
        memory = None
        if cfg.enc_dec:
            memory = self._encode(params, batch["enc_input"])
        x = self._embed(params, batch["tokens"])
        if cfg.frontend == "vision":
            x = jnp.concatenate([batch["frontend"].astype(x.dtype), x], axis=1)
        B, S, _ = x.shape
        x, raw_caches, _ = tfm.stack_apply_full(
            params["stack"], x, cfg, ctx, memory=memory, causal=True,
            collect_caches=True)
        caches = self.init_caches(B, max_len, enc_len=memory.shape[1] if memory is not None else 0)
        for slot, c in raw_caches.items():
            tgt = caches[slot]
            if "k" in c:  # (G,B,S,KV,hd) -> seq-major (G,S_max,B,KV,hd)
                k = c["k"].transpose(0, 2, 1, 3, 4)
                v = c["v"].transpose(0, 2, 1, 3, 4)
                tgt["k"] = jax.lax.dynamic_update_slice_in_dim(tgt["k"], k.astype(tgt["k"].dtype), 0, axis=1)
                tgt["v"] = jax.lax.dynamic_update_slice_in_dim(tgt["v"], v.astype(tgt["v"].dtype), 0, axis=1)
            if "ck" in c:
                tgt["ck"] = c["ck"].transpose(0, 2, 1, 3, 4).astype(tgt["ck"].dtype)
                tgt["cv"] = c["cv"].transpose(0, 2, 1, 3, 4).astype(tgt["cv"].dtype)
            for key in ("h", "conv", "C", "n", "c"):
                if key in c:
                    tgt[key] = c[key].astype(tgt[key].dtype)
        x = norm(params["final_norm"], x)
        logits = self._logits(params, x[:, -1:])[:, 0]
        return logits, caches, S

    # ---------------------------------------------------------------- decode
    def decode_step(self, params, caches, token, pos):
        """One token for the whole batch. token: (B,) int32. pos: scalar."""
        cfg, ctx = self.cfg, self.ctx
        _, norm = make_norm(cfg)
        x = self._embed(params, token[:, None])
        x = ctx.constrain(x, ctx.batch_spec, None, None)
        x, new_caches, counts = tfm.stack_apply_decode(
            params["stack"], x, cfg, ctx, caches, pos)
        x = norm(params["final_norm"], x)
        logits = self._logits(params, x)[:, 0]
        next_token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return logits, new_caches, next_token, counts

    # ----------------------------------------------------- dirty events (§3.2)
    def dirty_events_train(self, batch, aux) -> Dict[str, Any]:
        """Domain-space dirty events for sparse leaves after a train step.

        Returned dict maps param-leaf path suffixes to bool row-masks; the
        train loop expands them to params/moments and marks everything else
        ALL-dirty (dense AdamW updates every block).
        """
        cfg = self.cfg
        events: Dict[str, Any] = {}
        presence = jnp.zeros((cfg.padded_vocab,), bool).at[
            batch["tokens"].reshape(-1)].set(True, mode="drop")
        events["embed"] = presence
        counts = aux["expert_counts"]  # (n_groups, group_size, E)
        for s in range(cfg.group_size):
            if cfg.ffn_kind(s) == "moe":
                ev = counts[:, s, :] > 0  # (n_groups, E)
                for w in ("wi", "wg", "wo"):
                    events[f"stack/slot_{s}/moe/{w}"] = ev
        return events

    def dirty_events_decode(self, caches, pos) -> Dict[str, Any]:
        """KV-cache page dirty events for a decode step at ``pos``.

        Masks are (n_groups, S_max) over the seq-major cache leading dims —
        only the written position's page goes dirty (paper: one page per
        cache-line write burst). Recurrent-state caches (mamba/xlstm) are
        rewritten wholesale each step -> ALL.
        """
        from repro.core.engine import ALL
        cfg = self.cfg
        events: Dict[str, Any] = {}
        for s, (mixer, _) in enumerate(tfm.slot_kinds(cfg)):
            slot = caches[f"slot_{s}"]
            if mixer == "attn":
                G, S_max = slot["k"].shape[0], slot["k"].shape[1]
                ev = jnp.zeros((G, S_max), bool).at[:, pos].set(True)
                events[f"slot_{s}/k"] = ev
                events[f"slot_{s}/v"] = ev
            else:
                for w in slot:
                    if w not in ("ck", "cv"):
                        events[f"slot_{s}/{w}"] = ALL
        return events


def build_model(cfg: ModelConfig, ctx: ParallelCtx = NO_PARALLEL) -> Model:
    return Model(cfg=cfg, ctx=ctx)
