"""Mixture-of-Experts FFN with expert parallelism (EP) over the TP axis.

Design (DESIGN.md §5): activations stay replicated across the `model` axis
(as in TP transformers); each model-rank owns E/|model| experts, selects its
local experts' tokens from the (replicated) token set via a sorted
fixed-capacity dispatch, runs a per-expert matmul loop, scatters results
back, and a single psum over `model` combines expert outputs — the same
collective a dense TP FFN needs, so EP costs no extra collective class.
Expert weights are additionally FSDP-sharded over `data` and all-gathered
per layer.

The per-expert dynamic-slice loop avoids materializing the (T*k, d) gathered
token buffer (4+ GB at 32k-prefill scale); peak extra memory is
O(E_local * capacity * d).
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .layers import dense_init
from .parallel import ParallelCtx, NO_PARALLEL, shard_map
from jax.sharding import PartitionSpec as P


def moe_init(key, cfg, dtype=jnp.float32):
    d, E, ff = cfg.d_model, cfg.n_experts, cfg.expert_d_ff
    ks = jax.random.split(key, 5)
    p = {"router": dense_init(ks[0], (d, E), in_axis=0, dtype=jnp.float32)}
    if cfg.activation == "swiglu":
        p["wi"] = dense_init(ks[1], (E, d, ff), in_axis=1, dtype=dtype)
        p["wg"] = dense_init(ks[2], (E, d, ff), in_axis=1, dtype=dtype)
    else:
        p["wi"] = dense_init(ks[1], (E, d, ff), in_axis=1, dtype=dtype)
    p["wo"] = dense_init(ks[3], (E, ff, d), in_axis=1, dtype=dtype)
    return p


def _expert_ffn(x, wi, wg, wo, activation):
    if activation == "swiglu":
        h = jax.nn.silu(x @ wg) * (x @ wi)
    elif activation == "squared_relu":
        h = jnp.square(jax.nn.relu(x @ wi))
    else:
        h = jax.nn.gelu(x @ wi)
    return h @ wo


def _moe_local(params, x2d, cfg, ep_axis: Optional[str], fsdp_axis: Optional[str],
               dp_axes: Tuple[str, ...] = ()):
    """Per-device MoE over local tokens (replicated across ep_axis)."""
    T, d = x2d.shape
    E, K = cfg.n_experts, cfg.top_k
    ep = (jax.lax.axis_size(ep_axis) if hasattr(jax.lax, "axis_size")
          else jax.lax.psum(1, ep_axis)) if ep_axis else 1
    E_loc = E // ep
    e_off = jax.lax.axis_index(ep_axis) * E_loc if ep_axis else 0
    cap = max(1, min(T * K, int(math.ceil(T * K / E * cfg.capacity_factor))))

    logits = (x2d @ params["router"].astype(x2d.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, ids = jax.lax.top_k(probs, K)                     # (T, K)
    gates = gates / jnp.sum(gates, axis=-1, keepdims=True)

    # Sorted fixed-capacity dispatch (stable: earlier tokens win capacity,
    # mirroring the paper-era switch routing priority).
    flat_e = ids.reshape(-1)                                 # (T*K,)
    flat_t = jnp.arange(T * K, dtype=jnp.int32) // K
    flat_g = gates.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    se, st, sg = flat_e[order], flat_t[order], flat_g[order]
    seg_start = jnp.searchsorted(se, se, side="left")
    pos = jnp.arange(T * K, dtype=jnp.int32) - seg_start.astype(jnp.int32)

    wi, wo = params["wi"], params["wo"]
    wg = params.get("wg")
    if ep_axis:  # shard_map gave us the local expert slab
        pass
    if fsdp_axis:  # FSDP: gather the d (or ff) dimension shards per layer
        wi = jax.lax.all_gather(wi, fsdp_axis, axis=1, tiled=True)
        wo = jax.lax.all_gather(wo, fsdp_axis, axis=1, tiled=True)
        if wg is not None:
            wg = jax.lax.all_gather(wg, fsdp_axis, axis=1, tiled=True)

    out = jnp.zeros((T, d), jnp.float32)
    for le in range(E_loc):
        e = le + e_off
        start = jnp.searchsorted(se, e, side="left").astype(jnp.int32)
        tok = jax.lax.dynamic_slice_in_dim(st, start, cap)
        eid = jax.lax.dynamic_slice_in_dim(se, start, cap)
        g = jax.lax.dynamic_slice_in_dim(sg, start, cap)
        within = jax.lax.dynamic_slice_in_dim(pos, start, cap)
        keep = (eid == e) & (within < cap)
        g = jnp.where(keep, g, 0.0)
        xe = x2d[tok] * keep[:, None].astype(x2d.dtype)      # (cap, d)
        ye = _expert_ffn(
            xe.astype(x2d.dtype),
            wi[le].astype(x2d.dtype),
            None if wg is None else wg[le].astype(x2d.dtype),
            wo[le].astype(x2d.dtype),
            cfg.activation,
        )
        out = out.at[tok].add(ye.astype(jnp.float32) * g[:, None])

    if ep_axis:
        out = jax.lax.psum(out, ep_axis)

    # Which experts received tokens (Vilamb dirty tracking) + balance loss.
    counts = jnp.zeros((E,), jnp.int32).at[flat_e].add(1, mode="drop")
    me = jnp.mean(probs, axis=0)
    ce = counts.astype(jnp.float32) / max(T * K, 1)
    aux_loss = E * jnp.sum(me * ce)
    # Reduce stats to a value identical on every device: tokens are
    # replicated over ep_axis (divide the ep-fold back out) and partitioned
    # over dp_axes (sum).
    stat_axes = tuple(dp_axes) + ((ep_axis,) if ep_axis else ())
    if stat_axes:
        counts = jax.lax.psum(counts, stat_axes) // ep
        aux_loss = jax.lax.psum(aux_loss, stat_axes) / ep
        ndp = jax.lax.psum(1, tuple(dp_axes)) if dp_axes else 1
        aux_loss = aux_loss / ndp
    return out.astype(x2d.dtype), counts, aux_loss


def moe_apply(
    params, x2d: jax.Array, cfg, ctx: ParallelCtx = NO_PARALLEL
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """MoE FFN over flat tokens (T, d) -> (out, expert_counts, aux_loss)."""
    if ctx.mesh is None or ctx.tp_axis is None or cfg.n_experts % max(ctx.axis_size(ctx.tp_axis), 1):
        out, counts, aux = _moe_local(params, x2d, cfg, None, None)
        return out, counts, aux

    tp, fsdp = ctx.tp_axis, ctx.fsdp_axis
    dp = ctx.batch_spec
    dp_axes = ctx.dp_axes
    if dp is not None:
        import numpy as _np
        k = int(_np.prod([ctx.axis_size(a) for a in ctx.dp_axes]))
        if x2d.shape[0] % max(k, 1):
            dp, dp_axes = None, ()   # tiny decode batches: replicate tokens
    wspec_i = P(tp, fsdp, None)
    wspec_o = P(tp, fsdp, None)
    in_specs = (
        {
            "router": P(None, None),
            **({"wg": wspec_i} if "wg" in params else {}),
            "wi": wspec_i,
            "wo": wspec_o,
        },
        P(dp, None),
    )

    def body(p, x):
        return _moe_local(p, x, cfg, tp, fsdp, dp_axes=dp_axes)

    fn = shard_map(
        body, mesh=ctx.mesh, in_specs=in_specs,
        out_specs=(P(dp, None), P(None), P()),
        check_vma=False,
    )
    return fn(params, x2d)
