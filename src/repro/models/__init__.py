from .config import ModelConfig
from .model import build_model
