"""Parallelism context threaded through model code.

Keeps the model definitions mesh-agnostic: every distribution decision is a
`constrain` (GSPMD sharding hint) or an explicit shard_map wrap (MoE expert
parallelism), all of which degrade to no-ops when ``mesh is None`` (CPU smoke
tests run the identical code path).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.common.compat import shard_map


@dataclasses.dataclass(frozen=True)
class ParallelCtx:
    mesh: Optional[Mesh] = None
    tp_axis: Optional[str] = "model"
    # str, or tuple for cross-pod FSDP (ZeRO over DCN: ("pod", "data")).
    fsdp_axis = "data"
    pod_axis: Optional[str] = "pod"

    def __init__(self, mesh=None, tp_axis="model", fsdp_axis="data", pod_axis="pod"):
        object.__setattr__(self, "mesh", mesh)
        if mesh is not None:
            names = mesh.axis_names
            tp_axis = tp_axis if tp_axis in names else None
            pod_axis = pod_axis if pod_axis in names else None
            if isinstance(fsdp_axis, tuple):
                fs = tuple(a for a in fsdp_axis if a in names)
                fsdp_axis = fs if len(fs) > 1 else (fs[0] if fs else None)
            else:
                fsdp_axis = fsdp_axis if fsdp_axis in names else None
        object.__setattr__(self, "tp_axis", tp_axis)
        object.__setattr__(self, "fsdp_axis", fsdp_axis)
        object.__setattr__(self, "pod_axis", pod_axis)

    @property
    def dp_axes(self) -> Tuple[str, ...]:
        """Axes the batch is sharded over."""
        axes = []
        if self.pod_axis:
            axes.append(self.pod_axis)
        fs = self.fsdp_axis if isinstance(self.fsdp_axis, tuple) else (
            (self.fsdp_axis,) if self.fsdp_axis else ())
        for a in fs:
            if a not in axes:
                axes.append(a)
        return tuple(axes)

    @property
    def batch_spec(self):
        return tuple(self.dp_axes) or None

    def axis_size(self, name) -> int:
        if self.mesh is None or name is None:
            return 1
        if isinstance(name, tuple):
            out = 1
            for a in name:
                out *= self.mesh.shape[a]
            return out
        return self.mesh.shape[name]

    def constrain(self, x, *spec):
        if self.mesh is None:
            return x
        return jax.lax.with_sharding_constraint(x, NamedSharding(self.mesh, P(*spec)))

    def divides(self, dim: int, axis) -> bool:
        return axis is not None and dim % self.axis_size(axis) == 0

    def seq_spec(self, seq_len: int) -> Optional[str]:
        """Sequence-parallel axis for activations between layers (Megatron-SP):
        residual-stream tensors are sharded over the TP axis on the sequence
        dim wherever it divides; GSPMD inserts the all-gather at attention
        and the reduce-scatter after. Cuts saved-activation memory by |tp|."""
        if self.tp_axis is not None and seq_len % self.axis_size(self.tp_axis) == 0 and seq_len > 1:
            return self.tp_axis
        return None


NO_PARALLEL = ParallelCtx(mesh=None, tp_axis=None, fsdp_axis=None, pod_axis=None)
