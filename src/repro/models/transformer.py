"""Architecture assembly: decoder-only / enc-dec LMs over heterogeneous
layer stacks (attention, Mamba, mLSTM, sLSTM mixers x dense/MoE FFNs).

Layers are stacked in *groups* (the pattern period: 8 for jamba's 1:7
attn:mamba interleave, 8 for xlstm's 7:1 mLSTM:sLSTM, 1 for uniform stacks)
and executed with ``lax.scan`` over groups so the HLO stays one-group-sized
regardless of depth (94-layer MoE compiles as fast as 16-layer dense).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from . import attention as attn_mod
from . import mamba as mamba_mod
from . import moe as moe_mod
from . import xlstm as xlstm_mod
from .config import ModelConfig
from .layers import embed_init, ffn_apply, ffn_init, make_norm
from .parallel import ParallelCtx, NO_PARALLEL


def slot_kinds(cfg: ModelConfig) -> List[Tuple[str, str]]:
    """(mixer, ffn) kind per slot within one scan group."""
    return [(cfg.layer_kind(s), cfg.ffn_kind(s)) for s in range(cfg.group_size)]


# --------------------------------------------------------------------- init
def _slot_init(key, cfg: ModelConfig, mixer: str, ffn: str, dtype, cross: bool):
    norm_init, _ = make_norm(cfg)
    ks = jax.random.split(key, 8)
    p: Dict[str, Any] = {"mixer_norm": norm_init(ks[0], cfg.d_model)}
    if mixer == "attn":
        p["attn"] = attn_mod.attn_init(ks[1], cfg, dtype)
    elif mixer == "mamba":
        p["mamba"] = mamba_mod.mamba_init(ks[1], cfg, dtype)
    elif mixer == "mlstm":
        p["mlstm"] = xlstm_mod.mlstm_init(ks[1], cfg, dtype)
    elif mixer == "slstm":
        p["slstm"] = xlstm_mod.slstm_init(ks[1], cfg, dtype)
    if cross:
        p["cross_norm"] = norm_init(ks[2], cfg.d_model)
        p["cross"] = attn_mod.attn_init(ks[3], cfg, dtype)
    if ffn != "none":
        p["ffn_norm"] = norm_init(ks[4], cfg.d_model)
        if ffn == "moe":
            p["moe"] = moe_mod.moe_init(ks[5], cfg, dtype)
            if cfg.dense_residual:
                p["dense_res"] = ffn_init(ks[6], cfg, cfg.d_ff, dtype)
        else:
            p["ffn"] = ffn_init(ks[5], cfg, cfg.d_ff, dtype)
    return p


def stack_init(key, cfg: ModelConfig, n_groups: int, dtype, cross: bool = False):
    kinds = slot_kinds(cfg)
    out = {}
    for s, (mixer, ffn) in enumerate(kinds):
        gkeys = jax.random.split(jax.random.fold_in(key, s), n_groups)
        out[f"slot_{s}"] = jax.vmap(
            lambda k: _slot_init(k, cfg, mixer, ffn, dtype, cross))(gkeys)
    return out


# -------------------------------------------------------------------- apply
def _slot_apply_full(
    p, x, cfg, ctx, mixer: str, ffn: str,
    memory=None, causal: bool = True,
):
    """Full-sequence slot (train / prefill). Returns (x, cache, counts)."""
    _, norm = make_norm(cfg)
    h = norm(p["mixer_norm"], x)
    cache = None
    shards = 1
    if ctx.mesh is not None:
        import numpy as _np
        shards = int(_np.prod([ctx.axis_size(a) for a in
                               (list(ctx.dp_axes) + ([ctx.tp_axis] if ctx.tp_axis else []))]))
    if mixer == "attn":
        if causal:
            y, (k, v) = attn_mod.causal_attention(
                p["attn"], h, cfg, tile=cfg.attn_tile, shards=shards, ctx=ctx)
        else:
            y, (k, v) = attn_mod.full_attention(p["attn"], h, cfg, rope=True, ctx=ctx)
        cache = {"k": k, "v": v}  # (B,S,KV,hd); prefill converts layout
    elif mixer == "mamba":
        y, cache = mamba_mod.mamba_apply(p["mamba"], h, cfg, ctx)
    elif mixer == "mlstm":
        y, cache = xlstm_mod.mlstm_apply(p["mlstm"], h, cfg, ctx)
    else:
        y, cache = xlstm_mod.slstm_apply(p["slstm"], h, cfg, ctx)
    x = x + y

    if memory is not None:  # enc-dec cross attention
        h = norm(p["cross_norm"], x)
        y, (ck, cv) = attn_mod.full_attention(p["cross"], h, cfg, kv_x=memory,
                                              rope=False, ctx=ctx)
        cache = dict(cache or {}, ck=ck, cv=cv)
        x = x + y

    counts = None
    if ffn != "none":
        h = norm(p["ffn_norm"], x)
        if ffn == "moe":
            B, S, d = h.shape
            y2, counts, aux = moe_mod.moe_apply(p["moe"], h.reshape(B * S, d), cfg, ctx)
            y2 = y2.reshape(B, S, d)
            if cfg.dense_residual:
                y2 = y2 + ffn_apply(p["dense_res"], h, cfg)
        else:
            y2 = ffn_apply(p["ffn"], h, cfg)
            aux = jnp.float32(0)
        x = x + y2
        counts = (counts, aux) if counts is not None else (jnp.zeros((max(cfg.n_experts, 1),), jnp.int32), aux)
    else:
        counts = (jnp.zeros((max(cfg.n_experts, 1),), jnp.int32), jnp.float32(0))
    return x, cache, counts


def _slot_apply_decode(p, x, cfg, ctx, mixer: str, ffn: str, cache, pos, memory_len=None):
    """One-token slot. x: (B,1,d). Returns (x, new_cache, counts)."""
    _, norm = make_norm(cfg)
    h = norm(p["mixer_norm"], x)
    if mixer == "attn":
        y, k_c, v_c = attn_mod.decode_attention(
            p["attn"], h, cfg, cache["k"], cache["v"], pos)
        new_cache = dict(cache, k=k_c, v=v_c)
    elif mixer == "mamba":
        y, st = mamba_mod.mamba_decode_step(p["mamba"], h, cfg, cache)
        new_cache = dict(cache, **st)
    elif mixer == "mlstm":
        y, st = xlstm_mod.mlstm_decode_step(p["mlstm"], h, cfg, cache)
        new_cache = dict(cache, **st)
    else:
        y, st = xlstm_mod.slstm_decode_step(p["slstm"], h, cfg, cache)
        new_cache = dict(cache, **st)
    x = x + y

    if "ck" in (cache or {}):
        h = norm(p["cross_norm"], x)
        y, _, _ = attn_mod.decode_attention(
            p["cross"], h, cfg, cache["ck"], cache["cv"], pos, rope=False, cross=True)
        x = x + y

    counts = jnp.zeros((max(cfg.n_experts, 1),), jnp.int32)
    if ffn != "none":
        h = norm(p["ffn_norm"], x)
        if ffn == "moe":
            B = h.shape[0]
            y2, counts, _ = moe_mod.moe_apply(p["moe"], h.reshape(B, -1), cfg, ctx)
            y2 = y2.reshape(B, 1, -1)
            if cfg.dense_residual:
                y2 = y2 + ffn_apply(p["dense_res"], h, cfg)
        else:
            y2 = ffn_apply(p["ffn"], h, cfg)
        x = x + y2
    return x, new_cache, counts


def stack_apply_full(
    stack, x, cfg: ModelConfig, ctx: ParallelCtx,
    memory=None, causal: bool = True, collect_caches: bool = False,
):
    """Scan the stack over groups. Returns (x, caches, (counts, aux_loss))."""
    kinds = slot_kinds(cfg)

    def group(x, gp):
        sp = ctx.seq_spec(x.shape[1]) if cfg.seq_parallel else None
        x = ctx.constrain(x, ctx.batch_spec, sp, None)
        caches, counts, aux = {}, [], jnp.float32(0)
        for s, (mixer, ffn) in enumerate(kinds):
            # Remat at SLOT granularity: a layer's backward holds only that
            # layer's residuals (group-level remat made an 8-layer jamba
            # group's entire residual set live at once — 100+ GB/chip).
            def one_slot(x_, sp, _mixer=mixer, _ffn=ffn):
                return _slot_apply_full(
                    sp, x_, cfg, ctx, _mixer, _ffn, memory=memory, causal=causal)
            if cfg.remat != "none":
                one_slot = jax.checkpoint(one_slot)
            x, c, (cnt, a) = one_slot(x, gp[f"slot_{s}"])
            if collect_caches and c is not None:
                caches[f"slot_{s}"] = c
            counts.append(cnt)
            aux = aux + a
        return x, (caches, jnp.stack(counts), aux)

    x, (caches, counts, aux) = jax.lax.scan(
        lambda carry, gp: group(carry, gp), x, stack,
        unroll=True if cfg.unroll_layers else 1)
    return x, caches, (counts, jnp.sum(aux))


def stack_apply_decode(stack, x, cfg: ModelConfig, ctx: ParallelCtx, caches, pos):
    kinds = slot_kinds(cfg)

    def group(x, inp):
        gp, gc = inp
        new_c, counts = {}, []
        for s, (mixer, ffn) in enumerate(kinds):
            x, c, cnt = _slot_apply_decode(
                gp[f"slot_{s}"], x, cfg, ctx, mixer, ffn, gc.get(f"slot_{s}"), pos)
            new_c[f"slot_{s}"] = c
            counts.append(cnt)
        return x, (new_c, jnp.stack(counts))

    x, (new_caches, counts) = jax.lax.scan(
        group, x, (stack, caches), unroll=True if cfg.unroll_layers else 1)
    return x, new_caches, counts
