"""Failure handling: preemption flush (the paper's battery), restart logic,
corruption repair.

The paper's battery guarantees redundancy is brought up-to-date on a power
failure (§3.3). The TPU-fleet analogue: SIGTERM arrives with a grace window;
the handler (1) forces a redundancy flush (Algorithm 1 over all dirty
state), (2) writes a checkpoint, (3) exits with a restartable code. §4.7's
battery sizing becomes "flush seconds within the grace budget", measured by
benchmarks/battery.py.
"""
from __future__ import annotations

import dataclasses
import signal
import sys
import time
from typing import Any, Callable, Optional

import jax


@dataclasses.dataclass
class PreemptionHandler:
    grace_seconds: float = 30.0
    exit_code: int = 42          # restartable by the job scheduler

    def __post_init__(self):
        self._requested = False
        self._flush_seconds: Optional[float] = None

    def install(self):
        signal.signal(signal.SIGTERM, self._on_signal)
        signal.signal(signal.SIGUSR1, self._on_signal)  # test hook
        return self

    def _on_signal(self, signum, frame):
        self._requested = True

    @property
    def requested(self) -> bool:
        return self._requested

    def drain(self, trainer, state, ckpt=None) -> Any:
        """Flush redundancy + checkpoint within the grace budget."""
        t0 = time.perf_counter()
        state = trainer.flush(state)              # battery analogue
        jax.block_until_ready(jax.tree.leaves(state.red)[:1] or [state.step])
        self._flush_seconds = time.perf_counter() - t0
        if ckpt is not None:
            ckpt.save(int(state.step), state, blocking=True)
        return state

    @property
    def flush_seconds(self) -> Optional[float]:
        return self._flush_seconds


def repair_corruption(engine, leaves, red, mismatches, details=None) -> tuple:
    """Recover every detected-corrupt block from parity (paper left this
    unimplemented; we do not). Returns (repaired_leaves, n_fixed, n_lost).

    ``engine`` is anything exposing ``recover_block`` and ``metas`` — a
    RedundancyEngine or a ProtectedStore (which routes each leaf to its
    owning group).  The plan/execute split lives in
    :mod:`repro.core.repairs`, shared with the live scrub patroller.

    Two unrecoverable classes are refused *loudly*, never papered over:

    * blocks in vulnerable stripes (another member dirty/shadow-set) —
      parity is stale there (paper §3.3); and
    * **two or more detected-corrupt blocks sharing one parity group** —
      XOR parity is single-failure-correcting, and "repairing" one member
      from a stripe containing another corrupted member would fabricate
      plausible-looking garbage while reporting success.  The whole stripe
      is counted lost and a warning names it.

    ``details`` (optional list) collects one structured
    :class:`repro.core.repairs.UnrecoverableBlock` per refused stripe —
    which blocks of which leaf, and why — so reports can name the loss,
    not just count it.  Callers fall back to checkpoint restore for lost
    blocks (``CheckpointManager.restore_verified`` does this
    automatically, and records the details in its ``RestoreReport``).
    """
    import warnings

    from repro.core.repairs import (plan_stripe_repairs, repair_blocks,
                                    vulnerable_unrecoverable)

    singles, unrec = plan_stripe_repairs(engine.metas, mismatches)
    for u in unrec:
        warnings.warn(
            f"{u.leaf}: {len(u.blocks)} corrupt blocks {list(u.blocks)} share "
            f"parity group {u.stripe}; XOR parity corrects single failures — "
            "counting the stripe as lost (restore from checkpoint)",
            RuntimeWarning, stacklevel=2)
    leaves, fixed, vulnerable = repair_blocks(engine, leaves, red, singles)
    unrec = unrec + vulnerable_unrecoverable(engine.metas, vulnerable)
    if details is not None:
        details.extend(unrec)
    lost = sum(len(u.blocks) for u in unrec)
    return leaves, len(fixed), lost
