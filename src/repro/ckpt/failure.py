"""Failure handling: preemption flush (the paper's battery), restart logic,
corruption repair.

The paper's battery guarantees redundancy is brought up-to-date on a power
failure (§3.3). The TPU-fleet analogue: SIGTERM arrives with a grace window;
the handler (1) forces a redundancy flush (Algorithm 1 over all dirty
state), (2) writes a checkpoint, (3) exits with a restartable code. §4.7's
battery sizing becomes "flush seconds within the grace budget", measured by
benchmarks/battery.py.
"""
from __future__ import annotations

import dataclasses
import signal
import sys
import time
from typing import Any, Callable, Optional

import jax


@dataclasses.dataclass
class PreemptionHandler:
    grace_seconds: float = 30.0
    exit_code: int = 42          # restartable by the job scheduler

    def __post_init__(self):
        self._requested = False
        self._flush_seconds: Optional[float] = None

    def install(self):
        signal.signal(signal.SIGTERM, self._on_signal)
        signal.signal(signal.SIGUSR1, self._on_signal)  # test hook
        return self

    def _on_signal(self, signum, frame):
        self._requested = True

    @property
    def requested(self) -> bool:
        return self._requested

    def drain(self, trainer, state, ckpt=None) -> Any:
        """Flush redundancy + checkpoint within the grace budget."""
        t0 = time.perf_counter()
        state = trainer.flush(state)              # battery analogue
        jax.block_until_ready(jax.tree.leaves(state.red)[:1] or [state.step])
        self._flush_seconds = time.perf_counter() - t0
        if ckpt is not None:
            ckpt.save(int(state.step), state, blocking=True)
        return state

    @property
    def flush_seconds(self) -> Optional[float]:
        return self._flush_seconds


def repair_corruption(engine, leaves, red, mismatches) -> tuple:
    """Recover every detected-corrupt block from parity (paper left this
    unimplemented; we do not). Returns (repaired_leaves, n_fixed, n_lost).

    ``engine`` is anything exposing ``recover_block`` and ``metas`` — a
    RedundancyEngine or a ProtectedStore (which routes each leaf to its
    owning group).

    Two unrecoverable classes are refused *loudly*, never papered over:

    * blocks in vulnerable stripes (another member dirty/shadow-set) —
      parity is stale there (paper §3.3); and
    * **two or more detected-corrupt blocks sharing one parity group** —
      XOR parity is single-failure-correcting, and "repairing" one member
      from a stripe containing another corrupted member would fabricate
      plausible-looking garbage while reporting success.  The whole stripe
      is counted lost and a warning names it.

    Callers fall back to checkpoint restore for lost blocks
    (``CheckpointManager.restore_verified`` does this automatically).
    """
    import collections
    import warnings

    import numpy as np

    fixed = 0
    lost = 0
    leaves = dict(leaves)
    metas = engine.metas
    for name, mask in mismatches.items():
        ids = np.nonzero(np.asarray(mask))[0]
        if not ids.size:
            continue
        from repro.core.blocks import global_stripe_id

        meta = metas[name]
        by_stripe = collections.defaultdict(list)
        for b in ids:
            # Global stripe id: parity groups never span shards.
            by_stripe[global_stripe_id(meta, b)].append(int(b))
        for stripe, blks in sorted(by_stripe.items()):
            if len(blks) > 1:
                warnings.warn(
                    f"{name}: {len(blks)} corrupt blocks {blks} share parity "
                    f"group {stripe}; XOR parity corrects single failures — "
                    "counting the stripe as lost (restore from checkpoint)",
                    RuntimeWarning, stacklevel=2)
                lost += len(blks)
                continue
            b = blks[0]
            repaired, ok = engine.recover_block(leaves[name], red[name], name, b)
            if bool(ok):
                leaves[name] = repaired
                fixed += 1
            else:
                lost += 1
    return leaves, fixed, lost
