from .checkpoint import CheckpointManager, RestoreReport
from .failure import PreemptionHandler
