from .checkpoint import CheckpointManager
from .failure import PreemptionHandler
