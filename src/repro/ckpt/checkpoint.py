"""Fault-tolerant checkpointing with Vilamb meta-checksums.

Design points for fleet scale:
  * **Atomic**: write to ``step_N.tmp/`` then rename — a crash mid-save never
    corrupts the latest checkpoint.
  * **Self-verifying**: every leaf file carries an fmix32 XOR-fold checksum
    (the paper's mechanism applied to the storage tier); restore verifies
    before handing state back, and falls back to the previous checkpoint on
    mismatch.
  * **Redundancy-aware**: the Vilamb state (checksums, parity, dirty+shadow
    bitvectors) is part of the checkpoint, so a restart resumes with the
    exact coverage the paper's shadow protocol guarantees.
  * **Async**: device->host snapshot is synchronous (cheap); serialization
    runs on a background thread so training continues.
  * **Elastic**: leaves are saved as full logical arrays; a restarted job
    may reload onto a different mesh (reshard-on-load via device_put with
    the new shardings).
  * **Store-verified**: ``restore_verified`` cross-checks a restored state
    against its own redundancy (via a :class:`repro.core.ProtectedStore`
    scrub + meta-checksum) and repairs single-block corruption from parity
    instead of discarding the whole checkpoint.
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
import shutil
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


@dataclasses.dataclass
class RestoreReport:
    """What ``restore_verified`` did — observability for the fault battery.

    ``tried`` records one ``(step, outcome)`` pair per candidate in the
    order attempted; outcomes: ``ok``, ``ok_repaired``, ``load_failed``,
    ``file_checksum``, ``meta_checksum``, ``unrecoverable``,
    ``repair_failed``.  ``step`` is the checkpoint finally returned
    (None = every candidate rejected).  ``repaired_blocks`` counts parity
    rebuilds on the *returned* candidate; ``lost_blocks`` accumulates the
    unrepairable blocks of *rejected* candidates (the reason they were
    skipped) — the returned checkpoint itself lost nothing.
    ``unrecoverable`` names those losses: one structured
    :class:`repro.core.repairs.UnrecoverableBlock` per refused stripe
    (leaf, global block ids, and whether the stripe was multi-corrupt or
    vulnerable), so operators see *what* was given up on, not a bare count.
    """
    tried: List[Tuple[int, str]] = dataclasses.field(default_factory=list)
    step: Optional[int] = None
    repaired_blocks: int = 0
    lost_blocks: int = 0
    unrecoverable: List[Any] = dataclasses.field(default_factory=list)


def _path_str(kp) -> str:
    parts = []
    for k in kp:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "name"):
            parts.append(str(k.name))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def _np_checksum(a: np.ndarray) -> int:
    """fmix32 XOR-fold over the raw bytes (numpy mirror of core.checksum)."""
    raw = np.frombuffer(a.tobytes() + b"\x00" * (-a.nbytes % 4), dtype=np.uint32)
    idx = np.arange(raw.size, dtype=np.uint32)
    x = raw ^ (idx * np.uint32(0x9E3779B9))
    x ^= x >> 16
    x = (x * np.uint32(0x85EBCA6B)) & np.uint32(0xFFFFFFFF)
    x ^= x >> 13
    x = (x * np.uint32(0xC2B2AE35)) & np.uint32(0xFFFFFFFF)
    x ^= x >> 16
    return int(np.bitwise_xor.reduce(x)) if x.size else 0


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self.last_restore_report: Optional[RestoreReport] = None

    # ----------------------------------------------------------------- save
    def save(self, step: int, state: Any, blocking: bool = True) -> None:
        leaves, _ = jax.tree_util.tree_flatten_with_path(state)
        host = {_path_str(kp): np.asarray(jax.device_get(v)) for kp, v in leaves}
        self.wait()
        if blocking:
            self._write(step, host)
        else:
            self._thread = threading.Thread(target=self._write, args=(step, host))
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host: Dict[str, np.ndarray]) -> None:
        tmp = self.dir / f"step_{step}.tmp"
        final = self.dir / f"step_{step}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        manifest: Dict[str, Any] = {"step": step, "leaves": {}, "bf16": []}
        arrays = {}
        for i, (k, v) in enumerate(host.items()):
            key = f"a{i}"
            if v.dtype.name == "bfloat16":
                manifest["bf16"].append(k)
                arrays[key] = v.view(np.uint16)
            else:
                arrays[key] = v
            manifest["leaves"][k] = {
                "shape": list(v.shape), "dtype": v.dtype.name,
                "checksum": _np_checksum(v), "file_key": key,
            }
        np.savez(tmp / "state.npz", **arrays)
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)  # atomic publish
        self._gc()

    def _gc(self) -> None:
        for s in self.steps()[: -self.keep]:
            shutil.rmtree(self.dir / f"step_{s}", ignore_errors=True)

    # -------------------------------------------------------------- restore
    def steps(self):
        out = []
        for p in self.dir.glob("step_*"):
            if p.is_dir() and not p.name.endswith(".tmp"):
                out.append(int(p.name.split("_")[1]))
        return sorted(out)

    def restore_flat(self, step: Optional[int] = None,
                     verify: bool = True) -> Optional[Dict[str, np.ndarray]]:
        """Newest-first restore with checksum verification; a corrupted
        checkpoint is rejected and the previous one tried (paper §2.2)."""
        import ml_dtypes
        candidates = self.steps()
        if step is not None:
            candidates = [s for s in candidates if s == step]
        for s in reversed(candidates):
            d = self.dir / f"step_{s}"
            try:
                manifest = json.loads((d / "manifest.json").read_text())
                z = np.load(d / "state.npz")
                out: Dict[str, np.ndarray] = {}
                ok = True
                bf16 = set(manifest.get("bf16", []))
                for k, meta in manifest["leaves"].items():
                    v = z[meta["file_key"]]
                    if k in bf16:
                        v = v.view(ml_dtypes.bfloat16)
                    if verify and _np_checksum(v) != meta["checksum"]:
                        ok = False
                        break
                    out[k] = v
                if ok:
                    out["__step__"] = np.int32(s)
                    return out
            except Exception:
                continue
        return None

    def restore_verified(self, state_struct: Any, store, *,
                         leaves_of=None, replace_leaves=None,
                         shardings: Any = None,
                         step: Optional[int] = None) -> Optional[Any]:
        """Newest-first restore verified end-to-end by the ProtectedStore.

        File checksums (``restore_into``) catch storage corruption; this
        additionally scrubs the restored protected leaves against their
        restored redundancy state and verifies the checksum-of-checksums.
        Detected blocks are rebuilt from parity when their stripe permits;
        an unrecoverable checkpoint is skipped and the previous one tried.

        ``leaves_of(state) -> flat leaves`` / ``replace_leaves(state,
        leaves) -> state`` default to the TrainState protected-leaf view.

        ``self.last_restore_report`` (a :class:`RestoreReport`) records the
        attempt trail — which candidates were rejected and why, and how
        many blocks the returned one needed rebuilt — so the fault battery
        can assert *why* a restore succeeded, not just that it did.
        """
        if leaves_of is None or replace_leaves is None:
            from repro.train.state import protected_leaves, replace_protected
            leaves_of = leaves_of or (
                lambda st: protected_leaves(st.params, st.opt))
            replace_leaves = replace_leaves or (
                lambda st, lv: replace_protected(st, lv))
        report = RestoreReport()
        self.last_restore_report = report
        candidates = self.steps()
        if step is not None:
            candidates = [s for s in candidates if s == step]
        for s in reversed(candidates):
            try:
                state = self.restore_into(state_struct, shardings, step=s)
            except Exception as e:
                # Keep falling back through older checkpoints, but loudly: a
                # systematic failure (struct mismatch, permissions) would
                # otherwise masquerade as "no checkpoint, fresh start".
                import warnings
                warnings.warn(f"restore of step {s} failed: {e!r}; "
                              "trying the previous checkpoint")
                report.tried.append((s, "load_failed"))
                continue
            if state is None:
                report.tried.append((s, "file_checksum"))
                continue
            if store is None or not store.protects:
                report.tried.append((s, "ok"))
                report.step = s
                return state
            red = state.red
            leaves = leaves_of(state)
            if not all(bool(ok) for ok in store.verify_meta(red).values()):
                report.tried.append((s, "meta_checksum"))
                continue  # corrupted checksum pages: try the previous ckpt
            mm = store.scrub(leaves, red)
            if sum(int(v.sum()) for v in jax.tree_util.tree_leaves(mm)) == 0:
                report.tried.append((s, "ok"))
                report.step = s
                return state
            details: List[Any] = []
            repaired, fixed, lost = store.repair(leaves, red, mm,
                                                 details=details)
            if lost:
                report.tried.append((s, "unrecoverable"))
                report.lost_blocks += int(lost)
                report.unrecoverable.extend(details)
                continue  # vulnerable or multi-corrupt stripe: fall back
            mm2 = store.scrub(repaired, red)
            if sum(int(v.sum()) for v in jax.tree_util.tree_leaves(mm2)) == 0:
                report.tried.append((s, "ok_repaired"))
                report.step = s
                report.repaired_blocks += int(fixed)
                return replace_leaves(state, repaired)
            report.tried.append((s, "repair_failed"))
        return None

    def restore_into(self, state_struct: Any, shardings: Any = None,
                     step: Optional[int] = None) -> Optional[Any]:
        """Rebuild a state pytree (elastic: any mesh/shardings)."""
        host = self.restore_flat(step)
        if host is None:
            return None
        host.pop("__step__", None)

        shard_flat: Dict[str, Any] = {}
        if shardings is not None:
            for kp, sh in jax.tree_util.tree_flatten_with_path(
                    shardings, is_leaf=lambda x: hasattr(x, "spec"))[0]:
                shard_flat[_path_str(kp)] = sh

        def fill(kp, leaf_struct):
            k = _path_str(kp)
            v = host.get(k)
            if v is None:
                raise KeyError(f"checkpoint missing leaf {k}")
            if tuple(v.shape) != tuple(leaf_struct.shape):
                raise ValueError(f"shape mismatch for {k}: ckpt {v.shape} vs {leaf_struct.shape}")
            sh = shard_flat.get(k)
            if sh is not None:
                return jax.device_put(v, sh)
            return jax.numpy.asarray(v)

        return jax.tree_util.tree_map_with_path(fill, state_struct)
