"""Tick-scheduled scrub patroller.

The paper's scheduled scrub (``ProtectedStore.scrub``) reads every block of
every leaf in one pass — fine at checkpoint boundaries, far too heavy to
run often, so silent corruption sits latent for most of a scrub period.
The patroller closes that gap with a **continuous low-priority sweep**: a
cursor walks local block space and each quiet tick verifies one bounded
window (``patrol_bytes_per_tick``) of one leaf against its stored
checksums — the same comparison as scrub, paced so foreground work never
waits on a full-leaf pass.  Detection latency drops from "next scheduled
scrub" (hundreds of steps) to "next sweep" (a handful), which feeds the
measured-MTTDL model (:func:`repro.core.mttdl.mttdl_measured`) directly.

Duty order inside one tick — strictly below the foreground:

1. foreground writes / due redundancy updates (the store's group loop ran
   before we are called);
2. online shard rebuild, one bounded window per tick (loss recovery);
3. paced parity repairs of previously detected blocks;
4. a patrol probe — on quiet ticks (no update dispatched) and never
   while a rebuild is active; after ``patrol_max_starved_ticks``
   consecutive probe-less ticks one probe dispatches even on a busy tick
   (the starvation floor — wall-to-wall update traffic must not silently
   degrade detection latency to the scheduled-scrub baseline;
   ``TickReport.patrol_starved_ticks`` surfaces the current streak).

Probes are asynchronous: dispatched at tick ``t`` against the
post-dispatch live view (in-flight blocks are shadow-marked, so the clean
mask skips them), fetched non-blocking at ``t+1``.  At most one probe is
in flight.  Alongside each probe of a dim0-sharded leaf the same pass
exports the raw lanes, XOR-folded across shards into **cross-shard
parity** rows (:mod:`repro.scrub.rebuild`) — the patrol traffic doubles as
rebuild capital.  A tiny per-tick *write sample* (``dirty | shadow``,
fetched next tick) conservatively invalidates rows written since their
refresh; samples are processed before probe results each tick, so a stale
row is never validated over a fresh write.
"""
from __future__ import annotations

import collections
import dataclasses
import math
import warnings
from typing import Any, Callable, Dict, List, Mapping, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.repairs import (UnrecoverableBlock, plan_stripe_repairs,
                                repair_blocks, vulnerable_unrecoverable)
from repro.core.store import _ready
from repro.faults.inject import bits_to_mask

from .rebuild import CrossShardParity, ShardRebuilder, xor_fold as _xor_fold

# A block is only "repaired-for-sure" once a later probe stops flagging it.
# recover_block can succeed (stripe clean) yet reconstruct garbage if the
# corruption raced a parity refresh of its stripe; such blocks re-detect on
# the next sweep and are retried up to this many times before the stripe is
# declared lost.
MAX_REPAIR_ATTEMPTS = 3

# Bound on the observability histories (detections, measured latencies) so
# a long-running store does not grow them without limit; the MTTDL model
# only ever wants recent-window statistics anyway.
OBSERVABILITY_CAP = 4096

# A probe outstanding this many process attempts with ``is_ready`` still
# False is force-fetched: the readiness notification can go missing when
# the store's resolver thread runs a blocking transfer concurrently
# (observed on the CPU backend) — the value is long since computed, and
# waiting on the phantom would starve patrol forever behind the one
# outstanding probe.
PROBE_FORCE_TICKS = 4


class ShardLossConflictError(RuntimeError):
    """A second shard of the same leaf was declared lost while a rebuild of
    the first is active or pending.  Cross-shard parity is a single XOR
    fold: it can reconstruct exactly one missing shard, so the second loss
    is genuinely unrecoverable from ``xpar`` — raising keeps the in-flight
    rebuild's paste state intact instead of silently resetting it."""

    def __init__(self, leaf: str, active_shard: int, new_shard: int):
        self.leaf = leaf
        self.active_shard = int(active_shard)
        self.new_shard = int(new_shard)
        super().__init__(
            f"{leaf}: shard {new_shard} declared lost while shard "
            f"{active_shard} is still rebuilding; cross-shard parity "
            "covers a single lost shard, so a concurrent second loss is "
            "unrecoverable (wait for the active rebuild to finish)")


@dataclasses.dataclass(frozen=True)
class DetectionEvent:
    """One patrol detection: leaf, global block id, detection step, and —
    when the corruption was registered via :meth:`ScrubPatroller.
    expect_injection` — the measured latency in steps."""
    leaf: str
    block: int
    step: int
    latency_steps: Optional[int] = None


class ScrubPatroller:
    """Continuous verify-window patrol + online shard rebuild for one
    :class:`repro.core.ProtectedStore` (built by ``attach`` when
    ``RedundancyPolicy.patrol_bytes_per_tick > 0``)."""

    def __init__(self, store):
        self.store = store
        pol = store.policy
        self.patrol_bytes = int(pol.patrol_bytes_per_tick)
        # Mesh-geometry epoch: a remesh adoption rebuilds the patroller
        # fresh and bumps the store's version, so every parity image and
        # rebuilder carries the geometry it was folded under — stale xpar
        # from a previous mesh can never seed a rebuild on the new one.
        self.geometry_version = int(getattr(store, "geometry_version", 0))
        # Patrol targets: every vilamb-protected leaf, round-robin.  The
        # probe window is static per leaf (one compile serves the sweep).
        self.targets: List[str] = []
        self.window: Dict[str, int] = {}
        self.cursor: Dict[str, int] = {}
        self.sweeps: Dict[str, int] = {}
        self.xpar: Dict[str, CrossShardParity] = {}
        for g in store._protected():
            if g.policy.mode != "vilamb":
                continue
            for name in g.names:
                meta = store.metas[name]
                w = max(1, self.patrol_bytes // max(1, meta.bytes_per_block))
                self.window[name] = min(w, meta.n_blocks)
                self.cursor[name] = 0
                self.sweeps[name] = 0
                self.targets.append(name)
                eng = store.engine_for(name)
                k = eng.shard_factor(name)
                gshape = eng.global_leaf_structs[name].shape
                # Cross-shard parity needs clean row-contiguous shard
                # slices: dim0-sharded with an even split (the same
                # precondition as blocks.shard_slice / recover_block).
                if (k >= 2 and gshape and gshape[0] % k == 0
                        and tuple(meta.shape) ==
                        (gshape[0] // k,) + tuple(gshape[1:])):
                    self.xpar[name] = CrossShardParity(
                        name, meta.n_blocks,
                        version=self.geometry_version)
        self._primed = False
        self._jits: Dict[Any, Callable] = {}
        # In-flight async work: at most one probe; one write sample.
        self._probe: Optional[Tuple] = None
        self._probe_stuck = 0              # not-ready process attempts
        # Rows of the in-flight probe's leaf invalidated by write samples
        # processed since its dispatch: a probe that lands late must not
        # re-validate them (its clean mask predates those writes).
        self._probe_inval: Optional[np.ndarray] = None
        self._sample: Optional[Dict[str, jax.Array]] = None
        self._ti = 0                       # round-robin target index
        # Detection / repair bookkeeping ((name, global_block) keyed).
        self._detected: set = set()
        self._attempts: Dict[Tuple[str, int], int] = {}
        self._expected: Dict[Tuple[str, int], int] = {}
        self._repair_queue: List[List] = []    # [name, gblock, retries]
        # Queued losses: (name, shard, preloss-row-mask-or-None).
        self._pending_loss: List[Tuple[str, int, Optional[np.ndarray]]] = []
        self.rebuild: Optional[ShardRebuilder] = None
        # Observability.
        self.ticks = 0
        self.blocks_scanned = 0            # local probe positions covered
        self.starved_ticks = 0             # consecutive ticks with no probe
        self.detections: collections.deque = collections.deque(
            maxlen=OBSERVABILITY_CAP)
        self.latencies: collections.deque = collections.deque(
            maxlen=OBSERVABILITY_CAP)      # steps, registered injections only
        self.unrecoverable: List[UnrecoverableBlock] = []

    # ------------------------------------------------------------- plumbing
    def engine_of(self, name: str):
        eng = self.store.engine_for(name)
        assert eng is not None, name
        return eng

    def jit(self, key, fn, **kw) -> Callable:
        f = self._jits.get(key)
        if f is None:
            f = jax.jit(fn, **kw)
            self._jits[key] = f
        return f

    def fetch_live_rows(self, name: str, r) -> np.ndarray:
        """Exact (blocking) ``dirty | shadow`` fetch as a bool ``(k, nb)``
        row mask — writes land before the tick, so a fetch at tick ``t``
        sees every mark through step ``t``."""
        meta = self.store.metas[name]
        k = self.store.shard_factor(name)
        live = np.asarray(r.dirty) | np.asarray(r.shadow)
        return bits_to_mask(live, meta.n_blocks,
                            shards=k).reshape(k, meta.n_blocks)

    def adopt_repair(self, name: str, leaf, overlay, report) -> None:
        """Surface a repaired/rebuilt leaf: the patroller's own overlay uses
        it for the rest of the tick, and ``TickReport.repaired`` tells the
        caller to adopt it (train/serve loops fold it back)."""
        overlay[name] = leaf
        report.repaired[name] = leaf

    def _repin(self, name: str, leaf):
        """Pin a repaired leaf back to its NamedSharding — recover_block's
        scatter output may otherwise come back differently laid out and
        make the precompiled update programs reject the live view."""
        eng = self.engine_of(name)
        if eng.mesh is None:
            return leaf
        from jax.sharding import NamedSharding, PartitionSpec as P
        return jax.device_put(
            leaf, NamedSharding(eng.mesh, eng.specs.get(name, P())))

    # ------------------------------------------------------------------ API
    def expect_injection(self, name: str, gblock: int, step: int) -> None:
        """Register a known corruption (fault oracle / benches) so its
        patrol detection yields a measured latency in steps."""
        self._expected[(name, int(gblock))] = int(step)

    def declare_shard_lost(self, name: str, shard: int,
                           red: Optional[Mapping[str, Any]] = None) -> None:
        """Queue an online rebuild of ``name``'s ``shard`` (operator
        signal; probes also declare losses themselves past the
        ``shard_loss_threshold``).

        Pass the current ``red`` state when it is in hand: its ``dirty |
        shadow`` marks on the lost shard pin down *declaration-time*
        in-flight writes (data died with the shard — reported
        unrecoverable, never "fresh") while later foreground writes still
        classify as fresh.  Without ``red`` the rebuild snapshots at
        construction instead, which conservatively sweeps any write
        between declaration and the next tick into the pre-loss set."""
        if name not in self.xpar:
            raise ValueError(
                f"{name}: no cross-shard parity (leaf must be dim0-sharded "
                "across >= 2 shards for online rebuild)")
        if self.rebuild is not None and self.rebuild.name == name:
            if self.rebuild.shard == int(shard):
                return      # idempotent: already rebuilding this shard
            raise ShardLossConflictError(name, self.rebuild.shard, shard)
        for p in self._pending_loss:
            if p[0] != name:
                continue
            if p[1] == int(shard):
                return      # keep the earliest (closest-to-loss) snapshot
            # A different shard of the same leaf is already queued: the
            # single-XOR parity cannot cover both.
            raise ShardLossConflictError(name, p[1], shard)
        preloss = None
        if red is not None:
            preloss = self.fetch_live_rows(
                name, red[name])[int(shard)].copy()
        self._pending_loss.append((name, int(shard), preloss))

    def latency_stats(self, step_seconds: float = 1.0) -> Dict[str, float]:
        """Measured detection-latency summary for the MTTDL model
        (:func:`repro.core.mttdl.detection_latency_stats`)."""
        from repro.core import mttdl
        return mttdl.detection_latency_stats(self.latencies, step_seconds)

    def coverage(self) -> Dict[str, float]:
        """Fraction of each leaf's local block space the current sweep has
        covered (1.0 = at least one full sweep done)."""
        out = {}
        for n in self.targets:
            nb = self.store.metas[n].n_blocks
            out[n] = 1.0 if self.sweeps[n] else min(1.0, self.cursor[n] / nb)
        return out

    # ----------------------------------------------------------------- tick
    def on_tick(self, get_leaves, out, step: int, report,
                busy: bool = False) -> None:
        """One tick of background duty (called by ``ProtectedStore.tick``
        after the foreground group loop; mutates ``out`` and ``report``)."""
        self.ticks += 1
        overlay: Optional[Dict[str, Any]] = None

        def lv() -> Dict[str, Any]:
            nonlocal overlay
            if overlay is None:
                overlay = dict(get_leaves())
            return overlay

        if not self._primed:
            self._prime(lv(), out)
            self._primed = True
        # Invalidate-then-validate: write samples first, so a probe result
        # never re-validates a cross-shard parity row over a fresh write.
        self._process_sample()
        self._process_probe(out, step, report)
        if self.rebuild is None and self._pending_loss:
            self._start_rebuild(lv(), out, step)
        if self.rebuild is not None:
            self.rebuild.step_once(lv(), out, report, step)
            if self.rebuild.status.done:
                recs = self.rebuild.unrecoverable()
                self.unrecoverable.extend(recs)
                report.unrecoverable = report.unrecoverable + tuple(recs)
                self.rebuild = None
        elif self._repair_queue:
            self._run_repairs(lv, out, report)
        self._dispatch_sample(out)
        # Busy ticks defer the probe, but only up to the starvation floor:
        # under wall-to-wall update traffic the patrol would otherwise
        # never run and detection latency silently degrades to the
        # scheduled-scrub baseline.  After ``patrol_max_starved_ticks``
        # consecutive probe-less ticks one probe dispatches anyway
        # (0 disables the floor; rebuilds still take priority).
        floor = int(self.store.policy.patrol_max_starved_ticks)
        forced = floor > 0 and self.starved_ticks >= floor
        if ((not busy or forced) and self._probe is None
                and self.rebuild is None and self.targets):
            self._dispatch_probe(lv(), out, step, report)
            self.starved_ticks = 0
        elif self._probe is None and self.targets:
            self.starved_ticks += 1
        report.patrol_starved_ticks = self.starved_ticks

    # ------------------------------------------------------------- internals
    def _prime(self, leaves, out) -> None:
        """First tick: fold the initial cross-shard parity image per
        eligible leaf and seed row validity from the live bitvectors."""
        for name, xp in self.xpar.items():
            eng = self.engine_of(name)
            stack = self.jit(("stack", name),
                             eng.shard_lanes_fn(name))(leaves[name])
            xp.xpar = self.jit(("xfold", name), _xor_fold)(stack)
            xp.xvalid = ~self.fetch_live_rows(name, out[name]).any(axis=0)

    def _process_sample(self) -> None:
        if self._sample is None:
            return
        for name, words in self._sample.items():
            meta = self.store.metas[name]
            k = self.store.shard_factor(name)
            rows = bits_to_mask(np.asarray(words), meta.n_blocks,
                                shards=k).reshape(k, meta.n_blocks)
            written = rows.any(axis=0)
            self.xpar[name].xvalid &= ~written
            # Remember rows written while a probe is in flight on this
            # leaf: the probe's clean mask predates them, so its adoption
            # must not re-validate them (a probe landing >1 tick after
            # dispatch would otherwise undo this sample's invalidation).
            if (self._probe is not None and self._probe_inval is not None
                    and self._probe[0] == name):
                self._probe_inval |= written
        self._sample = None

    def _dispatch_sample(self, out) -> None:
        """Per-tick write sample for cross-shard parity freshness.  Runs on
        EVERY tick (not just probe ticks): a mark consumed by an update
        dispatched this tick leaves ``dirty`` at adoption, and only this
        sample still catches it in ``shadow``."""
        if not self.xpar:
            return
        names = tuple(sorted(self.xpar))
        fn = self.jit(("sample", names),
                      lambda rs: {n: jnp.bitwise_or(rs[n].dirty,
                                                    rs[n].shadow)
                                  for n in names})
        words = fn({n: out[n] for n in names})
        for w in words.values():
            try:
                w.copy_to_host_async()
            except AttributeError:
                pass
        self._sample = words

    def _dispatch_probe(self, leaves, out, step: int, report) -> None:
        name = self.targets[self._ti % len(self.targets)]
        self._ti += 1
        meta = self.store.metas[name]
        eng = self.engine_of(name)
        w, nb = self.window[name], meta.n_blocks
        # Clamp so windows never cross n_blocks: the final window of a
        # sweep re-probes a little instead (keeps every downstream
        # dynamic_update_slice un-clamped and in-range).
        start = min(self.cursor[name], nb - w)
        want_slab = name in self.xpar
        fn = self.jit(("probe", name, w, want_slab),
                      eng.verify_window_fn(name, w, want_slab=want_slab))
        outs = fn(leaves[name], out[name], np.int32(start))
        mism, clean = outs[0], outs[1]
        xwin = None
        if want_slab:
            xwin = self.jit(("xfold", name), _xor_fold)(outs[2])
        for a in (mism, clean):
            try:
                a.copy_to_host_async()
            except AttributeError:
                pass
        self._probe = (name, start, w, mism, clean, xwin, step)
        self._probe_inval = (np.zeros((nb,), bool) if want_slab else None)
        self.blocks_scanned += w
        self.cursor[name] = start + w
        if self.cursor[name] >= nb:
            self.cursor[name] = 0
            self.sweeps[name] += 1
        report.patrolled = report.patrolled + (name,)

    def _process_probe(self, out, step: int, report) -> None:
        if self._probe is None:
            return
        name, start, w, mism_d, clean_d, xwin_d, _ = self._probe
        if not (_ready(mism_d) and _ready(clean_d)):
            self._probe_stuck += 1
            if self._probe_stuck < PROBE_FORCE_TICKS:
                return  # still in flight; at most one probe outstanding
            # Stuck past any plausible execution time: force the (tiny)
            # fetch instead of trusting a readiness notification that may
            # never arrive — see PROBE_FORCE_TICKS.
            np.asarray(mism_d), np.asarray(clean_d)
        self._probe_stuck = 0
        self._probe = None
        inval, self._probe_inval = self._probe_inval, None
        if self.rebuild is not None and self.rebuild.name == name:
            # Dispatched before the loss was declared: its verdicts are
            # about pre-rebuild garbage.  Drop it wholesale (the next sweep
            # re-covers the window).
            return
        meta = self.store.metas[name]
        k = self.store.shard_factor(name)
        m = np.asarray(mism_d).reshape(k, w)
        c = np.asarray(clean_d).reshape(k, w)
        report.patrol_mismatches += int(m.sum())
        lost_shards = self._detect_loss(name, m, c, out)
        for s in range(k):
            if s in lost_shards:
                continue
            for j in np.flatnonzero(m[s]):
                self._on_detection(name, s * meta.n_blocks + start + int(j),
                                   step, report)
        # Adopt the probe's fold into cross-shard parity for rows every
        # shard saw clean and matching (skip entirely once a shard is
        # wholesale-suspect: its lanes are garbage, not parity capital).
        if name in self.xpar and xwin_d is not None and not lost_shards:
            ok = c.all(axis=0) & ~m.any(axis=0)
            if inval is not None:
                # Rows written after dispatch (per the samples processed
                # while this probe was in flight): the slab predates them.
                ok &= ~inval[start:start + w]
            if ok.any():
                xp = self.xpar[name]
                xp.xpar = self.jit(
                    ("xadopt", name, w),
                    _make_adopt(w, meta.lanes_per_block))(
                        xp.xpar, xwin_d, jnp.asarray(ok), np.int32(start))
                xp.xvalid[start:start + w] |= ok

    def _detect_loss(self, name: str, m: np.ndarray,
                     c: np.ndarray, out) -> set:
        """Wholesale-corrupt shard heuristic: within one probe window, a
        shard whose mismatches dominate its clean blocks is lost, not
        bitflipped — queue a rebuild instead of per-block repairs."""
        pol = self.store.policy
        lost = set()
        if name not in self.xpar:
            return lost      # no rebuild substrate; treat per-block
        for s in range(m.shape[0]):
            mm, cc = int(m[s].sum()), int(c[s].sum())
            if cc and mm >= max(pol.shard_loss_min_blocks,
                                math.ceil(pol.shard_loss_threshold * cc)):
                lost.add(s)
                try:
                    self.declare_shard_lost(name, s, out)
                except (ValueError, ShardLossConflictError):
                    # No parity substrate, or a second shard of a leaf
                    # already mid-rebuild: fall back to per-block handling
                    # (the probe's detections stand on their own).
                    lost.discard(s)
        return lost

    def _on_detection(self, name: str, gblock: int, step: int,
                      report) -> None:
        key = (name, gblock)
        if key in self._detected:
            return
        self._detected.add(key)
        if self._attempts.get(key, 0) >= MAX_REPAIR_ATTEMPTS:
            # Re-detected after repeated "successful" repairs: the stripe's
            # parity was refreshed over the corrupt data (vulnerability
            # window hit) and reconstruction keeps reproducing garbage.
            u = vulnerable_unrecoverable(self.store.metas, [(name, gblock)])
            self.unrecoverable.extend(u)
            report.unrecoverable = report.unrecoverable + tuple(u)
            return
        inj = self._expected.pop(key, None)
        lat = (step - inj) if inj is not None else None
        if lat is not None:
            self.latencies.append(int(lat))
        self.detections.append(DetectionEvent(name, gblock, int(step), lat))
        self._repair_queue.append([name, gblock, 0])

    def _start_rebuild(self, leaves, out, step: int) -> None:
        name, shard, preloss = self._pending_loss.pop(0)
        # Shard-wide garbage invalidates every queued per-block judgment
        # about this leaf; the rebuild re-establishes it wholesale and
        # later probes re-detect anything still wrong — with a fresh
        # attempt budget (stale counts would declare a post-rebuild
        # re-detection unrecoverable prematurely).
        self._repair_queue = [e for e in self._repair_queue if e[0] != name]
        self._detected = {d for d in self._detected if d[0] != name}
        self._attempts = {k: v for k, v in self._attempts.items()
                          if k[0] != name}
        try:
            self.rebuild = ShardRebuilder(self, name, shard,
                                          leaves, out, step, preloss)
        except RuntimeError as e:     # not primed yet: retry next tick
            warnings.warn(str(e), RuntimeWarning, stacklevel=2)
            self._pending_loss.append((name, shard, preloss))

    def _run_repairs(self, lv, out, report) -> None:
        budget = max(1, int(self.store.policy.patrol_repair_per_tick))
        by_leaf: Dict[str, List[int]] = {}
        for name, gb, _ in self._repair_queue:
            by_leaf.setdefault(name, []).append(gb)
        singles, multi = plan_stripe_repairs(self.store.metas, by_leaf)
        if multi:
            # >= 2 detections sharing a parity group: XOR cannot repair.
            bad = {(u.leaf, b) for u in multi for b in u.blocks}
            self._repair_queue = [e for e in self._repair_queue
                                  if (e[0], e[1]) not in bad]
            self.unrecoverable.extend(multi)
            report.unrecoverable = report.unrecoverable + tuple(multi)
        take = singles[:budget]
        if not take:
            return
        leaves = lv()
        repaired, fixed, vulnerable = repair_blocks(
            self.store, leaves, out, take)
        for name, gb in fixed:
            self.adopt_repair(name, self._repin(name, repaired[name]),
                              leaves, report)
            self._repair_queue = [e for e in self._repair_queue
                                  if (e[0], e[1]) != (name, gb)]
            # Success is provisional (see MAX_REPAIR_ATTEMPTS): forget the
            # detection so the next sweep can re-flag it if reconstruction
            # reproduced garbage.
            self._detected.discard((name, gb))
            self._attempts[(name, gb)] = self._attempts.get((name, gb),
                                                            0) + 1
        vul = set(vulnerable)
        drop: List[UnrecoverableBlock] = []
        for e in self._repair_queue:
            if (e[0], e[1]) in vul:
                e[2] += 1
                if e[2] > MAX_REPAIR_ATTEMPTS:
                    drop.extend(vulnerable_unrecoverable(
                        self.store.metas, [(e[0], e[1])]))
        if drop:
            gone = {(u.leaf, u.blocks[0]) for u in drop}
            self._repair_queue = [e for e in self._repair_queue
                                  if (e[0], e[1]) not in gone]
            self.unrecoverable.extend(drop)
            report.unrecoverable = report.unrecoverable + tuple(drop)


def _make_adopt(w: int, lanes: int):
    """Window adoption into the cross-shard parity image."""
    def adopt(xpar, xwin, ok, start):
        cur = jax.lax.dynamic_slice(xpar, (start, jnp.int32(0)), (w, lanes))
        new = jnp.where(ok[:, None], xwin, cur)
        return jax.lax.dynamic_update_slice(xpar, new,
                                            (start, jnp.int32(0)))
    return adopt
