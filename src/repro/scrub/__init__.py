"""Scrub patroller + online shard rebuild (docs/api.md, docs/perf.md).

Continuous low-priority verification of protected state between the
paper's scheduled full scrubs, plus reconstruction of a lost shard from
cross-shard parity while the foreground keeps running.  Enabled via
``RedundancyPolicy.patrol_bytes_per_tick``; see :mod:`repro.scrub.patrol`.
"""
from .patrol import (MAX_REPAIR_ATTEMPTS, DetectionEvent, ScrubPatroller,
                     ShardLossConflictError)
from .rebuild import (CrossShardParity, RebuildStatus, ShardRebuilder,
                      pack_mask_np)

__all__ = [
    "ScrubPatroller", "DetectionEvent", "MAX_REPAIR_ATTEMPTS",
    "ShardRebuilder", "RebuildStatus", "CrossShardParity", "pack_mask_np",
    "ShardLossConflictError",
]
