"""Online shard rebuild from cross-shard parity.

Shard-local XOR stripes (the paper's parity) correct a single block per
stripe — useless when a whole shard's data is lost or wholesale-corrupt
(device dropout, firmware scribble over one host's DAX range).  For that
failure domain the patroller maintains a second, orthogonal parity layer
per eligible leaf: **cross-shard parity** (``xpar``), one XOR row per
*local* block folding the same-indexed block of every shard.  Losing shard
``s`` then rebuilds block ``b`` as ``xpar[b] XOR (XOR of the surviving
shards' block b)`` — provided no shard wrote block ``b`` since its row was
refreshed.

Freshness is tracked host-side (``xvalid``) by the patroller's per-tick
write sampling plus an exact ``dirty | shadow`` fetch at rebuild start and
at every rebuild tick (writes land before the tick, so the fetch at tick
``t`` sees every mark through step ``t`` — no rebuilt paste can clobber a
foreground write).  Marks already live on the lost shard *at loss
declaration* are a separate class: those writes were in flight when the
shard died, so their data died with it — the ``preloss`` snapshot
(captured by ``declare_shard_lost`` when the caller passes ``red``, else
conservatively at rebuild construction) keeps them out of ``written``
until the mark is observed to clear once; only a mark that *appears*
after the snapshot is a foreground rewrite.  Blocks classified per
window:

* **rebuilt** — ``xvalid`` row, pasted from the reconstruction and marked
  dirty so the normal Algorithm-1 pipeline regenerates their shard-local
  redundancy (no direct checksum/parity surgery racing in-flight updates);
* **fresh** — rewritten by the foreground since the rebuild started; the
  new data supersedes the loss and its redundancy flows through the normal
  dirty path;
* **unrecoverable** — stale ``xpar`` row and never rewritten (including
  blocks already dirty at loss time: their pre-loss writes died with the
  shard).  Reported structurally and *also* marked dirty, so redundancy
  re-converges over the garbage (accepted, named loss) instead of alarming
  forever.

The per-tick paste window is bounded by ``rebuild_bytes_per_tick``
(default 4x the patrol budget) — the foreground stall per tick is one
bounded slice program plus a bitvector fetch, never a full-leaf pass.  The
one full-leaf read happens once, at rebuild start, to freeze the
surviving shards' XOR (so later survivor writes cannot skew the
reconstruction).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import blocks
from repro.core.repairs import UnrecoverableBlock


@dataclasses.dataclass
class CrossShardParity:
    """Per-leaf cross-shard parity: ``xpar[b]`` = XOR over shards of local
    block ``b``'s lanes; ``xvalid[b]`` = no shard wrote block ``b`` since
    the row was refreshed (host-tracked, conservatively invalidated)."""
    name: str
    n_blocks: int
    xpar: Optional[jax.Array] = None         # uint32 (n_blocks, lanes)
    xvalid: Optional[np.ndarray] = None      # bool (n_blocks,)
    # Mesh-geometry epoch this image was folded under; a remesh bumps the
    # store's version and discards images from the old geometry (a row
    # folded across k shards is meaningless once k changes).
    version: int = 0

    def __post_init__(self):
        if self.xvalid is None:
            self.xvalid = np.zeros((self.n_blocks,), bool)


@dataclasses.dataclass
class RebuildStatus:
    """Progress of one online shard rebuild (surfaced on ``TickReport``)."""
    leaf: str
    shard: int
    total_blocks: int
    started_step: int
    rebuilt: int = 0
    fresh: int = 0
    lost: int = 0
    ticks: int = 0
    done: bool = False


def xor_fold(stack):
    """XOR-fold a ``(k, ...)`` stack over dim0 (cross-shard parity).

    Unrolled elementwise XOR rather than ``lax.reduce``: dim0 is the
    sharded axis, and a custom-computation cross-device reduce is
    unsupported on some backends — elementwise XOR of the (static, small)
    ``k`` slices lowers everywhere.  This belongs to the tiny cross-shard
    host programs, deliberately outside the collective-free per-shard rule
    (the per-shard fit flags, by contrast, never even need one: the store
    AND-folds their fetched row on the host).
    """
    out = stack[0]
    for i in range(1, stack.shape[0]):
        out = out ^ stack[i]
    return out


def pack_mask_np(mask: np.ndarray, n_words: int) -> np.ndarray:
    """Host-side pack of a bool block mask into uint32 words (bit ``i`` of
    word ``j`` = block ``j*32+i`` — the :mod:`repro.core.bits` layout)."""
    padded = np.zeros((n_words * 32,), bool)
    padded[:mask.size] = mask
    w = padded.reshape(n_words, 32).astype(np.uint64)
    return (w << np.arange(32, dtype=np.uint64)).sum(
        axis=1, dtype=np.uint64).astype(np.uint32)


class ShardRebuilder:
    """One in-progress rebuild of a lost shard, paced over ticks.

    Construction blocks once: an exact freshness fetch plus the dispatch of
    the full reconstruction image ``recon = frozen_survivor_xor ^ xpar``
    (device-resident, one shard's size).  Each :meth:`step_once` pastes a
    bounded window of ``recon`` into the lost shard's slice and marks it
    dirty — everything else is the normal redundancy pipeline.
    """

    def __init__(self, patroller, name: str, shard: int,
                 leaves, red, step: int,
                 preloss: Optional[np.ndarray] = None):
        self.pat = patroller
        self.name = name
        self.shard = int(shard)
        store = patroller.store
        eng = patroller.engine_of(name)
        self.eng = eng
        self.meta = meta = store.metas[name]
        self.k = eng.shard_factor(name)
        xp = patroller.xpar.get(name)
        if xp is None or xp.xpar is None:
            raise RuntimeError(
                f"{name}: shard rebuild needs cross-shard parity "
                "(leaf not dim0-sharded, or patroller not yet primed)")
        if xp.version != patroller.geometry_version:
            raise RuntimeError(
                f"{name}: cross-shard parity is from mesh geometry epoch "
                f"{xp.version}, patroller is at {patroller.geometry_version}"
                " — stale parity cannot seed a rebuild after a remesh")
        assert 0 <= self.shard < self.k, (name, shard, self.k)
        nb = meta.n_blocks
        budget = int(store.policy.rebuild_bytes_per_tick) or (
            4 * int(store.policy.patrol_bytes_per_tick))
        self.wb = max(1, min(nb, budget // max(1, meta.bytes_per_block)))
        self.rows_local = eng.global_leaf_structs[name].shape[0] // self.k

        # Exact freshness fetch (blocking, once): a row any shard wrote
        # since its refresh cannot be rebuilt from it.
        live = self.pat.fetch_live_rows(name, red[name])    # (k, nb) bool
        xp.xvalid &= ~live.any(axis=0)
        # Pre-loss in-flight writes: marks on the lost shard at loss
        # declaration (or, without a declaration-time snapshot, every mark
        # live now).  Their data died with the shard, so they must never
        # count as foreground rewrites — the per-tick refetch re-sees the
        # same marks, and without the snapshot those blocks would be
        # misclassified "fresh" while holding scribble.  Conservative: at
        # worst a block the foreground actually rewrote inside the
        # snapshot window is reported lost while holding correct data.
        self.preloss = (live[self.shard] if preloss is None
                        else np.asarray(preloss, bool)).copy()
        self.eligible = xp.xvalid & ~self.preloss
        self.written = live[self.shard] & ~self.preloss
        # A cleared mark resolves the ambiguity: the pre-loss write was
        # consumed, so any mark that appears later is a genuine rewrite.
        self.preloss &= live[self.shard]
        self.done_mask = np.zeros((nb,), bool)
        self.lost_blocks: List[int] = []                    # local ids
        self.cur = 0
        self.status = RebuildStatus(leaf=name, shard=self.shard,
                                    total_blocks=nb, started_step=int(step))

        # Freeze the surviving shards' XOR and finish the reconstruction
        # image in one dispatch: recon[b] = (fold_all ^ lost_slab)[b] ^
        # xpar[b] = the lost shard's block b as of its row's refresh.
        stack_fn = eng.shard_lanes_fn(name)
        lost, rows_local = self.shard, self.rows_local

        def recon_of(leaf, xpar):
            stack = stack_fn(leaf)                          # (k, nb, L)
            sub = jax.lax.dynamic_slice_in_dim(
                leaf, lost * rows_local, rows_local, 0)
            return xor_fold(stack) ^ blocks.to_lanes(sub, meta) ^ xpar

        self.recon = self.pat.jit(("recon", name, self.shard),
                                  recon_of)(leaves[name], xp.xpar)

    # ------------------------------------------------------------------ tick
    def step_once(self, leaves, out, report, step: Optional[int]) -> None:
        """Paste one bounded window; updates ``out`` (dirty marks) and
        ``report`` (repaired leaf + status) in place via the patroller.

        ``step`` is None when driven from a stepless drain (``settle()``
        without a step); the crash phase then omits the kwarg so the
        crash machine's own step counter fills it in."""
        meta, nb = self.meta, self.meta.n_blocks
        self.status.ticks += 1
        # Per-tick exact freshness fetch: marks through this step are
        # visible (writes precede the tick), so a block the foreground
        # rewrote is never pasted over.  Only marks that appeared after
        # the pre-loss snapshot count as rewrites (a carried-over mark is
        # an in-flight write whose data died with the shard).
        live = self.pat.fetch_live_rows(self.name, out[self.name])
        now = live[self.shard]
        self.written |= now & ~self.preloss
        self.preloss &= now

        start = min(self.cur, max(0, nb - self.wb))
        ids = np.arange(start, start + self.wb)
        fresh_ids = ids[~self.done_mask[ids] & self.written[ids]]
        ok = np.zeros((nb,), bool)
        lost_now = np.zeros((nb,), bool)
        sel = ids[~self.done_mask[ids] & ~self.written[ids]]
        ok[sel[self.eligible[sel]]] = True
        lost_now[sel[~self.eligible[sel]]] = True
        self.done_mask[ids] = True
        self.lost_blocks.extend(int(b) for b in np.flatnonzero(lost_now))
        self.status.rebuilt += int(ok.sum())
        self.status.fresh += int(fresh_ids.size)
        self.status.lost += int(lost_now.sum())

        leaf2 = self._write_fn()(leaves[self.name], self.recon,
                                 jnp.asarray(ok[ids]), np.int32(start))
        # Rebuilt *and* unrecoverable blocks go dirty: Algorithm 1 then
        # regenerates shard-local checksums/parity through the normal
        # pipeline (rebuilt = correct redundancy; lost = consistent
        # redundancy over the reported garbage, so scrub stops alarming).
        mark = ok | lost_now
        if mark.any():
            words = jnp.asarray(pack_mask_np(mark, meta.n_dirty_words))
            r = out[self.name]
            out[self.name] = dataclasses.replace(
                r, dirty=self._mark_fn()(r.dirty, words))
        self.pat.adopt_repair(self.name, leaf2, leaves, report)

        self.cur = start + self.wb
        if self.cur >= nb:
            self.status.done = True
        report.rebuild = self.status
        self.pat.store._phase("rebuild_paste", red=dict(out),
                              **({} if step is None else {"step": int(step)}),
                              leaf=self.name, shard=self.shard,
                              window=(int(start), int(start + self.wb)))

    def unrecoverable(self) -> List[UnrecoverableBlock]:
        """Structured loss records (global ids), grouped by parity stripe."""
        meta, per = self.meta, {}
        for b in self.lost_blocks:
            gb = self.shard * meta.n_blocks + b
            per.setdefault(blocks.global_stripe_id(meta, gb), []).append(gb)
        return [UnrecoverableBlock(self.name, s, tuple(bs), "shard_loss")
                for s, bs in sorted(per.items())]

    # ------------------------------------------------------------- programs
    def _write_fn(self):
        """Window paste into the lost shard's slice, pinned to the leaf's
        sharding (a free-floating output would make the precompiled update
        programs reject the live view)."""
        meta, wb = self.meta, self.wb
        lost, rows_local = self.shard, self.rows_local

        def write_window(leaf, recon, ok, start):
            sub = jax.lax.dynamic_slice_in_dim(
                leaf, lost * rows_local, rows_local, 0)
            lanes = blocks.to_lanes(sub, meta)
            cur = jax.lax.dynamic_slice(
                lanes, (start, jnp.int32(0)), (wb, meta.lanes_per_block))
            new = jax.lax.dynamic_slice(
                recon, (start, jnp.int32(0)), (wb, meta.lanes_per_block))
            lanes = jax.lax.dynamic_update_slice(
                lanes, jnp.where(ok[:, None], new, cur),
                (start, jnp.int32(0)))
            sub = blocks.from_lanes(lanes, meta)
            return jax.lax.dynamic_update_slice_in_dim(
                leaf, sub, lost * rows_local, 0)

        kw = {}
        if self.eng.mesh is not None:
            from jax.sharding import NamedSharding
            spec = self.eng.specs.get(self.name)
            if spec is not None:
                kw["out_shardings"] = NamedSharding(self.eng.mesh, spec)
        return self.pat.jit(("rebuild_write", self.name, self.shard, wb),
                            write_window, **kw)

    def _mark_fn(self):
        """OR a packed block mask into the lost shard's dirty words."""
        nw, lost = self.meta.n_dirty_words, self.shard

        def mark(dirty, mask_words):
            seg = jax.lax.dynamic_slice_in_dim(dirty, lost * nw, nw, 0)
            return jax.lax.dynamic_update_slice_in_dim(
                dirty, seg | mask_words, lost * nw, 0)

        kw = {}
        if self.eng.mesh is not None:
            kw["out_shardings"] = self.eng.red_shardings()[self.name].dirty
        return self.pat.jit(("rebuild_mark", self.name, self.shard),
                            mark, **kw)
