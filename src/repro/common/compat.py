"""Version-compat shims for JAX API drift."""
from __future__ import annotations

import inspect

import jax

try:  # JAX >= 0.4.35 stable API
    _shard_map = jax.shard_map
except AttributeError:  # pragma: no cover
    from jax.experimental.shard_map import shard_map as _shard_map  # type: ignore

# Replica/VMA checking kwarg was renamed check_rep -> check_vma across JAX
# versions; disable it under whichever name this JAX spells it.
_CHECK_KW = ("check_vma" if "check_vma" in
             inspect.signature(_shard_map).parameters else "check_rep")


def shard_map(fn, **kw):
    kw.pop("check_vma", None)
    return _shard_map(fn, **{**kw, _CHECK_KW: False})
