"""Path-keyed flattening of nested param/state dicts."""
from __future__ import annotations

from typing import Any, Dict


def flatten_dict(tree: Any, sep: str = "/", prefix: str = "") -> Dict[str, Any]:
    out: Dict[str, Any] = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(flatten_dict(tree[k], sep, f"{prefix}{k}{sep}"))
    else:
        out[prefix[: -len(sep)] if prefix else ""] = tree
    return out


def unflatten_dict(flat: Dict[str, Any], sep: str = "/") -> Any:
    tree: Dict[str, Any] = {}
    for path, leaf in flat.items():
        parts = path.split(sep)
        node = tree
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = leaf
    return tree
