from .flatten import flatten_dict, unflatten_dict
