"""Elastic remesh: online shard grow/shrink via incremental re-striping.

``ProtectedStore.remesh(new_mesh)`` migrates every protected leaf onto a
grown or shrunk device mesh over bounded per-tick windows — no
stop-the-world re-attach; see :mod:`repro.remesh.migrate` and docs/api.md.
"""
from .migrate import (RemeshError, RemeshGeometryError,
                      RemeshInProgressError, RemeshMigrator, RemeshStatus,
                      translate_marks, validate_remesh)

__all__ = [
    "RemeshError", "RemeshGeometryError", "RemeshInProgressError",
    "RemeshMigrator", "RemeshStatus", "translate_marks", "validate_remesh",
]
