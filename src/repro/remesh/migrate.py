"""Incremental re-striping of protected state onto a new device mesh.

Real deployments resize: a pod gains devices (grow) or loses a rack
(shrink).  Re-attaching the store on the new mesh would stop the world for
a full-leaf redundancy recompute; this module instead migrates **online**,
riding the same bounded-window discipline as the online shard rebuild
(:mod:`repro.scrub.rebuild`):

1. **Start** (one tick): every leaf is ``device_put`` onto the new mesh
   (value-identical — data never transforms, only its sharding), and
   zero-initialised new-geometry redundancy is laid out per the new
   shardings.  Zeros are safe capital: Algorithm 1 recomputes checksums
   *from data* for dirty blocks and whole-stripe parity *from data* for
   dirty stripes, so windows fill the arrays in without ever reading the
   zeros as truth.  The ``meta_ck`` seed is the checksum-of-checksums of
   the zero page (consistent by construction, kept consistent by every
   windowed update).
2. **Migrate** (bounded ticks): per leaf, a cursor walks the new *local*
   block space; each tick marks one window of ``remesh_bytes_per_tick``
   bytes dirty in the new bitvectors and dispatches the new engine's
   Algorithm-1 program (work-queue variant when the window fits, full
   fallback otherwise — counted in ``RemeshStatus.overflowed``).  Cost per
   tick tracks the window, never the leaf: the pinned bound is
   ``ticks == max_leaf ceil(n_blocks / window)``.
3. **Adopt** (the tick the last window lands): the OLD redundancy —
   frozen during migration except for ``on_write`` marks, and therefore
   crash-authoritative throughout — is read once, and every old
   ``dirty | shadow`` mark is translated into new-geometry dirty marks
   (:func:`translate_marks`), so writes that raced the migration re-enter
   the normal pipeline instead of leaving stale new redundancy.  Blocks
   the old cross-shard parity layer could not vouch quiescent at
   migration start (``xvalid`` False) are conservatively re-marked too —
   the freshness tracking seeds the handover (``RemeshStatus.
   xpar_seeded`` counts the rows it vouched for).  Then the store swaps
   wholesale: mesh, engines/groups, jit caches, and a **fresh patroller**
   under a bumped ``geometry_version`` — cross-shard parity folded across
   the old shard count is meaningless on the new one, so old images are
   discarded, never reinterpreted.

Crash story: until adoption the old red is the only truth — a crash
persists value-identical leaves plus old-geometry redundancy, and restart
recovers on the old mesh exactly as if the remesh had never been asked
for.  The ``remesh_migrate`` crash phase fires after every window with
that old view.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Mapping, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.common.compat import shard_map
from repro.core import checksum
from repro.core.engine import RedundancyEngine, _local_shape
from repro.core.blocks import make_meta
from repro.core.state import LeafRedundancy
from repro.faults.inject import bits_to_mask
from repro.scrub.rebuild import pack_mask_np


class RemeshError(RuntimeError):
    """Base class for elastic-remesh failures."""


class RemeshInProgressError(RemeshError):
    """A remesh is already queued or actively migrating."""


class RemeshGeometryError(RemeshError):
    """The requested mesh cannot host the attached leaves (uneven split,
    unknown axis, missing mesh, or an unsupported group mode)."""


@dataclasses.dataclass
class RemeshStatus:
    """Progress of one elastic remesh (surfaced on ``TickReport.remesh``).

    ``total_blocks``/``migrated`` count new-geometry *local* blocks (each
    window covers the same local range on every new shard in parallel);
    ``overflowed`` counts windows whose marks missed the work queue (full
    fallback ran); ``xpar_seeded`` counts old cross-shard-parity rows that
    vouched quiescence at start — rows it could not vouch re-enter the new
    geometry conservatively dirty at adoption."""
    from_shape: Tuple[int, ...]
    to_shape: Tuple[int, ...]
    total_blocks: int
    started_step: int
    migrated: int = 0
    xpar_seeded: int = 0
    ticks: int = 0
    overflowed: int = 0
    done: bool = False


def validate_remesh(store, new_mesh, specs: Mapping[str, Any]) -> None:
    """Typed pre-flight: every attached leaf must split evenly onto
    ``new_mesh`` under its spec, and every protected group must be a mode
    migration supports (``vilamb``/``none`` — ``sync`` keeps redundancy
    inline with writes and has no frozen-old-red migration story)."""
    if new_mesh is None or store.mesh is None:
        raise RemeshGeometryError(
            "elastic remesh needs a mesh on both sides (store.mesh and "
            "new_mesh); use attach() for machine-local stores")
    for g in store.groups.values():
        if g.policy.mode == "sync":
            raise RemeshGeometryError(
                f"group {g.label}: sync-mode leaves cannot remesh online "
                "(inline redundancy has no frozen-old-geometry window)")
    structs = getattr(store, "_structs", None)
    if not structs:
        raise RemeshGeometryError("store has no attached leaves to remesh")
    for name, st in structs.items():
        spec = specs.get(name)
        try:
            _local_shape(st.shape, spec, new_mesh)
        except (AssertionError, KeyError) as e:
            raise RemeshGeometryError(
                f"{name}: shape {tuple(st.shape)} does not re-stripe onto "
                f"mesh {dict(new_mesh.shape)} under spec {spec} ({e})"
            ) from e


def translate_marks(old_mask: np.ndarray, old_lanes_per_block: int,
                    new_lanes_per_block: int, new_n_blocks: int,
                    new_k: int) -> np.ndarray:
    """Translate per-block marks between block geometries through the one
    invariant space both share: global uint32 words of the flattened leaf
    (dim0 sharding keeps every shard's rows word-contiguous globally).

    ``old_mask`` is bool ``(k_old, nb_old)``; old shard ``s`` local block
    ``b`` covers global words ``[(s*nb_old + b) * L_old, ... + L_old)``.
    Returns bool ``(new_k, new_n_blocks)`` marking every new block whose
    word range intersects a marked old block — conservative by
    construction (a partial overlap marks the whole new block)."""
    old_mask = np.asarray(old_mask, bool)
    out = np.zeros((new_k * new_n_blocks,), bool)
    gb = np.flatnonzero(old_mask.reshape(-1))
    if gb.size:
        w0 = gb.astype(np.int64) * int(old_lanes_per_block)
        w1 = w0 + int(old_lanes_per_block)
        b0 = w0 // int(new_lanes_per_block)
        b1 = -(-w1 // int(new_lanes_per_block))          # ceil div
        np.clip(b0, 0, out.size, out=b0)
        np.clip(b1, 0, out.size, out=b1)
        for a, b in zip(b0, b1):
            out[a:b] = True
    return out.reshape(new_k, new_n_blocks)


class RemeshMigrator:
    """One in-progress mesh geometry change, paced over ticks.

    Construction blocks once per leaf for the ``device_put`` move (the
    moved arrays surface through ``TickReport.repaired`` — callers adopt
    them like any rebuild paste) and lays out zeroed new-geometry
    redundancy.  Each :meth:`step_once` marks one bounded window dirty in
    the new bitvectors and dispatches the new engine's Algorithm-1
    program; :meth:`adopt` performs the wholesale handover.
    """

    def __init__(self, store, new_mesh, new_specs: Mapping[str, Any],
                 leaves: Mapping[str, jax.Array], red, step: int):
        self.store = store
        self.new_mesh = new_mesh
        self.new_specs = dict(new_specs)
        pol = store.policy

        # New-geometry engines, one per protected group (same resolved
        # config — only mesh/specs change).
        self.new_engines: Dict[str, RedundancyEngine] = {}
        for g in store._protected():
            self.new_engines[g.label] = RedundancyEngine(
                {n: store._structs[n] for n in g.names}, g.engine.config,
                mesh=new_mesh,
                specs={n: self.new_specs[n] for n in g.names
                       if n in self.new_specs})

        # Move every attached leaf onto the new mesh (value-identical).
        self.moved: Dict[str, jax.Array] = {}
        for name in store._structs:
            if name not in leaves:
                continue
            self.moved[name] = jax.device_put(
                leaves[name],
                NamedSharding(new_mesh, self.new_specs.get(name, P())))

        # Zero-initialised new redundancy, pinned to the new shardings.
        # meta_ck seeds as the checksum-of-checksums of the zero page so
        # the incremental (queued) updates stay consistent from the first
        # window; everything else really is zeros (never read as truth —
        # only dirty blocks/stripes are ever recomputed-from-data into it).
        self.new_red: Dict[str, LeafRedundancy] = {}
        budget = (int(pol.remesh_bytes_per_tick)
                  or 4 * int(pol.patrol_bytes_per_tick))
        self.wb: Dict[str, int] = {}
        self.cur: Dict[str, int] = {}
        self.done_mask: Dict[str, np.ndarray] = {}
        total = 0
        for label, eng in self.new_engines.items():
            shardings = eng.red_shardings()
            for name, meta in eng.metas.items():
                kn = eng.shard_factor(name)
                nb = meta.n_blocks
                ck0 = jnp.asarray(checksum.meta_checksum(
                    jnp.zeros((nb,), jnp.uint32)), jnp.uint32)
                self.new_red[name] = jax.device_put(
                    LeafRedundancy(
                        checksums=jnp.zeros((nb * kn,), jnp.uint32),
                        parity=jnp.zeros(
                            (meta.n_stripes * kn, meta.lanes_per_block),
                            jnp.uint32),
                        dirty=jnp.zeros((meta.n_dirty_words * kn,),
                                        jnp.uint32),
                        shadow=jnp.zeros((meta.n_dirty_words * kn,),
                                         jnp.uint32),
                        meta_ck=jnp.full((kn,), ck0, jnp.uint32)),
                    shardings[name])
                self.wb[name] = (max(1, min(nb, budget
                                            // max(1, meta.bytes_per_block)))
                                 if budget else nb)
                self.cur[name] = 0
                self.done_mask[name] = np.zeros((nb,), bool)
                total += nb

        # Freshness seed from the old cross-shard parity layer: rows it
        # vouched quiescent at start need no conservative re-mark at
        # adoption; rows it could not (or leaves it never covered, when
        # the patroller tracked them) re-enter the new geometry dirty.
        self._stale0: Dict[str, np.ndarray] = {}
        seeded = 0
        pat = store.patroller
        if pat is not None:
            for name, xp in pat.xpar.items():
                if name in self.new_red and xp.xvalid is not None:
                    self._stale0[name] = ~np.asarray(xp.xvalid, bool)
                    seeded += int(np.asarray(xp.xvalid).sum())

        def mesh_dims(m):
            return tuple(int(m.shape[a]) for a in m.axis_names)

        self.status = RemeshStatus(
            from_shape=mesh_dims(store.mesh), to_shape=mesh_dims(new_mesh),
            total_blocks=total, started_step=int(step), xpar_seeded=seeded)
        self._jits: Dict[Any, Callable] = {}

    # ------------------------------------------------------------------ tick
    def step_once(self, leaves, out, report, step: Optional[int]) -> None:
        """Mark + dispatch one bounded window per unfinished leaf; fires
        the ``remesh_migrate`` crash phase with the still-authoritative
        OLD red view.  ``step`` is None from a stepless drain; the phase
        then omits the kwarg so the crash machine's counter fills it."""
        self.status.ticks += 1
        marks: Dict[str, Dict[str, jax.Array]] = {}
        for label, eng in self.new_engines.items():
            for name, meta in eng.metas.items():
                nb = meta.n_blocks
                if self.cur[name] >= nb:
                    continue
                wb = self.wb[name]
                start = min(self.cur[name], max(0, nb - wb))
                ids = np.arange(start, start + wb)
                fresh = ids[~self.done_mask[name][ids]]
                self.done_mask[name][ids] = True
                self.status.migrated += int(fresh.size)
                window = np.zeros((nb,), bool)
                window[ids] = True
                marks.setdefault(label, {})[name] = jnp.asarray(
                    pack_mask_np(window, meta.n_dirty_words))
                self.cur[name] = start + wb
        for label, wmap in marks.items():
            eng = self.new_engines[label]
            names = tuple(eng.metas)
            red_sub = {n: self.new_red[n] for n in names}
            red_sub = self._mark_fn(label, tuple(sorted(wmap)))(red_sub, wmap)
            queued = eng.has_queue and eng.queue_fits(red_sub)
            if eng.has_queue and not queued:
                self.status.overflowed += 1
            self.new_red.update(self._update_fn(label, queued)(
                {n: leaves[n] for n in names}, red_sub))
        if all(self.cur[n] >= eng.metas[n].n_blocks
               for eng in self.new_engines.values() for n in eng.metas):
            self.status.done = True
        report.remesh = self.status
        self.store._phase("remesh_migrate", red=dict(out),
                          **({} if step is None else {"step": int(step)}),
                          migrated=self.status.migrated,
                          ticks=self.status.ticks)

    # ------------------------------------------------------------- adoption
    def adopt(self, out, report) -> None:
        """Wholesale handover: translate old live marks into new dirty,
        swap mesh/engines/groups/jit caches, bump ``geometry_version``,
        rebuild the patroller fresh, and replace ``out``'s entries with
        the new-geometry redundancy."""
        from repro.core.store import _Group
        store = self.store
        for g in store._protected():
            old_eng = g.engine
            new_eng = self.new_engines[g.label]
            for name in g.names:
                old_meta = old_eng.metas[name]
                new_meta = new_eng.metas[name]
                k_old = old_eng.shard_factor(name)
                k_new = new_eng.shard_factor(name)
                r_old = out[name]
                live = bits_to_mask(
                    np.asarray(r_old.dirty) | np.asarray(r_old.shadow),
                    old_meta.n_blocks, shards=k_old
                ).reshape(k_old, old_meta.n_blocks)
                stale = self._stale0.get(name)
                if stale is not None:
                    live = live | stale[None, :]
                new_mask = translate_marks(
                    live, old_meta.lanes_per_block,
                    new_meta.lanes_per_block, new_meta.n_blocks, k_new)
                if new_mask.any():
                    words = np.concatenate([
                        pack_mask_np(new_mask[s], new_meta.n_dirty_words)
                        for s in range(k_new)])
                    r_new = self.new_red[name]
                    self.new_red[name] = dataclasses.replace(
                        r_new, dirty=jax.device_put(
                            jnp.asarray(words),
                            new_eng.red_shardings()[name].dirty))
        store.mesh = self.new_mesh
        store._specs = dict(self.new_specs)
        groups = {}
        for label, g in store.groups.items():
            eng = self.new_engines.get(label) if g.engine is not None else None
            # Carry the freshness clocks: the deadline counts from the
            # oldest unprotected write, and a migration moves data without
            # updating redundancy for post-start writes — a fresh _Group's
            # default clocks (step 0 / now) would both fire a spurious
            # steps-deadline right after adoption AND silently extend the
            # wall-clock deadline by the whole migration.
            groups[label] = _Group(label, g.policy, g.names, eng,
                                   last_update_step=g.last_update_step,
                                   last_update_time=g.last_update_time)
        store.groups = groups
        for n, meta in list(store._none_metas.items()):
            lshape = _local_shape(store._structs[n].shape,
                                  self.new_specs.get(n), self.new_mesh)
            store._none_metas[n] = make_meta(
                jax.ShapeDtypeStruct(lshape, store._structs[n].dtype),
                lanes_per_block=store.policy.lanes_per_block,
                stripe_data_blocks=store.policy.stripe_data_blocks)
        store._jit_update = {}
        store._jit_scrub = {}
        store._jit_misc = {}
        store.geometry_version += 1
        store.patroller = None
        if store.policy.patrol_bytes_per_tick > 0 and any(
                g.policy.mode == "vilamb" for g in store._protected()):
            from repro.scrub import ScrubPatroller
            store.patroller = ScrubPatroller(store)
        out.update(self.new_red)
        report.remesh = self.status

    # ------------------------------------------------------------- programs
    def _mark_fn(self, label: str, names: Tuple[str, ...]) -> Callable:
        """OR the same packed local block mask into every new shard's
        dirty words (per-shard under shard_map, collective-free — the
        window covers the same local range on every shard)."""
        key = ("mark", label, names)
        fn = self._jits.get(key)
        if fn is None:
            eng = self.new_engines[label]

            def local(red_l, wmap):
                o = dict(red_l)
                for n, w in wmap.items():
                    o[n] = dataclasses.replace(
                        red_l[n], dirty=red_l[n].dirty | w)
                return o

            specs = {n: eng.red_spec(n) for n in eng.metas}
            fn = self._jits[key] = jax.jit(shard_map(
                local, mesh=self.new_mesh,
                in_specs=(specs, {n: P() for n in names}),
                out_specs=specs, check_vma=False))
        return fn

    def _update_fn(self, label: str, queued: bool) -> Callable:
        """Jitted new-engine Algorithm-1 program (donates the migrating
        red — the migrator owns it exclusively until adoption)."""
        key = ("update", label, queued)
        fn = self._jits.get(key)
        if fn is None:
            eng = self.new_engines[label]
            step = (eng.redundancy_step_queued if queued
                    else eng.redundancy_step)
            fn = self._jits[key] = jax.jit(step, donate_argnums=(1,))
        return fn
