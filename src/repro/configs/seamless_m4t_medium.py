"""seamless-m4t-medium [audio] — encoder-decoder, multimodal frontend stub.

12L (encoder) + 12L (decoder) d_model=1024 16H (MHA kv=16) d_ff=4096
vocab=256206 [arXiv:2308.11596; hf]. The speech frontend is a stub per the
assignment: input_specs() provides precomputed frame embeddings
(B, enc_len, d).
"""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="audio",
    n_layers=12,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab_size=256206,
    norm="layernorm",
    activation="gelu",
    enc_dec=True,
    frontend="audio",
)

SMOKE = dataclasses.replace(
    CONFIG, name="seamless-smoke", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=4, d_ff=128, vocab_size=512,
)
