from .registry import ARCHS, get_arch, get_smoke, list_archs
