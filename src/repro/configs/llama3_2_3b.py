"""llama3.2-3b [dense] — small llama3; tied embeddings, RoPE theta 5e5.

28L d_model=3072 24H (GQA kv=8) d_ff=8192 vocab=128256
[hf:meta-llama/Llama-3.2-1B family; unverified].
"""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama3.2-3b",
    family="dense",
    n_layers=28,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=128256,
    norm="rmsnorm",
    activation="swiglu",
    rope_theta=500000.0,
    tie_embeddings=True,
)

SMOKE = dataclasses.replace(
    CONFIG, name="llama3.2-smoke", n_layers=3, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=128, vocab_size=512,
)
