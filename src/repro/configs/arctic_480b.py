"""arctic-480b [moe] — 128 experts top-2 + dense residual path.

35L d_model=7168 56H (GQA kv=8) d_ff=4864 vocab=32000
[hf:Snowflake/snowflake-arctic-base; hf].
"""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    family="moe",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=4864,
    vocab_size=32000,
    n_experts=128,
    top_k=2,
    moe_d_ff=4864,
    dense_residual=True,
    norm="rmsnorm",
    activation="swiglu",
    moment_dtype="bfloat16",   # 480B: HBM budget (DESIGN.md §6)
)

SMOKE = dataclasses.replace(
    CONFIG, name="arctic-smoke", n_layers=3, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=96, moe_d_ff=96, vocab_size=512, n_experts=8, top_k=2,
    moment_dtype="float32",
)
