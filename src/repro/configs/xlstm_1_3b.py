"""xlstm-1.3b [ssm] — mLSTM + sLSTM blocks at 7:1, no FFN (d_ff=0).

48L d_model=2048 4H vocab=50304 [arXiv:2405.04517; unverified]. O(1)
recurrent state -> runs long_500k.
"""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    ssm_kind="xlstm",
    slstm_every=8,
    norm="layernorm",
    activation="gelu",
)

SMOKE = dataclasses.replace(
    CONFIG, name="xlstm-smoke", n_layers=8, d_model=64, n_heads=4,
    n_kv_heads=4, d_ff=0, vocab_size=512,
)
