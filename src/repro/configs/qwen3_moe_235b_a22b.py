"""qwen3-moe-235b-a22b [moe] — 128 experts, top-8.

94L d_model=4096 64H (GQA kv=4) d_ff=1536 (expert width) vocab=151936
[hf:Qwen/Qwen3-30B-A3B scaled family; hf].
"""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    n_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    d_ff=1536,
    vocab_size=151936,
    n_experts=128,
    top_k=8,
    moe_d_ff=1536,
    norm="rmsnorm",
    activation="swiglu",
    moment_dtype="bfloat16",
)

SMOKE = dataclasses.replace(
    CONFIG, name="qwen3-moe-smoke", n_layers=4, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=96, moe_d_ff=96, vocab_size=512, n_experts=8, top_k=2,
    moment_dtype="float32",
)
