"""nemotron-4-15b [dense] — GQA + squared-ReLU MLP.

32L d_model=6144 48H (GQA kv=8) d_ff=24576 vocab=256000
[arXiv:2402.16819; unverified].
"""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-15b",
    family="dense",
    n_layers=32,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=24576,
    vocab_size=256000,
    norm="layernorm",
    activation="squared_relu",
)

SMOKE = dataclasses.replace(
    CONFIG, name="nemotron-smoke", n_layers=3, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=128, vocab_size=512,
)
