"""Architecture registry: --arch <id> resolves here."""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.models.config import ModelConfig

_ARCH_MODULES = [
    "jamba_1_5_large_398b",
    "qwen3_moe_235b_a22b",
    "arctic_480b",
    "internvl2_1b",
    "olmo_1b",
    "nemotron_4_15b",
    "glm4_9b",
    "llama3_2_3b",
    "seamless_m4t_medium",
    "xlstm_1_3b",
]

ARCHS: Dict[str, str] = {}
for _m in _ARCH_MODULES:
    mod = importlib.import_module(f"repro.configs.{_m}")
    ARCHS[mod.CONFIG.name] = _m


def list_archs() -> List[str]:
    return list(ARCHS)


def get_arch(name: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{ARCHS[name]}")
    return mod.CONFIG


def get_smoke(name: str) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    mod = importlib.import_module(f"repro.configs.{ARCHS[name]}")
    return mod.SMOKE
