"""glm4-9b [dense] — RoPE, GQA kv=2.

40L d_model=4096 32H (GQA kv=2) d_ff=13696 vocab=151552 [hf:THUDM/glm-4-9b; hf].
"""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="glm4-9b",
    family="dense",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=2,
    d_ff=13696,
    vocab_size=151552,
    norm="rmsnorm",
    activation="swiglu",
)

SMOKE = dataclasses.replace(
    CONFIG, name="glm4-smoke", n_layers=3, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=128, vocab_size=512,
)
