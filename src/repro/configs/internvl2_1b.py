"""internvl2-1b [vlm] — InternViT frontend (stub) + Qwen2-0.5B-family LM.

24L d_model=896 14H (GQA kv=2) d_ff=4864 vocab=151655 [arXiv:2404.16821; hf].
Frontend is a stub per the assignment: input_specs() provides precomputed
patch embeddings (B, 256, d). 14 heads do not divide the 16-way TP axis, so
attention projections fall back to replicated TP (DESIGN.md §6).
"""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-1b",
    family="vlm",
    n_layers=24,
    d_model=896,
    n_heads=14,
    n_kv_heads=2,
    d_ff=4864,
    vocab_size=151655,
    norm="rmsnorm",
    activation="swiglu",
    frontend="vision",
    frontend_len=256,
)

SMOKE = dataclasses.replace(
    CONFIG, name="internvl2-smoke", n_layers=3, d_model=56, n_heads=14,
    n_kv_heads=2, d_ff=96, vocab_size=512, frontend_len=16,
)
