"""jamba-1.5-large-398b [hybrid] — Mamba+attention 1:7 interleave + MoE.

72L d_model=8192 64H (GQA kv=8) d_ff=24576 vocab=65536, MoE 16e top-2
[arXiv:2403.19887; hf]. MoE on every 2nd layer (as in Jamba), which lands
the analytic parameter count at ~398B. Sub-quadratic (runs long_500k).
"""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=24576,
    vocab_size=65536,
    n_experts=16,
    top_k=2,
    moe_every=2,
    attn_every=8,
    ssm_kind="mamba",
    d_state=16,
    d_conv=4,
    norm="rmsnorm",
    activation="swiglu",
    moment_dtype="bfloat16",   # 398B: fp32 moments exceed v5e HBM
)

SMOKE = dataclasses.replace(
    CONFIG, name="jamba-smoke", n_layers=16, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=128, vocab_size=512, n_experts=4, top_k=2,
    moment_dtype="float32",
)
