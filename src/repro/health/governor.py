"""Freshness-SLO health governor: breaker state machine + escalation ladder.

The paper's headline contract is a *tunable knob between performance and
quicker redundancy* — ``max_vulnerable_steps``/``_seconds`` on
:class:`repro.core.RedundancyPolicy`.  Without enforcement that knob is
best-effort: a wedged async dispatch, a straggler storm, or a
rebuild/remesh monopolizing the tick ladder can silently blow the
deadline.  The :class:`HealthGovernor` is the enforcement layer.  It is
owned by :class:`repro.core.ProtectedStore` (constructed in ``attach``
when ``policy.health`` is set; ``None`` by default — zero overhead when
off) and hooks the tick at three points: ``begin_tick`` (reset per-tick
scratch), the per-group ladder probes inside the group loop, and
``end_tick`` (age audit, breaker transitions, ``TickReport.health``).

Per monitored (vilamb) group it tracks:

* **vulnerability age** — steps and wall-clock since the group's last
  adopted redundancy update (the store's ``last_update_step/_time``
  clocks, which PR 8 also carries across remesh adoption),
* **in-flight dispatch latency** — wall-clock age of the group's
  ``_Pending`` async update,
* **starvation** — patrol starvation streak and active rebuild/remesh,
  surfaced on :class:`HealthReport` for operators and the autotuner.

and drives a per-group breaker ``HEALTHY -> DEGRADED -> CRITICAL`` with
hysteresis on recovery (``recovery_ticks`` calm ticks step the breaker
*down one level*; escalation is immediate).  The escalation ladder:

1. **retry** — a pending older than ``dispatch_timeout_s`` whose fit
   flags are still not ready is abandoned (the group's freshness clocks
   roll back to their pre-dispatch values so the deadline keeps counting
   from the oldest unprotected write) and re-dispatched after a bounded
   exponential backoff (:mod:`repro.health.backoff`), at most
   ``dispatch_retry_attempts`` times within ``retry_total_s``;
2. **forced resolve** — within ``deadline_margin_steps``/``_s`` of the
   deadline the tick stops speculating: the in-flight update is resolved
   blocking and a fresh update dispatched, so the deadline is met *early*
   rather than missed;
3. **backpressure** — once rung 1 exhausts (or the deadline is actually
   violated) foreground writes are admission-controlled in ``on_write``:
   ``backpressure="error"`` raises :class:`BackpressureError`,
   ``"spin"`` applies a bounded per-write sleep (``backpressure_spin_s``)
   so the device can drain.  Host-side only — under a jax trace
   admission is a no-op (the jitted step never blocks);
4. **sync escalation** — the group temporarily abandons the async
   pipeline and runs a blocking update *every tick* (the sync-policy
   equivalent for vilamb groups: zero vulnerability window at the cost
   of per-tick stall) until the breaker recovers to HEALTHY.

Every rung fires a :class:`HealthAction` and every breaker transition is
surfaced on ``TickReport.health`` (:class:`HealthReport`).  Only when the
ladder is exhausted and a group's age still exceeds its deadline does the
governor raise :class:`FreshnessViolationError`
(``violation_mode="raise"``) or record it on ``HealthReport.violations``
(``"report"``) — a deadline miss is *never* silent.

During an elastic remesh the store's group loop is skipped wholesale
(old-geometry redundancy is authoritative until adoption) — the one
window where the ladder above cannot run.  With ``remesh_drain=True``
(default) the governor closes it: when a group's margin expires
mid-migration the remaining migration windows are drained synchronously
this tick, adoption runs, and overdue groups get a blocking update —
trading the bounded-window guarantee for the freshness SLO.  With
``remesh_drain=False`` the migration keeps its bound and the governor
reports the violation instead.
"""
from __future__ import annotations

import dataclasses
import random
import time
from typing import Any, Dict, List, Optional, Tuple

from repro.health.backoff import backoff_delay

__all__ = [
    "HEALTHY", "DEGRADED", "CRITICAL", "BREAKER_STATES",
    "HealthPolicy", "HealthAction", "HealthReport",
    "BackpressureError", "FreshnessViolation", "FreshnessViolationError",
    "HealthGovernor",
]

HEALTHY = "healthy"
DEGRADED = "degraded"
CRITICAL = "critical"
BREAKER_STATES = (HEALTHY, DEGRADED, CRITICAL)
_LEVEL = {HEALTHY: 0, DEGRADED: 1, CRITICAL: 2}


@dataclasses.dataclass(frozen=True)
class HealthPolicy:
    """Governor knobs (``RedundancyPolicy.health``; see docs/api.md)."""
    # Rung 1: wedged-dispatch timeout + bounded exponential retry backoff.
    # The backoff knobs are shared semantics with read_verified's
    # read_retry_* knobs (both draw from repro.health.backoff).
    dispatch_timeout_s: float = 0.5        # 0 disables rung 1
    dispatch_retry_attempts: int = 3
    retry_backoff_s: float = 0.005
    retry_backoff_cap_s: float = 0.1
    retry_jitter_frac: float = 0.25
    retry_total_s: float = 0.5
    # Rung 2: force a blocking resolve this many steps / seconds *before*
    # the group's max_vulnerable_* deadline would expire.
    deadline_margin_steps: int = 1
    deadline_margin_s: float = 0.0
    # Rung 3: foreground admission control once the breaker is CRITICAL.
    backpressure: str = "spin"             # none | error | spin
    backpressure_spin_s: float = 0.002
    # Rung 4: blocking update every tick until recovery.
    sync_escalation: bool = True
    # Hysteresis: calm ticks required to step the breaker down one level.
    recovery_ticks: int = 3
    # Mid-remesh enforcement: drain the migration when a margin expires
    # (True) vs keep the bounded window and report the violation (False).
    remesh_drain: bool = True
    violation_mode: str = "raise"          # raise | report
    jitter_seed: int = 0

    def __post_init__(self):
        if self.backpressure not in ("none", "error", "spin"):
            raise ValueError(
                f"backpressure must be none|error|spin, got "
                f"{self.backpressure!r}")
        if self.violation_mode not in ("raise", "report"):
            raise ValueError(
                f"violation_mode must be raise|report, got "
                f"{self.violation_mode!r}")


@dataclasses.dataclass(frozen=True)
class HealthAction:
    """One escalation-ladder rung firing for one group on one tick."""
    group: str
    rung: int          # 1=retry 2=forced_resolve/remesh_drain 3=backpressure 4=sync
    kind: str
    step: int
    detail: str = ""


@dataclasses.dataclass(frozen=True)
class FreshnessViolation:
    """One group whose vulnerability age exceeded its deadline at tick end."""
    group: str
    step: int
    age_steps: int
    age_seconds: float
    deadline_steps: int
    deadline_seconds: float


class BackpressureError(RuntimeError):
    """Foreground write rejected by rung-3 admission control.

    Raised from ``on_write`` (host path only) while one or more groups'
    breakers are CRITICAL and ``HealthPolicy.backpressure == "error"``.
    The write was NOT recorded — back off and retry, or switch the policy
    to ``"spin"`` for transparent throttling.
    """

    def __init__(self, groups: Tuple[str, ...]):
        self.groups = tuple(groups)
        super().__init__(
            "foreground write backpressured: breaker CRITICAL for group(s) "
            + ", ".join(self.groups))


class FreshnessViolationError(RuntimeError):
    """The escalation ladder was exhausted and a freshness deadline is
    still blown — the typed, never-silent end of the line."""

    def __init__(self, violations: Tuple[FreshnessViolation, ...]):
        self.violations = tuple(violations)
        msg = "; ".join(
            f"{v.group}: age {v.age_steps} steps/{v.age_seconds:.3f}s vs "
            f"deadline {v.deadline_steps} steps/{v.deadline_seconds:.3f}s"
            for v in self.violations)
        super().__init__(f"freshness deadline violated after escalation "
                         f"ladder exhausted: {msg}")


@dataclasses.dataclass
class HealthReport:
    """Per-tick governor observability (``TickReport.health``)."""
    step: int
    states: Dict[str, str] = dataclasses.field(default_factory=dict)
    # (group, from_state, to_state) breaker transitions this tick.
    transitions: Tuple[Tuple[str, str, str], ...] = ()
    actions: Tuple[HealthAction, ...] = ()
    # group -> (age_steps, age_seconds) at tick end.
    ages: Dict[str, Tuple[int, float]] = dataclasses.field(
        default_factory=dict)
    violations: Tuple[FreshnessViolation, ...] = ()
    # Rung-3 admissions throttled/rejected since the previous tick.
    backpressure_events: int = 0
    # Starvation surface (mirrors TickReport; here so one object carries
    # the whole health picture for operators and the autotuner).
    patrol_starved_ticks: int = 0
    rebuild_active: bool = False
    remesh_active: bool = False

    @property
    def worst(self) -> str:
        return max(self.states.values(), key=_LEVEL.__getitem__,
                   default=HEALTHY)


@dataclasses.dataclass
class _GroupHealth:
    """Mutable per-group breaker bookkeeping (keyed by group label, so it
    survives remesh adoption's group-object swap)."""
    state: str = HEALTHY
    calm: int = 0
    retries: int = 0
    retry_spent_s: float = 0.0
    sync_escalated: bool = False
    backpressure: bool = False
    acted: bool = False        # per-tick scratch: any ladder rung fired


class HealthGovernor:
    """Breaker + escalation ladder for one :class:`ProtectedStore`.

    The store calls (in tick order): ``begin_tick`` -> per group
    ``check_pending`` / ``within_margin`` / ``is_sync_escalated`` ->
    (``note_forced_resolve`` / ``note_remesh_drain`` as rungs fire) ->
    ``end_tick``.  ``admit`` hooks ``on_write``.
    """

    def __init__(self, store, hp: Optional[HealthPolicy] = None):
        if hp is None:
            cand = getattr(store.policy, "health", None)
            hp = cand if isinstance(cand, HealthPolicy) else HealthPolicy()
        self.store = store
        self.hp = hp
        self._groups: Dict[str, _GroupHealth] = {}
        self._rng = random.Random(hp.jitter_seed)
        self._sleep = time.sleep           # injectable (tests, benches)
        self._step = 0
        self._now = time.monotonic()
        self._actions: List[HealthAction] = []
        self._violations: List[FreshnessViolation] = []
        self._transitions: List[Tuple[str, str, str]] = []
        self._bp_events = 0
        self.last_report: Optional[HealthReport] = None

    # ------------------------------------------------------------- lookup

    def group(self, label: str) -> _GroupHealth:
        gh = self._groups.get(label)
        if gh is None:
            gh = self._groups[label] = _GroupHealth()
        return gh

    def is_sync_escalated(self, label: str) -> bool:
        gh = self._groups.get(label)
        return gh is not None and gh.sync_escalated

    def backpressure_groups(self) -> Tuple[str, ...]:
        return tuple(l for l, gh in self._groups.items() if gh.backpressure)

    # ------------------------------------------------------ tick lifecycle

    def begin_tick(self, step: int, now: float) -> None:
        self._step, self._now = step, now
        self._actions = []
        self._violations = []
        self._transitions = []
        for gh in self._groups.values():
            gh.acted = False

    def _act(self, label: str, rung: int, kind: str, detail: str = "",
             *, counts: bool = True) -> None:
        self._actions.append(HealthAction(label, rung, kind, self._step,
                                          detail))
        if counts:
            self.group(label).acted = True

    def _escalate(self, label: str, target: str) -> None:
        gh = self.group(label)
        if _LEVEL[target] > _LEVEL[gh.state]:
            self._transitions.append((label, gh.state, target))
            gh.state = target
        gh.calm = 0

    # Rung 1 ----------------------------------------------------------------

    def check_pending(self, g) -> bool:
        """Timeout a wedged in-flight update; abandon, backoff, escalate.

        Returns True when a pending was abandoned: the tick must
        re-dispatch ``g`` *this tick* (the periodic ``due`` check is
        step-aligned, so waiting for it would let the breaker cool down
        between retries and the retry budget would never be consumed).
        Abandoning rolls the group's freshness clocks back to their
        pre-dispatch values; the live view's epoch shadow keeps every
        block covered by the abandoned update conservatively dirty, so
        no coverage is lost."""
        hp = self.hp
        p = g.pending
        if p is None or hp.dispatch_timeout_s <= 0.0:
            return False
        # dispatched_at stamps the dispatcher-thread *enqueue* (the new
        # dispatch site): a launch stuck in the queue behind a wedged
        # device ages — and abandons — exactly like a launched-but-
        # unfinished one.
        age = time.monotonic() - p.dispatched_at
        if age < hp.dispatch_timeout_s:
            return False
        from repro.core import store as store_mod   # patched in tests
        if store_mod._pending_ready(p):
            return False                 # slow but done: resolve, don't kill
        gh = self.group(g.label)
        # Roll the freshness clocks back to the oldest unprotected write
        # (min: a step-counter rebase may already have zeroed them).
        g.last_update_step = min(g.last_update_step, p.prev_step)
        g.last_update_time = min(g.last_update_time, p.prev_time)
        g.pending = None
        gh.retries += 1
        if gh.retries > hp.dispatch_retry_attempts:
            # Rung 1 exhausted: escalate to backpressure + sync escalation.
            self._escalate(g.label, CRITICAL)
            self._act(g.label, 1, "retry_exhausted",
                      f"attempt {gh.retries} > {hp.dispatch_retry_attempts}")
            if hp.backpressure != "none" and not gh.backpressure:
                gh.backpressure = True
                self._act(g.label, 3, "backpressure_on")
            if hp.sync_escalation and not gh.sync_escalated:
                gh.sync_escalated = True
                self._act(g.label, 4, "sync_escalate")
            return True
        self._escalate(g.label, DEGRADED)
        delay = backoff_delay(gh.retries, hp.retry_backoff_s,
                              cap=hp.retry_backoff_cap_s,
                              jitter_frac=hp.retry_jitter_frac,
                              rng=self._rng)
        if hp.retry_total_s > 0.0:
            delay = min(delay, max(0.0, hp.retry_total_s - gh.retry_spent_s))
        self._act(g.label, 1, "retry_timeout",
                  f"attempt {gh.retries}, pending age {age:.3f}s, "
                  f"backoff {delay * 1e3:.1f}ms")
        if delay > 0.0:
            self._sleep(delay)
            gh.retry_spent_s += delay
        return True

    # Rung 2 ----------------------------------------------------------------

    def within_margin(self, g, step: int, now: float) -> bool:
        """True when ``g`` is within the configured margin of its
        freshness deadline — the tick must stop speculating."""
        hp, lp = self.hp, g.policy
        if (lp.max_vulnerable_steps > 0 and hp.deadline_margin_steps > 0
                and step - g.last_update_step
                >= lp.max_vulnerable_steps - hp.deadline_margin_steps):
            return True
        if (lp.max_vulnerable_seconds > 0 and hp.deadline_margin_s > 0
                and now - g.last_update_time
                >= lp.max_vulnerable_seconds - hp.deadline_margin_s):
            return True
        return False

    def note_forced_resolve(self, label: str, step: int) -> None:
        self._escalate(label, DEGRADED)
        self._act(label, 2, "forced_resolve",
                  "margin expiring: in-flight update resolved blocking")

    # Remesh hole ----------------------------------------------------------

    def remesh_overdue(self, step: int, now: float) -> Tuple[str, ...]:
        """Vilamb groups whose margin (or deadline) expired while the
        group loop is suspended by an active remesh."""
        out = []
        for g in self.store._protected():
            lp = g.policy
            if lp.mode != "vilamb":
                continue
            if not (lp.max_vulnerable_steps > 0
                    or lp.max_vulnerable_seconds > 0):
                continue
            hit = self.within_margin(g, step, now)
            hit |= (lp.max_vulnerable_steps > 0
                    and step - g.last_update_step >= lp.max_vulnerable_steps)
            hit |= (lp.max_vulnerable_seconds > 0
                    and now - g.last_update_time >= lp.max_vulnerable_seconds)
            if hit:
                out.append(g.label)
        return tuple(out)

    def note_remesh_drain(self, label: str, step: int) -> None:
        self._escalate(label, DEGRADED)
        self._act(label, 2, "remesh_drain",
                  "migration drained synchronously: freshness SLO beats "
                  "the bounded per-tick window")

    # Rung 3 ----------------------------------------------------------------

    def admit(self, red) -> None:
        """``on_write`` admission control.  Host path only: under a jax
        trace this is a no-op (the jitted step must never block)."""
        flagged = self.backpressure_groups()
        if not flagged:
            return
        import jax
        for leaf in jax.tree_util.tree_leaves(red):
            if isinstance(leaf, jax.core.Tracer):
                return
        self._bp_events += 1
        if self.hp.backpressure == "error":
            raise BackpressureError(flagged)
        if self.hp.backpressure == "spin" and self.hp.backpressure_spin_s > 0:
            self._sleep(self.hp.backpressure_spin_s)

    # ---------------------------------------------------------- end of tick

    def end_tick(self, report, step: int, now: float) -> None:
        """Audit every monitored group's age, run the breaker, attach
        :class:`HealthReport` to ``report``; raise on exhausted ladder."""
        hp = self.hp
        states: Dict[str, str] = {}
        ages: Dict[str, Tuple[int, float]] = {}
        for g in self.store._protected():
            lp = g.policy
            if lp.mode != "vilamb":
                continue
            gh = self.group(g.label)
            age_steps = max(0, step - g.last_update_step)
            age_s = max(0.0, now - g.last_update_time)
            ages[g.label] = (age_steps, age_s)
            violated = (
                (lp.max_vulnerable_steps > 0
                 and age_steps > lp.max_vulnerable_steps)
                or (lp.max_vulnerable_seconds > 0
                    and age_s > lp.max_vulnerable_seconds))
            if violated:
                self._violations.append(FreshnessViolation(
                    g.label, step, age_steps, age_s,
                    lp.max_vulnerable_steps, lp.max_vulnerable_seconds))
                # Ladder exhausted for this tick: engage rungs 3+4 so the
                # *next* ticks recover, and trip the breaker.
                if hp.backpressure != "none" and not gh.backpressure:
                    gh.backpressure = True
                    self._act(g.label, 3, "backpressure_on",
                              "deadline violated")
                if hp.sync_escalation and not gh.sync_escalated:
                    gh.sync_escalated = True
                    self._act(g.label, 4, "sync_escalate",
                              "deadline violated")
                self._escalate(g.label, CRITICAL)
            elif gh.acted:
                # Some rung fired: the tick was not calm.  Rung >= 3 means
                # CRITICAL; rung 1/2 alone means DEGRADED (escalations
                # already applied where they fired; this just resets calm).
                gh.calm = 0
            else:
                gh.calm += 1
                if gh.state != HEALTHY and gh.calm >= hp.recovery_ticks:
                    down = HEALTHY if gh.state == DEGRADED else DEGRADED
                    self._transitions.append((g.label, gh.state, down))
                    gh.state = down
                    gh.calm = 0
                    if gh.state != CRITICAL and gh.backpressure:
                        gh.backpressure = False
                        self._act(g.label, 3, "backpressure_off",
                                  counts=False)
                    if gh.state == HEALTHY:
                        gh.sync_escalated = False
                        gh.retries = 0
                        gh.retry_spent_s = 0.0
            states[g.label] = gh.state
        rep = HealthReport(
            step=step, states=states,
            transitions=tuple(self._transitions),
            actions=tuple(self._actions), ages=ages,
            violations=tuple(self._violations),
            backpressure_events=self._bp_events,
            patrol_starved_ticks=int(report.patrol_starved_ticks),
            rebuild_active=(report.rebuild is not None
                            and not report.rebuild.done),
            remesh_active=(report.remesh is not None
                           and not report.remesh.done))
        self._bp_events = 0
        report.health = rep
        self.last_report = rep
        if self._violations and hp.violation_mode == "raise":
            raise FreshnessViolationError(tuple(self._violations))
