"""Freshness-SLO health governor (see governor.py module docstring).

Enable by setting ``RedundancyPolicy(health=HealthPolicy(...))`` (or
``health=True`` for defaults); the store constructs the governor in
``attach`` and surfaces per-tick state on ``TickReport.health``.
"""
from repro.health.backoff import backoff_delay, backoff_schedule
from repro.health.governor import (
    BREAKER_STATES, CRITICAL, DEGRADED, HEALTHY,
    BackpressureError, FreshnessViolation, FreshnessViolationError,
    HealthAction, HealthGovernor, HealthPolicy, HealthReport,
)

__all__ = [
    "backoff_delay", "backoff_schedule",
    "BREAKER_STATES", "HEALTHY", "DEGRADED", "CRITICAL",
    "HealthPolicy", "HealthAction", "HealthReport", "HealthGovernor",
    "BackpressureError", "FreshnessViolation", "FreshnessViolationError",
]
