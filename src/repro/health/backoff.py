"""Shared retry-backoff schedule: exponential, capped, jittered, budgeted.

One policy for every retry loop in the library — ``read_verified``'s
degraded-read retries and the health governor's wedged-dispatch retries
both draw their delays from here, so "how long do we wait before trying
again" is a single auditable knob set rather than N ad-hoc sleeps.

Semantics (all seconds):

* delay for 1-based attempt ``a`` is ``base * 2**(a-1)``,
* ``cap > 0`` is a hard per-delay ceiling (post-exponentiation),
* ``jitter_frac`` shrinks each delay by a seeded uniform fraction in
  ``[0, jitter_frac)`` — jitter only ever *reduces* the delay, so ``cap``
  and ``total`` remain hard bounds and tests can assert ceilings,
* ``total > 0`` is a cumulative budget: the schedule's sum never exceeds
  it; delays past the budget degenerate to 0 (retry immediately — the
  caller's attempt count still bounds the loop).

``base <= 0`` yields an all-zero schedule (retry immediately), which is
the backwards-compatible default for ``read_retry_backoff_s=0``.
"""
from __future__ import annotations

import random
from typing import List, Optional


def backoff_delay(attempt: int, base: float, *, cap: float = 0.0,
                  jitter_frac: float = 0.0,
                  rng: Optional[random.Random] = None) -> float:
    """Delay in seconds before retry ``attempt`` (1-based)."""
    if base <= 0.0 or attempt <= 0:
        return 0.0
    d = float(base) * (2.0 ** (attempt - 1))
    if cap > 0.0:
        d = min(d, float(cap))
    if jitter_frac > 0.0:
        r = rng.random() if rng is not None else random.random()
        d *= 1.0 - min(float(jitter_frac), 1.0) * r
    return d


def backoff_schedule(attempts: int, base: float, *, cap: float = 0.0,
                     total: float = 0.0, jitter_frac: float = 0.0,
                     seed: int = 0) -> List[float]:
    """Full deterministic delay schedule for ``attempts`` retries."""
    rng = random.Random(seed)
    out: List[float] = []
    spent = 0.0
    for a in range(1, max(0, int(attempts)) + 1):
        d = backoff_delay(a, base, cap=cap, jitter_frac=jitter_frac, rng=rng)
        if total > 0.0:
            d = min(d, max(0.0, float(total) - spent))
        out.append(d)
        spent += d
    return out
