"""Production meshes.

Defined as functions (never module-level constants) so importing this module
never touches JAX device state. The dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import to build these meshes on CPU.
"""
from __future__ import annotations

import jax

try:  # JAX >= 0.5 explicit-sharding API
    from jax.sharding import AxisType
    _AXIS_KW = lambda n: {"axis_types": (AxisType.Auto,) * n}
except ImportError:  # older JAX: every axis is Auto already
    _AXIS_KW = lambda n: {}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_AXIS_KW(len(axes)))


def make_mesh(shape, axes):
    """Arbitrary mesh (tests / small dry-runs)."""
    return jax.make_mesh(tuple(shape), tuple(axes), **_AXIS_KW(len(axes)))
