"""Analytic per-chip HBM model for the dry-run "fits" verdict.

The CPU backend's buffer assignment lacks the TPU memory-aware scheduler, so
``memory_analysis().temp_size`` massively over-reports live temps (it is
recorded as a pessimistic upper bound). The planning model below is the one
you'd size a real run with: exact state bytes (from the actual per-leaf
PartitionSpecs, including replication fallbacks and redundancy arrays) plus
a first-principles activation/working-set estimate.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import numpy as np

HBM_BUDGET = 16 * 2**30          # v5e
HEADROOM = 0.9                   # fragmentation / runtime reserves


def _local_bytes(struct, spec, mesh) -> int:
    from repro.core.engine import _local_shape
    shape = _local_shape(struct.shape, spec, mesh)
    return int(np.prod(shape) or 1) * jax.numpy.dtype(struct.dtype).itemsize


def state_bytes_per_chip(flat_structs: Dict, flat_specs: Dict, mesh) -> int:
    return sum(_local_bytes(v, flat_specs.get(k), mesh)
               for k, v in flat_structs.items())


def red_bytes_per_chip(store) -> int:
    """Redundancy-array bytes per chip (ProtectedStore or engine)."""
    total = 0
    metas = getattr(store, "protected_metas", None) or store.metas
    for meta in metas.values():  # metas are shard-local geometry
        total += meta.n_blocks * 4                       # checksums
        total += meta.n_stripes * meta.lanes_per_block * 4   # parity
        total += 2 * meta.n_dirty_words * 4              # dirty + shadow
    return total


def activation_model(cfg, shape, mesh, accum: int) -> Dict[str, int]:
    """Coarse working-set terms for one train step (per chip)."""
    axes = dict(mesh.shape)
    dp = int(np.prod([axes.get(a, 1) for a in ("pod", "data")]))
    tp = axes.get("model", 1)
    S, B = shape.seq_len, shape.global_batch
    tokens_ds = S * max(B // dp, 1) // accum          # per data-shard tokens
    sp = tp if S % tp == 0 else 1
    d = cfg.d_model
    out = {}
    # residual stream saved at every layer boundary (remat inputs), SP-sharded
    out["acts_saved"] = cfg.n_layers * tokens_ds * d * 2 // sp
    # LM head working set: f32 softmax + bf16 onehot + bf16 dlogits
    v_loc = cfg.padded_vocab // tp if cfg.padded_vocab % tp == 0 else cfg.padded_vocab
    out["logits_peak"] = tokens_ds * v_loc * (4 + 2 + 2)
    # per-slot backward working sets (max over layer kinds)
    ffn = 3 * tokens_ds * max(cfg.d_ff, 1) * 2 // sp
    h_loc = cfg.n_heads // tp if cfg.n_heads % tp == 0 else cfg.n_heads
    from repro.models.attention import pick_tile
    tile = pick_tile(B, cfg.n_heads, S, dp * (tp if cfg.n_heads % tp == 0 else 1))
    attn = 2 * max(B // dp, 1) // accum * h_loc * tile * tile * 4 \
        + 4 * tokens_ds * cfg.n_heads * cfg.hd * 2 // (tp if cfg.n_heads % tp == 0 else 1)
    slot = max(ffn, attn)
    if cfg.ssm_kind == "mamba" or cfg.attn_every:
        di = cfg.d_inner // tp if cfg.d_inner % tp == 0 else cfg.d_inner
        chunk = 128
        mamba = (4 * tokens_ds * di * 2             # xz, ys, dt-ish streams
                 + 3 * max(B // dp, 1) // accum * chunk * di * cfg.d_state * 4)
        slot = max(slot, mamba)
    if cfg.n_experts:
        cap = int(np.ceil(tokens_ds * cfg.top_k / cfg.n_experts
                          * cfg.capacity_factor))
        e_loc = max(cfg.n_experts // tp, 1)
        moe = e_loc * cap * (cfg.d_model + 3 * cfg.expert_d_ff) * 2
        # FSDP-gathered expert slab for one layer
        fs = dp if False else axes.get("data", 1)
        moe += 3 * e_loc * cfg.d_model * cfg.expert_d_ff * 2
        slot = max(slot, moe)
    out["slot_peak"] = int(slot)
    return out


def analytic_hbm(cfg, shape, mesh, setup, mode: str, accum: int) -> Dict:
    """Itemized per-chip HBM estimate for a dry-run cell."""
    from repro.common import flatten_dict
    rec: Dict = {}
    if shape.kind == "train":
        flat_p = flatten_dict(jax.eval_shape(setup.model.init, jax.random.PRNGKey(0)))
        from repro.dist.sharding import param_specs
        p_specs, _ = param_specs(flat_p, setup.model.ctx)
        pbytes = state_bytes_per_chip(flat_p, p_specs, mesh)
        mbytes = sum(_local_bytes(
            jax.ShapeDtypeStruct(v.shape, cfg.moment_dtype), p_specs.get(k), mesh)
            for k, v in flat_p.items())
        rec["params"] = pbytes
        rec["moments"] = 2 * mbytes
        rec["grads"] = mbytes * (2 if accum > 1 else 1)  # fp32 accum vs transient
        rec["redundancy"] = red_bytes_per_chip(setup.store) if setup.store else 0
        rec.update(activation_model(cfg, shape, mesh, accum))
    else:
        flat_p = flatten_dict(jax.eval_shape(setup.model.init, jax.random.PRNGKey(0)))
        from repro.dist.sharding import param_specs
        p_specs, _ = param_specs(flat_p, setup.model.ctx)
        rec["params"] = state_bytes_per_chip(flat_p, p_specs, mesh)
        if shape.kind == "decode":
            caches = setup.args_struct[1]
            from repro.dist.sharding import cache_specs
            flat_c = flatten_dict(caches)
            c_specs, _ = cache_specs(cfg, flat_c, setup.model.ctx, shape.global_batch)
            rec["caches"] = state_bytes_per_chip(flat_c, c_specs, mesh)
            rec["redundancy"] = (red_bytes_per_chip(setup.store)
                                 if getattr(setup, "store", None) else 0)
        else:  # prefill: transient attention/caches working set
            axes = dict(mesh.shape)
            dp = int(np.prod([axes.get(a, 1) for a in ("pod", "data")]))
            tp = axes.get("model", 1)
            kv = 2 * cfg.n_layers * (shape.global_batch // max(dp, 1)) * shape.seq_len \
                * cfg.n_kv_heads * cfg.hd * 2
            rec["caches"] = kv // (tp if shape.seq_len % tp == 0 else 1)
            rec.update(activation_model(cfg, shape, mesh, 1))
            rec.pop("acts_saved", None)  # no backward in prefill
    total = int(sum(rec.values()))
    rec["total"] = total
    rec["fits_16g_analytic"] = bool(total <= HBM_BUDGET * HEADROOM)
    return rec
