import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware: sharding
mismatches, compile-time OOM, or unsupported collectives fail here.
Results (memory analysis, HLO FLOPs/bytes, collective schedule, roofline
terms) are cached as JSON under results/dryrun/ for EXPERIMENTS.md.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch olmo-1b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""
import argparse
import json
import pathlib
import sys
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch, list_archs
from repro.launch import hlo_analysis as H
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import build_decode_setup, build_prefill_setup, build_train_setup
from repro.models.config import SHAPES

RESULTS = pathlib.Path(__file__).resolve().parents[3] / "results" / "dryrun"


def cell_applicability(cfg, shape) -> str:
    """'' if runnable, else the documented skip reason."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return "SKIP(full-attention arch; 500k decode requires sub-quadratic mixer)"
    return ""


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS per assignment: 6*N*D train (N_active for MoE), 2*N*D fwd."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n * shape.seq_len * shape.global_batch
    if shape.kind == "prefill":
        return 2.0 * n * shape.seq_len * shape.global_batch
    return 2.0 * n * shape.global_batch  # decode: one token per sequence


def _spmd_dump_dir():
    import tempfile
    return tempfile.mkdtemp(prefix="spmd_dump_")


def _semantic_collectives(dump_dir):
    """Collective stats from the after-SPMD-partitioning dump.

    The CPU backend promotes bf16 compute to f32 during optimization, so the
    final module's collective shapes double every bf16 wire; the
    partitioner-output module keeps semantic dtypes (what a TPU would move).
    """
    import glob as _glob
    files = sorted(_glob.glob(f"{dump_dir}/*after_spmd-partitioning*"))
    best = None
    for f in files:  # take the largest train_step-ish module
        sz = pathlib.Path(f).stat().st_size
        if best is None or sz > best[0]:
            best = (sz, f)
    if not best:
        return None
    return H.parse_collectives(pathlib.Path(best[1]).read_text())


def _compile_cell(cfg, shape, mesh, mode, accum, return_setup=False):
    """Lower + compile one step function; returns (compiled, fallback_log)."""
    if shape.kind == "train":
        setup = build_train_setup(cfg, shape, mesh, mode=mode, accum_steps=accum)
        fn = jax.jit(
            setup.step_fn,
            in_shardings=(setup.state_sharding, setup.batch_sharding),
            out_shardings=(setup.state_sharding, None),
            donate_argnums=(0,))
        lowered = fn.lower(setup.state_struct, setup.batch_struct)
    elif shape.kind == "prefill":
        setup = build_prefill_setup(cfg, shape, mesh)
        fn = jax.jit(setup.step_fn, in_shardings=setup.args_sharding,
                     out_shardings=setup.out_sharding)
        lowered = fn.lower(*setup.args_struct)
    else:  # decode
        setup = build_decode_setup(cfg, shape, mesh, mode=mode)
        fn = jax.jit(
            setup.step_fn,
            in_shardings=setup.args_sharding,
            donate_argnums=(1, 2))
        lowered = fn.lower(*setup.args_struct)
    import shutil
    dump = _spmd_dump_dir()
    compiled = lowered.compile(compiler_options={
        "xla_dump_to": dump, "xla_dump_hlo_pass_re": "spmd-partitioning"})
    compiled._semantic_coll = _semantic_collectives(dump)  # type: ignore
    shutil.rmtree(dump, ignore_errors=True)
    if return_setup:
        return compiled, setup.fallback_log, setup
    return compiled, setup.fallback_log


def _costs(compiled):
    ca = H.cost_analysis_dict(compiled)
    coll = getattr(compiled, "_semantic_coll", None)
    if coll is None:
        coll = H.parse_collectives(compiled.as_text())
    return {"flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0)),
            "coll": coll.total_bytes,
            "coll_detail": coll.summary()}


def run_cell(arch: str, shape_name: str, multi_pod: bool, mode: str = "vilamb",
             out_dir: pathlib.Path = RESULTS, tag: str = "",
             cfg_override=None, accum: "int|None" = None,
             extrapolate: bool = True) -> dict:
    """One dry-run cell.

    Compile #1: full-scale with the layer scan (production artifact) —
      proves lower+compile succeeds and gives realistic memory analysis.
    Compiles #2+#3 (2-group and 4-group variants, scan unrolled): XLA cost
      analysis counts while bodies once, so the scanned artifact
      under-reports per-layer costs; the unrolled small variants give exact
      per-group FLOPs/bytes/collectives, extrapolated linearly to full depth
      (layers are structurally identical across groups).
    """
    import dataclasses as _dc
    cfg = cfg_override or get_arch(arch)
    shape = SHAPES[shape_name]
    mesh_name = "multi" if multi_pod else "single"
    rec: dict = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                 "mode": mode, "tag": tag, "status": "ok"}
    skip = cell_applicability(cfg, shape)
    if skip:
        rec["status"] = skip
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(np.prod(list(mesh.shape.values())))
    if accum is None:
        from repro.launch.specs import default_accum
        accum = default_accum(cfg, shape, mesh)
    rec["accum_steps"] = accum

    with mesh:
        t0 = time.time()
        compiled, log, setup = _compile_cell(cfg, shape, mesh, mode, accum,
                                             return_setup=True)
        rec["compile_s"] = round(time.time() - t0, 1)
        rec["fallbacks"] = log
        rec["memory_analysis"] = H.memory_analysis_dict(compiled)
        rec["cost_analysis_scanned"] = _costs(compiled)
        try:
            from repro.launch.memory_model import analytic_hbm
            rec["hbm_model"] = analytic_hbm(cfg, shape, mesh, setup, mode, accum)
        except Exception as e:  # model must never break the dry-run
            rec["hbm_model"] = {"error": f"{type(e).__name__}: {e}"}

        G = cfg.n_groups
        gs = cfg.group_size
        if extrapolate and G > 2:
            t1 = time.time()
            c1 = _costs(_compile_cell(
                _dc.replace(cfg, n_layers=gs, unroll_layers=True),
                shape, mesh, mode, accum)[0])
            c2 = _costs(_compile_cell(
                _dc.replace(cfg, n_layers=2 * gs, unroll_layers=True),
                shape, mesh, mode, accum)[0])
            per_group = {k: (c2[k] - c1[k]) for k in ("flops", "bytes", "coll")}
            full = {k: c1[k] + (G - 1) * per_group[k] for k in per_group}
            rec["cost_extrapolation"] = {
                "g1": {k: c1[k] for k in per_group}, "g2": {k: c2[k] for k in per_group},
                "per_group": per_group, "extra_compile_s": round(time.time() - t1, 1),
                "coll_detail_g2": c2["coll_detail"],
            }
        else:
            # shallow model: unroll the real thing
            cu = _costs(_compile_cell(
                _dc.replace(cfg, unroll_layers=True), shape, mesh, mode, accum)[0])
            full = {k: cu[k] for k in ("flops", "bytes", "coll")}
            rec["cost_extrapolation"] = {"unrolled_exact": True,
                                         "coll_detail": cu["coll_detail"]}

    rec["collectives"] = rec["cost_analysis_scanned"]["coll_detail"]
    mf = model_flops(cfg, shape)
    rl = H.roofline_terms(
        flops_per_chip=full["flops"], bytes_per_chip=full["bytes"],
        coll_bytes_per_chip=full["coll"], chips=chips, model_flops=mf)
    rec["roofline"] = rl.as_dict()

    # HBM budget: analytic model gives the verdict (exact state bytes from
    # the real PartitionSpecs + working-set estimate); the CPU scheduler's
    # temp_size is recorded as a pessimistic upper bound (no TPU
    # memory-aware scheduling on the CPU backend).
    ma = rec["memory_analysis"]
    if ma:
        live = (ma.get("argument_size_in_bytes", 0)
                + ma.get("temp_size_in_bytes", 0)
                + ma.get("output_size_in_bytes", 0)
                - ma.get("alias_size_in_bytes", 0))
        rec["hbm_bytes_per_device_cpu_upper_bound"] = int(live)
    hm = rec.get("hbm_model", {})
    rec["hbm_bytes_per_device"] = int(hm.get("total", 0))
    rec["fits_16g"] = bool(hm.get("fits_16g_analytic", False))

    out_dir.mkdir(parents=True, exist_ok=True)
    name = f"{arch}__{shape_name}__{mesh_name}{('__' + tag) if tag else ''}.json"
    (out_dir / name).write_text(json.dumps(rec, indent=2, default=str))
    return rec


def run_redundancy_cell(arch: str, multi_pod: bool = False,
                        stripe: int = 4, lanes: int = 16384,
                        use_kernels: bool = False, dirty_frac: float = 1.0,
                        out_dir: pathlib.Path = RESULTS, tag: str = "red") -> dict:
    """Lower + compile Algorithm 1 itself over an arch's protected state.

    This is the paper's technique as its own roofline cell: memory-bound by
    construction, zero collectives (machine-local, §3.3). ``dirty_frac``
    scales the analytic amortized traffic; the compiled artifact is the
    full-pass (worst-case flush) cost.
    """
    import dataclasses as _dc
    import jax.numpy as jnp
    from repro.core.engine import RedundancyConfig, RedundancyEngine
    from repro.dist.sharding import param_specs
    from repro.common import flatten_dict
    from repro.launch.specs import make_ctx, tree_shardings
    from repro.models import build_model
    from repro.optim import AdamW, warmup_cosine
    from repro.train.state import protected_structs
    from repro.train.train_loop import make_redundancy_step
    from repro.train.state import TrainState

    cfg = get_arch(arch)
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(np.prod(list(mesh.shape.values())))
    ctx = make_ctx(cfg, mesh)
    model = build_model(cfg, ctx)
    params_struct = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    opt = AdamW(lr=warmup_cosine(3e-4, 100, 10000), moment_dtype=cfg.moment_dtype)
    opt_struct = jax.eval_shape(opt.init, params_struct)
    flat_p = flatten_dict(params_struct)
    p_specs, _ = param_specs(flat_p, ctx)
    prot = protected_structs(params_struct, opt_struct)
    prot_specs = {k: p_specs[k.partition("/")[2]] for k in prot}
    rcfg = RedundancyConfig(mode="vilamb", stripe_data_blocks=stripe,
                            lanes_per_block=lanes, use_kernels=use_kernels)
    engine = RedundancyEngine(prot, rcfg, mesh=mesh, specs=prot_specs)
    red_struct = engine.red_structs()
    red_shard = engine.red_shardings()

    from jax.sharding import NamedSharding, PartitionSpec as P
    p_shard = tree_shardings(params_struct, p_specs, mesh)
    rep = NamedSharding(mesh, P())
    state_struct = TrainState(params=params_struct, opt=opt_struct,
                              red=red_struct,
                              step=jax.ShapeDtypeStruct((), jnp.int32))
    state_shard = TrainState(params=p_shard,
                             opt={"m": p_shard, "v": p_shard, "count": rep},
                             red=red_shard, step=rep)
    fn = jax.jit(make_redundancy_step(engine),
                 in_shardings=(state_shard,), out_shardings=state_shard,
                 donate_argnums=(0,))
    t0 = time.time()
    with mesh:
        compiled = fn.lower(state_struct).compile()
    rec = {"arch": arch, "cell": "redundancy_step", "tag": tag,
           "stripe": stripe, "lanes_per_block": lanes,
           "compile_s": round(time.time() - t0, 1), "status": "ok"}
    ca = H.cost_analysis_dict(compiled)
    coll = H.parse_collectives(compiled.as_text())
    state_bytes = sum(
        int(np.prod(v.shape) or 1) * jnp.dtype(v.dtype).itemsize
        for v in prot.values()) / chips
    rl = H.roofline_terms(float(ca.get("flops", 0.0)),
                          float(ca.get("bytes accessed", 0.0)),
                          coll.total_bytes, chips, model_flops=0.0)
    rec["roofline"] = rl.as_dict()
    rec["collectives"] = coll.summary()
    rec["state_bytes_per_chip"] = int(state_bytes)
    # useful traffic = read dirty stripes once + write parity/checksums
    useful = state_bytes * dirty_frac * (1 + 1.0 / stripe)
    rec["useful_bytes_per_chip"] = int(useful)
    rec["memory_efficiency"] = useful / max(float(ca.get("bytes accessed", 1)), 1.0)
    out_dir.mkdir(parents=True, exist_ok=True)
    (out_dir / f"{arch}__redundancy__{tag}.json").write_text(
        json.dumps(rec, indent=2, default=str))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--mode", default="vilamb", choices=["none", "sync", "vilamb"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--tag", default="")
    ap.add_argument("--out", default=str(RESULTS))
    args = ap.parse_args()

    archs = list_archs() if (args.all or args.arch == "all") else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape == "all") else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    out_dir = pathlib.Path(args.out)

    failures = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                mesh_name = "multi" if mp else "single"
                fname = out_dir / f"{arch}__{shape}__{mesh_name}{('__' + args.tag) if args.tag else ''}.json"
                if args.skip_existing and fname.exists():
                    print(f"[skip] {arch} {shape} {mesh_name} (cached)")
                    continue
                label = f"{arch:26s} {shape:12s} {mesh_name:6s}"
                try:
                    rec = run_cell(arch, shape, mp, mode=args.mode,
                                   out_dir=out_dir, tag=args.tag)
                    if rec["status"] != "ok":
                        print(f"[----] {label} {rec['status']}")
                        out_dir.mkdir(parents=True, exist_ok=True)
                        fname.write_text(json.dumps(rec, indent=2))
                        continue
                    rl = rec["roofline"]
                    print(f"[ ok ] {label} compile={rec['compile_s']}s "
                          f"accum={rec['accum_steps']} "
                          f"bottleneck={rl['bottleneck']} "
                          f"frac={rl['roofline_fraction']:.3f} "
                          f"fits16G={rec.get('fits_16g', '?')}", flush=True)
                except Exception as e:
                    failures += 1
                    print(f"[FAIL] {label} {type(e).__name__}: {e}")
                    traceback.print_exc()
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
