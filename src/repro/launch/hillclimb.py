import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

"""Perf hillclimbing driver (EXPERIMENTS.md §Perf).

Runs tagged dry-run variants of one cell with config/knob overrides and
reports the three roofline terms vs the baseline, so each
hypothesis -> change -> measure -> validate iteration is one command:

  PYTHONPATH=src python -m repro.launch.hillclimb --arch glm4-9b \
      --shape train_4k --variant accum=1 --variant remat=none --tag noaccum
"""
import argparse
import dataclasses
import json
import pathlib

from repro.configs import get_arch
from repro.launch.dryrun import RESULTS, run_cell

PERF_DIR = pathlib.Path(__file__).resolve().parents[3] / "results" / "perf"

KNOB_TYPES = {
    "accum": int, "capacity_factor": float, "remat": str, "seq_parallel": lambda s: s == "true",
    "attn_tile": int, "moe_every": int, "expand": int, "param_dtype": str,
    "moment_dtype": str, "top_k": int, "norm_vjp": str,
    "attn_kv_gather_first": lambda s: s == "true",
    "bf16_grad_boundaries": lambda s: s == "true",
    "opt_grad_barrier": lambda s: s == "true",
}


def parse_variant(kvs):
    cfg_kw, accum = {}, None
    for kv in kvs:
        k, _, v = kv.partition("=")
        cast = KNOB_TYPES.get(k, str)
        if k == "accum":
            accum = int(v)
        else:
            cfg_kw[k] = cast(v)
    return cfg_kw, accum


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--mode", default="vilamb")
    ap.add_argument("--variant", action="append", default=[],
                    help="knob=value (repeatable); e.g. accum=1 remat=none")
    ap.add_argument("--tag", required=True)
    args = ap.parse_args()

    cfg_kw, accum = parse_variant(args.variant)
    cfg = get_arch(args.arch)
    if cfg_kw:
        cfg = dataclasses.replace(cfg, **cfg_kw)

    PERF_DIR.mkdir(parents=True, exist_ok=True)
    rec = run_cell(args.arch, args.shape, args.mesh == "multi", mode=args.mode,
                   out_dir=PERF_DIR, tag=args.tag, cfg_override=cfg, accum=accum)

    base_file = RESULTS / f"{args.arch}__{args.shape}__{args.mesh}.json"
    base = json.loads(base_file.read_text()) if base_file.exists() else None
    rl = rec["roofline"]
    print(f"\n=== {args.arch} {args.shape} {args.mesh} [{args.tag}] "
          f"variant={args.variant} ===")
    print(f"compute {rl['compute_s']:.3f}s  memory {rl['memory_s']:.3f}s  "
          f"collective {rl['collective_s']:.3f}s  bottleneck={rl['bottleneck']}  "
          f"frac={rl['roofline_fraction']:.4f}  fits={rec.get('fits_16g')}")
    if base and base["status"] == "ok":
        b = base["roofline"]
        for term in ("compute_s", "memory_s", "collective_s"):
            delta = (rl[term] - b[term]) / max(b[term], 1e-12) * 100
            print(f"  {term:13s} {b[term]:8.3f} -> {rl[term]:8.3f}  ({delta:+.1f}%)")
        print(f"  frac          {b['roofline_fraction']:.4f} -> "
              f"{rl['roofline_fraction']:.4f}")


if __name__ == "__main__":
    main()
