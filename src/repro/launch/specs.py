"""Dry-run setup: ShapeDtypeStruct inputs + shardings for every cell.

``input_specs()`` follows the shannon/kernels pattern: weak-type-correct,
shardable stand-ins with no device allocation. Training cells lower
``train_step``; decode cells lower ``serve_step`` (one token against a
seq_len KV cache); prefill cells lower the prefill forward.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.common import flatten_dict, unflatten_dict
from repro.core.store import ProtectedStore, RedundancyPolicy
from repro.data.pipeline import batch_structs
from repro.dist.sharding import cache_specs, param_specs
from repro.models import build_model
from repro.models.config import ModelConfig, ShapeConfig
from repro.models.parallel import ParallelCtx
from repro.optim import AdamW, warmup_cosine
from repro.serve.serve_loop import make_decode_step
from repro.train.state import TrainState, protected_structs
from repro.train.train_loop import make_train_step

ENC_MEMORY_LEN = 1024  # precomputed encoder memory length for decode cells

POD_FSDP_THRESHOLD = 8 * 2**30  # in-pod state bytes/chip above which ZeRO spans pods


def make_ctx(cfg: ModelConfig, mesh: Optional[Mesh]) -> ParallelCtx:
    """Parallelism context; 400B-class state enables cross-pod FSDP (ZeRO
    over DCN) when a pod axis exists.

    The trigger uses the *within-pod* state bytes (params + 2 moments over
    data x model only): without pod-FSDP the pod axis replicates state, so
    extra pods don't relieve per-chip HBM.
    """
    if mesh is None:
        return ParallelCtx(mesh=None)
    axes = dict(mesh.shape)
    chips_in_pod = int(np.prod([v for k, v in axes.items() if k != "pod"]))
    pb = jnp.dtype(cfg.param_dtype).itemsize
    mb = jnp.dtype(cfg.moment_dtype).itemsize
    state = cfg.param_count() * (pb + 2 * mb) / chips_in_pod
    if "pod" in axes and state > POD_FSDP_THRESHOLD:
        return ParallelCtx(mesh=mesh, fsdp_axis=("pod", "data"))
    return ParallelCtx(mesh=mesh)


def path_str(kp) -> str:
    parts = []
    for k in kp:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "name"):
            parts.append(str(k.name))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def tree_shardings(tree, flat_specs: Dict[str, P], mesh: Mesh):
    """Sharding pytree with the same treedef as ``tree`` (preserves empty
    subtrees, unlike flatten/unflatten round-trips)."""
    return jax.tree_util.tree_map_with_path(
        lambda kp, leaf: NamedSharding(mesh, flat_specs[path_str(kp)]), tree)


@dataclasses.dataclass
class TrainSetup:
    model: Any
    step_fn: Any
    state_struct: Any
    state_sharding: Any
    batch_struct: Dict[str, jax.ShapeDtypeStruct]
    batch_sharding: Any
    store: Optional[ProtectedStore]
    fallback_log: list
    redundancy_fn: Any = None
    red_leaves_struct: Any = None


def default_accum(cfg: ModelConfig, shape: ShapeConfig, mesh: Optional[Mesh]) -> int:
    """Microbatching heuristic: keep ~<=16k tokens per data-shard when the
    fp32 grad accumulator is affordable (small/mid models); big-param archs
    (accumulator >= ~4 GB/chip) run accum=1 — their activations are small
    relative to state anyway."""
    if mesh is None or shape.kind != "train":
        return 1
    dp = int(np.prod([mesh.shape[a] for a in ("pod", "data") if a in mesh.axis_names]))
    chips = int(np.prod(list(mesh.shape.values())))
    tokens_per_ds = shape.seq_len * shape.global_batch // max(dp, 1)
    accum = max(1, tokens_per_ds // 16384)
    grad_acc_bytes = cfg.param_count() * 4 / chips
    if grad_acc_bytes > 4 * 2**30:
        return 1
    while accum > 1 and (shape.global_batch // dp) % accum:
        accum -= 1
    return min(accum, 8)


def build_train_setup(
    cfg: ModelConfig,
    shape: ShapeConfig,
    mesh: Optional[Mesh],
    mode: str = "vilamb",
    period_steps: int = 8,
    use_kernels: bool = False,
    accum_steps: Optional[int] = None,
) -> TrainSetup:
    ctx = make_ctx(cfg, mesh)
    model = build_model(cfg, ctx)
    params_struct = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    opt = AdamW(lr=warmup_cosine(3e-4, 100, 10000), moment_dtype=cfg.moment_dtype)
    opt_struct = jax.eval_shape(opt.init, params_struct)

    flat_p = flatten_dict(params_struct)
    p_specs, log = param_specs(flat_p, ctx)
    prot_struct = protected_structs(params_struct, opt_struct)
    prot_specs = {}
    for k in prot_struct:
        root, _, suffix = k.partition("/")
        prot_specs[k] = p_specs[suffix]

    store = None
    red_struct: Any = {}
    red_shard: Any = {}
    if mode != "none":
        # Dry-run builder: skip attach-time AOT warmup (it would compile
        # every sharded Algorithm-1 variant just to lower the step); live
        # runs call store.warmup() once real sharded arrays exist.
        policy = RedundancyPolicy.single(mode, period_steps=period_steps,
                                         use_kernels=use_kernels,
                                         precompile=False)
        store = ProtectedStore(policy, mesh=mesh).attach(
            prot_struct, specs=prot_specs)
        red_struct = store.red_structs()
        red_shard = store.red_shardings() if mesh is not None else {}

    state_struct = TrainState(
        params=params_struct, opt=opt_struct, red=red_struct,
        step=jax.ShapeDtypeStruct((), jnp.int32))

    state_sharding = None
    if mesh is not None:
        rep = NamedSharding(mesh, P())
        p_shard = tree_shardings(params_struct, p_specs, mesh)
        state_sharding = TrainState(
            params=p_shard,
            opt={"m": p_shard, "v": p_shard, "count": rep},
            red=red_shard, step=rep)

    b_struct = batch_structs(cfg, shape)
    b_shard = None
    if mesh is not None:
        dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        k = int(np.prod([mesh.shape[a] for a in dp]))
        spec = P(dp) if shape.global_batch % k == 0 else P(None)
        b_shard = {kk: NamedSharding(mesh, spec) for kk in b_struct}

    if accum_steps is None:
        accum_steps = default_accum(cfg, shape, mesh)
    if accum_steps > 1:
        log.append(f"grad accumulation: {accum_steps} microbatches")
    step_fn = make_train_step(model, opt, store, accum_steps=accum_steps)
    red_fn = None
    if store is not None:
        from repro.train.train_loop import make_redundancy_step
        red_fn = make_redundancy_step(store)
    return TrainSetup(model, step_fn, state_struct, state_sharding,
                      b_struct, b_shard, store, log, red_fn)


@dataclasses.dataclass
class DecodeSetup:
    model: Any
    step_fn: Any
    args_struct: tuple
    args_sharding: Optional[tuple]
    store: Optional[ProtectedStore]
    fallback_log: list


def build_decode_setup(
    cfg: ModelConfig,
    shape: ShapeConfig,
    mesh: Optional[Mesh],
    mode: str = "vilamb",
    use_kernels: bool = False,
) -> DecodeSetup:
    ctx = make_ctx(cfg, mesh)
    model = build_model(cfg, ctx)
    B, S = shape.global_batch, shape.seq_len
    enc_len = ENC_MEMORY_LEN if cfg.enc_dec else 0

    params_struct = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    flat_p = flatten_dict(params_struct)
    p_specs, log = param_specs(flat_p, ctx)

    caches_struct = jax.eval_shape(lambda: model.init_caches(B, S, enc_len))
    flat_c = flatten_dict(caches_struct)
    c_specs, clog = cache_specs(cfg, flat_c, ctx, B)
    log = log + clog

    store = None
    red_struct: Any = {}
    red_shard: Any = {}
    if mode != "none":
        policy = RedundancyPolicy.single(mode, use_kernels=use_kernels,
                                         precompile=False)  # dry-run builder
        store = ProtectedStore(policy, mesh=mesh).attach(flat_c, specs=c_specs)
        red_struct = store.red_structs()
        red_shard = store.red_shardings() if mesh is not None else {}

    token_struct = jax.ShapeDtypeStruct((B,), jnp.int32)
    pos_struct = jax.ShapeDtypeStruct((), jnp.int32)
    args_struct = (params_struct, caches_struct, red_struct, token_struct, pos_struct)

    args_sharding = None
    if mesh is not None:
        rep = NamedSharding(mesh, P())
        dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        k = int(np.prod([mesh.shape[a] for a in dp]))
        tok_spec = P(dp) if B % k == 0 else P(None)
        args_sharding = (
            tree_shardings(params_struct, p_specs, mesh),
            tree_shardings(caches_struct, c_specs, mesh),
            red_shard,
            NamedSharding(mesh, tok_spec),
            rep,
        )

    step_fn = make_decode_step(model, store)
    return DecodeSetup(model, step_fn, args_struct, args_sharding, store, log)


@dataclasses.dataclass
class PrefillSetup:
    model: Any
    step_fn: Any
    args_struct: tuple
    args_sharding: Optional[tuple]
    fallback_log: list
    out_sharding: Optional[tuple] = None


def build_prefill_setup(
    cfg: ModelConfig, shape: ShapeConfig, mesh: Optional[Mesh]
) -> PrefillSetup:
    ctx = make_ctx(cfg, mesh)
    model = build_model(cfg, ctx)
    B, S = shape.global_batch, shape.seq_len
    params_struct = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    flat_p = flatten_dict(params_struct)
    p_specs, log = param_specs(flat_p, ctx)
    b_struct = batch_structs(cfg, shape)

    def prefill(params, batch):
        return model.prefill(params, batch, S)

    args_sharding = None
    out_sharding = None
    if mesh is not None:
        dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        k = int(np.prod([mesh.shape[a] for a in dp]))
        spec = P(dp) if B % k == 0 else P(None)
        args_sharding = (
            tree_shardings(params_struct, p_specs, mesh),
            {kk: NamedSharding(mesh, spec) for kk in b_struct},
        )
        # Constrain the prefilled caches to the decode-cache layout so the
        # (large) outputs land sharded, not replicated.
        enc_len = ENC_MEMORY_LEN if cfg.enc_dec else 0
        caches_struct = jax.eval_shape(lambda: model.init_caches(B, S, enc_len))
        c_specs, clog = cache_specs(cfg, flatten_dict(caches_struct), ctx, B)
        log.extend(clog)
        out_sharding = (
            NamedSharding(mesh, P(spec[0] if len(spec) else None, None)),
            tree_shardings(caches_struct, c_specs, mesh),
            NamedSharding(mesh, P()),   # pos scalar
        )
    return PrefillSetup(model, prefill, (params_struct, b_struct),
                        args_sharding, log, out_sharding)
