"""Serving launcher: batched prefill + decode with Vilamb-protected KV cache.

Example:
  PYTHONPATH=src python -m repro.launch.serve --arch glm4-9b --smoke \
      --batch 4 --prompt-len 32 --gen 64 --redundancy vilamb --period 16

Per-leaf policies (e.g. protect K pages harder than V pages):
  ... --policy "*/k=vilamb:8,*/v=vilamb:64" --max-vulnerable-steps 128
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--redundancy", default="vilamb", choices=["none", "sync", "vilamb"])
    ap.add_argument("--period", type=int, default=16)
    ap.add_argument("--scrub-every", type=int, default=16)
    ap.add_argument("--policy", default="",
                    help='per-leaf rules "pattern=mode[:period],..." '
                         "(fnmatch over flat cache paths)")
    ap.add_argument("--max-vulnerable-steps", type=int, default=0,
                    help="freshness deadline: force an update after this "
                         "many decode steps regardless of period")
    args = ap.parse_args(argv)

    from repro.common import flatten_dict
    from repro.configs import get_arch, get_smoke
    from repro.core import ProtectedStore, RedundancyPolicy
    from repro.models import build_model
    from repro.serve import Server

    cfg = get_smoke(args.arch) if args.smoke else get_arch(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    max_len = args.prompt_len + args.gen + 1

    key = jax.random.PRNGKey(7)
    batch = {"tokens": jax.random.randint(
        key, (args.batch, args.prompt_len), 0, cfg.vocab_size, jnp.int32)}
    if cfg.frontend == "vision":
        batch["frontend"] = jax.random.normal(
            key, (args.batch, cfg.frontend_len, cfg.d_model), jnp.float32)
    if cfg.enc_dec:
        batch["enc_input"] = jax.random.normal(
            key, (args.batch, args.prompt_len, cfg.d_model), jnp.float32)

    store = None
    if args.redundancy != "none" or args.policy:
        caches0 = jax.eval_shape(
            lambda: model.init_caches(args.batch, max_len,
                                      args.prompt_len if cfg.enc_dec else 0))
        policy = RedundancyPolicy.from_spec(
            args.policy, default_mode=args.redundancy,
            period_steps=args.period,
            max_vulnerable_steps=args.max_vulnerable_steps)
        store = ProtectedStore(policy).attach(flatten_dict(caches0))

    srv = Server(model=model, store=store, max_len=max_len)
    t0 = time.perf_counter()
    tokens, stats = srv.generate(params, batch, args.gen,
                                 scrub_every=args.scrub_every)
    dt = time.perf_counter() - t0
    print(f"[serve] generated {tokens.shape} in {dt:.2f}s "
          f"({args.batch * args.gen / dt:.1f} tok/s) "
          f"scrub mismatches={stats['mismatches']}")
    print("[serve] first sequence:", tokens[0, :16].tolist())


if __name__ == "__main__":
    main()
