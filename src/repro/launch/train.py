"""Training launcher.

Single-host CPU execution for development; the same script drives the
production mesh when run under multi-host JAX (jax.distributed initializes
from the cluster env). Wires together: config -> model -> sharding rules ->
ProtectedStore (per-leaf policies) -> Trainer loop -> checkpoints ->
preemption handler.

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch olmo-1b --smoke \
      --steps 50 --redundancy vilamb --period 8
  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-3b --smoke \
      --steps 20 --redundancy sync --inject-corruption 10

Per-leaf policies (params sync-protected, Adam moments amortized):
  ... --policy "params/*=sync,m/*=vilamb:16,v/*=vilamb:16" \
      --max-vulnerable-steps 64
"""
from __future__ import annotations

import argparse
import dataclasses
import sys
import time

import jax
import numpy as np


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true", help="reduced config")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--redundancy", default="vilamb", choices=["none", "sync", "vilamb"])
    ap.add_argument("--period", type=int, default=8)
    ap.add_argument("--scrub-period", type=int, default=32)
    ap.add_argument("--policy", default="",
                    help='per-leaf rules "pattern=mode[:period],..." '
                         "(fnmatch over params/... m/... v/... paths)")
    ap.add_argument("--max-vulnerable-steps", type=int, default=0,
                    help="freshness deadline: force an update after this "
                         "many steps regardless of period/back-off")
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--inject-corruption", type=int, default=0,
                    help="flip bits in a random block at this step (demo)")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    from repro.configs import get_arch, get_smoke
    from repro.core import ProtectedStore, RedundancyPolicy
    from repro.core import blocks as B
    from repro.data import SyntheticPipeline
    from repro.models import build_model
    from repro.models.config import ShapeConfig
    from repro.optim import AdamW, warmup_cosine
    from repro.train import Trainer, protected_leaves, protected_structs
    from repro.ckpt import CheckpointManager, PreemptionHandler

    cfg = get_smoke(args.arch) if args.smoke else get_arch(args.arch)
    model = build_model(cfg)
    shape = ShapeConfig("cli", args.seq, args.batch, "train")
    data = SyntheticPipeline(cfg, shape, seed=0)
    opt = AdamW(lr=warmup_cosine(args.lr, 10, args.steps),
                moment_dtype=cfg.moment_dtype)

    store = None
    if args.redundancy != "none" or args.policy:
        params0 = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        opt0 = jax.eval_shape(opt.init, params0)
        policy = RedundancyPolicy.from_spec(
            args.policy, default_mode=args.redundancy,
            period_steps=args.period, scrub_period_steps=args.scrub_period,
            max_vulnerable_steps=args.max_vulnerable_steps)
        store = ProtectedStore(policy).attach(protected_structs(params0, opt0))

    trainer = Trainer(model=model, opt=opt, store=store,
                      scrub_period_steps=args.scrub_period)
    handler = PreemptionHandler().install()
    ckpt = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None

    state = None
    if ckpt is not None and args.resume:
        struct = jax.eval_shape(lambda: trainer.init_state(jax.random.PRNGKey(0)))
        # Verified restore: scrub against the persisted redundancy and
        # parity-repair single-block corruption before resuming.
        state = ckpt.restore_verified(struct, store)
        if state is not None:
            print(f"[train] resumed from step {int(state.step)}")
    if state is None:
        state = trainer.init_state(jax.random.PRNGKey(0))

    t_start = time.perf_counter()
    done = 0
    while done < args.steps:
        def on_step(st, metrics):
            nonlocal done
            done += 1
            s = int(st.step)
            if s % args.log_every == 0:
                print(f"[train] step {s} loss {float(metrics['loss']):.4f} "
                      f"gnorm {float(metrics['grad_norm']):.3f}")
            if ckpt is not None and args.ckpt_every and s % args.ckpt_every == 0:
                ckpt.save(s, st, blocking=False)

        chunk = min(args.steps - done, 10)
        state = trainer.run(state, data, chunk, on_step=on_step)

        # Demonstration: SDC injection -> scrub detect -> parity repair.
        if args.inject_corruption and done >= args.inject_corruption and store:
            args.inject_corruption = 0
            state = trainer.flush(state)  # make everything clean/covered
            leaves = protected_leaves(state.params, state.opt)
            name = sorted(store.protected_metas)[0]
            meta = store.metas[name]
            lanes = B.to_lanes(leaves[name], meta)
            lanes = lanes.at[0, 0].add(np.uint32(0xDEAD))
            leaves[name] = B.from_lanes(lanes, meta)
            mm = store.scrub(leaves, state.red)
            n_bad = int(sum(int(v.sum()) for v in jax.tree.leaves(mm)))
            repaired, fixed, lostn = store.repair(leaves, state.red, mm)
            mm2 = store.scrub(repaired, state.red)
            n_after = int(sum(int(v.sum()) for v in jax.tree.leaves(mm2)))
            print(f"[vilamb] injected corruption: detected={n_bad} "
                  f"repaired={fixed} unrecoverable={lostn} residual={n_after}")

        if handler.requested:
            state = handler.drain(trainer, state, ckpt)
            print(f"[train] preempted: flushed in {handler.flush_seconds:.3f}s, "
                  f"checkpointed at step {int(state.step)}")
            sys.exit(handler.exit_code)

    dt = time.perf_counter() - t_start
    print(f"[train] done: {args.steps} steps in {dt:.1f}s "
          f"({args.steps * shape.seq_len * shape.global_batch / dt:.0f} tok/s) "
          f"alarms={trainer.corruption_alarms}")
    if ckpt is not None:
        state = trainer.flush(state)
        ckpt.save(int(state.step), state, blocking=True)


if __name__ == "__main__":
    main()
