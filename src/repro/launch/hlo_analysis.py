"""Roofline terms from compiled artifacts (DESIGN.md §7).

collective_bytes is not in cost_analysis(): we parse the *partitioned*
module text (``compiled.as_text()``) and sum effective ring-transfer bytes
for every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute, using the group size from ``replica_groups``.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional

# TPU v5e target constants (per chip).
PEAK_BF16_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9  # per link

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_LINE_RE = re.compile(
    r"=\s+(\([^)]*\)|[\w\[\],{}\d]+)\s+(all-gather|all-reduce|reduce-scatter|"
    r"all-to-all|collective-permute)(-start)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(txt: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(txt):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    m = _GROUPS_IOTA_RE.search(line)
    if m:  # [G,S]<=[N] iota form: S is the group size
        return int(m.group(2))
    return 1


def effective_bytes(op: str, result_bytes: int, g: int) -> float:
    """Ring-transfer bytes per chip."""
    if op == "collective-permute":  # point-to-point: no replica_groups attr
        return float(result_bytes)
    if g <= 1:
        return 0.0
    if op == "all-gather":          # result is the gathered buffer
        return result_bytes * (g - 1) / g
    if op == "reduce-scatter":      # result is the scattered shard
        return result_bytes * (g - 1)
    if op == "all-reduce":
        return 2.0 * result_bytes * (g - 1) / g
    if op == "all-to-all":
        return result_bytes * (g - 1) / g
    return float(result_bytes)      # collective-permute


@dataclasses.dataclass
class CollectiveStats:
    per_op: Dict[str, float]
    per_op_count: Dict[str, int]
    total_bytes: float

    def summary(self) -> Dict:
        return {"total_bytes": self.total_bytes,
                "per_op_bytes": self.per_op, "per_op_count": self.per_op_count}


def parse_collectives(hlo_text: str) -> CollectiveStats:
    per_op: Dict[str, float] = {}
    per_cnt: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _LINE_RE.search(line)
        if not m:
            continue
        shape_txt, op = m.group(1), m.group(2)
        b = _shape_bytes(shape_txt)
        g = _group_size(line)
        eb = effective_bytes(op, b, g)
        per_op[op] = per_op.get(op, 0.0) + eb
        per_cnt[op] = per_cnt.get(op, 0) + 1
    return CollectiveStats(per_op, per_cnt, sum(per_op.values()))


@dataclasses.dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float
    hlo_flops_per_chip: float
    hlo_bytes_per_chip: float
    collective_bytes_per_chip: float
    model_flops: float
    useful_ratio: float
    bottleneck: str
    roofline_fraction: float

    def as_dict(self) -> Dict:
        return dataclasses.asdict(self)


def roofline_terms(
    flops_per_chip: float,
    bytes_per_chip: float,
    coll_bytes_per_chip: float,
    chips: int,
    model_flops: float,
) -> Roofline:
    compute_s = flops_per_chip / PEAK_BF16_FLOPS
    memory_s = bytes_per_chip / HBM_BW
    collective_s = coll_bytes_per_chip / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    bottleneck = max(terms, key=terms.get)
    total_hlo_flops = flops_per_chip * chips
    useful = model_flops / total_hlo_flops if total_hlo_flops else 0.0
    # roofline fraction: useful model FLOPs over what the dominant term's
    # wall-time could have delivered at peak compute.
    dom = max(terms.values())
    frac = (model_flops / chips / PEAK_BF16_FLOPS) / dom if dom > 0 else 0.0
    return Roofline(compute_s, memory_s, collective_s, flops_per_chip,
                    bytes_per_chip, coll_bytes_per_chip, model_flops,
                    useful, bottleneck, frac)


def cost_analysis_dict(compiled) -> Dict[str, float]:
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return dict(ca or {})


def memory_analysis_dict(compiled) -> Dict[str, float]:
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return {}
    if ma is None:
        return {}
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "alias_size_in_bytes",
              "generated_code_size_in_bytes"):
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = int(v)
    return out


def assert_no_collectives(compiled_or_text, where: str = "program") -> None:
    """Assert a lowered/compiled program contains zero collective ops.

    The machine-locality acceptance check (paper §3.3, in contrast to
    Tvarak's cross-node offload): every sharded redundancy program —
    Algorithm 1 full, queued, and the overlap (async) variants — must
    lower to purely shard-local HLO.  Accepts a compiled executable, a
    ``jax.stages.Lowered``, or raw (partitioned) HLO text.
    """
    txt = compiled_or_text
    if not isinstance(txt, str):
        if hasattr(txt, "compile"):          # Lowered -> Compiled
            txt = txt.compile()
        txt = txt.as_text()
    found = sorted({op for op in COLLECTIVES if op in txt})
    assert not found, f"{where}: collectives in lowered HLO: {found}"
