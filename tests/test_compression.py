"""Gradient compression: quantization roundtrip + error feedback contract."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.dist.compression import BLOCK, _dequantize, _quantize


def test_quantize_roundtrip_bounded_error():
    x = jax.random.normal(jax.random.PRNGKey(0), (4 * BLOCK,)) * 3.0
    q, s = _quantize(x)
    back = _dequantize(q, s)
    err = np.abs(np.asarray(back - x))
    # per-block max error <= scale/2 = max|x|/254
    bounds = np.repeat(np.asarray(s).ravel() / 2 + 1e-7, BLOCK)
    assert (err <= bounds).all()


def test_error_feedback_accumulates_to_exact():
    """Sum over steps of (sent + error_t - error_{t-1}) == sum of inputs:
    EF guarantees no gradient mass is lost over time."""
    key = jax.random.PRNGKey(1)
    xs = jax.random.normal(key, (10, 2 * BLOCK)) * 0.1
    err = jnp.zeros((2 * BLOCK,))
    sent_total = jnp.zeros((2 * BLOCK,))
    for t in range(10):
        flat = xs[t] + err
        q, s = _quantize(flat)
        sent = _dequantize(q, s)
        err = flat - sent
        sent_total = sent_total + sent
    np.testing.assert_allclose(
        np.asarray(sent_total + err), np.asarray(xs.sum(0)), rtol=1e-5, atol=1e-5)
