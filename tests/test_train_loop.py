"""Train loop in all three redundancy modes: observational equivalence,
Algorithm-1 scheduling, accumulation equivalence."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.core import RedundancyConfig, RedundancyEngine
from repro.data import SyntheticPipeline
from repro.models import build_model
from repro.models.config import ShapeConfig
from repro.optim import AdamW, warmup_cosine
from repro.train import Trainer, protected_structs
from repro.train.train_loop import make_train_step


def _setup(arch="llama3.2-3b", mode="vilamb", period=4):
    cfg = get_smoke(arch)
    m = build_model(cfg)
    opt = AdamW(lr=warmup_cosine(3e-3, 5, 100))
    engine = None
    if mode != "none":
        p0 = jax.eval_shape(m.init, jax.random.PRNGKey(0))
        o0 = jax.eval_shape(opt.init, p0)
        engine = RedundancyEngine(
            protected_structs(p0, o0),
            RedundancyConfig(mode=mode, period_steps=period, lanes_per_block=512))
    tr = Trainer(model=m, opt=opt, engine=engine, mode=mode,
                 period_steps=period, scrub_period_steps=5)
    data = SyntheticPipeline(cfg, ShapeConfig("t", 64, 4, "train"), seed=0)
    return cfg, tr, data


@pytest.mark.parametrize("mode", ["none", "vilamb", "sync"])
def test_modes_train_identically(mode):
    """Redundancy is observational: losses must match No-Redundancy exactly."""
    cfg, tr, data = _setup(mode=mode)
    st = tr.init_state(jax.random.PRNGKey(0))
    losses = []
    st = tr.run(st, data, 8, on_step=lambda s, m: losses.append(float(m["loss"])))
    assert losses[-1] < losses[0]
    assert tr.corruption_alarms == 0
    if mode != "none":
        st = tr.flush(st)
        mm = tr.scrub_fn(st)
        assert sum(int(v.sum()) for v in jax.tree.leaves(mm)) == 0


def test_mode_losses_equal():
    results = {}
    for mode in ("none", "vilamb", "sync"):
        _, tr, data = _setup(mode=mode)
        st = tr.init_state(jax.random.PRNGKey(0))
        losses = []
        st = tr.run(st, data, 5, on_step=lambda s, m: losses.append(float(m["loss"])))
        results[mode] = losses
    np.testing.assert_allclose(results["none"], results["vilamb"], rtol=0, atol=0)
    np.testing.assert_allclose(results["none"], results["sync"], rtol=0, atol=0)


def test_grad_accumulation_equivalent():
    cfg = dataclasses.replace(get_smoke("olmo-1b"), param_dtype="float32")
    m = build_model(cfg)
    opt = AdamW(lr=lambda s: 1e-3)
    data = SyntheticPipeline(cfg, ShapeConfig("t", 32, 8, "train"), seed=1)
    batch = data.get(0)
    params = m.init(jax.random.PRNGKey(0))
    from repro.train.state import TrainState
    st = TrainState.create(params, opt.init(params))
    s1 = make_train_step(m, opt, None, "none", accum_steps=1)
    s4 = make_train_step(m, opt, None, "none", accum_steps=4)
    st1, m1 = jax.jit(s1)(st, batch)
    st4, m4 = jax.jit(s4)(st, batch)
    # same data, same total gradient: loss and grad norm agree; params agree
    # to Adam's first-step scale (lr) — near-zero grads flip sign freely
    # between accumulation orders, so atol is in units of lr.
    np.testing.assert_allclose(float(m1["loss"]), float(m4["loss"]), rtol=1e-5)
    np.testing.assert_allclose(float(m1["grad_norm"]), float(m4["grad_norm"]), rtol=1e-3)
    for a, b in zip(jax.tree.leaves(st1.params), jax.tree.leaves(st4.params)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=2.1e-3)


def test_vilamb_amortization_counter():
    """Dirty bits accumulate across steps and clear at the period boundary."""
    from repro.core import bits
    cfg, tr, data = _setup(mode="vilamb", period=100)  # loop won't trigger it
    st = tr.init_state(jax.random.PRNGKey(0))
    st = tr.run(st, data, 3)
    dirty_total = sum(int(bits.popcount(r.dirty)) for r in st.red.values())
    assert dirty_total > 0  # marked, not yet flushed
    st = tr.flush(st)
    dirty_total = sum(int(bits.popcount(r.dirty)) for r in st.red.values())
    assert dirty_total == 0
