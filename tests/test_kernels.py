"""Per-kernel shape/dtype sweeps vs the pure-jnp oracles (interpret=True)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container lacks hypothesis: deterministic shim
    from _hypothesis_fallback import given, settings, strategies as st

from repro.kernels.checksum import ops as cops
from repro.kernels.checksum import ref as cref
from repro.kernels.parity import ops as pops
from repro.kernels.parity import ref as pref
from repro.kernels.redundancy import ops as rops
from repro.kernels.redundancy import ref as rref


def _lanes(seed, nb, L):
    return jax.random.randint(jax.random.PRNGKey(seed), (nb, L), 0, 2**31 - 1, jnp.uint32)


@pytest.mark.parametrize("nb,L", [(1, 128), (3, 128), (13, 512), (8, 1024), (5, 4096 * 2)])
def test_checksum_kernel_shapes(nb, L):
    lanes = _lanes(0, nb, L)
    k = cops.block_checksums(lanes, use_pallas=True, interpret=True)
    np.testing.assert_array_equal(np.asarray(k), np.asarray(cref.block_checksums(lanes)))


@pytest.mark.parametrize("nb,L,sw", [(1, 128, 4), (9, 256, 2), (13, 512, 4),
                                     (10, 128, 5), (16, 8192, 4)])
def test_parity_kernel_shapes(nb, L, sw):
    lanes = _lanes(1, nb, L)
    k = pops.stripe_parity(lanes, stripe_width=sw, interpret=True)
    np.testing.assert_array_equal(np.asarray(k), np.asarray(pref.stripe_parity(lanes, sw)))


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 100), st.integers(1, 14), st.sampled_from([128, 256]),
       st.sampled_from([2, 4]), st.data())
def test_fused_kernel_property(seed, nb, L, sw, data):
    lanes = _lanes(seed, nb, L)
    bd = np.array(data.draw(st.lists(st.booleans(), min_size=nb, max_size=nb)))
    ns = -(-nb // sw)
    pad = np.zeros(ns * sw, bool)
    pad[:nb] = bd
    sd = pad.reshape(ns, sw).any(axis=1)
    old_cks = cref.block_checksums(lanes) ^ jnp.uint32(99)
    old_par = pref.stripe_parity(lanes, sw) ^ jnp.uint32(7)
    ck_k, pr_k = rops.fused_update(lanes, old_cks, old_par, jnp.asarray(bd),
                                   jnp.asarray(sd), sw, use_pallas=True, interpret=True)
    ck_r, pr_r = rref.fused_update(lanes, old_cks, old_par, jnp.asarray(bd),
                                   jnp.asarray(sd), sw)
    np.testing.assert_array_equal(np.asarray(ck_k), np.asarray(ck_r))
    np.testing.assert_array_equal(np.asarray(pr_k), np.asarray(pr_r))


def test_fused_kernel_work_queue_semantics():
    """Clean stripes' outputs must be byte-identical to old values even when
    the kernel never visits them (the work-queue skip, DESIGN.md kernels)."""
    lanes = _lanes(5, 12, 256)
    old_cks = jnp.arange(12, dtype=jnp.uint32) * 7
    old_par = jnp.full((3, 256), 0xABC, jnp.uint32)
    bd = jnp.zeros(12, bool)  # nothing dirty
    sd = jnp.zeros(3, bool)
    cks, par = rops.fused_update(lanes, old_cks, old_par, bd, sd, 4,
                                 use_pallas=True, interpret=True)
    np.testing.assert_array_equal(np.asarray(cks), np.asarray(old_cks))
    np.testing.assert_array_equal(np.asarray(par), np.asarray(old_par))
