"""Per-kernel shape/dtype sweeps vs the pure-jnp oracles (interpret=True)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container lacks hypothesis: deterministic shim
    from _hypothesis_fallback import given, settings, strategies as st

from repro.kernels.checksum import ops as cops
from repro.kernels.checksum import ref as cref
from repro.kernels.parity import ops as pops
from repro.kernels.parity import ref as pref
from repro.kernels.redundancy import ops as rops
from repro.kernels.redundancy import ref as rref


def _lanes(seed, nb, L):
    return jax.random.randint(jax.random.PRNGKey(seed), (nb, L), 0, 2**31 - 1, jnp.uint32)


@pytest.mark.parametrize("nb,L", [(1, 128), (3, 128), (13, 512), (8, 1024), (5, 4096 * 2)])
def test_checksum_kernel_shapes(nb, L):
    lanes = _lanes(0, nb, L)
    k = cops.block_checksums(lanes, use_pallas=True, interpret=True)
    np.testing.assert_array_equal(np.asarray(k), np.asarray(cref.block_checksums(lanes)))


@pytest.mark.parametrize("nb,L,sw", [(1, 128, 4), (9, 256, 2), (13, 512, 4),
                                     (10, 128, 5), (16, 8192, 4)])
def test_parity_kernel_shapes(nb, L, sw):
    lanes = _lanes(1, nb, L)
    k = pops.stripe_parity(lanes, stripe_width=sw, interpret=True)
    np.testing.assert_array_equal(np.asarray(k), np.asarray(pref.stripe_parity(lanes, sw)))


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 100), st.integers(1, 14), st.sampled_from([128, 256]),
       st.sampled_from([2, 4]), st.data())
def test_fused_kernel_property(seed, nb, L, sw, data):
    lanes = _lanes(seed, nb, L)
    bd = np.array(data.draw(st.lists(st.booleans(), min_size=nb, max_size=nb)))
    ns = -(-nb // sw)
    pad = np.zeros(ns * sw, bool)
    pad[:nb] = bd
    sd = pad.reshape(ns, sw).any(axis=1)
    old_cks = cref.block_checksums(lanes) ^ jnp.uint32(99)
    old_par = pref.stripe_parity(lanes, sw) ^ jnp.uint32(7)
    ck_k, pr_k = rops.fused_update(lanes, old_cks, old_par, jnp.asarray(bd),
                                   jnp.asarray(sd), sw, use_pallas=True, interpret=True)
    ck_r, pr_r = rref.fused_update(lanes, old_cks, old_par, jnp.asarray(bd),
                                   jnp.asarray(sd), sw)
    np.testing.assert_array_equal(np.asarray(ck_k), np.asarray(ck_r))
    np.testing.assert_array_equal(np.asarray(pr_k), np.asarray(pr_r))


# Adversarial lane payloads: float32 NaN/Inf patterns, zeros (XOR
# absorbing) and saturated words — kernels treat lanes as raw bits, so
# these must match the oracles exactly, not merely numerically.
SPECIALS = np.array([0x7FC00000, 0x7F800000, 0xFF800000, 0x7F800001,
                     0x00000000, 0xFFFFFFFF], dtype=np.uint32)


def _special_lanes(nb, L, offset=0):
    return jnp.asarray(
        SPECIALS[(np.arange(nb * L) + offset) % len(SPECIALS)]
        .reshape(nb, L))


@pytest.mark.parametrize("nb,L", [(1, 128), (5, 256), (13, 512)])
def test_checksum_kernel_special_values(nb, L):
    lanes = _special_lanes(nb, L)
    k = cops.block_checksums(lanes, use_pallas=True, interpret=True)
    np.testing.assert_array_equal(
        np.asarray(k), np.asarray(cref.block_checksums(lanes)))
    # identical NaN-pattern blocks must still checksum differently
    # (position salting defeats block-swap aliasing)
    if nb > 1:
        assert len(set(np.asarray(k).tolist())) == nb


@pytest.mark.parametrize("nb,L,sw", [(4, 128, 4), (10, 256, 5)])
def test_parity_kernel_special_values(nb, L, sw):
    lanes = _special_lanes(nb, L, offset=1)
    k = pops.stripe_parity(lanes, stripe_width=sw, interpret=True)
    np.testing.assert_array_equal(
        np.asarray(k), np.asarray(pref.stripe_parity(lanes, sw)))


def test_fused_kernel_special_values_and_zero_dirty():
    """NaN/Inf slabs through the fused kernel: dirty blocks refresh to the
    oracle's bits, a zero-dirty call is a bitwise no-op."""
    lanes = _special_lanes(12, 256, offset=2)
    old_cks = cref.block_checksums(lanes) ^ jnp.uint32(0xDEAD)
    old_par = pref.stripe_parity(lanes, 4) ^ jnp.uint32(0xBEEF)
    bd = jnp.zeros(12, bool).at[jnp.array([0, 5, 11])].set(True)
    sd = jnp.zeros(3, bool).at[jnp.array([0, 1, 2])].set(True)
    ck_k, pr_k = rops.fused_update(lanes, old_cks, old_par, bd, sd, 4,
                                   use_pallas=True, interpret=True)
    ck_r, pr_r = rref.fused_update(lanes, old_cks, old_par, bd, sd, 4)
    np.testing.assert_array_equal(np.asarray(ck_k), np.asarray(ck_r))
    np.testing.assert_array_equal(np.asarray(pr_k), np.asarray(pr_r))
    zd = jnp.zeros(12, bool)
    ck0, pr0 = rops.fused_update(lanes, old_cks, old_par, zd,
                                 jnp.zeros(3, bool), 4,
                                 use_pallas=True, interpret=True)
    np.testing.assert_array_equal(np.asarray(ck0), np.asarray(old_cks))
    np.testing.assert_array_equal(np.asarray(pr0), np.asarray(old_par))


def test_fused_kernel_work_queue_semantics():
    """Clean stripes' outputs must be byte-identical to old values even when
    the kernel never visits them (the work-queue skip, DESIGN.md kernels)."""
    lanes = _lanes(5, 12, 256)
    old_cks = jnp.arange(12, dtype=jnp.uint32) * 7
    old_par = jnp.full((3, 256), 0xABC, jnp.uint32)
    bd = jnp.zeros(12, bool)  # nothing dirty
    sd = jnp.zeros(3, bool)
    cks, par = rops.fused_update(lanes, old_cks, old_par, bd, sd, 4,
                                 use_pallas=True, interpret=True)
    np.testing.assert_array_equal(np.asarray(cks), np.asarray(old_cks))
    np.testing.assert_array_equal(np.asarray(par), np.asarray(old_par))
