"""Freshness-SLO health governor: breaker state machine, escalation
ladder (retry -> forced resolve -> backpressure -> sync escalation),
shared retry backoff, deadline-clock continuity across remesh, and the
chaos-soak battery's invariants.
"""
from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from subproc import run_snippet, MESH_PRELUDE

from repro.core import (ProtectedStore, RedundancyPolicy,
                        UnrecoverableReadError)
from repro.core import store as store_mod
from repro.core.store import TickReport
from repro.faults.inject import FaultSpec, apply_fault
from repro.health import (BackpressureError, CRITICAL, DEGRADED,
                          FreshnessViolationError, HEALTHY, HealthGovernor,
                          HealthPolicy, backoff_delay, backoff_schedule)

LANES = 64


def _store(health=None, *, period=2, n_rows=16, async_tick=True, **pol_kw):
    pol = RedundancyPolicy.single("vilamb", period_steps=period,
                                  lanes_per_block=LANES,
                                  async_tick=async_tick, health=health,
                                  **pol_kw)
    lv = {"w": jax.random.normal(jax.random.PRNGKey(0), (n_rows, 512),
                                 jnp.float32)}
    store = ProtectedStore(pol).attach(lv)
    red = store.init(lv)
    red = store.flush(lv, red, step=0)
    return store, lv, red


def _write(store, lv, red, rows=(0, 1)):
    idx = jnp.asarray(rows)
    lv = dict(lv, w=lv["w"].at[idx].add(0.5))
    ev = jnp.zeros((lv["w"].shape[0],), bool).at[idx].set(True)
    return lv, store.on_write(red, events={"w": ev})


def _group(store):
    return next(iter(store.groups.values()))


# ------------------------------------------------------------ retry backoff

def test_backoff_delay_exponential_and_cap():
    assert backoff_delay(1, 0.01) == pytest.approx(0.01)
    assert backoff_delay(2, 0.01) == pytest.approx(0.02)
    assert backoff_delay(3, 0.01) == pytest.approx(0.04)
    assert backoff_delay(4, 0.01, cap=0.03) == pytest.approx(0.03)
    assert backoff_delay(3, 0.0) == 0.0


def test_backoff_jitter_only_shrinks():
    import random
    rng = random.Random(7)
    for attempt in range(1, 6):
        base = backoff_delay(attempt, 0.01)
        jittered = backoff_delay(attempt, 0.01, jitter_frac=0.5, rng=rng)
        assert 0.5 * base <= jittered <= base


def test_backoff_schedule_total_budget():
    # raw [0.01, 0.02, 0.04->cap 0.02]; cumulative [0.01, 0.03, 0.05]
    # clipped to total 0.035 -> last delay degenerates to 0.005.
    ds = backoff_schedule(3, 0.01, cap=0.02, total=0.035)
    assert ds == pytest.approx([0.01, 0.02, 0.005])
    assert backoff_schedule(3, 0.0) == [0.0, 0.0, 0.0]
    assert sum(backoff_schedule(10, 0.01, total=0.02)) <= 0.02 + 1e-9


def test_read_verified_backoff_schedule_applied(monkeypatch):
    """The read-retry path uses the shared exponential schedule: with
    attempts=4, base 10ms, cap 20ms, total budget 35ms the sleeps are
    exactly [10ms, 20ms, 5ms]."""
    pol_kw = dict(read_retry_attempts=4, read_retry_backoff_s=0.01,
                  read_retry_backoff_cap_s=0.02, read_retry_total_s=0.035,
                  read_retry_jitter_frac=0.0)
    store, lv, red = _store(async_tick=False, **pol_kw)
    # Two corruptions in one stripe defeat single parity -> every retry
    # re-reads, then the typed error surfaces.
    for blk in (0, 1):
        lv, red = apply_fault(store.metas, lv, red,
                              FaultSpec("data_bitflip", "w", block=blk,
                                        lane=3, bit=7))
    sleeps = []
    monkeypatch.setattr(time, "sleep", sleeps.append)
    with pytest.raises(UnrecoverableReadError):
        store.read_verified(lv, red, "w", [0])
    assert sleeps == pytest.approx([0.01, 0.02, 0.005])


# ------------------------------------------------------- governor plumbing

def test_governor_off_by_default():
    store, lv, red = _store(health=None)
    lv, red = _write(store, lv, red)
    red, rep = store.tick(lv, red, 1, scrub_period=0)
    assert rep.health is None
    assert store._health is None


def test_governor_on_reports_healthy():
    store, lv, red = _store(HealthPolicy(violation_mode="report"))
    label = _group(store).label
    for step in range(1, 5):
        lv, red = _write(store, lv, red)
        red, rep = store.tick(lv, red, step, step_time=0.01, scrub_period=0)
        assert rep.health is not None
        assert rep.health.states[label] == HEALTHY
        assert rep.health.worst == HEALTHY
    assert rep.health.ages[label][0] >= 0


# ------------------------------------------------- rung 1: timeout + retry

def test_rung1_timeout_rolls_back_and_redispatches(monkeypatch):
    hp = HealthPolicy(dispatch_timeout_s=0.001, dispatch_retry_attempts=3,
                      retry_backoff_s=0.005, retry_jitter_frac=0.0,
                      violation_mode="report")
    store, lv, red = _store(hp)
    hg = store._health
    sleeps = []
    hg._sleep = sleeps.append
    for step in (1, 2):
        lv, red = _write(store, lv, red)
        red, rep = store.tick(lv, red, step, step_time=0.01, scrub_period=0)
    g = _group(store)
    assert g.pending is not None
    prev = g.pending.prev_step
    monkeypatch.setattr(store_mod, "_ready", lambda fits: False)
    g.pending.dispatched_at -= 10.0           # pending looks ancient
    red, rep = store.tick(lv, red, 3, step_time=0.01, scrub_period=0)
    acts = [(a.rung, a.kind) for a in rep.health.actions]
    assert (1, "retry_timeout") in acts
    assert rep.health.states[g.label] == DEGRADED
    assert sleeps == pytest.approx([0.005])    # bounded backoff slept
    # Re-dispatched THIS tick (a fresh pending), not at the next period
    # boundary — otherwise the breaker cools down between retries.
    assert g.pending is not None
    assert g.pending.prev_step <= prev


def test_rung1_exhaustion_escalates_then_recovers(monkeypatch):
    hp = HealthPolicy(dispatch_timeout_s=1e-6, dispatch_retry_attempts=1,
                      retry_backoff_s=0.0, backpressure="spin",
                      backpressure_spin_s=0.0, recovery_ticks=2,
                      violation_mode="report")
    store, lv, red = _store(hp)
    hg = store._health
    hg._sleep = lambda s: None
    monkeypatch.setattr(store_mod, "_ready", lambda fits: False)
    label = _group(store).label
    step, worst_seen = 1, []
    for _ in range(8):
        lv, red = _write(store, lv, red)
        red, rep = store.tick(lv, red, step, step_time=0.01, scrub_period=0)
        step += 1
        worst_seen.append(rep.health.states[label])
        if rep.health.states[label] == CRITICAL:
            break
    assert CRITICAL in worst_seen
    gh = hg.group(label)
    assert gh.sync_escalated and gh.backpressure
    kinds = {a.kind for a in rep.health.actions}
    assert {"retry_exhausted", "backpressure_on", "sync_escalate"} <= kinds
    # Recovery: the sync-escalated group updates via the blocking path
    # (calm), the breaker steps down one level per recovery_ticks calm
    # ticks, backpressure clears below CRITICAL, retries reset at HEALTHY.
    seen = []
    for _ in range(12):
        lv, red = _write(store, lv, red)
        red, rep = store.tick(lv, red, step, step_time=0.01, scrub_period=0)
        step += 1
        seen.append(rep.health.states[label])
        if rep.health.states[label] == HEALTHY:
            break
    assert seen[-1] == HEALTHY
    assert DEGRADED in seen                    # hysteresis: one level at a time
    assert not hg.group(label).backpressure
    assert not hg.group(label).sync_escalated
    assert hg.group(label).retries == 0


# ---------------------------------------------- rung 2: forced resolve

def test_rung2_margin_forces_blocking_resolve(monkeypatch):
    hp = HealthPolicy(dispatch_timeout_s=0.0,       # rung 1 disabled
                      deadline_margin_steps=2, violation_mode="report")
    store, lv, red = _store(hp, period=4, max_vulnerable_steps=6)
    monkeypatch.setattr(store_mod, "_ready", lambda fits: False)
    for step in range(1, 5):
        lv, red = _write(store, lv, red)
        red, rep = store.tick(lv, red, step, step_time=0.01, scrub_period=0)
    g = _group(store)
    assert g.pending is not None               # wedged probe: still in flight
    # Quiet ticks: the margin (deadline 6 - margin 2 = age 4) hits at
    # step 8; wait=True bypasses the probe and adopts the update early.
    fired = None
    for step in range(5, 9):
        red, rep = store.tick(lv, red, step, step_time=0.01, scrub_period=0)
        if any(a.kind == "forced_resolve" for a in rep.health.actions):
            fired = step
            break
    assert fired == 8, fired
    acts = [(a.rung, a.kind) for a in rep.health.actions]
    assert (2, "forced_resolve") in acts
    assert rep.health.states[g.label] == DEGRADED
    assert not rep.deadline_fired              # met early, not missed


# ------------------------------------------- rung 3: admission control

def test_backpressure_error_policy_raises_typed():
    hp = HealthPolicy(backpressure="error", violation_mode="report")
    store, lv, red = _store(hp)
    hg = store._health
    label = _group(store).label
    hg.group(label).backpressure = True
    with pytest.raises(BackpressureError) as ei:
        _write(store, lv, red)
    assert label in ei.value.groups


def test_backpressure_spin_policy_bounded_stall():
    hp = HealthPolicy(backpressure="spin", backpressure_spin_s=0.002,
                      violation_mode="report")
    store, lv, red = _store(hp)
    hg = store._health
    spins = []
    hg._sleep = spins.append
    hg.group(_group(store).label).backpressure = True
    lv, red = _write(store, lv, red)           # no raise: bounded spin
    assert spins == [0.002]


def test_backpressure_noop_under_trace():
    """Admission control must never block inside a jitted step — the
    tracer check turns it into a no-op under trace."""
    hp = HealthPolicy(backpressure="error", violation_mode="report")
    store, lv, red = _store(hp)
    store._health.group(_group(store).label).backpressure = True
    ev = jnp.zeros((lv["w"].shape[0],), bool).at[0].set(True)
    stepped = jax.jit(lambda r: store.on_write(r, events={"w": ev}))
    red2 = stepped(red)                        # would raise on the host path
    assert red2 is not None


# ----------------------------------------------- violations are typed

def _violating_governor(mode):
    hp = HealthPolicy(violation_mode=mode)
    store, lv, red = _store(hp, max_vulnerable_steps=4)
    hg = store._health
    g = _group(store)
    g.last_update_step = -10                   # ancient unprotected write
    return store, hg, g


def test_violation_reported_never_silent():
    store, hg, g = _violating_governor("report")
    now = time.monotonic()
    hg.begin_tick(20, now)
    rep = TickReport(step=20)
    hg.end_tick(rep, 20, now)
    assert rep.health.violations, "deadline excursion must be surfaced"
    v = rep.health.violations[0]
    assert v.group == g.label and v.age_steps == 30
    assert rep.health.states[g.label] == CRITICAL
    assert hg.group(g.label).backpressure or hg.group(g.label).sync_escalated


def test_violation_mode_raise_is_typed():
    store, hg, g = _violating_governor("raise")
    now = time.monotonic()
    hg.begin_tick(20, now)
    with pytest.raises(FreshnessViolationError) as ei:
        hg.end_tick(TickReport(step=20), 20, now)
    assert ei.value.violations[0].group == g.label


def test_health_policy_validation():
    with pytest.raises(ValueError):
        HealthPolicy(backpressure="bogus")
    with pytest.raises(ValueError):
        HealthPolicy(violation_mode="bogus")


# ------------------------------- patrol starvation x governor backpressure

def test_patrol_floor_survives_backpressure():
    """The patrol starvation floor keeps forcing probes while the
    governor applies backpressure, and the governor's report mirrors the
    starvation streak."""
    hp = HealthPolicy(backpressure="spin", backpressure_spin_s=0.001,
                      violation_mode="report")
    bpb = LANES * 4
    pol = RedundancyPolicy.single(
        "vilamb", period_steps=1, lanes_per_block=LANES,
        patrol_bytes_per_tick=8 * bpb, patrol_max_starved_ticks=4,
        async_tick=False, precompile=False, health=hp)
    lv = {"w": jax.random.normal(jax.random.PRNGKey(0), (32, 512),
                                 jnp.float32)}
    store = ProtectedStore(pol).attach(lv)
    red = store.init(lv)
    hg = store._health
    spins = []
    hg._sleep = spins.append
    hg.group(_group(store).label).backpressure = True
    for step in range(1, 31):
        lv, red = _write(store, lv, red, rows=(0, 1, 2, 3))
        red, rep = store.tick(lv, red, step, step_time=0.01, scrub_period=0)
        assert rep.updated, "tick unexpectedly quiet"
        assert rep.health.patrol_starved_ticks == rep.patrol_starved_ticks
    assert store.patroller.blocks_scanned >= 8   # floor forced probes
    assert rep.patrol_starved_ticks <= 4
    assert spins == [0.001] * 30                 # every admit spun, none raised


# ------------------------------ deadline-clock continuity across remesh

def test_remesh_adoption_carries_freshness_clocks():
    """Adoption must copy the old group's freshness clocks bit-for-bit:
    a fresh _Group would report step 0 / time.monotonic() and either
    fire a spurious steps-deadline right after adoption or silently
    extend the wall-clock one by the whole migration.  A huge period
    plus a one-tick migration budget keeps every dispatch out of the
    window, so the carry is observable exactly; the steps-deadline then
    fires at the step predicted by the *carried* clock, not rebased to
    the adoption step.  Health governor off: base store mechanics."""
    code = """
    store = mesh_store(period=64, max_vulnerable_steps=20,
                       remesh_bytes_per_tick=1 << 22)
    lv = put(make_leaves())
    red = store.init(lv)
    def write(lv, red):
        idx = jnp.asarray([0, 1])
        lv = dict(lv, w=lv["w"].at[idx].add(0.5))
        ev = jnp.zeros((64,), bool).at[idx].set(True)
        return lv, store.on_write(red, events={"w": ev})
    for step in range(1, 4):
        lv, red = write(lv, red)
        red, rep = store.tick(lv, red, step, scrub_period=0)
    g = [g for g in store.groups.values() if "w" in g.names][0]
    label = g.label
    # Pin a known freshness origin.  The wall-clock rewind makes a
    # reset-to-now at adoption visible; with max_vulnerable_seconds=0
    # it cannot trip the overdue path and refresh itself first.
    g.last_update_step = 3
    g.last_update_time -= 1000.0
    old_step, old_time = g.last_update_step, g.last_update_time
    store.remesh(make_mesh((1, 2, 2), ("pod", "data", "model")))
    step = 3
    while store.remeshing:
        step += 1
        assert step < 20, "migration outran the deadline window"
        lv, red = write(lv, red)
        red, rep = store.tick(lv, red, step, scrub_period=0)
        if rep.repaired:
            lv = dict(lv, **rep.repaired)
        assert not rep.deadline_fired, rep
    g2 = [g for g in store.groups.values() if g.label == label][0]
    assert g2 is not g
    assert g2.last_update_step == old_step, (g2.last_update_step, old_step)
    assert g2.last_update_time == old_time, (g2.last_update_time, old_time)
    fired_at = None
    while fired_at is None:
        step += 1
        assert step <= 23, "deadline never fired from carried clock"
        lv, red = write(lv, red)
        red, rep = store.tick(lv, red, step, scrub_period=0)
        if label in rep.deadline_fired:
            fired_at = step
    assert fired_at == old_step + 20, fired_at
    print("REBASE-OK")
    """
    run_snippet(code, "REBASE-OK", prelude=MESH_PRELUDE)


def test_governor_drains_remesh_at_deadline():
    """THE silent freshness hole: during a remesh the per-group update
    loop is skipped wholesale.  With the governor on, a group hitting
    its deadline mid-migration forces the remesh to drain and a blocking
    update runs — surfaced as a rung-2 remesh_drain action, never a
    silent excursion."""
    code = """
    from repro.health import HealthPolicy
    store = mesh_store(period=2, max_vulnerable_steps=6,
                       remesh_bytes_per_tick=128 * 4,
                       health=HealthPolicy(dispatch_timeout_s=0.0,
                                           deadline_margin_steps=1,
                                           violation_mode="report"))
    lv = put(make_leaves())
    red = store.init(lv)
    def write(lv, red):
        idx = jnp.asarray([0, 1])
        lv = dict(lv, w=lv["w"].at[idx].add(0.5))
        ev = jnp.zeros((64,), bool).at[idx].set(True)
        return lv, store.on_write(red, events={"w": ev})
    step = 0
    for step in range(1, 5):
        lv, red = write(lv, red)
        red, rep = store.tick(lv, red, step, scrub_period=0)
    store.remesh(make_mesh((1, 2, 2), ("pod", "data", "model")))
    drained = violated = False
    while store.remeshing:
        step += 1
        lv, red = write(lv, red)
        red, rep = store.tick(lv, red, step, scrub_period=0)
        if rep.repaired:
            lv = dict(lv, **rep.repaired)
        h = rep.health
        if h is not None:
            drained |= any(a.kind == "remesh_drain" for a in h.actions)
            violated |= bool(h.violations)
        for g in store.groups.values():
            lp = g.policy
            if lp.mode != "vilamb" or lp.max_vulnerable_steps <= 0:
                continue
            age = step - g.last_update_step
            visible = h is not None and (
                any(v.group == g.label for v in h.violations)
                or any(a.group == g.label for a in h.actions))
            assert age <= lp.max_vulnerable_steps or visible, (
                "SILENT freshness excursion", g.label, age, step)
        assert step < 600, "remesh never finished"
    assert drained, "governor never drained the remesh"
    print("DRAIN-OK")
    """
    run_snippet(code, "DRAIN-OK", prelude=MESH_PRELUDE)


# --------------------------------------------------------- chaos battery

def test_chaos_soak_machine_local():
    """Machine-local smoke soak: bitflips + straggler storm + crash under
    live traffic.  Invariants: zero silent deadline violations, zero
    stale verified reads, final state bitwise-recovered."""
    from repro.faults import run_chaos_soak
    r = run_chaos_soak(seed=0, sharded=False, smoke=True)
    assert r.ok(), r.summary()
    assert r.silent_violations == 0
    assert r.reads_stale == 0
    assert r.final_clean and r.final_bitwise
    assert r.bitflips_injected > 0 and r.crash_restores > 0


def test_chaos_schedule_is_seeded_and_composable():
    from repro.faults import ChaosSchedule, StormPhase
    a = ChaosSchedule.default(3, sharded=True, smoke=True)
    b = ChaosSchedule.default(3, sharded=True, smoke=True)
    assert [p.kind for p in a.phases] == [p.kind for p in b.phases]
    assert {"bitflips", "straggler", "crash", "shard_loss",
            "remesh", "drain"} <= {p.kind for p in a.phases}
    custom = ChaosSchedule([StormPhase("traffic", steps=2),
                            StormPhase("drain")], seed=9)
    assert custom.phases[0].steps == 2 and custom.seed == 9
