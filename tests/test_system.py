"""End-to-end system behaviour: the full Vilamb story on one workload.

Train -> dirty accumulation -> periodic Algorithm 1 -> scrub -> SDC inject ->
detect -> parity repair -> preemption flush -> checkpoint -> restart ->
identical continuation.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke
from repro.ckpt import CheckpointManager, PreemptionHandler
from repro.ckpt.failure import repair_corruption
from repro.common import unflatten_dict
from repro.core import RedundancyConfig, RedundancyEngine
from repro.core import bits, blocks as B
from repro.data import SyntheticPipeline
from repro.models import build_model
from repro.models.config import ShapeConfig
from repro.optim import AdamW, warmup_cosine
from repro.train import Trainer, protected_leaves, protected_structs


def test_full_lifecycle(tmp_path):
    cfg = get_smoke("qwen3-moe-235b-a22b")  # sparse (MoE) -> real dirty tracking
    model = build_model(cfg)
    opt = AdamW(lr=warmup_cosine(1e-3, 5, 100))
    p0 = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    o0 = jax.eval_shape(opt.init, p0)
    engine = RedundancyEngine(
        protected_structs(p0, o0),
        RedundancyConfig(mode="vilamb", period_steps=3, lanes_per_block=128))
    trainer = Trainer(model=model, opt=opt, engine=engine, mode="vilamb",
                      period_steps=3, scrub_period_steps=4)
    data = SyntheticPipeline(cfg, ShapeConfig("t", 32, 4, "train"), seed=0)

    # 1) train with periodic redundancy
    state = trainer.init_state(jax.random.PRNGKey(0))
    losses = []
    state = trainer.run(state, data, 6,
                        on_step=lambda s, m: losses.append(float(m["loss"])))
    assert losses[-1] < losses[0]
    assert trainer.corruption_alarms == 0

    # 2) sparse leaves are NOT fully dirty (dirty tracking is meaningful)
    stats = engine.dirty_stats(state.red)
    moe_leaf = next(k for k in stats if "/moe/wi" in k)
    # after a redundancy step + up to 2 more training steps, the MoE slab has
    # bounded dirt (top-k of experts per step)
    assert int(stats[moe_leaf]["dirty_blocks"]) < int(stats[moe_leaf]["total_blocks"])

    # 3) SDC inject -> detect -> repair
    state = trainer.flush(state)
    leaves = protected_leaves(state.params, state.opt)
    name = moe_leaf
    meta = engine.metas[name]
    lanes = B.to_lanes(leaves[name], meta)
    leaves[name] = B.from_lanes(lanes.at[0, 11].add(0xF00D), meta)
    mm = engine.scrub(leaves, state.red)
    assert sum(int(v.sum()) for v in jax.tree.leaves(mm)) == 1
    repaired, fixed, lost = repair_corruption(engine, leaves, state.red, mm)
    assert (fixed, lost) == (1, 0)

    # 4) preemption: flush + checkpoint within grace
    handler = PreemptionHandler()
    ckpt = CheckpointManager(tmp_path)
    state = handler.drain(trainer, state, ckpt)
    assert handler.flush_seconds is not None

    # 5) restart resumes bit-identically
    st_re = ckpt.restore_into(jax.eval_shape(lambda: state))
    assert int(st_re.step) == int(state.step)
    cont1 = trainer.run(state, data, 2)
    cont2 = trainer.run(st_re, data, 2)
    np.testing.assert_array_equal(
        np.asarray(jax.tree.leaves(cont1.params)[0]),
        np.asarray(jax.tree.leaves(cont2.params)[0]))
