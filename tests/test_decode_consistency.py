"""Decode path == prefill path (fp32, no-drop MoE capacity: exact)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import fp32_exact, tiny_batch
from repro.configs import get_smoke
from repro.models import build_model

ARCHS = ["llama3.2-3b", "jamba-1.5-large-398b", "xlstm-1.3b",
         "seamless-m4t-medium", "internvl2-1b", "qwen3-moe-235b-a22b"]


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_prefill(arch):
    cfg = fp32_exact(get_smoke(arch))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    B, S = 2, 16
    batch = tiny_batch(cfg, B=B, S=S, seed=5)
    batch.pop("labels")
    logits1, caches, pos = jax.jit(lambda p, b: model.prefill(p, b, 64))(params, batch)
    tok = jnp.argmax(logits1, -1).astype(jnp.int32)
    logits2, caches2, nxt, _ = jax.jit(model.decode_step)(params, caches, tok, pos)
    batch_ext = dict(batch, tokens=jnp.concatenate([batch["tokens"], tok[:, None]], 1))
    logits_ref, _, _ = jax.jit(lambda p, b: model.prefill(p, b, 64))(params, batch_ext)
    err = float(jnp.max(jnp.abs(logits2 - logits_ref)))
    scale = float(jnp.max(jnp.abs(logits_ref))) + 1e-9
    assert err / scale < 1e-4, f"{arch}: rel err {err/scale:.2e}"


def test_multi_token_greedy_decode_stable():
    cfg = fp32_exact(get_smoke("glm4-9b"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    batch = tiny_batch(cfg, B=2, S=8, seed=2)
    batch.pop("labels")
    logits, caches, pos = model.prefill(params, batch, 40)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    step = jax.jit(model.decode_step)
    toks = [tok]
    for t in range(6):
        logits, caches, tok, _ = step(params, caches, tok, pos + t)
        assert bool(jnp.all(jnp.isfinite(logits)))
        toks.append(tok)
    out = jnp.stack(toks, 1)
    assert out.shape == (2, 7)
