"""Batched multi-group dispatch + off-thread tick resolver (PR10).

Covers the sharded-overlap regression fix at the unit level: a due tick
dispatches ONE batched update program for every due vilamb group, the
device->host fit fetch is owned by the resolver thread (or starts at
dispatch time in inline mode — never inside ``_resolve``), the resolver
thread's lifecycle is bounded by flush, and ``step`` threads through
settle/flush as an explicit Optional (step 0 is a real step, not
"unknown").  Multi-device batching is covered in tests/test_sharded.py.
"""
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core.store as store_mod
from repro.core import LeafPolicy, ProtectedStore, RedundancyPolicy

RED_FIELDS = ("checksums", "parity", "dirty", "shadow", "meta_ck")


def _leaves(seed=0):
    return {"w": jax.random.normal(jax.random.PRNGKey(seed), (24, 200),
                                   jnp.float32),
            "e": jax.random.normal(jax.random.PRNGKey(seed + 1), (16, 64),
                                   jnp.bfloat16)}


def _store(period=1, dispatcher_thread=True, **kw):
    pol = RedundancyPolicy.single(
        "vilamb", period_steps=period, lanes_per_block=128,
        work_queue_frac=0.5, async_tick=True, precompile=False,
        dispatcher_thread=dispatcher_thread, **kw)
    return ProtectedStore(pol).attach(_leaves())


def _group(store):
    return next(iter(store.groups.values()))


def _write(store, red, rows=(0,)):
    ev = jnp.zeros((24,), bool).at[jnp.asarray(list(rows))].set(True)
    return store.on_write(red, events={"w": ev})


def _dispatch_threads():
    return [t for t in threading.enumerate()
            if t.name == "repro-dispatch" and t.is_alive()]


@pytest.fixture()
def mkstore():
    """Store factory that joins any resolver thread at test teardown, so
    one test's parked daemon thread never leaks into the next."""
    stores = []

    def make(**kw):
        s = _store(**kw)
        stores.append(s)
        return s

    yield make
    for s in stores:
        s._stop_dispatcher()


# ------------------------------------------------------------- batching

def test_multigroup_due_tick_is_one_batched_launch():
    """Two due vilamb groups -> exactly one ``_update_many_fn`` call per
    due tick carrying both labels, sharing one stacked fits vector and
    one resolver event; the per-group programs never launch."""
    pol = RedundancyPolicy(
        default=LeafPolicy(mode="vilamb", period_steps=2,
                           work_queue_frac=0.5),
        rules=(("e", LeafPolicy(mode="vilamb", period_steps=2,
                                work_queue_frac=0.0)),),
        lanes_per_block=128, async_tick=True, precompile=False)
    store = ProtectedStore(pol).attach(_leaves())
    groups = list(store._protected())
    assert len(groups) == 2
    many_calls, single_calls = [], []
    orig_many = store._update_many_fn
    store._update_many_fn = lambda labels, variants: (
        many_calls.append((labels, variants)),
        orig_many(labels, variants))[1]
    orig = store._update_fn
    store._update_fn = lambda label, variant: (
        single_calls.append((label, variant)), orig(label, variant))[1]
    lv = _leaves()
    red = store.init(lv)
    for step in (1, 2, 3, 4):
        red = store.on_write(red, events={
            "w": jnp.zeros((24,), bool).at[step].set(True),
            "e": jnp.zeros((16,), bool).at[step].set(True)})
        store.sync_inflight()
        n = len(many_calls)
        red, _ = store.tick(lv, red, step)
        if step % 2 == 0:
            assert len(many_calls) == n + 1, many_calls
            labels, _variants = many_calls[-1]
            assert sorted(labels) == sorted(g.label for g in groups)
            p0, p1 = (g.pending for g in groups)
            assert p0 is not None and p1 is not None
            assert p0.fits is p1.fits          # one stacked fits vector
            assert p0.launched is p1.launched  # one resolver event
            assert p0.fits.shape == (2,), p0.fits.shape
            assert (p0.fits_index, p1.fits_index) == (0, 1)
        else:
            assert len(many_calls) == n
    assert not single_calls, single_calls
    red = store.settle(red, lv)
    assert sum(int(v.sum()) for v in store.scrub(lv, red).values()) == 0
    store._stop_dispatcher()


def test_dispatcher_modes_bitwise_identical(mkstore):
    """dispatcher_thread on/off settle to bitwise-identical red state."""
    outs = []
    for thread_on in (True, False):
        store = mkstore(period=2, dispatcher_thread=thread_on)
        lv = _leaves()
        red = store.init(lv)
        for step in range(1, 8):
            rows = [(step * 3) % 24, (step * 7) % 24]
            lv = dict(lv, w=lv["w"].at[jnp.asarray(rows)].add(0.25 * step))
            red = _write(store, red, rows)
            red, _ = store.tick(lv, red, step)
        red = store.settle(red, lv)
        outs.append(red)
        assert sum(int(v.sum()) for v in store.scrub(lv, red).values()) == 0
    for k in outs[0]:
        for f in RED_FIELDS:
            np.testing.assert_array_equal(
                np.asarray(getattr(outs[0][k], f)),
                np.asarray(getattr(outs[1][k], f)), err_msg=f"{k}.{f}")


# --------------------------------------------- resolve never syncs device

class _NoAsyncFits:
    """Stand-in for a backend array without ``copy_to_host_async``:
    counts host conversions so the test can pin down WHEN the fetch
    happened."""

    def __init__(self, arr):
        self._arr = np.asarray(arr)
        self.conversions = 0

    @property
    def shape(self):
        return self._arr.shape

    def is_ready(self):
        return True

    def __array__(self, dtype=None):
        self.conversions += 1
        return self._arr if dtype is None else self._arr.astype(dtype)


def test_inline_fallback_fetch_happens_at_dispatch_not_resolve():
    """Satellite regression: without ``copy_to_host_async`` the fit fetch
    must run at dispatch time — ``_resolve`` reads the cached host bool,
    never converting the device array."""
    store = _store(period=1, dispatcher_thread=False)
    proxies = []
    orig_many = store._update_many_fn

    def wrapped(labels, variants):
        fn = orig_many(labels, variants)

        def call(subs, reds):
            outs, fits = fn(subs, reds)
            proxy = _NoAsyncFits(fits)
            proxies.append(proxy)
            return outs, proxy

        return call

    store._update_many_fn = wrapped
    lv = _leaves()
    red = store.init(lv)
    red = _write(store, red, (1,))
    red, _ = store.tick(lv, red, 1)             # dispatch
    p = _group(store).pending
    assert p is not None and proxies, "expected an overlapped dispatch"
    assert proxies[-1].conversions == 1, \
        "fallback fetch must run once, at dispatch time"
    assert p.fits_host is not None
    red = _write(store, red, (2,))
    red, rep = store.tick(lv, red, 2)           # adopts the pending
    assert rep.updated
    assert proxies[0].conversions == 1, \
        "_resolve must not convert the device array (no sync in resolve)"


def test_threaded_resolve_reads_cached_host_bool(monkeypatch, mkstore):
    """With the resolver thread, adoption after the join reads the folded
    host bool — poisoning the fold function proves it is not re-run on
    the tick thread."""
    store = mkstore(period=3, dispatcher_thread=True)
    lv = _leaves()
    red = store.init(lv)
    for step in (1, 2, 3):                      # dispatches at step 3
        red = _write(store, red, (step,))
        red, _ = store.tick(lv, red, step)
    store.sync_inflight()
    p = _group(store).pending
    assert p is not None and p.fits_host is not None, \
        "resolver thread must have folded the fit signal to a host bool"

    def boom(row):
        raise AssertionError("fold_fits_host re-run at resolution")

    monkeypatch.setattr(store_mod.workqueue, "fold_fits_host", boom)
    red, _ = store.tick(lv, red, 4)             # not due: lazy adoption only
    assert _group(store).pending is None, "pending must have been adopted"
    monkeypatch.undo()
    red = store.settle(red, lv)
    assert sum(int(v.sum()) for v in store.scrub(lv, red).values()) == 0


# ------------------------------------------------------------- lifecycle

def test_resolver_thread_lifecycle_bounded_by_flush(mkstore):
    """The resolver thread spins up lazily at the first overlapped
    dispatch and flush joins it — no thread outlives the quiescent
    point."""
    before = set(_dispatch_threads())
    store = mkstore(period=1, dispatcher_thread=True)
    assert store._dispatcher is None
    lv = _leaves()
    red = store.init(lv)
    red = _write(store, red, (0,))
    red, _ = store.tick(lv, red, 1)
    d = store._dispatcher
    assert d is not None and d.thread.is_alive()
    assert d.thread.daemon and d.thread.name == "repro-dispatch"
    red = store.flush(lv, red, step=1)
    assert store._dispatcher is None and not d.thread.is_alive(), \
        "flush must join the resolver thread"
    assert set(_dispatch_threads()) <= before, \
        "flush must not leave this store's resolver thread behind"
    # re-created lazily by the next overlapped dispatch
    red = _write(store, red, (2,))
    red, _ = store.tick(lv, red, 2)
    assert store._dispatcher is not None and store._dispatcher is not d
    red = store.settle(red, lv)


def test_inline_mode_never_creates_thread():
    before = set(_dispatch_threads())
    store = _store(period=1, dispatcher_thread=False)
    lv = _leaves()
    red = store.init(lv)
    red = _write(store, red, (0,))
    red, _ = store.tick(lv, red, 1)
    assert _group(store).pending is not None
    assert store._dispatcher is None
    assert set(_dispatch_threads()) <= before
    red = store.settle(red, lv)


# --------------------------------------------------- Optional step threading

def test_flush_step_zero_is_a_real_step_stamp():
    """Step 0 must stamp the freshness clock (the old ``step or 0``
    coercion treated it as "unknown" and skipped the stamp)."""
    store = _store(period=100, max_vulnerable_steps=2)
    lv = _leaves()
    red = store.init(lv)
    g = _group(store)
    g.last_update_step = 5          # pretend restored history
    red = store.flush(lv, red, step=0)
    assert g.last_update_step == 0, \
        "flush(step=0) must stamp the clock at step 0"
    red = _write(store, red, (0,))
    red, rep = store.tick(lv, red, 1)
    assert not rep.deadline_fired, \
        "deadline must count from the stamped step 0 (1 - 0 < 2)"
    red, rep = store.tick(lv, red, 2)
    assert rep.deadline_fired, "2 - 0 >= 2: deadline due now"
    store._stop_dispatcher()


def test_settle_phase_stamps_step_zero_and_omits_unknown(mkstore):
    """settle(step=0) stamps its dispatcher_join phase with step 0;
    settle() without a step omits the key entirely (so replay hooks can
    fill in their own counter) — None is never coerced to 0."""
    store = mkstore(period=1, dispatcher_thread=True)
    lv = _leaves()
    red = store.init(lv)
    seen = []
    store.add_phase_hook(lambda phase, info: seen.append((phase, info)))

    red = _write(store, red, (0,))
    red, _ = store.tick(lv, red, 1)
    assert _group(store).pending is not None
    red = store.settle(red, lv, step=0)
    joins = [i for ph, i in seen if ph == "dispatcher_join"]
    assert joins and joins[-1]["step"] == 0

    seen.clear()
    red = _write(store, red, (1,))
    red, _ = store.tick(lv, red, 2)
    assert _group(store).pending is not None
    red = store.settle(red, lv)
    joins = [i for ph, i in seen if ph == "dispatcher_join"]
    assert joins and "step" not in joins[-1]


class _NeverReady:
    """Device-array stand-in whose readiness notification never arrives
    (the value is computable, only ``is_ready`` lies — the CPU-backend
    hazard when a blocking transfer runs concurrently on the resolver
    thread)."""

    def is_ready(self):
        return False

    def __array__(self, dtype=None):
        return np.zeros((), dtype=dtype or bool)


def test_pending_ready_trusts_resolver_event_not_device_notification():
    """Thread mode: once the resolver event is set the folded fit bit is
    published — a stuck ``is_ready`` on the device array must not make
    the pending look in-flight (it would starve resolution behind a
    phantom signal).  Inline mode still gates on device readiness."""
    ev = threading.Event()
    p = store_mod._Pending(red=None, fits=_NeverReady(), queued=False,
                           step=1, launched=ev, fits_host=None)
    assert not store_mod._pending_ready(p), "resolver not done yet"
    ev.set()
    p.fits_host = True
    assert store_mod._pending_ready(p), \
        "event set + published bit => ready, device notification ignored"
    inline = store_mod._Pending(red=None, fits=_NeverReady(), queued=False,
                                step=1, launched=None)
    assert not store_mod._pending_ready(inline), \
        "inline mode still trusts the device readiness probe"


def test_patrol_probe_forces_fetch_past_stuck_readiness(monkeypatch):
    """A patrol probe whose ``is_ready`` never flips must not starve the
    patroller forever (it holds the single outstanding-probe slot): after
    PROBE_FORCE_TICKS process attempts the fetch is forced and the sweep
    continues."""
    import repro.scrub.patrol as patrol_mod

    pol = RedundancyPolicy.single(
        "vilamb", period_steps=2, lanes_per_block=8, async_tick=True,
        patrol_bytes_per_tick=2 * 8 * 4, precompile=False)
    lv = _leaves()
    store = ProtectedStore(pol).attach(lv)
    red = store.init(lv)
    monkeypatch.setattr(patrol_mod, "_ready", lambda x: False)
    patrolled = 0
    for step in range(1, 4 * patrol_mod.PROBE_FORCE_TICKS + 2):
        red, rep = store.tick(lv, red, step, scrub_period=0)
        patrolled += len(rep.patrolled)
    assert patrolled >= 2, \
        "stuck readiness must force-resolve, not wedge the probe slot"
    store._stop_dispatcher()
