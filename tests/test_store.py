"""ProtectedStore facade: per-leaf mixed policies vs single-mode engines
(byte-identical redundancy state), tick scheduling, freshness deadline,
straggler back-off with recovery, deprecation shims, and the mixed-policy
train + recovery round-trip."""
import dataclasses
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import CheckpointManager
from repro.configs import get_smoke
from repro.core import (ALL, LeafPolicy, ProtectedStore, RedundancyConfig,
                        RedundancyEngine, RedundancyPolicy, StragglerGovernor,
                        bits)
from repro.core import blocks as B
from repro.data import SyntheticPipeline
from repro.models import build_model
from repro.models.config import ShapeConfig
from repro.optim import AdamW
from repro.train import (Trainer, protected_leaves, protected_structs,
                         replace_protected)

RED_FIELDS = ("checksums", "parity", "dirty", "shadow", "meta_ck")


def _mixed_store(lanes=128):
    policy = RedundancyPolicy(
        default=LeafPolicy(mode="vilamb", period_steps=4),
        rules=(("params/*", LeafPolicy(mode="sync")),),
        lanes_per_block=lanes)
    a = jax.random.normal(jax.random.PRNGKey(0), (16, 256), jnp.float32)
    b = jax.random.normal(jax.random.PRNGKey(1), (8, 256), jnp.float32)
    leaves = {"params/w": a, "opt/m": b}
    store = ProtectedStore(policy).attach(leaves)
    return store, leaves


def test_policy_resolution_and_grouping():
    store, _ = _mixed_store()
    assert store.leaf_policy("params/w").mode == "sync"
    assert store.leaf_policy("opt/m").mode == "vilamb"
    modes = sorted(g.policy.mode for g in store.groups.values())
    assert modes == ["sync", "vilamb"]
    assert store.has_sync and store.has_periodic and store.protects


def test_mixed_policy_byte_identical_to_single_mode_engines():
    """A mixed store must produce exactly the redundancy state of the two
    dedicated single-mode engines it compiles down to."""
    store, leaves = _mixed_store()
    red = store.init(leaves)

    eng_s = RedundancyEngine(
        {"params/w": jax.ShapeDtypeStruct((16, 256), jnp.float32)},
        RedundancyConfig(mode="sync", lanes_per_block=128))
    eng_v = RedundancyEngine(
        {"opt/m": jax.ShapeDtypeStruct((8, 256), jnp.float32)},
        RedundancyConfig(mode="vilamb", period_steps=4, lanes_per_block=128))
    red_s = eng_s.init({"params/w": leaves["params/w"]})
    red_v = eng_v.init({"opt/m": leaves["opt/m"]})

    for step in range(1, 9):
        new = {"params/w": leaves["params/w"] + 0.1 * step,
               "opt/m": leaves["opt/m"].at[step % 8].add(1.0)}
        mask = jnp.zeros((8,), bool).at[step % 8].set(True)
        red = store.on_write(red, events={"opt/m": mask}, old=leaves, new=new)
        red_s = eng_s.sync_update({"params/w": leaves["params/w"]},
                                  {"params/w": new["params/w"]}, red_s)
        red_v = eng_v.mark_dirty(red_v, {"opt/m": mask})
        leaves = new
        red, report = store.tick(leaves, red, step)
        if step % 4 == 0:
            red_v = eng_v.redundancy_step({"opt/m": leaves["opt/m"]}, red_v)
            assert report.updated
        else:
            assert not report.updated

    # The default tick is overlap-pipelined: adopt the in-flight update
    # before comparing bits (no new pass is scheduled by settle).
    red = store.settle(red, leaves)
    for f in RED_FIELDS:
        np.testing.assert_array_equal(
            np.asarray(getattr(red["params/w"], f)),
            np.asarray(getattr(red_s["params/w"], f)))
        np.testing.assert_array_equal(
            np.asarray(getattr(red["opt/m"], f)),
            np.asarray(getattr(red_v["opt/m"], f)))
    assert sum(int(v.sum()) for v in store.scrub(leaves, red).values()) == 0


def test_tick_fires_updates_and_scrubs_on_schedule():
    policy = RedundancyPolicy.single("vilamb", period_steps=3,
                                     scrub_period_steps=5, lanes_per_block=128)
    leaves = {"w": jax.random.normal(jax.random.PRNGKey(2), (8, 256))}
    store = ProtectedStore(policy).attach(leaves)
    red = store.init(leaves)
    fired, scrubbed = [], []
    for step in range(1, 16):
        red = store.on_write(red, events={"w": ALL})
        red, rep = store.tick(leaves, red, step)
        if rep.updated:
            fired.append(step)
        if rep.scrubbed:
            scrubbed.append(step)
            assert rep.mismatches == 0
    assert fired == [3, 6, 9, 12, 15]
    assert scrubbed == [5, 10, 15]
    assert store.corruption_alarms == 0


def test_freshness_deadline_bounds_vulnerability():
    """The paper's knob made explicit: with period 100 but a 3-step
    deadline, dirty state is never older than 3 steps."""
    policy = RedundancyPolicy.single("vilamb", period_steps=100,
                                     max_vulnerable_steps=3,
                                     lanes_per_block=128)
    leaves = {"w": jax.random.normal(jax.random.PRNGKey(3), (8, 256))}
    store = ProtectedStore(policy).attach(leaves)
    red = store.init(leaves)
    red = store.on_write(red, events={"w": ALL})
    for step in range(1, 4):
        red, rep = store.tick(leaves, red, step)
        if step < 3:
            assert not rep.updated
    assert rep.updated and rep.deadline_fired
    assert int(bits.popcount(red["w"].dirty)) == 0


def test_freshness_deadline_survives_step_counter_reset():
    """A long-lived store ticked by restarting counters (serve request
    waves) must rebase its deadline tracking, not wedge on step < last."""
    policy = RedundancyPolicy.single("vilamb", period_steps=100,
                                     max_vulnerable_steps=3,
                                     lanes_per_block=128)
    leaves = {"w": jax.random.normal(jax.random.PRNGKey(5), (8, 256))}
    store = ProtectedStore(policy).attach(leaves)
    red = store.init(leaves)
    red = store.on_write(red, events={"w": ALL})
    for step in range(1, 11):                       # wave 1
        red, _ = store.tick(leaves, red, step)
    assert next(iter(store.groups.values())).last_update_step > 3
    red = store.on_write(red, events={"w": ALL})
    fired = []
    for step in range(1, 4):                        # wave 2: counter reset
        red, rep = store.tick(leaves, red, step)
        if rep.updated:
            fired.append(step)
    assert fired == [3]
    assert int(bits.popcount(red["w"].dirty)) == 0


def test_straggler_backoff_recovers():
    g = StragglerGovernor(factor=3.0, window=8, recovery_steps=4)
    for _ in range(8):
        g.observe(0.01)
    assert g.scale == 1
    g.observe(0.5)                      # straggler: period stretches
    assert g.scale == 2
    for _ in range(4):                  # renormalized: period shrinks back
        g.observe(0.01)
    assert g.scale == 1


def test_straggler_re_stretches_after_recovery():
    """A second storm after full recovery must stretch again: the spike
    left in the rolling window must not inflate the median enough to
    mask it, and the calm counter must restart from zero."""
    g = StragglerGovernor(factor=3.0, window=8, recovery_steps=4)
    for _ in range(8):
        g.observe(0.01)
    g.observe(0.5)
    assert g.scale == 2
    for i in range(4):
        g.observe(0.01)
        assert g.scale == (1 if i == 3 else 2)   # no early half-step
    g.observe(0.5)                      # second storm, spike still in window
    assert g.scale == 2
    g.observe(0.5)                      # sustained: doubles, never resets
    assert g.scale == 4
    for _ in range(3):                  # partial calm does not recover...
        g.observe(0.01)
    assert g.scale == 4
    g.observe(0.01)                     # ...the 4th consecutive step does
    assert g.scale == 2
    for _ in range(4):
        g.observe(0.01)
    assert g.scale == 1                 # staged recovery: one halving per run


def test_tick_applies_governor_to_period():
    policy = RedundancyPolicy.single("vilamb", period_steps=2,
                                     lanes_per_block=128,
                                     straggler_window=4,
                                     straggler_recovery_steps=2)
    leaves = {"w": jax.random.normal(jax.random.PRNGKey(4), (8, 256))}
    store = ProtectedStore(policy).attach(leaves)
    red = store.init(leaves)
    for step in range(1, 5):            # warm the window with normal steps
        red, _ = store.tick(leaves, red, step, step_time=0.01)
    red, rep = store.tick(leaves, red, 5, step_time=1.0)  # straggler
    assert store._governor.scale == 2
    red, rep = store.tick(leaves, red, 6, step_time=0.01)
    assert not rep.updated              # stretched period: 6 % 4 != 0
    red, rep = store.tick(leaves, red, 8, step_time=0.01)
    assert rep.updated                  # 8 % 4 == 0; recovery then kicks in
    assert store._governor.scale == 1


def test_from_spec_parser():
    pol = RedundancyPolicy.from_spec("params/*=sync,m/*=vilamb:16",
                                     default_mode="vilamb", period_steps=8)
    assert pol.leaf_policy("params/embed").mode == "sync"
    assert pol.leaf_policy("m/embed") == LeafPolicy("vilamb", period_steps=16)
    assert pol.leaf_policy("v/embed").period_steps == 8
    with pytest.raises(ValueError):
        RedundancyPolicy.from_spec("params/sync")


def test_deprecation_shim_engine_mode():
    eng = RedundancyEngine(
        {"w": jax.ShapeDtypeStruct((8, 256), jnp.float32)},
        RedundancyConfig(mode="vilamb", period_steps=4, lanes_per_block=128))
    from repro.core.store import as_store
    with pytest.warns(DeprecationWarning):
        store = as_store(eng, "vilamb", period_steps=4, caller="test")
    assert store.engine_for("w") is eng
    assert store.policy.lanes_per_block == 128
    assert store.leaf_policy("w").period_steps == 4


def _mixed_trainer():
    cfg = get_smoke("olmo-1b")
    m = build_model(cfg)
    opt = AdamW(lr=lambda s: 1e-3)
    p0 = jax.eval_shape(m.init, jax.random.PRNGKey(0))
    o0 = jax.eval_shape(opt.init, p0)
    policy = RedundancyPolicy(
        default=LeafPolicy(mode="vilamb", period_steps=2),
        rules=(("params/*", LeafPolicy(mode="sync")),),
        lanes_per_block=512)
    store = ProtectedStore(policy).attach(protected_structs(p0, o0))
    tr = Trainer(model=m, opt=opt, store=store)
    data = SyntheticPipeline(cfg, ShapeConfig("t", 32, 4, "train"), seed=0)
    return tr, store, data


def test_mixed_policy_train_and_recovery_roundtrip(tmp_path):
    """Acceptance: params=sync + opt=vilamb trains, detects + repairs SDC in
    both groups, and survives a verified checkpoint round-trip."""
    tr, store, data = _mixed_trainer()
    st = tr.init_state(jax.random.PRNGKey(0))
    losses = []
    st = tr.run(st, data, 5, on_step=lambda s, m: losses.append(float(m["loss"])))
    assert losses[-1] < losses[0]
    st = tr.flush(st)
    leaves = protected_leaves(st.params, st.opt)
    assert sum(int(v.sum()) for v in store.scrub(leaves, st.red).values()) == 0

    # corrupt one sync-protected (params) and one vilamb-protected (moment)
    for name in ("params/embed", "m/embed"):
        meta = store.metas[name]
        lanes = B.to_lanes(leaves[name], meta)
        leaves[name] = B.from_lanes(lanes.at[1, 2].add(0xBAD), meta)
    mm = store.scrub(leaves, st.red)
    assert sum(int(v.sum()) for v in mm.values()) == 2
    repaired, fixed, lost = store.repair(leaves, st.red, mm)
    assert (fixed, lost) == (2, 0)
    assert sum(int(v.sum()) for v in store.scrub(repaired, st.red).values()) == 0
    st = replace_protected(st, repaired)

    # checkpoint round-trip through the store-verified restore path
    mgr = CheckpointManager(tmp_path)
    mgr.save(int(st.step), st, blocking=True)
    st2 = mgr.restore_verified(jax.eval_shape(lambda: st), store)
    assert st2 is not None
    for a, b in zip(jax.tree.leaves(st.params), jax.tree.leaves(st2.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    # and training continues from the restored state
    losses2 = []
    st2 = tr.run(st2, data, 2, on_step=lambda s, m: losses2.append(float(m["loss"])))
    assert all(np.isfinite(l) for l in losses2)


def test_restore_verified_repairs_on_disk_corruption(tmp_path):
    """A checkpoint whose payload was silently corrupted (checksum updated to
    hide it from the file-level verify) is caught by the store scrub and
    parity-repaired on restore."""
    tr, store, data = _mixed_trainer()
    st = tr.init_state(jax.random.PRNGKey(0))
    st = tr.run(st, data, 2)
    st = tr.flush(st)
    mgr = CheckpointManager(tmp_path)
    mgr.save(int(st.step), st, blocking=True)

    # tamper with one protected block on disk, fixing up the file checksum so
    # only the redundancy layer can notice
    import json
    import pathlib
    d = pathlib.Path(tmp_path) / f"step_{int(st.step)}"
    manifest = json.loads((d / "manifest.json").read_text())
    z = dict(np.load(d / "state.npz"))
    key = next(k for k, m_ in manifest["leaves"].items()
               if k == "params/embed")
    fk = manifest["leaves"][key]["file_key"]
    arr = z[fk].copy()
    arr.flat[0] += 1.0
    z[fk] = arr
    from repro.ckpt.checkpoint import _np_checksum
    manifest["leaves"][key]["checksum"] = _np_checksum(arr)
    np.savez(d / "state.npz", **z)
    (d / "manifest.json").write_text(json.dumps(manifest))

    st2 = mgr.restore_verified(jax.eval_shape(lambda: st), store)
    assert st2 is not None
    np.testing.assert_array_equal(
        np.asarray(st2.params["embed"]), np.asarray(st.params["embed"]))
