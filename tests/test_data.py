"""Data pipeline: determinism (restart-reproducible), zipf skew, shapes."""
import numpy as np

from repro.configs import get_smoke
from repro.data import SyntheticPipeline
from repro.data.pipeline import batch_structs
from repro.models.config import SHAPES, ShapeConfig


def test_deterministic_per_step():
    cfg = get_smoke("llama3.2-3b")
    p1 = SyntheticPipeline(cfg, ShapeConfig("t", 64, 4, "train"), seed=5)
    p2 = SyntheticPipeline(cfg, ShapeConfig("t", 64, 4, "train"), seed=5)
    for step in (0, 3, 17):
        b1, b2 = p1.get(step), p2.get(step)
        np.testing.assert_array_equal(np.asarray(b1["tokens"]), np.asarray(b2["tokens"]))
    b_other = p1.get(1)
    assert not np.array_equal(np.asarray(b_other["tokens"]), np.asarray(p1.get(2)["tokens"]))


def test_labels_are_next_token():
    cfg = get_smoke("olmo-1b")
    p = SyntheticPipeline(cfg, ShapeConfig("t", 64, 2, "train"))
    b = p.get(0)
    np.testing.assert_array_equal(np.asarray(b["tokens"][:, 1:]),
                                  np.asarray(b["labels"][:, :-1]))


def test_zipf_skew():
    cfg = get_smoke("olmo-1b")
    p = SyntheticPipeline(cfg, ShapeConfig("t", 512, 8, "train"))
    toks = np.asarray(p.get(0)["tokens"]).ravel()
    counts = np.bincount(toks, minlength=cfg.vocab_size)
    top = np.sort(counts)[::-1]
    # hot keys dominate (YCSB-like), cold tail exists
    assert top[:10].sum() > 0.3 * counts.sum()
    assert (counts == 0).sum() > 0


def test_batch_structs_cover_families():
    for arch in ("internvl2-1b", "seamless-m4t-medium", "glm4-9b"):
        cfg = get_smoke(arch)
        st = batch_structs(cfg, SHAPES["train_4k"])
        assert "tokens" in st and "labels" in st
        if cfg.frontend == "vision":
            assert "frontend" in st
        if cfg.enc_dec:
            assert "enc_input" in st
        total = st["tokens"].shape[1]
        if cfg.frontend == "vision":
            total += cfg.frontend_len
        if cfg.enc_dec:
            total += st["enc_input"].shape[1]
        assert total == SHAPES["train_4k"].seq_len
