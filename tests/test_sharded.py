"""Multi-device behaviour (subprocess: needs XLA_FLAGS before jax import).

Covers: machine-local redundancy (zero collectives), sharded Algorithm 1,
dry-run machinery on a small production-shaped mesh, gradient compression.
"""
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_py(code: str, devices: int = 8, timeout: int = 900):
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}",
               PYTHONPATH=SRC)
    return subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                          env=env, capture_output=True, text=True, timeout=timeout)


def test_redundancy_is_machine_local():
    r = run_py("""
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.core import RedundancyConfig, RedundancyEngine
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((2,2,2), ("pod","data","model"))
        leaves = {"w": jax.random.normal(jax.random.PRNGKey(0), (8, 512), jnp.float32)}
        specs = {"w": P(("data","model"), None)}
        eng = RedundancyEngine({k: jax.ShapeDtypeStruct(v.shape, v.dtype) for k,v in leaves.items()},
                               RedundancyConfig(lanes_per_block=128), mesh=mesh, specs=specs)
        leaves = {k: jax.device_put(v, NamedSharding(mesh, specs[k])) for k,v in leaves.items()}
        red = eng.init(leaves)
        txt = jax.jit(eng.redundancy_step).lower(leaves, red).compile().as_text()
        bad = [op for op in ("all-reduce","all-gather","all-to-all","reduce-scatter") if op in txt]
        assert not bad, bad
        mm = eng.scrub(leaves, red)
        assert all(int(v.sum())==0 for v in mm.values())
        print("LOCAL_OK")
    """)
    assert "LOCAL_OK" in r.stdout, r.stdout + r.stderr


def test_tiny_mesh_dryrun_all_kinds():
    r = run_py("""
        import jax
        from repro.configs import get_smoke
        from repro.launch.mesh import make_mesh
        from repro.launch.specs import (build_train_setup, build_decode_setup,
                                        build_prefill_setup)
        from repro.models.config import ShapeConfig
        mesh = make_mesh((2,2,2), ("pod","data","model"))
        cfg = get_smoke("jamba-1.5-large-398b")
        with mesh:
            s = build_train_setup(cfg, ShapeConfig("t", 64, 8, "train"), mesh)
            jax.jit(s.step_fn, in_shardings=(s.state_sharding, s.batch_sharding),
                    out_shardings=(s.state_sharding, None), donate_argnums=(0,)
                    ).lower(s.state_struct, s.batch_struct).compile()
            d = build_decode_setup(cfg, ShapeConfig("d", 64, 8, "decode"), mesh)
            jax.jit(d.step_fn, in_shardings=d.args_sharding, donate_argnums=(1,2)
                    ).lower(*d.args_struct).compile()
            p = build_prefill_setup(cfg, ShapeConfig("p", 64, 4, "prefill"), mesh)
            jax.jit(p.step_fn, in_shardings=p.args_sharding,
                    out_shardings=p.out_sharding).lower(*p.args_struct).compile()
        print("DRYRUN_OK")
    """)
    assert "DRYRUN_OK" in r.stdout, r.stdout[-2000:] + r.stderr[-4000:]


def test_sharded_training_matches_single_device():
    r = run_py("""
        import jax, numpy as np
        from repro.configs import get_smoke
        from repro.launch.mesh import make_mesh
        from repro.launch.specs import build_train_setup
        from repro.models.config import ShapeConfig
        from repro.data import SyntheticPipeline
        import dataclasses
        cfg = dataclasses.replace(get_smoke("olmo-1b"), param_dtype="float32")
        shape = ShapeConfig("t", 32, 8, "train")
        # single device reference
        s1 = build_train_setup(cfg, shape, None, mode="none")
        params = s1.model.init(jax.random.PRNGKey(0))
        from repro.optim import AdamW, warmup_cosine
        opt = AdamW(lr=warmup_cosine(3e-4, 100, 10000), moment_dtype=cfg.moment_dtype)
        from repro.train.state import TrainState
        st = TrainState.create(params, opt.init(params))
        data = SyntheticPipeline(cfg, shape, seed=0)
        st1, m1 = jax.jit(s1.step_fn)(st, data.get(0))
        # sharded
        mesh = make_mesh((2,2,2), ("pod","data","model"))
        with mesh:
            s8 = build_train_setup(cfg, shape, mesh, mode="none", accum_steps=1)
            fn = jax.jit(s8.step_fn, in_shardings=(s8.state_sharding, s8.batch_sharding),
                         out_shardings=(s8.state_sharding, None))
            data8 = SyntheticPipeline(cfg, shape, seed=0, mesh=mesh)
            st8, m8 = fn(st, data8.get(0))
        l1, l8 = float(m1["loss"]), float(m8["loss"])
        assert abs(l1 - l8) < 5e-4, (l1, l8)
        a = np.asarray(jax.tree.leaves(st1.params)[0])
        b = np.asarray(jax.tree.leaves(st8.params)[0])
        np.testing.assert_allclose(a, b, rtol=2e-3, atol=1e-5)
        print("MATCH_OK", l1, l8)
    """)
    assert "MATCH_OK" in r.stdout, r.stdout[-2000:] + r.stderr[-4000:]
