"""Multi-device behaviour (subprocess: needs XLA_FLAGS before jax import).

Covers: machine-local redundancy (zero collectives — including the queued
and overlap-pipelined Algorithm-1 programs), the sharded work-queue /
async-tick matrix (bitwise identity vs the blocking full recompute on a
2x2x2 host mesh), the sync-free sharded hot path, dry-run machinery on a
small production-shaped mesh, and gradient compression.  Subprocess
plumbing and the shared sharded-store fixture live in tests/subproc.py.
"""
import pytest

from subproc import MESH_PRELUDE, run_snippet


def test_redundancy_is_machine_local():
    run_snippet("""
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.core import RedundancyConfig, RedundancyEngine
        from repro.launch.hlo_analysis import assert_no_collectives
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((2,2,2), ("pod","data","model"))
        leaves = {"w": jax.random.normal(jax.random.PRNGKey(0), (8, 512), jnp.float32)}
        specs = {"w": P(("data","model"), None)}
        eng = RedundancyEngine({k: jax.ShapeDtypeStruct(v.shape, v.dtype) for k,v in leaves.items()},
                               RedundancyConfig(lanes_per_block=128), mesh=mesh, specs=specs)
        leaves = {k: jax.device_put(v, NamedSharding(mesh, specs[k])) for k,v in leaves.items()}
        red = eng.init(leaves)
        assert_no_collectives(jax.jit(eng.redundancy_step).lower(leaves, red), "full")
        mm = eng.scrub(leaves, red)
        assert all(int(v.sum())==0 for v in mm.values())
        print("LOCAL_OK")
    """, "LOCAL_OK")


def test_sharded_queued_and_async_programs_are_collective_free():
    """Acceptance: the per-shard work-queue and overlap Algorithm-1
    programs — including the batched multi-group program — lower with
    zero collectives on a 2x2x2 mesh; the stacked fit vector keeps one
    bool per device per group and is AND-folded on the host, never in a
    device program."""
    run_snippet("""
        from repro.core import workqueue
        from repro.launch.hlo_analysis import assert_no_collectives
        store = mesh_store(async_tick=True, precompile=False)
        g = next(iter(store.groups.values()))
        eng = g.engine
        assert eng.has_queue and eng.queue_capacity("w") == 16 \
            and eng.queue_capacity("e") == 0, \
            (eng.has_queue, eng.queue_capacity("w"), eng.queue_capacity("e"))
        lv = put(make_leaves())
        red = store.init(lv)
        for variant in ("queued", "full", "async_queued", "async_full"):
            lowered = store._build_update(g.label, variant).lower(lv, red)
            assert_no_collectives(lowered, variant)
        for variant in ("async_queued", "async_full"):
            lowered = store._build_update_many(
                (g.label,), (variant,)).lower((lv,), (red,))
            assert_no_collectives(lowered, "many_" + variant)
        outs, stacked = store._update_many_fn(
            (g.label,), ("async_queued",))((lv,), (red,))
        # one row per group, one flag column per device
        assert stacked.shape == (1, 8), stacked.shape
        assert workqueue.fold_fits_host(np.asarray(stacked)[0])
        print("PROGRAMS_OK")
    """, "PROGRAMS_OK", prelude=MESH_PRELUDE)


@pytest.mark.parametrize("async_tick", ["0", "1"])
def test_sharded_queued_matrix_bitwise_vs_blocking_full(async_tick):
    """Queued-path x REPRO_ASYNC_TICK matrix: on a 2x2x2 host mesh the
    work-queue dispatch (blocking exact fit or speculative overlap per the
    env lever) must be bitwise-identical to the blocking full recompute,
    actually dispatch the queued program, and end scrub-clean."""
    run_snippet("""
        # env lever decides the tick mode (policy does not pin async_tick)
        store = mesh_store()
        used = []
        orig = store._update_fn
        store._update_fn = lambda label, variant: (used.append(variant),
                                                   orig(label, variant))[1]
        orig_many = store._update_many_fn
        store._update_many_fn = lambda labels, variants: (
            used.extend(variants), orig_many(labels, variants))[1]
        lv, red = drive(store, steps=8, seed=5)
        red = store.settle(red, lv)
        assert any("queued" in v for v in used), used
        import os
        if os.environ["REPRO_ASYNC_TICK"] == "1":
            assert any(v.startswith("async") for v in used), used
        else:
            assert not any(v.startswith("async") for v in used), used
        ref = mesh_store(frac=0.0, async_tick=False)    # blocking full recompute
        lv_ref, red_ref = drive(ref, steps=8, seed=5)
        assert_red_equal(red, red_ref)
        assert sum(int(v.sum()) for v in store.scrub(lv, red).values()) == 0
        assert all(bool(v) for v in store.verify_meta(red).values())
        print("MATRIX_OK")
    """, "MATRIX_OK", env={"REPRO_ASYNC_TICK": async_tick},
        prelude=MESH_PRELUDE)


def test_sharded_async_hot_path_never_pays_queue_fits_round_trip():
    """Acceptance: a due tick on the sharded overlap path must never call
    the host-side queue_fits round trip — the fit signal is the per-shard
    flag array folded on device and fetched one tick ahead."""
    run_snippet("""
        store = mesh_store(async_tick=True, period=1)
        def boom(*a, **k):
            raise AssertionError("queue_fits called on the sharded async hot path")
        for g in store._protected():
            g.engine.queue_fits = boom
        lv, red = drive(store, steps=6, seed=2)
        g = next(iter(store.groups.values()))
        assert g.pending is None or g.pending.fits.shape == (1, 8), \
            "pending fit signal must be the batched per-shard row"
        for g in store._protected():
            del g.engine.queue_fits          # settle may use the exact check
        red = store.settle(red, lv)
        assert sum(int(v.sum()) for v in store.scrub(lv, red).values()) == 0
        print("HOTPATH_OK")
    """, "HOTPATH_OK", prelude=MESH_PRELUDE)


def test_sharded_overflow_on_one_shard_is_bitwise_safe():
    """A speculative queued dispatch that overflows a single shard's local
    queue must keep that shard's snapshot marked and settle to the exact
    blocking-path bits via the full fallback."""
    run_snippet("""
        outs = []
        for async_on in (True, False):
            store = mesh_store(async_tick=async_on, period=1)
            lv = put(make_leaves())
            red = store.init(lv)
            g = next(iter(store.groups.values()))
            if async_on:
                g.predicted_fits = True       # force the misprediction
            # overflow ONLY shard 0 of "w" (it owns rows 0..7)
            ev = jnp.zeros((64,), bool).at[jnp.arange(8)].set(True)
            lv = dict(lv, w=lv["w"].at[jnp.arange(8)].add(1.0))
            red = store.on_write(red, events={"w": ev})
            red, rep = store.tick(lv, red, 1)
            if async_on:
                p = g.pending
                assert p is not None and p.queued
                store.sync_inflight()
                red, rep = store.tick(lv, red, 2)
                assert rep.overflowed and g.predicted_fits is False
            red = store.settle(red, lv)
            outs.append(red)
            assert sum(int(v.sum()) for v in store.scrub(lv, red).values()) == 0
        assert_red_equal(outs[0], outs[1])
        print("OVERFLOW_OK")
    """, "OVERFLOW_OK", prelude=MESH_PRELUDE)


def test_sharded_multigroup_tick_batches_one_launch_one_fetch():
    """Tentpole acceptance: with TWO due vilamb groups on 8 devices, a due
    tick dispatches exactly ONE batched update program covering both
    groups and fetches ONE stacked fits vector shared by both pendings;
    the per-group update programs never launch on the async tick path."""
    run_snippet("""
        from repro.core import LeafPolicy, ProtectedStore, RedundancyPolicy
        pol = RedundancyPolicy(
            default=LeafPolicy(mode="vilamb", period_steps=2,
                               work_queue_frac=0.5),
            rules=(("e", LeafPolicy(mode="vilamb", period_steps=2,
                                    work_queue_frac=0.0)),),
            lanes_per_block=128, async_tick=True, precompile=False)
        store = ProtectedStore(pol, mesh=MESH).attach(make_leaves(),
                                                      specs=SPECS)
        groups = list(store._protected())
        assert len(groups) == 2, [g.label for g in groups]
        many_calls, single_calls = [], []
        orig_many = store._update_many_fn
        store._update_many_fn = lambda labels, variants: (
            many_calls.append((labels, variants)),
            orig_many(labels, variants))[1]
        orig = store._update_fn
        store._update_fn = lambda label, variant: (
            single_calls.append((label, variant)),
            orig(label, variant))[1]
        lv = put(make_leaves())
        red = store.init(lv)
        for step in (1, 2, 3, 4):
            evw = jnp.zeros((64,), bool).at[step].set(True)
            eve = jnp.zeros((16,), bool).at[step].set(True)
            red = store.on_write(red, events={"w": evw, "e": eve})
            store.sync_inflight()
            n_before = len(many_calls)
            red, _ = store.tick(lv, red, step)
            if step % 2 == 0:                  # both groups due
                assert len(many_calls) == n_before + 1, many_calls
                labels, variants = many_calls[-1]
                assert sorted(labels) == sorted(g.label for g in groups)
                pendings = [g.pending for g in groups]
                assert all(p is not None for p in pendings)
                # ONE stacked fits vector + ONE resolver event per batch
                assert pendings[0].fits is pendings[1].fits
                assert pendings[0].launched is pendings[1].launched
                assert pendings[0].fits.shape == (2, 8), pendings[0].fits.shape
            else:
                assert len(many_calls) == n_before
        assert not single_calls, single_calls
        red = store.settle(red, lv)
        assert sum(int(v.sum()) for v in store.scrub(lv, red).values()) == 0
        print("MULTIGROUP_OK")
    """, "MULTIGROUP_OK", prelude=MESH_PRELUDE)


def test_sharded_dispatcher_thread_lifecycle():
    """Satellite acceptance: the resolver thread exists only between the
    first overlapped dispatch and the next flush/remesh handover — flush
    joins it cleanly, and a remesh adoption never leaks it."""
    run_snippet("""
        import threading
        from repro.launch.mesh import make_mesh

        def dispatch_threads():
            return [t for t in threading.enumerate()
                    if t.name == "repro-dispatch" and t.is_alive()]

        store = mesh_store(async_tick=True, period=1, precompile=False,
                           remesh_bytes_per_tick=64 * 128 * 4)
        assert store._dispatcher is None and not dispatch_threads()
        lv, red = drive(store, steps=3, seed=7)
        assert store._dispatcher is not None \
            and store._dispatcher.thread.is_alive(), \
            "overlapped dispatch must have spun up the resolver thread"
        red = store.flush(lv, red, step=3)
        assert store._dispatcher is None and not dispatch_threads(), \
            "flush must join the resolver thread"
        # Next overlapped dispatch re-creates it lazily...
        step = 3
        for step in (4, 5):
            ev = jnp.zeros((64,), bool).at[step].set(True)
            red = store.on_write(red, events={"w": ev})
            red, _ = store.tick(lv, red, step)
        assert store._dispatcher is not None
        # ...and a remesh handover shuts it down before migrating, without
        # leaking a thread across the geometry swap.
        store.remesh(make_mesh((2, 2, 1), ("pod", "data", "model")),
                     {"w": SPECS["w"], "e": SPECS["e"]})
        while store.remeshing:
            step += 1
            ev = jnp.zeros((64,), bool).at[step % 64].set(True)
            red = store.on_write(red, events={"w": ev})
            red, rep = store.tick(lv, red, step)
            if rep.repaired:
                lv = dict(lv, **rep.repaired)
            assert step < 80, "remesh never finished"
        assert len(dispatch_threads()) <= 1, \
            "remesh must not leak resolver threads"
        red = store.flush(lv, red, step=step)
        assert store._dispatcher is None and not dispatch_threads()
        assert sum(int(v.sum()) for v in store.scrub(lv, red).values()) == 0
        print("LIFECYCLE_OK")
    """, "LIFECYCLE_OK", prelude=MESH_PRELUDE)


def test_tiny_mesh_dryrun_all_kinds():
    run_snippet("""
        import jax
        from repro.configs import get_smoke
        from repro.launch.mesh import make_mesh
        from repro.launch.specs import (build_train_setup, build_decode_setup,
                                        build_prefill_setup)
        from repro.models.config import ShapeConfig
        mesh = make_mesh((2,2,2), ("pod","data","model"))
        cfg = get_smoke("jamba-1.5-large-398b")
        with mesh:
            s = build_train_setup(cfg, ShapeConfig("t", 64, 8, "train"), mesh)
            jax.jit(s.step_fn, in_shardings=(s.state_sharding, s.batch_sharding),
                    out_shardings=(s.state_sharding, None), donate_argnums=(0,)
                    ).lower(s.state_struct, s.batch_struct).compile()
            d = build_decode_setup(cfg, ShapeConfig("d", 64, 8, "decode"), mesh)
            jax.jit(d.step_fn, in_shardings=d.args_sharding, donate_argnums=(1,2)
                    ).lower(*d.args_struct).compile()
            p = build_prefill_setup(cfg, ShapeConfig("p", 64, 4, "prefill"), mesh)
            jax.jit(p.step_fn, in_shardings=p.args_sharding,
                    out_shardings=p.out_sharding).lower(*p.args_struct).compile()
        print("DRYRUN_OK")
    """, "DRYRUN_OK")


def test_sharded_training_matches_single_device():
    run_snippet("""
        import jax, numpy as np
        from repro.configs import get_smoke
        from repro.launch.mesh import make_mesh
        from repro.launch.specs import build_train_setup
        from repro.models.config import ShapeConfig
        from repro.data import SyntheticPipeline
        import dataclasses
        cfg = dataclasses.replace(get_smoke("olmo-1b"), param_dtype="float32")
        shape = ShapeConfig("t", 32, 8, "train")
        # single device reference
        s1 = build_train_setup(cfg, shape, None, mode="none")
        params = s1.model.init(jax.random.PRNGKey(0))
        from repro.optim import AdamW, warmup_cosine
        opt = AdamW(lr=warmup_cosine(3e-4, 100, 10000), moment_dtype=cfg.moment_dtype)
        from repro.train.state import TrainState
        st = TrainState.create(params, opt.init(params))
        data = SyntheticPipeline(cfg, shape, seed=0)
        st1, m1 = jax.jit(s1.step_fn)(st, data.get(0))
        # sharded
        mesh = make_mesh((2,2,2), ("pod","data","model"))
        with mesh:
            s8 = build_train_setup(cfg, shape, mesh, mode="none", accum_steps=1)
            fn = jax.jit(s8.step_fn, in_shardings=(s8.state_sharding, s8.batch_sharding),
                         out_shardings=(s8.state_sharding, None))
            data8 = SyntheticPipeline(cfg, shape, seed=0, mesh=mesh)
            st8, m8 = fn(st, data8.get(0))
        l1, l8 = float(m1["loss"]), float(m8["loss"])
        assert abs(l1 - l8) < 5e-4, (l1, l8)
        a = np.asarray(jax.tree.leaves(st1.params)[0])
        b = np.asarray(jax.tree.leaves(st8.params)[0])
        np.testing.assert_allclose(a, b, rtol=2e-3, atol=1e-5)
        print("MATCH_OK", l1, l8)
    """, "MATCH_OK")
