"""Checksum properties — the CRC-32C replacement must detect what the paper
needs detected (single-lane corruption, lane/block swaps) and support
Pangolin-style incremental diffs."""
import jax
import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container lacks hypothesis: deterministic shim
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core import checksum as C


def _lanes(seed, nb=6, L=64):
    return jax.random.randint(jax.random.PRNGKey(seed), (nb, L), 0, 2**31 - 1, jnp.uint32)


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 1000), st.integers(0, 5), st.integers(0, 63), st.integers(1, 2**32 - 1))
def test_single_lane_change_detected(seed, b, l, delta):
    lanes = _lanes(seed)
    c0 = C.block_checksums(lanes)
    lanes2 = lanes.at[b, l].set(lanes[b, l] ^ jnp.uint32(delta))
    c1 = C.block_checksums(lanes2)
    assert c0[b] != c1[b]
    mask = np.ones(6, bool); mask[b] = False
    np.testing.assert_array_equal(np.asarray(c0)[mask], np.asarray(c1)[mask])


def test_lane_swap_detected():
    lanes = _lanes(1)
    a, b = int(lanes[2, 3]), int(lanes[2, 40])
    if a == b:
        return
    swapped = lanes.at[2, 3].set(b).at[2, 40].set(a)
    assert C.block_checksums(lanes)[2] != C.block_checksums(swapped)[2]


def test_block_position_salting():
    """Identical content in different block slots yields different checksums
    (misdirected-write detection, paper §2.2)."""
    row = jax.random.randint(jax.random.PRNGKey(3), (1, 64), 0, 2**31 - 1, jnp.uint32)
    lanes = jnp.concatenate([row, row], axis=0)
    c = C.block_checksums(lanes)
    assert c[0] != c[1]


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 1000), st.integers(0, 1000))
def test_incremental_diff_equals_recompute(seed1, seed2):
    old = _lanes(seed1)
    new = _lanes(seed2)
    c_old = C.block_checksums(old)
    c_new = C.block_checksums(new)
    delta = C.checksum_diff(old, new)
    np.testing.assert_array_equal(np.asarray(c_old ^ delta), np.asarray(c_new))


def test_meta_checksum_detects_checksum_corruption():
    c = C.block_checksums(_lanes(7))
    m0 = C.meta_checksum(c)
    c2 = c.at[1].set(c[1] ^ jnp.uint32(1))
    assert m0 != C.meta_checksum(c2)
