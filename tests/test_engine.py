"""RedundancyEngine: Algorithm-1 invariants, scrub, recovery, sync mode."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container lacks hypothesis: deterministic shim
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core import ALL, RedundancyConfig, RedundancyEngine
from repro.core import bits, blocks as B

CFG = RedundancyConfig(lanes_per_block=128, stripe_data_blocks=4)


def _mk(seed=0, use_kernels=False):
    leaves = {
        "w": jax.random.normal(jax.random.PRNGKey(seed), (24, 200), jnp.float32),
        "e": jax.random.normal(jax.random.PRNGKey(seed + 1), (16, 64), jnp.bfloat16),
    }
    cfg = dataclasses.replace(CFG, use_kernels=use_kernels)
    eng = RedundancyEngine(
        {k: jax.ShapeDtypeStruct(v.shape, v.dtype) for k, v in leaves.items()}, cfg)
    return eng, leaves


@pytest.mark.parametrize("use_kernels", [False, True])
def test_algorithm1_invariant(use_kernels):
    """After redundancy_step, every clean block verifies and bitvectors are
    empty (paper Alg. 1 postcondition)."""
    eng, leaves = _mk(use_kernels=use_kernels)
    red = eng.init(leaves)
    assert all(int(v.sum()) == 0 for v in eng.scrub(leaves, red).values())
    leaves2 = dict(leaves, w=leaves["w"].at[5, 7].add(1.0))
    red = eng.mark_dirty(red, {"w": ALL})
    # dirty blocks are never flagged by scrub (no spurious alarms)
    assert all(int(v.sum()) == 0 for v in eng.scrub(leaves2, red).values())
    red = eng.redundancy_step(leaves2, red)
    assert all(int(v.sum()) == 0 for v in eng.scrub(leaves2, red).values())
    for r in red.values():
        assert int(bits.popcount(r.dirty)) == 0
        assert int(bits.popcount(r.shadow)) == 0
    assert all(bool(v) for v in eng.verify_meta(red).values())


def test_sparse_row_marking_limits_dirty_blocks():
    eng, leaves = _mk()
    red = eng.init(leaves)
    ev = jnp.zeros((16,), bool).at[3].set(True)  # one row of e
    red = eng.mark_dirty(red, {"e": ev})
    stats = eng.dirty_stats(red)
    assert int(stats["e"]["dirty_blocks"]) == 1
    assert int(stats["w"]["dirty_blocks"]) == 0


def test_sync_equals_async_checksums():
    """Pangolin-mode diffs land on the same redundancy as Algorithm 1."""
    eng, leaves = _mk()
    red0 = eng.init(leaves)
    leaves2 = {k: v + 1 for k, v in leaves.items()}
    red_sync = eng.sync_update(leaves, leaves2, red0)
    red_async = eng.redundancy_step(leaves2, eng.mark_dirty(red0, {"w": ALL, "e": ALL}))
    for k in leaves:
        np.testing.assert_array_equal(np.asarray(red_sync[k].checksums),
                                      np.asarray(red_async[k].checksums))
        np.testing.assert_array_equal(np.asarray(red_sync[k].parity),
                                      np.asarray(red_async[k].parity))


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 23), st.integers(0, 40))
def test_detect_and_recover_property(bad_block, lane):
    eng, leaves = _mk(seed=3)
    red = eng.init(leaves)
    meta = eng.metas["w"]
    lanes = B.to_lanes(leaves["w"], meta)
    lane = lane % meta.lanes_per_block
    corrupted = B.from_lanes(lanes.at[bad_block, lane].add(7777), meta)
    mm = eng.scrub(dict(leaves, w=corrupted), red)
    flagged = np.nonzero(np.asarray(mm["w"]))[0]
    assert flagged.tolist() == [bad_block]
    fixed, ok = eng.recover_block(corrupted, red["w"], "w", bad_block)
    assert bool(ok)
    np.testing.assert_array_equal(np.asarray(fixed), np.asarray(leaves["w"]))


def test_vulnerable_stripe_not_recoverable():
    eng, leaves = _mk(seed=4)
    red = eng.init(leaves)
    # dirty a sibling block in the same stripe -> vulnerable (paper §3.3)
    sibling = jnp.zeros((24 * 200,))  # mark via row mask on row covering block 1
    red = eng.mark_dirty(red, {"w": jnp.zeros((24,), bool).at[2].set(True)})
    meta = eng.metas["w"]
    lanes = B.to_lanes(leaves["w"], meta)
    corrupted = B.from_lanes(lanes.at[0, 0].add(1), meta)
    _, ok = eng.recover_block(corrupted, red["w"], "w", 0)
    assert not bool(ok)


def test_mttdl_stats_monotone_in_dirty_fraction():
    eng, leaves = _mk(seed=5)
    red = eng.init(leaves)
    s0 = eng.dirty_stats(red)
    red1 = eng.mark_dirty(red, {"w": jnp.zeros((24,), bool).at[0].set(True)})
    s1 = eng.dirty_stats(red1)
    red2 = eng.mark_dirty(red1, {"w": ALL})
    s2 = eng.dirty_stats(red2)
    assert (int(s0["w"]["vulnerable_stripes"]) <= int(s1["w"]["vulnerable_stripes"])
            <= int(s2["w"]["vulnerable_stripes"]))
