"""Perf knobs must be semantics-preserving: identical losses/outputs.

Every §Perf lever (sharding hints, custom VJPs, grad-cast boundaries,
accumulation) is observational on single-device math — these tests pin that
contract so hillclimbing can never silently change training.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from conftest import tiny_batch
from repro.configs import get_smoke
from repro.models import build_model


def _loss(cfg, batch, params=None):
    m = build_model(cfg)
    p = params if params is not None else m.init(jax.random.PRNGKey(0))
    (loss, aux), grads = jax.jit(
        jax.value_and_grad(m.loss, has_aux=True))(p, batch)
    return p, float(loss), grads


def test_knobs_preserve_loss_and_grads():
    base = dataclasses.replace(get_smoke("glm4-9b"), param_dtype="float32")
    batch = tiny_batch(base, B=2, S=32)
    p0, l0, g0 = _loss(base, batch)
    variants = {
        "kv_first_off": dataclasses.replace(base, attn_kv_gather_first=False),
        "kv_first_on": dataclasses.replace(base, attn_kv_gather_first=True),
        "grad_cast": dataclasses.replace(base, bf16_grad_boundaries=True),
        "custom_norm": dataclasses.replace(base, norm_vjp="custom"),
        "no_sp": dataclasses.replace(base, seq_parallel=False),
        "tile_512": dataclasses.replace(base, attn_tile=32),
    }
    for name, cfg in variants.items():
        _, l1, g1 = _loss(cfg, batch, params=p0)
        assert abs(l1 - l0) < 1e-5, (name, l0, l1)
        for a, b in zip(jax.tree.leaves(g0), jax.tree.leaves(g1)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=5e-4, atol=1e-6, err_msg=name)


def test_fast_path_block_marking_matches_general():
    """Row==block fast path in mark_dirty must agree with the general path."""
    from repro.core import RedundancyConfig, RedundancyEngine, bits
    # rows exactly one block each (1024 f32 = 1024 lanes = lanes_per_block)
    leaves = {"h": jnp.zeros((64, 1024), jnp.float32)}
    eng = RedundancyEngine(
        {k: jax.ShapeDtypeStruct(v.shape, v.dtype) for k, v in leaves.items()},
        RedundancyConfig(lanes_per_block=1024))
    assert eng.metas["h"].n_blocks == 64  # fast-path precondition
    red = eng.init(leaves)
    ev = jnp.zeros((64,), bool).at[jnp.array([3, 17, 40])].set(True)
    red2 = eng.mark_dirty(red, {"h": ev})
    got = np.asarray(bits.unpack(red2["h"].dirty, 64))
    np.testing.assert_array_equal(got, np.asarray(ev))
