"""Scrub patroller + online shard rebuild (repro.scrub).

Machine-local: byte-budget pacing, full-sweep coverage bound, mid-traffic
bitflip detection with bitwise parity repair, structured unrecoverable
reporting, and the measured >= 10x detection-latency win over a scheduled
scrub (deterministic: step_seconds=1, settled store — the MTTDL ratio
reduces to the latency ratio).

Multi-device (subprocess, 8 forced host devices): the steady-state patrol
programs (verify window, write sample) lower with zero collectives on a
2x2x2 mesh, and a wholesale shard loss rebuilds bitwise from cross-shard
parity while the foreground keeps writing into the lost shard.  The
rebuild's reconstruction/paste programs are *deliberately* cross-shard
(data must move between shards — same category as the tiny fold programs),
so they are exempt from the collective-free rule.
"""
import math

import numpy as np
import jax
import jax.numpy as jnp

from subproc import run_snippet

from repro.core import (ProtectedStore, RedundancyPolicy, UnrecoverableBlock,
                        plan_stripe_repairs)
from repro.faults.inject import FaultSpec

LANES = 128
BPB = LANES * 4                    # bytes per block at 128 uint32 lanes


def make_store(n_rows=32, cols=512, patrol_blocks=8, **kw):
    leaves = {"w": jax.random.normal(jax.random.PRNGKey(0),
                                     (n_rows, cols), jnp.float32)}
    pol = RedundancyPolicy.single(
        "vilamb", period_steps=2, lanes_per_block=LANES,
        patrol_bytes_per_tick=patrol_blocks * BPB, precompile=False, **kw)
    store = ProtectedStore(pol).attach(leaves)
    return store, leaves, store.init(leaves)


def wait_probe(store):
    """Determinism under machine load: the next probe only dispatches
    once the previous one's flags have landed, so a loaded host would
    otherwise see fewer probes per N ticks (flaky pacing/sweep counts).
    Same idiom as tests/subproc.py's pending-update wait."""
    if store.patroller is not None and store.patroller._probe is not None:
        _, _, _, mism_d, clean_d, _, _ = store.patroller._probe
        jax.block_until_ready((mism_d, clean_d))


def quiet_ticks(store, leaves, red, step, n):
    for _ in range(n):
        red, rep = store.tick(leaves, red, step, scrub_period=0)
        if rep.repaired:
            leaves = dict(leaves, **rep.repaired)
        step += 1
        wait_probe(store)
    return leaves, red, step


# ---------------------------------------------------------------- machine-local


def test_patroller_gated_on_budget():
    store, _, _ = make_store(patrol_blocks=0)
    assert store.patroller is None
    store, _, _ = make_store(patrol_blocks=8)
    assert store.patroller is not None
    assert store.patroller.window["w"] == 8


def test_patrol_byte_budget_pacing():
    """Each probe covers exactly the byte budget's worth of blocks; the
    per-tick scan never exceeds it and the window caps at the leaf size."""
    store, leaves, red = make_store(patrol_blocks=8)     # nb=128, window=8
    pat = store.patroller
    nb = store.metas["w"].n_blocks
    assert nb == 128 and pat.window["w"] == 8
    T = 24
    leaves, red, _ = quiet_ticks(store, leaves, red, 0, T)
    # One probe max per tick (dispatch gated on the previous one landing),
    # every probe exactly one window: budget is a per-tick ceiling.
    assert pat.blocks_scanned % 8 == 0
    assert 8 * (T // 2) <= pat.blocks_scanned <= 8 * T
    # Budget larger than the leaf clamps to one-probe-covers-everything.
    big, _, _ = make_store(patrol_blocks=10_000)
    assert big.patroller.window["w"] == nb


def test_patrol_full_coverage_within_bound():
    """A full sweep completes within ~2 ticks per window (dispatch + land),
    so detection latency is bounded by the configured sweep length."""
    store, leaves, red = make_store(patrol_blocks=8)
    pat = store.patroller
    nb = store.metas["w"].n_blocks
    bound = 2 * math.ceil(nb / 8) + 4
    step = 0
    for _ in range(bound):
        red, _ = store.tick(leaves, red, step, scrub_period=0)
        step += 1
        wait_probe(store)
        if pat.sweeps["w"] >= 1:
            break
    assert pat.sweeps["w"] >= 1, (pat.sweeps, pat.cursor, bound)
    assert pat.coverage()["w"] == 1.0


def test_patrol_detects_and_repairs_mid_traffic():
    """A bitflip on a settled block is detected by the patrol *while
    foreground writes keep landing*, parity-repaired bitwise, and the
    store scrubs clean afterwards."""
    store, leaves, red = make_store(n_rows=32, patrol_blocks=8)
    pat = store.patroller
    rows = jnp.arange(4)                     # traffic: rows 0..3 only
    step = 0
    for _ in range(6):                       # settle the rest of the heap
        leaves = dict(leaves, w=leaves["w"].at[rows].add(0.5))
        ev = jnp.zeros((32,), bool).at[rows].set(True)
        red = store.on_write(red, events={"w": ev})
        red, _ = store.tick(leaves, red, step, scrub_period=0)
        step += 1
    red = store.flush(leaves, red, step)
    # Corrupt a block far from the traffic (4 blocks per 512-elem row).
    blk = 16 * (512 * 4 // BPB)
    leaves, red = store.inject(leaves, red, FaultSpec(
        kind="data_bitflip", leaf="w", block=blk, lane=3, bit=7))
    pat.expect_injection("w", blk, step)
    detected = repaired = False
    for _ in range(3 * (2 * (128 // 8) + 4)):
        leaves = dict(leaves, w=leaves["w"].at[rows].add(0.5))
        ev = jnp.zeros((32,), bool).at[rows].set(True)
        red = store.on_write(red, events={"w": ev})
        red, rep = store.tick(leaves, red, step, scrub_period=0)
        step += 1
        if rep.repaired:
            leaves = dict(leaves, **rep.repaired)
            repaired = True
        if pat.latencies:
            detected = True
        if detected and repaired:
            break
    assert detected, "patrol never detected the injected bitflip"
    assert repaired, "patrol never repaired the detected block"
    assert pat.latencies[0] <= 2 * (2 * (128 // 8) + 4)
    red = store.flush(leaves, red, step)
    assert store.scrub_check(leaves, red) == 0
    # Bitwise: the repaired block equals the original data (row 16 was
    # never written after init, so parity reconstruction must restore it).
    orig = np.asarray(jax.random.normal(jax.random.PRNGKey(0),
                                        (32, 512), jnp.float32))
    np.testing.assert_array_equal(np.asarray(leaves["w"])[16], orig[16])


def test_patrol_starvation_floor():
    """Wall-to-wall foreground traffic (an update dispatched every tick)
    must not starve the patrol forever: past
    ``patrol_max_starved_ticks`` consecutive probe-less ticks one probe
    dispatches anyway, and the streak rides on
    ``TickReport.patrol_starved_ticks``.  Floor 0 disables forcing (the
    pure quiet-tick gate), which is the starvation baseline."""
    for floor, expect_probes in ((0, False), (4, True)):
        leaves = {"w": jax.random.normal(jax.random.PRNGKey(0),
                                         (32, 512), jnp.float32)}
        pol = RedundancyPolicy.single(
            "vilamb", period_steps=1, lanes_per_block=LANES,
            patrol_bytes_per_tick=8 * BPB, precompile=False,
            async_tick=False, patrol_max_starved_ticks=floor)
        store = ProtectedStore(pol).attach(leaves)
        red = store.init(leaves)
        pat = store.patroller
        last = 0
        for step in range(1, 31):      # step 0 is never update-due
            leaves = dict(leaves, w=leaves["w"].at[:4].add(0.5))
            ev = jnp.zeros((32,), bool).at[:4].set(True)
            red = store.on_write(red, events={"w": ev})
            red, rep = store.tick(leaves, red, step, scrub_period=0)
            assert rep.updated, "tick unexpectedly quiet"
            last = rep.patrol_starved_ticks
        if expect_probes:
            assert pat.blocks_scanned >= 8, pat.blocks_scanned
            assert last <= floor, last
        else:
            assert pat.blocks_scanned == 0
            assert last >= 20, last


def test_unrecoverable_reported_structurally():
    """Two corruptions in one stripe defeat single-parity: the patroller
    reports them as a typed UnrecoverableBlock instead of looping."""
    store, leaves, red = make_store(patrol_blocks=8)
    pat = store.patroller
    red = store.flush(leaves, red, 0)
    for blk in (0, 1):                       # same stripe (stripe size 4+1)
        leaves, red = store.inject(leaves, red, FaultSpec(
            kind="data_bitflip", leaf="w", block=blk, lane=1, bit=2))
    step, found = 1, []
    for _ in range(40):
        red, rep = store.tick(leaves, red, step, scrub_period=0)
        if rep.repaired:
            leaves = dict(leaves, **rep.repaired)
        found.extend(rep.unrecoverable)
        step += 1
        if found:
            break
    assert found, "multi-corrupt stripe never reported"
    rec = found[0]
    assert isinstance(rec, UnrecoverableBlock)
    assert rec.leaf == "w" and rec.reason == "multi_corrupt"
    assert rec.stripe == 0 and set(rec.blocks) == {0, 1}
    assert pat.unrecoverable                 # also kept on the patroller


def test_plan_stripe_repairs_classifies():
    store, _, red = make_store()
    metas = {"w": store.metas["w"]}
    singles, unrec = plan_stripe_repairs(metas, {"w": [2, 8, 9]})
    assert singles == [("w", 2)]
    assert len(unrec) == 1 and unrec[0].reason == "multi_corrupt"
    assert set(unrec[0].blocks) == {8, 9}
    # bool-mask form is equivalent
    mask = np.zeros((store.metas["w"].n_blocks,), bool)
    mask[[2, 8, 9]] = True
    singles2, unrec2 = plan_stripe_repairs(metas, {"w": mask})
    assert singles2 == singles and unrec2[0].blocks == unrec[0].blocks


def test_patrol_latency_beats_scheduled_scrub_10x():
    """Acceptance: measured detection latency (hence measured MTTDL) with
    the patroller is >= 10x better than scheduled-scrub-only detection.
    Deterministic: unit step seconds, settled store, fixed schedules."""
    from benchmarks.mttdl_bench import run_patrolled
    rows = {name: derived for name, _, derived in
            run_patrolled(n_rows=256, sweep_ticks=8, scrub_period=240,
                          n_faults=1)}
    assert "mttdl/patrol/improvement" in rows, rows
    ratio = float(rows["mttdl/patrol/improvement"].split("x")[0])
    assert ratio >= 10.0, rows


# ----------------------------------------------------------------- multi-device


def test_sharded_patrol_programs_collective_free():
    run_snippet("""
        import numpy as np
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.core import ProtectedStore, RedundancyPolicy
        from repro.launch.hlo_analysis import assert_no_collectives
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
        spec = P(("pod", "data", "model"), None)
        pol = RedundancyPolicy.single(
            "vilamb", period_steps=2, lanes_per_block=128, async_tick=True,
            patrol_bytes_per_tick=32 * 128 * 4, precompile=False)
        w = jax.random.normal(jax.random.PRNGKey(0), (64, 2048), jnp.float32)
        lv = {"w": jax.device_put(w, NamedSharding(mesh, spec))}
        store = ProtectedStore(pol, mesh=mesh).attach(lv, specs={"w": spec})
        red = store.init(lv)
        pat = store.patroller
        eng = pat.engine_of("w")
        wdw = pat.window["w"]
        for want_slab in (False, True):
            lowered = jax.jit(eng.verify_window_fn("w", wdw, want_slab)).lower(
                lv["w"], red["w"], jnp.int32(0))
            assert_no_collectives(lowered, f"patrol_probe(slab={want_slab})")
        # per-tick write sample: elementwise over the sharded bitvectors
        lowered = jax.jit(lambda r: r.dirty | r.shadow).lower(red["w"])
        assert_no_collectives(lowered, "patrol_sample")
        print("PATROL_LOCAL_OK")
    """, "PATROL_LOCAL_OK")


def test_sharded_shard_loss_rebuild_bitwise():
    """Wholesale shard loss on a 2x2x2 mesh: the online rebuild restores
    the lost shard bitwise from cross-shard parity while foreground writes
    keep landing in the lost shard, within the paced tick budget."""
    run_snippet("""
        import math
        import numpy as np
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.core import ProtectedStore, RedundancyPolicy
        from repro.faults.inject import FaultSpec
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
        spec = P(("pod", "data", "model"), None)
        pol = RedundancyPolicy.single(
            "vilamb", period_steps=2, lanes_per_block=128, async_tick=True,
            patrol_bytes_per_tick=32 * 128 * 4, precompile=False)
        w = jax.random.normal(jax.random.PRNGKey(0), (64, 2048), jnp.float32)
        lv = {"w": jax.device_put(w, NamedSharding(mesh, spec))}
        store = ProtectedStore(pol, mesh=mesh).attach(lv, specs={"w": spec})
        red = store.init(lv)
        pat = store.patroller
        step = 0
        # Quiet sweeps until cross-shard parity covers the leaf.
        for _ in range(48):
            red, _ = store.tick(lv, red, step, scrub_period=0); step += 1
            xp = pat.xpar["w"]
            if xp.xpar is not None and bool(xp.xvalid.all()):
                break
        assert bool(pat.xpar["w"].xvalid.all()), "xpar never covered leaf"
        expected = np.array(np.asarray(lv["w"]))

        lost, rows_local = 3, 64 // 8
        lv, red = store.inject(lv, red, FaultSpec(
            kind="shard_loss", leaf="w", block=lost))
        pat._attempts[("w", 5)] = 99       # must reset with the rebuild
        store.declare_shard_lost("w", lost, red)
        # Foreground keeps writing — into the lost shard only (writes to
        # survivors after the xpar freeze are legitimate losses).
        w_rows = np.arange(lost * rows_local, lost * rows_local + 2)
        status = None
        writes = 0
        for i in range(24):
            idx = jnp.asarray(w_rows)
            lv = dict(lv, w=lv["w"].at[idx].set(float(i + 1)))
            expected[w_rows] = float(i + 1)
            writes += 1
            ev = jnp.zeros((64,), bool).at[idx].set(True)
            red = store.on_write(red, events={"w": ev})
            red, rep = store.tick(lv, red, step, scrub_period=0); step += 1
            if rep.repaired:
                lv = dict(lv, **rep.repaired)
            if rep.rebuild is not None and rep.rebuild.done:
                status = rep.rebuild
                break
        assert status is not None, "rebuild never finished"
        nb = store.metas["w"].n_blocks
        # Pacing: the rebuild takes ceil(nb / window) ticks, not one giant
        # stall (rebuild budget defaults to 4x the patrol budget).
        wb = min(nb, 4 * 32)
        assert status.ticks == math.ceil(nb / wb), (status, nb, wb)
        assert status.lost == 0, status
        # Stale per-block repair-attempt counts for the leaf died with the
        # rebuild (post-rebuild re-detections get a fresh budget).
        assert all(k[0] != "w" for k in pat._attempts), pat._attempts
        assert status.rebuilt + status.fresh == nb, status
        red = store.flush(lv, red, step)
        assert store.scrub_check(lv, red) == 0
        got = np.asarray(lv["w"])
        np.testing.assert_array_equal(got, expected)
        print("REBUILD_OK", status.rebuilt, status.fresh, writes)
    """, "REBUILD_OK")


def test_sharded_preloss_dirty_blocks_reported_lost():
    """Blocks with writes in flight *at loss time* (dirty at declaration)
    died with the shard: the rebuild must report them as ``shard_loss``
    unrecoverables, never misclassify them as fresh foreground rewrites —
    while the rest of the shard still rebuilds bitwise."""
    run_snippet("""
        import numpy as np
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.core import ProtectedStore, RedundancyPolicy
        from repro.faults.inject import FaultSpec
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
        spec = P(("pod", "data", "model"), None)
        pol = RedundancyPolicy.single(
            "vilamb", period_steps=2, lanes_per_block=128, async_tick=True,
            patrol_bytes_per_tick=32 * 128 * 4, precompile=False)
        w = jax.random.normal(jax.random.PRNGKey(0), (64, 2048), jnp.float32)
        lv = {"w": jax.device_put(w, NamedSharding(mesh, spec))}
        store = ProtectedStore(pol, mesh=mesh).attach(lv, specs={"w": spec})
        red = store.init(lv)
        pat = store.patroller
        step = 0
        for _ in range(48):
            red, _ = store.tick(lv, red, step, scrub_period=0); step += 1
            xp = pat.xpar["w"]
            if xp.xpar is not None and bool(xp.xvalid.all()):
                break
        assert bool(pat.xpar["w"].xvalid.all()), "xpar never covered leaf"
        expected = np.array(np.asarray(lv["w"]))

        lost, rows_local = 3, 64 // 8
        nb = store.metas["w"].n_blocks          # 128 local blocks
        bpr = nb // rows_local                  # 16 blocks per local row
        # An in-flight write at loss time: marks land, then the shard dies
        # before its redundancy covers the write — the data is gone.
        w_rows = np.arange(lost * rows_local, lost * rows_local + 2)
        idx = jnp.asarray(w_rows)
        lv = dict(lv, w=lv["w"].at[idx].set(7.0))
        ev = jnp.zeros((64,), bool).at[idx].set(True)
        red = store.on_write(red, events={"w": ev})
        lv, red = store.inject(lv, red, FaultSpec(
            kind="shard_loss", leaf="w", block=lost))
        store.declare_shard_lost("w", lost, red)   # marks -> preloss
        status, unrec = None, []
        for _ in range(24):
            red, rep = store.tick(lv, red, step, scrub_period=0); step += 1
            if rep.repaired:
                lv = dict(lv, **rep.repaired)
            unrec.extend(rep.unrecoverable)
            if rep.rebuild is not None and rep.rebuild.done:
                status = rep.rebuild
                break
        assert status is not None, "rebuild never finished"
        n_preloss = 2 * bpr
        assert status.lost == n_preloss, status
        assert status.fresh == 0, status
        assert status.rebuilt == nb - n_preloss, status
        want = {lost * nb + b for b in range(n_preloss)}
        got_blocks = {b for u in unrec if u.reason == "shard_loss"
                      for b in u.blocks}
        assert got_blocks == want, (sorted(got_blocks), sorted(want))
        # The untouched remainder of the shard still rebuilt bitwise, and
        # redundancy re-converged over the named loss (no eternal alarm).
        red = store.flush(lv, red, step)
        assert store.scrub_check(lv, red) == 0
        got = np.asarray(lv["w"])
        rest = np.arange(lost * rows_local + 2, (lost + 1) * rows_local)
        np.testing.assert_array_equal(got[rest], expected[rest])
        print("PRELOSS_OK", status.lost, status.rebuilt)
    """, "PRELOSS_OK")


def test_sharded_late_probe_cannot_revalidate_written_rows():
    """A probe that stays in flight for more than one tick must not
    re-validate cross-shard parity rows a foreground write invalidated
    after its dispatch (its clean mask predates the write): the sample
    invalidations processed while it flew mask its adoption."""
    run_snippet("""
        import numpy as np
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.core import ProtectedStore, RedundancyPolicy
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
        spec = P(("pod", "data", "model"), None)
        pol = RedundancyPolicy.single(
            "vilamb", period_steps=2, lanes_per_block=128, async_tick=True,
            patrol_bytes_per_tick=32 * 128 * 4, precompile=False)
        w = jax.random.normal(jax.random.PRNGKey(0), (64, 2048), jnp.float32)
        lv = {"w": jax.device_put(w, NamedSharding(mesh, spec))}
        store = ProtectedStore(pol, mesh=mesh).attach(lv, specs={"w": spec})
        red = store.init(lv)
        pat = store.patroller
        # Tick 0: prime + dispatch the first probe (window [0, 32)).
        red, _ = store.tick(lv, red, 0, scrub_period=0)
        assert pat._probe is not None and pat._probe[1] == 0

        class Slow:                    # pin the probe in flight
            def __init__(self, a, gate): self.a, self.gate = a, gate
            def is_ready(self): return self.gate[0] <= 0
            def __array__(self, *a, **k): return np.asarray(self.a)
        gate = [1]
        nm, st, wdw, mi, cl, xw, sp = pat._probe
        pat._probe = (nm, st, wdw, Slow(mi, gate), Slow(cl, gate), xw, sp)

        # A write lands while the probe is in flight: global row 0 ->
        # shard 0, local blocks [0, 16).
        lv = dict(lv, w=lv["w"].at[0:1].add(1.0))
        red = store.on_write(red, events={"w": jnp.zeros((64,), bool)
                                          .at[0].set(True)})
        # Tick 1: probe still pinned; the write sample covering the new
        # marks is dispatched.  Tick 2: that sample is processed (rows
        # [0, 16) invalidated), then the probe lands and adopts.
        red, _ = store.tick(lv, red, 1, scrub_period=0)
        gate[0] = 0
        red, _ = store.tick(lv, red, 2, scrub_period=0)
        xv = pat.xpar["w"].xvalid
        assert pat._probe is None, "probe never landed"
        assert not xv[0:16].any(), "late probe re-validated written rows"
        assert xv[16:32].all(), "adoption lost for untouched rows"
        print("LATE_PROBE_OK")
    """, "LATE_PROBE_OK")
