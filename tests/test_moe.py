"""MoE dispatch semantics: capacity, determinism, EP-free local path."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke
from repro.models import moe as M


def _cfg(**kw):
    return dataclasses.replace(get_smoke("qwen3-moe-235b-a22b"), **kw)


def test_no_drop_capacity_matches_dense_mixture():
    """With capacity >= T*K, MoE output equals the explicit dense top-k sum."""
    cfg = _cfg(capacity_factor=float(8), param_dtype="float32")
    p = M.moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (12, cfg.d_model), jnp.float32)
    out, counts, aux = M.moe_apply(p, x, cfg)
    # dense reference
    logits = (x @ p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, -1)
    gates, ids = jax.lax.top_k(probs, cfg.top_k)
    gates = gates / gates.sum(-1, keepdims=True)
    ref = jnp.zeros_like(x)
    for t in range(12):
        acc = jnp.zeros((cfg.d_model,))
        for k in range(cfg.top_k):
            e = int(ids[t, k])
            h = jax.nn.silu(x[t] @ p["wg"][e]) * (x[t] @ p["wi"][e])
            acc = acc + gates[t, k] * (h @ p["wo"][e])
        ref = ref.at[t].set(acc)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-4, atol=2e-4)
    assert int(counts.sum()) == 12 * cfg.top_k


def test_capacity_drops_are_bounded():
    cfg = _cfg(capacity_factor=1.0)
    p = M.moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(2), (64, cfg.d_model), jnp.float32)
    out, counts, aux = M.moe_apply(p, x, cfg)
    assert bool(jnp.all(jnp.isfinite(out)))
    assert float(aux) > 0


def test_deterministic():
    cfg = _cfg()
    p = M.moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(3), (32, cfg.d_model), jnp.float32)
    o1, c1, _ = jax.jit(lambda p, x: M.moe_apply(p, x, cfg))(p, x)
    o2, c2, _ = jax.jit(lambda p, x: M.moe_apply(p, x, cfg))(p, x)
    np.testing.assert_array_equal(np.asarray(o1), np.asarray(o2))
    np.testing.assert_array_equal(np.asarray(c1), np.asarray(c2))
