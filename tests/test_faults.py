"""Fault-injection & crash-consistency battery (repro.faults).

Proves the paper's §5 delayed-coverage guarantees end to end: every
injected corruption outside the vulnerability window is detected (and
single-block ones repaired), every crash point of the pipelined tick is
bitwise-recoverable, and losses only ever happen provably inside the
knob-bounded window.
"""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import CheckpointManager
from repro.ckpt.failure import repair_corruption
from repro.core import ALL, ProtectedStore, RedundancyPolicy
from repro.core import blocks as B
from repro.core import mttdl
from repro.faults import (CrashPlan, CrashPointMachine, FaultInjector,
                          FaultSpec, check_detection, vulnerability_window)
from repro.faults.crashpoints import StoreState

SEED = int(os.environ.get("REPRO_TEST_SEED", "0"))


def _leaves():
    return {"w": jax.random.normal(jax.random.PRNGKey(0), (24, 200),
                                   jnp.float32),
            "e": jax.random.normal(jax.random.PRNGKey(1), (16, 64),
                                   jnp.bfloat16)}


def _store(async_on=True, period=2, scrub=0, deadline=0):
    pol = RedundancyPolicy.single(
        "vilamb", period_steps=period, scrub_period_steps=scrub,
        max_vulnerable_steps=deadline, lanes_per_block=128,
        work_queue_frac=0.5, async_tick=async_on, precompile=False)
    return ProtectedStore(pol).attach(_leaves())


def _clean_state():
    store = _store()
    leaves = _leaves()
    red = store.init(leaves)
    return store, leaves, red


# ------------------------------------------------------------- injector
def test_injector_deterministic_from_seed():
    store, _, red = _clean_state()
    a = FaultInjector(store, seed=7).plan(8, kinds=("data_bitflip",
                                                    "torn_write"))
    b = FaultInjector(store, seed=7).plan(8, kinds=("data_bitflip",
                                                    "torn_write"))
    assert a == b
    c = FaultInjector(store, seed=8).plan(8, kinds=("data_bitflip",
                                                    "torn_write"))
    assert a != c
    x = FaultInjector(store, seed=7).plan_clean_blocks(red, 4)
    y = FaultInjector(store, seed=7).plan_clean_blocks(red, 4)
    assert x == y


def test_data_faults_detected_by_scrub():
    """Every data-side fault kind on a clean store is caught, exactly."""
    for kind in ("data_bitflip", "torn_write", "stale_redundancy"):
        store, leaves, red = _clean_state()
        inj = FaultInjector(store, seed=SEED)
        spec = dataclasses.replace(
            inj.plan(1, kinds=(kind,), leaf="w")[0], block=5,
            blocks=(5, 6) if kind == "torn_write" else
            ((5,) if kind == "stale_redundancy" else ()))
        lv2, red2 = store.inject(leaves, red, spec)
        mm = store.scrub(lv2, red2)
        got = set(np.flatnonzero(np.asarray(mm["w"])).tolist())
        assert got == set(spec.touched_blocks), (kind, got)
        assert int(np.asarray(mm["e"]).sum()) == 0


def test_redundancy_side_faults_caught_by_meta_or_repair():
    store, leaves, red = _clean_state()
    # checksum corruption: the block scrubs as mismatching AND the
    # checksum-of-checksums flags the page
    _, red_ck = store.inject(leaves, red, FaultSpec(
        kind="checksum_bitflip", leaf="w", block=3, bit=5))
    assert not bool(store.verify_meta(red_ck)["w"])
    mm = store.scrub(leaves, red_ck)
    assert np.flatnonzero(np.asarray(mm["w"])).tolist() == [3]
    # meta corruption alone: data scrubs clean, meta check trips
    _, red_mc = store.inject(leaves, red, FaultSpec(
        kind="meta_bitflip", leaf="w", bit=1))
    assert not bool(store.verify_meta(red_mc)["w"])
    assert sum(int(v.sum()) for v in store.scrub(leaves, red_mc).values()) == 0
    # parity corruption: silent for scrub, but a repair through that stripe
    # must produce data the post-repair scrub rejects (never silent success)
    _, red_par = store.inject(leaves, red, FaultSpec(
        kind="parity_bitflip", leaf="w", block=8, lane=2, bit=9))
    lv_bad, _ = store.inject(leaves, red_par, FaultSpec(
        kind="data_bitflip", leaf="w", block=8, lane=1, bit=1))
    mm = store.scrub(lv_bad, red_par)
    repaired, fixed, lost = repair_corruption(store, lv_bad, red_par, mm)
    assert (fixed, lost) == (1, 0)
    mm2 = store.scrub(repaired, red_par)
    assert int(np.asarray(mm2["w"]).sum()) > 0   # bad parity -> bad rebuild


# --------------------------------------------------------------- oracle
@pytest.mark.parametrize("seed", [SEED, SEED + 1, SEED + 2])
def test_oracle_full_detection_no_false_positives(seed):
    """Acceptance: 100% detection of single-stripe corruptions outside the
    window, zero false positives, across seeds."""
    store = _store(period=2)
    leaves = _leaves()
    red = store.init(leaves)
    rng = np.random.default_rng(seed)
    for step in range(1, 7):
        rows = np.sort(rng.choice(24, size=int(rng.integers(1, 4)),
                                  replace=False))
        idx = jnp.asarray(rows)
        leaves = dict(leaves, w=leaves["w"].at[idx].add(0.5))
        red = store.on_write(red, events={
            "w": jnp.zeros((24,), bool).at[idx].set(True)})
        red, _ = store.tick(leaves, red, step)
    inj = FaultInjector(store, seed=seed)
    specs = inj.plan_clean_blocks(red, n=5, kinds=("data_bitflip",
                                                   "stale_redundancy"))
    assert specs, "workload dirtied every stripe; shrink the write set"
    window = vulnerability_window(store, red)
    lv2, red2 = inj.inject_many(leaves, red, specs)
    rep = check_detection(store, lv2, red2, specs, window=window)
    assert rep.ok, rep.summary()
    want = {(s.leaf, b) for s in specs for b in s.touched_blocks}
    assert sum(len(v) for v in rep.expected.values()) == len(want)
    assert not any(rep.in_window.values())


def test_oracle_in_window_corruption_is_classified_not_flagged():
    """A corruption under a live dirty mark is invisible to scrub (stale
    checksum) — the oracle must classify it in-window, not as a miss."""
    store, leaves, red = _clean_state()
    red = store.on_write(red, events={
        "w": jnp.zeros((24,), bool).at[0].set(True)})
    window = vulnerability_window(store, red)
    dirty_block = int(np.flatnonzero(window.blocks["w"])[0])
    spec = FaultSpec(kind="data_bitflip", leaf="w", block=dirty_block,
                     lane=1, bit=3)
    lv2, red2 = store.inject(leaves, red, spec)
    rep = check_detection(store, lv2, red2, [spec], window=window)
    assert rep.ok
    assert rep.in_window == {"w": {dirty_block}}
    assert not rep.expected and not rep.detected.get("w")


# -------------------------------------------------------- crash machine
def _machine(tmp_path, **kw):
    def make_store():
        return _store(period=2, deadline=3)

    kw.setdefault("steps", 6)
    kw.setdefault("scrub_every", 5)
    kw.setdefault("hold_inflight_steps", (3, 4))
    return CrashPointMachine(make_store, _leaves, tmp_path, seed=SEED, **kw)


def test_crash_sweep_covers_pipeline_and_recovers(tmp_path):
    """Acceptance: every PR3 tick phase fires and every crash point is
    bitwise-recoverable (no corruption injected -> no loss allowed)."""
    m = _machine(tmp_path)
    outcomes = m.sweep(require_phases=(
        "dispatch", "coalesce", "adopt", "adopt_forced", "on_write",
        "tick", "flush"))
    assert outcomes
    bad = [o for o in outcomes if o.classification != "recovered_bitwise"]
    assert not bad, [(o.plan, o.classification, o.diverged) for o in bad]
    assert all(o.scrub_after_flush == 0 for o in outcomes)


def test_crash_corruption_outside_window_repairs(tmp_path):
    m = _machine(tmp_path)
    fired = m.enumerate_phases()
    plan = [CrashPlan(p, o) for p, o in fired if p == "dispatch"][-1]
    probe = m.run_crash(plan)
    window_w = probe.window.get("w", set())
    meta = m._probe().protected_metas["w"]
    sw = meta.stripe_data_blocks
    clean = [b for b in range(meta.n_blocks)
             if all(v // sw != b // sw for v in window_w)]
    out = m.run_crash(plan, faults=(FaultSpec(
        kind="data_bitflip", leaf="w", block=clean[0], lane=3, bit=7),))
    assert out.classification == "recovered_bitwise"


def test_crash_corruption_inside_window_is_provably_bounded(tmp_path):
    m = _machine(tmp_path)
    fired = m.enumerate_phases()
    plan = [CrashPlan(p, o) for p, o in fired if p == "dispatch"][-1]
    probe = m.run_crash(plan)
    window_w = sorted(probe.window.get("w", set()))
    assert window_w, "dispatch crash point must hold a non-empty shadow"
    out = m.run_crash(plan, faults=(FaultSpec(
        kind="data_bitflip", leaf="w", block=window_w[0], lane=3, bit=7),))
    assert out.classification == "lost_within_window"
    assert set(out.diverged.get("w", ())) <= set(window_w)
    assert out.scrub_after_flush == 0      # forward progress resumes


# ----------------------------------------------- restore_verified paths
def _saved_state(tmp_path, store, leaves, red, step=1):
    state = StoreState(leaves=dict(leaves), red=dict(red),
                       step=jnp.asarray(step, jnp.int32))
    mgr = CheckpointManager(tmp_path)
    mgr.save(step, state, blocking=True)
    return mgr, state


def _restore(mgr, state, store):
    return mgr.restore_verified(
        jax.eval_shape(lambda: state), store,
        leaves_of=lambda st: st.leaves,
        replace_leaves=lambda st, lv: dataclasses.replace(
            st, leaves=dict(lv)))


def test_restore_verified_multi_leaf_and_boundary_corruption(tmp_path):
    """Corruptions across two leaves plus both sides of a parity-group
    boundary (and the padded last stripe) all repair on restore."""
    store, leaves, red = _clean_state()
    red = store.flush(leaves, red)
    mgr, state = _saved_state(tmp_path, store, leaves, red)
    meta = store.protected_metas["w"]
    sw = meta.stripe_data_blocks
    lv2, red2 = dict(leaves), dict(red)
    for spec in (
            FaultSpec(kind="data_bitflip", leaf="w", block=sw - 1, lane=9,
                      bit=4),                       # last block of stripe 0
            FaultSpec(kind="data_bitflip", leaf="w", block=sw, lane=0,
                      bit=31),                      # first block of stripe 1
            FaultSpec(kind="data_bitflip", leaf="w",
                      block=meta.n_blocks - 1, lane=2, bit=1),  # padded stripe
            FaultSpec(kind="data_bitflip", leaf="e", block=0, lane=5,
                      bit=17)):                     # second leaf
        lv2, red2 = store.inject(lv2, red2, spec)
    state_bad = StoreState(leaves=lv2, red=red2, step=state.step)
    mgr.save(1, state_bad, blocking=True)
    restored = _restore(mgr, state, store)
    assert restored is not None
    rep = mgr.last_restore_report
    assert rep.step == 1 and rep.repaired_blocks == 4
    assert rep.tried == [(1, "ok_repaired")]
    for name in leaves:
        np.testing.assert_array_equal(np.asarray(restored.leaves[name]),
                                      np.asarray(leaves[name]))


def test_same_parity_group_double_corruption_fails_loudly(tmp_path):
    """Satellite acceptance: two corrupt stripes-mates must not silently
    'repair'; repair refuses, warns, and restore falls back a checkpoint."""
    store, leaves, red = _clean_state()
    red = store.flush(leaves, red)
    mgr, state = _saved_state(tmp_path, store, leaves, red, step=1)
    # newest checkpoint carries the double corruption in stripe 1
    lv2, red2 = store.inject(leaves, red, FaultSpec(
        kind="data_bitflip", leaf="w", block=4, lane=3, bit=2))
    lv2, red2 = store.inject(lv2, red2, FaultSpec(
        kind="data_bitflip", leaf="w", block=5, lane=8, bit=19))
    mgr.save(2, StoreState(leaves=lv2, red=red2, step=jnp.asarray(
        2, jnp.int32)), blocking=True)

    mm = store.scrub(lv2, red2)
    with pytest.warns(RuntimeWarning, match="share parity group"):
        _, fixed, lost = repair_corruption(store, lv2, red2, mm)
    assert (fixed, lost) == (0, 2)

    with pytest.warns(RuntimeWarning, match="share parity group"):
        restored = _restore(mgr, state, store)
    assert restored is not None
    rep = mgr.last_restore_report
    assert rep.tried == [(2, "unrecoverable"), (1, "ok")]
    assert rep.step == 1 and rep.lost_blocks == 2
    # structured loss records: which stripe, which blocks, why (PR6)
    assert len(rep.unrecoverable) == 1
    u = rep.unrecoverable[0]
    assert (u.leaf, u.reason) == ("w", "multi_corrupt")
    assert u.stripe == 1 and set(u.blocks) == {4, 5}
    np.testing.assert_array_equal(np.asarray(restored.leaves["w"]),
                                  np.asarray(leaves["w"]))


# ------------------------------------------------------------ mttdl glue
def test_mttdl_measured_reduces_to_closed_form_and_is_monotone():
    closed = mttdl.mttdl_vilamb(1e9, 12.0, 5)
    zero_lat = mttdl.mttdl_measured(1e9, 12.0, 5, 1000, 0.0)
    assert zero_lat == pytest.approx(closed, rel=1e-12)
    lats = [mttdl.mttdl_measured(1e9, 12.0, 5, 1000, L)
            for L in (0.0, 1.0, 1e3, 1e6)]
    assert all(a >= b for a, b in zip(lats, lats[1:]))
    assert mttdl.mttdl_measured(1e9, 0.0, 5, 1000, 0.0) == float("inf")
    assert mttdl.detection_latency_stats([]) == {
        "n": 0, "mean_s": 0.0, "max_s": 0.0}
    st = mttdl.detection_latency_stats([2, None, 4], step_seconds=0.5)
    assert st == {"n": 2, "mean_s": 1.5, "max_s": 2.0}


# ------------------------------------------------------------ phase hooks
def test_phase_hooks_fire_and_remove():
    store, leaves, red = _clean_state()
    seen = []
    hook = lambda phase, info: seen.append(phase)
    store.add_phase_hook(hook)
    red = store.on_write(red, events={"w": ALL})
    red, _ = store.tick(leaves, red, 2)
    red = store.flush(leaves, red, step=2)
    assert "on_write" in seen and "flush" in seen
    assert "dispatch" in seen or "blocking_update" in seen
    store.remove_phase_hook(hook)
    n = len(seen)
    store.tick(leaves, red, 4)
    assert len(seen) == n


def test_phase_hooks_skip_under_trace():
    """A hook must never fire inside a jitted step (host-level only)."""
    store, leaves, red = _clean_state()

    def boom(phase, info):
        raise AssertionError(f"hook fired under trace: {phase}")

    store.add_phase_hook(boom)

    @jax.jit
    def step(red):
        return store.on_write(red, events={"w": ALL})

    red2 = step(red)      # traces on_write; hook must stay silent
    store.remove_phase_hook(boom)
    assert int(np.asarray(red2["w"].dirty).sum()) > 0


# ------------------------------------------------- mesh-sharded coverage
# Multi-device: bodies run in a subprocess (XLA_FLAGS must predate the jax
# import); the shared 2x2x2 fixture lives in tests/subproc.py.

def test_sharded_faults_inject_global_geometry_detect_per_shard():
    """Faults planned through global block geometry land on the owning
    shard's slice and are detected by that shard's local scrub — 100%
    outside-window detection, zero false positives, across shards."""
    from subproc import MESH_PRELUDE, run_snippet
    run_snippet("""
        from repro.faults import (FaultInjector, FaultSpec, check_detection,
                                  vulnerability_window)
        store = mesh_store(async_tick=True, precompile=False)
        lv, red = drive(store, steps=6, seed=1)
        assert store.shard_factor("w") == 8 and store.shard_factor("e") == 4
        inj = FaultInjector(store, seed=1)
        specs = inj.plan_clean_blocks(red, n=6, kinds=("data_bitflip",
                                                       "stale_redundancy"))
        nb = store.protected_metas["w"].n_blocks
        shards_hit = {s.block // nb for s in specs if s.leaf == "w"}
        assert len(shards_hit) > 1, shards_hit   # multiple failure domains
        window = vulnerability_window(store, red)
        lv2, red2 = inj.inject_many(lv, red, specs)
        rep = check_detection(store, lv2, red2, specs, window=window)
        assert rep.ok, rep.summary()
        # every injected global block id was flagged by scrub
        for s in specs:
            for b in s.touched_blocks:
                assert b in rep.detected[s.leaf], (s, rep.detected)
        # repair rebuilds the corrupted shards bitwise from local parity
        mm = store.scrub(lv2, red2)
        repaired, fixed, lost = store.repair(lv2, red2, mm)
        assert lost == 0 and fixed == sum(len(v) for v in rep.detected.values())
        for k in lv:
            np.testing.assert_array_equal(np.asarray(repaired[k]),
                                          np.asarray(lv[k]), err_msg=k)
        # a meta flip on shard 5 trips only that leaf's per-shard meta check
        gb = 5 * nb + 2
        _, red3 = store.inject(lv, red, FaultSpec(kind="meta_bitflip",
                                                  leaf="w", block=gb, bit=7))
        ok = store.verify_meta(red3)
        assert not bool(ok["w"]) and bool(ok["e"]), ok
        print("SHARDED_FAULTS_OK")
    """, "SHARDED_FAULTS_OK", prelude=MESH_PRELUDE)


def test_sharded_crash_points_recover_bitwise():
    """Crash-point sweep subset on the sharded overlap pipeline: dying at
    dispatch / mid-flight coalesce / adoption / forced resolve / flush
    must restore bitwise on a fresh store, and outside-window corruption
    of a non-zero shard's persisted state must parity-repair."""
    from subproc import MESH_PRELUDE, run_snippet
    run_snippet("""
        import tempfile
        from repro.faults import CrashPlan, CrashPointMachine, FaultSpec
        def make_store():
            return mesh_store(async_tick=True, precompile=False,
                              max_vulnerable_steps=3)
        def make_crash_leaves():
            return put(make_leaves())
        with tempfile.TemporaryDirectory() as tmp:
            machine = CrashPointMachine(make_store, make_crash_leaves, tmp,
                                        seed=0, steps=7, scrub_every=5,
                                        hold_inflight_steps=(3, 4))
            fired = machine.enumerate_phases()
            plans = []
            for ph in ("dispatch", "coalesce", "adopt", "adopt_forced",
                       "flush"):
                occ = [o for p, o in fired if p == ph]
                assert occ, (ph, sorted({p for p, _ in fired}))
                plans.append(CrashPlan(ph, occ[-1]))
            for plan in plans:
                out = machine.run_crash(plan)
                assert out.ok, (plan, out.classification, out.diverged)
            # corrupt a clean block on a non-zero shard while down
            probe = machine.run_crash(plans[0])
            meta = machine._probe().protected_metas["w"]
            k = machine._probe().shard_factor("w")
            win = probe.window.get("w", set())
            stripe = lambda b: (b // meta.n_blocks,
                                (b % meta.n_blocks) // meta.stripe_data_blocks)
            clean = [b for b in range(meta.n_blocks, meta.n_blocks * k)
                     if b not in win
                     and not any(stripe(b) == stripe(v) for v in win)]
            out = machine.run_crash(plans[0], faults=(
                FaultSpec(kind="data_bitflip", leaf="w", block=clean[0],
                          lane=3, bit=7),))
            assert out.classification == "recovered_bitwise", out.classification
        print("SHARDED_CRASH_OK")
    """, "SHARDED_CRASH_OK", prelude=MESH_PRELUDE)
