"""Deliverable (f): per-arch reduced-config smoke tests — one forward/train
step on CPU, asserting output shapes and no NaNs."""
import jax
import jax.numpy as jnp
import pytest

from conftest import tiny_batch
from repro.configs import get_arch, get_smoke, list_archs
from repro.models import build_model


@pytest.mark.parametrize("arch", list_archs())
def test_forward_and_grad_step(arch):
    cfg = get_smoke(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = tiny_batch(cfg, B=2, S=32)
    (loss, aux), grads = jax.jit(
        jax.value_and_grad(model.loss, has_aux=True))(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), arch
    assert bool(jnp.isfinite(aux["ce"]))
    for leaf in jax.tree.leaves(grads):
        assert bool(jnp.all(jnp.isfinite(leaf.astype(jnp.float32)))), arch
    # counts shape: (n_groups, group_size, E)
    assert aux["expert_counts"].shape == (cfg.n_groups, cfg.group_size,
                                          max(cfg.n_experts, 1))


@pytest.mark.parametrize("arch", list_archs())
def test_full_config_is_exact_assignment(arch):
    """The full configs carry the exact assigned hyperparameters."""
    cfg = get_arch(arch)
    expected = {
        "jamba-1.5-large-398b": (72, 8192, 64, 8, 24576, 65536),
        "qwen3-moe-235b-a22b": (94, 4096, 64, 4, 1536, 151936),
        "arctic-480b": (35, 7168, 56, 8, 4864, 32000),
        "internvl2-1b": (24, 896, 14, 2, 4864, 151655),
        "olmo-1b": (16, 2048, 16, 16, 8192, 50304),
        "nemotron-4-15b": (32, 6144, 48, 8, 24576, 256000),
        "glm4-9b": (40, 4096, 32, 2, 13696, 151552),
        "llama3.2-3b": (28, 3072, 24, 8, 8192, 128256),
        "seamless-m4t-medium": (12, 1024, 16, 16, 4096, 256206),
        "xlstm-1.3b": (48, 2048, 4, 4, 0, 50304),
    }[arch]
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
           cfg.d_ff, cfg.vocab_size)
    assert got == expected


def test_moe_expert_counts_drive_dirty_events():
    cfg = get_smoke("qwen3-moe-235b-a22b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = tiny_batch(cfg)
    loss, aux = jax.jit(model.loss)(params, batch)
    ev = model.dirty_events_train(batch, aux)
    assert "embed" in ev
    moe_evs = [k for k in ev if "/moe/" in k]
    assert moe_evs, "MoE arch must emit expert dirty events"
    for k in moe_evs:
        assert ev[k].shape == (cfg.n_groups, cfg.n_experts)
    # top-k routing: some but usually not all experts touched per layer
    assert int(ev[moe_evs[0]].sum()) >= 1
