"""Stripe parity: reconstruction inverts corruption; diffs compose."""
import jax
import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container lacks hypothesis: deterministic shim
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core import parity as P


def _lanes(seed, nb=11, L=64):
    return jax.random.randint(jax.random.PRNGKey(seed), (nb, L), 0, 2**31 - 1, jnp.uint32)


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 500), st.sampled_from([2, 4, 5]), st.integers(0, 10))
def test_reconstruct_inverts_corruption(seed, sw, bad_block):
    lanes = _lanes(seed)
    par = P.stripe_parity(lanes, sw)
    sid = bad_block // sw
    corrupted = lanes.at[bad_block].set(lanes[bad_block] ^ jnp.uint32(0xBEEF))
    rebuilt = P.reconstruct_block(corrupted, par[sid], sw, bad_block, sid)
    np.testing.assert_array_equal(np.asarray(rebuilt), np.asarray(lanes[bad_block]))


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 500), st.integers(0, 500), st.sampled_from([4, 5]))
def test_parity_diff_equals_recompute(s1, s2, sw):
    old, new = _lanes(s1), _lanes(s2)
    p_old = P.stripe_parity(old, sw)
    p_new = P.stripe_parity(new, sw)
    np.testing.assert_array_equal(
        np.asarray(p_old ^ P.parity_diff(old, new, sw)), np.asarray(p_new))


def test_masked_parity_keeps_clean_rows():
    lanes = _lanes(9)
    old = P.stripe_parity(lanes, 4) ^ jnp.uint32(123)  # stale everywhere
    sdirty = jnp.zeros((3,), bool).at[1].set(True)
    out = P.stripe_parity_masked(lanes, old, sdirty, 4)
    np.testing.assert_array_equal(np.asarray(out[0]), np.asarray(old[0]))
    np.testing.assert_array_equal(np.asarray(out[2]), np.asarray(old[2]))
    np.testing.assert_array_equal(
        np.asarray(out[1]), np.asarray(P.stripe_parity(lanes, 4)[1]))
