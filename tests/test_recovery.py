"""End-to-end fault tolerance: SDC inject -> scrub detect -> parity repair ->
training continues; preemption flush within grace budget."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke
from repro.ckpt import CheckpointManager, PreemptionHandler
from repro.ckpt.failure import repair_corruption
from repro.core import RedundancyConfig, RedundancyEngine
from repro.core import blocks as B
from repro.common import unflatten_dict
from repro.data import SyntheticPipeline
from repro.models import build_model
from repro.models.config import ShapeConfig
from repro.optim import AdamW
from repro.train import Trainer, protected_leaves, protected_structs


def _trainer():
    cfg = get_smoke("llama3.2-3b")
    m = build_model(cfg)
    opt = AdamW(lr=lambda s: 1e-3)
    p0 = jax.eval_shape(m.init, jax.random.PRNGKey(0))
    o0 = jax.eval_shape(opt.init, p0)
    engine = RedundancyEngine(protected_structs(p0, o0),
                              RedundancyConfig(mode="vilamb", lanes_per_block=512,
                                               period_steps=2))
    data = SyntheticPipeline(cfg, ShapeConfig("t", 32, 4, "train"), seed=0)
    return Trainer(model=m, opt=opt, engine=engine, mode="vilamb",
                   period_steps=2, scrub_period_steps=0), data


def test_sdc_detect_repair_continue():
    tr, data = _trainer()
    st = tr.init_state(jax.random.PRNGKey(0))
    st = tr.run(st, data, 3)
    st = tr.flush(st)                     # everything clean + covered
    eng = tr.engine
    leaves = protected_leaves(st.params, st.opt)

    # inject a bit flip into a params block
    name = "params/embed"
    meta = eng.metas[name]
    lanes = B.to_lanes(leaves[name], meta)
    leaves[name] = B.from_lanes(lanes.at[2, 5].add(0xBAD), meta)

    mm = eng.scrub(leaves, st.red)
    total = sum(int(v.sum()) for v in jax.tree.leaves(mm))
    assert total == 1

    repaired, fixed, lost = repair_corruption(eng, leaves, st.red, mm)
    assert (fixed, lost) == (1, 0)
    mm2 = eng.scrub(repaired, st.red)
    assert sum(int(v.sum()) for v in jax.tree.leaves(mm2)) == 0

    # put repaired params back into the state and keep training
    import dataclasses
    params = {k[len("params/"):]: v for k, v in repaired.items()
              if k.startswith("params/")}
    st = dataclasses.replace(st, params=unflatten_dict(params))
    losses = []
    st = tr.run(st, data, 2, on_step=lambda s, m: losses.append(float(m["loss"])))
    assert all(np.isfinite(l) for l in losses)


def test_preemption_drain(tmp_path):
    tr, data = _trainer()
    st = tr.init_state(jax.random.PRNGKey(0))
    st = tr.run(st, data, 3)
    h = PreemptionHandler()
    ckpt = CheckpointManager(tmp_path)
    st = h.drain(tr, st, ckpt)
    assert h.flush_seconds is not None and h.flush_seconds < 30
    assert ckpt.steps() == [int(st.step)]
    mm = tr.scrub_fn(st)
    assert sum(int(v.sum()) for v in jax.tree.leaves(mm)) == 0
