"""Block-view (page) geometry and bitcast roundtrips."""
import jax
import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container lacks hypothesis: deterministic shim
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core import blocks as B

DTYPES = ["float32", "bfloat16", "int32", "float16", "int8"]


@settings(max_examples=40, deadline=None)
@given(
    st.sampled_from(DTYPES),
    st.lists(st.integers(1, 40), min_size=1, max_size=3),
    st.sampled_from([128, 256, 512]),
    st.sampled_from([2, 4, 5]),
)
def test_lanes_roundtrip(dtype, shape, lpb, sw):
    x = jax.random.normal(jax.random.PRNGKey(0), shape, jnp.float32)
    x = (x * 100).astype(jnp.dtype(dtype))
    meta = B.make_meta(x, lanes_per_block=lpb, stripe_data_blocks=sw)
    lanes = B.to_lanes(x, meta)
    assert lanes.shape == (meta.n_blocks, meta.lanes_per_block)
    assert lanes.dtype == jnp.uint32
    back = B.from_lanes(lanes, meta)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(x))
    assert meta.n_stripes == -(-meta.n_blocks // sw)


def test_row_block_mask_basic():
    x = jnp.zeros((10, 70), jnp.float32)  # 70 lanes per row
    meta = B.make_meta(x, lanes_per_block=128)
    # row 0 covers lanes [0,70) -> block 0; row 3 lanes [210,280) -> blocks 1,2
    m = B.row_block_mask(meta, jnp.array([0]))
    assert bool(m[0]) and int(m.sum()) == 1
    m = B.row_block_mask(meta, jnp.array([3]))
    got = np.nonzero(np.asarray(m))[0].tolist()
    assert got == [1, 2]
    # negative ids ignored
    m = B.row_block_mask(meta, jnp.array([-1]))
    assert int(m.sum()) == 0


def test_row_block_mask_multidim():
    x = jnp.zeros((4, 8, 32), jnp.float32)  # rows over first 2 dims
    meta = B.make_meta(x, lanes_per_block=128)
    # flattened row (1, 2) = row 10 -> lanes [320, 352) -> block 2
    m = B.row_block_mask(meta, jnp.array([10]), row_dims=2)
    got = np.nonzero(np.asarray(m))[0].tolist()
    assert got == [2]
