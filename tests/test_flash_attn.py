"""Flash-attention kernel vs exact-softmax oracle (interpret=True sweep)."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attn import ops as fops
from repro.kernels.flash_attn import ref as fref
from repro.models import attention as A
from repro.configs import get_smoke


@pytest.mark.parametrize("B,S,H,hd,causal,dtype", [
    (1, 256, 2, 64, True, "float32"),
    (2, 512, 4, 128, True, "float32"),
    (1, 256, 2, 64, False, "float32"),
    (2, 256, 2, 128, True, "bfloat16"),
    (1, 1024, 1, 64, True, "float32"),
])
def test_flash_matches_oracle(B, S, H, hd, causal, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    shape = (B, S, H, hd)
    q = jax.random.normal(ks[0], shape, jnp.dtype(dtype))
    k = jax.random.normal(ks[1], shape, jnp.dtype(dtype))
    v = jax.random.normal(ks[2], shape, jnp.dtype(dtype))
    out = fops.flash_attention(q, k, v, causal=causal, use_pallas=True,
                               interpret=True, block_q=128, block_k=128)
    ref = fops.flash_attention(q, k, v, causal=causal, use_pallas=False)
    tol = 2e-2 if dtype == "bfloat16" else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


def test_flash_matches_model_attention():
    """The kernel agrees with the model's tiled jnp attention end to end."""
    cfg = get_smoke("llama3.2-3b")
    p = A.attn_init(jax.random.PRNGKey(1), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 256, cfg.d_model), jnp.float32)
    y_model, (k, v) = A.causal_attention(p, x, cfg, tile=128)
    # recompute with the kernel on the same projections
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    q = A.apply_rope(q, jnp.arange(256)[None, :], cfg.rope_theta)
    ke = A.expand_kv(A.apply_rope(
        jnp.einsum("bsd,dhk->bshk", x, p["wk"]), jnp.arange(256)[None, :],
        cfg.rope_theta), cfg.n_heads)
    ve = A.expand_kv(jnp.einsum("bsd,dhk->bshk", x, p["wv"]), cfg.n_heads)
    out = fops.flash_attention(q, ke, ve, causal=True, interpret=True,
                               block_q=128, block_k=128)
    y_kernel = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    np.testing.assert_allclose(np.asarray(y_kernel), np.asarray(y_model),
                               rtol=2e-4, atol=2e-5)
