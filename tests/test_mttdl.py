"""MTTDL model (paper §4.8) + measured vulnerable stripes vs update period."""
import jax
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.core import RedundancyConfig, RedundancyEngine, mttdl
from repro.core.engine import ALL
from repro.data import SyntheticPipeline
from repro.models import build_model
from repro.models.config import ShapeConfig
from repro.optim import AdamW
from repro.train import Trainer, protected_structs


def test_formulas():
    # paper: MTTDL_NoRed = MTTF/P ; MTTDL_Vilamb = MTTF/(V*N); uplift = P/(V*N)
    assert mttdl.mttdl_no_red(1e6, 1000) == 1e3
    assert mttdl.mttdl_vilamb(1e6, 10, 5) == 2e4
    assert mttdl.mttdl_uplift(1000, 10, 5) == 20.0
    assert mttdl.mttdl_uplift(1000, 0, 5) == float("inf")


def test_uplift_decreases_with_period():
    """Paper §4.8: longer update periods leave more vulnerable stripes ->
    lower MTTDL uplift. Measured on a real (sparse-update) workload."""
    cfg = get_smoke("qwen3-moe-235b-a22b")
    m = build_model(cfg)
    opt = AdamW(lr=lambda s: 1e-3)
    uplifts = {}
    for period in (1, 4):
        p0 = jax.eval_shape(m.init, jax.random.PRNGKey(0))
        o0 = jax.eval_shape(opt.init, p0)
        eng = RedundancyEngine(protected_structs(p0, o0),
                               RedundancyConfig(mode="vilamb", lanes_per_block=128,
                                                period_steps=period))
        tr = Trainer(model=m, opt=opt, engine=eng, mode="vilamb", period_steps=period)
        st = tr.init_state(jax.random.PRNGKey(0))
        data = SyntheticPipeline(cfg, ShapeConfig("t", 32, 4, "train"), seed=0)
        trace = []
        def snap(s, _):
            trace.append(jax.tree.map(int, eng.dirty_stats(s.red)))
        st = tr.run(st, data, 6, on_step=snap)
        avg = mttdl.average_stats(trace)
        uplifts[period] = mttdl.aggregate_uplift(avg, cfg.n_experts and 4 or 4)
    assert uplifts[1] >= uplifts[4]
    assert uplifts[1] > 1.0
