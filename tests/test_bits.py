"""Packed dirty-bitvector properties (paper §3.2 metadata)."""
import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container lacks hypothesis: deterministic shim
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core import bits


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 200), st.data())
def test_pack_unpack_roundtrip(n, data):
    mask = np.array(data.draw(st.lists(st.booleans(), min_size=n, max_size=n)))
    words = bits.pack_mask(jnp.asarray(mask))
    back = np.asarray(bits.unpack(words, n))
    np.testing.assert_array_equal(back, mask)
    assert int(bits.popcount(words)) == int(mask.sum())


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 100), st.data())
def test_mark_is_or(n, data):
    m1 = np.array(data.draw(st.lists(st.booleans(), min_size=n, max_size=n)))
    m2 = np.array(data.draw(st.lists(st.booleans(), min_size=n, max_size=n)))
    w = bits.pack_mask(jnp.asarray(m1))
    w = bits.mark(w, jnp.asarray(m2))
    np.testing.assert_array_equal(np.asarray(bits.unpack(w, n)), m1 | m2)


def test_mark_ids_idempotent_and_ignores_negative():
    w = bits.zeros(70)
    ids = jnp.array([3, 3, 64, -1, -5, 69])
    w = bits.mark_ids(w, 70, ids)
    got = np.asarray(bits.unpack(w, 70))
    want = np.zeros(70, bool)
    want[[3, 64, 69]] = True
    np.testing.assert_array_equal(got, want)


def test_test_bit_and_any():
    w = bits.zeros(40)
    assert not bool(bits.any_set(w))
    w = bits.mark_ids(w, 40, jnp.array([33]))
    assert bool(bits.test_bit(w, 33))
    assert not bool(bits.test_bit(w, 32))
    assert bool(bits.any_set(w))
