"""Minimal deterministic stand-in for ``hypothesis`` when it is missing.

The container image does not always ship hypothesis; rather than skip the
property tests entirely, this shim replays each ``@given`` body over a
fixed number of seeded-random examples.  It implements exactly the subset
this repo's tests use: ``given``, ``settings(max_examples=, deadline=)``,
and the ``integers`` / ``booleans`` / ``lists`` / ``sampled_from`` /
``data`` strategies.  No shrinking, no database — property *coverage* is
weaker than real hypothesis, but the invariants still execute end to end.

Usage (at the top of a test module):

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        from _hypothesis_fallback import given, settings, strategies as st
"""
from __future__ import annotations

import functools
import inspect
import types

import numpy as np

_DEFAULT_EXAMPLES = 10


class _Strategy:
    def __init__(self, draw):
        self._draw = draw


def integers(min_value, max_value):
    return _Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))


def booleans():
    return _Strategy(lambda rng: bool(rng.integers(0, 2)))


def sampled_from(options):
    options = list(options)
    return _Strategy(lambda rng: options[int(rng.integers(len(options)))])


def lists(elements: _Strategy, min_size=0, max_size=10):
    def draw(rng):
        n = int(rng.integers(min_size, max_size + 1))
        return [elements._draw(rng) for _ in range(n)]
    return _Strategy(draw)


class _Data:
    """Interactive draws sharing the example's RNG stream."""

    def __init__(self, rng):
        self._rng = rng

    def draw(self, strategy: _Strategy):
        return strategy._draw(self._rng)


def data():
    return _Strategy(lambda rng: _Data(rng))


def given(*strategies):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_max_examples", _DEFAULT_EXAMPLES)
            for i in range(n):
                rng = np.random.default_rng(0xC0FFEE + i)
                drawn = [s._draw(rng) for s in strategies]
                fn(*args, *drawn, **kwargs)
        # Hide the parameterized signature from pytest's fixture resolution
        # (real hypothesis does the same): the wrapper takes no arguments.
        del wrapper.__wrapped__
        wrapper.__signature__ = inspect.Signature()
        return wrapper
    return deco


def settings(max_examples: int = _DEFAULT_EXAMPLES, deadline=None, **_ignored):
    def deco(fn):
        fn._max_examples = max_examples
        return fn
    return deco


strategies = types.SimpleNamespace(
    integers=integers, booleans=booleans, lists=lists,
    sampled_from=sampled_from, data=data)
