import os
import random
import sys

# Tests must see exactly ONE device (the dry-run sets its own flags in a
# subprocess); keep heavy compile knobs off.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# ----------------------------------------------------------------- seeding
# Every source of randomness is seeded from one knob so any failure —
# including the fault-injection battery — reproduces from the seed printed
# in the pytest header:  REPRO_TEST_SEED=<n> python -m pytest ...
SEED = int(os.environ.get("REPRO_TEST_SEED", "0"))
random.seed(SEED)
np.random.seed(SEED)

try:  # real hypothesis: pin a derandomized profile so CI runs are replayable
    from hypothesis import HealthCheck, settings as hp_settings

    hp_settings.register_profile(
        "repro",
        derandomize=True,
        deadline=None,
        suppress_health_check=list(HealthCheck),
        print_blob=True,
    )
    hp_settings.load_profile("repro")
except ImportError:  # the bundled fallback shim is deterministic already
    pass


def pytest_report_header(config):
    return (f"repro seed: REPRO_TEST_SEED={SEED} "
            "(numpy/random/jax fixtures + hypothesis profile)")


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(SEED)


@pytest.fixture()
def fresh_rng():
    """Per-test generator — same stream every run for a given SEED."""
    return np.random.default_rng(SEED)


@pytest.fixture()
def jax_key():
    """Seeded JAX PRNG key; split, never reuse, for deterministic tests."""
    return jax.random.PRNGKey(SEED)


def tiny_batch(cfg, B=2, S=32, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    b = {}
    S_txt = S
    if cfg.frontend == "vision":
        S_txt = S - cfg.frontend_len
        b["frontend"] = jax.random.normal(ks[2], (B, cfg.frontend_len, cfg.d_model), jnp.float32)
    if cfg.enc_dec:
        b["enc_input"] = jax.random.normal(ks[3], (B, S // 2, cfg.d_model), jnp.float32)
        S_txt = S // 2
    b["tokens"] = jax.random.randint(ks[0], (B, S_txt), 0, cfg.vocab_size, jnp.int32)
    b["labels"] = jax.random.randint(ks[1], (B, S_txt), 0, cfg.vocab_size, jnp.int32)
    return b


def fp32_exact(cfg):
    """fp32 + no-drop MoE capacity: paths must agree bit-tightly."""
    kw = {"param_dtype": "float32"}
    if cfg.n_experts:
        kw["capacity_factor"] = float(cfg.n_experts)
    return dataclasses.replace(cfg, **kw)
