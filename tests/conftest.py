import os
import sys

# Tests must see exactly ONE device (the dry-run sets its own flags in a
# subprocess); keep heavy compile knobs off.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def tiny_batch(cfg, B=2, S=32, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    b = {}
    S_txt = S
    if cfg.frontend == "vision":
        S_txt = S - cfg.frontend_len
        b["frontend"] = jax.random.normal(ks[2], (B, cfg.frontend_len, cfg.d_model), jnp.float32)
    if cfg.enc_dec:
        b["enc_input"] = jax.random.normal(ks[3], (B, S // 2, cfg.d_model), jnp.float32)
        S_txt = S // 2
    b["tokens"] = jax.random.randint(ks[0], (B, S_txt), 0, cfg.vocab_size, jnp.int32)
    b["labels"] = jax.random.randint(ks[1], (B, S_txt), 0, cfg.vocab_size, jnp.int32)
    return b


def fp32_exact(cfg):
    """fp32 + no-drop MoE capacity: paths must agree bit-tightly."""
    kw = {"param_dtype": "float32"}
    if cfg.n_experts:
        kw["capacity_factor"] = float(cfg.n_experts)
    return dataclasses.replace(cfg, **kw)
