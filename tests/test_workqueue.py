"""Work-queue compaction path (core/workqueue.py): bitwise identity with the
reference Algorithm-1 update, overflow dispatch, partial-stripe padding,
incremental meta-checksums, and the segment-XOR sync row path."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container lacks hypothesis: deterministic shim
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core import (ALL, ProtectedStore, RedundancyConfig,
                        RedundancyEngine, RedundancyPolicy, bits, checksum,
                        workqueue)
from repro.core import blocks as B

RED_FIELDS = ("checksums", "parity", "dirty", "shadow", "meta_ck")


def _mk(frac=0.5, seed=0):
    """24x200 f32 leaf: 38 blocks, 10 stripes (last one partial: 2 blocks)."""
    leaves = {
        "w": jax.random.normal(jax.random.PRNGKey(seed), (24, 200), jnp.float32),
        "e": jax.random.normal(jax.random.PRNGKey(seed + 1), (16, 64), jnp.bfloat16),
    }
    structs = {k: jax.ShapeDtypeStruct(v.shape, v.dtype) for k, v in leaves.items()}
    eng = RedundancyEngine(structs, RedundancyConfig(
        lanes_per_block=128, stripe_data_blocks=4, work_queue_frac=frac))
    return eng, leaves


def _assert_red_equal(a, b):
    for k in a:
        for f in RED_FIELDS:
            np.testing.assert_array_equal(
                np.asarray(getattr(a[k], f)), np.asarray(getattr(b[k], f)),
                err_msg=f"{k}.{f}")


def test_queue_capacity_derivation():
    eng, _ = _mk(frac=0.5)
    assert eng.metas["w"].n_stripes == 10
    assert eng.queue_capacity("w") == 5           # ceil(10 * 0.5)
    assert eng.queue_capacity("e") == 0           # 1 stripe: queue pointless
    assert eng.has_queue
    eng_off, _ = _mk(frac=0.0)
    assert not eng_off.has_queue


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_queued_bitwise_identical_random_masks(seed):
    """Compacted and reference redundancy_step agree bitwise for random
    dirty block masks that fit the queue (incl. the padded last stripe)."""
    eng, leaves = _mk(frac=0.5)
    red = eng.init(leaves)
    rng = np.random.default_rng(seed)
    # <= 5 dirty stripes on w (fits capacity 5); random row events on e
    # (capacity 0 there: always full path, must still agree)
    stripes = rng.choice(10, size=rng.integers(0, 6), replace=False)
    bmask = np.zeros((38,), bool)
    for s in stripes:
        blks = np.arange(s * 4, min((s + 1) * 4, 38))
        bmask[rng.choice(blks, size=rng.integers(1, len(blks) + 1),
                         replace=False)] = True
    red = eng.mark_dirty(red, {"e": jnp.asarray(rng.random(16) < 0.3)})
    red = {"w": dataclasses.replace(
        red["w"], dirty=bits.mark(red["w"].dirty, jnp.asarray(bmask))),
        "e": red["e"]}
    leaves2 = {k: v + 1 for k, v in leaves.items()}
    assert eng.queue_fits(red)
    _assert_red_equal(eng.redundancy_step_queued(leaves2, red),
                      eng.redundancy_step(leaves2, red))


def test_partial_last_stripe_queued():
    """Dirty bits in the padded last stripe (2 of 4 member blocks exist)."""
    eng, leaves = _mk(frac=0.5)
    red = eng.init(leaves)
    bmask = jnp.zeros((38,), bool).at[jnp.array([36, 37])].set(True)
    red = {"w": dataclasses.replace(
        red["w"], dirty=bits.mark(red["w"].dirty, bmask)), "e": red["e"]}
    # mutate only data inside the marked blocks (elem 4750 -> lane 4750
    # -> block 37), so clean blocks stay scrub-consistent
    leaves2 = dict(leaves, w=leaves["w"].at[23, 150].add(2.0))
    assert eng.queue_fits(red)
    out_q = eng.redundancy_step_queued(leaves2, red)
    _assert_red_equal(out_q, eng.redundancy_step(leaves2, red))
    # postcondition: scrub-clean and verifiable meta
    assert all(int(v.sum()) == 0 for v in eng.scrub(leaves2, out_q).values())
    assert all(bool(v) for v in eng.verify_meta(out_q).values())


def test_queue_overflow_detected_and_full_fallback():
    """fits==False past capacity; the store then dispatches the reference
    program, so state stays bitwise-identical to a no-queue engine."""
    eng, leaves = _mk(frac=0.5)
    red = eng.init(leaves)
    red_all = eng.mark_dirty(red, {"w": ALL, "e": ALL})
    assert not eng.queue_fits(red_all)            # 10 stripes > capacity 5
    # boundary: exactly capacity stripes still fits
    bmask = jnp.zeros((38,), bool).at[jnp.arange(5) * 4].set(True)
    red_fit = {"w": dataclasses.replace(
        red["w"], dirty=bits.mark(red["w"].dirty, bmask)), "e": red["e"]}
    assert eng.queue_fits(red_fit)

    pol_q = RedundancyPolicy.single("vilamb", period_steps=1,
                                    lanes_per_block=128, work_queue_frac=0.5)
    pol_f = RedundancyPolicy.single("vilamb", period_steps=1,
                                    lanes_per_block=128, work_queue_frac=0.0)
    leaves2 = {k: v + 3 for k, v in leaves.items()}
    outs = []
    for pol in (pol_q, pol_f):
        store = ProtectedStore(pol).attach(leaves)
        r0 = store.init(leaves)
        r0 = store.on_write(r0, events={"w": ALL, "e": ALL})  # overflow
        r1, rep = store.tick(leaves2, r0, 1)
        assert rep.updated
        # settle adopts the overlapped dispatch (and would repair a
        # speculative overflow via the full fallback) before comparing
        outs.append(store.settle(r1, leaves2))
    _assert_red_equal(outs[0], outs[1])


def test_store_tick_dispatches_queued_and_matches_reference():
    """Sparse dirty state through store.tick (speculative queued dispatch
    once the fit signal resolves) must equal a work-queue-disabled store
    byte for byte."""
    _, leaves = _mk()
    ev = jnp.zeros((24,), bool).at[jnp.array([0, 7])].set(True)
    outs = []
    for frac in (0.5, 0.0):
        pol = RedundancyPolicy.single("vilamb", period_steps=1,
                                      lanes_per_block=128,
                                      work_queue_frac=frac)
        store = ProtectedStore(pol).attach(leaves)
        r0 = store.init(leaves)
        lv = dict(leaves)
        # two rounds: the pessimistic first dispatch goes full and resolves
        # the fit signal; the second round then speculates queued
        for step in (1, 2):
            r0 = store.on_write(r0, events={"w": ev})
            # only the marked rows change (dirty tracking covers every write)
            lv = dict(lv, w=lv["w"].at[jnp.array([0, 7])].add(-0.5 * step))
            r1, rep = store.tick(lv, r0, step)
            assert rep.updated
            r0 = r1
            # deterministic resolution timing (joins the launch thread too)
            store.sync_inflight()
        if frac > 0:
            g = next(iter(store.groups.values()))
            if store.policy.async_tick:    # overlap: speculation went queued
                assert g.pending is not None and g.pending.queued
            else:                          # blocking: exact fit check agreed
                assert g.predicted_fits
        r1 = store.settle(r1, lv)
        outs.append(r1)
        assert sum(int(v.sum()) for v in store.scrub(lv, r1).values()) == 0
    _assert_red_equal(outs[0], outs[1])


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_incremental_meta_checksum_matches_full(seed):
    """meta ^ meta_checksum_delta(changed) == full rehash, bitwise."""
    rng = np.random.default_rng(seed)
    n = int(rng.integers(1, 200))
    cks = jnp.asarray(rng.integers(0, 2**32, size=n, dtype=np.uint32))
    k = int(rng.integers(0, n + 1))
    idx = rng.choice(n, size=k, replace=False).astype(np.int32)
    new_vals = jnp.asarray(rng.integers(0, 2**32, size=k, dtype=np.uint32))
    cks2 = cks.at[jnp.asarray(idx)].set(new_vals) if k else cks
    meta0 = checksum.meta_checksum(cks)
    delta = checksum.meta_checksum_delta(
        cks[jnp.asarray(idx)], new_vals, jnp.asarray(idx)) if k else jnp.uint32(0)
    np.testing.assert_array_equal(
        np.asarray(meta0 ^ delta), np.asarray(checksum.meta_checksum(cks2)))


def test_sync_update_rows_duplicate_stripe_regression():
    """Unique rows sharing a stripe must XOR-accumulate parity deltas (the
    segment-XOR scatter), matching the dense sync_update oracle — including
    the incremental meta-checksum; order of rows must not matter."""
    heap = jax.random.normal(jax.random.PRNGKey(2), (16, 32), jnp.float32)
    eng = RedundancyEngine(
        {"h": jax.ShapeDtypeStruct(heap.shape, heap.dtype)},
        RedundancyConfig(mode="sync", lanes_per_block=32, stripe_data_blocks=4))
    red = eng.init({"h": heap})
    for rows in ([0, 1, 2, 9], [9, 2, 0, 1], [4, 5, 6, 7], [15]):
        rows = jnp.asarray(rows, jnp.int32)
        new_rows = heap[rows] + 3.0
        new_heap = heap.at[rows].set(new_rows)
        got = eng.sync_update_rows("h", red["h"], rows, heap[rows], new_rows)
        want = eng.sync_update({"h": heap}, {"h": new_heap}, red)["h"]
        np.testing.assert_array_equal(np.asarray(got.checksums),
                                      np.asarray(want.checksums))
        np.testing.assert_array_equal(np.asarray(got.parity),
                                      np.asarray(want.parity))
        np.testing.assert_array_equal(np.asarray(got.meta_ck),
                                      np.asarray(want.meta_ck))


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_row_mask_block_mask_matches_nonzero_oracle(seed):
    """mark_dirty's direct row->block reduction == nonzero + row_block_mask
    across straddling and packed row geometries."""
    rng = np.random.default_rng(seed)
    for shape, lanes in (((24, 200), 128), ((16, 64), 128), ((7, 130), 128),
                         ((5, 7, 11), 64), ((64, 32), 128)):
        meta = B.make_meta(jax.ShapeDtypeStruct(shape, jnp.float32),
                           lanes_per_block=lanes, stripe_data_blocks=4)
        m = rng.random(shape[0]) < rng.random()
        got = B.row_mask_block_mask(meta, jnp.asarray(m), row_dims=1)
        ids = (jnp.asarray(np.flatnonzero(m).astype(np.int32))
               if m.any() else jnp.asarray([-1], jnp.int32))
        want = B.row_block_mask(meta, ids, row_dims=1)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want),
                                      err_msg=f"{shape} lanes={lanes}")


def test_compact_stripe_ids_contract():
    sd = jnp.asarray([False, True, False, True, True, False])
    ids, count, overflow = workqueue.compact_stripe_ids(sd, 4)
    assert ids.tolist() == [1, 3, 4, 6] and int(count) == 3 and not bool(overflow)
    ids, count, overflow = workqueue.compact_stripe_ids(sd, 2)
    assert int(count) == 3 and bool(overflow)
    # kernel convention: pad by repeating the last live id
    ids, count, _ = workqueue.compact_stripe_ids(sd, 6, pad_repeat_last=True)
    assert ids.tolist() == [1, 3, 4, 4, 4, 4]


# Adversarial payloads: float32 NaN/Inf bit patterns and saturated words.
# The redundancy path is pure bit manipulation — special float values must
# round-trip bitwise and never weaken detection.
SPECIALS = np.array([0x7FC00000, 0x7F800000, 0xFF800000, 0x7F800001,
                     0x00000000, 0xFFFFFFFF], dtype=np.uint32)


def test_nan_inf_payloads_bitwise_identical_and_detected():
    """NaN/Inf-laden leaves: queued == full bitwise, scrub stays clean, and
    a single-bit NaN->Inf flip on a clean block is still caught."""
    eng, leaves = _mk(frac=0.5)
    shape = leaves["w"].shape
    pattern = SPECIALS[np.arange(np.prod(shape)) % len(SPECIALS)]
    leaves = dict(leaves, w=jnp.asarray(
        pattern.reshape(shape).view(np.float32)))
    red = eng.init(leaves)
    bmask = jnp.zeros((38,), bool).at[jnp.array([0, 1, 17])].set(True)
    red = {"w": dataclasses.replace(
        red["w"], dirty=bits.mark(red["w"].dirty, bmask)), "e": red["e"]}
    # overwrite the dirty blocks with a *different* special pattern
    meta = eng.metas["w"]
    lanes = B.to_lanes(leaves["w"], meta)
    rolled = jnp.asarray(np.roll(SPECIALS, 1)[
        np.arange(meta.lanes_per_block) % len(SPECIALS)].astype(np.uint32))
    for b in (0, 1, 17):
        lanes = lanes.at[b].set(rolled)
    leaves2 = dict(leaves, w=B.from_lanes(lanes, meta))
    assert eng.queue_fits(red)
    out_q = eng.redundancy_step_queued(leaves2, red)
    _assert_red_equal(out_q, eng.redundancy_step(leaves2, red))
    assert all(int(v.sum()) == 0 for v in eng.scrub(leaves2, out_q).values())
    # NaN (0x7FC00000) -> +Inf (0x7F800000) is one bit (22) on a clean block
    corrupt = B.from_lanes(
        B.to_lanes(leaves2["w"], meta).at[20, 4].set(
            B.to_lanes(leaves2["w"], meta)[20, 4] ^ jnp.uint32(1 << 22)),
        meta)
    mm = eng.scrub(dict(leaves2, w=corrupt), out_q)
    assert np.flatnonzero(np.asarray(mm["w"])).tolist() == [20]


def test_zero_dirty_update_is_bitwise_noop():
    """Zero dirty bits: both Algorithm-1 variants and a due store tick must
    leave every redundancy field bitwise untouched (sentinel-only queues)."""
    eng, leaves = _mk(frac=0.5)
    red = eng.init(leaves)
    _assert_red_equal(eng.redundancy_step(leaves, red), red)
    _assert_red_equal(eng.redundancy_step_queued(leaves, red), red)
    for async_on in (True, False):
        pol = RedundancyPolicy.single(
            "vilamb", period_steps=1, lanes_per_block=128,
            work_queue_frac=0.5, async_tick=async_on)
        store = ProtectedStore(pol).attach(leaves)
        r0 = store.init(leaves)
        r0_host = jax.tree.map(np.asarray, r0)  # blocking tick donates r0
        r1, rep = store.tick(leaves, r0, 1)     # due, nothing dirty
        assert rep.updated
        _assert_red_equal(store.settle(r1, leaves), r0_host)


def test_exactly_at_capacity_queue_including_partial_stripe():
    """Dirty stripes == capacity exactly, with the sentinel-adjacent last
    (partial, 2-block) stripe in the set: queued must match full bitwise."""
    eng, leaves = _mk(frac=0.5)
    assert eng.queue_capacity("w") == 5
    red = eng.init(leaves)
    # stripes {0, 3, 5, 7, 9}; 9 is the partial last stripe (blocks 36, 37)
    blks = jnp.array([0, 12, 20, 28, 36, 37])
    bmask = jnp.zeros((38,), bool).at[blks].set(True)
    red = {"w": dataclasses.replace(
        red["w"], dirty=bits.mark(red["w"].dirty, bmask)), "e": red["e"]}
    meta = eng.metas["w"]
    lanes = B.to_lanes(leaves["w"], meta)
    for b in [0, 12, 20, 28, 36, 37]:
        lanes = lanes.at[b, 0].add(jnp.uint32(b + 1))
    leaves2 = dict(leaves, w=B.from_lanes(lanes, meta))
    assert eng.queue_fits(red)
    out_q = eng.redundancy_step_queued(leaves2, red)
    _assert_red_equal(out_q, eng.redundancy_step(leaves2, red))
    assert all(int(v.sum()) == 0 for v in eng.scrub(leaves2, out_q).values())
    # one more stripe is one too many
    over = {"w": dataclasses.replace(
        red["w"], dirty=bits.mark(red["w"].dirty,
                                  jnp.zeros((38,), bool).at[4].set(True))),
        "e": red["e"]}
    assert not eng.queue_fits(over)


def test_sentinel_colliding_ids_drop_not_wrap():
    """ids equal to the sentinel (n_stripes / n_blocks) must be dropped by
    every scatter — never wrap around or clobber stripe 0."""
    from repro.core import parity
    par = jnp.arange(12, dtype=jnp.uint32).reshape(3, 4)
    deltas = jnp.full((2, 4), 0xFFFFFFFF, jnp.uint32)
    out = parity.scatter_xor_stripes(
        par, jnp.asarray([3, 3], jnp.int32), deltas)   # 3 == ns sentinel
    np.testing.assert_array_equal(np.asarray(out), np.asarray(par))
    # queued_update with an all-sentinel queue over special-value lanes
    lanes = jnp.asarray(SPECIALS[np.arange(8 * 128) % len(SPECIALS)]
                        .reshape(8, 128))
    old_cks = checksum.block_checksums(lanes)
    old_par = jnp.zeros((2, 128), jnp.uint32)
    ids = jnp.full((4,), 2, jnp.int32)                 # 2 == n_stripes here
    cks, par2, meta = workqueue.queued_update(
        lanes, old_cks, old_par, checksum.meta_checksum(old_cks),
        jnp.zeros((8,), bool), ids, 4)
    np.testing.assert_array_equal(np.asarray(cks), np.asarray(old_cks))
    np.testing.assert_array_equal(np.asarray(par2), np.asarray(old_par))
    np.testing.assert_array_equal(
        np.asarray(meta), np.asarray(checksum.meta_checksum(old_cks)))


def test_queued_preserves_scrub_detection():
    """After a queued pass, corruption of a *clean* block is still caught —
    checksums of untouched blocks must not be disturbed by the scatter."""
    eng, leaves = _mk(frac=0.5)
    red = eng.init(leaves)
    red = eng.mark_dirty(red, {"w": jnp.zeros((24,), bool).at[0].set(True)})
    leaves2 = dict(leaves, w=leaves["w"].at[0, 0].add(1.0))
    red = eng.redundancy_step_queued(leaves2, red)
    meta = eng.metas["w"]
    lanes = B.to_lanes(leaves2["w"], meta)
    corrupted = B.from_lanes(lanes.at[20, 3].add(99), meta)
    mm = eng.scrub(dict(leaves2, w=corrupted), red)
    assert np.flatnonzero(np.asarray(mm["w"])).tolist() == [20]
