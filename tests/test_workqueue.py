"""Work-queue compaction path (core/workqueue.py): bitwise identity with the
reference Algorithm-1 update, overflow dispatch, partial-stripe padding,
incremental meta-checksums, and the segment-XOR sync row path."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container lacks hypothesis: deterministic shim
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core import (ALL, ProtectedStore, RedundancyConfig,
                        RedundancyEngine, RedundancyPolicy, bits, checksum,
                        workqueue)
from repro.core import blocks as B

RED_FIELDS = ("checksums", "parity", "dirty", "shadow", "meta_ck")


def _mk(frac=0.5, seed=0):
    """24x200 f32 leaf: 38 blocks, 10 stripes (last one partial: 2 blocks)."""
    leaves = {
        "w": jax.random.normal(jax.random.PRNGKey(seed), (24, 200), jnp.float32),
        "e": jax.random.normal(jax.random.PRNGKey(seed + 1), (16, 64), jnp.bfloat16),
    }
    structs = {k: jax.ShapeDtypeStruct(v.shape, v.dtype) for k, v in leaves.items()}
    eng = RedundancyEngine(structs, RedundancyConfig(
        lanes_per_block=128, stripe_data_blocks=4, work_queue_frac=frac))
    return eng, leaves


def _assert_red_equal(a, b):
    for k in a:
        for f in RED_FIELDS:
            np.testing.assert_array_equal(
                np.asarray(getattr(a[k], f)), np.asarray(getattr(b[k], f)),
                err_msg=f"{k}.{f}")


def test_queue_capacity_derivation():
    eng, _ = _mk(frac=0.5)
    assert eng.metas["w"].n_stripes == 10
    assert eng.queue_capacity("w") == 5           # ceil(10 * 0.5)
    assert eng.queue_capacity("e") == 0           # 1 stripe: queue pointless
    assert eng.has_queue
    eng_off, _ = _mk(frac=0.0)
    assert not eng_off.has_queue


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_queued_bitwise_identical_random_masks(seed):
    """Compacted and reference redundancy_step agree bitwise for random
    dirty block masks that fit the queue (incl. the padded last stripe)."""
    eng, leaves = _mk(frac=0.5)
    red = eng.init(leaves)
    rng = np.random.default_rng(seed)
    # <= 5 dirty stripes on w (fits capacity 5); random row events on e
    # (capacity 0 there: always full path, must still agree)
    stripes = rng.choice(10, size=rng.integers(0, 6), replace=False)
    bmask = np.zeros((38,), bool)
    for s in stripes:
        blks = np.arange(s * 4, min((s + 1) * 4, 38))
        bmask[rng.choice(blks, size=rng.integers(1, len(blks) + 1),
                         replace=False)] = True
    red = eng.mark_dirty(red, {"e": jnp.asarray(rng.random(16) < 0.3)})
    red = {"w": dataclasses.replace(
        red["w"], dirty=bits.mark(red["w"].dirty, jnp.asarray(bmask))),
        "e": red["e"]}
    leaves2 = {k: v + 1 for k, v in leaves.items()}
    assert eng.queue_fits(red)
    _assert_red_equal(eng.redundancy_step_queued(leaves2, red),
                      eng.redundancy_step(leaves2, red))


def test_partial_last_stripe_queued():
    """Dirty bits in the padded last stripe (2 of 4 member blocks exist)."""
    eng, leaves = _mk(frac=0.5)
    red = eng.init(leaves)
    bmask = jnp.zeros((38,), bool).at[jnp.array([36, 37])].set(True)
    red = {"w": dataclasses.replace(
        red["w"], dirty=bits.mark(red["w"].dirty, bmask)), "e": red["e"]}
    # mutate only data inside the marked blocks (elem 4750 -> lane 4750
    # -> block 37), so clean blocks stay scrub-consistent
    leaves2 = dict(leaves, w=leaves["w"].at[23, 150].add(2.0))
    assert eng.queue_fits(red)
    out_q = eng.redundancy_step_queued(leaves2, red)
    _assert_red_equal(out_q, eng.redundancy_step(leaves2, red))
    # postcondition: scrub-clean and verifiable meta
    assert all(int(v.sum()) == 0 for v in eng.scrub(leaves2, out_q).values())
    assert all(bool(v) for v in eng.verify_meta(out_q).values())


def test_queue_overflow_detected_and_full_fallback():
    """fits==False past capacity; the store then dispatches the reference
    program, so state stays bitwise-identical to a no-queue engine."""
    eng, leaves = _mk(frac=0.5)
    red = eng.init(leaves)
    red_all = eng.mark_dirty(red, {"w": ALL, "e": ALL})
    assert not eng.queue_fits(red_all)            # 10 stripes > capacity 5
    # boundary: exactly capacity stripes still fits
    bmask = jnp.zeros((38,), bool).at[jnp.arange(5) * 4].set(True)
    red_fit = {"w": dataclasses.replace(
        red["w"], dirty=bits.mark(red["w"].dirty, bmask)), "e": red["e"]}
    assert eng.queue_fits(red_fit)

    pol_q = RedundancyPolicy.single("vilamb", period_steps=1,
                                    lanes_per_block=128, work_queue_frac=0.5)
    pol_f = RedundancyPolicy.single("vilamb", period_steps=1,
                                    lanes_per_block=128, work_queue_frac=0.0)
    leaves2 = {k: v + 3 for k, v in leaves.items()}
    outs = []
    for pol in (pol_q, pol_f):
        store = ProtectedStore(pol).attach(leaves)
        r0 = store.init(leaves)
        r0 = store.on_write(r0, events={"w": ALL, "e": ALL})  # overflow
        r1, rep = store.tick(leaves2, r0, 1)
        assert rep.updated
        # settle adopts the overlapped dispatch (and would repair a
        # speculative overflow via the full fallback) before comparing
        outs.append(store.settle(r1, leaves2))
    _assert_red_equal(outs[0], outs[1])


def test_store_tick_dispatches_queued_and_matches_reference():
    """Sparse dirty state through store.tick (speculative queued dispatch
    once the fit signal resolves) must equal a work-queue-disabled store
    byte for byte."""
    _, leaves = _mk()
    ev = jnp.zeros((24,), bool).at[jnp.array([0, 7])].set(True)
    outs = []
    for frac in (0.5, 0.0):
        pol = RedundancyPolicy.single("vilamb", period_steps=1,
                                      lanes_per_block=128,
                                      work_queue_frac=frac)
        store = ProtectedStore(pol).attach(leaves)
        r0 = store.init(leaves)
        lv = dict(leaves)
        # two rounds: the pessimistic first dispatch goes full and resolves
        # the fit signal; the second round then speculates queued
        for step in (1, 2):
            r0 = store.on_write(r0, events={"w": ev})
            # only the marked rows change (dirty tracking covers every write)
            lv = dict(lv, w=lv["w"].at[jnp.array([0, 7])].add(-0.5 * step))
            r1, rep = store.tick(lv, r0, step)
            assert rep.updated
            r0 = r1
            g = next(iter(store.groups.values()))
            if g.pending is not None:   # deterministic resolution timing
                jax.block_until_ready(g.pending.fits)
        if frac > 0:
            g = next(iter(store.groups.values()))
            assert g.pending is not None and g.pending.queued
        r1 = store.settle(r1, lv)
        outs.append(r1)
        assert sum(int(v.sum()) for v in store.scrub(lv, r1).values()) == 0
    _assert_red_equal(outs[0], outs[1])


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_incremental_meta_checksum_matches_full(seed):
    """meta ^ meta_checksum_delta(changed) == full rehash, bitwise."""
    rng = np.random.default_rng(seed)
    n = int(rng.integers(1, 200))
    cks = jnp.asarray(rng.integers(0, 2**32, size=n, dtype=np.uint32))
    k = int(rng.integers(0, n + 1))
    idx = rng.choice(n, size=k, replace=False).astype(np.int32)
    new_vals = jnp.asarray(rng.integers(0, 2**32, size=k, dtype=np.uint32))
    cks2 = cks.at[jnp.asarray(idx)].set(new_vals) if k else cks
    meta0 = checksum.meta_checksum(cks)
    delta = checksum.meta_checksum_delta(
        cks[jnp.asarray(idx)], new_vals, jnp.asarray(idx)) if k else jnp.uint32(0)
    np.testing.assert_array_equal(
        np.asarray(meta0 ^ delta), np.asarray(checksum.meta_checksum(cks2)))


def test_sync_update_rows_duplicate_stripe_regression():
    """Unique rows sharing a stripe must XOR-accumulate parity deltas (the
    segment-XOR scatter), matching the dense sync_update oracle — including
    the incremental meta-checksum; order of rows must not matter."""
    heap = jax.random.normal(jax.random.PRNGKey(2), (16, 32), jnp.float32)
    eng = RedundancyEngine(
        {"h": jax.ShapeDtypeStruct(heap.shape, heap.dtype)},
        RedundancyConfig(mode="sync", lanes_per_block=32, stripe_data_blocks=4))
    red = eng.init({"h": heap})
    for rows in ([0, 1, 2, 9], [9, 2, 0, 1], [4, 5, 6, 7], [15]):
        rows = jnp.asarray(rows, jnp.int32)
        new_rows = heap[rows] + 3.0
        new_heap = heap.at[rows].set(new_rows)
        got = eng.sync_update_rows("h", red["h"], rows, heap[rows], new_rows)
        want = eng.sync_update({"h": heap}, {"h": new_heap}, red)["h"]
        np.testing.assert_array_equal(np.asarray(got.checksums),
                                      np.asarray(want.checksums))
        np.testing.assert_array_equal(np.asarray(got.parity),
                                      np.asarray(want.parity))
        np.testing.assert_array_equal(np.asarray(got.meta_ck),
                                      np.asarray(want.meta_ck))


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_row_mask_block_mask_matches_nonzero_oracle(seed):
    """mark_dirty's direct row->block reduction == nonzero + row_block_mask
    across straddling and packed row geometries."""
    rng = np.random.default_rng(seed)
    for shape, lanes in (((24, 200), 128), ((16, 64), 128), ((7, 130), 128),
                         ((5, 7, 11), 64), ((64, 32), 128)):
        meta = B.make_meta(jax.ShapeDtypeStruct(shape, jnp.float32),
                           lanes_per_block=lanes, stripe_data_blocks=4)
        m = rng.random(shape[0]) < rng.random()
        got = B.row_mask_block_mask(meta, jnp.asarray(m), row_dims=1)
        ids = (jnp.asarray(np.flatnonzero(m).astype(np.int32))
               if m.any() else jnp.asarray([-1], jnp.int32))
        want = B.row_block_mask(meta, ids, row_dims=1)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want),
                                      err_msg=f"{shape} lanes={lanes}")


def test_compact_stripe_ids_contract():
    sd = jnp.asarray([False, True, False, True, True, False])
    ids, count, overflow = workqueue.compact_stripe_ids(sd, 4)
    assert ids.tolist() == [1, 3, 4, 6] and int(count) == 3 and not bool(overflow)
    ids, count, overflow = workqueue.compact_stripe_ids(sd, 2)
    assert int(count) == 3 and bool(overflow)
    # kernel convention: pad by repeating the last live id
    ids, count, _ = workqueue.compact_stripe_ids(sd, 6, pad_repeat_last=True)
    assert ids.tolist() == [1, 3, 4, 4, 4, 4]


def test_queued_preserves_scrub_detection():
    """After a queued pass, corruption of a *clean* block is still caught —
    checksums of untouched blocks must not be disturbed by the scatter."""
    eng, leaves = _mk(frac=0.5)
    red = eng.init(leaves)
    red = eng.mark_dirty(red, {"w": jnp.zeros((24,), bool).at[0].set(True)})
    leaves2 = dict(leaves, w=leaves["w"].at[0, 0].add(1.0))
    red = eng.redundancy_step_queued(leaves2, red)
    meta = eng.metas["w"]
    lanes = B.to_lanes(leaves2["w"], meta)
    corrupted = B.from_lanes(lanes.at[20, 3].add(99), meta)
    mm = eng.scrub(dict(leaves2, w=corrupted), red)
    assert np.flatnonzero(np.asarray(mm["w"])).tolist() == [20]
