"""Collective parser + roofline math unit tests."""
import pytest

from repro.launch import hlo_analysis as H

HLO = """
ENTRY %main (p0: f32[8,128]) -> f32[8,128] {
  %ag = f32[64,128]{1,0} all-gather(%p0), replica_groups={{0,1,2,3,4,5,6,7}}, dimensions={0}
  %ar = f32[8,128]{1,0} all-reduce(%p0), replica_groups=[32,8]<=[256]T(1,0), to_apply=%add
  %rs = bf16[4,128]{1,0} reduce-scatter(%x), replica_groups={{0,1}}, dimensions={0}
  %aa = f32[8,128]{1,0} all-to-all(%p0), replica_groups={{0,1,2,3}}
  %cp = u32[16]{0} collective-permute(%p0), source_target_pairs={{0,1}}
  %tup = (f32[8,128]{1,0}, f32[8]{0}) all-reduce(%p0, %p1), replica_groups={{0,1,2,3}}, to_apply=%add
}
"""


def test_parse_collectives():
    st = H.parse_collectives(HLO)
    assert st.per_op_count == {"all-gather": 1, "all-reduce": 2,
                               "reduce-scatter": 1, "all-to-all": 1,
                               "collective-permute": 1}
    ag = 64 * 128 * 4 * 7 / 8
    ar = 2 * 8 * 128 * 4 * 7 / 8
    ar2 = 2 * (8 * 128 * 4 + 8 * 4) * 3 / 4
    rs = 4 * 128 * 2 * 1
    aa = 8 * 128 * 4 * 3 / 4
    cp = 16 * 4
    assert st.per_op["all-gather"] == pytest.approx(ag)
    assert st.per_op["all-reduce"] == pytest.approx(ar + ar2)
    assert st.per_op["reduce-scatter"] == pytest.approx(rs)
    assert st.per_op["all-to-all"] == pytest.approx(aa)
    assert st.per_op["collective-permute"] == pytest.approx(cp)


def test_group_size_forms():
    assert H._group_size("replica_groups={{0,1,2,3}}") == 4
    assert H._group_size("replica_groups=[32,8]<=[256]") == 8
    assert H._group_size("no groups here") == 1


def test_roofline_terms():
    rl = H.roofline_terms(
        flops_per_chip=197e12, bytes_per_chip=819e9,
        coll_bytes_per_chip=25e9, chips=10, model_flops=197e12 * 10)
    assert rl.compute_s == pytest.approx(1.0)
    assert rl.memory_s == pytest.approx(1.0)
    assert rl.collective_s == pytest.approx(0.5)
    assert rl.bottleneck in ("compute", "memory")
    assert rl.useful_ratio == pytest.approx(1.0)
    assert rl.roofline_fraction == pytest.approx(1.0)


def test_shape_bytes_tuple():
    assert H._shape_bytes("(f32[2,2]{1,0}, bf16[4]{0})") == 16 + 8
    assert H._shape_bytes("pred[7]") == 7
