"""Checkpoint layer: atomicity, self-verification, redundancy persistence,
restart-resume equivalence."""
import pathlib

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke
from repro.ckpt import CheckpointManager
from repro.core import RedundancyConfig, RedundancyEngine
from repro.data import SyntheticPipeline
from repro.models import build_model
from repro.models.config import ShapeConfig
from repro.optim import AdamW
from repro.train import Trainer, protected_structs


def _trainer(mode="vilamb"):
    cfg = get_smoke("olmo-1b")
    m = build_model(cfg)
    opt = AdamW(lr=lambda s: 1e-3)
    engine = None
    if mode != "none":
        p0 = jax.eval_shape(m.init, jax.random.PRNGKey(0))
        o0 = jax.eval_shape(opt.init, p0)
        engine = RedundancyEngine(protected_structs(p0, o0),
                                  RedundancyConfig(mode=mode, lanes_per_block=512))
    data = SyntheticPipeline(cfg, ShapeConfig("t", 32, 4, "train"), seed=0)
    return Trainer(model=m, opt=opt, engine=engine, mode=mode, period_steps=2), data


def test_roundtrip_with_redundancy_state(tmp_path):
    tr, data = _trainer()
    st = tr.init_state(jax.random.PRNGKey(0))
    st = tr.run(st, data, 3)
    mgr = CheckpointManager(tmp_path)
    mgr.save(3, st, blocking=True)
    st2 = mgr.restore_into(jax.eval_shape(lambda: st))
    assert st2 is not None
    for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(st2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_restart_resumes_identically(tmp_path):
    """Deterministic pipeline + checkpoint => restarted run is bit-equal."""
    tr, data = _trainer()
    st = tr.init_state(jax.random.PRNGKey(0))
    st = tr.run(st, data, 2)
    mgr = CheckpointManager(tmp_path)
    mgr.save(int(st.step), st, blocking=True)
    # continue original
    st_cont = tr.run(st, data, 2)
    # restart from disk
    tr2, data2 = _trainer()
    st_re = mgr.restore_into(jax.eval_shape(lambda: st))
    st_re = tr2.run(st_re, data2, 2)
    np.testing.assert_array_equal(
        np.asarray(jax.tree.leaves(st_cont.params)[0]),
        np.asarray(jax.tree.leaves(st_re.params)[0]))


def test_corrupt_checkpoint_falls_back(tmp_path):
    tr, data = _trainer(mode="none")
    st = tr.init_state(jax.random.PRNGKey(0))
    mgr = CheckpointManager(tmp_path)
    mgr.save(1, st, blocking=True)
    st = tr.run(st, data, 1)
    mgr.save(2, st, blocking=True)
    # corrupt the newest checkpoint's payload
    npz = pathlib.Path(tmp_path) / "step_2" / "state.npz"
    raw = bytearray(npz.read_bytes())
    raw[len(raw) // 2] ^= 0xFF
    npz.write_bytes(bytes(raw))
    got = mgr.restore_flat()
    assert got is not None
    assert int(got["__step__"]) == 1  # fell back past the corrupted one


def test_async_save(tmp_path):
    tr, data = _trainer(mode="none")
    st = tr.init_state(jax.random.PRNGKey(0))
    mgr = CheckpointManager(tmp_path)
    mgr.save(1, st, blocking=False)
    mgr.wait()
    assert mgr.steps() == [1]


def test_gc_keeps_last_k(tmp_path):
    tr, data = _trainer(mode="none")
    st = tr.init_state(jax.random.PRNGKey(0))
    mgr = CheckpointManager(tmp_path, keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, st, blocking=True)
    assert mgr.steps() == [3, 4]
