"""Serving with Vilamb-protected KV caches: page dirty tracking, periodic
redundancy, scrub cleanliness, and corruption detection on cache pages."""
import jax
import jax.numpy as jnp
import numpy as np

from conftest import tiny_batch
from repro.common import flatten_dict
from repro.configs import get_smoke
from repro.core import RedundancyConfig, RedundancyEngine
from repro.core import bits, blocks as B
from repro.models import build_model
from repro.serve import Server


def _mk(arch="glm4-9b"):
    cfg = get_smoke(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = tiny_batch(cfg, B=2, S=16)
    batch.pop("labels")
    caches0 = jax.eval_shape(lambda: model.init_caches(2, 64, 0))
    eng = RedundancyEngine(
        flatten_dict(caches0),
        RedundancyConfig(mode="vilamb", lanes_per_block=128))
    return cfg, model, params, batch, eng


def test_generate_with_vilamb_clean():
    cfg, model, params, batch, eng = _mk()
    srv = Server(model=model, engine=eng, mode="vilamb", period_steps=4, max_len=64)
    toks, stats = srv.generate(params, batch, 10, scrub_every=3)
    assert toks.shape == (2, 10)
    assert stats["mismatches"] == 0


def test_decode_marks_kv_pages_dirty():
    cfg, model, params, batch, eng = _mk()
    logits, caches, pos = model.prefill(params, batch, 64)
    red = eng.init(flatten_dict(caches))
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    from repro.serve.serve_loop import make_decode_step
    step = make_decode_step(model, eng, "vilamb")
    _, caches2, red2, _ = step(params, caches, red, tok, pos)
    dirty = {k: int(bits.popcount(r.dirty)) for k, r in red2.items()}
    assert sum(dirty.values()) > 0
    # only KV leaves dirtied (glm has attention mixers only)
    for k, n in dirty.items():
        assert n == 0 or k.endswith("/k") or k.endswith("/v")


def test_cache_corruption_detected_and_recovered():
    cfg, model, params, batch, eng = _mk()
    _, caches, _ = model.prefill(params, batch, 64)
    flat = flatten_dict(caches)
    red = eng.init(flat)
    name = next(k for k in flat if k.endswith("/k"))
    meta = eng.metas[name]
    lanes = B.to_lanes(flat[name], meta)
    flat_bad = dict(flat)
    flat_bad[name] = B.from_lanes(lanes.at[1, 3].add(999), meta)
    mm = eng.scrub(flat_bad, red)
    assert int(mm[name].sum()) == 1
    bad = int(np.nonzero(np.asarray(mm[name]))[0][0])
    fixed, ok = eng.recover_block(flat_bad[name], red[name], name, bad)
    assert bool(ok)
    np.testing.assert_array_equal(np.asarray(fixed), np.asarray(flat[name]))
