"""Overlap-pipelined tick (PR3): sync-free speculative dispatch must stay
bitwise-identical to the blocking path — including flush/scrub called while
an update is in flight and speculative queued-vs-full mispredictions — and
the hot path must never pay a device->host round trip."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container lacks hypothesis: deterministic shim
    from _hypothesis_fallback import given, settings, strategies as st

import repro.core.store as store_mod
from repro.core import ALL, ProtectedStore, RedundancyPolicy, bits
from repro.core import blocks as B

RED_FIELDS = ("checksums", "parity", "dirty", "shadow", "meta_ck")


def _leaves(seed=0):
    return {"w": jax.random.normal(jax.random.PRNGKey(seed), (24, 200),
                                   jnp.float32),
            "e": jax.random.normal(jax.random.PRNGKey(seed + 1), (16, 64),
                                   jnp.bfloat16)}


def _store(async_on, period=3, frac=0.5, precompile=True):
    pol = RedundancyPolicy.single(
        "vilamb", period_steps=period, lanes_per_block=128,
        work_queue_frac=frac, async_tick=async_on, precompile=precompile)
    return ProtectedStore(pol).attach(_leaves())


def _assert_red_equal(a, b):
    for k in a:
        for f in RED_FIELDS:
            np.testing.assert_array_equal(
                np.asarray(getattr(a[k], f)), np.asarray(getattr(b[k], f)),
                err_msg=f"{k}.{f}")


def _group(store):
    return next(iter(store.groups.values()))


def _drive(store, leaves, steps, seed=0):
    """Identical write/mark/tick sequence for any store."""
    rng = np.random.default_rng(seed)
    lv = dict(leaves)
    red = store.init(lv)
    for step in range(1, steps + 1):
        rows = rng.choice(24, size=rng.integers(1, 5), replace=False)
        ev = jnp.zeros((24,), bool).at[jnp.asarray(rows)].set(True)
        lv = dict(lv, w=lv["w"].at[jnp.asarray(rows)].add(0.25 * step))
        red = store.on_write(red, events={"w": ev})
        red, _ = store.tick(lv, red, step)
    return lv, red


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_async_end_state_bitwise_identical_to_blocking(seed):
    """Random sparse workloads: settled async state == blocking state."""
    sa, sb = _store(True), _store(False)
    lv_a, red_a = _drive(sa, _leaves(), 9, seed=seed)
    lv_b, red_b = _drive(sb, _leaves(), 9, seed=seed)
    red_a = sa.settle(red_a, lv_a)
    _assert_red_equal(red_a, red_b)
    assert sum(int(v.sum()) for v in sa.scrub(lv_a, red_a).values()) == 0


def test_flush_mid_flight_matches_blocking():
    """flush while an async update is in flight == blocking-path flush."""
    outs = []
    for async_on in (True, False):
        store = _store(async_on, period=2)
        lv = _leaves()
        red = store.init(lv)
        ev = jnp.zeros((24,), bool).at[jnp.array([1, 5])].set(True)
        red = store.on_write(red, events={"w": ev})
        lv = dict(lv, w=lv["w"].at[1].add(2.0).at[5].add(1.0))
        red, _ = store.tick(lv, red, 2)          # async: update in flight
        red = store.on_write(red, events={"w": jnp.zeros((24,), bool)
                                          .at[9].set(True)})
        lv = dict(lv, w=lv["w"].at[9].add(3.0))
        red = store.flush(lv, red, step=3)
        if async_on:
            assert _group(store).pending is None  # flush resolved it
        outs.append((lv, red))
    _assert_red_equal(outs[0][1], outs[1][1])


def test_scrub_check_mid_flight_matches_blocking():
    """Corruption of a clean block is detected mid-flight exactly as the
    blocking path would detect it, and in-flight blocks stay skipped."""
    counts = []
    for async_on in (True, False):
        store = _store(async_on, period=2)
        lv = _leaves()
        red = store.init(lv)
        ev = jnp.zeros((24,), bool).at[0].set(True)
        red = store.on_write(red, events={"w": ev})
        lv = dict(lv, w=lv["w"].at[0].add(1.0))
        red, _ = store.tick(lv, red, 2)          # async: update in flight
        meta = store.metas["w"]
        lanes = B.to_lanes(lv["w"], meta)
        bad = dict(lv, w=B.from_lanes(lanes.at[20, 3].add(99), meta))
        mm = store.scrub(bad, red)
        assert np.flatnonzero(np.asarray(mm["w"])).tolist() == [20]
        counts.append(store.scrub_check(bad, red))
    assert counts[0] == counts[1] > 0


def test_speculative_misprediction_is_bitwise_safe():
    """A queued dispatch launched on a wrong fit prediction (overflow) must
    settle to the exact blocking-path bits via the full fallback."""
    outs = []
    for async_on in (True, False):
        store = _store(async_on, period=1)
        lv = _leaves()
        red = store.init(lv)
        if async_on:
            _group(store).predicted_fits = True   # force the misprediction
        red = store.on_write(red, events={"w": ALL, "e": ALL})
        lv = {k: v + 1 for k, v in lv.items()}
        red, _ = store.tick(lv, red, 1)           # async: queued, overflows
        if async_on:
            p = _group(store).pending
            assert p is not None and p.queued
            store.sync_inflight()
            red, rep = store.tick(lv, red, 2)     # resolves -> full fallback
            assert rep.overflowed
            assert _group(store).predicted_fits is False
        red = store.settle(red, lv)
        outs.append(red)
        assert sum(int(v.sum()) for v in store.scrub(lv, red).values()) == 0
    _assert_red_equal(outs[0], outs[1])


def test_scrub_after_overflow_leaves_callers_red_usable():
    """Regression: settle's overflow repair (run from the read-only scrub
    path) must not donate the caller's red — ticking must keep working on
    the same lineage afterwards, bitwise-equal to the blocking path."""
    outs = []
    for async_on in (True, False):
        store = _store(async_on, period=1)
        lv = _leaves()
        red = store.init(lv)
        if async_on:
            _group(store).predicted_fits = True   # force queued overflow
        red = store.on_write(red, events={"w": ALL, "e": ALL})
        lv = {k: v + 1 for k, v in lv.items()}
        red, _ = store.tick(lv, red, 1)           # async: in flight
        assert store.scrub_check(lv, red) == 0    # settles internally
        # the caller's red must still be alive and tickable
        red = store.on_write(red, events={"w": jnp.zeros((24,), bool)
                                          .at[2].set(True)})
        lv = dict(lv, w=lv["w"].at[2].add(0.5))
        red, _ = store.tick(lv, red, 2)
        red = store.settle(red, lv)
        outs.append(red)
        assert sum(int(v.sum()) for v in store.scrub(lv, red).values()) == 0
    _assert_red_equal(outs[0], outs[1])


def test_in_flight_blocks_stay_conservatively_marked():
    """Between dispatch and resolution the live view must keep the consumed
    snapshot marked (shadow) so accounting and recovery treat those blocks
    as vulnerable, and the returned dirty bitmap is the fresh epoch B."""
    store = _store(True, period=2)
    lv = _leaves()
    red = store.init(lv)
    ev = jnp.zeros((24,), bool).at[jnp.array([0, 3])].set(True)
    red = store.on_write(red, events={"w": ev})
    lv = dict(lv, w=lv["w"].at[0].add(1.0).at[3].add(1.0))
    red, _ = store.tick(lv, red, 2)
    assert _group(store).pending is not None
    assert int(bits.popcount(red["w"].dirty)) == 0          # fresh epoch B
    assert int(bits.popcount(red["w"].shadow)) > 0          # snapshot A
    stats = store.dirty_stats(red)
    assert int(stats["w"]["dirty_blocks"]) > 0              # conservative


def test_coalescing_folds_due_ticks_into_inflight_update(monkeypatch):
    """Due ticks arriving while an update is outstanding coalesce (at most
    one in flight); the deferred update dispatches on resolution."""
    store = _store(True, period=1)
    lv = _leaves()
    red = store.init(lv)
    red = store.on_write(red, events={"w": jnp.zeros((24,), bool)
                                      .at[0].set(True)})
    red, _ = store.tick(lv, red, 1)               # dispatch
    g = _group(store)
    first = g.pending
    assert first is not None
    monkeypatch.setattr(store_mod, "_ready", lambda x: False)
    red, rep = store.tick(lv, red, 2)             # due, but still "in flight"
    assert rep.coalesced and rep.updated
    assert g.pending is first and first.coalesced == 1
    monkeypatch.undo()
    store.sync_inflight()
    red, rep = store.tick(lv, red, 3)             # resolves + deferred fires
    assert g.pending is not None and g.pending.step == 3
    red = store.settle(red, lv)
    assert sum(int(v.sum()) for v in store.scrub(lv, red).values()) == 0


def test_no_queue_fits_round_trip_on_async_hot_path(monkeypatch):
    """Acceptance: a due tick must never pay the host-side queue_fits
    round trip on the overlap-pipelined path."""
    store = _store(True, period=1)
    lv = _leaves()
    red = store.init(lv)

    def boom(*a, **k):
        raise AssertionError("queue_fits called on the async hot path")

    for g in store.groups.values():
        monkeypatch.setattr(g.engine, "queue_fits", boom)
    for step in range(1, 6):
        red = store.on_write(red, events={"w": jnp.zeros((24,), bool)
                                          .at[step % 24].set(True)})
        lv = dict(lv, w=lv["w"].at[step % 24].add(0.5))
        red, _ = store.tick(lv, red, step)        # would raise if it synced
    monkeypatch.undo()
    red = store.settle(red, lv)
    assert sum(int(v.sum()) for v in store.scrub(lv, red).values()) == 0


def test_attach_precompiles_update_variants():
    """Satellite: attach warms both Algorithm-1 variants (plus the epoch
    swap) so the first due tick never hides a compile stall."""
    store = _store(True)
    label = _group(store).label
    assert (label, "async_full") in store._jit_update
    assert (label, "async_queued") in store._jit_update
    assert (label, "swap") in store._jit_misc
    blocking = _store(False)
    label = _group(blocking).label
    assert (label, "full") in blocking._jit_update
    assert (label, "queued") in blocking._jit_update
    cold = _store(True, precompile=False)
    assert not cold._jit_update


def test_blocking_flush_seeds_speculation():
    """flush's exact queue_fits answer becomes the next fit prediction."""
    store = _store(True, period=4)
    lv = _leaves()
    red = store.init(lv)
    assert _group(store).predicted_fits is False  # pessimistic start
    ev = jnp.zeros((24,), bool).at[0].set(True)   # sparse: fits
    red = store.on_write(red, events={"w": ev})
    lv = dict(lv, w=lv["w"].at[0].add(1.0))
    red = store.flush(lv, red, step=0)
    assert _group(store).predicted_fits is True


def test_deadline_forces_resolution_and_update(monkeypatch):
    """An overdue freshness deadline must block-resolve the in-flight
    update rather than coalesce forever."""
    pol = RedundancyPolicy.single(
        "vilamb", period_steps=100, max_vulnerable_steps=2,
        lanes_per_block=128, async_tick=True)
    store = ProtectedStore(pol).attach(_leaves())
    lv = _leaves()
    red = store.init(lv)
    red = store.on_write(red, events={"w": ALL})
    red, rep = store.tick(lv, red, 2)             # overdue -> dispatch
    assert rep.updated and rep.deadline_fired
    monkeypatch.setattr(store_mod, "_ready", lambda x: False)
    red = store.on_write(red, events={"w": ALL})
    red, rep = store.tick(lv, red, 4)             # overdue again: must not
    assert rep.updated                            # coalesce past the deadline
    assert _group(store).pending.step == 4
