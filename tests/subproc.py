"""Shared multi-device subprocess harness.

Multi-device tests need ``XLA_FLAGS=--xla_force_host_platform_device_count``
exported *before* jax is imported, so every such test runs its body in a
subprocess.  This module is the one place that test-side snippet
plumbing lives (test_sharded.py and test_faults.py reuse it) — tests
supply the body and a success marker instead of copy-pasting
``subprocess.run`` calls.  (The benchmarks' ``overlap_sharded`` child and
``repro.faults``' sharded leg spawn their own subprocesses: shipped code
cannot import from tests/.)

``MESH_PRELUDE`` is the canonical 2x2x2 sharded-store fixture: two leaves
("w" fully sharded over pod x data x model, "e" sharded over pod x data and
replicated over model), a sparse scripted writer, and the bitwise
red-state comparator.  Geometry is sized so "w" has a live per-shard work
queue (local stripes 32, capacity 16 at frac 0.5) while "e" is too small
to compact (capacity 0) — both paths stay exercised in one store.
"""
from __future__ import annotations

import os
import subprocess
import sys
import textwrap

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_py(code: str, devices: int = 8, timeout: int = 900,
           env: dict | None = None) -> subprocess.CompletedProcess:
    """Run dedented ``code`` under ``devices`` forced host devices."""
    full_env = dict(
        os.environ,
        XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}",
        PYTHONPATH=SRC)
    full_env.update(env or {})
    return subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                          env=full_env, capture_output=True, text=True,
                          timeout=timeout)


def run_snippet(code: str, marker: str, devices: int = 8, timeout: int = 900,
                env: dict | None = None, prelude: str = "",
                ) -> subprocess.CompletedProcess:
    """``run_py`` + assert the success marker was printed (with diagnostics).

    ``prelude`` (e.g. :data:`MESH_PRELUDE`) is prepended *after* the body
    is dedented — naive string concatenation would leave the body indented
    relative to the margin-level prelude, and Python would happily parse
    it into the prelude's last suite instead of running it.
    """
    r = run_py(prelude + textwrap.dedent(code), devices=devices,
               timeout=timeout, env=env)
    assert marker in r.stdout, (
        f"marker {marker!r} missing (exit {r.returncode})\n"
        f"--- stdout ---\n{r.stdout[-3000:]}\n"
        f"--- stderr ---\n{r.stderr[-6000:]}")
    return r


MESH_PRELUDE = """
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.core import ProtectedStore, RedundancyPolicy
from repro.launch.mesh import make_mesh

MESH = make_mesh((2, 2, 2), ("pod", "data", "model"))
SPECS = {"w": P(("pod", "data", "model"), None), "e": P(("pod", "data"), None)}
FIELDS = ("checksums", "parity", "dirty", "shadow", "meta_ck")

def make_leaves():
    return {"w": jax.random.normal(jax.random.PRNGKey(0), (64, 2048), jnp.float32),
            "e": jax.random.normal(jax.random.PRNGKey(1), (16, 1024), jnp.bfloat16)}

def put(lv):
    return {k: jax.device_put(v, NamedSharding(MESH, SPECS[k])) for k, v in lv.items()}

def mesh_store(mesh=MESH, frac=0.5, period=2, **kw):
    pol = RedundancyPolicy.single("vilamb", period_steps=period,
                                  lanes_per_block=128, work_queue_frac=frac, **kw)
    return ProtectedStore(pol, mesh=mesh).attach(
        make_leaves(), specs=SPECS if mesh is not None else None)

def drive(store, steps=8, seed=0):
    rng = np.random.default_rng(seed)
    lv = put(make_leaves()) if store.mesh is not None else make_leaves()
    red = store.init(lv)
    for step in range(1, steps + 1):
        rows = rng.choice(64, size=int(rng.integers(1, 4)), replace=False)
        idx = jnp.asarray(np.sort(rows))
        lv = dict(lv, w=lv["w"].at[idx].add(0.25 * step))
        ev = jnp.zeros((64,), bool).at[idx].set(True)
        red = store.on_write(red, events={"w": ev})
        # Determinism: every due tick must see the in-flight update as
        # ready (adopt, never coalesce), independent of machine load.
        # sync_inflight also joins the dispatcher-thread launch, which a
        # bare block_until_ready(pending.fits) would race against.
        store.sync_inflight()
        red, _ = store.tick(lv, red, step)
    return lv, red

def assert_red_equal(a, b):
    for k in a:
        for f in FIELDS:
            np.testing.assert_array_equal(
                np.asarray(getattr(a[k], f)), np.asarray(getattr(b[k], f)),
                err_msg=f"{k}.{f}")
"""
