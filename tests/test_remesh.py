"""Elastic remesh (repro.remesh) + degraded-mode reads.

Machine-local tests cover the pure pieces (mark translation, typed
errors, policy knobs, ``read_verified`` recovery ladder); the multi-device
legs run in subprocesses (see tests/subproc.py) and prove the ISSUE's
acceptance bar directly:

* grow 4 -> 8 and shrink 8 -> 4 migrate **bitwise-identically** under
  concurrent foreground writes into migrating blocks, with no
  stop-the-world re-attach and the pinned tick bound
  ``ceil(moved_blocks / window)``;
* the tick priority ladder holds (foreground > due ticks > rebuild >
  remesh > patrol): a remesh queued during an active rebuild waits for
  the paste to finish;
* settle/flush drain outstanding rebuild/remesh windows before adopting
  (checkpoints never persist a half-pasted shard), surfacing moved
  leaves via ``take_repaired``;
* crash-point replay sweeps through ``rebuild_paste`` and
  ``remesh_migrate`` classify every crash ``recovered_bitwise`` (dropout
  semantics — shard data intact) while the scribbled variant is
  ``rejected`` by verified restore, never silently adopted.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from subproc import run_snippet

from repro.core import (ProtectedStore, RedundancyPolicy,
                        UNRECOVERABLE_REASONS, UnrecoverableReadError)
from repro.core import blocks as B
from repro.faults.crashpoints import CRASH_PHASES
from repro.faults.inject import FAULT_KINDS, FaultSpec, apply_fault
from repro.remesh import (RemeshGeometryError, RemeshStatus, translate_marks)


# --------------------------------------------------------------- unit tests

def test_translate_marks_identity():
    """Equal lanes-per-block (the policy-constant case): marks map 1:1
    through global block space regardless of the shard split."""
    old = np.zeros((4, 32), bool)
    old[1, 3] = old[2, 31] = True
    new = translate_marks(old, 128, 128, new_n_blocks=16, new_k=8)
    assert new.shape == (8, 16)
    got = set(np.flatnonzero(new.reshape(-1)).tolist())
    assert got == {1 * 32 + 3, 2 * 32 + 31}


def test_translate_marks_regrouped_lanes():
    """Unequal lanes-per-block: one old block covers the word range of
    several new blocks (and vice versa) — translation is conservative
    (covers at least the old range), never lossy."""
    old = np.zeros((2, 8), bool)
    old[0, 2] = True            # words [128, 192) at 64 lanes/block
    new = translate_marks(old, 64, 32, new_n_blocks=16, new_k=2)
    got = set(np.flatnonzero(new.reshape(-1)).tolist())
    assert got == {4, 5}        # words [128, 192) at 32 lanes/block
    # widen: 32 -> 64 lanes/block, block 5 = words [160, 192) -> block 2
    old2 = np.zeros((2, 16), bool)
    old2[0, 5] = True
    new2 = translate_marks(old2, 32, 64, new_n_blocks=8, new_k=2)
    assert set(np.flatnonzero(new2.reshape(-1)).tolist()) == {2}


def test_remesh_registry_extensions():
    assert "rebuild_paste" in CRASH_PHASES
    assert "remesh_migrate" in CRASH_PHASES
    assert "mesh_grow" in FAULT_KINDS and "mesh_shrink" in FAULT_KINDS
    assert "read_timeout" in UNRECOVERABLE_REASONS


def test_policy_remesh_knobs_defaults():
    pol = RedundancyPolicy.single("vilamb")
    assert pol.remesh_bytes_per_tick == 0
    assert pol.read_retry_attempts == 3
    assert pol.read_retry_backoff_s == 0.0


def test_remesh_requires_mesh():
    """A machine-local (mesh-less) store cannot remesh — typed geometry
    error, not a silent no-op."""
    pol = RedundancyPolicy.single("vilamb", lanes_per_block=64)
    lv = {"w": jax.random.normal(jax.random.PRNGKey(0), (8, 256), jnp.float32)}
    store = ProtectedStore(pol).attach(lv)
    with pytest.raises(RemeshGeometryError):
        store.remesh(None)


def test_remesh_status_fields():
    st = RemeshStatus(from_shape=(1, 2, 2), to_shape=(2, 2, 2),
                      total_blocks=128, started_step=4)
    assert not st.done and st.migrated == 0 and st.ticks == 0


# ------------------------------------------------------ degraded-mode reads

def _small_store():
    pol = RedundancyPolicy.single("vilamb", period_steps=2,
                                  lanes_per_block=64,
                                  read_retry_attempts=2,
                                  read_retry_backoff_s=0.0)
    lv = {"w": jax.random.normal(jax.random.PRNGKey(0), (16, 512),
                                 jnp.float32)}
    store = ProtectedStore(pol).attach(lv)
    red = store.init(lv)
    red = store.flush(lv, red, step=0)
    return store, lv, red


def test_read_verified_clean_blocks():
    store, lv, red = _small_store()
    meta = store.metas["w"]
    lanes = np.asarray(B.to_lanes(lv["w"], meta))
    got = store.read_verified(lv, red, "w", [0, 5])
    np.testing.assert_array_equal(got[0], lanes[0])
    np.testing.assert_array_equal(got[5], lanes[5])


def test_read_verified_reconstructs_corrupt_block():
    """A checksum-mismatching block is parity-reconstructed and the
    *original* bytes returned — the caller never sees the corruption."""
    store, lv, red = _small_store()
    meta = store.metas["w"]
    lanes = np.asarray(B.to_lanes(lv["w"], meta))
    lv2, red2 = apply_fault(store.metas, lv, red,
                            FaultSpec("data_bitflip", "w", block=3,
                                      lane=1, bit=7))
    got = store.read_verified(lv2, red2, "w", [3])
    np.testing.assert_array_equal(got[3], lanes[3])


def test_read_verified_in_window_returns_newest():
    """Blocks inside the vulnerability window return the current data —
    the newest write is the truth; redundancy is just stale."""
    store, lv, red = _small_store()
    meta = store.metas["w"]
    lv2 = dict(lv, w=lv["w"].at[0].add(1.0))
    ev = jnp.zeros((16,), bool).at[0].set(True)
    red2 = store.on_write(red, events={"w": ev})
    got = store.read_verified(lv2, red2, "w", [0])
    np.testing.assert_array_equal(
        got[0], np.asarray(B.to_lanes(lv2["w"], meta))[0])


def test_read_verified_unrecoverable_is_typed():
    """Two corrupt blocks in one stripe: parity cannot repair, retries
    exhaust, and the caller gets a typed error naming every lost block —
    never stale bytes presented as data."""
    store, lv, red = _small_store()
    assert store.metas["w"].stripe_data_blocks > 1
    for b in (0, 1):
        lv, red = apply_fault(store.metas, lv, red,
                              FaultSpec("data_bitflip", "w", block=b,
                                        lane=0, bit=1))
    with pytest.raises(UnrecoverableReadError) as ei:
        store.read_verified(lv, red, "w", [0, 1])
    recs = ei.value.records
    assert all(r.reason == "read_timeout" for r in recs)
    assert sorted(b for r in recs for b in r.blocks) == [0, 1]


# ------------------------------------------------------- mesh fault kinds

def test_mesh_fault_kinds_machine_local():
    store, lv, red = _small_store()
    meta = store.metas["w"]
    # grow: data intact, redundancy zeroed
    lv2, red2 = apply_fault(store.metas, lv, red,
                            FaultSpec("mesh_grow", "w", block=0))
    np.testing.assert_array_equal(np.asarray(lv2["w"]), np.asarray(lv["w"]))
    assert not np.asarray(red2["w"].checksums[:meta.n_blocks]).any()
    # shrink: data + redundancy scribbled
    lv3, red3 = apply_fault(store.metas, lv, red,
                            FaultSpec("mesh_shrink", "w", block=0))
    assert (np.asarray(lv3["w"]) != np.asarray(lv["w"])).any()
    assert (np.asarray(red3["w"].checksums[:meta.n_blocks])
            != np.asarray(red["w"].checksums[:meta.n_blocks])).all()
    with pytest.raises(ValueError):
        apply_fault(store.metas, lv, red,
                    FaultSpec("mesh_grow", "w", block=7))


# ----------------------------------------------------- multi-device legs

_REMESH_BODY = """
    import math
    import numpy as np
    import jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.core import ProtectedStore, RedundancyPolicy
    from repro.launch.mesh import make_mesh

    OLD = make_mesh({old_dims}, ("pod", "data", "model"))
    NEW = make_mesh({new_dims}, ("pod", "data", "model"))
    SPEC = P(("pod", "data", "model"), None)
    pol = RedundancyPolicy.single(
        "vilamb", period_steps=2, lanes_per_block=128, async_tick=True,
        precompile=False, remesh_bytes_per_tick=32 * 128 * 4)
    w = jax.random.normal(jax.random.PRNGKey(0), (64, 2048), jnp.float32)
    lv = {{"w": jax.device_put(w, NamedSharding(OLD, SPEC))}}
    store = ProtectedStore(pol, mesh=OLD).attach(lv, specs={{"w": SPEC}})
    red = store.init(lv)
    host = {{"w": np.array(np.asarray(lv["w"]))}}
    rng = np.random.default_rng(0)

    def write(lv, red, step):
        rows = np.sort(rng.choice(64, size=3, replace=False))
        idx = jnp.asarray(rows)
        lv = dict(lv, w=lv["w"].at[idx].add(jnp.float32(0.25 * step)))
        host["w"][rows] += np.float32(0.25 * step)
        ev = jnp.zeros((64,), bool).at[idx].set(True)
        return lv, store.on_write(red, events={{"w": ev}})

    step = 0
    for step in range(1, 4):
        lv, red = write(lv, red, step)
        red, rep = store.tick(lv, red, step)

    store.remesh(NEW)
    assert store.remeshing
    # second request while one is queued/migrating -> typed error
    from repro.remesh import RemeshInProgressError
    try:
        store.remesh(OLD)
        raise SystemExit("expected RemeshInProgressError")
    except RemeshInProgressError:
        pass
    status = None
    while store.remeshing:
        step += 1
        # Foreground writes keep landing IN migrating blocks — online, no
        # stop-the-world: the tick interleaves migration windows with them.
        lv, red = write(lv, red, step)
        red, rep = store.tick(lv, red, step)
        if rep.remesh is not None:
            status = rep.remesh
        if rep.repaired:
            lv = dict(lv, **rep.repaired)
        assert step < 60, "remesh never finished"
    assert status is not None and status.done, status
    assert store.geometry_version == 1
    assert store.shard_factor("w") == {new_k}
    # Bitwise: migrated + foreground-written state matches the host mirror.
    np.testing.assert_array_equal(np.asarray(lv["w"]), host["w"])
    # Pinned migration bound: ceil(moved_blocks / window) ticks, no more.
    nb = store.metas["w"].n_blocks
    wb = max(1, min(nb, (32 * 128 * 4) // (128 * 4)))
    assert status.ticks == math.ceil(nb / wb), (status, nb, wb)
    # Forward progress on the new mesh: more writes, then a clean scrub.
    for _ in range(3):
        step += 1
        lv, red = write(lv, red, step)
        red, rep = store.tick(lv, red, step)
    red = store.flush(lv, red, step=step)
    assert store.scrub_check(lv, red) == 0
    np.testing.assert_array_equal(np.asarray(lv["w"]), host["w"])
    print("REMESH_{tag}_OK", status.migrated, status.ticks)
"""


def test_sharded_remesh_grow_bitwise_online():
    """Grow 4 -> 8 devices: incremental re-striping stays bitwise-correct
    under concurrent foreground writes, within the pinned tick bound."""
    run_snippet(_REMESH_BODY.format(old_dims="(1, 2, 2)",
                                    new_dims="(2, 2, 2)", new_k=8,
                                    tag="GROW"), "REMESH_GROW_OK")


def test_sharded_remesh_shrink_bitwise_online():
    """Shrink 8 -> 4 devices: the reverse migration, same guarantees."""
    run_snippet(_REMESH_BODY.format(old_dims="(2, 2, 2)",
                                    new_dims="(1, 2, 2)", new_k=4,
                                    tag="SHRINK"), "REMESH_SHRINK_OK")


def test_sharded_remesh_ladder_conflicts_and_drain():
    """One run exercising the full robustness surface: idempotent loss
    declaration, typed second-shard conflict, remesh queued behind an
    active rebuild (priority ladder), loss refused during remesh,
    settle-time drain of outstanding paste windows (take_repaired),
    degraded read of a lost-shard block mid-rebuild, geometry-versioned
    patroller after adoption — all bitwise-verified."""
    run_snippet("""
        import numpy as np
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.core import ProtectedStore, RedundancyPolicy
        from repro.core import blocks as B
        from repro.faults.inject import FaultSpec
        from repro.launch.mesh import make_mesh
        from repro.scrub import ShardLossConflictError

        mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
        spec = P(("pod", "data", "model"), None)
        pol = RedundancyPolicy.single(
            "vilamb", period_steps=2, lanes_per_block=128, async_tick=True,
            patrol_bytes_per_tick=8 * 128 * 4, precompile=False)
        w = jax.random.normal(jax.random.PRNGKey(0), (64, 2048), jnp.float32)
        lv = {"w": jax.device_put(w, NamedSharding(mesh, spec))}
        store = ProtectedStore(pol, mesh=mesh).attach(lv, specs={"w": spec})
        red = store.init(lv)
        pat = store.patroller
        step = 0
        for _ in range(48):
            red, _ = store.tick(lv, red, step, scrub_period=0); step += 1
            xp = pat.xpar["w"]
            if xp.xpar is not None and bool(xp.xvalid.all()):
                break
        assert bool(pat.xpar["w"].xvalid.all()), "xpar never covered leaf"
        expected = np.array(np.asarray(lv["w"]))

        lv, red = store.inject(lv, red, FaultSpec(
            kind="shard_loss", leaf="w", block=3))
        store.declare_shard_lost("w", 3, red)
        store.declare_shard_lost("w", 3, red)   # idempotent while pending
        red, rep = store.tick(lv, red, step, scrub_period=0); step += 1
        if rep.repaired: lv = dict(lv, **rep.repaired)
        assert pat.rebuild is not None, "rebuild should span several ticks"
        phases = []
        store.add_phase_hook(lambda ph, info: phases.append(ph))
        store.declare_shard_lost("w", 3, red)   # idempotent while active
        try:
            store.declare_shard_lost("w", 5, red)
            raise SystemExit("expected ShardLossConflictError")
        except ShardLossConflictError as e:
            assert (e.leaf, e.active_shard, e.new_shard) == ("w", 3, 5)
        # Degraded read mid-rebuild: a scribbled lost-shard block comes
        # back as the reconstructed ORIGINAL bytes, never the scribble.
        meta = store.metas["w"]
        g = 3 * meta.n_blocks + 1
        got = store.read_verified(lv, red, "w", [g])
        want = np.asarray(B.to_lanes(
            B.shard_slice(jnp.asarray(expected), meta, 8, 3)[0], meta))[1]
        np.testing.assert_array_equal(got[g], want)
        # Remesh queues behind the active rebuild (priority ladder)...
        NEW = make_mesh((1, 2, 2), ("pod", "data", "model"))
        store.remesh(NEW)
        assert store.remeshing and store._remesh is None
        # ...and shard loss is refused while a remesh is queued/migrating.
        try:
            store.declare_shard_lost("w", 5, red)
            raise SystemExit("expected RuntimeError")
        except ShardLossConflictError:
            raise SystemExit("wrong error type")
        except RuntimeError:
            pass
        # settle() with leaves drains the outstanding paste windows: no
        # half-pasted shard can reach a checkpoint taken now.
        red = store.settle(red, lv)
        moved = store.take_repaired()
        assert moved, "drain surfaced no pasted leaves"
        lv = dict(lv, **moved)
        assert pat.rebuild is None
        assert "rebuild_paste" in phases, set(phases)
        # The queued (never-started) remesh survives the settle...
        assert store.remeshing and store.geometry_version == 0
        # ...and runs now that the ladder is clear.
        for _ in range(24):
            red, rep = store.tick(lv, red, step, scrub_period=0); step += 1
            if rep.repaired: lv = dict(lv, **rep.repaired)
            if not store.remeshing: break
        assert not store.remeshing
        assert "remesh_migrate" in phases, set(phases)
        assert store.geometry_version == 1 and store.shard_factor("w") == 4
        assert store.patroller is not pat
        assert store.patroller.geometry_version == 1
        red = store.flush(lv, red, step=step)
        assert store.scrub_check(lv, red) == 0
        np.testing.assert_array_equal(np.asarray(lv["w"]), expected)
        print("LADDER_OK", sorted(set(phases)))
    """, "LADDER_OK")


def test_sharded_crash_sweep_rebuild_and_remesh():
    """Crash-point replay through active background work.  Dropout
    semantics (declare lost, data intact) let every crash classify
    ``recovered_bitwise``; the scribbled variant must be ``rejected`` by
    the verified restore — a crashed half-pasted scribble is never
    silently adopted as data."""
    run_snippet("""
        import tempfile
        import numpy as np
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.core import ProtectedStore, RedundancyPolicy
        from repro.faults.crashpoints import CrashPlan, CrashPointMachine
        from repro.faults.inject import FaultSpec
        from repro.launch.mesh import make_mesh

        mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
        spec = P(("pod", "data", "model"), None)

        def make_store():
            pol = RedundancyPolicy.single(
                "vilamb", period_steps=2, lanes_per_block=128,
                async_tick=True, patrol_bytes_per_tick=8 * 128 * 4,
                precompile=False, remesh_bytes_per_tick=64 * 128 * 4)
            return ProtectedStore(pol, mesh=mesh).attach(
                make_leaves(), specs={"w": spec})

        def make_leaves():
            w = jax.random.normal(jax.random.PRNGKey(0), (64, 2048),
                                  jnp.float32)
            return {"w": jax.device_put(w, NamedSharding(mesh, spec))}

        def drop_shard(store, leaves, red):
            store.declare_shard_lost("w", 3, red)

        with tempfile.TemporaryDirectory() as d:
            m = CrashPointMachine(make_store, make_leaves, d, seed=0,
                                  steps=8, actions={3: drop_shard})
            outs = m.sweep(require_phases=("rebuild_paste",),
                           only_phases=("rebuild_paste",))
            assert len(outs) >= 2, outs
            bad = [o for o in outs if o.classification != "recovered_bitwise"]
            assert not bad, bad
        print("SWEEP_REBUILD_OK", len(outs))

        NEW = make_mesh((1, 2, 2), ("pod", "data", "model"))
        def start_remesh(store, leaves, red):
            store.remesh(NEW)

        with tempfile.TemporaryDirectory() as d:
            m = CrashPointMachine(make_store, make_leaves, d, seed=0,
                                  steps=10, actions={3: start_remesh})
            outs = m.sweep(require_phases=("remesh_migrate",),
                           only_phases=("remesh_migrate",))
            assert len(outs) >= 2, outs
            bad = [o for o in outs if o.classification != "recovered_bitwise"]
            assert not bad, bad
        print("SWEEP_REMESH_OK", len(outs))

        # Scribbled variant: the persisted crash image holds a half-pasted
        # scribbled shard; verified restore must refuse it outright.
        def scribble_and_drop(store, leaves, red):
            lv2, red2 = store.inject(leaves, red, FaultSpec(
                kind="shard_loss", leaf="w", block=3))
            store.declare_shard_lost("w", 3, red2)
            return lv2, red2

        with tempfile.TemporaryDirectory() as d:
            m = CrashPointMachine(make_store, make_leaves, d, seed=0,
                                  steps=8, actions={3: scribble_and_drop})
            out = m.run_crash(CrashPlan("rebuild_paste", 0))
            assert out.classification == "rejected", out
        print("SWEEP_ALL_OK")
    """, "SWEEP_ALL_OK", timeout=1800)
