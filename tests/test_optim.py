"""AdamW: lazy-row semantics (the substrate of Vilamb dirty tracking)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import AdamW, warmup_cosine


def test_lazy_rows_bit_identical():
    opt = AdamW(lr=lambda s: 1e-2, weight_decay=0.1)
    params = {"embed": jax.random.normal(jax.random.PRNGKey(0), (10, 8)),
              "w": jax.random.normal(jax.random.PRNGKey(1), (8, 8))}
    opt_state = opt.init(params)
    grads = jax.tree.map(jnp.ones_like, params)
    mask = jnp.zeros((10,), bool).at[jnp.array([2, 5])].set(True)
    p2, o2, gn = opt.update(grads, opt_state, params, {"embed": mask})
    em0, em2 = np.asarray(params["embed"]), np.asarray(p2["embed"])
    # untouched rows bit-identical (clean blocks stay clean)
    touched = np.asarray(mask)
    np.testing.assert_array_equal(em2[~touched], em0[~touched])
    assert not np.array_equal(em2[touched], em0[touched])
    # moments too
    np.testing.assert_array_equal(np.asarray(o2["m"]["embed"])[~touched], 0.0)
    # dense leaf fully updated
    assert not np.array_equal(np.asarray(p2["w"]), np.asarray(params["w"]))


def test_clipping_and_schedule():
    opt = AdamW(lr=warmup_cosine(1e-2, 2, 10), clip_norm=1.0)
    params = {"w": jnp.ones((4, 4))}
    st = opt.init(params)
    big = {"w": jnp.full((4, 4), 100.0)}
    p2, st2, gn = opt.update(big, st, params)
    assert float(gn) > 1.0
    # clipped: effective first-step update magnitude bounded by lr
    assert float(jnp.max(jnp.abs(p2["w"] - params["w"]))) < 0.02


def test_empty_subtree_preserved():
    opt = AdamW(lr=lambda s: 1e-3)
    params = {"norm": {}, "w": jnp.ones((2, 2))}
    st = opt.init(params)
    p2, st2, _ = opt.update({"norm": {}, "w": jnp.ones((2, 2))}, st, params)
    assert p2["norm"] == {}
    assert "norm" in st2["m"]
