"""Fault-tolerance walkthrough: train -> SDC injection -> scrub detection ->
parity reconstruction -> training continues; then a vulnerable-stripe case
falls back to checkpoint restore.

    PYTHONPATH=src python examples/recovery_demo.py
"""
import sys

sys.path.insert(0, "src")

import dataclasses

import jax
import numpy as np

from repro.ckpt import CheckpointManager
from repro.common import unflatten_dict
from repro.configs import get_smoke
from repro.core import ProtectedStore, RedundancyPolicy
from repro.core import blocks as B
from repro.data import SyntheticPipeline
from repro.models import build_model
from repro.models.config import ShapeConfig
from repro.optim import AdamW
from repro.train import Trainer, protected_leaves, protected_structs

cfg = get_smoke("llama3.2-3b")
model = build_model(cfg)
opt = AdamW(lr=lambda s: 1e-3)
p0 = jax.eval_shape(model.init, jax.random.PRNGKey(0))
o0 = jax.eval_shape(opt.init, p0)
store = ProtectedStore(RedundancyPolicy.single(
    "vilamb", period_steps=4)).attach(protected_structs(p0, o0))
trainer = Trainer(model=model, opt=opt, store=store)
data = SyntheticPipeline(cfg, ShapeConfig("d", 64, 4, "train"), seed=0)
ckpt = CheckpointManager("/tmp/vilamb_recovery_ckpt", keep=2)

state = trainer.init_state(jax.random.PRNGKey(0))
state = trainer.run(state, data, 4)
state = trainer.flush(state)
ckpt.save(int(state.step), state, blocking=True)
print("trained 4 steps, flushed, checkpointed.")

# --- Scenario 1: clean-stripe corruption -> parity repair ------------------
leaves = protected_leaves(state.params, state.opt)
name = "params/embed"
meta = store.metas[name]
bad_block = meta.n_blocks // 2
lanes = B.to_lanes(leaves[name], meta)
leaves[name] = B.from_lanes(lanes.at[bad_block, 3].add(0xBEEF), meta)
print("\n[1] injected a bit flip into", name, "block", bad_block)
mm = store.scrub(leaves, state.red)
print("    scrub detected:", int(sum(v.sum() for v in jax.tree.leaves(mm))), "block(s)")
repaired, fixed, lost = store.repair(leaves, state.red, mm)
print(f"    parity repair: fixed={fixed} unrecoverable={lost}")
params = unflatten_dict({k[len('params/'):]: v for k, v in repaired.items()
                         if k.startswith("params/")})
state = dataclasses.replace(state, params=params)
state = trainer.run(state, data, 2)
print("    training continued; loss finite:", True)

# --- Scenario 2: corruption inside the vulnerability window ----------------
# One fresh (unflushed) step leaves every written page dirty: a corruption
# there is checksummed-over silently — exactly the paper's tunable window of
# vulnerability (§3.3). The checkpoint layer is the safety net.
state2 = trainer.run(state, data, 1)       # fresh dirt, no redundancy pass yet
leaves = protected_leaves(state2.params, state2.opt)
lanes = B.to_lanes(leaves[name], store.metas[name])
leaves[name] = B.from_lanes(lanes.at[0, 0].add(1), store.metas[name])
mm = store.scrub(leaves, state2.red)
n_det = int(sum(v.sum() for v in jax.tree.leaves(mm)))
print(f"\n[2] corruption on a DIRTY page: scrub detected={n_det} "
      "(silent — inside the paper's vulnerability window)")
restored = ckpt.restore_verified(jax.eval_shape(lambda: state2), store)
print("    safety net: checkpoint restore at step", int(restored.step),
      "- the deterministic pipeline replays the exact stream from there.")
