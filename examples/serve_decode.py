"""Serve a small model with batched requests; the paged KV cache is
protected by Vilamb (page-granular dirty tracking, periodic redundancy,
scrubbing between batches).

    PYTHONPATH=src python examples/serve_decode.py
"""
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.common import flatten_dict
from repro.configs import get_smoke
from repro.core import ProtectedStore, RedundancyPolicy
from repro.models import build_model
from repro.serve import Server

BATCH, PROMPT, GEN = 4, 24, 40

cfg = get_smoke("glm4-9b")
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))
max_len = PROMPT + GEN + 1

caches0 = jax.eval_shape(lambda: model.init_caches(BATCH, max_len, 0))
store = ProtectedStore(RedundancyPolicy.single(
    "vilamb", period_steps=16)).attach(flatten_dict(caches0))
server = Server(model=model, store=store, max_len=max_len)

for req in range(3):  # batched request waves
    batch = {"tokens": jax.random.randint(
        jax.random.PRNGKey(req), (BATCH, PROMPT), 0, cfg.vocab_size, jnp.int32)}
    t0 = time.time()
    tokens, stats = server.generate(params, batch, GEN, scrub_every=10)
    dt = time.time() - t0
    print(f"request wave {req}: {tokens.shape} in {dt:.2f}s "
          f"({BATCH*GEN/dt:.1f} tok/s), KV scrub mismatches={stats['mismatches']}")
    print("  first seq:", tokens[0, :12].tolist())
