"""Quickstart: protect any JAX state dict with Vilamb in ~20 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.core import ALL, RedundancyConfig, RedundancyEngine
from repro.core import blocks as B

# 1) Any pytree of arrays is protectable state (here: a toy KV heap).
state = {"heap": jax.random.normal(jax.random.PRNGKey(0), (1024, 1024))}

# 2) Build the engine (paper defaults: 4+1 stripes; update period in steps).
engine = RedundancyEngine(
    {k: jax.ShapeDtypeStruct(v.shape, v.dtype) for k, v in state.items()},
    RedundancyConfig(mode="vilamb", period_steps=8))
red = engine.init(state)
print("blocks:", engine.metas["heap"].n_blocks,
      "stripes:", engine.metas["heap"].n_stripes)

# 3) Writes mark dirty rows; Algorithm 1 amortizes redundancy every period.
for step in range(8):
    rows = jax.random.randint(jax.random.PRNGKey(step), (16,), 0, 1024)
    state["heap"] = state["heap"].at[rows].add(1.0)
    red = engine.mark_dirty(red, {"heap": jnp.zeros((1024,), bool).at[rows].set(True)})
stats = jax.tree.map(int, engine.dirty_stats(red))["heap"]
print(f"dirty blocks after 8 steps: {stats['dirty_blocks']} "
      f"(vulnerable stripes: {stats['vulnerable_stripes']})")
red = engine.redundancy_step(state, red)          # the background thread's pass

# 4) Scrub detects silent corruption; parity repairs it.
meta = engine.metas["heap"]
lanes = B.to_lanes(state["heap"], meta)
state["heap"] = B.from_lanes(lanes.at[5, 99].add(0xBAD), meta)   # SDC!
bad = engine.scrub(state, red)["heap"]
print("scrub flagged blocks:", [int(i) for i in jnp.nonzero(bad)[0]])
fixed, ok = engine.recover_block(state["heap"], red["heap"], "heap", 5)
print("parity reconstruction succeeded:", bool(ok),
      "- scrub after repair:", int(engine.scrub({"heap": fixed}, red)["heap"].sum()))
