"""Quickstart: protect any JAX state dict with Vilamb in ~20 lines.

One facade owns the whole redundancy lifecycle:

    store = ProtectedStore(policy).attach(state)   # what / how to protect
    red   = store.init(state)                      # full pass at creation
    red   = store.on_write(red, events=...)        # inside each write step
    red, _ = store.tick(state, red, step)          # once per host step

    PYTHONPATH=src python examples/quickstart.py
"""
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.core import LeafPolicy, ProtectedStore, RedundancyPolicy
from repro.core import blocks as B

# 1) Any pytree of arrays is protectable state (here: a hot KV heap plus a
#    cold param blob). Policies are declarative and PER LEAF: the heap runs
#    the paper's asynchronous mode with period T=8 and a freshness deadline
#    (the paper's tunable knob: at most 16 steps of vulnerability, however
#    the governor stretches the period); params use the sync (Pangolin) mode.
state = {"heap": jax.random.normal(jax.random.PRNGKey(0), (1024, 1024)),
         "params": jax.random.normal(jax.random.PRNGKey(1), (512, 512))}
policy = RedundancyPolicy(
    default=LeafPolicy(mode="vilamb", period_steps=8, max_vulnerable_steps=16),
    rules=(("params*", LeafPolicy(mode="sync")),))

store = ProtectedStore(policy).attach(state)
red = store.init(state)
print("blocks:", store.metas["heap"].n_blocks,
      "stripes:", store.metas["heap"].n_stripes,
      "| groups:", [(g.policy.mode, g.names) for g in store.groups.values()])

# 2) Writes report to the store: dirty marks for vilamb leaves, the old/new
#    diff for sync leaves. tick() owns the Algorithm-1 schedule, scrubbing,
#    straggler back-off, and the freshness deadline — no mode branches here.
for step in range(1, 9):
    rows = jax.random.randint(jax.random.PRNGKey(step), (16,), 0, 1024)
    old = dict(state)
    state["heap"] = state["heap"].at[rows].add(1.0)
    state["params"] = state["params"] * 0.999
    red = store.on_write(
        red, events={"heap": jnp.zeros((1024,), bool).at[rows].set(True)},
        old=old, new=state)
    red, report = store.tick(state, red, step)
    if report.updated:
        print(f"step {step}: Algorithm 1 ran for {report.updated}")
stats = jax.tree.map(int, store.dirty_stats(red))["heap"]
print(f"dirty blocks after 8 steps: {stats['dirty_blocks']} "
      f"(vulnerable stripes: {stats['vulnerable_stripes']})")
red = store.flush(state, red)      # preemption/battery path: force updates now

# 3) Scrub detects silent corruption; parity repairs it.
meta = store.metas["heap"]
lanes = B.to_lanes(state["heap"], meta)
state["heap"] = B.from_lanes(lanes.at[5, 99].add(0xBAD), meta)   # SDC!
bad = store.scrub(state, red)["heap"]
print("scrub flagged blocks:", [int(i) for i in jnp.nonzero(bad)[0]])
fixed, ok = store.recover_block(state["heap"], red["heap"], "heap", 5)
state["heap"] = fixed
print("parity reconstruction succeeded:", bool(ok),
      "- scrub after repair:",
      int(store.scrub(state, red)["heap"].sum()))
