"""End-to-end training driver: an LM trained with asynchronous redundancy,
periodic scrubbing, checkpointing, and preemption flush.

Quick demo (CPU, ~2 min):
    PYTHONPATH=src python examples/train_with_vilamb.py

Full ~100M-param run (a few hundred steps):
    PYTHONPATH=src python examples/train_with_vilamb.py --full --steps 300
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import dataclasses

import jax

from repro.ckpt import CheckpointManager, PreemptionHandler
from repro.configs import get_smoke
from repro.core import ProtectedStore, RedundancyPolicy, mttdl
from repro.data import SyntheticPipeline
from repro.models import build_model
from repro.models.config import ModelConfig, ShapeConfig
from repro.optim import AdamW, warmup_cosine
from repro.train import Trainer, protected_structs


def model_100m() -> ModelConfig:
    return ModelConfig(
        name="demo-100m", family="dense", n_layers=12, d_model=768,
        n_heads=12, n_kv_heads=12, d_ff=3072, vocab_size=32768,
        norm="rmsnorm", activation="swiglu", param_dtype="float32")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="~100M params")
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--period", type=int, default=8)
    ap.add_argument("--ckpt", default="/tmp/vilamb_demo_ckpt")
    args = ap.parse_args()

    cfg = model_100m() if args.full else get_smoke("olmo-1b")
    print(f"model: {cfg.name} ({cfg.param_count()/1e6:.1f}M params)")
    model = build_model(cfg)
    opt = AdamW(lr=warmup_cosine(3e-4, 20, args.steps))
    p0 = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    o0 = jax.eval_shape(opt.init, p0)
    store = ProtectedStore(RedundancyPolicy.single(
        "vilamb", period_steps=args.period,
        scrub_period_steps=4 * args.period)).attach(protected_structs(p0, o0))
    trainer = Trainer(model=model, opt=opt, store=store)
    handler = PreemptionHandler().install()
    ckpt = CheckpointManager(args.ckpt, keep=2)

    shape = ShapeConfig("demo", 256 if args.full else 64, 8, "train")
    data = SyntheticPipeline(cfg, shape, seed=0)

    state = trainer.init_state(jax.random.PRNGKey(0))
    t0 = time.time()
    trace = []

    def on_step(st, m):
        s = int(st.step)
        trace.append(jax.tree.map(int, store.dirty_stats(st.red)))
        if s % 10 == 0:
            tput = s * shape.seq_len * shape.global_batch / (time.time() - t0)
            print(f"step {s:4d} loss {float(m['loss']):.4f} {tput:,.0f} tok/s")
        if s % 50 == 0:
            ckpt.save(s, st, blocking=False)
        if handler.requested:
            handler.drain(trainer, st, ckpt)
            sys.exit(42)

    state = trainer.run(state, data, args.steps, on_step=on_step)
    state = trainer.flush(state)
    ckpt.save(int(state.step), state, blocking=True)

    avg = mttdl.average_stats(trace)
    up = mttdl.aggregate_uplift(avg, store.policy.stripe_data_blocks + 1)
    print(f"done. scrub alarms: {trainer.corruption_alarms}; "
          f"measured MTTDL uplift over No-Redundancy: {up:.1f}x")


if __name__ == "__main__":
    main()
