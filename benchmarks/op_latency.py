"""Fig. 6 analogue: per-op transactional latencies (alloc/overwrite/dealloc)
for 64 B and 4 KB objects."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from .common import Region, emit


def _timed_threaded(write, heap, red, keys, val, iters=30):
    """Time the write op while threading (donated) state through."""
    heap, red = write(heap, red, keys, val)
    jax.block_until_ready(heap)
    t0 = time.perf_counter()
    for _ in range(iters):
        heap, red = write(heap, red, keys, val)
    jax.block_until_ready(heap)
    return (time.perf_counter() - t0) / iters * 1e6


def run(n_rows: int = 2048):
    rows = []
    for size_name, elems in (("64B", 16), ("4KB", 1024)):
        for mode in ("none", "sync", "vilamb"):
            lats = {}
            for op in ("alloc", "overwrite", "dealloc"):
                r = Region(n_rows=n_rows, mode=mode, period=8)
                keys = jnp.arange(8, dtype=jnp.int32)
                if op == "dealloc":
                    val = jnp.zeros((8, 1024), jnp.float32)
                elif elems < 1024:  # small object: partial-row write
                    val = jnp.asarray(r.heap[keys]).at[:, :elems].set(1.0)
                else:
                    val = jnp.ones((8, 1024), jnp.float32)
                lats[op] = _timed_threaded(r.write, r.heap, r.red, keys, val)
            for op, lat in lats.items():
                rows.append((f"fig6_latency/{op}/{size_name}/{mode}", lat,
                             f"{lat:.1f} us/txn-batch"))
    return rows


if __name__ == "__main__":
    emit(run())
