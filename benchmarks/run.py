"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (absolute wall numbers are CPU;
cross-mode ratios reproduce the paper's claims). Roofline terms come from
the dry-run artifacts (see repro.launch.dryrun).

Machine-readable output (perf trajectory tracking, see docs/perf.md):

    python -m benchmarks.run --json BENCH_PR2.json            # full sweep
    python -m benchmarks.run --json BENCH_PR2.json --smoke \
        --only insert_throughput,dirty_cost                   # CI artifact

The JSON artifact is ``{"env": {...}, "rows": [{name, us_per_call,
derived}, ...]}`` — one row per CSV line, plus enough environment metadata
to compare artifacts across PRs.
"""
from __future__ import annotations

import argparse
import json
import platform
import sys
import time

sys.path.insert(0, "src")

# Per-module kwargs for --smoke (tiny shapes, CI-budget runtimes).
SMOKE_KW = {
    "insert_throughput": dict(steps=6, n_rows=1024),
    "ycsb": dict(steps=6, n_rows=1024, batch=128),
    "op_latency": dict(n_rows=1024),
    "overwrite_scaling": dict(steps=6, n_rows=1024),
    "fio_patterns": dict(steps=6, n_rows=1024, batch=32),
    # fig9a capped at 4096 rows; the fig9c sweep keeps its representative
    # region size (sweep_rows default) even in smoke mode — see dirty_cost.
    "dirty_cost": dict(n_rows=4096, iters=10),
    # The sharded leg keeps its full-size shapes even in smoke mode: the
    # multi-group batching win only shows once per-due-tick update work is
    # non-trivial (see overlap.py), and the leg is ~15 s wall.
    "overlap": dict(steps=120, n_rows=2048, batch=32, repeats=2,
                    sharded_steps=40),
    "battery": dict(n_rows=1024),
    "mttdl_bench": dict(n_rows=1024, steps=12),
    "kernel_bench": dict(nb=128, L=512),
    "scrub_bench": dict(steps=24, n_rows=512, sweep_ticks=8,
                        sharded_steps=8, sharded_rows=128),
    "remesh_bench": dict(steps=12, n_rows=512, read_iters=8,
                         sharded_steps=8, sharded_rows=128),
    "health_bench": dict(steps=60, n_rows=512, batch=32),
}


def _env_metadata(args) -> dict:
    import jax
    dev = jax.devices()[0]
    return {
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S%z"),
        "python": platform.python_version(),
        "platform": platform.platform(),
        "jax": jax.__version__,
        "backend": jax.default_backend(),
        "device": str(dev.device_kind),
        "device_count": jax.device_count(),
        "smoke": bool(args.smoke),
        "only": args.only or None,
    }


def main(argv=None) -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--json", dest="json_path", default=None,
                   help="also write rows + env metadata to this JSON file")
    p.add_argument("--only", default="",
                   help="comma-separated module names (e.g. dirty_cost,ycsb)")
    p.add_argument("--smoke", action="store_true",
                   help="tiny shapes / few iterations (CI budget)")
    p.add_argument("--repeat", type=int, default=1,
                   help="run each module N times, keep the per-row minimum "
                        "us_per_call (scheduler-noise suppression on the "
                        "shared CPU container; a real regression raises "
                        "the minimum too)")
    args = p.parse_args(argv)

    from . import (battery, dirty_cost, fio_patterns, health_bench,
                   insert_throughput, kernel_bench, mttdl_bench, op_latency,
                   overlap, overwrite_scaling, remesh_bench, roofline,
                   scrub_bench, ycsb)
    from .common import emit

    modules = [
        ("fig1/fig5 insert throughput", insert_throughput),
        ("fig4 ycsb", ycsb),
        ("fig6 op latency", op_latency),
        ("fig7 overwrite scaling", overwrite_scaling),
        ("fig8 fio patterns", fio_patterns),
        ("fig9 dirty-bit cost", dirty_cost),
        ("overlap pipeline", overlap),
        ("sec4.7 battery", battery),
        ("sec4.8 mttdl", mttdl_bench),
        ("scrub patrol + rebuild", scrub_bench),
        ("elastic remesh + degraded reads", remesh_bench),
        ("health governor + breaker recovery", health_bench),
        ("kernel fusion", kernel_bench),
        ("roofline", roofline),
    ]
    selected = {s.strip() for s in args.only.split(",") if s.strip()}
    known = {mod.__name__.rsplit(".", 1)[-1] for _, mod in modules}
    unknown = selected - known
    if unknown:
        p.error(f"unknown --only module(s) {sorted(unknown)}; "
                f"choose from {sorted(known)}")
    all_rows = []
    print("name,us_per_call,derived")
    for title, mod in modules:
        short = mod.__name__.rsplit(".", 1)[-1]
        if selected and short not in selected:
            continue
        kw = SMOKE_KW.get(short, {}) if args.smoke else {}
        t0 = time.time()
        try:
            # Best-of-N merge by row name: wall rows (us > 0) keep their
            # fastest repeat, derived-only rows keep the first.
            merged: dict = {}
            order: list = []
            for _ in range(max(args.repeat, 1)):
                for name, us, derived in mod.run(**kw):
                    if name not in merged:
                        merged[name] = (us, derived)
                        order.append(name)
                    elif us > 0 and us < merged[name][0]:
                        merged[name] = (us, derived)
            rows = [(n, *merged[n]) for n in order]
            emit(rows)
            all_rows.extend(rows)
        except Exception as e:  # keep the harness running
            print(f"{title},0,ERROR {type(e).__name__}: {e}")
            all_rows.append((f"{short}/ERROR", 0.0,
                             f"{type(e).__name__}: {e}"))
        print(f"# [{title}] {time.time() - t0:.1f}s", file=sys.stderr)

    if args.json_path:
        doc = {
            "env": _env_metadata(args),
            "rows": [{"name": n, "us_per_call": round(float(us), 2),
                      "derived": str(d)} for n, us, d in all_rows],
        }
        with open(args.json_path, "w") as f:
            json.dump(doc, f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"# wrote {args.json_path} ({len(doc['rows'])} rows)",
              file=sys.stderr)


if __name__ == "__main__":
    main()
