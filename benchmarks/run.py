"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (absolute wall numbers are CPU;
cross-mode ratios reproduce the paper's claims). Roofline terms come from
the dry-run artifacts (see repro.launch.dryrun).
"""
from __future__ import annotations

import sys
import time

sys.path.insert(0, "src")


def main() -> None:
    from . import (battery, dirty_cost, fio_patterns, insert_throughput,
                   kernel_bench, mttdl_bench, op_latency, overwrite_scaling,
                   roofline, ycsb)
    from .common import emit

    modules = [
        ("fig1/fig5 insert throughput", insert_throughput),
        ("fig4 ycsb", ycsb),
        ("fig6 op latency", op_latency),
        ("fig7 overwrite scaling", overwrite_scaling),
        ("fig8 fio patterns", fio_patterns),
        ("fig9 dirty-bit cost", dirty_cost),
        ("sec4.7 battery", battery),
        ("sec4.8 mttdl", mttdl_bench),
        ("kernel fusion", kernel_bench),
        ("roofline", roofline),
    ]
    print("name,us_per_call,derived")
    for title, mod in modules:
        t0 = time.time()
        try:
            rows = mod.run()
            emit(rows)
        except Exception as e:  # keep the harness running
            print(f"{title},0,ERROR {type(e).__name__}: {e}")
        print(f"# [{title}] {time.time() - t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
