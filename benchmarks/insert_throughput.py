"""Fig. 1 / Fig. 5 analogue: insert-only throughput vs concurrency.

Threads -> parallel insert lanes per step; KV stores -> the region heap.
The paper's claim: Vilamb ~matches No-Redundancy and beats Pangolin 3-5x at
high op rates; Pangolin's synchronous per-op updates bind at high rates.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from .common import Region, emit, key_stream


def run(steps: int = 30, n_rows: int = 4096):
    rows = []
    vals_cache = {}
    results = {}
    for threads in (1, 8, 32):
        batch = 16 * threads
        vals = vals_cache.setdefault(batch, jnp.ones((batch, 1024), jnp.float32))
        for mode, period in (("none", 0), ("sync", 0), ("vilamb", 4), ("vilamb", 16)):
            r = Region(n_rows=n_rows, mode=mode, period=max(period, 1))
            keys = key_stream("seq", steps + 1, batch, n_rows)
            # best-of-2: scheduler noise on the shared CPU container swings
            # single runs 2-3x, which would trip the CI regression guard
            dt = min(r.run_writes(keys, vals) for _ in range(2))
            ops = steps * batch / dt
            name = f"fig1_insert/{mode}{'' if mode != 'vilamb' else f'_p{period}'}/threads{threads}"
            rows.append((name, dt / steps * 1e6, f"{ops:.0f} ops/s"))
            results[(mode, period, threads)] = ops
    # derived: vilamb speedup over sync at max concurrency (paper: 3-5x)
    sp = results[("vilamb", 16, 32)] / results[("sync", 0, 32)]
    base = results[("vilamb", 16, 32)] / results[("none", 0, 32)]
    rows.append(("fig1_insert/vilamb_over_pangolin_32t", 0.0, f"{sp:.2f}x"))
    rows.append(("fig1_insert/vilamb_vs_noredundancy_32t", 0.0, f"{base:.2f}x of NoRed"))
    return rows


if __name__ == "__main__":
    emit(run())
