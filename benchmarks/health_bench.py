"""Health-governor benchmarks: steady-state overhead + breaker recovery.

Two questions the PR-8 acceptance gate asks:

* ``health/governor_overhead`` — what does the governor cost on a
  *healthy* store?  The ladder's rungs never fire there, so the whole
  price is the per-tick bookkeeping (begin_tick / check_pending probe /
  end_tick age accounting).  Acceptance target: <= 5% added tick stall.
* ``chaos/recovery_ticks`` — when a storm does trip the breaker, how
  many calm ticks until the group is HEALTHY again?  Measured here with
  a deterministic machine-local wedged-dispatch storm (the in-flight
  probe is forced to report "not ready" so rung 1 times out, retries
  exhaust, and the breaker lands in CRITICAL with sync escalation);
  recovery is then pure hysteresis and must match 2 x recovery_ticks.

Wall rows (``health/tick_*``) are absolute CPU numbers; the derived
percentage is the signal.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from .common import LANES_PER_BLOCK, ROW_ELEMS, STRIPE, emit, key_stream
from repro.core import ProtectedStore, RedundancyPolicy


def _mk(n_rows: int, health=None):
    """Region-alike built directly: Region doesn't forward the health knob."""
    heap = jnp.zeros((n_rows, ROW_ELEMS), jnp.float32)
    policy = RedundancyPolicy.single(
        "vilamb", period_steps=4, lanes_per_block=LANES_PER_BLOCK,
        stripe_data_blocks=STRIPE, async_tick=True, health=health)
    store = ProtectedStore(policy).attach({"heap": heap})
    red = store.init({"heap": heap})

    def write(heap, red, rows, vals):
        heap = heap.at[rows].set(vals)
        mask = jnp.zeros((n_rows,), bool).at[rows].set(True)
        return heap, store.on_write(red, events={"heap": mask})

    return store, heap, red, jax.jit(write, donate_argnums=(0, 1))


def _tick_us(store, heap, red, write, keys, vals, steps: int,
             quiescent: bool, reps: int = 3):
    """Best-of-``reps`` mean per-tick wall micro-seconds, warmed.

    The per-pass minimum is the stable statistic on a shared machine (a
    scheduler hiccup lands in one pass and is dropped), so the derived
    on-vs-off percentage row is meaningful within a single invocation
    instead of relying on run.py's cross-invocation --repeat merge.
    """
    # warm: compile the write and prime one full update cycle
    heap, red = write(heap, red, keys[0], vals)
    red, _ = store.tick({"heap": heap}, red, 1)
    red = store.settle(red, {"heap": heap})
    jax.block_until_ready(heap)
    step = 2
    best = float("inf")
    for _ in range(max(1, reps)):
        t0 = time.perf_counter()
        for i in range(steps):
            if not quiescent:
                heap, red = write(heap, red, keys[i % len(keys)], vals)
            red, _ = store.tick({"heap": heap}, red, step, step_time=0.01)
            step += 1
        best = min(best, (time.perf_counter() - t0) / steps * 1e6)
    red = store.settle(red, {"heap": heap})
    jax.block_until_ready((heap, jax.tree.leaves(red)))
    return best


def run_overhead(steps: int = 200, n_rows: int = 2048, batch: int = 64):
    from repro.health import HealthPolicy

    keys = key_stream("uniform", 16, batch, n_rows)
    vals = jnp.ones((batch, ROW_ELEMS), jnp.float32)
    rows = []
    us = {}
    for quiescent in (False, True):
        kind = "quiescent" if quiescent else "healthy"
        for on in (False, True):
            hp = HealthPolicy(violation_mode="report") if on else None
            store, heap, red, write = _mk(n_rows, health=hp)
            u = _tick_us(store, heap, red, write, keys, vals, steps,
                         quiescent)
            us[(kind, on)] = u
            rows.append((f"health/tick_{kind}_{'on' if on else 'off'}",
                         u, f"best-of-3 mean tick wall, governor "
                            f"{'on' if on else 'off'} ({steps} ticks/pass)"))
    pct = (us[("healthy", True)] / max(us[("healthy", False)], 1e-9) - 1.0) \
        * 100.0
    # Quiescent ticks are ~10us no-ops, so a percentage there is noise
    # amplification — report the absolute bookkeeping cost instead.
    qd = us[("quiescent", True)] - us[("quiescent", False)]
    rows.append(("health/governor_overhead", 0.0,
                 f"{pct:+.1f}% added tick stall on a healthy store "
                 f"(acceptance <= 5%; quiescent bookkeeping "
                 f"{qd:+.1f}us on a {us[('quiescent', False)]:.0f}us "
                 f"no-op tick)"))
    return rows


def run_recovery(n_rows: int = 256, batch: int = 32):
    """Wedged-dispatch storm -> CRITICAL -> count ticks back to HEALTHY.

    Deterministic: the module-level in-flight probe is patched to report
    "never ready", so every async dispatch times out (rung 1), retries
    exhaust, and the breaker escalates to CRITICAL with sync escalation
    (rung 4).  The sync-escalated group then updates via the blocking
    path, accrues calm ticks, and steps down CRITICAL -> DEGRADED ->
    HEALTHY; the measured count is the hysteresis 2 x recovery_ticks.
    """
    import repro.core.store as store_mod
    from repro.health import CRITICAL, HealthPolicy

    hp = HealthPolicy(dispatch_timeout_s=1e-6, dispatch_retry_attempts=1,
                      retry_backoff_s=0.0, backpressure="none",
                      recovery_ticks=3, violation_mode="report")
    store, heap, red, write = _mk(n_rows, health=hp)
    hg = store._health
    hg._sleep = lambda s: None
    keys = key_stream("uniform", 8, batch, n_rows)
    vals = jnp.ones((batch, ROW_ELEMS), jnp.float32)
    step = 1
    for i in range(4):                       # calm warmup traffic
        heap, red = write(heap, red, keys[i % len(keys)], vals)
        red, _ = store.tick({"heap": heap}, red, step, step_time=0.01)
        step += 1

    real_ready = store_mod._ready
    store_mod._ready = lambda fits: False    # wedge the in-flight probe
    try:
        storm = 0
        while storm < 64:                    # drive until the breaker trips
            heap, red = write(heap, red, keys[step % len(keys)], vals)
            red, _ = store.tick({"heap": heap}, red, step, step_time=0.01)
            step += 1
            storm += 1
            rep = hg.last_report
            if rep is not None and rep.worst == CRITICAL:
                break
        recovery = 0
        while recovery < 200:                # calm ticks under sync escalation
            heap, red = write(heap, red, keys[step % len(keys)], vals)
            red, _ = store.tick({"heap": heap}, red, step, step_time=0.01)
            step += 1
            recovery += 1
            if hg.last_report.worst == "healthy":
                break
    finally:
        store_mod._ready = real_ready
    red = store.settle(red, {"heap": heap})
    ok = hg.last_report.worst == "healthy"
    return [("chaos/recovery_ticks", 0.0,
             f"{recovery} ticks CRITICAL->HEALTHY under wedged-dispatch "
             f"storm (tripped in {storm}; hysteresis 2x{hp.recovery_ticks} "
             f"calm ticks{'' if ok else '; WARN: never recovered'})")]


def run(steps: int = 200, n_rows: int = 2048, batch: int = 64):
    rows = run_overhead(steps=steps, n_rows=n_rows, batch=batch)
    rows.extend(run_recovery(n_rows=min(n_rows, 256)))
    return rows


if __name__ == "__main__":
    emit(run())
