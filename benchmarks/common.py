"""Benchmark substrate: a DAX-NVM-region analogue with 4 KB pages.

A "heap" of ``n_rows`` rows of 1024 fp32 elements — each row is exactly one
4 KiB block (the paper's page size; lanes_per_block=1024) — protected by the
three redundancy options. Insert/overwrite/remove/read ops mirror the
paper's PMDK/fio workloads; Pangolin-mode (sync) updates cost O(touched
rows) via the diff identities, Vilamb amortizes over the update period.

Relative throughputs reproduce the paper's claims; absolute numbers are CPU.
"""
from __future__ import annotations

import dataclasses
import sys
import time
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, "src")

from repro.core import (ALL, RedundancyConfig, RedundancyEngine,
                        block_checksums, checksum_diff, parity_diff)
from repro.core import bits, blocks as B

ROW_ELEMS = 1024          # 4 KiB fp32 rows == paper pages
LANES_PER_BLOCK = 1024    # one block per row
STRIPE = 4


@dataclasses.dataclass
class Region:
    n_rows: int = 4096
    mode: str = "none"                    # none | sync | vilamb
    period: int = 16                      # redundancy period (steps)

    def __post_init__(self):
        self.heap = jnp.zeros((self.n_rows, ROW_ELEMS), jnp.float32)
        cfg = RedundancyConfig(mode=self.mode if self.mode != "none" else "vilamb",
                               lanes_per_block=LANES_PER_BLOCK,
                               stripe_data_blocks=STRIPE)
        self.engine = RedundancyEngine(
            {"heap": jax.ShapeDtypeStruct(self.heap.shape, self.heap.dtype)}, cfg)
        self.red = self.engine.init({"heap": self.heap}) if self.mode != "none" else None
        self.meta = self.engine.metas["heap"]
        self._build()

    def _build(self):
        mode = self.mode
        engine, meta = self.engine, self.meta

        def write_none(heap, red, rows, vals):
            return heap.at[rows].set(vals), red

        def write_vilamb(heap, red, rows, vals):
            heap = heap.at[rows].set(vals)
            mask = jnp.zeros((self.n_rows,), bool).at[rows].set(True)
            red = engine.mark_dirty(red, {"heap": mask})
            return heap, red

        def write_sync(heap, red, rows, vals):
            """Pangolin: per-object diff update inline (touched rows only)."""
            old_rows = heap[rows]
            heap = heap.at[rows].set(vals)
            old_lanes = jax.lax.bitcast_convert_type(old_rows, jnp.uint32)
            new_lanes = jax.lax.bitcast_convert_type(vals, jnp.uint32)
            r = red["heap"]
            # rows == blocks: per-row checksum diff with the row's block salt
            bids = rows.astype(jnp.uint32)
            lids = jnp.arange(ROW_ELEMS, dtype=jnp.uint32)[None, :]
            from repro.core.checksum import fmix32, lane_salt
            salt = lane_salt(bids[:, None], lids)
            dck = jax.lax.reduce(
                fmix32(old_lanes ^ salt) ^ fmix32(new_lanes ^ salt),
                jnp.uint32(0), jax.lax.bitwise_xor, (1,))
            cks = r.checksums.at[rows].set(r.checksums[rows] ^ dck)
            delta = old_lanes ^ new_lanes
            sid = rows // STRIPE
            par = r.parity.at[sid].set(r.parity[sid] ^ delta)
            red = dict(red)
            import dataclasses as dc
            from repro.core.checksum import meta_checksum
            red["heap"] = dc.replace(r, checksums=cks, parity=par,
                                     meta_ck=meta_checksum(cks))
            return heap, red

        write = {"none": write_none, "vilamb": write_vilamb, "sync": write_sync}[mode]
        self.write = jax.jit(write, donate_argnums=(0, 1))
        self.read = jax.jit(lambda heap, rows: heap[rows])
        if self.mode != "none":
            self.red_step = jax.jit(
                lambda heap, red: engine.redundancy_step({"heap": heap}, red),
                donate_argnums=(1,))

    def run_writes(self, key_batches, vals) -> float:
        """Timed loop; returns wall seconds. Applies Vilamb periodicity."""
        heap, red = self.heap, self.red
        # warmup compile
        heap, red = self.write(heap, red, key_batches[0], vals)
        if self.mode == "vilamb":
            red = self.red_step(heap, red)
        jax.block_until_ready(heap)
        t0 = time.perf_counter()
        for i, rows in enumerate(key_batches[1:], 1):
            heap, red = self.write(heap, red, rows, vals)
            if self.mode == "vilamb" and i % self.period == 0:
                red = self.red_step(heap, red)
        jax.block_until_ready(heap)
        dt = time.perf_counter() - t0
        self.heap, self.red = heap, red
        return dt

    def vulnerable_stripes(self) -> int:
        if self.red is None:
            return 0
        return int(self.engine.dirty_stats(self.red)["heap"]["vulnerable_stripes"])


def key_stream(pattern: str, steps: int, batch: int, n_rows: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    out = []
    for s in range(steps):
        if pattern == "seq":
            base = (s * batch) % n_rows
            rows = (base + np.arange(batch)) % n_rows
        elif pattern == "zipf":
            z = rng.zipf(1.3, size=batch)
            rows = ((z - 1) % n_rows)
        else:  # uniform
            rows = rng.integers(0, n_rows, size=batch)
        # dedupe within a batch (scatter rules), keep batch size stable
        rows = np.unique(rows)
        if len(rows) < batch:
            fill = np.setdiff1d(np.arange(n_rows), rows)[: batch - len(rows)]
            rows = np.concatenate([rows, fill])
        out.append(jnp.asarray(np.sort(rows[:batch]).astype(np.int32)))
    return out


def emit(rows):
    for name, us, derived in rows:
        print(f"{name},{us:.2f},{derived}")
