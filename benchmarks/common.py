"""Benchmark substrate: a DAX-NVM-region analogue with 4 KB pages.

A "heap" of ``n_rows`` rows of 1024 fp32 elements — each row is exactly one
4 KiB block (the paper's page size; lanes_per_block=1024) — protected by a
:class:`repro.core.ProtectedStore`.  The store owns the redundancy
lifecycle: ``on_write`` records each write batch (dirty marks for vilamb,
the sparse row-diff for sync/Pangolin), ``tick`` applies the periodic
Algorithm-1 schedule.  Insert/overwrite/remove/read ops mirror the paper's
PMDK/fio workloads; sync costs O(touched rows) via the diff identities,
Vilamb amortizes over the update period.

Relative throughputs reproduce the paper's claims; absolute numbers are CPU.
"""
from __future__ import annotations

import dataclasses
import sys
import time
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, "src")

from repro.core import ProtectedStore, RedundancyPolicy

ROW_ELEMS = 1024          # 4 KiB fp32 rows == paper pages
LANES_PER_BLOCK = 1024    # one block per row
STRIPE = 4


@dataclasses.dataclass
class Region:
    n_rows: int = 4096
    mode: str = "none"                    # none | sync | vilamb
    period: int = 16                      # redundancy period (steps)
    # Overlap-pipelined tick (the library default).  The wall-throughput
    # benches construct blocking Regions: on this repo's shared-CPU
    # container, keeping the previous epoch's redundancy arrays alive for
    # the overlap costs defensive copies that a serial device cannot hide,
    # so raw wall numbers stay comparable with the blocking-tick baseline
    # artifact.  benchmarks/overlap.py measures the pipelined path
    # explicitly (foreground stall + end-to-end).
    pipelined: bool = False
    # Scrub patroller byte budget (0 = disabled); benchmarks/scrub_bench.py
    # and the patrolled MTTDL rows size this to hit a target sweep length.
    patrol_bytes_per_tick: int = 0

    def __post_init__(self):
        self.heap = jnp.zeros((self.n_rows, ROW_ELEMS), jnp.float32)
        policy = RedundancyPolicy.single(
            self.mode, period_steps=self.period,
            lanes_per_block=LANES_PER_BLOCK, stripe_data_blocks=STRIPE,
            async_tick=self.pipelined,
            patrol_bytes_per_tick=self.patrol_bytes_per_tick)
        self.store = ProtectedStore(policy).attach({"heap": self.heap})
        self.red = self.store.init({"heap": self.heap})
        self.meta = self.store.metas["heap"]
        # Back-compat surface for sibling benchmark modules.
        self.engine = self.store.engine_for("heap")
        self._build()

    def _build(self):
        store = self.store
        n_rows = self.n_rows

        def write(heap, red, rows, vals):
            old = heap[rows]
            heap = heap.at[rows].set(vals)
            mask = jnp.zeros((n_rows,), bool).at[rows].set(True)
            red = store.on_write(red, events={"heap": mask},
                                 row_diffs={"heap": (rows, old, vals)})
            return heap, red

        self.write = jax.jit(write, donate_argnums=(0, 1))
        self.read = jax.jit(lambda heap, rows: heap[rows])
        if store.protects:
            self.red_step = jax.jit(
                lambda heap, red: store.redundancy_step({"heap": heap}, red),
                donate_argnums=(1,))

    def run_writes(self, key_batches, vals, think_s: float = 0.0) -> float:
        """Timed loop; returns wall seconds. The store's tick applies the
        Vilamb periodicity (no-op for sync/none policies).  ``think_s``
        inserts closed-loop per-batch think time (fio ``thinktime``)."""
        heap, red = self.heap, self.red
        # warmup compile (write step + the periodic pass)
        heap, red = self.write(heap, red, key_batches[0], vals)
        if self.store.has_periodic:
            red = self.store.flush({"heap": heap}, red)
        jax.block_until_ready(heap)
        think = float(think_s)
        t0 = time.perf_counter()
        for i, rows in enumerate(key_batches[1:], 1):
            heap, red = self.write(heap, red, rows, vals)
            red, _ = self.store.tick({"heap": heap}, red, i)
            if think > 0.0:
                # Closed-loop think time (fio ``thinktime`` analogue): the
                # app core works between ops while the device core absorbs
                # whatever the tick dispatched.  Busy wait — time.sleep has
                # multi-ms granularity on this kernel.
                end = time.perf_counter() + think
                while time.perf_counter() < end:
                    pass
        # Fairness: the pipelined tick defers adoption, so settle and drain
        # every dispatched update inside the timed window.
        red = self.store.settle(red, {"heap": heap})
        jax.block_until_ready((heap, jax.tree.leaves(red)))
        dt = time.perf_counter() - t0
        self.heap, self.red = heap, red
        return dt

    def vulnerable_stripes(self) -> int:
        if not self.red:
            return 0
        return int(self.store.dirty_stats(self.red)["heap"]["vulnerable_stripes"])


def key_stream(pattern: str, steps: int, batch: int, n_rows: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    out = []
    for s in range(steps):
        if pattern == "seq":
            base = (s * batch) % n_rows
            rows = (base + np.arange(batch)) % n_rows
        elif pattern == "zipf":
            z = rng.zipf(1.3, size=batch)
            rows = ((z - 1) % n_rows)
        else:  # uniform
            rows = rng.integers(0, n_rows, size=batch)
        # dedupe within a batch (scatter rules), keep batch size stable
        rows = np.unique(rows)
        if len(rows) < batch:
            fill = np.setdiff1d(np.arange(n_rows), rows)[: batch - len(rows)]
            rows = np.concatenate([rows, fill])
        out.append(jnp.asarray(np.sort(rows[:batch]).astype(np.int32)))
    return out


def emit(rows):
    for name, us, derived in rows:
        print(f"{name},{us:.2f},{derived}")
