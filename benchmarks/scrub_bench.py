"""Scrub patroller + online shard rebuild: what the background duty costs.

The patroller (repro.scrub) trades a per-tick byte budget for detection
latency; the online rebuild trades a bounded per-tick window for a
foreground that never stops.  Rows:

  * ``scrub/patrol_tick_off`` / ``scrub/patrol_tick_on`` — mean wall per
    step of a write+tick loop with the patroller disabled vs enabled
    (same traffic), the patrol's foreground overhead.
  * ``scrub/patrol_coverage`` — ticks per full sweep at the configured
    budget (detection-latency upper bound, in ticks).
  * ``scrub/rebuild_*`` (multi-device child) — wholesale shard loss on a
    2x2x2 mesh-sharded store: ticks + wall to rebuild the shard from
    cross-shard parity while the foreground keeps writing, plus the
    foreground's per-step wall during vs before the rebuild (the measured
    stall the ``rebuild_bytes_per_tick`` budget bounds).

The multi-device leg runs in a subprocess (``--sharded-child``) because
``XLA_FLAGS=--xla_force_host_platform_device_count`` must be exported
before jax is imported — same protocol as benchmarks/overlap.py.
"""
from __future__ import annotations

import os
import subprocess
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from .common import ROW_ELEMS, Region, key_stream

SHARDED_DEVICES = 8
ROW_BYTES = ROW_ELEMS * 4


def _measure_patrol(patrol_rows_per_tick: int, steps: int, n_rows: int,
                    batch: int, period: int):
    r = Region(n_rows=n_rows, mode="vilamb", period=period,
               patrol_bytes_per_tick=patrol_rows_per_tick * ROW_BYTES)
    keys = key_stream("uniform", steps + 1, batch, n_rows)
    vals = jnp.ones((batch, ROW_ELEMS), jnp.float32)
    heap, red = r.heap, r.red
    heap, red = r.write(heap, red, keys[0], vals)
    red = r.store.flush({"heap": heap}, red)
    jax.block_until_ready(heap)
    t0 = time.perf_counter()
    for i in range(1, steps + 1):
        heap, red = r.write(heap, red, keys[i], vals)
        red, rep = r.store.tick({"heap": heap}, red, i, scrub_period=0)
        if rep.repaired:
            heap = rep.repaired.get("heap", heap)
    red = r.store.settle(red, {"heap": heap})
    jax.block_until_ready((heap, jax.tree.leaves(red)))
    wall_us = (time.perf_counter() - t0) / steps * 1e6
    pat = r.store.patroller
    swept = pat.sweeps["heap"] if pat is not None else 0
    return wall_us, swept


def sharded_child(steps: int, n_rows: int, batch: int, period: int) -> None:
    """Child entry: shard-loss rebuild rows (stdout CSV is the protocol)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.core import ProtectedStore, RedundancyPolicy
    from repro.faults.inject import FaultSpec
    from repro.launch.mesh import make_mesh

    mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
    spec = P(("pod", "data", "model"), None)
    pol = RedundancyPolicy.single(
        "vilamb", period_steps=period, lanes_per_block=1024,
        stripe_data_blocks=4, work_queue_frac=0.0,
        patrol_bytes_per_tick=(n_rows // 8) * ROW_BYTES,
        precompile=False)
    heap = jnp.zeros((n_rows, ROW_ELEMS), jnp.float32)
    store = ProtectedStore(pol, mesh=mesh).attach(
        {"heap": heap}, specs={"heap": spec})
    heap = jax.device_put(heap, NamedSharding(mesh, spec))
    red = store.init({"heap": heap})
    k = store.shard_factor("heap")
    rows_local = n_rows // k
    batch = min(batch, rows_local)   # key_stream can't exceed the key space
    keys = key_stream("uniform", 2 * steps + 2, batch, rows_local)
    vals = jnp.ones((batch, ROW_ELEMS), jnp.float32)

    def write(heap, red, rows):
        heap = heap.at[rows].set(vals)
        mask = jnp.zeros((n_rows,), bool).at[rows].set(True)
        return heap, store.on_write(red, events={"heap": mask})

    step = 0
    # Warm + settle, then sweep until cross-shard parity covers the heap.
    for i in range(4):
        heap, red = write(heap, red, keys[i])
        red, _ = store.tick({"heap": heap}, red, step); step += 1
    red = store.flush({"heap": heap}, red, step)
    pat = store.patroller

    def covered() -> bool:
        # Probes racing live writes fail xpar adoption, so sweep counts
        # under-promise; full xvalid is the real rebuild precondition.
        xp = pat.xpar.get("heap")
        return xp is not None and bool(xp.xvalid.all())

    for _ in range(64):
        red, _ = store.tick({"heap": heap}, red, step); step += 1
        if covered():
            break

    # Baseline foreground wall per step (writes into the soon-lost shard).
    lost = 2
    base = jnp.asarray(np.arange(lost * rows_local, (lost + 1) * rows_local))
    def lost_rows(i):
        return base[np.asarray(keys[i]) % rows_local]
    jax.block_until_ready(heap)
    t0 = time.perf_counter()
    for i in range(steps):
        heap, red = write(heap, red, lost_rows(i))
        red, rep = store.tick({"heap": heap}, red, step); step += 1
    jax.block_until_ready(heap)
    before_us = (time.perf_counter() - t0) / steps * 1e6
    red = store.flush({"heap": heap}, red, step)
    for _ in range(64):
        red, _ = store.tick({"heap": heap}, red, step); step += 1
        if covered():
            break

    # Lose a shard wholesale; keep writing into it while it rebuilds.
    lv, red = store.inject({"heap": heap}, red, FaultSpec(
        kind="shard_loss", leaf="heap", block=lost))
    heap = lv["heap"]
    store.declare_shard_lost("heap", lost, red)
    rebuild_ticks = None
    t0 = time.perf_counter()
    i = 0
    while rebuild_ticks is None and i < 4 * steps:
        heap, red = write(heap, red, lost_rows(steps + i))
        red, rep = store.tick({"heap": heap}, red, step); step += 1
        if rep.repaired:
            heap = rep.repaired.get("heap", heap)
        if rep.rebuild is not None and rep.rebuild.done:
            rebuild_ticks = rep.rebuild.ticks
        i += 1
    jax.block_until_ready(heap)
    during_us = (time.perf_counter() - t0) / max(i, 1) * 1e6
    shard_bytes = rows_local * ROW_BYTES
    if rebuild_ticks is None:
        print("scrub/rebuild_ERROR,0.0,rebuild did not finish in budget")
        return
    wall_s = during_us * 1e-6 * i
    mb_s = shard_bytes / max(wall_s, 1e-9) / 1e6
    stall = during_us / max(before_us, 1e-9)
    for name, us, derived in (
            ("scrub/rebuild_ticks", 0.0,
             f"{rebuild_ticks} ticks to rebuild {shard_bytes >> 10} KiB "
             f"shard ({SHARDED_DEVICES} host devices)"),
            ("scrub/rebuild_throughput", during_us,
             f"{mb_s:.2f} MB/s reconstructed while foreground wrote "
             "into the lost shard"),
            ("scrub/rebuild_stall", 0.0,
             f"{stall:.2f}x foreground step wall during rebuild "
             f"(before {before_us:.0f} us -> during {during_us:.0f} us)")):
        print(f"{name},{us:.2f},{derived}")


def _sharded_rows(steps: int, n_rows: int, batch: int, period: int):
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(
        os.environ,
        XLA_FLAGS=f"--xla_force_host_platform_device_count={SHARDED_DEVICES}",
        PYTHONPATH=os.path.join(root, "src") + os.pathsep
        + os.environ.get("PYTHONPATH", ""))
    cmd = [sys.executable, "-m", "benchmarks.scrub_bench", "--sharded-child",
           str(steps), str(n_rows), str(batch), str(period)]
    try:
        r = subprocess.run(cmd, env=env, capture_output=True, text=True,
                           timeout=1800, cwd=root)
    except Exception as e:  # keep the harness running without the rows
        return [("scrub/rebuild_ERROR", 0.0, f"spawn failed: {e}")]
    if r.returncode != 0:
        return [("scrub/rebuild_ERROR", 0.0,
                 f"exit {r.returncode}: {r.stderr.strip()[-200:]}")]
    rows = []
    for line in r.stdout.splitlines():
        if line.startswith("scrub/"):
            name, us, derived = line.split(",", 2)
            rows.append((name, float(us), derived))
    return rows


def run(steps: int = 96, n_rows: int = 2048, batch: int = 32,
        period: int = 4, sweep_ticks: int = 16, sharded_steps: int = 24,
        sharded_rows: int = 256):
    off, _ = _measure_patrol(0, steps, n_rows, batch, period)
    budget_rows = max(1, n_rows // sweep_ticks)
    on, swept = _measure_patrol(budget_rows, steps, n_rows, batch, period)
    overhead = (on - off) / max(off, 1e-9) * 100.0
    rows = [
        ("scrub/patrol_tick_off", off, "wall us/step, patroller disabled"),
        ("scrub/patrol_tick_on", on,
         f"wall us/step at {budget_rows * ROW_BYTES >> 10} KiB/tick budget "
         f"({overhead:+.1f}% vs off)"),
        ("scrub/patrol_coverage", 0.0,
         f"{swept} full sweeps in {steps} ticks "
         f"(target sweep {sweep_ticks} ticks; latency bound = one sweep)"),
    ]
    return rows + _sharded_rows(sharded_steps, sharded_rows, batch, period)


if __name__ == "__main__":
    if len(sys.argv) > 1 and sys.argv[1] == "--sharded-child":
        sharded_child(*map(int, sys.argv[2:6]))
    else:
        from .common import emit
        emit(run())
