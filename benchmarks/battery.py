"""§4.7 analogue: preemption-flush budget (the paper's battery sizing).

Measures the worst-case redundancy flush after a period of dirty
accumulation, projects it onto TPU v5e HBM bandwidth via the policy model,
and prices the paper's battery equivalents for reference.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from .common import Region, emit, key_stream
from repro.core import policy


def run(n_rows: int = 8192):
    rows = []
    for wl, pattern, period in (("ycsb_a_like", "zipf", 16),
                                ("rtree_like_sparse", "uniform", 16),
                                ("fio_random_p60", "uniform", 60)):
        r = Region(n_rows=n_rows, mode="vilamb", period=period)
        keys = key_stream(pattern, period + 1, 256, n_rows)
        vals = jnp.ones((256, 1024), jnp.float32)
        heap, red = r.heap, r.red
        _ = r.red_step(heap, jax.tree.map(jnp.copy, red))  # warm (donating a copy)
        for i in range(period):          # accumulate a full period of dirt
            heap, red = r.write(heap, red, keys[i], vals)
        jax.block_until_ready(heap)
        stats = jax.tree.map(int, r.engine.dirty_stats(red))
        est = policy.estimate_flush(stats, {"heap": r.meta.bytes_per_block},
                                    r.meta.stripe_data_blocks)
        t0 = time.perf_counter()
        red = r.red_step(heap, red)
        jax.block_until_ready(jax.tree.leaves(red))
        wall = time.perf_counter() - t0
        rows.append((f"battery/{wl}/flush_wall", wall * 1e6,
                     f"{stats['heap']['dirty_blocks']} dirty pages"))
        rows.append((f"battery/{wl}/flush_v5e_model", est.seconds * 1e6,
                     f"{est.energy_kj*1e3:.3f} J @500W; "
                     f"ultracap ${est.ultracap_dollars:.4f} "
                     f"liion ${est.liion_dollars:.6f}"))
    return rows


if __name__ == "__main__":
    emit(run())
