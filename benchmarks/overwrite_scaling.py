"""Fig. 7 analogue: overwrite throughput vs thread count (64 B / 4 KB)."""
from __future__ import annotations

import jax.numpy as jnp

from .common import Region, emit, key_stream


def run(steps: int = 20, n_rows: int = 4096):
    rows = []
    results = {}
    for size_name, elems in (("64B", 16), ("4KB", 1024)):
        for threads in (1, 8, 32):
            batch = 8 * threads
            for mode, period in (("none", 0), ("sync", 0), ("vilamb", 8)):
                r = Region(n_rows=n_rows, mode=mode, period=max(period, 1))
                keys = key_stream("uniform", steps + 1, batch, n_rows)
                vals = jnp.full((batch, 1024), 2.0, jnp.float32)
                dt = r.run_writes(keys, vals)
                ops = steps * batch / dt
                results[(size_name, mode, threads)] = ops
                rows.append((f"fig7_overwrite/{size_name}/{mode}/threads{threads}",
                             dt / steps * 1e6, f"{ops:.0f} ops/s"))
    for size_name in ("64B", "4KB"):
        v = results[(size_name, "vilamb", 32)] / results[(size_name, "sync", 32)]
        rows.append((f"fig7_overwrite/{size_name}/vilamb_over_pangolin_32t", 0.0,
                     f"{v:.2f}x"))
    return rows


if __name__ == "__main__":
    emit(run())
