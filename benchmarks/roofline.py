"""§Roofline: assemble the per-(arch x shape x mesh) table from dry-run JSON."""
from __future__ import annotations

import json
import pathlib

RESULTS = pathlib.Path(__file__).resolve().parents[1] / "results" / "dryrun"


def load_records(mesh: str = "single"):
    recs = []
    for f in sorted(RESULTS.glob(f"*__{mesh}.json")):
        recs.append(json.loads(f.read_text()))
    return recs


def frac_of(r) -> float:
    """Roofline fraction; decode cells use the memory-bound form (useful
    bytes = params+caches read once / HLO bytes), since one-token steps have
    negligible FLOPs by construction."""
    rl = r["roofline"]
    if r["shape"].endswith(("decode_32k", "long_500k")) or r["shape"].startswith(("decode", "long")):
        hm = r.get("hbm_model", {})
        useful_bytes = hm.get("params", 0) + hm.get("caches", 0)
        if useful_bytes and rl["hlo_bytes_per_chip"]:
            mem_frac = useful_bytes / rl["hlo_bytes_per_chip"]
            # bound by the dominant term: memory vs collective
            dom = max(rl["memory_s"], rl["collective_s"], rl["compute_s"])
            return mem_frac * rl["memory_s"] / dom
    return rl["roofline_fraction"]


def table(mesh: str = "single") -> str:
    lines = [
        "| arch | shape | compute s | memory s | collective s | bottleneck | "
        "MODEL/HLO | roofline frac | fits 16G | accum |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in load_records(mesh):
        if r["status"] != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                         f"{r['status']} | — | — | — | — |")
            continue
        rl = r["roofline"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {rl['compute_s']:.3f} | "
            f"{rl['memory_s']:.3f} | {rl['collective_s']:.3f} | "
            f"{rl['bottleneck']} | {rl['useful_ratio']:.2f} | "
            f"{frac_of(r):.4f} | {r.get('fits_16g')} | "
            f"{r.get('accum_steps', 1)} |")
    return "\n".join(lines)


def run():
    rows = []
    for r in load_records("single"):
        if r["status"] != "ok":
            continue
        rl = r["roofline"]
        rows.append((f"roofline/{r['arch']}/{r['shape']}", 0.0,
                     f"{rl['bottleneck']}-bound frac={rl['roofline_fraction']:.4f}"))
    if not rows:
        rows.append(("roofline/none", 0.0, "run repro.launch.dryrun first"))
    return rows


if __name__ == "__main__":
    print(table("single"))
