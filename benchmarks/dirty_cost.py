"""Fig. 9 analogue: cost of checking/clearing dirty bits vs batch size and
region size.

The paper's syscall/page-walk/TLB components become: mark (scatter-OR into
the packed bitvector), snapshot+clear, and the masked redundancy update the
bits gate. Batching -> bitvector word granularity per op.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from .common import Region, emit, key_stream
from repro.core import bits


def _timed(fn, *args, iters=100):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def run():
    rows = []
    # (a) region-size scaling at fixed batch (paper fig 9a)
    for n_rows in (1024, 4096, 16384):
        r = Region(n_rows=n_rows, mode="vilamb", period=1)
        keys = key_stream("uniform", 2, 512, n_rows)[0]
        mark = jax.jit(lambda red, k: r.engine.mark_dirty(
            red, {"heap": jnp.zeros((n_rows,), bool).at[k].set(True)}))
        us = _timed(mark, r.red, keys)
        rows.append((f"fig9a_dirty_mark/rows{n_rows}", us, f"{n_rows*4096//2**20} MiB region"))
        heap, red = r.write(r.heap, r.red, keys, jnp.ones((512, 1024)))
        us2 = _timed(lambda h, rd: r.engine.redundancy_step({"heap": h}, rd), heap, red)
        rows.append((f"fig9a_check_clear_update/rows{n_rows}", us2,
                     "snapshot+clear+masked update"))
    # (b) batch-size scaling at fixed region (paper fig 9b)
    n_rows = 8192
    for batch in (32, 128, 512, 2048):
        r = Region(n_rows=n_rows, mode="vilamb", period=1)
        keys = key_stream("uniform", 2, batch, n_rows)[0]
        heap, red = r.write(r.heap, r.red, keys, jnp.ones((batch, 1024)))
        us = _timed(lambda h, rd: r.engine.redundancy_step({"heap": h}, rd), heap, red)
        rows.append((f"fig9b_update_batch/batch{batch}", us,
                     f"{us/batch:.2f} us/page amortized"))
    return rows


if __name__ == "__main__":
    emit(run())
