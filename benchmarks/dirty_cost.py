"""Fig. 9 analogue: cost of checking/clearing dirty bits vs batch size and
region size, plus the dirty-fraction sweep of the work-queue path.

The paper's syscall/page-walk/TLB components become: mark (scatter-OR into
the packed bitvector), snapshot+clear, and the masked redundancy update the
bits gate. Batching -> bitvector word granularity per op.

``fig9c_dirty_fraction`` is the paper's central scaling claim on the
default (non-Pallas) XLA path: ``redundancy_step`` cost must track the
*dirty* fraction, not the region size.  Sparse fractions dispatch the
work-queue program (core/workqueue.py) exactly as ``ProtectedStore.tick``
does — via the host-side ``queue_fits`` check — and dense fractions fall
back to the full recompute, so the sweep times what production runs.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from .common import Region, emit, key_stream
from repro.core import bits


def _timed(fn, *args, iters=100, repeats=1):
    """us/call: best-of-``repeats`` round means (min cuts scheduler noise)."""
    out = fn(*args)
    jax.block_until_ready(out)
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        for _ in range(iters):
            out = fn(*args)
        jax.block_until_ready(out)
        best = min(best, (time.perf_counter() - t0) / iters * 1e6)
    return best


def sweep(n_rows: int = 8192, fracs=(0.01, 0.05, 0.125, 0.5, 1.0),
          iters: int = 20):
    """Dirty-fraction sweep of Algorithm 1 on the default XLA path.

    Emits one row per fraction with the time relative to an explicitly
    measured 100%-dirty full-recompute reference; the acceptance bar is
    1% dirty <= 25% of full.  Each timed call includes the host-side
    ``queue_fits`` dispatch check, exactly as ``ProtectedStore.tick`` pays
    it per firing update.
    """
    r = Region(n_rows=n_rows, mode="vilamb", period=1)
    eng = r.engine
    step_full = jax.jit(lambda h, rd: eng.redundancy_step({"heap": h}, rd))
    step_queued = jax.jit(
        lambda h, rd: eng.redundancy_step_queued({"heap": h}, rd))

    def dispatch(h, rd):                    # == store._run_update's decision
        return (step_queued if eng.queue_fits(rd) else step_full)(h, rd)

    def dirty_red(k):
        # Contiguous dirty run: dirty-stripe fraction == dirty-row fraction
        # (spread single rows would touch one stripe each, inflating the
        # stripe fraction 4x past the row fraction).
        mask = jnp.zeros((n_rows,), bool).at[jnp.arange(k)].set(True)
        return eng.mark_dirty(r.red, {"heap": mask})

    full_us = _timed(dispatch, r.heap, dirty_red(n_rows),
                     iters=iters, repeats=5)
    rows = []
    for frac in fracs:
        red = dirty_red(max(1, int(n_rows * frac)))
        fits = eng.queue_fits(red)
        us = (full_us if frac >= 1.0 else
              _timed(dispatch, r.heap, red, iters=iters, repeats=5))
        rows.append((
            f"fig9c_dirty_fraction/f{frac:g}", us,
            f"{100.0 * us / full_us:.0f}% of full; "
            f"{'queued' if fits else 'full'} dispatch"))
    return rows


def run(n_rows: int = 16384, iters: int = 50, sweep_rows: int = 8192):
    rows = []
    # (a) region-size scaling at fixed batch (paper fig 9a); n_rows caps the
    # largest region (smoke mode) without dropping or duplicating points
    sizes = [s for s in (1024, 4096, 16384) if s <= n_rows]
    if n_rows not in sizes:
        sizes.append(n_rows)
    for nr in sizes:
        r = Region(n_rows=nr, mode="vilamb", period=1)
        keys = key_stream("uniform", 2, 512, nr)[0]
        mark = jax.jit(lambda red, k, r=r, nr=nr: r.engine.mark_dirty(
            red, {"heap": jnp.zeros((nr,), bool).at[k].set(True)}))
        us = _timed(mark, r.red, keys, iters=iters)
        rows.append((f"fig9a_dirty_mark/rows{nr}", us, f"{nr*4096//2**20} MiB region"))
        heap, red = r.write(r.heap, r.red, keys, jnp.ones((512, 1024)))
        step = jax.jit(lambda h, rd, r=r: r.engine.redundancy_step({"heap": h}, rd))
        us2 = _timed(step, heap, red, iters=iters)
        rows.append((f"fig9a_check_clear_update/rows{nr}", us2,
                     "snapshot+clear+masked update"))
    # (b) batch-size scaling at fixed region (paper fig 9b)
    nr = min(8192, n_rows)
    for batch in (32, 128, 512, 2048):
        r = Region(n_rows=nr, mode="vilamb", period=1)
        keys = key_stream("uniform", 2, batch, nr)[0]
        heap, red = r.write(r.heap, r.red, keys, jnp.ones((batch, 1024)))
        step = jax.jit(lambda h, rd, r=r: r.engine.redundancy_step({"heap": h}, rd))
        us = _timed(step, heap, red, iters=iters)
        rows.append((f"fig9b_update_batch/batch{batch}", us,
                     f"{us/batch:.2f} us/page amortized"))
    # (c) dirty-fraction scaling of the work-queue path (paper fig 9 claim);
    # pinned at a representative region size — at tiny regions fixed dispatch
    # overheads dominate and the ratio stops reflecting the ∝-dirty scaling
    rows.extend(sweep(n_rows=sweep_rows, iters=max(10, iters // 5)))
    return rows


if __name__ == "__main__":
    emit(run())
