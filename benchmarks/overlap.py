"""Overlap pipeline: what the redundancy path costs the foreground thread.

The paper's headline is *asynchronous* redundancy — background updates
overlapped with foreground writes.  The quantity the overlap pipeline
changes is the **foreground stall**: the time the application thread spends
inside ``store.tick`` per step.  The blocking tick (PR2, ``async_tick=
False``) pays a host-side ``queue_fits`` round trip on every due tick,
which drains the whole dispatch pipeline before the update can even
launch; the overlap-pipelined tick (PR3 default) costs one speculative
dispatch plus a non-blocking flag read.

Measured per step over a write+tick loop at period 4:

  * ``overlap/tick_stall_*``  — mean host time inside ``tick`` (the
    foreground redundancy overhead; p99 in ``derived`` shows the due-tick
    spike).  **Headline**: ``overlap/overhead_reduction`` is the ratio of
    blocking vs pipelined stall over the ``none`` baseline — the
    acceptance bar is >= 2x.
  * ``overlap/endtoend_*``    — full wall clock per step, for context.  On
    this repo's 2-core CPU container the "device" shares cores with the
    host and the two variants execute bitwise-identical update programs,
    so end-to-end wall is device-bound and near-equal here; on an
    accelerator (device compute does not steal host cycles) the stall
    difference converts 1:1 into step time.

Both variants settle and drain every dispatched update inside the timed
window, so the comparison is work-for-work fair.

The ``overlap_sharded/*`` rows repeat the stall comparison on a 2x2x2
host-device mesh (per-shard work queues, AND-folded fit flag): the
multi-device run happens in a subprocess because
``XLA_FLAGS=--xla_force_host_platform_device_count`` must be exported
before jax is imported.
"""
from __future__ import annotations

import os
import subprocess
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from .common import ROW_ELEMS, Region, key_stream

SHARDED_DEVICES = 8


def _measure(mode: str, pipelined: bool, steps: int, n_rows: int,
             batch: int, period: int):
    r = Region(n_rows=n_rows, mode=mode, period=period, pipelined=pipelined)
    keys = key_stream("uniform", steps + 1, batch, n_rows)
    vals = jnp.ones((batch, ROW_ELEMS), jnp.float32)
    heap, red = r.heap, r.red
    heap, red = r.write(heap, red, keys[0], vals)
    if r.store.has_periodic:
        red = r.store.flush({"heap": heap}, red)
    jax.block_until_ready(heap)
    ticks = []
    t0 = time.perf_counter()
    for i, rows in enumerate(keys[1:], 1):
        heap, red = r.write(heap, red, rows, vals)
        s0 = time.perf_counter()
        red, _ = r.store.tick({"heap": heap}, red, i)
        ticks.append(time.perf_counter() - s0)
    red = r.store.settle(red, {"heap": heap})
    jax.block_until_ready((heap, jax.tree.leaves(red)))
    wall_us = (time.perf_counter() - t0) / steps * 1e6
    t = np.asarray(ticks) * 1e6
    return float(t.mean()), float(np.percentile(t, 99)), wall_us


def _measure_sharded(pipelined, steps: int, n_rows: int, batch: int,
                     period: int, mode: str = "vilamb"):
    """One sharded stall measurement (runs inside the 8-device child)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.core import ProtectedStore, RedundancyPolicy
    from repro.launch.mesh import make_mesh

    mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
    spec = P(("pod", "data", "model"), None)
    pol = RedundancyPolicy.single(mode, period_steps=period,
                                  async_tick=pipelined)
    store = ProtectedStore(pol, mesh=mesh).attach(
        {"heap": jax.ShapeDtypeStruct((n_rows, ROW_ELEMS), jnp.float32)},
        specs={"heap": spec})
    heap = jax.device_put(
        jax.random.normal(jax.random.PRNGKey(0), (n_rows, ROW_ELEMS),
                          jnp.float32), NamedSharding(mesh, spec))
    red = store.init({"heap": heap}) if store.protects else {}
    rng = np.random.default_rng(0)
    all_rows = [jnp.asarray(np.sort(rng.choice(n_rows, batch, replace=False)))
                for _ in range(steps + 1)]
    heap = heap.at[all_rows[0]].add(1.0)
    if store.has_periodic:
        red = store.flush({"heap": heap}, red)
    jax.block_until_ready(heap)
    ticks = []
    t0 = time.perf_counter()
    for i, rows in enumerate(all_rows[1:], 1):
        heap = heap.at[rows].add(1.0)
        if store.protects:
            ev = jnp.zeros((n_rows,), bool).at[rows].set(True)
            red = store.on_write(red, events={"heap": ev})
        s0 = time.perf_counter()
        red, _ = store.tick({"heap": heap}, red, i)
        ticks.append(time.perf_counter() - s0)
    if store.protects:
        red = store.settle(red, {"heap": heap})
    jax.block_until_ready((heap, jax.tree.leaves(red)))
    wall_us = (time.perf_counter() - t0) / steps * 1e6
    t = np.asarray(ticks) * 1e6
    return float(t.mean()), float(np.percentile(t, 99)), wall_us


def sharded_child(steps: int, n_rows: int, batch: int, period: int) -> None:
    """Child entry: print the sharded CSV rows (stdout is the protocol)."""
    n = _measure_sharded(True, steps, n_rows, batch, period, mode="none")
    b = _measure_sharded(False, steps, n_rows, batch, period)
    p = _measure_sharded(True, steps, n_rows, batch, period)
    noise_us = 5.0
    ratio = max(b[0] - n[0], noise_us) / max(p[0] - n[0], noise_us)
    dev = f"{SHARDED_DEVICES} host devices, per-shard queues"
    for name, us, derived in (
            ("overlap_sharded/tick_stall_none", n[0],
             f"p99 {n[1]:.0f} us (baseline; {dev})"),
            ("overlap_sharded/tick_stall_blocking", b[0],
             f"p99 {b[1]:.0f} us; per-shard queue_fits round trip"),
            ("overlap_sharded/tick_stall_pipelined", p[0],
             f"p99 {p[1]:.0f} us; AND-folded fit flag fetched a tick ahead"),
            ("overlap_sharded/overhead_reduction", 0.0,
             f"{ratio:.2f}x sharded foreground stall cut")):
        print(f"{name},{us:.2f},{derived}")


def _sharded_rows(steps: int, n_rows: int, batch: int, period: int):
    """Spawn the multi-device child and parse its CSV rows.

    Paths are anchored off ``__file__`` (never the caller's cwd) so the
    rows survive ``python -m benchmarks.run`` launched from anywhere.
    """
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(
        os.environ,
        XLA_FLAGS=f"--xla_force_host_platform_device_count={SHARDED_DEVICES}",
        PYTHONPATH=os.path.join(root, "src") + os.pathsep
        + os.environ.get("PYTHONPATH", ""))
    cmd = [sys.executable, "-m", "benchmarks.overlap", "--sharded-child",
           str(steps), str(n_rows), str(batch), str(period)]
    try:
        r = subprocess.run(cmd, env=env, capture_output=True, text=True,
                           timeout=1800, cwd=root)
    except Exception as e:  # keep the harness running without the rows
        return [("overlap_sharded/ERROR", 0.0, f"spawn failed: {e}")]
    if r.returncode != 0:
        return [("overlap_sharded/ERROR", 0.0,
                 f"exit {r.returncode}: {r.stderr.strip()[-200:]}")]
    rows = []
    for line in r.stdout.splitlines():
        if line.startswith("overlap_sharded/"):
            name, us, derived = line.split(",", 2)
            rows.append((name, float(us), derived))
    return rows


def run(steps: int = 240, n_rows: int = 4096, batch: int = 32,
        period: int = 4, repeats: int = 2, sharded_steps: int = 120):
    best = {}
    for name, mode, pipelined in (("none", "none", True),
                                  ("blocking", "vilamb", False),
                                  ("pipelined", "vilamb", True)):
        runs = [_measure(mode, pipelined, steps, n_rows, batch, period)
                for _ in range(repeats)]
        best[name] = min(runs, key=lambda x: x[0])   # least-noise run
    n, b, p = best["none"], best["blocking"], best["pipelined"]
    # Floor both stalls at the timer/scheduler noise level so a lucky run
    # where the pipelined mean dips below the baseline cannot report an
    # unbounded (meaningless) reduction.
    noise_us = 5.0
    stall_blk = max(b[0] - n[0], noise_us)
    stall_pipe = max(p[0] - n[0], noise_us)
    ratio = stall_blk / stall_pipe
    return [
        ("overlap/tick_stall_none", n[0], f"p99 {n[1]:.0f} us (baseline)"),
        ("overlap/tick_stall_blocking", b[0],
         f"p99 {b[1]:.0f} us; queue_fits round trip each due tick"),
        ("overlap/tick_stall_pipelined", p[0],
         f"p99 {p[1]:.0f} us; sync-free speculative dispatch"),
        ("overlap/overhead_reduction", 0.0,
         f"{ratio:.2f}x foreground stall cut (bar: >= 2x)"),
        ("overlap/endtoend_none", n[2], "wall us/step"),
        ("overlap/endtoend_blocking", b[2],
         "wall us/step (device-bound on shared-CPU container)"),
        ("overlap/endtoend_pipelined", p[2],
         "wall us/step (identical device work by construction)"),
    ] + _sharded_rows(sharded_steps, n_rows, batch, period)


if __name__ == "__main__":
    if len(sys.argv) > 1 and sys.argv[1] == "--sharded-child":
        sharded_child(*map(int, sys.argv[2:6]))
    else:
        from .common import emit
        emit(run())
