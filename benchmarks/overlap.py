"""Overlap pipeline: what the redundancy path costs the foreground thread.

The paper's headline is *asynchronous* redundancy — background updates
overlapped with foreground writes.  The quantity the overlap pipeline
changes is the **foreground stall**: the time the application thread spends
inside ``store.tick`` per step.  The blocking tick (PR2, ``async_tick=
False``) pays a host-side ``queue_fits`` round trip on every due tick,
which drains the whole dispatch pipeline before the update can even
launch; the overlap-pipelined tick (PR3 default) costs one speculative
dispatch plus a non-blocking flag read.

Measured per step over a write+tick loop at period 4:

  * ``overlap/tick_stall_*``  — mean host time inside ``tick`` (the
    foreground redundancy overhead; p99 in ``derived`` shows the due-tick
    spike).  **Headline**: ``overlap/overhead_reduction`` is the ratio of
    blocking vs pipelined stall over the ``none`` baseline — the
    acceptance bar is >= 2x.
  * ``overlap/endtoend_*``    — full wall clock per step, for context.  On
    this repo's 2-core CPU container the "device" shares cores with the
    host and the two variants execute bitwise-identical update programs,
    so end-to-end wall is device-bound and near-equal here; on an
    accelerator (device compute does not steal host cycles) the stall
    difference converts 1:1 into step time.

Both variants settle and drain every dispatched update inside the timed
window, so the comparison is work-for-work fair.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from .common import ROW_ELEMS, Region, key_stream


def _measure(mode: str, pipelined: bool, steps: int, n_rows: int,
             batch: int, period: int):
    r = Region(n_rows=n_rows, mode=mode, period=period, pipelined=pipelined)
    keys = key_stream("uniform", steps + 1, batch, n_rows)
    vals = jnp.ones((batch, ROW_ELEMS), jnp.float32)
    heap, red = r.heap, r.red
    heap, red = r.write(heap, red, keys[0], vals)
    if r.store.has_periodic:
        red = r.store.flush({"heap": heap}, red)
    jax.block_until_ready(heap)
    ticks = []
    t0 = time.perf_counter()
    for i, rows in enumerate(keys[1:], 1):
        heap, red = r.write(heap, red, rows, vals)
        s0 = time.perf_counter()
        red, _ = r.store.tick({"heap": heap}, red, i)
        ticks.append(time.perf_counter() - s0)
    red = r.store.settle(red, {"heap": heap})
    jax.block_until_ready((heap, jax.tree.leaves(red)))
    wall_us = (time.perf_counter() - t0) / steps * 1e6
    t = np.asarray(ticks) * 1e6
    return float(t.mean()), float(np.percentile(t, 99)), wall_us


def run(steps: int = 240, n_rows: int = 4096, batch: int = 32,
        period: int = 4, repeats: int = 2):
    best = {}
    for name, mode, pipelined in (("none", "none", True),
                                  ("blocking", "vilamb", False),
                                  ("pipelined", "vilamb", True)):
        runs = [_measure(mode, pipelined, steps, n_rows, batch, period)
                for _ in range(repeats)]
        best[name] = min(runs, key=lambda x: x[0])   # least-noise run
    n, b, p = best["none"], best["blocking"], best["pipelined"]
    # Floor both stalls at the timer/scheduler noise level so a lucky run
    # where the pipelined mean dips below the baseline cannot report an
    # unbounded (meaningless) reduction.
    noise_us = 5.0
    stall_blk = max(b[0] - n[0], noise_us)
    stall_pipe = max(p[0] - n[0], noise_us)
    ratio = stall_blk / stall_pipe
    return [
        ("overlap/tick_stall_none", n[0], f"p99 {n[1]:.0f} us (baseline)"),
        ("overlap/tick_stall_blocking", b[0],
         f"p99 {b[1]:.0f} us; queue_fits round trip each due tick"),
        ("overlap/tick_stall_pipelined", p[0],
         f"p99 {p[1]:.0f} us; sync-free speculative dispatch"),
        ("overlap/overhead_reduction", 0.0,
         f"{ratio:.2f}x foreground stall cut (bar: >= 2x)"),
        ("overlap/endtoend_none", n[2], "wall us/step"),
        ("overlap/endtoend_blocking", b[2],
         "wall us/step (device-bound on shared-CPU container)"),
        ("overlap/endtoend_pipelined", p[2],
         "wall us/step (identical device work by construction)"),
    ]


if __name__ == "__main__":
    from .common import emit
    emit(run())
