"""Overlap pipeline: what the redundancy path costs the foreground thread.

The paper's headline is *asynchronous* redundancy — background updates
overlapped with foreground writes.  The quantity the overlap pipeline
changes is the **foreground stall**: the time the application thread spends
inside ``store.tick`` per step.  The blocking tick (PR2, ``async_tick=
False``) pays a host-side ``queue_fits`` round trip on every due tick,
which drains the whole dispatch pipeline before the update can even
launch; the overlap-pipelined tick (PR3 default) costs one speculative
dispatch plus a non-blocking flag read.

Measured per step over a write+tick loop at period 4.  Every mode —
``none`` baseline, blocking, pipelined — runs the **same untimed warm
loop** (``2 * period + 1`` write+tick steps, then settle) before its
timed window, so compilation of the tick path (including the batched
multi-group update program and the resolver-thread spin-up for the
pipelined variant) never lands inside the measurement:

  * ``overlap/tick_stall_*``  — mean host time inside ``tick`` (the
    foreground redundancy overhead; ``derived`` repeats the mean next to
    the p99 so the due-tick spike is visible).  **Headline**:
    ``overlap/overhead_reduction`` is the ratio of blocking vs pipelined
    stall over the ``none`` baseline, computed from the *means* — the
    same statistic the ``tick_stall_*`` value column prints — with the
    p99-based ratio quoted alongside in ``derived``.  The acceptance bar
    is >= 2x.
  * ``overlap/endtoend_*``    — full wall clock per step, for context.  On
    this repo's 2-core CPU container the "device" shares cores with the
    host and the two variants execute bitwise-identical update programs,
    so end-to-end wall is device-bound and near-equal here; on an
    accelerator (device compute does not steal host cycles) the stall
    difference converts 1:1 into step time.

Both variants settle and drain every dispatched update inside the timed
window, so the comparison is work-for-work fair.

The ``overlap_sharded/*`` rows repeat the stall comparison on a 2x2x2
host-device mesh (per-shard work queues).  Here the pipelined tick
launches ONE batched multi-group update program per due tick and hands
the single stacked fit vector to the resolver thread, which fetches and
AND-folds it off the critical path — versus the blocking tick's
per-group ``queue_fits`` round trips.  The sharded leg uses its own
(larger) ``sharded_rows``/``sharded_batch`` shapes: with toy shapes the
per-due-tick update work is negligible and both modes degenerate to the
same per-array dispatch overhead, hiding exactly the regression this row
guards.  The multi-device run happens in a subprocess because
``XLA_FLAGS=--xla_force_host_platform_device_count`` must be exported
before jax is imported.
"""
from __future__ import annotations

import os
import subprocess
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from .common import ROW_ELEMS, Region, key_stream

SHARDED_DEVICES = 8
# The sharded store protects this many separately-sharded leaves (= vilamb
# groups).  One group would hide the regression this row guards: the
# blocking tick pays a dispatch + host round trip per GROUP, the pipelined
# tick one batched program per tick regardless of the group count.
SHARDED_GROUPS = 8


def _measure(mode: str, pipelined: bool, steps: int, n_rows: int,
             batch: int, period: int):
    r = Region(n_rows=n_rows, mode=mode, period=period, pipelined=pipelined)
    warm = 2 * period + 1
    keys = key_stream("uniform", steps + warm + 1, batch, n_rows)
    vals = jnp.ones((batch, ROW_ELEMS), jnp.float32)
    heap, red = r.heap, r.red
    heap, red = r.write(heap, red, keys[0], vals)
    if r.store.has_periodic:
        red = r.store.flush({"heap": heap}, red)
    # Identical untimed warm loop for every mode: two full periods of
    # write+tick (covers compilation of the due-tick update program and,
    # for the pipelined variant, the resolver-thread spin-up), then a
    # settle so each timed window starts from the same quiescent state.
    for i, rows in enumerate(keys[1:warm + 1], 1):
        heap, red = r.write(heap, red, rows, vals)
        red, _ = r.store.tick({"heap": heap}, red, i)
    red = r.store.settle(red, {"heap": heap})
    jax.block_until_ready((heap, jax.tree.leaves(red)))
    ticks = []
    t0 = time.perf_counter()
    for i, rows in enumerate(keys[warm + 1:], warm + 1):
        heap, red = r.write(heap, red, rows, vals)
        s0 = time.perf_counter()
        red, _ = r.store.tick({"heap": heap}, red, i)
        ticks.append(time.perf_counter() - s0)
    red = r.store.settle(red, {"heap": heap})
    jax.block_until_ready((heap, jax.tree.leaves(red)))
    wall_us = (time.perf_counter() - t0) / steps * 1e6
    t = np.asarray(ticks) * 1e6
    return float(t.mean()), float(np.percentile(t, 99)), wall_us


def _measure_sharded(pipelined, steps: int, n_rows: int, batch: int,
                     period: int, mode: str = "vilamb"):
    """One sharded stall measurement (runs inside the 8-device child).

    The store protects ``SHARDED_GROUPS`` separately-sharded leaves —
    the shape a real train/serve state has — so every due tick is a
    *multi-group* tick: the blocking path pays one dispatch + host
    round trip per group, the pipelined path one batched program for
    all of them with the fit fetch on the resolver thread.
    """
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.core import ProtectedStore, RedundancyPolicy
    from repro.launch.mesh import make_mesh

    mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
    spec = P(("pod", "data", "model"), None)
    g_rows, g_batch = n_rows // SHARDED_GROUPS, batch // SHARDED_GROUPS
    names = [f"heap{k}" for k in range(SHARDED_GROUPS)]
    pol = RedundancyPolicy.single(mode, period_steps=period,
                                  async_tick=pipelined)
    store = ProtectedStore(pol, mesh=mesh).attach(
        {nm: jax.ShapeDtypeStruct((g_rows, ROW_ELEMS), jnp.float32)
         for nm in names},
        specs={nm: spec for nm in names})
    leaves = {nm: jax.device_put(
        jax.random.normal(jax.random.PRNGKey(k), (g_rows, ROW_ELEMS),
                          jnp.float32), NamedSharding(mesh, spec))
        for k, nm in enumerate(names)}
    red = store.init(leaves) if store.protects else {}
    rng = np.random.default_rng(0)
    warm = 2 * period + 1
    all_rows = [jnp.asarray(np.sort(rng.choice(g_rows, g_batch,
                                               replace=False)))
                for _ in range(steps + warm + 1)]

    # The documented write path: on_write is traceable and belongs INSIDE
    # the caller's jitted mutation step (train/serve do exactly this) —
    # one program per step, not 2 eager ops + a dirty-mark per leaf.
    @jax.jit
    def write_step(leaves, red, rows):
        leaves = {nm: v.at[rows].add(1.0) for nm, v in leaves.items()}
        if store.protects:
            ev = jnp.zeros((g_rows,), bool).at[rows].set(True)
            red = store.on_write(red, events={nm: ev for nm in names})
        return leaves, red

    def one_step(leaves, red, i, rows, ticks=None):
        leaves, red = write_step(leaves, red, rows)
        s0 = time.perf_counter()
        red, _ = store.tick(leaves, red, i)
        if ticks is not None:
            ticks.append(time.perf_counter() - s0)
        return leaves, red

    leaves = {nm: v.at[all_rows[0]].add(1.0) for nm, v in leaves.items()}
    if store.has_periodic:
        red = store.flush(leaves, red)
    # Same untimed warm loop as the single-device harness: blocking and
    # pipelined both compile their due-tick programs (for pipelined, the
    # one batched multi-group dispatch) and settle before timing.
    for i, rows in enumerate(all_rows[1:warm + 1], 1):
        leaves, red = one_step(leaves, red, i, rows)
    if store.protects:
        red = store.settle(red, leaves)
    jax.block_until_ready((leaves, jax.tree.leaves(red)))
    ticks = []
    t0 = time.perf_counter()
    for i, rows in enumerate(all_rows[warm + 1:], warm + 1):
        leaves, red = one_step(leaves, red, i, rows, ticks)
    if store.protects:
        red = store.settle(red, leaves)
    jax.block_until_ready((leaves, jax.tree.leaves(red)))
    wall_us = (time.perf_counter() - t0) / steps * 1e6
    t = np.asarray(ticks) * 1e6
    return float(t.mean()), float(np.percentile(t, 99)), wall_us


def sharded_child(steps: int, n_rows: int, batch: int, period: int) -> None:
    """Child entry: print the sharded CSV rows (stdout is the protocol)."""
    n = _measure_sharded(True, steps, n_rows, batch, period, mode="none")
    b = _measure_sharded(False, steps, n_rows, batch, period)
    p = _measure_sharded(True, steps, n_rows, batch, period)
    # The ratio is computed from the MEANS — the same statistic the
    # tick_stall_* value column prints — with the p99-based ratio quoted
    # alongside, so the guarded number and the printed numbers agree.
    noise_us = 5.0
    ratio = max(b[0] - n[0], noise_us) / max(p[0] - n[0], noise_us)
    ratio99 = max(b[1] - n[1], noise_us) / max(p[1] - n[1], noise_us)
    dev = (f"{SHARDED_DEVICES} host devices, {SHARDED_GROUPS} vilamb "
           "groups, per-shard queues")
    g = f"{SHARDED_GROUPS}g"
    for name, us, derived in (
            ("overlap_sharded/tick_stall_none", n[0],
             f"mean {n[0]:.0f} / p99 {n[1]:.0f} us (baseline; {dev})"),
            (f"overlap_sharded/tick_stall_blocking_{g}", b[0],
             f"mean {b[0]:.0f} / p99 {b[1]:.0f} us; one dispatch + "
             "queue_fits round trip PER GROUP each due tick"),
            (f"overlap_sharded/tick_stall_pipelined_{g}", p[0],
             f"mean {p[0]:.0f} / p99 {p[1]:.0f} us; ONE batched "
             "multi-group program, fit fetch+fold on the resolver thread"),
            ("overlap_sharded/overhead_reduction", 0.0,
             f"{ratio:.2f}x sharded stall cut from means "
             f"(p99-based {ratio99:.2f}x; bar: >= 2x)"),
            ("overlap_sharded/endtoend_none", n[2], "wall us/step"),
            ("overlap_sharded/endtoend_blocking", b[2],
             "wall us/step (device-bound on shared-CPU container)"),
            ("overlap_sharded/endtoend_pipelined", p[2],
             "wall us/step (identical device work by construction)")):
        print(f"{name},{us:.2f},{derived}")


def _sharded_rows(steps: int, n_rows: int, batch: int, period: int):
    """Spawn the multi-device child and parse its CSV rows.

    Paths are anchored off ``__file__`` (never the caller's cwd) so the
    rows survive ``python -m benchmarks.run`` launched from anywhere.
    """
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(
        os.environ,
        XLA_FLAGS=f"--xla_force_host_platform_device_count={SHARDED_DEVICES}",
        PYTHONPATH=os.path.join(root, "src") + os.pathsep
        + os.environ.get("PYTHONPATH", ""))
    cmd = [sys.executable, "-m", "benchmarks.overlap", "--sharded-child",
           str(steps), str(n_rows), str(batch), str(period)]
    try:
        r = subprocess.run(cmd, env=env, capture_output=True, text=True,
                           timeout=1800, cwd=root)
    except Exception as e:  # keep the harness running without the rows
        return [("overlap_sharded/ERROR", 0.0, f"spawn failed: {e}")]
    if r.returncode != 0:
        return [("overlap_sharded/ERROR", 0.0,
                 f"exit {r.returncode}: {r.stderr.strip()[-200:]}")]
    rows = []
    for line in r.stdout.splitlines():
        if line.startswith("overlap_sharded/"):
            name, us, derived = line.split(",", 2)
            rows.append((name, float(us), derived))
    return rows


def run(steps: int = 240, n_rows: int = 4096, batch: int = 32,
        period: int = 4, repeats: int = 2, sharded_steps: int = 60,
        sharded_rows: int = 16384, sharded_batch: int = 512):
    best = {}
    for name, mode, pipelined in (("none", "none", True),
                                  ("blocking", "vilamb", False),
                                  ("pipelined", "vilamb", True)):
        runs = [_measure(mode, pipelined, steps, n_rows, batch, period)
                for _ in range(repeats)]
        best[name] = min(runs, key=lambda x: x[0])   # least-noise run
    n, b, p = best["none"], best["blocking"], best["pipelined"]
    # Floor both stalls at the timer/scheduler noise level so a lucky run
    # where the pipelined mean dips below the baseline cannot report an
    # unbounded (meaningless) reduction.  The headline ratio uses the
    # MEANS — the statistic the tick_stall_* value column prints — and
    # the derived string quotes the p99-based ratio next to it.
    noise_us = 5.0
    ratio = max(b[0] - n[0], noise_us) / max(p[0] - n[0], noise_us)
    ratio99 = max(b[1] - n[1], noise_us) / max(p[1] - n[1], noise_us)
    return [
        ("overlap/tick_stall_none", n[0],
         f"mean {n[0]:.0f} / p99 {n[1]:.0f} us (baseline)"),
        ("overlap/tick_stall_blocking", b[0],
         f"mean {b[0]:.0f} / p99 {b[1]:.0f} us; queue_fits round trip "
         "each due tick"),
        ("overlap/tick_stall_pipelined", p[0],
         f"mean {p[0]:.0f} / p99 {p[1]:.0f} us; sync-free speculative "
         "dispatch"),
        ("overlap/overhead_reduction", 0.0,
         f"{ratio:.2f}x foreground stall cut from means "
         f"(p99-based {ratio99:.2f}x; bar: >= 2x)"),
        ("overlap/endtoend_none", n[2], "wall us/step"),
        ("overlap/endtoend_blocking", b[2],
         "wall us/step (device-bound on shared-CPU container)"),
        ("overlap/endtoend_pipelined", p[2],
         "wall us/step (identical device work by construction)"),
    ] + _sharded_rows(sharded_steps, sharded_rows, sharded_batch, period)


if __name__ == "__main__":
    if len(sys.argv) > 1 and sys.argv[1] == "--sharded-child":
        sharded_child(*map(int, sys.argv[2:6]))
    else:
        from .common import emit
        emit(run())
