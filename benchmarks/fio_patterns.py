"""Fig. 8 analogue: raw-buffer read/write with uniform/seq/zipf patterns.

fio treats the DAX file as raw bytes — no transactional API, which is
exactly the workload Pangolin cannot serve (its programming-model
restriction); Vilamb attaches transparently. We therefore compare
No-Redundancy vs Vilamb at several update periods, as the paper does.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from .common import Region, emit, key_stream


def run(steps: int = 24, n_rows: int = 4096, batch: int = 64):
    rows = []
    vals = jnp.full((batch, 1024), 3.0, jnp.float32)
    results = {}
    for pattern in ("uniform", "seq", "zipf"):
        for mode, period in (("none", 0), ("vilamb", 2), ("vilamb", 8), ("vilamb", 32)):
            r = Region(n_rows=n_rows, mode=mode, period=max(period, 1))
            keys = key_stream(pattern, steps + 1, batch, n_rows)
            dt = r.run_writes(keys, vals)
            tput = steps * batch * 4096 / dt / 2**20  # MiB/s written
            results[(pattern, mode, period)] = tput
            tag = mode if mode == "none" else f"vilamb_p{period}"
            rows.append((f"fig8_fio_write/{pattern}/{tag}", dt / steps * 1e6,
                         f"{tput:.0f} MiB/s"))
        # read-only: dirty-bit checking cost only
        r = Region(n_rows=n_rows, mode="vilamb", period=8)
        keys = key_stream(pattern, steps + 1, batch, n_rows)
        out = r.read(r.heap, keys[0]); jax.block_until_ready(out)
        r.red = r.red_step(r.heap, r.red)  # warm the periodic pass
        t0 = time.perf_counter()
        for i in range(1, steps + 1):
            out = r.read(r.heap, keys[i])
            if i % 8 == 0:
                r.red = r.red_step(r.heap, r.red)
        jax.block_until_ready(out)
        dt = time.perf_counter() - t0
        rows.append((f"fig8_fio_read/{pattern}/vilamb_p8", dt / steps * 1e6,
                     f"{steps*batch*4096/dt/2**20:.0f} MiB/s"))
    for pattern in ("uniform", "seq", "zipf"):
        ovh = 1 - results[(pattern, "vilamb", 32)] / results[(pattern, "none", 0)]
        rows.append((f"fig8_fio_write/{pattern}/overhead_p32", 0.0, f"{ovh*100:.1f}%"))
    return rows


if __name__ == "__main__":
    emit(run())
