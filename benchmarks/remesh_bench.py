"""Elastic remesh + degraded-mode reads: what elasticity costs.

The remesh (repro.remesh) re-stripes every protected leaf onto a grown or
shrunk mesh over bounded per-tick migration windows — the foreground never
stops.  Degraded reads (``ProtectedStore.read_verified``) trade a
verification/reconstruction pass for never returning stale bytes.  Rows:

  * ``remesh/migrate_ticks`` (multi-device child) — ticks to migrate a
    store across a 4 -> 8 device grow at the configured
    ``remesh_bytes_per_tick`` budget (the pinned bound is
    ``ceil(moved_blocks / window)``).
  * ``remesh/throughput`` — MB/s re-striped while the foreground kept
    writing into migrating blocks.
  * ``remesh/stall`` — foreground step wall during vs before the
    migration: the bounded per-tick stall the budget buys.
  * ``remesh/degraded_read`` — wall per ``read_verified`` call on clean
    blocks (the verify-before-return floor).
  * ``remesh/degraded_read_recon`` — wall per call when the block must be
    parity-reconstructed first (the degraded path proper).

The multi-device leg runs in a subprocess (``--sharded-child``) because
``XLA_FLAGS=--xla_force_host_platform_device_count`` must be exported
before jax is imported — same protocol as benchmarks/scrub_bench.py.
"""
from __future__ import annotations

import os
import subprocess
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from .common import ROW_ELEMS, Region, key_stream

SHARDED_DEVICES = 8
ROW_BYTES = ROW_ELEMS * 4


def _measure_degraded_read(n_rows: int, iters: int):
    from repro.faults.inject import FaultSpec, apply_fault
    r = Region(n_rows=n_rows, mode="vilamb", period=4)
    heap, red = r.heap, r.red
    red = r.store.flush({"heap": heap}, red)
    blocks = list(range(0, min(8, n_rows)))
    r.store.read_verified({"heap": heap}, red, "heap", blocks)   # warm
    t0 = time.perf_counter()
    for _ in range(iters):
        r.store.read_verified({"heap": heap}, red, "heap", blocks)
    clean_us = (time.perf_counter() - t0) / iters * 1e6
    # Corrupt one block per probed stripe: every call reconstructs.
    lv, red2 = {"heap": heap}, red
    lv, red2 = apply_fault(r.store.metas, lv, red2, FaultSpec(
        "data_bitflip", "heap", block=0, lane=3, bit=5))
    t0 = time.perf_counter()
    for _ in range(iters):
        r.store.read_verified(lv, red2, "heap", [0])
    recon_us = (time.perf_counter() - t0) / iters * 1e6
    return clean_us, recon_us, len(blocks)


def sharded_child(steps: int, n_rows: int, batch: int, period: int) -> None:
    """Child entry: grow-migration rows (stdout CSV is the protocol)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.core import ProtectedStore, RedundancyPolicy
    from repro.launch.mesh import make_mesh

    old = make_mesh((1, 2, 2), ("pod", "data", "model"))
    new = make_mesh((2, 2, 2), ("pod", "data", "model"))
    spec = P(("pod", "data", "model"), None)
    budget_blocks = max(8, n_rows // 16)
    pol = RedundancyPolicy.single(
        "vilamb", period_steps=period, lanes_per_block=1024,
        stripe_data_blocks=4, work_queue_frac=0.0, precompile=False,
        remesh_bytes_per_tick=budget_blocks * ROW_BYTES)
    heap = jnp.zeros((n_rows, ROW_ELEMS), jnp.float32)
    store = ProtectedStore(pol, mesh=old).attach(
        {"heap": heap}, specs={"heap": spec})
    heap = jax.device_put(heap, NamedSharding(old, spec))
    red = store.init({"heap": heap})
    batch = min(batch, n_rows // 8)
    keys = key_stream("uniform", 4 * steps + 8, batch, n_rows)
    vals = jnp.ones((batch, ROW_ELEMS), jnp.float32)

    def write(heap, red, rows):
        heap = heap.at[rows].set(vals)
        mask = jnp.zeros((n_rows,), bool).at[rows].set(True)
        return heap, store.on_write(red, events={"heap": mask})

    step = 0
    for i in range(4):   # warm the programs
        heap, red = write(heap, red, keys[i])
        red, _ = store.tick({"heap": heap}, red, step); step += 1
    red = store.flush({"heap": heap}, red, step)

    # Baseline foreground wall per step on the old mesh.
    jax.block_until_ready(heap)
    t0 = time.perf_counter()
    for i in range(steps):
        heap, red = write(heap, red, keys[4 + i])
        red, rep = store.tick({"heap": heap}, red, step); step += 1
    jax.block_until_ready(heap)
    before_us = (time.perf_counter() - t0) / steps * 1e6

    # Grow 4 -> 8 while the foreground keeps writing into migrating rows.
    store.remesh(new)
    status = None
    t0 = time.perf_counter()
    i = 0
    while store.remeshing and i < 8 * steps:
        heap, red = write(heap, red, keys[4 + steps + i])
        red, rep = store.tick({"heap": heap}, red, step); step += 1
        if rep.remesh is not None:
            status = rep.remesh
        if rep.repaired:
            heap = rep.repaired.get("heap", heap)
        i += 1
    jax.block_until_ready(heap)
    during_us = (time.perf_counter() - t0) / max(i, 1) * 1e6
    if status is None or not status.done:
        print("remesh/migrate_ERROR,0.0,migration did not finish in budget")
        return
    moved_bytes = n_rows * ROW_BYTES
    wall_s = during_us * 1e-6 * i
    mb_s = moved_bytes / max(wall_s, 1e-9) / 1e6
    stall = during_us / max(before_us, 1e-9)
    for name, us, derived in (
            ("remesh/migrate_ticks", 0.0,
             f"{status.ticks} ticks to re-stripe {moved_bytes >> 10} KiB "
             f"across a 4 -> {SHARDED_DEVICES} device grow "
             f"(window {budget_blocks} blocks/tick)"),
            ("remesh/throughput", during_us,
             f"{mb_s:.2f} MB/s re-striped while the foreground wrote "
             "into migrating blocks"),
            ("remesh/stall", 0.0,
             f"{stall:.2f}x foreground step wall during migration "
             f"(before {before_us:.0f} us -> during {during_us:.0f} us)")):
        print(f"{name},{us:.2f},{derived}")


def _sharded_rows(steps: int, n_rows: int, batch: int, period: int):
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(
        os.environ,
        XLA_FLAGS=f"--xla_force_host_platform_device_count={SHARDED_DEVICES}",
        PYTHONPATH=os.path.join(root, "src") + os.pathsep
        + os.environ.get("PYTHONPATH", ""))
    cmd = [sys.executable, "-m", "benchmarks.remesh_bench", "--sharded-child",
           str(steps), str(n_rows), str(batch), str(period)]
    try:
        r = subprocess.run(cmd, env=env, capture_output=True, text=True,
                           timeout=1800, cwd=root)
    except Exception as e:  # keep the harness running without the rows
        return [("remesh/migrate_ERROR", 0.0, f"spawn failed: {e}")]
    if r.returncode != 0:
        return [("remesh/migrate_ERROR", 0.0,
                 f"exit {r.returncode}: {r.stderr.strip()[-200:]}")]
    rows = []
    for line in r.stdout.splitlines():
        if line.startswith("remesh/"):
            name, us, derived = line.split(",", 2)
            rows.append((name, float(us), derived))
    return rows


def run(steps: int = 24, n_rows: int = 2048, batch: int = 32,
        period: int = 4, read_iters: int = 20, sharded_steps: int = 16,
        sharded_rows: int = 256):
    clean_us, recon_us, nb = _measure_degraded_read(
        min(n_rows, 512), read_iters)
    rows = [
        ("remesh/degraded_read", clean_us,
         f"verified read of {nb} clean 4 KiB blocks (wall us/call)"),
        ("remesh/degraded_read_recon", recon_us,
         "verified read with parity reconstruction of 1 corrupt block"),
    ]
    return rows + _sharded_rows(sharded_steps, sharded_rows, batch, period)


if __name__ == "__main__":
    if len(sys.argv) > 1 and sys.argv[1] == "--sharded-child":
        sharded_child(*map(int, sys.argv[2:6]))
    else:
        from .common import emit
        emit(run())
