"""Fig. 4 analogue: YCSB A/B/C mixes (Redis -> region heap, zipf keys)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from .common import Region, emit, key_stream


def run(steps: int = 30, n_rows: int = 4096, batch: int = 512):
    # batch sized so per-step redundancy work dominates the CPU's fixed
    # ~0.1-1 ms jit-dispatch floor (the paper's Redis runs 10k+ ops/s where
    # that floor is irrelevant); overhead ratios are meaningful above it.
    rows = []
    vals = jnp.ones((batch, 1024), jnp.float32)
    mixes = {"ycsb_a": 0.5, "ycsb_b": 0.05, "ycsb_c": 0.0}  # update fraction
    results = {}
    for wl, upd_frac in mixes.items():
        wbatch = max(int(batch * upd_frac), 0)
        for mode, period in (("none", 0), ("sync", 0), ("vilamb", 4), ("vilamb", 16)):
            r = Region(n_rows=n_rows, mode=mode, period=max(period, 1))
            wkeys = key_stream("zipf", steps + 1, max(wbatch, 1), n_rows, seed=1)
            rkeys = key_stream("zipf", steps + 1, batch - wbatch or 1, n_rows, seed=2)
            wv = vals[:max(wbatch, 1)]
            heap, red = r.heap, r.red
            heap, red = r.write(heap, red, wkeys[0], wv)
            _ = r.read(heap, rkeys[0])
            if mode == "vilamb":  # warm the periodic pass (compile != work)
                red = r.red_step(heap, red)
            jax.block_until_ready(heap)
            t0 = time.perf_counter()
            for i in range(1, steps + 1):
                if wbatch:
                    heap, red = r.write(heap, red, wkeys[i], wv)
                out = r.read(heap, rkeys[i])
                if mode == "vilamb" and i % r.period == 0:
                    red = r.red_step(heap, red)
            jax.block_until_ready(out)
            dt = time.perf_counter() - t0
            ops = steps * batch / dt
            name = f"fig4_{wl}/{mode}{'' if mode != 'vilamb' else f'_p{period}'}"
            rows.append((name, dt / steps * 1e6, f"{ops:.0f} ops/s"))
            results[(wl, mode, period)] = ops
    for wl in mixes:
        ovh_v = 1 - results[(wl, "vilamb", 16)] / results[(wl, "none", 0)]
        ovh_s = 1 - results[(wl, "sync", 0)] / results[(wl, "none", 0)]
        rows.append((f"fig4_{wl}/overhead", 0.0,
                     f"vilamb_p16 {ovh_v*100:.1f}% vs pangolin {ovh_s*100:.1f}%"))
    return rows


if __name__ == "__main__":
    emit(run())
