"""Timing-breakdown analogue (paper §4.6 'design decisions'): fused
one-pass checksum+parity vs separate passes, and HLO bytes-accessed proof
that the fused kernel halves the memory term (the dominant roofline term of
the redundancy step)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from .common import emit
from repro.core import checksum as C, parity as P
from repro.kernels.redundancy import ref as rref


def _bytes_accessed(fn, *args):
    c = jax.jit(fn).lower(*args).compile()
    ca = c.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    return float(ca.get("bytes accessed", 0.0))


def run(nb: int = 512, L: int = 1024):
    rows = []
    lanes = jax.random.randint(jax.random.PRNGKey(0), (nb, L), 0, 2**31 - 1, jnp.uint32)
    bd = jnp.ones((nb,), bool)
    sd = jnp.ones((nb // 4,), bool)
    old_c = jnp.zeros((nb,), jnp.uint32)
    old_p = jnp.zeros((nb // 4, L), jnp.uint32)

    def split_pass(lanes):
        return C.block_checksums(lanes), P.stripe_parity(lanes, 4)

    def fused_pass(lanes):
        return rref.fused_update(lanes, old_c, old_p, bd, sd, 4)

    b_split = _bytes_accessed(split_pass, lanes)
    # The one-pass fused kernel (kernels/redundancy) reads each stripe once
    # and emits both outputs; its traffic is analytic (the CPU cost model
    # cannot see inside a Pallas kernel, and the jnp reference is the
    # paper-faithful two-pass loop by construction):
    b_fused = lanes.size * 4 + (nb // 4) * L * 4 + nb * 4
    rows.append(("kernel/bytes_split_pass_measured", 0.0, f"{b_split:.3e} B"))
    rows.append(("kernel/bytes_fused_kernel_analytic", 0.0,
                 f"{b_fused:.3e} B ({b_split/b_fused:.2f}x less traffic fused)"))

    for name, fn in (("split", split_pass), ("fused", fused_pass)):
        f = jax.jit(fn)
        out = f(lanes); jax.block_until_ready(out)
        t0 = time.perf_counter()
        for _ in range(20):
            out = f(lanes)
        jax.block_until_ready(out)
        rows.append((f"kernel/{name}_wall", (time.perf_counter() - t0) / 20 * 1e6,
                     f"{nb} pages"))
    return rows


if __name__ == "__main__":
    emit(run())
