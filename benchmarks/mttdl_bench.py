"""§4.8 analogue: MTTDL uplift from measured vulnerable stripes AND
measured scrub detection latencies.

Reproduces the paper's trend table: shorter update periods -> fewer
vulnerable stripes -> larger MTTDL uplift over No-Redundancy; read-heavy
workloads see larger uplifts than write-heavy ones.

The ``mttdl/measured/*`` rows go beyond the closed form: the fault
injector (repro.faults) corrupts clean blocks mid-run, scheduled scrubs
detect them, and the measured latencies + wall step time feed
:func:`repro.core.mttdl.mttdl_measured` — MTTDL grounded in what the
system actually detected, not what the formula assumes.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from .common import Region, STRIPE, emit, key_stream
from repro.core import mttdl

# Arbitrary-but-fixed per-block MTTF: uplifts/ratios are the signal, the
# absolute scale cancels (same convention as the closed-form rows).
MTTF_BLOCK_S = 1.0e9


def run_measured(n_rows: int = 4096, steps: int = 40, batch: int = 64,
                 scrub_period: int = 8, n_faults: int = 6):
    """Measured-detection MTTDL: inject -> scrub-detect -> mttdl_measured."""
    from repro.faults.inject import FaultSpec
    from repro.faults.oracle import measure_detection_latency

    r = Region(n_rows=n_rows, mode="vilamb", period=4)
    store = r.store
    # Writes stay in the lower half of the heap, so injected corruptions in
    # the upper half sit on provably-clean blocks: every one is detectable
    # and its latency is exactly "time to the next scheduled scrub".
    keys = key_stream("uniform", steps + 1, batch, n_rows // 2)
    vals = jnp.ones((batch, 1024), jnp.float32)
    inject_at = {}
    for i in range(n_faults):
        step = 3 + i * max(1, (steps - 6) // n_faults)
        blk = n_rows // 2 + i * (STRIPE + 1)    # one per stripe
        inject_at.setdefault(step, []).append(FaultSpec(
            kind="data_bitflip", leaf="heap", block=blk,
            lane=7 * (i + 1) % 1024, bit=(3 * i) % 32))

    vuln = []

    def drive(step, leaves, red):
        if step == 0:
            return {"heap": r.heap}, r.red
        heap, red = r.write(leaves["heap"], red, keys[step], vals)
        red, _ = store.tick({"heap": heap}, red, step)
        # V sampled at the exposure point (post-write), paper convention.
        vuln.append(int(store.dirty_stats(red)["heap"]["vulnerable_stripes"]))
        return {"heap": heap}, red

    t0 = time.perf_counter()
    records = measure_detection_latency(
        store, drive, inject_at, steps=steps, scrub_period=scrub_period)
    wall = time.perf_counter() - t0
    step_s = wall / max(steps, 1)
    lat = mttdl.detection_latency_stats(
        [rec.latency_steps for rec in records], step_seconds=step_s)
    detected = sum(1 for rec in records if rec.detected_step is not None)
    meta = r.meta
    v_avg = sum(vuln) / max(len(vuln), 1)   # time-averaged V over the run
    closed = mttdl.mttdl_vilamb(MTTF_BLOCK_S, max(v_avg, 1e-9), STRIPE + 1)
    measured = mttdl.mttdl_measured(
        MTTF_BLOCK_S, v_avg, STRIPE + 1, meta.n_stripes, lat["mean_s"])
    rows = [
        ("mttdl/measured/detection", 0.0,
         f"{detected}/{len(records)} injected corruptions detected; "
         f"mean latency {lat['mean_s'] * 1e3:.1f}ms "
         f"(max {lat['max_s'] * 1e3:.1f}ms, scrub every {scrub_period})"),
        ("mttdl/measured/empirical", 0.0,
         f"MTTDL {measured:.3g}s vs closed-form {closed:.3g}s "
         f"(ratio {measured / closed if closed else 0:.3f}; "
         f"V_avg={v_avg:.1f}, latency-widened window)"),
    ]
    return rows, detected, len(records)


def run(n_rows: int = 8192, steps: int = 48):
    rows = []
    uplifts = {}
    for wl, batch in (("ycsb_a_like", 256), ("ycsb_b_like", 16)):
        for period in (1, 4, 16):
            r = Region(n_rows=n_rows, mode="vilamb", period=period)
            keys = key_stream("zipf", steps + 1, batch, n_rows)
            vals = jnp.ones((batch, 1024), jnp.float32)
            heap, red = r.heap, r.red
            vuln = []
            for i in range(steps):
                heap, red = r.write(heap, red, keys[i], vals)
                # sample V at the moment of exposure (after the write, before
                # the background pass) — the paper's vulnerable-window measure
                vuln.append(int(r.engine.dirty_stats(red)["heap"]["vulnerable_stripes"]))
                if (i + 1) % period == 0:
                    red = r.engine.redundancy_step({"heap": heap}, red)
            v_avg = sum(vuln) / len(vuln)
            up = mttdl.mttdl_uplift(r.meta.n_blocks, v_avg, STRIPE + 1)
            uplifts[(wl, period)] = up
            rows.append((f"mttdl/{wl}/period{period}", 0.0,
                         f"uplift {up:.1f}x (V_avg={v_avg:.1f})"))
    # paper-trend assertions surfaced as derived values
    a = uplifts[("ycsb_a_like", 1)] / max(uplifts[("ycsb_a_like", 16)], 1e-9)
    rows.append(("mttdl/trend_period", 0.0,
                 f"p1 vs p16 uplift ratio {a:.1f}x (paper: shorter period => higher MTTDL)"))
    b = uplifts[("ycsb_b_like", 1)] / max(uplifts[("ycsb_a_like", 1)], 1e-9)
    rows.append(("mttdl/trend_readheavy", 0.0,
                 f"read-heavy/write-heavy uplift ratio {b:.1f}x (paper: 74x vs 15x)"))
    measured_rows, detected, injected = run_measured(
        n_rows=min(n_rows, 4096), steps=max(steps // 2, 24))
    rows.extend(measured_rows)
    if detected != injected:
        rows.append(("mttdl/measured/WARN", 0.0,
                     f"only {detected}/{injected} injections detected — "
                     "scrub schedule or injector placement regressed"))
    return rows


if __name__ == "__main__":
    emit(run())
