"""§4.8 analogue: MTTDL uplift from measured vulnerable stripes.

Reproduces the paper's trend table: shorter update periods -> fewer
vulnerable stripes -> larger MTTDL uplift over No-Redundancy; read-heavy
workloads see larger uplifts than write-heavy ones.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import Region, STRIPE, emit, key_stream
from repro.core import mttdl


def run(n_rows: int = 8192, steps: int = 48):
    rows = []
    uplifts = {}
    for wl, batch in (("ycsb_a_like", 256), ("ycsb_b_like", 16)):
        for period in (1, 4, 16):
            r = Region(n_rows=n_rows, mode="vilamb", period=period)
            keys = key_stream("zipf", steps + 1, batch, n_rows)
            vals = jnp.ones((batch, 1024), jnp.float32)
            heap, red = r.heap, r.red
            vuln = []
            for i in range(steps):
                heap, red = r.write(heap, red, keys[i], vals)
                # sample V at the moment of exposure (after the write, before
                # the background pass) — the paper's vulnerable-window measure
                vuln.append(int(r.engine.dirty_stats(red)["heap"]["vulnerable_stripes"]))
                if (i + 1) % period == 0:
                    red = r.engine.redundancy_step({"heap": heap}, red)
            v_avg = sum(vuln) / len(vuln)
            up = mttdl.mttdl_uplift(r.meta.n_blocks, v_avg, STRIPE + 1)
            uplifts[(wl, period)] = up
            rows.append((f"mttdl/{wl}/period{period}", 0.0,
                         f"uplift {up:.1f}x (V_avg={v_avg:.1f})"))
    # paper-trend assertions surfaced as derived values
    a = uplifts[("ycsb_a_like", 1)] / max(uplifts[("ycsb_a_like", 16)], 1e-9)
    rows.append(("mttdl/trend_period", 0.0,
                 f"p1 vs p16 uplift ratio {a:.1f}x (paper: shorter period => higher MTTDL)"))
    b = uplifts[("ycsb_b_like", 1)] / max(uplifts[("ycsb_a_like", 1)], 1e-9)
    rows.append(("mttdl/trend_readheavy", 0.0,
                 f"read-heavy/write-heavy uplift ratio {b:.1f}x (paper: 74x vs 15x)"))
    return rows


if __name__ == "__main__":
    emit(run())
