"""§4.8 analogue: MTTDL uplift from measured vulnerable stripes AND
measured scrub detection latencies.

Reproduces the paper's trend table: shorter update periods -> fewer
vulnerable stripes -> larger MTTDL uplift over No-Redundancy; read-heavy
workloads see larger uplifts than write-heavy ones.

The ``mttdl/measured/*`` rows go beyond the closed form: the fault
injector (repro.faults) corrupts clean blocks mid-run, scheduled scrubs
detect them, and the measured latencies + wall step time feed
:func:`repro.core.mttdl.mttdl_measured` — MTTDL grounded in what the
system actually detected, not what the formula assumes.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from .common import Region, STRIPE, emit, key_stream
from repro.core import mttdl

# Arbitrary-but-fixed per-block MTTF: uplifts/ratios are the signal, the
# absolute scale cancels (same convention as the closed-form rows).
MTTF_BLOCK_S = 1.0e9


def run_measured(n_rows: int = 4096, steps: int = 40, batch: int = 64,
                 scrub_period: int = 8, n_faults: int = 6):
    """Measured-detection MTTDL: inject -> scrub-detect -> mttdl_measured."""
    from repro.faults.inject import FaultSpec
    from repro.faults.oracle import measure_detection_latency

    r = Region(n_rows=n_rows, mode="vilamb", period=4)
    store = r.store
    # Writes stay in the lower half of the heap, so injected corruptions in
    # the upper half sit on provably-clean blocks: every one is detectable
    # and its latency is exactly "time to the next scheduled scrub".
    keys = key_stream("uniform", steps + 1, batch, n_rows // 2)
    vals = jnp.ones((batch, 1024), jnp.float32)
    inject_at = {}
    for i in range(n_faults):
        step = 3 + i * max(1, (steps - 6) // n_faults)
        blk = n_rows // 2 + i * (STRIPE + 1)    # one per stripe
        inject_at.setdefault(step, []).append(FaultSpec(
            kind="data_bitflip", leaf="heap", block=blk,
            lane=7 * (i + 1) % 1024, bit=(3 * i) % 32))

    vuln = []

    def drive(step, leaves, red):
        if step == 0:
            return {"heap": r.heap}, r.red
        heap, red = r.write(leaves["heap"], red, keys[step], vals)
        red, _ = store.tick({"heap": heap}, red, step)
        # V sampled at the exposure point (post-write), paper convention.
        vuln.append(int(store.dirty_stats(red)["heap"]["vulnerable_stripes"]))
        return {"heap": heap}, red

    t0 = time.perf_counter()
    records = measure_detection_latency(
        store, drive, inject_at, steps=steps, scrub_period=scrub_period)
    wall = time.perf_counter() - t0
    step_s = wall / max(steps, 1)
    lat = mttdl.detection_latency_stats(
        [rec.latency_steps for rec in records], step_seconds=step_s)
    detected = sum(1 for rec in records if rec.detected_step is not None)
    meta = r.meta
    v_avg = sum(vuln) / max(len(vuln), 1)   # time-averaged V over the run
    closed = mttdl.mttdl_vilamb(MTTF_BLOCK_S, max(v_avg, 1e-9), STRIPE + 1)
    measured = mttdl.mttdl_measured(
        MTTF_BLOCK_S, v_avg, STRIPE + 1, meta.n_stripes, lat["mean_s"])
    rows = [
        ("mttdl/measured/detection", 0.0,
         f"{detected}/{len(records)} injected corruptions detected; "
         f"mean latency {lat['mean_s'] * 1e3:.1f}ms "
         f"(max {lat['max_s'] * 1e3:.1f}ms, scrub every {scrub_period})"),
        ("mttdl/measured/empirical", 0.0,
         f"MTTDL {measured:.3g}s vs closed-form {closed:.3g}s "
         f"(ratio {measured / closed if closed else 0:.3f}; "
         f"V_avg={v_avg:.1f}, latency-widened window)"),
    ]
    return rows, detected, len(records)


ROW_BYTES = 4096          # one 4 KiB block per heap row (common.ROW_ELEMS)


def run_patrolled(n_rows: int = 256, sweep_ticks: int = 8,
                  scrub_period: int = 240, n_faults: int = 2):
    """Patroller-vs-scheduled-scrub detection latency -> measured MTTDL.

    Deterministic by construction (``step_seconds=1.0``, settled store, one
    injection at a time): the with/without MTTDL ratio reduces to the
    latency ratio L_scheduled / L_patrol, so the >= 10x improvement the
    patroller claims is a property of the schedule, not of wall clock.

    Both phases run on a *settled* store (flushed, V = 0): the measured
    MTTDL is then purely the double-fault term ``S * (N*lam)^2 * L``, which
    is exactly the term detection latency controls.
    """
    from repro.faults.inject import FaultSpec

    def phase(patrol: bool):
        bytes_per_tick = (
            (n_rows // sweep_ticks) * ROW_BYTES if patrol else 0)
        r = Region(n_rows=n_rows, mode="vilamb", period=4,
                   patrol_bytes_per_tick=bytes_per_tick)
        store, heap, red = r.store, r.heap, r.red
        keys = key_stream("uniform", 9, 32, n_rows)
        vals = jnp.ones((32, 1024), jnp.float32)
        step = 0
        for i in range(8):                      # phase 1: live traffic
            heap, red = r.write(heap, red, keys[i], vals)
            red, _ = store.tick({"heap": heap}, red, step, scrub_period=0)
            step += 1
        red = store.flush({"heap": heap}, red, step)    # settle: V -> 0
        if patrol:          # one full sweep so the cursor cadence is known
            for _ in range(2 * sweep_ticks):
                red, _ = store.tick({"heap": heap}, red, step,
                                    scrub_period=0)
                step += 1
        latencies = []
        leaves = {"heap": heap}
        for i in range(n_faults):
            # Align injections just after a scheduled scrub would have
            # run, so the scheduled-scrub latency is ~ the full period
            # (the patroller's is ~ one sweep regardless).
            step = ((step // scrub_period) + 1) * scrub_period + 3
            blk = (i * 37) % r.meta.n_blocks
            spec = FaultSpec(kind="data_bitflip", leaf="heap", block=blk,
                             lane=11, bit=5)
            leaves, red = store.inject(leaves, red, spec)
            if patrol:
                store.patroller.expect_injection("heap", blk, step)
            inject_step = step
            detected = None
            for _ in range(2 * scrub_period):
                red, rep = store.tick(
                    leaves, red, step,
                    scrub_period=0 if patrol else scrub_period)
                if rep.repaired:
                    leaves = dict(leaves, **rep.repaired)
                if patrol:
                    if store.patroller.latencies and len(
                            store.patroller.latencies) > i:
                        detected = step
                elif rep.mismatches:
                    detected = step
                step += 1
                if detected is not None:
                    break
            if detected is None:
                return None, None
            latencies.append(detected - inject_step)
            if not patrol:
                # Scheduled scrub only detects; clear the corruption so the
                # next round starts clean (the patroller repaired its own).
                leaves, _, _ = store.repair(leaves, red,
                                            store.scrub(leaves, red))
        stats = mttdl.detection_latency_stats(latencies, step_seconds=1.0)
        v_avg = 0.0        # settled store during the detection phase
        m = mttdl.mttdl_measured_live(
            MTTF_BLOCK_S, v_avg, STRIPE + 1, r.meta.n_stripes,
            assumed_latency_seconds=stats["mean_s"], measured=stats)
        return stats, m

    with_stats, mttdl_with = phase(patrol=True)
    without_stats, mttdl_without = phase(patrol=False)
    rows = []
    if with_stats is None or without_stats is None:
        rows.append(("mttdl/patrol/WARN", 0.0,
                     "an injected corruption went undetected — patroller "
                     "sweep or scrub schedule regressed"))
        return rows
    rows.append(("mttdl/patrol/without", 0.0,
                 f"MTTDL {mttdl_without:.3g}s at scheduled-scrub latency "
                 f"{without_stats['mean_s']:.0f} steps "
                 f"(period {scrub_period})"))
    rows.append(("mttdl/patrol/with", 0.0,
                 f"MTTDL {mttdl_with:.3g}s at patrol latency "
                 f"{with_stats['mean_s']:.0f} steps "
                 f"(sweep {sweep_ticks} ticks)"))
    ratio = mttdl_with / mttdl_without if mttdl_without else float("inf")
    rows.append(("mttdl/patrol/improvement", 0.0,
                 f"{ratio:.1f}x measured-MTTDL improvement from the "
                 "patroller (acceptance floor: 10x)"))
    return rows


def run(n_rows: int = 8192, steps: int = 48):
    rows = []
    uplifts = {}
    for wl, batch in (("ycsb_a_like", 256), ("ycsb_b_like", 16)):
        for period in (1, 4, 16):
            r = Region(n_rows=n_rows, mode="vilamb", period=period)
            keys = key_stream("zipf", steps + 1, batch, n_rows)
            vals = jnp.ones((batch, 1024), jnp.float32)
            heap, red = r.heap, r.red
            vuln = []
            for i in range(steps):
                heap, red = r.write(heap, red, keys[i], vals)
                # sample V at the moment of exposure (after the write, before
                # the background pass) — the paper's vulnerable-window measure
                vuln.append(int(r.engine.dirty_stats(red)["heap"]["vulnerable_stripes"]))
                if (i + 1) % period == 0:
                    red = r.engine.redundancy_step({"heap": heap}, red)
            v_avg = sum(vuln) / len(vuln)
            up = mttdl.mttdl_uplift(r.meta.n_blocks, v_avg, STRIPE + 1)
            uplifts[(wl, period)] = up
            rows.append((f"mttdl/{wl}/period{period}", 0.0,
                         f"uplift {up:.1f}x (V_avg={v_avg:.1f})"))
    # paper-trend assertions surfaced as derived values
    a = uplifts[("ycsb_a_like", 1)] / max(uplifts[("ycsb_a_like", 16)], 1e-9)
    rows.append(("mttdl/trend_period", 0.0,
                 f"p1 vs p16 uplift ratio {a:.1f}x (paper: shorter period => higher MTTDL)"))
    b = uplifts[("ycsb_b_like", 1)] / max(uplifts[("ycsb_a_like", 1)], 1e-9)
    rows.append(("mttdl/trend_readheavy", 0.0,
                 f"read-heavy/write-heavy uplift ratio {b:.1f}x (paper: 74x vs 15x)"))
    measured_rows, detected, injected = run_measured(
        n_rows=min(n_rows, 4096), steps=max(steps // 2, 24))
    rows.extend(measured_rows)
    if detected != injected:
        rows.append(("mttdl/measured/WARN", 0.0,
                     f"only {detected}/{injected} injections detected — "
                     "scrub schedule or injector placement regressed"))
    rows.extend(run_patrolled(n_rows=min(n_rows, 256)))
    return rows


if __name__ == "__main__":
    emit(run())
