#!/usr/bin/env python
"""Benchmark regression guard: compare a fresh BENCH_*.json artifact against
the prior checked-in baseline, row by row, failing loudly on big slowdowns.

    python scripts/bench_guard.py BENCH_PR3.json --baseline BENCH_PR2.json

Rows are matched by ``name``; only rows present in both artifacts are
compared.  A row regresses when ``us_per_call`` grew by more than
``--tolerance`` (default 2.0x, override with env ``BENCH_GUARD_TOL``).
Rows below the ``--min-us`` noise floor in the *baseline* are skipped —
sub-100 us wall numbers on a shared CPU container are scheduler noise —
as are derived-only rows (``us_per_call == 0``).  Improvements are
reported but never fail.

``--require PATTERN`` (repeatable, fnmatch) asserts the fresh artifact
*contains* at least one row matching each pattern — a presence guard for
rows whose absence would silently drop coverage (e.g. the multi-device
``overlap/endtoend_*`` legs falling back to their ERROR row).

Exit status 1 on any regression or missing required row, so
``scripts/ci.sh`` fails the build.
"""
from __future__ import annotations

import argparse
import fnmatch
import json
import os
import sys


def load_rows(path: str) -> dict:
    with open(path) as f:
        doc = json.load(f)
    return {r["name"]: float(r["us_per_call"]) for r in doc["rows"]}


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("artifact", help="fresh benchmark JSON to check")
    p.add_argument("--baseline", required=True,
                   help="prior checked-in benchmark JSON")
    p.add_argument("--tolerance", type=float,
                   default=float(os.environ.get("BENCH_GUARD_TOL", "2.0")),
                   help="max allowed new/old us_per_call ratio (default 2.0;"
                        " env BENCH_GUARD_TOL overrides)")
    # Sub-150 us rows on the shared CPU container swing >3x between
    # identical runs (measured on fig1_insert/none/threads1); anything
    # below that floor is scheduler noise, not signal.
    p.add_argument("--min-us", type=float, default=150.0,
                   help="skip rows whose baseline is below this noise floor")
    p.add_argument("--require", action="append", default=[],
                   metavar="PATTERN",
                   help="fail unless the fresh artifact has >=1 row matching "
                        "this fnmatch pattern (repeatable)")
    args = p.parse_args(argv)

    new = load_rows(args.artifact)
    old = load_rows(args.baseline)

    missing = [pat for pat in args.require
               if not any(fnmatch.fnmatch(name, pat) for name in new)]
    if missing:
        print(f"bench_guard: {args.artifact} is missing required rows:")
        for pat in missing:
            print(f"  no row matches {pat!r}")
        return 1

    shared = sorted(set(new) & set(old))
    if not shared:
        print(f"bench_guard: no shared rows between {args.artifact} and "
              f"{args.baseline}; nothing to compare")
        return 0

    regressions, compared = [], 0
    print(f"bench_guard: {args.artifact} vs {args.baseline} "
          f"(tolerance {args.tolerance:.2f}x, noise floor {args.min_us:.0f} us)")
    for name in shared:
        o, n = old[name], new[name]
        if o <= 0 or n <= 0 or o < args.min_us:
            continue
        compared += 1
        ratio = n / o
        flag = ""
        if ratio > args.tolerance:
            flag = "  << REGRESSION"
            regressions.append((name, o, n, ratio))
        elif ratio < 1 / args.tolerance:
            flag = "  (improved)"
        print(f"  {name}: {o:.0f} -> {n:.0f} us  ({ratio:.2f}x){flag}")

    if regressions:
        print(f"\nbench_guard: {len(regressions)}/{compared} rows regressed "
              f"past {args.tolerance:.2f}x:")
        for name, o, n, ratio in regressions:
            print(f"  {name}: {o:.0f} -> {n:.0f} us ({ratio:.2f}x)")
        print("If intentional (e.g. a semantics trade), rerun with "
              "BENCH_GUARD_TOL=<higher> and justify in the PR.")
        return 1
    print(f"bench_guard: OK ({compared} rows within tolerance)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
