#!/usr/bin/env bash
# One-invocation reproducible verify: deps -> tier-1 tests (both tick
# modes) -> fault-injection battery -> smoke benchmark + guard.
#
#   bash scripts/ci.sh                 # full pipeline
#   SKIP_BENCH=1 bash scripts/ci.sh    # tests + fault battery only
#   CI_FULL_BOTH=1 bash scripts/ci.sh  # run the *entire* suite in both
#                                      # tick modes (default reruns only
#                                      # the redundancy-path files)
#
# The test suite runs even when pip / the network is unavailable: property
# tests fall back to the deterministic shim in tests/_hypothesis_fallback.py.
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== [1/5] dependencies (best-effort) =="
python -m pip install -q hypothesis 2>/dev/null \
    && echo "hypothesis installed" \
    || echo "pip/network unavailable - tests use the bundled fallback shim"

echo "== [2/5] tier-1 test suite (async_tick=1, the default) =="
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} REPRO_ASYNC_TICK=1 \
    python -m pytest -x -q

echo "== [3/5] tier-1 on the blocking tick (REPRO_ASYNC_TICK=0) =="
# Every policy that does not pass async_tick explicitly flips to the
# blocking tick, so crash-point and dispatch regressions hiding behind the
# overlap pipeline fail CI too.  Files that never construct a
# ProtectedStore are mode-invariant; rerunning them is pure waste, so the
# default second pass covers the redundancy surface only (CI_FULL_BOTH=1
# reruns everything).
if [ "${CI_FULL_BOTH:-0}" = "1" ]; then
  BLOCKING_TARGETS=(tests)
else
  # (test_faults.py is absent on purpose: its stores pin async_tick
  # explicitly, so the env lever is a no-op there — the fault battery in
  # step 4 covers that surface once.)
  BLOCKING_TARGETS=(tests/test_store.py tests/test_async_tick.py
                    tests/test_workqueue.py tests/test_engine.py
                    tests/test_recovery.py tests/test_ckpt.py
                    tests/test_system.py tests/test_mttdl.py
                    tests/test_perf_knobs.py)
fi
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} REPRO_ASYNC_TICK=0 \
    python -m pytest -x -q "${BLOCKING_TARGETS[@]}"

echo "== [4/5] fault-injection battery (crash sweep + oracle, 3 seeds) =="
# Deterministic crash-point replay over every pipelined-tick phase plus
# the vulnerability-window oracle; exit 1 on any unrecoverable crash,
# missed detection, or false positive (see docs/testing.md).
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m repro.faults --smoke

if [ "${SKIP_BENCH:-0}" != "1" ]; then
  echo "== [5/5] smoke benchmark (tiny shapes) + perf artifact + guard =="
  # insert_throughput exercises all three policies; dirty_cost sweeps the
  # work-queue dirty-fraction scaling; overlap measures the pipelined vs
  # blocking tick; mttdl_bench now also reports MTTDL from *measured*
  # scrub detection latencies (fault injector).  The JSON artifact
  # (BENCH_PR4.json) is the machine-readable perf trajectory — docs/perf.md.
  # --repeat 3: per-row best-of-N — the shared container's scheduler can
  # swing multi-ms rows >2x between identical runs; the minimum is stable
  # and a real regression raises it too.
  PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m benchmarks.run \
      --smoke --repeat 3 --only insert_throughput,dirty_cost,overlap,mttdl_bench \
      --json "${BENCH_JSON:-BENCH_PR4.json}"
  # Regression guard: compare key rows against the prior checked-in
  # artifact; >2x slowdowns fail the build (BENCH_GUARD_TOL overrides).
  PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python scripts/bench_guard.py \
      "${BENCH_JSON:-BENCH_PR4.json}" --baseline BENCH_PR3.json
fi
echo "== CI OK =="
