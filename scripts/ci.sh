#!/usr/bin/env bash
# One-invocation reproducible verify: deps -> tier-1 tests -> smoke benchmark.
#
#   bash scripts/ci.sh            # full tier-1 + smoke benchmark
#   SKIP_BENCH=1 bash scripts/ci.sh   # tests only
#
# The test suite runs even when pip / the network is unavailable: property
# tests fall back to the deterministic shim in tests/_hypothesis_fallback.py.
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== [1/3] dependencies (best-effort) =="
python -m pip install -q hypothesis 2>/dev/null \
    && echo "hypothesis installed" \
    || echo "pip/network unavailable - tests use the bundled fallback shim"

echo "== [2/3] tier-1 test suite =="
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q

if [ "${SKIP_BENCH:-0}" != "1" ]; then
  echo "== [3/3] smoke benchmark (tiny shapes) + perf artifact + guard =="
  # insert_throughput exercises all three policies; dirty_cost sweeps the
  # work-queue dirty-fraction scaling; overlap measures the pipelined vs
  # blocking tick.  The JSON artifact (BENCH_PR3.json) is the
  # machine-readable perf trajectory — see docs/perf.md.
  PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m benchmarks.run \
      --smoke --only insert_throughput,dirty_cost,overlap \
      --json "${BENCH_JSON:-BENCH_PR3.json}"
  # Regression guard: compare key rows against the prior checked-in
  # artifact; >2x slowdowns fail the build (BENCH_GUARD_TOL overrides).
  PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python scripts/bench_guard.py \
      "${BENCH_JSON:-BENCH_PR3.json}" --baseline BENCH_PR2.json
fi
echo "== CI OK =="
