#!/usr/bin/env bash
# One-invocation reproducible verify: deps -> tier-1 tests (both tick
# modes) -> multi-device sharded tier (both tick modes) -> fault-injection
# battery -> smoke benchmark + guard.
#
#   bash scripts/ci.sh                 # full pipeline
#   SKIP_BENCH=1 bash scripts/ci.sh    # tests + fault battery only
#   CI_FULL_BOTH=1 bash scripts/ci.sh  # run the *entire* suite in both
#                                      # tick modes (default reruns only
#                                      # the redundancy-path files)
#
# The test suite runs even when pip / the network is unavailable: property
# tests fall back to the deterministic shim in tests/_hypothesis_fallback.py.
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== [1/6] dependencies (best-effort) =="
python -m pip install -q hypothesis 2>/dev/null \
    && echo "hypothesis installed" \
    || echo "pip/network unavailable - tests use the bundled fallback shim"

echo "== [2/6] tier-1 test suite (async_tick=1, the default) =="
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} REPRO_ASYNC_TICK=1 \
    python -m pytest -x -q

echo "== [3/6] tier-1 on the blocking tick (REPRO_ASYNC_TICK=0) =="
# Every policy that does not pass async_tick explicitly flips to the
# blocking tick, so crash-point and dispatch regressions hiding behind the
# overlap pipeline fail CI too.  Files that never construct a
# ProtectedStore are mode-invariant; rerunning them is pure waste, so the
# default second pass covers the redundancy surface only (CI_FULL_BOTH=1
# reruns everything).
if [ "${CI_FULL_BOTH:-0}" = "1" ]; then
  BLOCKING_TARGETS=(tests)
else
  # (test_faults.py and test_health.py are absent on purpose: their
  # stores pin async_tick explicitly, so the env lever is a no-op there —
  # the fault battery + chaos soak in step 5 cover that surface once.)
  BLOCKING_TARGETS=(tests/test_store.py tests/test_async_tick.py
                    tests/test_workqueue.py tests/test_engine.py
                    tests/test_recovery.py tests/test_ckpt.py
                    tests/test_system.py tests/test_mttdl.py
                    tests/test_perf_knobs.py)
fi
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} REPRO_ASYNC_TICK=0 \
    python -m pytest -x -q "${BLOCKING_TARGETS[@]}"

echo "== [4/6] multi-device sharded tier (8 host devices, blocking tick) =="
# Both tick modes run over the sharded tier: step 2 (tier-1) already
# covers REPRO_ASYNC_TICK=1, so this leg adds only the blocking rerun —
# the env lever is inherited by the test subprocesses, and the queued x
# tick-mode matrix inside test_sharded.py additionally pins both modes
# explicitly.  The sharded tests export their own per-subprocess
# XLA_FLAGS=--xla_force_host_platform_device_count=8 (the flag must
# predate the jax import); the outer export covers any future sharded
# test that runs in-process.
XLA_FLAGS=--xla_force_host_platform_device_count=8 \
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} REPRO_ASYNC_TICK=0 \
    python -m pytest -x -q tests/test_sharded.py \
    tests/test_scrub.py tests/test_remesh.py -k sharded

echo "== [5/6] fault-injection battery (crash sweep + oracle + sharded) =="
# Deterministic crash-point replay over every pipelined-tick phase plus
# the vulnerability-window oracle, then the same oracle + crash subset on
# a 2x2x2 mesh-sharded store; exit 1 on any unrecoverable crash, missed
# detection, or false positive (see docs/testing.md).
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m repro.faults --smoke
# Chaos soak: seeded storm schedule (bitflips + crash + straggler storms
# + a mid-storm remesh/rebuild) under live traffic with the health
# governor on; exit 1 on any silent freshness excursion, a typed-but-
# unreported violation, or a non-bitwise post-storm recovery.
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m repro.faults --chaos --smoke

if [ "${SKIP_BENCH:-0}" != "1" ]; then
  echo "== [6/6] smoke benchmark (tiny shapes) + perf artifact + guard =="
  # insert_throughput exercises all three policies; dirty_cost sweeps the
  # work-queue dirty-fraction scaling; overlap measures the pipelined vs
  # blocking tick (now incl. the overlap_sharded/* mesh rows, spawned on 8
  # host devices); mttdl_bench reports MTTDL from *measured* scrub
  # detection latencies (fault injector + patroller); scrub_bench measures
  # the patroller's foreground overhead and the online shard-rebuild stall;
  # remesh_bench measures the elastic 4 -> 8 grow migration (throughput +
  # bounded foreground stall) and the degraded-read latency floor;
  # health_bench measures the governor's added tick stall on a healthy
  # store (acceptance: <= 5%) and the breaker's trip -> recover tick
  # count under a wedged dispatcher.
  # The JSON artifact (BENCH_PR10.json) is the machine-readable perf
  # trajectory — docs/perf.md.
  # --repeat 3: per-row best-of-N — the shared container's scheduler can
  # swing multi-ms rows >2x between identical runs; the minimum is stable
  # and a real regression raises it too.
  PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m benchmarks.run \
      --smoke --repeat 3 \
      --only insert_throughput,dirty_cost,overlap,mttdl_bench,scrub_bench,remesh_bench,health_bench \
      --json "${BENCH_JSON:-BENCH_PR10.json}"
  # Regression guard: compare key rows against the prior checked-in
  # artifact; >2x slowdowns fail the build (BENCH_GUARD_TOL overrides).
  # --require: the multi-device legs must actually produce their rows —
  # a spawn failure degrades to */ERROR rows, which must fail CI, not
  # silently drop coverage.  overlap_sharded/overhead_reduction is the
  # PR10 flagship row (pipelined must beat blocking on the mesh);
  # health/governor_overhead and chaos/recovery_ticks are derived rows
  # (us=0): presence-required, never time-guarded.
  PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python scripts/bench_guard.py \
      "${BENCH_JSON:-BENCH_PR10.json}" --baseline BENCH_PR8.json \
      --require 'overlap/endtoend_*' \
      --require 'overlap_sharded/overhead_reduction' \
      --require 'scrub/patrol_tick_*' \
      --require 'scrub/rebuild_ticks' --require 'mttdl/patrol/improvement' \
      --require 'remesh/migrate_ticks' --require 'remesh/throughput' \
      --require 'remesh/stall' --require 'remesh/degraded_read' \
      --require 'health/governor_overhead' --require 'chaos/recovery_ticks'
fi
echo "== CI OK =="
